"""L2 correctness: CP-ALS model vs dense references + algorithmic invariants.

- MTTKRP vs a dense einsum reference over the densified tensor
- distributed equivalence: per-rank mttkrp_only results sum to the full
  MTTKRP (the property that makes the rust coordinator's Allgatherv-as-sum
  gathering numerically exact)
- fit identity vs a direct dense Frobenius computation
- ALS monotone-ish convergence on low-rank-plus-noise data
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model

SETTINGS = dict(deadline=None, max_examples=10)
R = 16


def random_coo(rng, dims, nnz):
    i = rng.integers(0, dims[0], nnz).astype(np.int32)
    j = rng.integers(0, dims[1], nnz).astype(np.int32)
    k = rng.integers(0, dims[2], nnz).astype(np.int32)
    v = rng.normal(size=nnz).astype(np.float32)
    return v, i, j, k


def densify(dims, v, i, j, k):
    x = np.zeros(dims, np.float32)
    np.add.at(x, (i, j, k), v)
    return x


def factors(rng, dims, r=R, scale=0.3):
    return [jnp.asarray(rng.normal(size=(d, r)) * scale, jnp.float32)
            for d in dims]


def dense_mttkrp(x, fb, fc, mode):
    """Reference MTTKRP via einsum over the dense tensor."""
    fb, fc = np.asarray(fb), np.asarray(fc)
    if mode == 0:
        return np.einsum("ijk,jr,kr->ir", x, fb, fc)
    if mode == 1:
        return np.einsum("ijk,ir,kr->jr", x, fb, fc)
    return np.einsum("ijk,ir,jr->kr", x, fb, fc)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_mttkrp_mode0_matches_dense(seed):
    rng = np.random.default_rng(seed)
    dims, nnz = (64, 32, 32), 512
    v, i, j, k = random_coo(rng, dims, nnz)
    fa, fb, fc = factors(rng, dims)
    x = densify(dims, v, i, j, k)
    out = model.mttkrp_only(jnp.asarray(v), jnp.asarray(i), jnp.asarray(j),
                            jnp.asarray(k), fb, fc, out_rows=dims[0])
    np.testing.assert_allclose(out, dense_mttkrp(x, fb, fc, 0),
                               rtol=1e-3, atol=1e-3)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), mode=st.sampled_from([1, 2]))
def test_mttkrp_other_modes_match_dense(seed, mode):
    rng = np.random.default_rng(seed)
    dims, nnz = (64, 32, 32), 512
    v, i, j, k = random_coo(rng, dims, nnz)
    fa, fb, fc = factors(rng, dims)
    x = densify(dims, v, i, j, k)
    idx = [jnp.asarray(a) for a in (i, j, k)]
    if mode == 1:
        out = model.mttkrp_only(jnp.asarray(v), idx[1], idx[0], idx[2],
                                fa, fc, out_rows=dims[1])
        expect = dense_mttkrp(x, fa, fc, 1)
    else:
        out = model.mttkrp_only(jnp.asarray(v), idx[2], idx[0], idx[1],
                                fa, fb, out_rows=dims[2])
        expect = dense_mttkrp(x, fa, fb, 2)
    np.testing.assert_allclose(out, expect, rtol=1e-3, atol=1e-3)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), ranks=st.sampled_from([2, 4]))
def test_distributed_mttkrp_equals_full(seed, ranks):
    """Partial per-rank MTTKRPs (padded slices) sum to the full MTTKRP.

    This is the numerical contract the rust ReFacTo coordinator relies on:
    Allgatherv over disjoint row slices == elementwise sum of partials.
    """
    rng = np.random.default_rng(seed)
    dims, nnz = (64, 32, 32), 1024
    v, i, j, k = random_coo(rng, dims, nnz)
    _, fb, fc = factors(rng, dims)
    full = model.mttkrp_only(jnp.asarray(v), jnp.asarray(i), jnp.asarray(j),
                             jnp.asarray(k), fb, fc, out_rows=dims[0])
    # Split nonzeros by contiguous slices of mode 0 (DFacTo partition),
    # pad every slice to the same static length with val=0 entries.
    per_rank = nnz  # padded length (>= any slice)
    acc = np.zeros((dims[0], R), np.float32)
    bounds = np.linspace(0, dims[0], ranks + 1).astype(int)
    for rnk in range(ranks):
        mask = (i >= bounds[rnk]) & (i < bounds[rnk + 1])
        pv = np.zeros(per_rank, np.float32)
        pi = np.zeros(per_rank, np.int32)
        pj = np.zeros(per_rank, np.int32)
        pk = np.zeros(per_rank, np.int32)
        cnt = mask.sum()
        pv[:cnt], pi[:cnt], pj[:cnt], pk[:cnt] = v[mask], i[mask], j[mask], k[mask]
        part = model.mttkrp_only(jnp.asarray(pv), jnp.asarray(pi),
                                 jnp.asarray(pj), jnp.asarray(pk),
                                 fb, fc, out_rows=dims[0])
        acc += np.asarray(part)
    np.testing.assert_allclose(acc, np.asarray(full), rtol=1e-3, atol=1e-3)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_fit_identity_matches_dense(seed):
    """Sparse fit identity == direct dense Frobenius computation."""
    rng = np.random.default_rng(seed)
    dims, nnz = (32, 32, 16), 256
    v, i, j, k = random_coo(rng, dims, nnz)
    fa, fb, fc = factors(rng, dims)
    lam = jnp.asarray(rng.uniform(0.5, 2.0, R), jnp.float32)
    x = densify(dims, v, i, j, k)
    # NB: densify collapses duplicate coordinates; rebuild v from x so the
    # sparse and dense views agree exactly.
    ii, jj, kk = np.nonzero(x)
    vv = x[ii, jj, kk]
    n_pad = 512
    pv = np.zeros(n_pad, np.float32); pv[:len(vv)] = vv
    pi = np.zeros(n_pad, np.int32); pi[:len(ii)] = ii
    pj = np.zeros(n_pad, np.int32); pj[:len(jj)] = jj
    pk = np.zeros(n_pad, np.int32); pk[:len(kk)] = kk
    norm_x_sq = float((x ** 2).sum())
    fit = model.fit_only(jnp.float32(norm_x_sq), jnp.asarray(pv),
                         jnp.asarray(pi), jnp.asarray(pj), jnp.asarray(pk),
                         lam, fa, fb, fc)
    est = np.einsum("r,ir,jr,kr->ijk", np.asarray(lam), np.asarray(fa),
                    np.asarray(fb), np.asarray(fc))
    expect = 1.0 - np.linalg.norm(x - est) / np.linalg.norm(x)
    np.testing.assert_allclose(float(fit), expect, rtol=1e-3, atol=1e-3)


def test_als_converges_on_low_rank_data():
    """Fit increases (loss decreases) on a true low-rank tensor."""
    rng = np.random.default_rng(42)
    dims = (64, 32, 32)
    true = factors(rng, dims, r=4, scale=1.0)
    x = np.einsum("ir,jr,kr->ijk", *[np.asarray(f) for f in true])
    ii, jj, kk = np.nonzero(np.abs(x) > 0.5)
    vv = x[ii, jj, kk].astype(np.float32)
    n_pad = 1 << int(np.ceil(np.log2(max(len(vv), 512))))
    pv = np.zeros(n_pad, np.float32); pv[:len(vv)] = vv
    pi = np.zeros(n_pad, np.int32); pi[:len(ii)] = ii
    pj = np.zeros(n_pad, np.int32); pj[:len(jj)] = jj
    pk = np.zeros(n_pad, np.int32); pk[:len(kk)] = kk
    fa, fb, fc = factors(rng, dims)
    nx = jnp.float32((pv ** 2).sum())
    args = [jnp.asarray(a) for a in (pv, pi, pj, pk)]
    fits = []
    for _ in range(8):
        fa, fb, fc, lam, fit = model.als_sweep(*args, fb, fc, nx, dims=dims)
        fits.append(float(fit))
    assert fits[-1] > fits[0], fits
    assert fits[-1] > 0.5, fits  # low-rank data should be well explained


def test_normalize_columns_unit_norm():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(128, R)), jnp.float32)
    an, lam = model.normalize_columns(a)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(an), axis=0),
                               np.ones(R), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(an) * np.asarray(lam),
                               np.asarray(a), rtol=1e-4, atol=1e-5)


def test_normalize_columns_zero_column_safe():
    a = jnp.zeros((64, R), jnp.float32)
    an, lam = model.normalize_columns(a)
    assert np.all(np.isfinite(np.asarray(an)))
    assert np.all(np.asarray(lam) == 0.0)


def test_update_post_matches_inline_update():
    """factor_update_post == the update_mode path used inside als_sweep."""
    rng = np.random.default_rng(9)
    dims, nnz = (64, 32, 32), 512
    v, i, j, k = random_coo(rng, dims, nnz)
    _, fb, fc = factors(rng, dims)
    m = model.mttkrp_only(jnp.asarray(v), jnp.asarray(i), jnp.asarray(j),
                          jnp.asarray(k), fb, fc, out_rows=dims[0])
    a_post, lam_post = model.factor_update_post(m, fb, fc)
    a_ref, lam_ref = model.update_mode(jnp.asarray(v), jnp.asarray(i),
                                       jnp.asarray(j), jnp.asarray(k),
                                       fb, fc, dims[0])
    np.testing.assert_allclose(np.asarray(a_post), np.asarray(a_ref),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lam_post), np.asarray(lam_ref),
                               rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_spd_inverse_matches_linalg(seed):
    """Pure-HLO Gauss-Jordan inverse == jnp.linalg.inv on SPD matrices."""
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(16, 16)).astype(np.float32)
    v = a @ a.T + 0.1 * np.eye(16, dtype=np.float32)
    ours = model.spd_inverse(jnp.asarray(v))
    ref = np.linalg.inv(v)
    np.testing.assert_allclose(np.asarray(ours), ref, rtol=2e-2, atol=2e-3)


def test_spd_inverse_identity():
    eye = jnp.eye(16, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(model.spd_inverse(eye)), np.eye(16),
                               rtol=1e-5, atol=1e-6)
