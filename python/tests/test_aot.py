"""AOT artifact golden checks: shapes, entry computations, meta.json.

The rust runtime trusts meta.json to build input literals; these tests
pin the contract.
"""

import json
import os

import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_configs_are_block_aligned():
    for name, cfg in aot.CONFIGS.items():
        i, j, k = cfg["dims"]
        for d in (i, j, k):
            assert d % 32 == 0, (name, d)
        assert cfg["nnz"] % 64 == 0, name
        assert cfg["rank"] == 16


def test_lower_all_small_artifact_set():
    names = [n for n, _, _ in aot.lower_all("small", aot.CONFIGS["small"])]
    assert names == [
        "als_sweep_small",
        "mttkrp_mode0_small", "mttkrp_mode1_small", "mttkrp_mode2_small",
        "update_post_mode0_small", "update_post_mode1_small",
        "update_post_mode2_small",
        "fit_small",
    ]


def test_hlo_text_is_parseable_entry():
    """Every lowered computation must emit HLO text with an ENTRY block."""
    for name, lowered, meta in aot.lower_all("small", aot.CONFIGS["small"]):
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text, name
        assert "HloModule" in text, name
        # return_tuple=True: root of the entry computation is a tuple
        assert "tuple(" in text or "tuple" in text, name


def test_meta_shapes_match_model():
    cfg = aot.CONFIGS["small"]
    i_dim, j_dim, k_dim = cfg["dims"]
    n, r = cfg["nnz"], cfg["rank"]
    metas = {name: meta for name, _, meta in
             aot.lower_all("small", cfg)}
    sweep = metas["als_sweep_small"]
    in_shapes = [tuple(s["shape"]) for s in sweep["inputs"]]
    assert in_shapes == [(n,), (n,), (n,), (n,),
                         (j_dim, r), (k_dim, r), ()]
    out_shapes = [tuple(s["shape"]) for s in sweep["outputs"]]
    assert out_shapes == [(i_dim, r), (j_dim, r), (k_dim, r), (r,), ()]

    m0 = metas["mttkrp_mode0_small"]
    assert tuple(m0["outputs"][0]["shape"]) == (i_dim, r)
    m1 = metas["mttkrp_mode1_small"]
    assert tuple(m1["outputs"][0]["shape"]) == (j_dim, r)
    up2 = metas["update_post_mode2_small"]
    assert tuple(up2["inputs"][0]["shape"]) == (k_dim, r)
    assert tuple(up2["outputs"][1]["shape"]) == (r,)

    fit = metas["fit_small"]
    assert tuple(fit["outputs"][0]["shape"]) == ()


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "meta.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_built_artifacts_match_lowered_meta():
    with open(os.path.join(ART, "meta.json")) as f:
        index = json.load(f)
    for cfg_name in aot.CONFIGS:
        for name, _, meta in aot.lower_all(cfg_name, aot.CONFIGS[cfg_name]):
            assert name in index, name
            assert index[name]["inputs"] == meta["inputs"], name
            assert index[name]["outputs"] == meta["outputs"], name
            path = os.path.join(ART, index[name]["file"])
            assert os.path.exists(path), path
            head = open(path).read(200)
            assert "HloModule" in head, name


def test_auto_block_properties():
    assert model._auto_block(2048, 512) == 512
    assert model._auto_block(64, 512) == 64
    assert model._auto_block(96, 512) == 32
    assert model._auto_block(1, 512) == 1
    # always divides, never exceeds cap
    for dim in (32, 64, 100, 128, 4096):
        b = model._auto_block(dim, 256)
        assert dim % b == 0 and b <= 256
