"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes (block-multiple and auto-block), dtypes, and
value regimes; assert_allclose against compile.kernels.ref — the core
correctness signal for Layer 1 (kernels run with interpret=True; see
DESIGN.md).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gram import gram
from compile.kernels.krp_scale import krp_scale
from compile.kernels.matmul import matmul

SETTINGS = dict(deadline=None, max_examples=20)


def rand(rng, shape, dtype, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, dtype)


# ---------------------------------------------------------------------------
# krp_scale
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n_blocks=st.integers(1, 8),
    block_n=st.sampled_from([64, 128, 512]),
    r=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_krp_scale_matches_ref(n_blocks, block_n, r, seed):
    rng = np.random.default_rng(seed)
    n = n_blocks * block_n
    vals = rand(rng, (n,), jnp.float32)
    b = rand(rng, (n, r), jnp.float32)
    c = rand(rng, (n, r), jnp.float32)
    out = krp_scale(vals, b, c, block_n=block_n)
    np.testing.assert_allclose(out, ref.krp_scale_ref(vals, b, c), rtol=1e-6)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_krp_scale_bf16(seed):
    rng = np.random.default_rng(seed)
    n, r = 256, 16
    vals = rand(rng, (n,), jnp.bfloat16)
    b = rand(rng, (n, r), jnp.bfloat16)
    c = rand(rng, (n, r), jnp.bfloat16)
    out = krp_scale(vals, b, c, block_n=128)
    expect = ref.krp_scale_ref(vals, b, c)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_krp_scale_padding_entries_are_zero():
    """val=0 padding entries (the COO padding convention) produce 0 rows."""
    n, r = 128, 16
    rng = np.random.default_rng(0)
    vals = rand(rng, (n,), jnp.float32)
    vals = vals.at[n // 2:].set(0.0)
    b = rand(rng, (n, r), jnp.float32)
    c = rand(rng, (n, r), jnp.float32)
    out = krp_scale(vals, b, c, block_n=64)
    assert np.all(np.asarray(out[n // 2:]) == 0.0)


def test_krp_scale_rejects_unaligned():
    with pytest.raises(AssertionError):
        krp_scale(jnp.zeros(100), jnp.zeros((100, 8)), jnp.zeros((100, 8)),
                  block_n=64)


def test_krp_scale_single_block():
    rng = np.random.default_rng(7)
    vals = rand(rng, (512,), jnp.float32)
    b = rand(rng, (512, 16), jnp.float32)
    c = rand(rng, (512, 16), jnp.float32)
    np.testing.assert_allclose(
        krp_scale(vals, b, c), ref.krp_scale_ref(vals, b, c), rtol=1e-6)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    i_blocks=st.integers(1, 8),
    block_i=st.sampled_from([32, 64, 256]),
    r=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(i_blocks, block_i, r, seed):
    rng = np.random.default_rng(seed)
    i_dim = i_blocks * block_i
    m = rand(rng, (i_dim, r), jnp.float32)
    w = rand(rng, (r, r), jnp.float32)
    out = matmul(m, w, block_i=block_i)
    np.testing.assert_allclose(out, ref.matmul_ref(m, w), rtol=1e-5, atol=1e-5)


def test_matmul_identity():
    rng = np.random.default_rng(1)
    m = rand(rng, (256, 16), jnp.float32)
    out = matmul(m, jnp.eye(16, dtype=jnp.float32))
    np.testing.assert_allclose(out, m, rtol=1e-6)


def test_matmul_zero_w():
    m = jnp.ones((256, 16), jnp.float32)
    out = matmul(m, jnp.zeros((16, 16), jnp.float32))
    assert np.all(np.asarray(out) == 0.0)


def test_matmul_rejects_unaligned():
    with pytest.raises(AssertionError):
        matmul(jnp.zeros((100, 8)), jnp.zeros((8, 8)), block_i=64)


# ---------------------------------------------------------------------------
# gram
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    i_blocks=st.integers(1, 8),
    block_i=st.sampled_from([32, 64, 256]),
    r=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_matches_ref(i_blocks, block_i, r, seed):
    rng = np.random.default_rng(seed)
    a = rand(rng, (i_blocks * block_i, r), jnp.float32)
    out = gram(a, block_i=block_i)
    np.testing.assert_allclose(out, ref.gram_ref(a), rtol=1e-4, atol=1e-4)


def test_gram_is_symmetric_psd():
    rng = np.random.default_rng(3)
    a = rand(rng, (512, 16), jnp.float32)
    g = np.asarray(gram(a))
    np.testing.assert_allclose(g, g.T, rtol=1e-5, atol=1e-6)
    eig = np.linalg.eigvalsh(g)
    assert eig.min() >= -1e-3


def test_gram_multi_block_accumulation():
    """Accumulation across grid steps == single-block result."""
    rng = np.random.default_rng(4)
    a = rand(rng, (512, 8), jnp.float32)
    np.testing.assert_allclose(
        gram(a, block_i=64), gram(a, block_i=512), rtol=1e-4, atol=1e-4)


def test_gram_zero_rows_ignored():
    """Padded (all-zero) rows must not change the gram matrix."""
    rng = np.random.default_rng(5)
    a = rand(rng, (256, 16), jnp.float32)
    padded = jnp.concatenate([a, jnp.zeros((256, 16), jnp.float32)])
    np.testing.assert_allclose(
        gram(padded, block_i=256), gram(a, block_i=256), rtol=1e-5, atol=1e-5)
