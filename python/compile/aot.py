"""AOT-lower the L2/L1 stack to HLO *text* artifacts for the rust runtime.

Interchange format is HLO text, NOT serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per tensor configuration we export:
  als_sweep_<cfg>.hlo.txt            single-rank full ALS sweep + fit
  mttkrp_mode{0,1,2}_<cfg>.hlo.txt   per-rank MTTKRP (between collectives)
  update_post_mode{0,1,2}_<cfg>.hlo.txt  post-Allgatherv factor update
  fit_<cfg>.hlo.txt                  fit/convergence metric
plus meta.json describing every artifact's input/output shapes so the
rust runtime can construct literals without re-parsing HLO.

Usage: python -m compile.aot --out ../artifacts [--configs small,e2e]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Tensor configurations: (I, J, K) padded mode sizes, N padded nnz, rank R.
# "small" keeps tests fast; "e2e" is the examples/refacto_e2e.rs workload.
CONFIGS = {
    "small": dict(dims=(128, 64, 64), nnz=2048, rank=16),
    "e2e": dict(dims=(2048, 512, 256), nnz=131072, rank=16),
}

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_all(cfg_name, cfg):
    """Yield (artifact_name, lowered, meta) for one tensor configuration."""
    i_dim, j_dim, k_dim = cfg["dims"]
    n, r = cfg["nnz"], cfg["rank"]
    dims = (i_dim, j_dim, k_dim)

    coo = [spec((n,), F32)] + [spec((n,), I32)] * 3     # vals, i, j, k
    factors = [spec((i_dim, r)), spec((j_dim, r)), spec((k_dim, r))]
    scalar = spec((), F32)
    lam = spec((r,), F32)

    def meta(ins, outs):
        def fmt(s):
            return {"shape": list(s.shape),
                    "dtype": "f32" if s.dtype == jnp.float32 else "i32"}
        return {"inputs": [fmt(s) for s in ins], "outputs": [fmt(s) for s in outs]}

    # --- full single-rank sweep ------------------------------------------
    # NB: no initial A input — the mode-0 update would never read it and
    # XLA strips dead parameters from the lowered entry computation.
    ins = coo + [factors[1], factors[2], scalar]
    yield (
        f"als_sweep_{cfg_name}",
        model.als_sweep.lower(*ins, dims=dims),
        meta(ins, factors + [lam, scalar]),
    )

    # --- per-rank MTTKRP, one artifact per mode --------------------------
    # mode 0: rows=i, gathers from (B, C), output (I, R)
    # mode 1: rows=j, gathers from (A, C), output (J, R)
    # mode 2: rows=k, gathers from (A, B), output (K, R)
    mode_factors = [
        (factors[1], factors[2], i_dim),
        (factors[0], factors[2], j_dim),
        (factors[0], factors[1], k_dim),
    ]
    for mode, (fb, fc, out_rows) in enumerate(mode_factors):
        ins = [spec((n,), F32)] + [spec((n,), I32)] * 3 + [fb, fc]
        yield (
            f"mttkrp_mode{mode}_{cfg_name}",
            model.mttkrp_only.lower(*ins, out_rows=out_rows),
            meta(ins, [spec((out_rows, r))]),
        )

    # --- post-collective factor update, one per mode ---------------------
    for mode, (fb, fc, out_rows) in enumerate(mode_factors):
        ins = [spec((out_rows, r)), fb, fc]
        yield (
            f"update_post_mode{mode}_{cfg_name}",
            model.factor_update_post.lower(*ins),
            meta(ins, [spec((out_rows, r)), lam]),
        )

    # --- fit --------------------------------------------------------------
    ins = [scalar] + coo + [lam] + factors
    yield (
        f"fit_{cfg_name}",
        model.fit_only.lower(*ins),
        meta(ins, [scalar]),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--configs", default=",".join(CONFIGS),
                    help="comma-separated config names")
    args = ap.parse_args()

    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    index = {}
    for cfg_name in args.configs.split(","):
        cfg = CONFIGS[cfg_name]
        for name, lowered, meta in lower_all(cfg_name, cfg):
            text = to_hlo_text(lowered)
            path = os.path.join(out_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            meta["file"] = f"{name}.hlo.txt"
            meta["config"] = dict(cfg, name=cfg_name)
            index[name] = meta
            print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(index, f, indent=1, sort_keys=True)
    print(f"wrote {out_dir}/meta.json ({len(index)} artifacts)")


if __name__ == "__main__":
    main()
