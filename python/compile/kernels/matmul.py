"""Pallas kernel: tiled factor-matrix update matmul, out = M @ W.

CP-ALS updates each factor matrix as A <- M(X) * pinv(V) where M(X) is the
(I, R) MTTKRP result and V = (B^T B) .* (C^T C) is (R, R). The (I, R) x
(R, R) matmul streams row tiles of M through VMEM while the small W tile
stays resident — MXU-shaped on real hardware (f32 accumulate), VPU/dot on
the interpret path.

VMEM per grid step (f32, BLOCK_I=256, R=16):
  m 16 KiB + w 1 KiB + out 16 KiB = 33 KiB.
With R=16 the MXU's 128x128 systolic array is fed 16 lanes -> ~12.5%
utilization ceiling. That is the paper's own rank choice (single-precision
rank-16 decompositions); we record the honest estimate in DESIGN.md §8
rather than padding R.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_I = 256


def _matmul_kernel(m_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(
        m_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_i",))
def matmul(m, w, *, block_i=DEFAULT_BLOCK_I):
    """out = M @ W with M: (I, R), W: (R, R); I a multiple of block_i."""
    i_dim, r = m.shape
    assert w.shape == (r, r), (w.shape, r)
    assert i_dim % block_i == 0, f"I={i_dim} must be a multiple of block_i={block_i}"
    grid = (i_dim // block_i,)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_i, r), lambda i: (i, 0)),
            pl.BlockSpec((r, r), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_i, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((i_dim, r), m.dtype),
        interpret=True,
    )(m, w)
