"""Pallas kernel: fused Khatri-Rao product-scale (MTTKRP elementwise core).

ReFacTo's compute hot-spot is the MTTKRP, which DFacTo formulates as SpMV
and runs through cuSPARSE (warp-per-row CSR on K40m/P100). On the
TPU-shaped Pallas model the irregular gather/scatter halves stay in XLA
HLO (native gather / scatter-add); the dense elementwise core — scaling
the Khatri-Rao rows by the nonzero values — is this kernel:

    P[n, r] = vals[n] * B[j_n, r] * C[k_n, r]

where ``b_rows = B[j]`` and ``c_rows = C[k]`` are pre-gathered. The
BlockSpec expresses the HBM->VMEM schedule the CUDA code expressed with
threadblocks: tiles of (BLOCK_N, R) stream through VMEM and the VPU does
the two multiplies per element.

VMEM footprint per grid step (f32, BLOCK_N=512, R=16):
  vals 2 KiB + b 32 KiB + c 32 KiB + out 32 KiB = 98 KiB  (<< 16 MiB VMEM)
MXU is not engaged (pure elementwise -> VPU-bound); arithmetic intensity
is 2 FLOP per 16 loaded bytes, so the kernel is HBM-bandwidth-bound on
real hardware — exactly like its CUDA counterpart.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 512


def _krp_scale_kernel(vals_ref, b_ref, c_ref, o_ref):
    # vals tile is (BLOCK_N,); broadcast over the rank dimension.
    o_ref[...] = vals_ref[...][:, None] * b_ref[...] * c_ref[...]


@functools.partial(jax.jit, static_argnames=("block_n",))
def krp_scale(vals, b_rows, c_rows, *, block_n=DEFAULT_BLOCK_N):
    """P[n, :] = vals[n] * b_rows[n, :] * c_rows[n, :], tiled over n.

    ``vals``: (N,), ``b_rows``/``c_rows``: (N, R). N must be a multiple of
    ``block_n`` (the model pads the COO stream to guarantee this).
    Always runs with interpret=True: real-TPU lowering emits a Mosaic
    custom-call the CPU PJRT plugin cannot execute (see DESIGN.md).
    """
    n, r = b_rows.shape
    assert vals.shape == (n,), (vals.shape, n)
    assert c_rows.shape == (n, r)
    assert n % block_n == 0, f"N={n} must be a multiple of block_n={block_n}"
    grid = (n // block_n,)
    return pl.pallas_call(
        _krp_scale_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n, r), lambda i: (i, 0)),
            pl.BlockSpec((block_n, r), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, r), vals.dtype),
        interpret=True,
    )(vals, b_rows, c_rows)
