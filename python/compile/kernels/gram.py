"""Pallas kernel: gram matrix out = A^T A with grid accumulation.

Each CP-ALS mode update needs the (R, R) gram matrices of the other two
factor matrices. A is (I, R) with I up to millions of rows; the kernel
streams (BLOCK_I, R) tiles through VMEM and accumulates the (R, R) output
block across sequential grid steps — the canonical Pallas reduction
pattern (output BlockSpec maps every grid step to the same block, a
pl.when zeroes it on the first step).

VMEM per grid step (f32, BLOCK_I=256, R=16): a 16 KiB + out 1 KiB.
On the MXU this is a (16 x BLOCK_I) x (BLOCK_I x 16) matmul per step:
K-dim is large (good) but M=N=16 again caps utilization; see DESIGN.md §8.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_I = 256


def _gram_kernel(a_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...].T, a_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_i",))
def gram(a, *, block_i=DEFAULT_BLOCK_I):
    """out = A^T A (f32), A: (I, R), I a multiple of block_i."""
    i_dim, r = a.shape
    assert i_dim % block_i == 0, f"I={i_dim} must be a multiple of block_i={block_i}"
    grid = (i_dim // block_i,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_i, r), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((r, r), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((r, r), jnp.float32),
        interpret=True,
    )(a)
