"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every Pallas kernel in this package has a reference implementation here;
pytest (python/tests/) asserts allclose between kernel and oracle across
hypothesis-generated shapes/dtypes. This is the CORE correctness signal
for Layer 1.
"""

import jax.numpy as jnp


def krp_scale_ref(vals, b_rows, c_rows):
    """Fused Khatri-Rao product-scale: P[n, r] = vals[n] * B[j_n, r] * C[k_n, r].

    ``b_rows``/``c_rows`` are the pre-gathered factor rows (gathering stays
    in XLA HLO; see DESIGN.md §3 Hardware adaptation).
    """
    return vals[:, None] * b_rows * c_rows


def matmul_ref(m, w):
    """Factor update core: out = M @ W, with f32 accumulation."""
    return jnp.matmul(m, w, preferred_element_type=jnp.float32).astype(m.dtype)


def gram_ref(a):
    """Gram matrix: out = A^T A, accumulated in f32."""
    return jnp.matmul(a.T, a, preferred_element_type=jnp.float32).astype(jnp.float32)
