"""L2: CP-ALS (ReFacTo's per-rank compute) in JAX, calling the L1 kernels.

The paper's case study, ReFacTo (Section III), is a GPU extension of
DFacTo: coarse-grained CP-ALS where each rank owns a contiguous slice of
every mode, computes the MTTKRP rows for its slice, and Allgatherv's the
updated factor rows. Communication lives in Layer 3 (rust); THIS module is
the per-rank compute that runs between collectives:

  1. mttkrp      — M = X_(n) (C ⊙ B): gather + krp_scale kernel + segment-sum
  2. gram + hadamard + regularized solve  — A <- M (V + eps I)^-1
  3. column normalization                  — lambda weights
  4. fit         — ||X - M̂||_F via the standard sparse CP identity

Tensors are padded COO with static shapes (AOT requirement): nnz padded to
a multiple of the krp_scale block with val=0 / index=0 entries, mode sizes
padded to a multiple of the matmul/gram block. Rank R is fixed at build
time (paper uses single-precision, we default R=16).

Everything here is lowered ONCE by aot.py to HLO text; python never runs
on the request path.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.gram import gram
from .kernels.krp_scale import krp_scale
from .kernels.matmul import matmul

RIDGE_EPS = 1e-6


def _auto_block(dim, cap):
    """Largest power-of-two block <= cap that divides dim.

    AOT shapes are padded to powers of two (tensor/partition layer
    guarantees this), so this always finds a block >= 1 and keeps tiles
    VMEM-sized for the L1 kernels.
    """
    b = 1
    while b * 2 <= cap and dim % (b * 2) == 0:
        b *= 2
    return b


def mttkrp(vals, rows, cols_b, cols_c, fb, fc, out_rows):
    """Matricized-tensor times Khatri-Rao product for one mode.

    vals: (N,) nonzero values (padding entries are 0.0)
    rows: (N,) output row index per nonzero (this mode's index)
    cols_b/cols_c: (N,) indices into the other two factor matrices
    fb/fc: (J, R) / (K, R) factor matrices
    out_rows: static output row count (padded mode size)

    The gathers and the scatter-add stay in XLA HLO (native on CPU/TPU);
    the elementwise core is the Pallas krp_scale kernel.
    """
    b_rows = fb[cols_b]            # (N, R) gather
    c_rows = fc[cols_c]            # (N, R) gather
    # Tile cap 32768: interpret-mode Pallas pays a large fixed cost per
    # grid step (~8 ms measured, EXPERIMENTS.md §Perf), so we use the
    # largest tile that still fits the TPU VMEM budget (32K x 16 f32 x 4
    # buffers ~ 8 MiB < 16 MiB) instead of the GPU-ish 512-row tile.
    p = krp_scale(vals, b_rows, c_rows,
                  block_n=_auto_block(vals.shape[0], 32768))   # L1 kernel
    out = jnp.zeros((out_rows, fb.shape[1]), vals.dtype)
    return out.at[rows].add(p)     # scatter-add (segment sum)


def _gram(a):
    return gram(a, block_i=_auto_block(a.shape[0], 256))


def hadamard_gram(fb, fc):
    """V = (B^T B) .* (C^T C) — both grams via the L1 gram kernel."""
    return _gram(fb) * _gram(fc)


def spd_inverse(v):
    """Gauss-Jordan inverse of a (small) SPD matrix, in pure HLO ops.

    `jnp.linalg.inv` lowers to a LAPACK custom-call on CPU (typed-FFI API
    the pinned xla_extension 0.5.1 rejects) and is unavailable on TPU
    anyway; CP-ALS only ever inverts the (R, R) hadamard-of-grams matrix,
    which the ridge makes strictly positive definite, so pivot-free
    Gauss-Jordan is exact and lowers to plain fori_loop + arithmetic.
    """
    r = v.shape[0]
    aug = jnp.concatenate([v, jnp.eye(r, dtype=v.dtype)], axis=1)  # (r, 2r)

    def step(i, aug):
        row = aug[i] / aug[i, i]
        aug = aug - jnp.outer(aug[:, i], row)
        return aug.at[i].set(row)

    aug = jax.lax.fori_loop(0, r, step, aug)
    return aug[:, r:]


def solve_update(m, v):
    """A <- M @ (V + eps I)^{-1}.

    V is (R, R) symmetric positive semi-definite; a small ridge keeps the
    solve well-posed when factors are rank-deficient (standard CP-ALS
    practice). The (I, R) x (R, R) product is the L1 matmul kernel.
    """
    r = v.shape[0]
    v_reg = v + RIDGE_EPS * jnp.eye(r, dtype=v.dtype)
    w = spd_inverse(v_reg).astype(m.dtype)
    return matmul(m, w, block_i=_auto_block(m.shape[0], 256))


def normalize_columns(a):
    """Column-normalize a factor matrix, returning (A_normalized, lambda)."""
    lam = jnp.sqrt(jnp.sum(a * a, axis=0))
    safe = jnp.where(lam > 0, lam, 1.0)
    return a / safe, lam


def update_mode(vals, rows, cols_b, cols_c, fb, fc, out_rows):
    """One CP-ALS mode update; returns (A_new_normalized, lambda)."""
    m = mttkrp(vals, rows, cols_b, cols_c, fb, fc, out_rows)
    v = hadamard_gram(fb, fc)
    a_new = solve_update(m, v)
    return normalize_columns(a_new)


def model_norm_sq(lam, fa, fb, fc):
    """||M̂||_F^2 = lam^T (A^T A .* B^T B .* C^T C) lam."""
    g = _gram(fa) * _gram(fb) * _gram(fc)
    lam32 = lam.astype(jnp.float32)
    return lam32 @ g @ lam32


def sparse_inner(vals, i, j, k, lam, fa, fb, fc):
    """<X, M̂> over the nonzeros: sum_n vals_n * sum_r lam_r A[i,r]B[j,r]C[k,r].

    Reuses krp_scale for the B.*C rows, then contracts with A rows and lam.
    Padding entries contribute 0 because their value is 0.
    """
    p = krp_scale(vals, fb[j], fc[k],
                  block_n=_auto_block(vals.shape[0], 32768))  # vals * B[j] .* C[k]
    est = jnp.sum(p * fa[i] * lam[None, :].astype(vals.dtype), axis=1)
    return jnp.sum(est)


def fit_value(norm_x_sq, vals, i, j, k, lam, fa, fb, fc):
    """CP fit = 1 - ||X - M̂|| / ||X|| using the sparse identity

    ||X - M̂||^2 = ||X||^2 - 2 <X, M̂> + ||M̂||^2.
    """
    inner = sparse_inner(vals, i, j, k, lam, fa, fb, fc)
    norm_m_sq = model_norm_sq(lam, fa, fb, fc)
    resid_sq = jnp.maximum(norm_x_sq - 2.0 * inner + norm_m_sq, 0.0)
    return 1.0 - jnp.sqrt(resid_sq) / jnp.sqrt(norm_x_sq)


@functools.partial(jax.jit, static_argnames=("dims",))
def als_sweep(vals, i, j, k, fb, fc, norm_x_sq, *, dims):
    """One full ALS sweep (update modes 0,1,2 in sequence) + fit.

    dims: static (I, J, K) padded mode sizes.
    Returns (fa, fb, fc, lam, fit). The sweep starts at mode 0, which
    only reads B and C — an initial A input would be dead (and XLA would
    strip the parameter from the lowered HLO), so the signature omits it.
    """
    i_dim, j_dim, k_dim = dims
    fa, _ = update_mode(vals, i, j, k, fb, fc, i_dim)
    fb, _ = update_mode(vals, j, i, k, fa, fc, j_dim)
    fc, lam = update_mode(vals, k, i, j, fa, fb, k_dim)
    fit = fit_value(norm_x_sq, vals, i, j, k, lam, fa, fb, fc)
    return fa, fb, fc, lam, fit


@functools.partial(jax.jit, static_argnames=("out_rows",))
def mttkrp_only(vals, rows, cols_b, cols_c, fb, fc, *, out_rows):
    """Standalone MTTKRP artifact (the per-rank hot path between collectives).

    In the distributed ReFacTo loop each rank calls this on ITS padded
    nonzero slice; the resulting partial rows are disjoint across ranks,
    so the Allgatherv that follows is (numerically) an elementwise sum of
    the per-rank outputs — which is how the rust coordinator gathers them.
    """
    return mttkrp(vals, rows, cols_b, cols_c, fb, fc, out_rows)


@jax.jit
def factor_update_post(m, fb, fc):
    """Post-collective factor update: A <- normalize(M (V + eps I)^-1).

    Runs on the *gathered* full MTTKRP result after the Allgatherv.
    Returns (A_new, lambda).
    """
    v = hadamard_gram(fb, fc)
    a_new = solve_update(m, v)
    return normalize_columns(a_new)


@jax.jit
def fit_only(norm_x_sq, vals, i, j, k, lam, fa, fb, fc):
    """Standalone fit artifact (per-iteration convergence logging)."""
    return fit_value(norm_x_sq, vals, i, j, k, lam, fa, fb, fc)
