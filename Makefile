# Convenience targets. Tier-1 verify is `make verify`.

.PHONY: build test test-conformance test-workload test-faults test-collectives test-recovery test-scale test-serve verify bench bench-smoke bench-delta bench-workload bench-faults bench-collectives bench-serve artifacts fmt clippy

build:
	cargo build --release

test:
	cargo test -q

# The schedule-conformance property harness on its own (CI runs this as
# a dedicated step; it is also part of `make test`).
test-conformance:
	cargo test --test schedule_conformance

# The workload engine's differential / property / determinism suites on
# their own (CI runs this as a dedicated step; also part of `make test`).
test-workload:
	cargo test --test workload_differential --test workload_properties --test workload_determinism

# The fault subsystem's differential oracle + property suites on their
# own (CI runs this as a dedicated step; also part of `make test`).
test-faults:
	cargo test --test faults_differential --test faults_properties

# The collective suite's closed-form + chunking-differential harness on
# its own (CI runs this as a dedicated step; also part of `make test`).
test-collectives:
	cargo test --test collective_conformance

# The hard-fault recovery subsystem on its own: the timeout-retry-
# reroute-shrink driver units, the supervised-workload SLO runner, the
# outage differential oracles and the stall-diagnosis agreement tests
# (CI runs this as a dedicated step; all of it is also part of
# `make test`).
test-recovery:
	cargo test --lib recovery
	cargo test --lib slo
	cargo test --test faults_differential outage
	cargo test --test faults_differential recovery
	cargo test --test faults_differential stall

# The open-loop serving engine on its own: the serve unit suite
# (closed-loop anchor, policy semantics, warm-up/knee detection, the
# warm-started ServeDelta), the report section, the BENCH_serve.json
# byte pin and the `agv serve` CLI smoke (CI runs this as a dedicated
# step; all of it is also part of `make test`).
test-serve:
	cargo test --lib serve
	cargo test --test workload_determinism serve
	cargo test --test cli_smoke serve

# The thousand-rank scale subsystem on its own: the three-way
# sharded / unsharded / reference differential harness, the parametric
# fabric property tests, the large-P (256/1024/4096) schedule-
# conformance cases and the byte-for-byte pin of the BENCH_engine.json
# scale subtree (CI runs this as a dedicated step; also part of
# `make test`).
test-scale:
	cargo test --test scale_differential
	cargo test --test proptests prop_fa
	cargo test --test schedule_conformance conformance_p
	cargo test --test workload_determinism scale

verify: build test

# Full measurement run; bench_engine writes BENCH_engine.json,
# bench_hierarchy writes BENCH_hierarchy.json, bench_workload writes
# BENCH_workload.json and bench_faults writes BENCH_faults.json at the
# repo root.
bench:
	cargo bench --bench bench_engine -- --json
	cargo bench --bench bench_hierarchy -- --json
	cargo bench --bench bench_workload -- --json
	cargo bench --bench bench_faults -- --json
	cargo bench --bench bench_serve -- --json
	cargo bench --bench bench_collectives -- --json
	cargo bench --bench bench_ablations

# The workload grid alone (BENCH_workload.json is byte-reproducible
# from its seed; AGV_BENCH_QUICK=1 redirects to the .quick.json name).
bench-workload:
	cargo bench --bench bench_workload -- --json

# The fault grid alone (BENCH_faults.json is byte-reproducible from its
# seed; AGV_BENCH_QUICK=1 redirects to the .quick.json name).
bench-faults:
	cargo bench --bench bench_faults -- --json

# The collective-suite grid on its own; writes BENCH_collectives.json.
bench-collectives:
	cargo bench --bench bench_collectives -- --json

# The serving capacity grid alone (BENCH_serve.json is byte-reproducible
# from its seed; AGV_BENCH_QUICK=1 redirects to the .quick.json name).
bench-serve:
	cargo bench --bench bench_serve -- --json

# Warm-started delta-simulation smoke (DESIGN.md §16): runs the fault
# and workload ensemble benches in quick mode, which asserts warm-vs-
# cold agreement to 1e-9 per scenario and gates the warm/cold wall-
# clock ratio at >= 2x, and prints the measured speedup. No canonical
# artifact is touched (quick mode writes BENCH_*.quick.json scratch).
bench-delta:
	AGV_BENCH_QUICK=1 cargo bench --bench bench_faults -- --json
	AGV_BENCH_QUICK=1 cargo bench --bench bench_workload -- --json
	AGV_BENCH_QUICK=1 cargo bench --bench bench_serve -- --json

# CI smoke: every bench target builds and runs with slashed iteration
# counts (AGV_BENCH_QUICK=1) so the targets cannot bit-rot. In quick
# mode bench_engine/bench_hierarchy write BENCH_*.quick.json (scratch),
# never the canonical BENCH_*.json.
bench-smoke:
	AGV_BENCH_QUICK=1 cargo bench --bench bench_engine -- --json
	AGV_BENCH_QUICK=1 cargo bench --bench bench_hierarchy -- --json
	AGV_BENCH_QUICK=1 cargo bench --bench bench_workload -- --json
	AGV_BENCH_QUICK=1 cargo bench --bench bench_faults -- --json
	AGV_BENCH_QUICK=1 cargo bench --bench bench_serve -- --json
	AGV_BENCH_QUICK=1 cargo bench --bench bench_collectives -- --json
	AGV_BENCH_QUICK=1 cargo bench --bench bench_ablations
	AGV_BENCH_QUICK=1 cargo bench --bench bench_osu_fig2
	AGV_BENCH_QUICK=1 cargo bench --bench bench_refacto_fig3
	AGV_BENCH_QUICK=1 cargo bench --bench bench_table1

fmt:
	cargo fmt --all --check

clippy:
	cargo clippy --workspace --all-targets -- -D warnings

# AOT-lower the JAX/Pallas CP-ALS model to HLO-text artifacts for the
# rust runtime (DESIGN.md §6). Needs a Python environment with JAX;
# execution additionally needs a build with real XLA bindings.
artifacts:
	cd python && python -m compile.aot --out ../rust/artifacts
