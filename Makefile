# Convenience targets. Tier-1 verify is `make verify`.

.PHONY: build test verify bench artifacts fmt clippy

build:
	cargo build --release

test:
	cargo test -q

verify: build test

bench:
	cargo bench --bench bench_engine
	cargo bench --bench bench_ablations

fmt:
	cargo fmt --all --check

clippy:
	cargo clippy --workspace --all-targets -- -D warnings

# AOT-lower the JAX/Pallas CP-ALS model to HLO-text artifacts for the
# rust runtime (DESIGN.md §6). Needs a Python environment with JAX;
# execution additionally needs a build with real XLA bindings.
artifacts:
	cd python && python -m compile.aot --out ../rust/artifacts
