//! Bench target regenerating **Fig. 2** (OSU Allgatherv sweep) and
//! timing the harness itself. `cargo bench --bench bench_osu_fig2`.
//!
//! Prints (a) the figure's data rows — the reproduction artifact — and
//! (b) measurement statistics of the simulation harness (our custom
//! harness replaces criterion, which is unavailable offline).

use agv_bench::comm::Library;
use agv_bench::osu::{run_osu, OsuConfig};
use agv_bench::report::fig2;
use agv_bench::topology::systems::SystemKind;
use agv_bench::util::bench::{bench, black_box, iters, warmup};

fn main() {
    println!("=== Fig. 2 data (per-rank message size -> total time) ===\n");
    let cells = fig2::grid();
    print!("{}", fig2::render(&cells));

    println!("=== harness timing (simulation cost, not paper metric) ===");
    let cfg = OsuConfig::default();
    for system in SystemKind::all() {
        let topo = system.build();
        for lib in Library::all() {
            let name = format!("osu_sweep/{}/{}/8gpus", system.name(), lib.name());
            let r = bench(&name, warmup(1), iters(5), || {
                black_box(run_osu(&cfg, &topo, lib, 8.min(topo.num_gpus())));
            });
            println!("{}", r.report_line());
        }
    }
}
