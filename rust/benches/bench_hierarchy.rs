//! Hierarchical Allgatherv + auto-selection benchmarks: wall-clock cost
//! of schedule construction and of the selector's exhaustive argmin,
//! plus the *simulated* times the hierarchy is about — hierarchical vs
//! flat vs NCCL on multi-DGX, and auto vs the best fixed library.
//!
//! `cargo bench --bench bench_hierarchy [-- --json]`
//!
//! With `--json` (what `make bench` passes) results land in
//! `BENCH_hierarchy.json` at the repo root (quick mode writes the
//! scratch `BENCH_hierarchy.quick.json` instead, like `bench_engine`).

use agv_bench::comm::algorithms::{hierarchical_allgatherv, ring_allgatherv, LeaderAlgo};
use agv_bench::comm::select::{simulate, Algo, AlgoSelector, Candidate};
use agv_bench::comm::{run_allgatherv, Library, Params};
use agv_bench::topology::systems::{multi_dgx, node_groups};
use agv_bench::util::bench::{bench, black_box, iters, quick_mode, warmup};
use agv_bench::util::json::{obj, Json};
use agv_bench::util::{fmt_bytes, fmt_time};

fn main() {
    let json_out = std::env::args().any(|a| a == "--json");
    let params = Params::default();
    let topo = multi_dgx(2);
    let p = 16;
    let groups = node_groups(&topo, p);

    let mut cases: Vec<Json> = Vec::new();

    // schedule construction cost (hierarchical vs flat ring)
    let r = bench("schedule/hierarchical_ring/multi_dgx2_p16", warmup(2), iters(200), || {
        black_box(hierarchical_allgatherv(p, &groups, LeaderAlgo::Ring));
    });
    println!("{}", r.report_line());
    cases.push(r.to_json(&[]));
    let r = bench("schedule/flat_ring/p16", warmup(2), iters(200), || {
        black_box(ring_allgatherv(p, None));
    });
    println!("{}", r.report_line());
    cases.push(r.to_json(&[]));

    // selector cost: exhaustive argmin vs one cached decision
    let cv = vec![4u64 << 20; p];
    let r = bench("selector/select_fresh/multi_dgx2_16x4MB", warmup(1), iters(10), || {
        let sel = AlgoSelector::new(params);
        black_box(sel.select_fresh(&topo, &cv));
    });
    println!("{}", r.report_line());
    cases.push(r.to_json(&[]));
    let r = bench("selector/select_cached/multi_dgx2_16x4MB", warmup(1), iters(10), || {
        let mut sel = AlgoSelector::new(params);
        sel.select(&topo, &cv); // miss fills the table
        for _ in 0..8 {
            // hits simulate only the cached winner + library defaults
            black_box(sel.select(&topo, &cv));
        }
    });
    println!("{}", r.report_line());
    cases.push(r.to_json(&[]));

    // simulated-time table: hierarchical vs flat vs NCCL vs auto
    println!("\n=== simulated Allgatherv on multi-dgx-2 @ 16 GPUs (regular counts) ===");
    println!(
        "{:>10} {:>14} {:>14} {:>14} {:>14} {:>14}",
        "size/rank", "flat-ring", "hier-ring", "hier-bruck", "nccl", "auto"
    );
    let sizes: &[u64] = if quick_mode() {
        &[64 << 10, 1 << 20]
    } else {
        &[64 << 10, 1 << 20, 4 << 20, 16 << 20]
    };
    let mut simulated: Vec<Json> = Vec::new();
    let mut auto_speedups: Vec<Json> = Vec::new();
    for &m in sizes {
        let cv = vec![m; p];
        let t = |c: Candidate| simulate(&topo, params, c, &cv).map(|r| r.time).unwrap_or(f64::NAN);
        let flat = t(Candidate { lib: Library::MpiCuda, algo: Algo::Ring });
        let hring = t(Candidate { lib: Library::MpiCuda, algo: Algo::HierarchicalRing });
        let hbruck = t(Candidate { lib: Library::MpiCuda, algo: Algo::HierarchicalBruck });
        let nccl = run_allgatherv(Library::Nccl, &topo, &cv).time;
        let auto = AlgoSelector::new(params).select_fresh(&topo, &cv);
        println!(
            "{:>10} {:>14} {:>14} {:>14} {:>14} {:>14}  <- {}",
            fmt_bytes(m),
            fmt_time(flat),
            fmt_time(hring),
            fmt_time(hbruck),
            fmt_time(nccl),
            fmt_time(auto.time),
            auto.candidate.label()
        );
        let best_fixed = Library::all()
            .into_iter()
            .map(|l| run_allgatherv(l, &topo, &cv).time)
            .fold(f64::INFINITY, f64::min);
        simulated.push(obj(vec![
            ("per_rank_bytes", Json::Num(m as f64)),
            ("flat_ring_s", Json::Num(flat)),
            ("hier_ring_s", Json::Num(hring)),
            ("hier_bruck_s", Json::Num(hbruck)),
            ("nccl_s", Json::Num(nccl)),
            ("auto_s", Json::Num(auto.time)),
            ("auto_choice", Json::Str(auto.candidate.label())),
            ("auto_speedup_vs_best_fixed", Json::Num(best_fixed / auto.time)),
        ]));
        auto_speedups.push(Json::Num(best_fixed / auto.time));
    }

    if json_out {
        let doc = obj(vec![
            ("bench", Json::Str("bench_hierarchy".into())),
            ("quick", Json::Bool(quick_mode())),
            ("cases", Json::Arr(cases)),
            ("simulated_multi_dgx2_16", Json::Arr(simulated)),
            ("auto_speedup_vs_best_fixed", Json::Arr(auto_speedups)),
        ]);
        let path = if quick_mode() {
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hierarchy.quick.json")
        } else {
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hierarchy.json")
        };
        std::fs::write(path, doc.render() + "\n").expect("write BENCH_hierarchy json");
        println!("\nwrote {path}");
    }
}
