//! Multi-tenant workload engine benchmark: wall-clock cost of the
//! shared-sim admission loop per system, plus the deterministic
//! simulated-metric payload.
//!
//! `cargo bench --bench bench_workload [-- --json]`
//!
//! With `--json` (what `make bench-workload` passes) the simulated
//! metrics are written to `BENCH_workload.json` at the repo root.
//! Deliberately, the artifact holds **no wall-clock numbers** — only
//! simulation outputs — so the same seed reproduces it byte-for-byte
//! (tests/workload_determinism.rs pins the in-process equivalent).
//! Wall-clock timing of the same cases is printed below instead.
//! `AGV_BENCH_QUICK=1` slashes iteration counts and redirects the
//! artifact to `BENCH_workload.quick.json` (scratch), as in the other
//! bench targets.

use agv_bench::comm::Params;
use agv_bench::perturb::bench::delta_ensemble;
use agv_bench::util::bench::{bench, black_box, iters, quick_mode, warmup};
use agv_bench::workload::bench::{bench_cases, bench_doc};
use agv_bench::workload::{run_workload, WorkloadDelta};

/// Seed of the canonical BENCH_workload.json grid.
const SEED: u64 = 42;

fn main() {
    let json_out = std::env::args().any(|a| a == "--json");

    // wall-clock: how fast does the engine admit + simulate each case?
    for (label, topo, spec) in bench_cases(SEED) {
        let ops: usize = spec.tenants.iter().map(|t| t.ops).sum();
        let name = format!("workload/{label}");
        let r = bench(&name, warmup(1), iters(8), || {
            black_box(run_workload(&topo, &spec, Params::default()).unwrap());
        });
        println!("{}   ({:.0} ops/s)", r.report_line(), ops as f64 / r.mean_s);
    }

    // wall-clock: fault-timeline ensemble over one workload DAG, warm
    // delta replay vs cold re-simulation (DESIGN.md §16). Quick mode
    // gates the ratio at >= 2x; BENCH_workload.json records the
    // deterministic work-unit counterpart.
    let (label, topo, spec) = bench_cases(SEED).remove(0);
    let wd = WorkloadDelta::record(&topo, &spec, Params::default())
        .expect("bench spec must validate");
    let makespan = wd.run(&[]).makespan;
    let ens = delta_ensemble(&topo, makespan, SEED);
    let warm = bench(&format!("workload/delta-warm/{label}"), warmup(1), iters(8), || {
        for faults in &ens {
            black_box(wd.run(faults));
        }
    });
    println!("{}", warm.report_line());
    let cold = bench(&format!("workload/delta-cold/{label}"), warmup(1), iters(2), || {
        for faults in &ens {
            black_box(wd.run_cold(faults));
        }
    });
    println!("{}", cold.report_line());
    let speedup = cold.mean_s / warm.mean_s;
    println!("  -> delta-sim speedup over cold re-simulation: {speedup:.2}x");
    for faults in &ens {
        let rel = (wd.run(faults).makespan - wd.run_cold(faults).makespan).abs()
            / wd.run_cold(faults).makespan.max(1e-300);
        assert!(rel < 1e-9, "warm-vs-cold workload divergence: {rel}");
    }
    if quick_mode() {
        assert!(speedup >= 2.0, "delta-sim quick gate: {speedup:.2}x < 2x");
    }

    if json_out {
        let doc = bench_doc(SEED);
        let path = if quick_mode() {
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_workload.quick.json")
        } else {
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_workload.json")
        };
        std::fs::write(path, doc.render() + "\n").expect("write BENCH_workload json");
        println!("\nwrote {path}");
    }
}
