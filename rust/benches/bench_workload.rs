//! Multi-tenant workload engine benchmark: wall-clock cost of the
//! shared-sim admission loop per system, plus the deterministic
//! simulated-metric payload.
//!
//! `cargo bench --bench bench_workload [-- --json]`
//!
//! With `--json` (what `make bench-workload` passes) the simulated
//! metrics are written to `BENCH_workload.json` at the repo root.
//! Deliberately, the artifact holds **no wall-clock numbers** — only
//! simulation outputs — so the same seed reproduces it byte-for-byte
//! (tests/workload_determinism.rs pins the in-process equivalent).
//! Wall-clock timing of the same cases is printed below instead.
//! `AGV_BENCH_QUICK=1` slashes iteration counts and redirects the
//! artifact to `BENCH_workload.quick.json` (scratch), as in the other
//! bench targets.

use agv_bench::comm::Params;
use agv_bench::util::bench::{bench, black_box, iters, quick_mode, warmup};
use agv_bench::workload::bench::{bench_cases, bench_doc};
use agv_bench::workload::run_workload;

/// Seed of the canonical BENCH_workload.json grid.
const SEED: u64 = 42;

fn main() {
    let json_out = std::env::args().any(|a| a == "--json");

    // wall-clock: how fast does the engine admit + simulate each case?
    for (label, topo, spec) in bench_cases(SEED) {
        let ops: usize = spec.tenants.iter().map(|t| t.ops).sum();
        let name = format!("workload/{label}");
        let r = bench(&name, warmup(1), iters(8), || {
            black_box(run_workload(&topo, &spec, Params::default()).unwrap());
        });
        println!("{}   ({:.0} ops/s)", r.report_line(), ops as f64 / r.mean_s);
    }

    if json_out {
        let doc = bench_doc(SEED);
        let path = if quick_mode() {
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_workload.quick.json")
        } else {
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_workload.json")
        };
        std::fs::write(path, doc.render() + "\n").expect("write BENCH_workload json");
        println!("\nwrote {path}");
    }
}
