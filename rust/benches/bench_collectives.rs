//! Collective-suite benchmark: wall-clock cost of the op-generic
//! compose/run path per system × op, plus the deterministic
//! simulated-metric payload.
//!
//! `cargo bench --bench bench_collectives [-- --json]`
//!
//! With `--json` (what `make bench-collectives` passes) the simulated
//! metrics — per-library times, auto verdicts and chunk-pipelining
//! speedups — are written to `BENCH_collectives.json` at the repo root.
//! As in every bench target the artifact holds **no wall-clock
//! numbers**, only simulation outputs, so the same seed reproduces it
//! byte-for-byte (tests/workload_determinism.rs pins the in-process
//! equivalent). `AGV_BENCH_QUICK=1` slashes iteration counts and
//! redirects the artifact to `BENCH_collectives.quick.json` (scratch).

use agv_bench::comm::collective::bench::{bench_cases, bench_doc};
use agv_bench::comm::collective::run_collective;
use agv_bench::comm::transport::ChunkCfg;
use agv_bench::comm::{Library, Params};
use agv_bench::util::bench::{bench, black_box, iters, quick_mode, warmup};

/// Seed of the canonical BENCH_collectives.json grid.
const SEED: u64 = 42;

fn main() {
    let json_out = std::env::args().any(|a| a == "--json");

    // wall-clock: how fast does the op-generic path compose + simulate?
    for (label, topo, spec) in bench_cases(SEED) {
        let name = format!("collective/{label}");
        let r = bench(&name, warmup(1), iters(8), || {
            for lib in Library::all() {
                black_box(run_collective(&topo, lib, Params::default(), &spec, ChunkCfg::none()));
            }
        });
        println!("{}", r.report_line());
    }

    if json_out {
        let doc = bench_doc(SEED);
        let path = if quick_mode() {
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_collectives.quick.json")
        } else {
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_collectives.json")
        };
        std::fs::write(path, doc.render() + "\n").expect("write BENCH_collectives json");
        println!("\nwrote {path}");
    }
}
