//! Bench target regenerating **Table I** (data-set message statistics)
//! and timing the statistics pipeline. `cargo bench --bench bench_table1`.

use agv_bench::report::table1;
use agv_bench::tensor::datasets;
use agv_bench::tensor::messages::MsgStats;
use agv_bench::util::bench::{bench, black_box, iters, warmup};

fn main() {
    println!("=== Table I ===\n");
    print!("{}", table1::render());
    println!();

    println!("=== harness timing ===");
    for d in datasets::all() {
        let name = format!("table1_stats/{}", d.name);
        let r = bench(&name, warmup(2), iters(10), || {
            for gpus in [2usize, 8, 16] {
                black_box(MsgStats::of(&d, gpus));
            }
        });
        println!("{}", r.report_line());
    }
}
