//! Open-loop serving engine benchmark: wall-clock cost of composing
//! and simulating a serving DAG per system, warm-vs-cold delta replay
//! over fault ensembles, plus the deterministic simulated-metric
//! payload (knee curves, policy comparison, zero-rate anchor).
//!
//! `cargo bench --bench bench_serve [-- --json]`
//!
//! With `--json` (what `make bench-serve` passes) the simulated
//! metrics are written to `BENCH_serve.json` at the repo root.
//! Deliberately, the artifact holds **no wall-clock numbers** — only
//! simulation outputs — so the same seed reproduces it byte-for-byte
//! (tests/workload_determinism.rs pins the in-process equivalent).
//! Wall-clock timing of the same cases is printed below instead.
//! `AGV_BENCH_QUICK=1` slashes iteration counts and redirects the
//! artifact to `BENCH_serve.quick.json` (scratch), as in the other
//! bench targets.

use agv_bench::comm::Params;
use agv_bench::perturb::bench::delta_ensemble;
use agv_bench::util::bench::{bench, black_box, iters, quick_mode, warmup};
use agv_bench::workload::serve::bench::{bench_cases, bench_doc};
use agv_bench::workload::{run_serve, ServeDelta};

/// Seed of the canonical BENCH_serve.json grid.
const SEED: u64 = 42;

fn main() {
    let json_out = std::env::args().any(|a| a == "--json");

    // wall-clock: how fast does the engine compose + simulate one
    // serving case (arrivals, admission gates, the shared DAG)?
    for (label, topo, spec) in bench_cases(SEED) {
        let jobs: usize = spec.workload.tenants.iter().map(|t| t.ops).sum();
        let name = format!("serve/{label}");
        let r = bench(&name, warmup(1), iters(8), || {
            black_box(run_serve(&topo, &spec, Params::default()).unwrap());
        });
        println!("{}   ({:.0} jobs/s)", r.report_line(), jobs as f64 / r.mean_s);
    }

    // wall-clock: fault-timeline ensemble over one serving DAG, warm
    // delta replay vs cold re-simulation (DESIGN.md §16/§17). Quick
    // mode gates the ratio at >= 2x; BENCH_serve.json records the
    // deterministic work-unit counterpart in its delta_sim subtree.
    let (label, topo, spec) = bench_cases(SEED).remove(0);
    let sd = ServeDelta::record(&topo, &spec, Params::default())
        .expect("bench spec must validate");
    let makespan = sd.run(&[]).makespan;
    let ens = delta_ensemble(&topo, makespan, SEED);
    let warm = bench(&format!("serve/delta-warm/{label}"), warmup(1), iters(8), || {
        for faults in &ens {
            black_box(sd.run(faults));
        }
    });
    println!("{}", warm.report_line());
    let cold = bench(&format!("serve/delta-cold/{label}"), warmup(1), iters(2), || {
        for faults in &ens {
            black_box(sd.run_cold(faults));
        }
    });
    println!("{}", cold.report_line());
    let speedup = cold.mean_s / warm.mean_s;
    println!("  -> delta-sim speedup over cold re-simulation: {speedup:.2}x");
    for faults in &ens {
        let rel = (sd.run(faults).makespan - sd.run_cold(faults).makespan).abs()
            / sd.run_cold(faults).makespan.max(1e-300);
        assert!(rel < 1e-9, "warm-vs-cold serve divergence: {rel}");
    }
    if quick_mode() {
        assert!(speedup >= 2.0, "delta-sim quick gate: {speedup:.2}x < 2x");
    }

    if json_out {
        let doc = bench_doc(SEED);
        let path = if quick_mode() {
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.quick.json")
        } else {
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json")
        };
        std::fs::write(path, doc.render() + "\n").expect("write BENCH_serve json");
        println!("\nwrote {path}");
    }
}
