//! Bench target regenerating **Fig. 3** (ReFacTo communication time) and
//! timing the simulation harness. `cargo bench --bench bench_refacto_fig3`.

use agv_bench::comm::{Library, Params};
use agv_bench::cpals::comm_model::refacto_comm;
use agv_bench::report::fig3;
use agv_bench::tensor::datasets;
use agv_bench::topology::systems::SystemKind;
use agv_bench::util::bench::{bench, black_box, iters, warmup};

fn main() {
    println!("=== Fig. 3 data (10 CP-ALS iterations) ===\n");
    let panels = fig3::default_panels();
    print!("{}", fig3::render(&panels));

    println!("=== harness timing ===");
    for system in SystemKind::all() {
        let topo = system.build();
        for d in datasets::all() {
            let name = format!("refacto/{}/{}/8gpus", system.name(), d.name);
            let r = bench(&name, warmup(1), iters(5), || {
                for lib in Library::all() {
                    black_box(refacto_comm(&topo, lib, Params::default(), &d, 8, 1));
                }
            });
            println!("{}", r.report_line());
        }
    }
}
