//! Microbenchmarks of the substrate hot paths: the discrete-event flow
//! engine, routing, and one full collective of each library — the L3
//! performance targets of DESIGN.md §8 (>= 1e5 simulated transfers/s).
//! `cargo bench --bench bench_engine`.

use agv_bench::comm::{run_allgatherv, Library};
use agv_bench::sim::Sim;
use agv_bench::topology::systems::{cluster, dgx1};
use agv_bench::util::bench::{bench, black_box};
use agv_bench::util::prng::Rng;

fn main() {
    let dgx = dgx1();
    let clu = cluster(16);

    // raw engine throughput: chains of random flows with contention
    for n_flows in [100usize, 1000, 5000] {
        let name = format!("engine/random_dag/{n_flows}_flows");
        let r = bench(&name, 1, 8, || {
            let mut rng = Rng::new(42);
            let mut sim = Sim::new(&dgx);
            let mut last = None;
            for _ in 0..n_flows {
                let a = rng.gen_range(8) as usize;
                let mut b = rng.gen_range(8) as usize;
                if a == b {
                    b = (b + 1) % 8;
                }
                let path = dgx.route_gpus(a, b).unwrap();
                let deps: Vec<_> = if rng.next_f64() < 0.3 {
                    last.into_iter().collect()
                } else {
                    vec![]
                };
                last = Some(sim.flow(path, 1e6 + rng.gen_range(1 << 22) as f64, 1e-6, &deps));
            }
            black_box(sim.run());
        });
        let flows_per_sec = n_flows as f64 / r.mean_s;
        println!("{}   ({:.0} flows/s)", r.report_line(), flows_per_sec);
    }

    // routing cost
    let r = bench("topology/route_all_pairs/cluster16", 2, 20, || {
        for a in 0..16 {
            for b in 0..16 {
                if a != b {
                    black_box(clu.route_gpus(a, b));
                }
            }
        }
    });
    println!("{}", r.report_line());

    // one full collective per library (the Fig. 2/3 inner loop)
    for lib in Library::all() {
        for (topo, label, gpus) in [(&dgx, "dgx1", 8usize), (&clu, "cluster", 16)] {
            let counts = vec![16u64 << 20; gpus];
            let name = format!("allgatherv/{}/{}x16MB", lib.name(), label);
            let r = bench(&name, 1, 10, || {
                black_box(run_allgatherv(lib, topo, &counts));
            });
            println!("{}", r.report_line());
        }
    }
}
