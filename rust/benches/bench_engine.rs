//! Microbenchmarks of the substrate hot paths: the discrete-event flow
//! engine (event-driven vs the retained reference core), routing, and
//! one full collective of each library — the L3 performance targets of
//! DESIGN.md §8 (>= 1e5 simulated transfers/s).
//!
//! `cargo bench --bench bench_engine [-- --json]`
//!
//! With `--json` (what `make bench` passes) the results are also written
//! to `BENCH_engine.json` at the repo root: per-case timing plus the
//! event-engine/reference-engine speedup per DAG size, so the perf
//! trajectory accumulates in-tree run over run. `AGV_BENCH_QUICK=1`
//! slashes iteration counts for the CI smoke step.

use agv_bench::comm::{run_allgatherv, Library};
use agv_bench::sim::scale::{build_leaf_rings, leaf_group_size, scale_doc, scale_specs};
use agv_bench::sim::{run_sharded, Sim, SimResult};
use agv_bench::topology::systems::{cluster, dgx1};
use agv_bench::topology::Topology;
use agv_bench::util::bench::{bench, black_box, iters, quick_mode, warmup};
use agv_bench::util::json::{obj, Json};
use agv_bench::util::prng::Rng;

/// Random contended DAG over the DGX-1: ~70% independent flows, ~30%
/// chained onto the previous one (same construction the seed bench
/// used, so numbers stay comparable release over release).
fn build_random_dag(topo: &Topology, n_flows: usize) -> Sim<'_> {
    let mut rng = Rng::new(42);
    let mut sim = Sim::new(topo);
    let mut last = None;
    for _ in 0..n_flows {
        let a = rng.gen_range(8) as usize;
        let mut b = rng.gen_range(8) as usize;
        if a == b {
            b = (b + 1) % 8;
        }
        let path = topo.route_gpus(a, b).unwrap();
        let deps: Vec<_> = if rng.next_f64() < 0.3 {
            last.into_iter().collect()
        } else {
            vec![]
        };
        last = Some(sim.flow(path, 1e6 + rng.gen_range(1 << 22) as f64, 1e-6, &deps));
    }
    sim
}

fn main() {
    let json_out = std::env::args().any(|a| a == "--json");
    let dgx = dgx1();
    let clu = cluster(16);

    let mut cases: Vec<Json> = Vec::new();
    let mut speedups: Vec<(&str, f64)> = Vec::new();

    // raw engine throughput, event-driven vs reference, same DAGs
    for n_flows in [100usize, 1000, 5000] {
        let event_name = format!("engine/random_dag/{n_flows}_flows");
        let event = bench(&event_name, warmup(1), iters(8), || {
            black_box(build_random_dag(&dgx, n_flows).run());
        });
        let flows_per_sec = n_flows as f64 / event.mean_s;
        println!("{}   ({:.0} flows/s)", event.report_line(), flows_per_sec);
        cases.push(event.to_json(&[("flows_per_s", flows_per_sec)]));

        let ref_name = format!("engine_reference/random_dag/{n_flows}_flows");
        let reference = bench(&ref_name, warmup(1), iters(4), || {
            black_box(build_random_dag(&dgx, n_flows).run_reference());
        });
        let ref_flows_per_sec = n_flows as f64 / reference.mean_s;
        println!("{}   ({:.0} flows/s)", reference.report_line(), ref_flows_per_sec);
        cases.push(reference.to_json(&[("flows_per_s", ref_flows_per_sec)]));

        let speedup = reference.mean_s / event.mean_s;
        let label: &str = match n_flows {
            100 => "random_dag/100_flows",
            1000 => "random_dag/1000_flows",
            _ => "random_dag/5000_flows",
        };
        println!("  -> event-driven speedup over reference: {speedup:.2}x\n");
        speedups.push((label, speedup));
    }

    // sanity while we have both engines in hand: identical results
    {
        let new: SimResult = build_random_dag(&dgx, 200).run();
        let old: SimResult = build_random_dag(&dgx, 200).run_reference();
        let rel = (new.makespan - old.makespan).abs() / old.makespan;
        assert!(rel < 1e-9, "engines diverged: {} vs {}", new.makespan, old.makespan);
    }

    // routing cost
    let r = bench("topology/route_all_pairs/cluster16", warmup(2), iters(20), || {
        for a in 0..16 {
            for b in 0..16 {
                if a != b {
                    black_box(clu.route_gpus(a, b));
                }
            }
        }
    });
    println!("{}", r.report_line());
    cases.push(r.to_json(&[]));

    // one full collective per library (the Fig. 2/3 inner loop)
    for lib in Library::all() {
        for (topo, label, gpus) in [(&dgx, "dgx1", 8usize), (&clu, "cluster", 16)] {
            let counts = vec![16u64 << 20; gpus];
            let name = format!("allgatherv/{}/{}x16MB", lib.name(), label);
            let r = bench(&name, warmup(1), iters(10), || {
                black_box(run_allgatherv(lib, topo, &counts));
            });
            println!("{}", r.report_line());
            cases.push(r.to_json(&[]));
        }
    }

    // thousand-rank fabrics (DESIGN.md §15): the sharded driver on the
    // leaf-ring workload, swept over shard counts. shards=1 is the
    // whole-DAG single-engine baseline (same partition code path), so
    // the curve is a pure shard-count speedup. Quick mode runs the
    // ~1k-rank fabrics; the full bench runs the >= 4096-rank ones.
    let mut scale_curve: Vec<Json> = Vec::new();
    for spec in scale_specs(quick_mode()) {
        let topo = spec.build();
        let group = leaf_group_size(spec);
        let ranks = topo.num_gpus();
        let mut base_mean = f64::NAN;
        for shards in [1usize, 4, 16, 64] {
            // shards actually executed (vs requested): the collapse
            // guard makes a welded-DAG degradation visible instead of
            // silently paying pool dispatch for one effective shard
            let (probe, _, _) =
                run_sharded(build_leaf_rings(&topo, group, 42), shards, usize::MAX);
            let effective = probe.stats.shards_effective;
            let name = format!("scale/{}/{ranks}ranks/shards{shards}", spec.name());
            let r = bench(&name, warmup(1), iters(2), || {
                black_box(run_sharded(build_leaf_rings(&topo, group, 42), shards, usize::MAX));
            });
            if shards == 1 {
                base_mean = r.mean_s;
            }
            let speedup = base_mean / r.mean_s;
            println!(
                "{}   ({speedup:.2}x vs 1 shard, {effective} effective)",
                r.report_line()
            );
            cases.push(r.to_json(&[
                ("speedup_vs_1_shard", speedup),
                ("shards_effective", effective as f64),
            ]));
            scale_curve.push(obj(vec![
                ("system", Json::Str(spec.name())),
                ("ranks", Json::Num(ranks as f64)),
                ("shards", Json::Num(shards as f64)),
                ("shards_effective", Json::Num(effective as f64)),
                ("mean_s", Json::Num(r.mean_s)),
                ("speedup_vs_1_shard", Json::Num(speedup)),
            ]));
        }
        println!();
    }

    if json_out {
        let doc = obj(vec![
            ("bench", Json::Str("bench_engine".into())),
            ("quick", Json::Bool(quick_mode())),
            ("cases", Json::Arr(cases)),
            // deterministic sharded-vs-unsharded agreement metrics (the
            // determinism suite pins this subtree byte-for-byte) next
            // to the wall-clock shard-count speedup curve
            (
                "scale",
                obj(vec![
                    ("cross_check", scale_doc(42, quick_mode())),
                    ("speedup_curve", Json::Arr(scale_curve)),
                ]),
            ),
            (
                "speedup_vs_reference",
                obj(speedups
                    .into_iter()
                    .map(|(k, v)| (k, Json::Num(v)))
                    .collect()),
            ),
        ]);
        // quick-mode (smoke) numbers are meaningless as measurements:
        // write them to a scratch name so CI/contributor smoke runs
        // never clobber the canonical BENCH_engine.json log
        let path = if quick_mode() {
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_engine.quick.json")
        } else {
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_engine.json")
        };
        std::fs::write(path, doc.render() + "\n").expect("write BENCH_engine json");
        println!("\nwrote {path}");
    }
}
