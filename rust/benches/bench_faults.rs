//! Fault subsystem benchmark: wall-clock cost of degraded-fabric
//! simulation and of robust selection (the one-build-many-sims
//! scenario fan-out), plus the deterministic simulated-metric payload.
//!
//! `cargo bench --bench bench_faults [-- --json]`
//!
//! With `--json` (what `make bench-faults` passes) the simulated
//! metrics are written to `BENCH_faults.json` at the repo root.
//! Deliberately, the artifact holds **no wall-clock numbers** — only
//! simulation outputs — so the same seed reproduces it byte-for-byte
//! (`tests/workload_determinism.rs` pins the in-process equivalent).
//! `AGV_BENCH_QUICK=1` slashes iteration counts and redirects the
//! artifact to `BENCH_faults.quick.json` (scratch), as in the other
//! bench targets.

use agv_bench::comm::select::{AlgoSelector, RobustObjective};
use agv_bench::comm::{compose_allgatherv, Library, Params};
use agv_bench::perturb::bench::{bench_cases, bench_doc, delta_ensemble};
use agv_bench::perturb::{ensemble, perturbed_allgatherv, DeltaSim, EnsembleCfg};
use agv_bench::sim::Sim;
use agv_bench::topology::systems::SystemKind;
use agv_bench::util::bench::{bench, black_box, iters, quick_mode, warmup};

/// Seed of the canonical BENCH_faults.json grid.
const SEED: u64 = 42;

fn main() {
    let json_out = std::env::args().any(|a| a == "--json");

    // wall-clock: degraded single-collective simulation per system
    for (label, topo, counts, perts) in bench_cases(SEED) {
        let name = format!("faults/{label}");
        let r = bench(&name, warmup(1), iters(16), || {
            for lib in agv_bench::comm::Library::all() {
                black_box(perturbed_allgatherv(&topo, lib, Params::default(), &counts, &perts));
            }
        });
        println!("{}", r.report_line());
    }

    // wall-clock: robust selection over an ensemble (schedule built
    // once, every candidate simulated on every scenario)
    let topo = SystemKind::Dgx1.build();
    let counts = vec![4u64 << 20; 8];
    let ens = ensemble(&topo, &EnsembleCfg::quick(SEED));
    let sims_per_select =
        AlgoSelector::new(Params::default()).evaluate_robust(&topo, &counts, &ens).len()
            * ens.len();
    let r = bench("faults/robust-select/dgx1", warmup(1), iters(8), || {
        let sel = AlgoSelector::new(Params::default());
        black_box(sel.select_robust(&topo, &counts, &ens, RobustObjective::P95));
    });
    println!("{}   ({:.0} scenario-sims/s)", r.report_line(), sims_per_select as f64 / r.mean_s);

    // wall-clock: warm-started delta replay vs cold re-simulation of a
    // time-windowed ensemble over one recorded baseline (DESIGN.md
    // §16). The deterministic work-unit counterpart of this ratio is
    // what BENCH_faults.json records; quick mode gates the wall-clock
    // ratio at >= 2x so a regression fails the CI smoke step.
    let mut sim = Sim::new(&topo);
    let done = compose_allgatherv(&mut sim, Library::Nccl, Params::default(), &counts, None);
    let delta = DeltaSim::record(sim);
    let dens = delta_ensemble(&topo, delta.baseline().makespan, SEED);
    let warm = bench("faults/delta-warm/dgx1/nccl", warmup(1), iters(16), || {
        for perts in &dens {
            black_box(delta.run(perts));
        }
    });
    println!("{}", warm.report_line());
    let cold = bench("faults/delta-cold/dgx1/nccl", warmup(1), iters(4), || {
        for perts in &dens {
            black_box(delta.run_cold(perts));
        }
    });
    println!("{}", cold.report_line());
    let speedup = cold.mean_s / warm.mean_s;
    println!("  -> delta-sim speedup over cold re-simulation: {speedup:.2}x");
    {
        // agreement tripwire on the exact ensemble just timed
        for perts in &dens {
            let tw = delta.run(perts).0.finish(done);
            let tc = delta.run_cold(perts).0.finish(done);
            let rel = (tw - tc).abs() / tc.abs().max(1e-300);
            assert!(rel < 1e-9, "warm {tw} vs cold {tc} diverged: {rel}");
        }
    }
    if quick_mode() {
        assert!(speedup >= 2.0, "delta-sim quick gate: {speedup:.2}x < 2x");
    }

    if json_out {
        let doc = bench_doc(SEED);
        let path = if quick_mode() {
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_faults.quick.json")
        } else {
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_faults.json")
        };
        std::fs::write(path, doc.render() + "\n").expect("write BENCH_faults json");
        println!("\nwrote {path}");
    }
}
