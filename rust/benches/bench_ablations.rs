//! Ablations of the design choices DESIGN.md §10 calls out:
//!  - allgatherv algorithm (ring vs Bruck vs recursive doubling) across
//!    message regimes;
//!  - NCCL's bcast-series Allgatherv (paper Listing 1) vs a hypothetical
//!    native ring allgatherv — quantifying the overhead the paper's
//!    future-work section speculates about;
//!  - staged-pipeline chunk size;
//!  - DFacTo nnz-balanced partition vs naive equal-rows partition
//!    (message CV impact).
//! `cargo bench --bench bench_ablations`.

use agv_bench::comm::algorithms::{
    bruck_allgatherv, recursive_doubling_allgatherv, ring_allgatherv,
};
use agv_bench::comm::nccl::detect_ring;
use agv_bench::comm::transport::{direct_flow, run_schedule, staged_pipeline};
use agv_bench::comm::{run_allgatherv, Library, Params};
use agv_bench::sim::Sim;
use agv_bench::tensor::datasets::{self, ROW_BYTES};
use agv_bench::tensor::partition::profile_rows;
use agv_bench::tensor::ModeProfile;
use agv_bench::topology::systems::{cluster, dgx1};
use agv_bench::util::bench::quick_mode;
use agv_bench::util::stats::Summary;
use agv_bench::util::{fmt_bytes, fmt_time};

/// Simulated time of a schedule over direct GPU flows (isolates the
/// algorithm from the transport).
fn schedule_time(
    topo: &agv_bench::topology::Topology,
    sched: &agv_bench::comm::algorithms::Schedule,
    p: usize,
    counts: &[u64],
) -> f64 {
    let mut sim = Sim::new(topo);
    let entry = vec![None; p];
    let _ = run_schedule(&mut sim, p, sched, &entry, |sim, op, deps| {
        direct_flow(sim, topo, op.from, op.to, op.bytes(counts) as f64, 2.0e-6, deps)
    });
    sim.run().makespan
}

fn main() {
    let dgx = dgx1();
    let clu = cluster(16);

    // AGV_BENCH_QUICK=1 (CI smoke) drops the largest message sizes —
    // the regime coverage matters for the report, not for bit-rot
    let sizes: &[u64] = if quick_mode() {
        &[4 << 10, 1 << 20]
    } else {
        &[4 << 10, 64 << 10, 1 << 20, 16 << 20, 128 << 20]
    };

    println!("=== ablation: allgatherv algorithm x message regime (DGX-1, 8 GPUs) ===");
    println!("{:>10} {:>14} {:>14} {:>14}", "size", "ring", "bruck", "rec-dbl");
    for &msg in sizes {
        let counts = vec![msg; 8];
        let ring = schedule_time(&dgx, &ring_allgatherv(8, None), 8, &counts);
        let bruck = schedule_time(&dgx, &bruck_allgatherv(8), 8, &counts);
        let rd = schedule_time(&dgx, &recursive_doubling_allgatherv(8), 8, &counts);
        println!(
            "{:>10} {:>14} {:>14} {:>14}",
            fmt_bytes(msg), fmt_time(ring), fmt_time(bruck), fmt_time(rd)
        );
    }

    println!("\n=== ablation: Listing-1 bcast-series vs native ring allgatherv (NCCL) ===");
    // native ring = single launch, ring allgatherv schedule on the NCCL
    // ring ordering; bcast-series = the shipping NCCL model.
    for (topo, label, p) in [(&dgx, "dgx1", 8usize), (&clu, "cluster", 8)] {
        println!("  {label}:");
        for msg in [64u64 << 10, 4 << 20, 64 << 20] {
            let counts = vec![msg; p];
            let series = run_allgatherv(Library::Nccl, topo, &counts).time;
            let order = detect_ring(topo, p);
            let native =
                schedule_time(topo, &ring_allgatherv(p, Some(&order)), p, &counts) + 9.0e-6;
            println!(
                "    {:>10}: bcast-series {:>12}  native-ring {:>12}  overhead {:.2}x",
                fmt_bytes(msg),
                fmt_time(series),
                fmt_time(native),
                series / native
            );
        }
    }

    println!("\n=== ablation: staged-pipeline chunk size (DGX-1 0->5, 64MB) ===");
    for chunk in [64u64 << 10, 256 << 10, 512 << 10, 2 << 20, 16 << 20] {
        let params = Params { pipeline_chunk: chunk, ..Params::default() };
        let mut sim = Sim::new(&dgx);
        let id = staged_pipeline(&mut sim, &dgx, &params, 0, 5, 64.0 * 1048576.0, &[]);
        let t = sim.run().finish(id);
        println!("    chunk {:>8}: {:>12}", fmt_bytes(chunk), fmt_time(t));
    }

    println!("\n=== ablation: DFacTo nnz-balanced vs equal-rows partition (message CV) ===");
    for d in datasets::all() {
        let balanced: Vec<f64> = (0..3)
            .flat_map(|m| {
                profile_rows(&d.modes[m], 8)
                    .into_iter()
                    .map(|r| (r * ROW_BYTES) as f64)
            })
            .collect();
        let equal: Vec<f64> = (0..3)
            .flat_map(|m| {
                let rows = d.modes[m].dim / 8;
                std::iter::repeat((rows * ROW_BYTES) as f64).take(8)
            })
            .collect();
        let _ = ModeProfile { dim: 1, skew: 0.0 };
        println!(
            "    {:<10} CV nnz-balanced {:.2} vs equal-rows {:.2} (equal rows balance bytes, unbalance compute)",
            d.name,
            Summary::of(&balanced).cv,
            Summary::of(&equal).cv,
        );
    }
}
