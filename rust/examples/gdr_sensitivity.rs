//! Paper §V-C: sensitivity of MVAPICH-GDR to MV2_GPUDIRECT_LIMIT on
//! irregular workloads. Sweeps the limit for every data set at 2, 8 and
//! 16 cluster GPUs and reports the swing and the optimum per setting —
//! reproducing the paper's observation that the optimal value shifts by
//! orders of magnitude with the GPU count (512MB at 2 GPUs vs 16B at 8
//! for DELICIOUS on their testbed).
//!
//!     cargo run --release --example gdr_sensitivity

use agv_bench::cpals::comm_model::gdr_limit_sweep;
use agv_bench::tensor::datasets;
use agv_bench::topology::systems::SystemKind;
use agv_bench::util::{fmt_bytes, fmt_time};

fn main() {
    let topo = SystemKind::Cluster.build();
    let limits: Vec<u64> = vec![
        16,
        4 << 10,
        64 << 10,
        1 << 20,
        4 << 20,
        8 << 20,
        64 << 20,
        512 << 20,
    ];
    for spec in datasets::all() {
        println!("== {} ==", spec.name);
        for gpus in [2usize, 8, 16] {
            let sweep = gdr_limit_sweep(&topo, &spec, gpus, 1, &limits);
            let (best_l, best_t) = sweep
                .iter()
                .min_by(|a, b| a.1.total_cmp(&b.1))
                .copied()
                .unwrap();
            let worst = sweep.iter().map(|&(_, t)| t).fold(0.0f64, f64::max);
            println!(
                "  {gpus:>2} GPUs: best limit {:>8} ({}/iter), swing {:.2}x",
                fmt_bytes(best_l),
                fmt_time(best_t),
                worst / best_t
            );
            for (l, t) in &sweep {
                println!("        {:>8} -> {:>12}", fmt_bytes(*l), fmt_time(*t));
            }
        }
        println!();
    }
}
