//! Fig. 1 explorer: device/link inventories, GPUDirect P2P matrices,
//! NVLink reachability, and bandwidth matrices for the three systems.
//!
//!     cargo run --release --example topology_explorer

use agv_bench::topology::systems::SystemKind;

fn main() {
    for kind in SystemKind::all() {
        let t = kind.build();
        let n = t.num_gpus();
        println!("==== {} ({} devices, {} links, {} GPUs) ====", t.name, t.devices.len(), t.links.len(), n);

        println!("\n  link inventory:");
        let mut by_class: std::collections::BTreeMap<String, usize> = Default::default();
        for l in &t.links {
            *by_class.entry(format!("{:?}", l.class)).or_default() += 1;
        }
        for (class, count) in by_class {
            println!("    {class:<16} x{count}");
        }

        println!("\n  GPUDirect P2P ('+' P2P, 'n' NVLink multi-hop only, '.' host/IB path):");
        for a in 0..n {
            let row: String = (0..n)
                .map(|b| {
                    if a == b {
                        ' '
                    } else if t.p2p_accessible(a, b) {
                        '+'
                    } else if t.route_nvlink_only(a, b).is_some() {
                        'n'
                    } else {
                        '.'
                    }
                })
                .collect();
            println!("    gpu{a:<2} {row}");
        }

        println!("\n  pairwise bottleneck bandwidth (GB/s, widest route):");
        print!("        ");
        for b in 0..n {
            print!("{b:>6}");
        }
        println!();
        for a in 0..n {
            print!("    {a:>3} ");
            for b in 0..n {
                if a == b {
                    print!("{:>6}", "-");
                } else {
                    let p = t.route_gpus(a, b).unwrap();
                    print!("{:>6.1}", t.path_bandwidth(&p) / 1e9);
                }
            }
            println!();
        }
        println!();
    }
}
