//! END-TO-END driver (DESIGN.md §6): full ReFacTo factorization on a
//! real small workload, proving all three layers compose:
//!
//! - L1/L2: the Pallas krp_scale/matmul/gram kernels inside the JAX
//!   CP-ALS model, AOT-lowered to HLO text at `make artifacts`;
//! - runtime: loaded and executed here through the PJRT CPU client —
//!   python is NOT running;
//! - L3: the DFacTo partitioner slices the tensor across 8 simulated
//!   DGX-1 GPUs; per-rank MTTKRP partials are computed for its slice and
//!   gathered (numerically exact sum of disjoint rows), while the
//!   *timing* of each Allgatherv comes from the simulated MPI /
//!   MPI-CUDA / NCCL libraries.
//!
//! The loss curve (CP fit per iteration) plus the per-library simulated
//! communication times are printed and recorded in EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example refacto_e2e
//!     (add `-- --config e2e` for the 2048x512x256 / 131k-nnz workload)

use agv_bench::comm::Library;
use agv_bench::cpals::driver::Driver;
use agv_bench::runtime::{default_artifacts_dir, Runtime};
use agv_bench::tensor::{synth, ModeProfile, TensorSpec};
use agv_bench::topology::systems::SystemKind;
use agv_bench::util::cli::Args;
use agv_bench::util::fmt_time;

fn main() {
    let args = Args::from_env();
    let config = args.get_or("config", "e2e").to_string();
    let gpus = args.get_usize("gpus", 8);
    let iters = args.get_usize("iters", 10);
    let seed = args.get_u64("seed", 42);

    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    let runtime = match Runtime::open(&dir) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("cannot open artifacts ({e:#}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    let topo = SystemKind::Dgx1.build();
    let mut driver = Driver::new(runtime, &config, &topo, gpus, Library::all().to_vec());
    let ([di, dj, dk], n_pad, rank) = driver.shapes().expect("artifact shapes");
    println!(
        "ReFacTo e2e: {di}x{dj}x{dk}, up to {n_pad} nnz, R={rank}, {gpus} simulated DGX-1 GPUs"
    );

    // Netflix-like skew, planted rank-8 structure + noise.
    let nnz = n_pad - n_pad / 8;
    let spec = TensorSpec {
        name: "e2e-synth",
        modes: [
            ModeProfile { dim: di as u64, skew: 0.6 },
            ModeProfile { dim: dj as u64, skew: 0.4 },
            ModeProfile { dim: dk as u64, skew: 0.2 },
        ],
        nnz: nnz as u64,
    };
    let tensor = synth::low_rank_coo(&spec, nnz, 8, 0.05, seed);
    println!("generated synthetic tensor: {} nnz (planted rank 8 + 5% noise)\n", tensor.nnz());

    let report = driver.run(&tensor, iters, seed).expect("factorization failed");

    println!("iter  fit        d(fit)     compute(real)");
    let mut prev = 0.0;
    for l in &report.iters {
        println!(
            "{:>4}  {:<9.5} {:>+9.5}  {:>12}",
            l.iter,
            l.fit,
            l.fit - prev,
            fmt_time(l.compute_secs)
        );
        prev = l.fit;
    }
    println!("\nsimulated Allgatherv time for the whole factorization (DGX-1, {gpus} GPUs):");
    for (lib, t) in &report.comm_totals {
        println!("  {:<9} {:>12}", lib.name(), fmt_time(*t));
    }
    println!("\ncompute total (real, PJRT CPU): {}", fmt_time(report.compute_total));
    assert!(
        report.final_fit() > report.iters[0].fit,
        "fit did not improve: {} -> {}",
        report.iters[0].fit,
        report.final_fit()
    );
    println!("OK: fit improved {:.5} -> {:.5}", report.iters[0].fit, report.final_fit());
}
