//! Full Fig. 2 reproduction: the OSU Allgatherv sweep on every system,
//! library and GPU count, with ASCII charts and CSV output.
//!
//!     cargo run --release --example osu_benchmark [-- --csv-dir out/]

use agv_bench::report::{fig2, write_csv};
use agv_bench::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cells = fig2::grid();
    print!("{}", fig2::render(&cells));
    if let Some(dir) = args.get("csv-dir") {
        let dir = std::path::PathBuf::from(dir);
        for cell in &cells {
            let p = write_csv(&dir, &fig2::csv_name(cell), &fig2::csv(cell)).unwrap();
            eprintln!("wrote {}", p.display());
        }
    }

    // The qualitative observations §V-B makes about this figure:
    use agv_bench::comm::Library::{Mpi, MpiCuda, Nccl};
    use agv_bench::topology::systems::SystemKind;
    let cell = |s, g| cells.iter().find(|c| c.system == s && c.gpus == g).unwrap();
    let dgx2 = cell(SystemKind::Dgx1, 2);
    let dgx8 = cell(SystemKind::Dgx1, 8);
    let clu8 = cell(SystemKind::Cluster, 8);
    println!("§V-B checkpoints:");
    println!(
        "  DGX-1 2 GPUs @16MB: MPI / MPI-CUDA = {:.1}x (NVLink P2P advantage)",
        dgx2.ratio_at(Mpi, MpiCuda, 16 << 20)
    );
    println!(
        "  DGX-1 8 GPUs @16MB: MPI-CUDA / NCCL = {:.2}x (NCCL rides 2-hop NVLink)",
        dgx8.ratio_at(MpiCuda, Nccl, 16 << 20)
    );
    println!(
        "  DGX-1 8 GPUs @8KB:  NCCL / MPI-CUDA = {:.2}x (bcast-series launch overhead)",
        dgx8.ratio_at(Nccl, MpiCuda, 8 << 10)
    );
    println!(
        "  cluster 8 GPUs @64MB: MPI / NCCL = {:.2}x (all libraries converge on IB)",
        clu8.ratio_at(Mpi, Nccl, 64 << 20)
    );
}
