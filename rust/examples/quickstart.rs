//! Quickstart: build a topology, run one irregular Allgatherv with each
//! communication library, and print the simulated times.
//!
//!     cargo run --release --example quickstart

use agv_bench::comm::{run_allgatherv, Library};
use agv_bench::topology::systems::SystemKind;
use agv_bench::util::{fmt_bytes, fmt_time};

fn main() {
    // An irregular set of per-rank contributions (bytes), like a skewed
    // tensor mode would produce: one dominant block plus small ones.
    let counts: Vec<u64> = vec![
        256 << 10,  // 256 KB
        96 << 20,   // 96 MB (dominant)
        1 << 20,    // 1 MB
        4 << 20,    // 4 MB
        512 << 10,  // 512 KB
        16 << 20,   // 16 MB
        2 << 20,    // 2 MB
        8 << 20,    // 8 MB
    ];
    let total: u64 = counts.iter().sum();
    println!("irregular Allgatherv of {} total across 8 GPUs\n", fmt_bytes(total));

    for system in SystemKind::all() {
        let topo = system.build();
        println!("{}:", topo.name);
        for lib in Library::all() {
            let r = run_allgatherv(lib, &topo, &counts);
            println!(
                "  {:<9} {:>12}   ({} point-to-point flows simulated)",
                lib.name(),
                fmt_time(r.time),
                r.flows
            );
        }
        println!();
    }
    println!("Try `agv fig2`, `agv table1`, `agv fig3`, `agv findings` for the paper's figures.");
}
