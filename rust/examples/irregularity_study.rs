//! Irregularity study — the two future-work extensions of paper §VI:
//!
//! 1. Träff-style message-size *distribution* benchmark: fixed total
//!    volume, varying distribution across ranks (uniform -> spike) on
//!    every system — isolating the irregularity effect that made the
//!    tensor results contradict the OSU benchmark;
//! 2. rank-to-GPU mapping (paper §III-B): sequential vs "spread"
//!    mapping on the CS-Storm, showing when sequential binding is and
//!    is not optimal;
//! 3. more-GPUs-per-node: the same distribution study on a 2-node
//!    multi-DGX system (16 GPUs across NVLink islands).
//!
//!     cargo run --release --example irregularity_study

use agv_bench::comm::{Library, Params};
use agv_bench::osu::distributions::{distribution_study, Distribution};
use agv_bench::topology::systems::{cs_storm, multi_dgx, SystemKind};
use agv_bench::util::fmt_time;

fn main() {
    let total = 512u64 << 20;
    println!("== Träff-style distribution study (total volume 512MB, 8 GPUs) ==\n");
    for system in SystemKind::all() {
        let topo = system.build();
        println!("{}:", topo.name);
        println!(
            "  {:<12} {:>6} {:>14} {:>14} {:>14}",
            "distribution", "CV", "MPI", "MPI-CUDA", "NCCL"
        );
        let study = distribution_study(&topo, 8, total, Params::default(), 42);
        for dist in Distribution::all() {
            let t = |l: Library| {
                study
                    .iter()
                    .find(|p| p.dist == dist && p.library == l)
                    .unwrap()
                    .time
            };
            let cv = study.iter().find(|p| p.dist == dist).unwrap().cv;
            println!(
                "  {:<12} {:>6.2} {:>14} {:>14} {:>14}",
                dist.name(),
                cv,
                fmt_time(t(Library::Mpi)),
                fmt_time(t(Library::MpiCuda)),
                fmt_time(t(Library::Nccl)),
            );
        }
        println!();
    }

    println!("== rank-to-GPU mapping (CS-Storm, 8 ranks, uniform 32MB) ==\n");
    let storm = cs_storm();
    // spread: one rank per NVLink pair — throws away all bonded links
    let spread: Vec<usize> = (0..16).map(|r| (r % 8) * 2 + r / 8).collect();
    let remapped = storm.remap_gpus(&spread);
    let counts = vec![32u64 << 20; 8];
    for lib in Library::all() {
        let seq = lib.build(Params::default()).allgatherv(&storm, &counts);
        let spr = lib.build(Params::default()).allgatherv(&remapped, &counts);
        println!(
            "  {:<9} sequential {:>12}   spread {:>12}   penalty {:.2}x",
            lib.name(),
            fmt_time(seq.time),
            fmt_time(spr.time),
            spr.time / seq.time
        );
    }

    println!("\n== multi-DGX (2 nodes x 8 GPUs): distribution study at 16 ranks ==\n");
    let mdgx = multi_dgx(2);
    let study = distribution_study(&mdgx, 16, total, Params::default(), 42);
    println!(
        "  {:<12} {:>6} {:>14} {:>14} {:>14}",
        "distribution", "CV", "MPI", "MPI-CUDA", "NCCL"
    );
    for dist in Distribution::all() {
        let t = |l: Library| {
            study
                .iter()
                .find(|p| p.dist == dist && p.library == l)
                .unwrap()
                .time
        };
        let cv = study.iter().find(|p| p.dist == dist).unwrap().cv;
        println!(
            "  {:<12} {:>6.2} {:>14} {:>14} {:>14}",
            dist.name(),
            cv,
            fmt_time(t(Library::Mpi)),
            fmt_time(t(Library::MpiCuda)),
            fmt_time(t(Library::Nccl)),
        );
    }
}
