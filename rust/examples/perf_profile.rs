//! Perf-pass profiling harness: times each hot AOT artifact in isolation
//! (EXPERIMENTS.md §Perf, L1/L2 iteration log).
//!
//!     cargo run --release --example perf_profile
use agv_bench::runtime::{HostTensor, Runtime};
use agv_bench::util::prng::Rng;
use std::time::Instant;
fn main() {
    let mut rt = Runtime::open("artifacts").unwrap();
    let mut rng = Rng::new(1);
    let n = 131072usize;
    let vals: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let rows: Vec<i32> = (0..n).map(|_| rng.gen_range(2048) as i32).collect();
    let cb: Vec<i32> = (0..n).map(|_| rng.gen_range(512) as i32).collect();
    let cc: Vec<i32> = (0..n).map(|_| rng.gen_range(256) as i32).collect();
    let fb: Vec<f32> = (0..512*16).map(|_| rng.normal() as f32).collect();
    let fc: Vec<f32> = (0..256*16).map(|_| rng.normal() as f32).collect();
    let t0 = Instant::now();
    rt.ensure_compiled("mttkrp_mode0_e2e").unwrap();
    println!("compile: {:?}", t0.elapsed());
    for i in 0..3 {
        let t = Instant::now();
        let _ = rt.execute("mttkrp_mode0_e2e", &[
            HostTensor::F32(vals.clone()), HostTensor::I32(rows.clone()),
            HostTensor::I32(cb.clone()), HostTensor::I32(cc.clone()),
            HostTensor::F32(fb.clone()), HostTensor::F32(fc.clone())]).unwrap();
        println!("exec {i}: {:?}", t.elapsed());
    }
    // fit artifact
    let lam: Vec<f32> = vec![1.0; 16];
    let fa: Vec<f32> = (0..2048*16).map(|_| rng.normal() as f32).collect();
    let t0 = Instant::now();
    rt.ensure_compiled("fit_e2e").unwrap();
    println!("fit compile: {:?}", t0.elapsed());
    for i in 0..3 {
        let t = Instant::now();
        let _ = rt.execute("fit_e2e", &[
            HostTensor::F32(vec![1.0]), HostTensor::F32(vals.clone()),
            HostTensor::I32(rows.clone()), HostTensor::I32(cb.clone()), HostTensor::I32(cc.clone()),
            HostTensor::F32(lam.clone()), HostTensor::F32(fa.clone()),
            HostTensor::F32(fb.clone()), HostTensor::F32(fc.clone())]).unwrap();
        println!("fit exec {i}: {:?}", t.elapsed());
    }
    // update_post
    let m: Vec<f32> = (0..2048*16).map(|_| rng.normal() as f32).collect();
    rt.ensure_compiled("update_post_mode0_e2e").unwrap();
    for i in 0..3 {
        let t = Instant::now();
        let _ = rt.execute("update_post_mode0_e2e", &[
            HostTensor::F32(m.clone()), HostTensor::F32(fb.clone()), HostTensor::F32(fc.clone())]).unwrap();
        println!("update exec {i}: {:?}", t.elapsed());
    }
}
