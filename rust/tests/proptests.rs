//! Cross-module property tests (randomized invariants with replayable
//! seeds; see util::prop).

use agv_bench::comm::algorithms::{
    all_delivered, bcast_series_allgatherv, bruck_allgatherv, execute, execute_allreduce,
    execute_from, halving_doubling_allreduce, hierarchical_allgatherv, pairwise_alltoallv,
    recursive_doubling_allgatherv, ring_allgatherv, ring_allreduce, LeaderAlgo, Schedule,
};
use agv_bench::comm::select::AlgoSelector;
use agv_bench::comm::{run_allgatherv, Library, Params};
use agv_bench::prop_assert;
use agv_bench::sim::Sim;
use agv_bench::tensor::partition::{profile_nnz_share, profile_rows};
use agv_bench::tensor::ModeProfile;
use agv_bench::topology::systems::{node_groups, SystemKind, SystemSpec};
use agv_bench::topology::{DeviceKind, LinkClass, Path, Topology};
use agv_bench::util::prng::Rng;
use agv_bench::util::prop::{check, counts, fabrics};

#[test]
fn prop_any_algorithm_delivers_everything() {
    check("algorithms-deliver", 96, |rng| {
        let p = 1 + rng.gen_range(16) as usize;
        let pick = rng.gen_range(4);
        let schedules: Vec<Schedule> = match pick {
            0 => vec![ring_allgatherv(p, None)],
            1 => vec![bruck_allgatherv(p)],
            2 => {
                let pp = p.next_power_of_two();
                vec![recursive_doubling_allgatherv(pp)]
            }
            _ => bcast_series_allgatherv(p, None),
        };
        let p_eff = if pick == 2 { p.next_power_of_two() } else { p };
        let refs: Vec<&Schedule> = schedules.iter().collect();
        prop_assert!(all_delivered(&execute(p_eff, &refs)), "p={p} pick={pick}");
        Ok(())
    });
}

#[test]
fn prop_hierarchical_delivers_on_node_groupings() {
    // any system's node grouping, any slice size, both leader
    // algorithms: the two-level schedule is a correct Allgatherv
    check("hier-node-groupings", 48, |rng| {
        let sys = SystemKind::all()[rng.gen_range(3) as usize];
        let topo = sys.build();
        let p = 1 + rng.gen_range(topo.num_gpus() as u64) as usize;
        let groups = node_groups(&topo, p);
        let inter = if rng.gen_range(2) == 0 { LeaderAlgo::Ring } else { LeaderAlgo::Bruck };
        let s = hierarchical_allgatherv(p, &groups, inter);
        prop_assert!(
            all_delivered(&execute(p, &[&s])),
            "{} p={p} {inter:?}",
            sys.name()
        );
        Ok(())
    });
}

#[test]
fn prop_allreduce_schedules_fully_reduce_any_widths() {
    // the reduce-width generator (zeros allowed, never all-zero) drives
    // both allreduce schedules: the coverage oracle must report a full
    // reduction everywhere, and the ring's wire total must hit its
    // closed form — every segment crosses a link 2(P−1) times
    check("allreduce-delivery", 48, |rng| {
        let p = 1 + rng.gen_range(16) as usize;
        let widths = counts::reduce_widths(rng, p, 16 << 20);
        let total: u64 = widths.iter().sum();
        let ring = ring_allreduce(p, None);
        prop_assert!(execute_allreduce(p, &ring), "ring not fully reduced at p={p}");
        prop_assert!(
            ring.wire_bytes(&widths) == 2 * (p as u64 - 1) * total,
            "ring wire bytes off closed form at p={p} widths={widths:?}"
        );
        let pp = p.next_power_of_two();
        let hd = halving_doubling_allreduce(pp);
        prop_assert!(execute_allreduce(pp, &hd), "halving/doubling not reduced at p={pp}");
        Ok(())
    });
}

#[test]
fn prop_pairwise_alltoallv_delivers_rows_to_columns() {
    // the count-matrix generator shapes a random p×p zero-diagonal
    // matrix (block b = src·p + dst); after the pairwise exchange rank
    // r must hold exactly its own row plus its column, and the wire
    // total is exactly the off-diagonal sum — each block moves once
    check("alltoallv-delivery", 48, |rng| {
        let p = 1 + rng.gen_range(12) as usize;
        let m = counts::alltoallv_matrix(rng, p, 8 << 20);
        let s = pairwise_alltoallv(p);
        let init: Vec<Vec<bool>> =
            (0..p).map(|r| (0..p * p).map(|b| b / p == r).collect()).collect();
        let out = execute_from(p, p * p, &init, &[&s]);
        for (r, held) in out.iter().enumerate() {
            for (b, h) in held.iter().enumerate() {
                let (src, dst) = (b / p, b % p);
                prop_assert!(
                    *h == (src == r || dst == r),
                    "p={p}: rank {r} holding of block {b} (src {src} dst {dst}) wrong"
                );
            }
        }
        let off: u64 = (0..p * p).filter(|&b| b / p != b % p).map(|b| m[b]).sum();
        prop_assert!(s.wire_bytes(&m) == off, "p={p}: wire bytes not the off-diagonal sum");
        Ok(())
    });
}

#[test]
fn prop_library_models_accept_irregular_counts() {
    // the shared §IV-style irregularity generators drive every library
    // model (zeros included) to a finite, deterministic result
    check("irregular-counts-libs", 12, |rng| {
        let sys = SystemKind::all()[rng.gen_range(3) as usize];
        let topo = sys.build();
        let p = 2 + rng.gen_range(6) as usize;
        let cv = counts::irregular(rng, p, 64 << 20);
        for lib in Library::all() {
            let a = run_allgatherv(lib, &topo, &cv);
            prop_assert!(
                a.time.is_finite() && a.time >= 0.0,
                "{} {}: {cv:?} -> {}",
                sys.name(), lib.name(), a.time
            );
            let b = run_allgatherv(lib, &topo, &cv);
            prop_assert!(a.time.to_bits() == b.time.to_bits(), "{} nondeterministic", lib.name());
        }
        Ok(())
    });
}

#[test]
fn prop_selector_never_loses_to_fixed_libraries() {
    // the auto candidate set contains each library's default choice,
    // so the argmin can only match or beat every fixed library
    check("selector-dominates", 8, |rng| {
        let sys = SystemKind::all()[rng.gen_range(3) as usize];
        let topo = sys.build();
        let p = 2 + rng.gen_range(6) as usize;
        let cv = counts::irregular(rng, p, 32 << 20);
        let sel = AlgoSelector::new(Params::default()).select_fresh(&topo, &cv);
        for lib in Library::all() {
            let fixed = run_allgatherv(lib, &topo, &cv).time;
            prop_assert!(
                sel.time <= fixed,
                "{}: auto {} ({}) slower than {} {}",
                sys.name(), sel.time, sel.candidate.label(), lib.name(), fixed
            );
        }
        Ok(())
    });
}

#[test]
fn prop_comm_time_monotone_under_scaling() {
    // multiplying every count by 4 must not make any library faster
    check("comm-scaling", 12, |rng| {
        let sys = SystemKind::all()[rng.gen_range(3) as usize];
        let topo = sys.build();
        let p = 2 + rng.gen_range(6) as usize;
        let counts: Vec<u64> = (0..p).map(|_| (16 << 10) + rng.gen_range(16 << 20)).collect();
        let big: Vec<u64> = counts.iter().map(|c| c * 4).collect();
        for lib in Library::all() {
            let t1 = run_allgatherv(lib, &topo, &counts).time;
            let t2 = run_allgatherv(lib, &topo, &big).time;
            prop_assert!(
                t2 > t1,
                "{} {}: 4x bytes not slower ({t1} -> {t2})",
                sys.name(), lib.name()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_comm_deterministic() {
    check("comm-deterministic", 8, |rng| {
        let topo = SystemKind::Dgx1.build();
        let p = 2 + rng.gen_range(7) as usize;
        let counts: Vec<u64> = (0..p).map(|_| rng.gen_range(32 << 20)).collect();
        for lib in Library::all() {
            let a = run_allgatherv(lib, &topo, &counts).time;
            let b = run_allgatherv(lib, &topo, &counts).time;
            prop_assert!(a.to_bits() == b.to_bits(), "{}", lib.name());
        }
        Ok(())
    });
}

#[test]
fn prop_partition_is_exhaustive_and_balanced() {
    check("partition", 64, |rng| {
        let dim = 1000 + rng.gen_range(10_000_000);
        let skew = rng.gen_f64(0.0, 0.95);
        let parts = 1 + rng.gen_range(16) as usize;
        let mode = ModeProfile { dim, skew };
        let rows = profile_rows(&mode, parts);
        prop_assert!(rows.iter().sum::<u64>() == dim, "rows don't cover dim");
        prop_assert!(rows.iter().all(|&r| r >= 1), "empty slice");
        // nnz shares balanced within 10% for moderate skew; at extreme
        // skew a single head row can hold >= a full share (integer
        // granularity breaks the continuous model), so only boundedness
        // is required there.
        let nnz_total = 1_000_000_000u64;
        let shares = profile_nnz_share(&mode, parts, nnz_total);
        let target = nnz_total / parts as u64;
        let sum: u64 = shares.iter().sum();
        let sum_rel = (sum as f64 - nnz_total as f64).abs() / nnz_total as f64;
        prop_assert!(sum_rel < 0.01, "shares don't sum to nnz: {sum}");
        if skew < 0.7 {
            for s in shares {
                let rel = (s as f64 - target as f64).abs() / target as f64;
                prop_assert!(rel < 0.1, "share {s} vs {target} (dim={dim} skew={skew})");
            }
        }
        // at extreme skew a single head row can legally hold several
        // shares (integer granularity); only the sum invariant holds.
        Ok(())
    });
}

#[test]
fn prop_sim_conserves_bytes() {
    // Total bytes recorded on links == sum over flows of bytes x hops,
    // and `res.flows` == the number of positive-byte flows (zero-byte
    // flows complete at their latency without ever carrying traffic).
    // The event-driven engine charges each completing flow its exact
    // leftover, so conservation holds to fp-tolerance by construction —
    // this pins that contract against regressions.
    check("sim-conservation", 24, |rng| {
        let topo = SystemKind::Dgx1.build();
        let mut sim = Sim::new(&topo);
        let mut expected = 0.0f64;
        let mut positive_flows = 0usize;
        let n = 1 + rng.gen_range(20) as usize;
        let mut last = None;
        for _ in 0..n {
            let a = rng.gen_range(8) as usize;
            let mut b = rng.gen_range(8) as usize;
            if a == b {
                b = (b + 1) % 8;
            }
            let path = topo.route_gpus(a, b).unwrap();
            // ~1 in 5 flows carries zero bytes (pure latency marker)
            let bytes = if rng.gen_range(5) == 0 {
                0.0
            } else {
                1.0 + rng.gen_range(1 << 22) as f64
            };
            if bytes > 0.0 {
                positive_flows += 1;
            }
            expected += bytes * path.links.len() as f64;
            let deps: Vec<_> = if rng.next_f64() < 0.5 {
                last.into_iter().collect()
            } else {
                vec![]
            };
            last = Some(sim.flow(path, bytes, 1.0e-7, &deps));
        }
        let res = sim.run();
        let moved: f64 = res.linkdir_bytes.iter().sum();
        if expected > 0.0 {
            let rel = (moved - expected).abs() / expected;
            prop_assert!(rel < 1e-9, "moved {moved} expected {expected}");
        } else {
            prop_assert!(moved == 0.0, "moved {moved} with no payload");
        }
        prop_assert!(
            res.flows == positive_flows,
            "flows {} != positive-byte flows {positive_flows}",
            res.flows
        );
        Ok(())
    });
}

#[test]
fn prop_engines_agree() {
    // Differential oracle: the event-driven engine must reproduce the
    // pre-rewrite reference core on random contended DAGs — makespan to
    // 1e-9 relative, finish times to mixed abs+rel tolerance, and
    // per-linkdir bytes to 1e-6 relative (the reference drops <=1e-6
    // bytes of completion dust per flow; see the numerical contract
    // note in sim::reference).
    check("engine-parity", 24, |rng| {
        let sys = SystemKind::all()[rng.gen_range(3) as usize];
        let topo = sys.build();
        let gpus = topo.num_gpus();
        let n = 2 + rng.gen_range(40) as usize;
        let seed = rng.next_u64();
        let build = |topo: &agv_bench::topology::Topology| {
            let mut r = agv_bench::util::prng::Rng::new(seed);
            let mut sim = Sim::new(topo);
            let mut last = None;
            for _ in 0..n {
                let a = r.gen_range(gpus as u64) as usize;
                let mut b = r.gen_range(gpus as u64) as usize;
                if a == b {
                    b = (b + 1) % gpus;
                }
                let path = topo.route_gpus(a, b).unwrap();
                let bytes = 1.0 + r.gen_range(1 << 24) as f64;
                let lat = if r.gen_range(2) == 0 { 0.0 } else { 1.3e-6 };
                let deps: Vec<_> = if r.next_f64() < 0.4 {
                    last.into_iter().collect()
                } else {
                    vec![]
                };
                last = Some(sim.flow(path, bytes, lat, &deps));
            }
            sim
        };
        let new = build(&topo).run();
        let old = build(&topo).run_reference();
        prop_assert!(new.flows == old.flows, "{}: flow counts differ", sys.name());
        let rel = (new.makespan - old.makespan).abs() / old.makespan;
        prop_assert!(
            rel < 1e-9,
            "{}: makespan {} vs {}",
            sys.name(), new.makespan, old.makespan
        );
        for (i, (a, b)) in new.finish_times().iter().zip(old.finish_times()).enumerate() {
            // mixed tolerance: the reference core may complete a flow up
            // to 1e-6 bytes early at an unrelated event, an absolute
            // (not relative) time shift of <= 1e-6/rate per completion
            prop_assert!(
                (a - b).abs() < 1e-11 + 1e-9 * b.abs(),
                "{}: task {i} {a} vs {b}",
                sys.name()
            );
        }
        for (ld, (a, b)) in new.linkdir_bytes.iter().zip(&old.linkdir_bytes).enumerate() {
            let denom = b.abs().max(1.0);
            prop_assert!((a - b).abs() / denom < 1e-6, "{}: linkdir {ld} {a} vs {b}", sys.name());
        }
        Ok(())
    });
}

#[test]
fn prop_nccl_bcast_series_delivers_on_detected_rings() {
    // The timed NCCL model hand-builds its pipelined broadcasts in the
    // simulator; this property ties its ring ordering back to the
    // validated logical executor: the same bcast-series schedule over
    // the *detected* ring must deliver every block to every rank, on
    // every system at every rank count.
    check("nccl-delivery", 48, |rng| {
        let sys = SystemKind::all()[rng.gen_range(3) as usize];
        let topo = sys.build();
        let p = 1 + rng.gen_range(topo.num_gpus() as u64) as usize;
        let ring = agv_bench::comm::nccl::detect_ring(&topo, p);
        let series = bcast_series_allgatherv(p, Some(&ring));
        let refs: Vec<&Schedule> = series.iter().collect();
        prop_assert!(
            all_delivered(&execute(p, &refs)),
            "{} p={p} ring={ring:?}",
            sys.name()
        );
        Ok(())
    });
}

/// Path sanity shared by the fabric properties: consistent shape,
/// every link a declared live edge joining its neighbors, endpoints
/// the requested GPUs, no device revisited.
fn check_path(t: &Topology, p: &Path, a: usize, b: usize) -> Result<(), String> {
    prop_assert!(p.links.len() + 1 == p.devices.len(), "{}: ragged path {p:?}", t.name);
    prop_assert!(p.devices[0] == t.gpu(a), "{}: path does not start at GPU {a}", t.name);
    prop_assert!(*p.devices.last().unwrap() == t.gpu(b), "{}: path does not end at {b}", t.name);
    for (i, &l) in p.links.iter().enumerate() {
        prop_assert!(l < t.links.len(), "{}: undeclared link {l}", t.name);
        prop_assert!(t.link_alive(l), "{}: path crosses dead link {l}", t.name);
        let (x, y) = (p.devices[i], p.devices[i + 1]);
        let link = &t.links[l];
        prop_assert!(
            (link.a == x && link.b == y) || (link.a == y && link.b == x),
            "{}: link {l} does not join {x}-{y}",
            t.name
        );
    }
    let mut seen = p.devices.clone();
    seen.sort_unstable();
    seen.dedup();
    prop_assert!(seen.len() == p.devices.len(), "{}: path revisits a device", t.name);
    Ok(())
}

/// All-pairs when small, a random sample when large — routing the full
/// 156² of the biggest generated dragonfly every case would dominate
/// the suite's runtime without covering anything new.
fn pair_sample(rng: &mut Rng, n: usize) -> Vec<(usize, usize)> {
    if n <= 24 {
        (0..n).flat_map(|a| (0..n).filter(move |&b| b != a).map(move |b| (a, b))).collect()
    } else {
        (0..600)
            .map(|_| (rng.gen_range(n as u64) as usize, rng.gen_range(n as u64) as usize))
            .filter(|&(a, b)| a != b)
            .collect()
    }
}

#[test]
fn prop_fabric_all_gpu_pairs_route() {
    // connectivity: every generated fabric routes every (sampled) GPU
    // pair through declared live links only, endpoints included
    check("fabric-connectivity", 24, |rng| {
        let spec = fabrics::any_fabric(rng);
        let t = spec.build();
        let n = t.num_gpus();
        prop_assert!(n >= 1 && n == spec.max_gpus(), "{spec:?}: {n} GPUs");
        for (a, b) in pair_sample(rng, n) {
            let Some(p) = t.route_gpus(a, b) else {
                return Err(format!("{}: no route {a}->{b}", t.name));
            };
            check_path(&t, &p, a, b)?;
        }
        Ok(())
    });
}

#[test]
fn prop_fabric_gpu_links_are_symmetric() {
    // every rank sees the same multiset of adjacent link capacities
    // (the fabrics are rank-symmetric by construction), and each entry
    // is genuinely incident to that rank's GPU
    check("fabric-gpu-links", 32, |rng| {
        let spec = fabrics::any_fabric(rng);
        let t = spec.build();
        let classes = |r: usize| -> Vec<u64> {
            let mut c: Vec<u64> =
                t.gpu_links(r).iter().map(|&l| t.links[l].class.bandwidth().to_bits()).collect();
            c.sort_unstable();
            c
        };
        let expect = classes(0);
        for r in 0..t.num_gpus() {
            for &l in &t.gpu_links(r) {
                let link = &t.links[l];
                prop_assert!(
                    link.a == t.gpu(r) || link.b == t.gpu(r),
                    "{}: gpu_links({r}) lists non-incident link {l}",
                    t.name
                );
            }
            prop_assert!(
                classes(r) == expect,
                "{}: rank {r} capacity multiset differs from rank 0",
                t.name
            );
        }
        Ok(())
    });
}

#[test]
fn prop_fabric_reroutes_around_dead_switch_links() {
    // with_links_down on a switch-level link of a live route: the
    // fallback route (when one exists) avoids the dead link and stays
    // valid; on a cross-pod fat-tree of arity >= 4 a detour must exist
    check("fabric-reroute", 24, |rng| {
        let spec = fabrics::any_fabric(rng);
        let t = spec.build();
        let n = t.num_gpus();
        if n < 2 {
            return Ok(());
        }
        let a = rng.gen_range(n as u64) as usize;
        let b = (a + 1 + rng.gen_range(n as u64 - 1) as usize) % n;
        let p = t.route_gpus(a, b).expect("fabric route");
        // switch-level = both endpoints are fabric switches (node-less)
        let Some(&dead) = p
            .links
            .iter()
            .find(|&&l| {
                t.devices[t.links[l].a].node == usize::MAX
                    && t.devices[t.links[l].b].node == usize::MAX
            })
        else {
            return Ok(()); // intra-node or single-hop: nothing to kill
        };
        let masked = t.with_links_down(&[dead]);
        match masked.route_gpus(a, b) {
            Some(re) => {
                prop_assert!(!re.links.contains(&dead), "{}: reroute reuses dead link", t.name);
                check_path(&masked, &re, a, b)?;
            }
            None => {
                let diverse = matches!(spec, SystemSpec::FatTree { k } if k >= 4);
                prop_assert!(
                    !diverse,
                    "{}: no reroute for {a}->{b} despite path diversity",
                    t.name
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fat_tree_size_and_full_bisection() {
    // fat_tree(k) hosts exactly k^3/4 GPUs, and every switch stage has
    // equal aggregate up/down capacity: one same-class uplink per host
    // at each of the three stages, and every switch of uniform degree k
    check("fat-tree-bisection", 16, |rng| {
        let SystemSpec::FatTree { k } = fabrics::fat_tree_spec(rng) else { unreachable!() };
        let t = SystemSpec::FatTree { k }.build();
        let hosts = k * k * k / 4;
        prop_assert!(t.num_gpus() == hosts, "k={k}: {} GPUs, want {hosts}", t.num_gpus());
        let is_switch = |d: usize| t.devices[d].kind == DeviceKind::IbSwitch;
        let mut host_up = 0usize; // nic <-> edge
        let mut inter = 0usize; // edge<->agg and agg<->core
        let mut degree = vec![0usize; t.devices.len()];
        for l in &t.links {
            match (is_switch(l.a), is_switch(l.b)) {
                (true, true) => {
                    prop_assert!(l.class == LinkClass::InfinibandFdr, "k={k}: mixed classes");
                    inter += 1;
                    degree[l.a] += 1;
                    degree[l.b] += 1;
                }
                (true, false) | (false, true) => {
                    prop_assert!(l.class == LinkClass::InfinibandFdr, "k={k}: mixed classes");
                    host_up += 1;
                    degree[if is_switch(l.a) { l.a } else { l.b }] += 1;
                }
                (false, false) => {} // host-internal chain links
            }
        }
        prop_assert!(host_up == hosts, "k={k}: {host_up} host uplinks, want {hosts}");
        // edge->agg carries one link per host equivalent, agg->core too
        prop_assert!(inter == 2 * hosts, "k={k}: {inter} switch links, want {}", 2 * hosts);
        for (d, &deg) in degree.iter().enumerate() {
            if is_switch(d) {
                prop_assert!(deg == k, "k={k}: switch {d} degree {deg}");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_nccl_ring_is_permutation() {
    check("nccl-ring", 48, |rng| {
        let sys = SystemKind::all()[rng.gen_range(3) as usize];
        let topo = sys.build();
        let p = 1 + rng.gen_range(topo.num_gpus() as u64) as usize;
        let ring = agv_bench::comm::nccl::detect_ring(&topo, p);
        let mut sorted = ring.clone();
        sorted.sort_unstable();
        prop_assert!(
            sorted == (0..p).collect::<Vec<_>>(),
            "{}: ring {ring:?} not a permutation of 0..{p}",
            sys.name()
        );
        Ok(())
    });
}
