//! Integration: communication-library behaviour across modules
//! (topology x sim x algorithms), beyond the per-module unit tests.

use agv_bench::comm::{run_allgatherv, Library, Params};
use agv_bench::topology::systems::{cluster, cs_storm, dgx1, SystemKind};

#[test]
fn all_libraries_run_on_all_systems_and_counts() {
    for sys in SystemKind::all() {
        let topo = sys.build();
        for gpus in [1usize, 2, 3, 5, 8] {
            if gpus > topo.num_gpus() {
                continue;
            }
            let counts: Vec<u64> = (0..gpus).map(|r| ((r + 1) as u64) << 16).collect();
            for lib in Library::all() {
                let r = run_allgatherv(lib, &topo, &counts);
                if gpus > 1 {
                    assert!(r.time > 0.0, "{} {} {gpus}", sys.name(), lib.name());
                } else {
                    // degenerate single-rank collective: nothing moves
                    // (plain MPI still pays its explicit staging copies)
                    assert!(r.time >= 0.0);
                }
                assert!(r.time.is_finite());
            }
        }
    }
}

#[test]
fn cost_scales_roughly_linearly_at_large_sizes() {
    let topo = dgx1();
    for lib in Library::all() {
        let t1 = run_allgatherv(lib, &topo, &[32 << 20; 8]).time;
        let t2 = run_allgatherv(lib, &topo, &[64 << 20; 8]).time;
        let ratio = t2 / t1;
        assert!(
            (1.6..2.4).contains(&ratio),
            "{}: doubling size gives {ratio}x",
            lib.name()
        );
    }
}

#[test]
fn irregular_cost_at_least_uniform_cost_of_same_total() {
    // concentrating all bytes on one rank can't be cheaper than one
    // balanced call for ring-style schedules
    let topo = cluster(8);
    for lib in Library::all() {
        let uniform = run_allgatherv(lib, &topo, &[8 << 20; 8]).time;
        let mut counts = vec![0u64; 8];
        counts[3] = 64 << 20;
        let skewed = run_allgatherv(lib, &topo, &counts).time;
        assert!(
            skewed > 0.5 * uniform,
            "{}: skewed {skewed} vs uniform {uniform}",
            lib.name()
        );
    }
}

#[test]
fn zero_counts_everywhere_is_cheap() {
    let topo = dgx1();
    for lib in Library::all() {
        let r = run_allgatherv(lib, &topo, &[0; 8]);
        assert!(r.time < 1e-3, "{}: {r:?}", lib.name());
    }
}

#[test]
fn ring_serialization_hurts_mpicuda_on_dominant_block() {
    // the mechanism behind the Fig. 3 irregularity effects: a dominant
    // block crosses P-1 ring steps under MPI but is pipelined by NCCL
    let topo = dgx1();
    let mut counts = vec![256u64 << 10; 8];
    counts[0] = 128 << 20;
    let nccl = run_allgatherv(Library::Nccl, &topo, &counts).time;
    let cuda = run_allgatherv(Library::MpiCuda, &topo, &counts).time;
    assert!(nccl < cuda, "nccl {nccl} !< mpicuda {cuda}");
}

#[test]
fn params_are_actually_plumbed() {
    // doubling NCCL launch overhead must slow small-message collectives
    let topo = cs_storm();
    let counts = vec![4u64 << 10; 16];
    let base = Library::Nccl.build(Params::default()).allgatherv(&topo, &counts);
    let slow_params = Params { nccl_launch_overhead: 90.0e-6, ..Params::default() };
    let slow = Library::Nccl.build(slow_params).allgatherv(&topo, &counts);
    assert!(slow.time > base.time * 2.0, "{} vs {}", base.time, slow.time);

    // shrinking the eager limit must slow small MPI messages
    let fast = Library::Mpi.build(Params::default()).allgatherv(&topo, &counts);
    let no_eager = Params { eager_limit: 0, ..Params::default() };
    let slower = Library::Mpi.build(no_eager).allgatherv(&topo, &counts);
    assert!(slower.time > fast.time, "{} vs {}", fast.time, slower.time);
}

#[test]
fn multi_dgx_nccl_ring_spans_nodes() {
    // future-work system: NCCL must still build a valid ring across two
    // NVLink islands and complete collectives; intra-node stays NVLink.
    use agv_bench::comm::nccl::detect_ring;
    use agv_bench::topology::systems::multi_dgx;
    let t = multi_dgx(2);
    let ring = detect_ring(&t, 16);
    let mut sorted = ring.clone();
    sorted.sort_unstable();
    assert_eq!(sorted, (0..16).collect::<Vec<_>>());
    for lib in Library::all() {
        let r = run_allgatherv(lib, &t, &vec![4u64 << 20; 16]);
        assert!(r.time > 0.0 && r.time.is_finite(), "{}", lib.name());
    }
    // 16 GPUs on 2 DGX nodes beat 16 single-GPU cluster nodes (more
    // NVLink, fewer IB crossings)
    let clu = cluster(16);
    let m = vec![16u64 << 20; 16];
    let t_mdgx = run_allgatherv(Library::Nccl, &t, &m).time;
    let t_clu = run_allgatherv(Library::Nccl, &clu, &m).time;
    assert!(t_mdgx < t_clu, "multi-dgx {t_mdgx} !< cluster {t_clu}");
}

#[test]
fn rank_remapping_changes_cost_on_cs_storm() {
    // paper §III-B: sequential rank->GPU binding is not always neutral;
    // a mapping that splits the bonded pairs must cost more at 2 ranks.
    let storm = cs_storm();
    let spread: Vec<usize> = (0..16).map(|r| (r % 8) * 2 + r / 8).collect();
    let remapped = storm.remap_gpus(&spread);
    let counts = vec![64u64 << 20; 2];
    let seq = run_allgatherv(Library::MpiCuda, &storm, &counts).time;
    let spr = run_allgatherv(Library::MpiCuda, &remapped, &counts).time;
    assert!(
        spr > 2.0 * seq,
        "splitting the NVLink pair should hurt: seq={seq} spread={spr}"
    );
}

#[test]
fn flows_counted() {
    let topo = cluster(4);
    let r = run_allgatherv(Library::Mpi, &topo, &[1 << 20; 4]);
    // ring: 4 ranks x 3 steps = 12 wire sends, plus 4 D2H + 4 H2D staging
    assert!(r.flows >= 12, "{}", r.flows);
}
