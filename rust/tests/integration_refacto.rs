//! Integration: Fig. 3 qualitative shape assertions (paper §V-C) over
//! the full (dataset x system x library x GPUs) grid.

use std::sync::LazyLock;

use agv_bench::comm::Library::{Mpi, MpiCuda, Nccl};
use agv_bench::report::fig3::{panels, Fig3Panel};
use agv_bench::topology::systems::SystemKind;

static PANELS: LazyLock<Vec<Fig3Panel>> = LazyLock::new(|| panels(1));

fn panel(system: SystemKind, gpus: usize) -> &'static Fig3Panel {
    PANELS
        .iter()
        .find(|p| p.system == system && p.gpus == gpus)
        .unwrap()
}

#[test]
fn grid_complete_and_positive() {
    assert_eq!(PANELS.len(), 8);
    for p in PANELS.iter() {
        for row in &p.reports {
            for r in row {
                assert!(r.total_time > 0.0 && r.total_time.is_finite());
            }
        }
    }
}

#[test]
fn nccl_dgx1_vs_cluster_tensor_headline() {
    // §VI: "On the tensor data sets, we observed as much as a 4.7x
    // difference" (DGX-1 vs cluster, NCCL)
    let mut best = 0.0f64;
    for d in ["NETFLIX", "AMAZON", "DELICIOUS", "NELL-1"] {
        let ratio = panel(SystemKind::Cluster, 8).time(d, Nccl)
            / panel(SystemKind::Dgx1, 8).time(d, Nccl);
        assert!(ratio > 1.0, "{d}: DGX-1 not faster ({ratio})");
        best = best.max(ratio);
    }
    assert!(best > 1.8, "max advantage only {best}x");
}

#[test]
fn nccl_beats_mpicuda_on_irregular_2gpu_nvlink_but_not_amazon() {
    // "NCCL on all of the systems when using two GPUs exhibits better
    // performance than MPI-CUDA across all of the tensors with the
    // exception of AMAZON" — our model reproduces the flip on the
    // NVLink systems for the data sets whose dominant blocks cross the
    // IPC cliff (DELICIOUS, NELL-1); see EXPERIMENTS.md for NETFLIX.
    for sys in [SystemKind::Dgx1, SystemKind::CsStorm] {
        let p = panel(sys, 2);
        for d in ["DELICIOUS", "NELL-1"] {
            assert!(
                p.time(d, Nccl) < p.time(d, MpiCuda),
                "{} {d}: NCCL not faster",
                sys.name()
            );
        }
        assert!(
            p.time("AMAZON", MpiCuda) < p.time("AMAZON", Nccl),
            "{}: AMAZON should keep the benchmark ordering",
            sys.name()
        );
    }
}

#[test]
fn mpicuda_nell1_improves_from_2_to_8_gpus_on_dgx1() {
    // "the performance of MPI-CUDA on the NELL-1 data set when using 8
    // GPUs on the DGX-1 improves by 3.14x when compared to ... two GPUs"
    // (because per-rank blocks drop below the staging cliff)
    let t2 = panel(SystemKind::Dgx1, 2).time("NELL-1", MpiCuda);
    let t8 = panel(SystemKind::Dgx1, 8).time("NELL-1", MpiCuda);
    assert!(t8 < t2, "8 GPUs ({t8}) not faster than 2 ({t2})");
}

#[test]
fn cluster_library_times_within_sane_band() {
    // on the cluster all libraries share the same wire; no library may
    // win by more than ~10x on any data set (the paper's gaps are small)
    for gpus in [2usize, 8, 16] {
        let p = panel(SystemKind::Cluster, gpus);
        for d in ["NETFLIX", "AMAZON", "DELICIOUS", "NELL-1"] {
            let times = [p.time(d, Mpi), p.time(d, MpiCuda), p.time(d, Nccl)];
            let max = times.iter().cloned().fold(0.0, f64::max);
            let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
            assert!(max / min < 10.0, "{d}@{gpus}: spread {}", max / min);
        }
    }
}

#[test]
fn totals_increase_with_dataset_size_for_fixed_config() {
    // Fig. 3's x-axis ordering: bigger data sets cost more to communicate
    for sys in SystemKind::all() {
        let p = panel(sys, 8);
        for lib in [Mpi, MpiCuda, Nccl] {
            let nf = p.time("NETFLIX", lib);
            let nell = p.time("NELL-1", lib);
            assert!(
                nell > nf,
                "{} {}: NELL-1 ({nell}) !> NETFLIX ({nf})",
                sys.name(),
                lib.name()
            );
        }
    }
}
