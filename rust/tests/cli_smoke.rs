//! Smoke tests for the `agv` binary's CLI surface: every subcommand
//! listed in `main.rs::HELP` must parse (i.e. never hit the
//! unknown-command path, which exits 2), and `agv findings` must emit
//! the §VI ratio lines.

use std::process::{Command, Output};

fn agv(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_agv"))
        .args(args)
        .output()
        .expect("spawning agv")
}

fn stdout(o: &Output) -> String {
    String::from_utf8_lossy(&o.stdout).into_owned()
}

fn stderr(o: &Output) -> String {
    String::from_utf8_lossy(&o.stderr).into_owned()
}

/// Every subcommand in HELP. Kept in sync by `help_lists_every_subcommand`.
const COMMANDS: &[&str] = &[
    "topo", "fig2", "table1", "fig3", "findings", "auto", "osu", "refacto",
    "sweep-gdr", "faults", "workload", "serve", "collective", "e2e", "artifacts", "help",
];

#[test]
fn help_lists_every_subcommand() {
    let out = agv(&["help"]);
    assert!(out.status.success());
    let text = stdout(&out);
    for cmd in COMMANDS {
        assert!(
            text.lines().any(|l| l.trim_start().starts_with(cmd)),
            "HELP does not list `{cmd}`:\n{text}"
        );
    }
}

#[test]
fn no_args_prints_help() {
    let out = agv(&[]);
    assert!(out.status.success());
    assert!(stdout(&out).contains("USAGE: agv"));
}

#[test]
fn unknown_command_exits_2() {
    let out = agv(&["frobnicate"]);
    assert_eq!(out.status.code(), Some(2));
    assert!(stderr(&out).contains("unknown command"));
}

/// A subcommand "parses" iff it never reaches the unknown-command path:
/// exit code 2 with an "unknown command" message is the parse failure
/// signal (`e2e`/`artifacts` legitimately exit 1 when no AOT artifacts
/// are built — that is an environment error, not a parse error).
fn assert_parses(args: &[&str]) {
    let out = agv(args);
    let err = stderr(&out);
    assert!(
        !err.contains("unknown command"),
        "`agv {}` hit the unknown-command path:\n{err}",
        args.join(" ")
    );
    if !out.status.success() {
        assert_ne!(
            out.status.code(),
            Some(2),
            "`agv {}` exited 2 (CLI parse failure):\n{err}",
            args.join(" ")
        );
    }
}

#[test]
fn topo_runs() {
    let out = agv(&["topo"]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    for system in ["cluster-16", "dgx1", "cs-storm"] {
        assert!(text.contains(system), "missing {system}");
    }
}

#[test]
fn table1_runs() {
    let out = agv(&["table1"]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("TABLE I"));
    for d in ["NETFLIX", "AMAZON", "DELICIOUS", "NELL-1"] {
        assert!(text.contains(d), "missing {d}");
    }
}

#[test]
fn osu_single_cell_runs() {
    assert_parses(&["osu", "--system", "dgx1", "--gpus", "2", "--lib", "nccl"]);
}

#[test]
fn refacto_single_cell_runs() {
    let out = agv(&[
        "refacto", "--dataset", "netflix", "--system", "dgx1", "--gpus", "2",
        "--lib", "nccl", "--iters", "1",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stdout(&out).contains("NETFLIX"));
}

#[test]
fn auto_report_single_cell_runs() {
    let out = agv(&["auto", "--dataset", "netflix", "--gpus", "2"]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("AUTO-SELECTION"), "{text}");
    assert!(text.contains("NETFLIX"), "{text}");
    assert!(text.contains("geomean"), "{text}");
    // the selector's decision-table statistics ride the report footer
    assert!(text.contains("decision-table cache:"), "{text}");
    assert!(text.contains("hits"), "{text}");
    assert!(text.contains("misses"), "{text}");
}

#[test]
fn osu_auto_lib_runs() {
    let out = agv(&["osu", "--system", "dgx1", "--gpus", "2", "--lib", "auto"]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("auto selection"), "{text}");
    // every printed choice is a (library, algorithm) label
    assert!(text.contains('/'), "{text}");
}

#[test]
fn refacto_auto_lib_runs() {
    let out = agv(&[
        "refacto", "--dataset", "netflix", "--system", "dgx1", "--gpus", "2",
        "--lib", "auto", "--iters", "1",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("auto selection"), "{text}");
    assert!(text.contains("mode 0"), "{text}");
    assert!(text.contains("decision-table cache:"), "{text}");
}

#[test]
fn sweep_gdr_runs() {
    let out = agv(&["sweep-gdr", "--dataset", "netflix", "--gpus", "2", "--limits", "16,1MB"]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stdout(&out).contains("<-- best"));
}

#[test]
fn faults_list_links_runs() {
    let out = agv(&["faults", "--list-links", "--system", "dgx1"]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("links of dgx1"), "{text}");
    assert!(text.contains("NvLink") && text.contains("PcieGen3x16"), "{text}");
    // the full `agv faults` study is smoked in release mode by CI
}

#[test]
fn osu_perturbed_sweep_runs() {
    let out = agv(&[
        "osu", "--system", "dgx1", "--gpus", "2", "--lib", "nccl",
        "--perturb", "straggler:0:0.5",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("degraded [gpu0 straggler x0.50]"), "{text}");
    // a malformed spec and an out-of-range target both exit 2 cleanly
    let out = agv(&["osu", "--system", "dgx1", "--gpus", "2", "--perturb", "warp:0:0.5"]);
    assert_eq!(out.status.code(), Some(2));
    assert!(stderr(&out).contains("unknown kind"), "{}", stderr(&out));
    let out = agv(&["osu", "--system", "dgx1", "--gpus", "2", "--perturb", "link:999:0.5"]);
    assert_eq!(out.status.code(), Some(2));
    assert!(stderr(&out).contains("out of range"), "{}", stderr(&out));
}

#[test]
fn refacto_perturbed_runs() {
    let out = agv(&[
        "refacto", "--dataset", "netflix", "--system", "dgx1", "--gpus", "2",
        "--lib", "nccl", "--iters", "1", "--perturb", "straggler:0:0.5",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("degraded"), "{text}");
    assert!(text.contains("slowdown"), "{text}");
}

#[test]
fn workload_perturbed_runs() {
    let out = agv(&[
        "workload", "--system", "dgx1", "--tenants", "2", "--ops", "1",
        "--gpus", "2", "--total", "1MB", "--perturb", "straggler:0:0.5",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stdout(&out).contains("WORKLOAD"), "{}", stdout(&out));
    // an out-of-range fault is a clean workload error, not a panic
    let out = agv(&[
        "workload", "--system", "dgx1", "--tenants", "2", "--ops", "1",
        "--gpus", "2", "--total", "1MB", "--perturb", "link:999:0.5",
    ]);
    assert_eq!(out.status.code(), Some(1), "{}", stderr(&out));
    let err = stderr(&out);
    assert!(err.contains("out of range"), "{err}");
    assert!(!err.contains("panicked"), "{err}");
    // ... and --perturb does not apply to the --refacto hook
    let out = agv(&["workload", "--refacto", "netflix", "--perturb", "straggler:0:0.5"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("--perturb"), "{}", stderr(&out));
}

#[test]
fn malformed_numeric_flags_are_rejected_cleanly() {
    // bad --chunks / --gap / --iters never panic: the rejection names
    // the flag and what it expects, and the exit is the command's
    // normal failure path
    let cases: &[&[&str]] = &[
        &["collective", "--op", "allreduce", "--gpus", "2", "--chunks", "many"],
        &[
            "workload", "--system", "dgx1", "--tenants", "2", "--ops", "1",
            "--gpus", "2", "--gap", "soon",
        ],
        &["fig3", "--iters", "not-a-number"],
    ];
    for args in cases {
        let out = agv(args);
        assert!(
            !out.status.success(),
            "`agv {}` accepted a malformed numeric flag",
            args.join(" ")
        );
        let err = stderr(&out);
        assert!(err.contains("expects"), "`agv {}`:\n{err}", args.join(" "));
        assert!(!err.contains("panicked"), "`agv {}` panicked:\n{err}", args.join(" "));
    }
    // malformed --perturb outage items are rejected with the grammar
    let out = agv(&["osu", "--system", "dgx1", "--gpus", "2", "--perturb", "down:one"]);
    assert_eq!(out.status.code(), Some(2), "{}", stderr(&out));
    let err = stderr(&out);
    assert!(err.contains("bad target"), "{err}");
    assert!(!err.contains("panicked"), "{err}");
    let out = agv(&["osu", "--system", "dgx1", "--gpus", "2", "--perturb", "gpudown:0:0.5:1:2:3"]);
    assert_eq!(out.status.code(), Some(2), "{}", stderr(&out));
    assert!(stderr(&out).contains("expected"), "{}", stderr(&out));
}

#[test]
fn fail_fast_commands_reject_permanent_outages() {
    // a permanent outage would starve the fail-fast engine (diagnosed
    // stall, not a slow finish): the CLI points at the recovery-aware
    // surfaces instead of panicking mid-run
    let out = agv(&[
        "osu", "--system", "dgx1", "--gpus", "2", "--lib", "nccl", "--perturb", "down:0",
    ]);
    assert_eq!(out.status.code(), Some(2), "{}", stderr(&out));
    let err = stderr(&out);
    assert!(err.contains("faults --outage"), "{err}");
    assert!(!err.contains("panicked"), "{err}");
    // a *transient* outage revives and completes natively
    let out = agv(&[
        "osu", "--system", "dgx1", "--gpus", "2", "--lib", "nccl",
        "--perturb", "down:0:0.0005:0.001",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stdout(&out).contains("degraded"), "{}", stdout(&out));
}

#[test]
fn workload_gap_flag_runs_and_rejects_negative() {
    let out = agv(&[
        "workload", "--system", "dgx1", "--tenants", "2", "--ops", "1",
        "--gpus", "2", "--total", "1MB", "--gap", "0.002",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stdout(&out).contains("WORKLOAD"), "{}", stdout(&out));
    let out = agv(&[
        "workload", "--system", "dgx1", "--tenants", "2", "--ops", "1",
        "--gpus", "2", "--total", "1MB", "--gap", "-0.5",
    ]);
    assert_eq!(out.status.code(), Some(1), "{}", stderr(&out));
    let err = stderr(&out);
    assert!(err.contains("gap"), "{err}");
    assert!(!err.contains("panicked"), "{err}");
}

#[test]
fn workload_recover_supervises_hard_outages() {
    // a permanently dead GPU with only 2 ranks: no quorum to shrink
    // to, so the stalled jobs abort — but the supervised run completes
    // with SLO accounting instead of panicking
    let out = agv(&[
        "workload", "--system", "dgx1", "--tenants", "2", "--ops", "1",
        "--gpus", "2", "--total", "1MB", "--perturb", "gpudown:0", "--recover",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("SUPERVISED WORKLOAD"), "{text}");
    assert!(text.contains("aborted"), "{text}");
    // without --recover the same spec is rejected up front: the
    // fail-fast engine would stall, not finish slowly
    let out = agv(&[
        "workload", "--system", "dgx1", "--tenants", "2", "--ops", "1",
        "--gpus", "2", "--total", "1MB", "--perturb", "gpudown:0",
    ]);
    assert_eq!(out.status.code(), Some(2), "{}", stderr(&out));
    assert!(stderr(&out).contains("--recover"), "{}", stderr(&out));
    // ... and --recover does not apply to the --refacto hook
    let out = agv(&["workload", "--refacto", "netflix", "--recover"]);
    assert_eq!(out.status.code(), Some(1), "{}", stderr(&out));
    assert!(stderr(&out).contains("--recover"), "{}", stderr(&out));
}

#[test]
#[ignore = "full 3-system outage study; covered in release by CI's hard-fault smoke step"]
fn faults_outage_study_runs() {
    let out = agv(&["faults", "--outage", "--seed", "7"]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("OUTAGES"), "{text}");
    assert!(text.contains("outage verdict"), "{text}");
}

#[test]
fn workload_smoke_on_each_system() {
    for system in ["cluster", "dgx1", "cs-storm"] {
        let out = agv(&[
            "workload", "--system", system, "--tenants", "2", "--ops", "2",
            "--gpus", "2", "--total", "1MB", "--seed", "1",
        ]);
        assert!(out.status.success(), "{system}: {}", stderr(&out));
        let text = stdout(&out);
        assert!(text.contains("WORKLOAD"), "{system}:\n{text}");
        assert!(text.contains("slowdown"), "{system}:\n{text}");
        assert!(text.contains("tenant-0") && text.contains("tenant-1"), "{system}:\n{text}");
    }
}

#[test]
fn workload_auto_lib_runs() {
    let out = agv(&[
        "workload", "--system", "dgx1", "--tenants", "2", "--ops", "1",
        "--gpus", "2", "--total", "1MB", "--lib", "auto",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    // auto tenants report (library, algorithm) candidate labels
    assert!(stdout(&out).contains('/'), "{}", stdout(&out));
}

#[test]
fn workload_refacto_hook_runs() {
    let out = agv(&[
        "workload", "--refacto", "netflix", "--system", "dgx1", "--tenants", "2",
        "--iters", "1", "--gpus", "2",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("CONTENDED REFACTO"), "{text}");
    assert!(text.contains("slowdown"), "{text}");
    // flags that cannot apply to the refacto tenant are rejected, not
    // silently ignored
    let out = agv(&["workload", "--refacto", "netflix", "--total", "1MB"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("--total"), "{}", stderr(&out));
}

#[test]
fn workload_rejects_malformed_trace_cleanly() {
    let dir = std::env::temp_dir().join("agv_workload_trace_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bad.trace");
    std::fs::write(&path, "1KB, 2KB\n1KB, junk\n").unwrap();
    let out = agv(&["workload", "--system", "dgx1", "--trace", path.to_str().unwrap()]);
    assert_eq!(out.status.code(), Some(1), "malformed trace must exit 1");
    let err = stderr(&out);
    assert!(err.contains("workload failed"), "{err}");
    assert!(err.contains("line 2") && err.contains("junk"), "no line context:\n{err}");
    assert!(!err.contains("panicked"), "panicked instead of clean error:\n{err}");
    // a missing trace file is the same class of clean failure
    let out = agv(&["workload", "--trace", "/definitely/not/here.trace"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(!stderr(&out).contains("panicked"), "{}", stderr(&out));
}

#[test]
fn workload_valid_trace_runs() {
    let dir = std::env::temp_dir().join("agv_workload_trace_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("good.trace");
    std::fs::write(&path, "# two ops on two ranks\n1MB, 64KB\n0, 2MB\n").unwrap();
    let out = agv(&[
        "workload", "--system", "dgx1", "--tenants", "2", "--ops", "2",
        "--gpus", "2", "--trace", path.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stdout(&out).contains("trace"), "{}", stdout(&out));
}

#[test]
fn serve_pinned_rate_runs_every_policy() {
    for policy in ["fifo", "fair", "reject"] {
        let out = agv(&[
            "serve", "--system", "dgx1", "--tenants", "2", "--jobs", "3",
            "--gpus", "2", "--total", "1MB", "--rate", "200", "--policy", policy,
            "--depth", "2", "--seed", "1",
        ]);
        assert!(out.status.success(), "{policy}: {}", stderr(&out));
        let text = stdout(&out);
        assert!(text.contains("SERVE"), "{policy}:\n{text}");
        assert!(text.contains("latency p50"), "{policy}:\n{text}");
        assert!(text.contains(&format!("policy {policy}(2)")), "{policy}:\n{text}");
    }
}

#[test]
fn serve_zero_rate_is_the_closed_loop_anchor() {
    // --rate 0 degenerates to the closed-loop workload engine; the
    // header says so and the run completes every job
    let out = agv(&[
        "serve", "--system", "dgx1", "--tenants", "2", "--jobs", "2",
        "--gpus", "2", "--total", "1MB", "--rate", "0", "--seed", "1",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("closed loop (zero arrival rate)"), "{text}");
    assert!(text.contains("4 completed, 0 rejected"), "{text}");
}

#[test]
fn serve_sweep_reports_the_knee() {
    // no --rate: sweep offered load and mark the p95 knee row
    let dir = std::env::temp_dir().join("agv_serve_csv_test");
    let out = agv(&[
        "serve", "--system", "dgx1", "--tenants", "2", "--jobs", "4",
        "--gpus", "2", "--total", "1MB", "--seed", "1",
        "--csv-dir", dir.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("SERVE"), "{text}");
    assert!(text.contains("<=="), "no knee marker:\n{text}");
    assert!(text.contains("capacity verdict"), "{text}");
    let csv = std::fs::read_to_string(dir.join("serve.csv")).expect("serve.csv written");
    assert!(csv.starts_with("system,"), "{csv}");
    assert!(csv.lines().count() > 1, "{csv}");
}

#[test]
fn serve_rejects_malformed_flags_with_exit_2() {
    // usage errors exit 2 before any simulation, naming the flag
    let cases: &[(&[&str], &str)] = &[
        (&["serve", "--system", "dgx1", "--rate", "junk"], "--rate expects a finite number"),
        (&["serve", "--system", "dgx1", "--rate", "-1"], "--rate must be finite non-negative"),
        (&["serve", "--system", "dgx1", "--policy", "nope"], "unknown policy `nope`"),
        (&["serve", "--system", "dgx1", "--depth", "0"], "--depth must be at least 1"),
        (&["serve", "--system", "dgx1", "--lib", "cudnn"], "unknown library"),
        (&["serve", "--system", "dgx1", "--total", "lots"], "bad size"),
    ];
    for (args, fragment) in cases {
        let out = agv(args);
        assert_eq!(out.status.code(), Some(2), "`agv {}`:\n{}", args.join(" "), stderr(&out));
        let err = stderr(&out);
        assert!(err.contains(fragment), "`agv {}` missing '{fragment}':\n{err}", args.join(" "));
        assert!(!err.contains("panicked"), "`agv {}` panicked:\n{err}", args.join(" "));
    }
}

#[test]
fn collective_runs_every_op() {
    for op in ["allgatherv", "allreduce", "bcast", "alltoallv"] {
        let out = agv(&[
            "collective", "--op", op, "--system", "dgx1", "--gpus", "2", "--total", "1MB",
        ]);
        assert!(out.status.success(), "{op}: {}", stderr(&out));
        let text = stdout(&out);
        assert!(text.contains(&format!("collective {op}")), "{op}:\n{text}");
        // every shape row reports an auto verdict next to the fixed libs
        assert!(text.contains("auto"), "{op}:\n{text}");
    }
}

#[test]
fn collective_chunked_and_perturbed_run() {
    let out = agv(&[
        "collective", "--op", "allreduce", "--system", "dgx1", "--gpus", "2",
        "--total", "1MB", "--chunks", "4",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stdout(&out).contains("chunks 4"), "{}", stdout(&out));
    let out = agv(&[
        "collective", "--op", "bcast", "--system", "dgx1", "--gpus", "2",
        "--total", "1MB", "--root", "1", "--perturb", "straggler:0:0.5",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stdout(&out).contains("degraded"), "{}", stdout(&out));
}

#[test]
fn collective_rejects_unknown_op_cleanly() {
    let out = agv(&["collective", "--op", "gatherv"]);
    assert_eq!(out.status.code(), Some(1), "{}", stderr(&out));
    let err = stderr(&out);
    assert!(err.contains("unknown op"), "{err}");
    assert!(!err.contains("panicked"), "panicked instead of clean error:\n{err}");
    // a bcast root outside the communicator is the same class of error
    let out = agv(&["collective", "--op", "bcast", "--gpus", "2", "--root", "7"]);
    assert_eq!(out.status.code(), Some(1), "{}", stderr(&out));
    assert!(!stderr(&out).contains("panicked"), "{}", stderr(&out));
}

#[test]
fn fig3_minimal_runs() {
    let out = agv(&["fig3", "--iters", "1"]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stdout(&out).contains("FIG. 3"));
}

#[test]
#[ignore = "full Fig. 2 grid; covered in release by CI's paper-artifacts step and internally by `findings`"]
fn fig2_runs_to_completion() {
    let out = agv(&["fig2"]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("FIG. 2"));
    assert!(text.contains("MPI-CUDA"));
}

#[test]
fn findings_emits_section_vi_ratio_lines() {
    let out = agv(&["findings"]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("HEADLINE FINDINGS"), "no headline:\n{text}");
    // The three §VI ratio lines, each naming ours and the paper's value.
    assert!(text.contains("(paper: 8.3x)"), "OSU DGX-1-vs-cluster line missing");
    assert!(text.contains("(paper: 1.2x)"), "cluster NCCL-vs-GDR line missing");
    assert!(text.contains("MV2_GPUDIRECT_LIMIT"), "GDR sweep line missing");
    // every reported ratio is a real number, not NaN/inf
    assert!(!text.contains("NaN") && !text.contains("inf"), "{text}");
}

// ---------------------------------------------------------------------------
// Parametric fabrics on the --system grammar (DESIGN.md §15)
// ---------------------------------------------------------------------------

#[test]
fn topo_list_pins_the_system_grammar() {
    let out = agv(&["topo", "--list"]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("systems accepted by --system:"), "{text}");
    for paper in ["cluster", "dgx1", "cs-storm"] {
        let line = text.lines().find(|l| l.trim_start().starts_with(paper));
        assert!(line.is_some_and(|l| l.contains("GPUs (paper Fig. 1)")), "{paper}:\n{text}");
    }
    assert!(text.contains("fat-tree:k=<even>"), "{text}");
    assert!(text.contains("dragonfly:a=<n>,p=<n>,h=<n>"), "{text}");
    assert!(text.contains("multi-plane-pod:nodes=<n>,gpus=<n>,rails=<n>"), "{text}");
}

#[test]
fn topo_builds_fabrics_and_omits_large_matrices() {
    // a small pod still prints the P2P matrix; a 1024-host fat-tree
    // omits it instead of dumping a megabyte of dots
    let out = agv(&["topo", "--system", "multi-plane-pod:nodes=2,gpus=4,rails=2"]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("== pod-2x4x2 =="), "{text}");
    assert!(text.contains("GPUDirect P2P matrix"), "{text}");
    assert!(text.contains("sample routes:"), "{text}");
    let out = agv(&["topo", "--system", "fat-tree:k=16"]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("== fat-tree-k16 =="), "{text}");
    assert!(text.contains("P2P matrix omitted (1024 GPUs"), "{text}");
}

#[test]
fn fabric_specs_accepted_across_subcommands() {
    // the same --system grammar works on every surface that takes one
    let out = agv(&["osu", "--system", "fat-tree:k=4", "--gpus", "2", "--lib", "nccl"]);
    assert!(out.status.success(), "osu: {}", stderr(&out));
    assert!(stdout(&out).contains("fat-tree-k4"), "{}", stdout(&out));
    let out = agv(&[
        "collective", "--op", "allgatherv", "--system", "dragonfly:a=2,p=2,h=2",
        "--gpus", "2", "--total", "1MB",
    ]);
    assert!(out.status.success(), "collective: {}", stderr(&out));
    assert!(stdout(&out).contains("dragonfly-2x2x2"), "{}", stdout(&out));
    let out = agv(&[
        "workload", "--system", "multi-plane-pod:nodes=2,gpus=4,rails=2",
        "--tenants", "2", "--ops", "1", "--gpus", "2", "--total", "1MB",
    ]);
    assert!(out.status.success(), "workload: {}", stderr(&out));
    assert!(stdout(&out).contains("pod-2x4x2"), "{}", stdout(&out));
    let out = agv(&[
        "auto", "--dataset", "netflix", "--system", "multi-plane-pod:nodes=2,gpus=4,rails=2",
    ]);
    assert!(out.status.success(), "auto: {}", stderr(&out));
    assert!(stdout(&out).contains("pod-2x4x2"), "{}", stdout(&out));
}

#[test]
fn malformed_fabric_specs_exit_2_with_a_hint() {
    // every rejection is a usage error (exit 2) whose message names the
    // offending field and shows the accepted form
    let cases: &[(&[&str], &str)] = &[
        (&["osu", "--system", "fat-tree:k=3"], "even"),
        (&["osu", "--system", "fat-tree:k=3"], "try --system fat-tree:k=16"),
        (&["osu", "--system", "fat-tree:k=0"], "even and >= 2"),
        (&["osu", "--system", "dragonfly:a=2,p=2,h=0"], "h=0 leaves dragonfly groups"),
        (
            &["osu", "--system", "multi-plane-pod:nodes=2,gpus=4,rails=0"],
            "zero rails leaves pod nodes unreachable",
        ),
        (&["osu", "--system", "torus:x=4"], "unknown system family 'torus'"),
        (&["osu", "--system", "torus:x=4"], "fat-tree:k=<even>"), // grammar hint
        (&["osu", "--system", "fat-tree:arity=4"], "unknown field 'arity'"),
        (&["osu", "--system", "dragonfly:a=2,p=2"], "missing 'h='"),
        (&["osu", "--system", "fat-tree:k=four"], "non-negative integer"),
        // the same parse guards every surface, not just osu
        (&["collective", "--op", "allreduce", "--system", "fat-tree:k=7"], "even"),
        (&["workload", "--system", "dragonfly:a=0,p=1,h=1"], "router per group"),
        (&["auto", "--dataset", "netflix", "--system", "pod:nodes=0,gpus=4,rails=1"], "node"),
        (&["topo", "--system", "mesh"], "unknown system"),
    ];
    for (args, fragment) in cases {
        let out = agv(args);
        assert_eq!(out.status.code(), Some(2), "`agv {}`:\n{}", args.join(" "), stderr(&out));
        let err = stderr(&out);
        assert!(err.contains("--system"), "`agv {}` lost the flag name:\n{err}", args.join(" "));
        assert!(err.contains(fragment), "`agv {}` missing '{fragment}':\n{err}", args.join(" "));
        assert!(!err.contains("panicked"), "`agv {}` panicked:\n{err}", args.join(" "));
    }
}

#[test]
fn e2e_and_artifacts_parse_without_artifacts() {
    // Without `make artifacts` these exit 1 ("cannot open artifacts"),
    // which still proves the subcommands parse.
    assert_parses(&["artifacts"]);
    assert_parses(&["e2e", "--config", "small", "--gpus", "2", "--iters", "1"]);
}
