//! Schedule-conformance property harness (DESIGN.md §7): every
//! Allgatherv schedule in the crate — the flat ring / Bruck / recursive
//! doubling / bcast-series AND the hierarchical two-level ones — must,
//! for random P ∈ 2..=32, random ring orders, roots and groupings:
//!
//! 1. deliver every block to every rank (`execute` + `all_delivered`);
//! 2. never ship a block the sender does not yet hold at that step
//!    (`execute` asserts this internally on the pre-step snapshot);
//! 3. match the closed-form transfer count: every Allgatherv schedule
//!    here is *delivery-minimal* — each block moves exactly P-1 times,
//!    P·(P-1) total — and broadcasts move the root block P-1 times;
//! 4. carry byte volumes consistent with irregular (skewed, zero-heavy,
//!    single-hot-rank) count vectors: schedule bytes = (P-1)·Σcounts.
//!
//! The `AlgoSelector` is locked down the same way: on small exhaustive
//! grids its choice must achieve the minimum simulated time over all
//! candidates, and the hierarchical schedules on `multi_dgx(n)` must
//! stay within a stated tolerance of the best flat schedule while
//! moving strictly fewer bytes over the inter-node links.

use agv_bench::comm::algorithms::{
    all_delivered, bcast_series_allgatherv, binomial_bcast, bruck_allgatherv, execute,
    hierarchical_allgatherv, recursive_doubling_allgatherv, ring_allgatherv, ring_bcast,
    LeaderAlgo, Schedule,
};
use agv_bench::comm::select::{candidates, simulate, Algo, AlgoSelector, Candidate};
use agv_bench::comm::{Library, Params};
use agv_bench::prop_assert;
use agv_bench::topology::systems::{multi_dgx, node_groups, SystemKind, SystemSpec};
use agv_bench::topology::Topology;
use agv_bench::util::prng::Rng;
use agv_bench::util::prop::{check, counts};

// ---------------------------------------------------------------------------
// Harness helpers
// ---------------------------------------------------------------------------

/// How many times each block travels across all sends of all schedules.
fn block_transfers(p: usize, schedules: &[&Schedule]) -> Vec<usize> {
    let mut h = vec![0usize; p];
    for s in schedules {
        for op in s.steps.iter().flatten() {
            for &b in &op.blocks {
                h[b] += 1;
            }
        }
    }
    h
}

/// Total bytes a schedule ships under a count vector.
fn schedule_bytes(schedules: &[&Schedule], counts: &[u64]) -> u64 {
    schedules
        .iter()
        .flat_map(|s| s.steps.iter().flatten())
        .map(|op| op.bytes(counts))
        .sum()
}

/// Full Allgatherv conformance: delivery (running `execute`, which
/// panics if any rank sends an unheld block), the per-block P-1 closed
/// form, and the P·(P-1) total.
fn assert_allgatherv_conformance(
    p: usize,
    schedules: &[&Schedule],
    label: &str,
) -> Result<(), String> {
    let held = execute(p, schedules);
    prop_assert!(all_delivered(&held), "{label}: not all blocks delivered");
    for (b, &n) in block_transfers(p, schedules).iter().enumerate() {
        prop_assert!(
            n == p - 1,
            "{label}: block {b} moved {n} times, closed form says {}",
            p - 1
        );
    }
    let total: usize = schedules.iter().map(|s| s.total_block_transfers()).sum();
    prop_assert!(total == p * (p - 1), "{label}: total {total} != p(p-1)");
    Ok(())
}

/// Random grouping of `0..p` into 1..=p groups with shuffled membership
/// (leaders are arbitrary ranks, groups need not be contiguous).
fn random_groups(rng: &mut Rng, p: usize) -> Vec<Vec<usize>> {
    let g = 1 + rng.gen_range(p as u64) as usize;
    let mut perm: Vec<usize> = (0..p).collect();
    rng.shuffle(&mut perm);
    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); g];
    for (i, &r) in perm.iter().enumerate() {
        groups[i % g].push(r);
    }
    groups
}

// ---------------------------------------------------------------------------
// Flat schedules
// ---------------------------------------------------------------------------

#[test]
fn conformance_ring_random_orders() {
    check("conformance-ring", 64, |rng| {
        let p = 2 + rng.gen_range(31) as usize; // 2..=32
        let mut order: Vec<usize> = (0..p).collect();
        rng.shuffle(&mut order);
        let s = ring_allgatherv(p, Some(&order));
        assert_allgatherv_conformance(p, &[&s], &format!("ring p={p}"))
    });
}

#[test]
fn conformance_bruck_every_p() {
    for p in 2..=32 {
        let s = bruck_allgatherv(p);
        assert_allgatherv_conformance(p, &[&s], &format!("bruck p={p}")).unwrap();
    }
}

#[test]
fn conformance_recursive_doubling_powers_of_two() {
    for p in [2usize, 4, 8, 16, 32] {
        let s = recursive_doubling_allgatherv(p);
        assert_allgatherv_conformance(p, &[&s], &format!("rec-dbl p={p}")).unwrap();
    }
}

#[test]
fn conformance_bcast_series_random_orders() {
    check("conformance-bcast-series", 48, |rng| {
        let p = 2 + rng.gen_range(31) as usize;
        let mut order: Vec<usize> = (0..p).collect();
        rng.shuffle(&mut order);
        let series = bcast_series_allgatherv(p, Some(&order));
        let refs: Vec<&Schedule> = series.iter().collect();
        assert_allgatherv_conformance(p, &refs, &format!("bcast-series p={p}"))
    });
}

#[test]
fn conformance_broadcasts_random_roots() {
    // broadcasts (the building blocks): the root block reaches every
    // rank in exactly p-1 transfers
    check("conformance-bcasts", 48, |rng| {
        let p = 2 + rng.gen_range(31) as usize;
        let root = rng.gen_range(p as u64) as usize;
        let mut order: Vec<usize> = (0..p).collect();
        rng.shuffle(&mut order);
        for (s, label) in [
            (binomial_bcast(p, root), "binomial"),
            (ring_bcast(p, root, Some(&order)), "ring-bcast"),
        ] {
            let held = execute(p, &[&s]);
            for (r, h) in held.iter().enumerate() {
                prop_assert!(h[root], "{label} p={p} root={root}: rank {r} missing root");
            }
            prop_assert!(
                s.total_block_transfers() == p - 1,
                "{label} p={p}: {} transfers != p-1",
                s.total_block_transfers()
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Hierarchical schedules
// ---------------------------------------------------------------------------

#[test]
fn conformance_hierarchical_random_groupings() {
    check("conformance-hier", 96, |rng| {
        let p = 2 + rng.gen_range(31) as usize;
        let groups = random_groups(rng, p);
        let inter = if rng.gen_range(2) == 0 { LeaderAlgo::Ring } else { LeaderAlgo::Bruck };
        let s = hierarchical_allgatherv(p, &groups, inter);
        assert_allgatherv_conformance(
            p,
            &[&s],
            &format!("hier-{inter:?} p={p} groups={groups:?}"),
        )
    });
}

#[test]
fn conformance_hierarchical_on_system_groupings() {
    // the groupings the selector actually uses: node_groups of every
    // system (including degenerate single-node and one-GPU-per-node
    // shapes) and of multi-DGX at every slice size
    let mut topos: Vec<Topology> = SystemKind::all().iter().map(|k| k.build()).collect();
    topos.push(multi_dgx(2));
    topos.push(multi_dgx(4));
    for topo in &topos {
        for p in 2..=topo.num_gpus() {
            let groups = node_groups(topo, p);
            for inter in [LeaderAlgo::Ring, LeaderAlgo::Bruck] {
                let s = hierarchical_allgatherv(p, &groups, inter);
                assert_allgatherv_conformance(
                    p,
                    &[&s],
                    &format!("{} hier-{inter:?} p={p}", topo.name),
                )
                .unwrap();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Large P on the scale fabrics (DESIGN.md §15) — counting only, no
// timing: logical delivery via `execute` where affordable plus the
// closed-form transfer counts everywhere; the flow simulator never runs
// at these sizes (that's the scale bench's job).
// ---------------------------------------------------------------------------

/// Counting-only conformance for schedules too big to replay: the
/// per-block P-1 closed form and the P·(P-1) total, without the
/// held-set execution.
fn assert_transfer_counts(p: usize, schedules: &[&Schedule], label: &str) {
    for (b, &n) in block_transfers(p, schedules).iter().enumerate() {
        assert_eq!(n, p - 1, "{label}: block {b} moved {n} times");
    }
    let total: usize = schedules.iter().map(|s| s.total_block_transfers()).sum();
    assert_eq!(total, p * (p - 1), "{label}: total transfers off the closed form");
}

#[test]
fn conformance_p256_on_pod_grouping() {
    // 256 ranks = a 32-node 8-GPU pod; the hierarchical schedules use
    // its real node grouping
    let p = 256;
    let topo = SystemSpec::MultiPlanePod { nodes: 32, gpus: 8, rails: 2 }.build();
    assert_eq!(topo.num_gpus(), p);
    let groups = node_groups(&topo, p);
    assert_eq!(groups.len(), 32);
    for (s, label) in [
        (ring_allgatherv(p, None), "ring"),
        (bruck_allgatherv(p), "bruck"),
        (hierarchical_allgatherv(p, &groups, LeaderAlgo::Ring), "hier-ring"),
        (hierarchical_allgatherv(p, &groups, LeaderAlgo::Bruck), "hier-bruck"),
    ] {
        assert_allgatherv_conformance(p, &[&s], &format!("{label} p={p}")).unwrap();
    }
}

#[test]
fn conformance_p1024_on_fat_tree_and_pod_grouping() {
    // 1024 ranks = fat_tree(16)'s host count (the quick-mode scale
    // fabric) and a 128-node pod for the hierarchical grouping
    let p = 1024;
    assert_eq!(SystemSpec::FatTree { k: 16 }.build().num_gpus(), p);
    let pod = SystemSpec::MultiPlanePod { nodes: 128, gpus: 8, rails: 4 }.build();
    assert_eq!(pod.num_gpus(), p);
    let groups = node_groups(&pod, p);
    for (s, label) in [
        (ring_allgatherv(p, None), "ring"),
        (recursive_doubling_allgatherv(p), "rec-dbl"),
        (hierarchical_allgatherv(p, &groups, LeaderAlgo::Bruck), "hier-bruck"),
    ] {
        assert_allgatherv_conformance(p, &[&s], &format!("{label} p={p}")).unwrap();
    }
}

#[test]
fn conformance_p4096_logarithmic_schedules_execute() {
    // 4096 ranks (the full-bench scale): the logarithmic schedules
    // (12 steps) still replay through `execute`; the ring's 4095 step
    // snapshots would copy ~67 GB of held-set state, so it is covered
    // by the counting-only closed form at this size instead
    let p = 4096;
    for (s, label) in
        [(bruck_allgatherv(p), "bruck"), (recursive_doubling_allgatherv(p), "rec-dbl")]
    {
        assert_allgatherv_conformance(p, &[&s], &format!("{label} p={p}")).unwrap();
    }
    let ring = ring_allgatherv(p, None);
    assert_transfer_counts(p, &[&ring], "ring p=4096");
}

#[test]
fn conformance_p4096_hierarchical_counting_only() {
    // a 512-node pod's grouping: the two-level schedule stays
    // delivery-minimal at 4096 ranks (counting only — its ring of 512
    // leaders makes a full replay as costly as the flat ring's)
    let p = 4096;
    let pod = SystemSpec::MultiPlanePod { nodes: 512, gpus: 8, rails: 4 }.build();
    assert_eq!(pod.num_gpus(), p);
    let groups = node_groups(&pod, p);
    assert_eq!(groups.len(), 512);
    let s = hierarchical_allgatherv(p, &groups, LeaderAlgo::Ring);
    assert_transfer_counts(p, &[&s], "hier-ring p=4096");
}

// ---------------------------------------------------------------------------
// Irregular count vectors (shared generators from util::prop::counts)
// ---------------------------------------------------------------------------

#[test]
fn conformance_byte_volume_under_irregular_counts() {
    // delivery-minimality makes byte volume exact: every block ships
    // p-1 times, so schedule bytes = (p-1)·Σcounts — including when
    // counts contain zeros (SendOp::bytes must handle zero blocks)
    check("conformance-bytes", 96, |rng| {
        let p = 2 + rng.gen_range(31) as usize;
        let cv = counts::irregular(rng, p, 1 << 28);
        let expected = (p as u64 - 1) * cv.iter().sum::<u64>();
        let schedules: Vec<Schedule> = match rng.gen_range(4) {
            0 => vec![ring_allgatherv(p, None)],
            1 => vec![bruck_allgatherv(p)],
            2 => {
                let groups = random_groups(rng, p);
                vec![hierarchical_allgatherv(p, &groups, LeaderAlgo::Ring)]
            }
            _ => bcast_series_allgatherv(p, None),
        };
        let refs: Vec<&Schedule> = schedules.iter().collect();
        let vol = schedule_bytes(&refs, &cv);
        prop_assert!(vol == expected, "p={p}: bytes {vol} != (p-1)·Σ = {expected}");
        Ok(())
    });
}

#[test]
fn libraries_survive_zero_heavy_and_hot_counts() {
    // the full library models (and the selector) must accept the
    // irregular vectors without panics, returning finite times
    check("conformance-zero-heavy-libs", 8, |rng| {
        let topo = SystemKind::Dgx1.build();
        let p = 2 + rng.gen_range(7) as usize;
        for cv in [
            counts::zero_heavy(rng, p, 4 << 20),
            counts::single_hot(rng, p, 64 << 20),
            vec![0; p],
        ] {
            for lib in Library::all() {
                let t = agv_bench::comm::run_allgatherv(lib, &topo, &cv).time;
                prop_assert!(t.is_finite() && t >= 0.0, "{} {cv:?}: t={t}", lib.name());
            }
            let sel = AlgoSelector::new(Params::default()).select_fresh(&topo, &cv);
            prop_assert!(sel.time.is_finite(), "auto on {cv:?}");
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Differential tests: hierarchical vs flat, and the selector argmin
// ---------------------------------------------------------------------------

/// Bytes a schedule moves across node boundaries under a count vector.
fn inter_node_bytes(topo: &Topology, sched: &Schedule, counts: &[u64]) -> u64 {
    sched
        .steps
        .iter()
        .flatten()
        .filter(|op| !topo.same_node(op.from, op.to))
        .map(|op| op.bytes(counts))
        .sum()
}

/// Stated tolerance of the hierarchical-vs-flat differential test: the
/// two-level schedule trades a serial intra-node epilogue for strictly
/// less inter-node traffic, so in the bandwidth regime it may trail the
/// best flat schedule by a bounded factor while it wins the latency
/// regime outright.
const HIER_VS_FLAT_TOLERANCE: f64 = 2.0;

#[test]
fn hierarchical_within_tolerance_of_best_flat_on_multi_dgx() {
    let params = Params::default();
    for nodes in [2usize, 3] {
        let topo = multi_dgx(nodes);
        let p = 8 * nodes;
        for per_rank in [64u64 << 10, 1 << 20, 4 << 20] {
            let cv = counts::regular(p, per_rank);
            let mut flat = Vec::new();
            for algo in [Algo::Ring, Algo::RingTopo, Algo::Bruck, Algo::RecursiveDoubling] {
                for lib in [Library::Mpi, Library::MpiCuda] {
                    if let Some(r) = simulate(&topo, params, Candidate { lib, algo }, &cv) {
                        flat.push(r.time);
                    }
                }
            }
            let mut hier = Vec::new();
            for algo in [Algo::HierarchicalRing, Algo::HierarchicalBruck] {
                let cand = Candidate { lib: Library::MpiCuda, algo };
                if let Some(r) = simulate(&topo, params, cand, &cv) {
                    hier.push(r.time);
                }
            }
            assert!(!flat.is_empty() && !hier.is_empty());
            let best_flat = flat.iter().cloned().fold(f64::INFINITY, f64::min);
            let best_hier = hier.iter().cloned().fold(f64::INFINITY, f64::min);
            assert!(
                best_hier <= best_flat * HIER_VS_FLAT_TOLERANCE,
                "multi_dgx({nodes}) @ {per_rank}B/rank: hier {best_hier} vs flat {best_flat}"
            );
        }
    }
}

#[test]
fn hierarchical_moves_less_inter_node_traffic_than_flat_ring() {
    // deterministic structural win: the ring-of-leaders crosses each
    // node boundary once per byte, the flat ring roughly G times
    for nodes in [2usize, 3, 4] {
        let topo = multi_dgx(nodes);
        let p = 8 * nodes;
        let cv = counts::regular(p, 1 << 20);
        let groups = node_groups(&topo, p);
        for inter in [LeaderAlgo::Ring, LeaderAlgo::Bruck] {
            let hier = hierarchical_allgatherv(p, &groups, inter);
            let flat = ring_allgatherv(p, None);
            let hb = inter_node_bytes(&topo, &hier, &cv);
            let fb = inter_node_bytes(&topo, &flat, &cv);
            assert!(
                hb < fb,
                "multi_dgx({nodes}) {inter:?}: hier IB bytes {hb} !< flat ring {fb}"
            );
        }
    }
}

#[test]
fn selector_argmin_exhaustive_on_small_grids() {
    // on every (system, gpus, count-shape) cell of a small exhaustive
    // grid, the selector's choice must achieve the minimum simulated
    // time over all candidates — bit-exact, since it simulates the
    // same candidates deterministically
    let params = Params::default();
    let sel = AlgoSelector::new(params);
    let mut topos: Vec<Topology> = SystemKind::all().iter().map(|k| k.build()).collect();
    topos.push(multi_dgx(2));
    let mut rng = Rng::new(0xC0FFEE);
    let mut cells = 0usize;
    for topo in &topos {
        for p in [2usize, 4, 8, 16] {
            if p > topo.num_gpus() {
                continue;
            }
            let shapes = [
                counts::regular(p, 64 << 10),
                counts::regular(p, 8 << 20),
                counts::skewed(&mut rng, p, 16 << 20),
                counts::zero_heavy(&mut rng, p, 8 << 20),
                counts::single_hot(&mut rng, p, 64 << 20),
            ];
            for cv in &shapes {
                let evals = sel.evaluate(topo, cv);
                assert_eq!(evals.len(), candidates(topo, p).len(), "{} p={p}", topo.name);
                let min = evals.iter().map(|(_, r)| r.time).fold(f64::INFINITY, f64::min);
                let s = sel.select_fresh(topo, cv);
                assert_eq!(
                    s.time.to_bits(),
                    min.to_bits(),
                    "{} p={p} {cv:?}: selector {} vs min {min}",
                    topo.name, s.time
                );
                cells += 1;
            }
        }
    }
    assert!(cells >= 50, "grid unexpectedly small: {cells}");
}

#[test]
fn selector_beats_or_matches_every_fixed_library_on_multi_dgx() {
    let topo = multi_dgx(2);
    let sel = AlgoSelector::new(Params::default());
    let mut rng = Rng::new(7);
    for cv in [
        counts::regular(16, 1 << 20),
        counts::skewed(&mut rng, 16, 32 << 20),
        counts::single_hot(&mut rng, 16, 128 << 20),
    ] {
        let s = sel.select_fresh(&topo, &cv);
        for lib in Library::all() {
            let fixed = agv_bench::comm::run_allgatherv(lib, &topo, &cv).time;
            assert!(
                s.time <= fixed,
                "auto {} ({}) slower than fixed {} {}",
                s.time, s.candidate.label(), lib.name(), fixed
            );
        }
    }
}
