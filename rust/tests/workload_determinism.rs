//! Same seed + same spec ⇒ byte-identical artifacts, across two
//! in-process runs: the `BENCH_workload.json` payload and the `agv
//! workload` report render. Guards the deterministic-PRNG arrival
//! paths (every jitter draw comes from a seeded, removal-invariant
//! stream) and the worker-pool fan-out (results must come back in
//! submission order, never completion order).

use agv_bench::comm::{Library, Params};
use agv_bench::report::workload as report_workload;
use agv_bench::sim::scale::scale_doc;
use agv_bench::topology::systems::{SystemKind, SystemSpec};
use agv_bench::workload::bench::bench_doc;
use agv_bench::workload::{run_workload, TenantLib, WorkloadSpec};

#[test]
fn bench_doc_is_byte_identical_across_runs() {
    let a = bench_doc(42).render();
    let b = bench_doc(42).render();
    assert_eq!(a, b, "BENCH_workload.json payload is not reproducible");
    // and the seed genuinely matters (the PRNG streams are live)
    let c = bench_doc(43).render();
    assert_ne!(a, c, "different seeds produced identical artifacts");
}

#[test]
fn collectives_bench_doc_is_byte_identical_across_runs() {
    // BENCH_collectives.json: per-library times, auto verdicts and
    // chunk-pipelining speedups are all simulated metrics, so the same
    // seed must reproduce the artifact byte-for-byte across the
    // worker-pool fan-out
    let a = agv_bench::comm::collective::bench::bench_doc(42).render();
    let b = agv_bench::comm::collective::bench::bench_doc(42).render();
    assert_eq!(a, b, "BENCH_collectives.json payload is not reproducible");
    let c = agv_bench::comm::collective::bench::bench_doc(43).render();
    assert_ne!(a, c, "the seed is not live in the collectives artifact");
}

#[test]
fn faults_bench_doc_is_byte_identical_across_runs() {
    // BENCH_faults.json: simulated metrics only, so the same seed must
    // reproduce the artifact byte-for-byte (including the Monte-Carlo
    // ensemble draws behind the robust verdicts and the pool fan-out)
    let a = agv_bench::perturb::bench::bench_doc(42).render();
    let b = agv_bench::perturb::bench::bench_doc(42).render();
    assert_eq!(a, b, "BENCH_faults.json payload is not reproducible");
    let c = agv_bench::perturb::bench::bench_doc(43).render();
    assert_ne!(a, c, "the ensemble seed is not live in the faults artifact");
    // the PR-7 hard-outage grid rides the same artifact: its recovery
    // verdicts (strategy labels, recovered times) are simulated
    // metrics, so they are pinned byte-for-byte by the equality above —
    // just make sure the section is actually there
    assert!(a.contains("outage_cases"), "outage grid missing from BENCH_faults.json");
    assert!(a.contains("\"strategy\""), "recovery verdicts missing from the outage grid");
    // the PR-9 delta-simulation grid rides the same artifact: replay
    // tier counts and work-unit ratios are simulated metrics, pinned
    // byte-for-byte by the equality above — make sure the subtree and
    // its load-bearing fields are actually present
    for key in ["delta_sim", "\"warm_work_units\"", "\"cold_work_units\"", "\"work_ratio\"", "\"max_rel_err\""] {
        assert!(a.contains(key), "{key} missing from the BENCH_faults.json delta-sim subtree");
    }
}

#[test]
fn workload_bench_doc_carries_the_delta_sim_subtree() {
    // BENCH_workload.json grows the same delta-simulation grid; the
    // byte-equality test above pins its values, this pins its presence
    let a = bench_doc(42).render();
    for key in ["delta_sim", "\"warm_work_units\"", "\"work_ratio\"", "\"max_rel_err\""] {
        assert!(a.contains(key), "{key} missing from the BENCH_workload.json delta-sim subtree");
    }
}

#[test]
fn serve_bench_doc_is_byte_identical_across_runs() {
    // BENCH_serve.json: knee curves, policy comparison, zero-rate
    // anchor, and the delta-sim subtree are all simulated metrics, so
    // the same seed must reproduce the artifact byte-for-byte across
    // the worker-pool fan-out (the arrival PRNG streams included)
    let a = agv_bench::workload::serve::bench::bench_doc(42).render();
    let b = agv_bench::workload::serve::bench::bench_doc(42).render();
    assert_eq!(a, b, "BENCH_serve.json payload is not reproducible");
    let c = agv_bench::workload::serve::bench::bench_doc(43).render();
    assert_ne!(a, c, "the arrival seed is not live in the serve artifact");
    // load-bearing subtrees: the capacity curves with their knee
    // verdicts, the zero-rate anchor cases (asserted bit-exact against
    // run_workload in-process while the doc builds), and the PR-9
    // style delta-simulation grid extended to serving DAGs
    for key in [
        "\"curves\"",
        "\"knee_rho\"",
        "\"saturation_hz\"",
        "\"p999_s\"",
        "\"policies\"",
        "\"zero_rate\"",
        "delta_sim",
        "\"warm_work_units\"",
        "\"cold_work_units\"",
        "\"work_ratio\"",
        "\"max_rel_err\"",
    ] {
        assert!(a.contains(key), "{key} missing from BENCH_serve.json");
    }
    // the warm-start acceptance: replay tiers must let the warm path
    // bill fewer work units than cold re-simulation on every case
    let doc = agv_bench::workload::serve::bench::bench_doc(42);
    for case in doc.get("delta_sim").and_then(|d| d.as_arr()).expect("delta_sim array") {
        let ratio = case.get("work_ratio").and_then(|v| v.as_f64()).expect("work_ratio");
        assert!(ratio >= 1.0, "serving delta-sim did not beat cold: {ratio}");
    }
}

#[test]
fn closed_serve_matches_run_workload_on_both_engines() {
    // the zero-arrival-rate anchor on the reference engine too: the
    // serve DAG in closed mode is composed by the workload engine's
    // own compose_workload, so the bit-exactness must be engine-
    // independent (the event engine case is pinned in serve.rs's unit
    // tests and the BENCH_serve zero_rate subtree)
    use agv_bench::sim::with_reference_engine;
    use agv_bench::workload::serve::{ArrivalProcess, QueuePolicy};
    use agv_bench::workload::{run_serve, ServeSpec};
    let topo = SystemKind::Cluster.build();
    for lib in Library::all() {
        let wspec = WorkloadSpec::synthetic(2, 3, 4, TenantLib::Fixed(lib), 4 << 20, 21);
        let serve = ServeSpec {
            workload: wspec.clone(),
            arrivals: ArrivalProcess::Closed,
            policy: QueuePolicy::Fifo { depth: 4 },
        };
        let (sm, wm) = with_reference_engine(|| {
            let sr = run_serve(&topo, &serve, Params::default()).unwrap();
            let wr = run_workload(&topo, &wspec, Params::default()).unwrap();
            (sr.makespan, wr.makespan)
        });
        assert_eq!(sm.to_bits(), wm.to_bits(), "reference engine anchor: {}", lib.name());
    }
}

#[test]
fn report_render_is_byte_identical_across_runs() {
    let mk = |gpus: usize| {
        WorkloadSpec::synthetic(3, 3, gpus.min(8), TenantLib::Fixed(Library::Nccl), 8 << 20, 7)
    };
    let run = || {
        let sections =
            report_workload::study(&SystemSpec::paper_all(), Params::default(), mk).unwrap();
        (report_workload::render(&sections), report_workload::csv(&sections))
    };
    let (ra, ca) = run();
    let (rb, cb) = run();
    assert_eq!(ra, rb, "report render diverged between runs");
    assert_eq!(ca, cb, "report csv diverged between runs");
}

#[test]
fn scale_cross_check_doc_is_byte_identical_across_runs() {
    // the `scale.cross_check` subtree of BENCH_engine.json: sharded
    // runs at full worker fan-out must merge deterministically (by
    // shard index, never completion order), so the rendered doc is
    // byte-identical run over run — quick-mode fabrics (~1k ranks)
    // keep this affordable in tier-1
    let a = scale_doc(42, true).render();
    let b = scale_doc(42, true).render();
    assert_eq!(a, b, "BENCH_engine.json scale subtree is not reproducible");
    let c = scale_doc(43, true).render();
    assert_ne!(a, c, "the scale-case seed is not live in the artifact");
    assert!(a.contains("fat-tree-k16"), "quick fat-tree case missing:\n{a}");
    assert!(a.contains("dragonfly-8x4x4"), "quick dragonfly case missing:\n{a}");
}

#[test]
fn workload_results_are_bitwise_deterministic() {
    let topo = SystemKind::CsStorm.build();
    let spec = WorkloadSpec::synthetic(4, 3, 8, TenantLib::Fixed(Library::MpiCuda), 8 << 20, 99);
    let a = run_workload(&topo, &spec, Params::default()).unwrap();
    let b = run_workload(&topo, &spec, Params::default()).unwrap();
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
    assert_eq!(a.total_bytes.to_bits(), b.total_bytes.to_bits());
    assert_eq!(a.flows, b.flows);
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        assert_eq!(x.completion.to_bits(), y.completion.to_bits());
        for (ox, oy) in x.ops.iter().zip(&y.ops) {
            assert_eq!(ox.arrival.to_bits(), oy.arrival.to_bits());
            assert_eq!(ox.finish.to_bits(), oy.finish.to_bits());
        }
    }
}
