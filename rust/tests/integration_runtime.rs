//! Integration: the PJRT runtime against the real AOT artifacts, plus
//! the end-to-end driver. Requires `make artifacts` (tests are skipped
//! with a message when artifacts are absent, e.g. in a docs-only
//! checkout).

use agv_bench::comm::Library;
use agv_bench::cpals::driver::Driver;
use agv_bench::runtime::{HostTensor, Runtime};
use agv_bench::tensor::synth::{low_rank_coo, pad_coo};
use agv_bench::tensor::{ModeProfile, TensorSpec};
use agv_bench::topology::systems::dgx1;
use agv_bench::util::prng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("meta.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

/// Host-side MTTKRP reference (mode 0 semantics).
fn host_mttkrp(
    vals: &[f32],
    rows: &[i32],
    cols_b: &[i32],
    cols_c: &[i32],
    fb: &[f32],
    fc: &[f32],
    out_rows: usize,
    r: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; out_rows * r];
    for n in 0..vals.len() {
        let (row, cb, cc) = (rows[n] as usize, cols_b[n] as usize, cols_c[n] as usize);
        for x in 0..r {
            out[row * r + x] += vals[n] * fb[cb * r + x] * fc[cc * r + x];
        }
    }
    out
}

#[test]
fn artifacts_inventory() {
    let dir = require_artifacts!();
    let rt = Runtime::open(dir).unwrap();
    let names = rt.artifacts();
    for base in [
        "als_sweep", "mttkrp_mode0", "mttkrp_mode1", "mttkrp_mode2",
        "update_post_mode0", "update_post_mode1", "update_post_mode2", "fit",
    ] {
        for cfg in ["small", "e2e"] {
            assert!(
                names.contains(&format!("{base}_{cfg}").as_str()),
                "missing {base}_{cfg}"
            );
        }
    }
    assert_eq!(rt.platform(), "cpu");
}

#[test]
fn mttkrp_artifact_matches_host_reference() {
    let dir = require_artifacts!();
    let mut rt = Runtime::open(dir).unwrap();
    let meta = rt.meta("mttkrp_mode0_small").unwrap().clone();
    let n = meta.inputs[0].shape[0];
    let (j_dim, r) = (meta.inputs[4].shape[0], meta.inputs[4].shape[1]);
    let k_dim = meta.inputs[5].shape[0];
    let i_dim = meta.outputs[0].shape[0];

    let mut rng = Rng::new(7);
    let vals: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let rows: Vec<i32> = (0..n).map(|_| rng.gen_range(i_dim as u64) as i32).collect();
    let cb: Vec<i32> = (0..n).map(|_| rng.gen_range(j_dim as u64) as i32).collect();
    let cc: Vec<i32> = (0..n).map(|_| rng.gen_range(k_dim as u64) as i32).collect();
    let fb: Vec<f32> = (0..j_dim * r).map(|_| rng.normal() as f32 * 0.3).collect();
    let fc: Vec<f32> = (0..k_dim * r).map(|_| rng.normal() as f32 * 0.3).collect();

    let outs = rt
        .execute(
            "mttkrp_mode0_small",
            &[
                HostTensor::F32(vals.clone()),
                HostTensor::I32(rows.clone()),
                HostTensor::I32(cb.clone()),
                HostTensor::I32(cc.clone()),
                HostTensor::F32(fb.clone()),
                HostTensor::F32(fc.clone()),
            ],
        )
        .unwrap();
    let got = outs[0].as_f32().unwrap();
    let expect = host_mttkrp(&vals, &rows, &cb, &cc, &fb, &fc, i_dim, r);
    assert_eq!(got.len(), expect.len());
    let mut max_err = 0.0f32;
    for (g, e) in got.iter().zip(&expect) {
        max_err = max_err.max((g - e).abs());
    }
    assert!(max_err < 1e-3, "max abs err {max_err}");
}

#[test]
fn update_post_produces_unit_columns() {
    let dir = require_artifacts!();
    let mut rt = Runtime::open(dir).unwrap();
    let meta = rt.meta("update_post_mode0_small").unwrap().clone();
    let (i_dim, r) = (meta.inputs[0].shape[0], meta.inputs[0].shape[1]);
    let (j_dim, k_dim) = (meta.inputs[1].shape[0], meta.inputs[2].shape[0]);
    let mut rng = Rng::new(3);
    let m: Vec<f32> = (0..i_dim * r).map(|_| rng.normal() as f32).collect();
    let fb: Vec<f32> = (0..j_dim * r).map(|_| rng.normal() as f32 * 0.5).collect();
    let fc: Vec<f32> = (0..k_dim * r).map(|_| rng.normal() as f32 * 0.5).collect();
    let outs = rt
        .execute(
            "update_post_mode0_small",
            &[HostTensor::F32(m), HostTensor::F32(fb), HostTensor::F32(fc)],
        )
        .unwrap();
    let a = outs[0].as_f32().unwrap();
    let lam = outs[1].as_f32().unwrap();
    assert_eq!(a.len(), i_dim * r);
    assert_eq!(lam.len(), r);
    // columns are unit-norm (or zero)
    for col in 0..r {
        let norm: f32 = (0..i_dim).map(|i| a[i * r + col].powi(2)).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-3 || norm < 1e-6, "col {col} norm {norm}");
        assert!(lam[col].is_finite());
    }
}

#[test]
fn als_sweep_artifact_improves_fit() {
    let dir = require_artifacts!();
    let mut rt = Runtime::open(dir).unwrap();
    let meta = rt.meta("als_sweep_small").unwrap().clone();
    let n = meta.inputs[0].shape[0];
    let (i_dim, r) = (meta.outputs[0].shape[0], meta.outputs[0].shape[1]);
    let j_dim = meta.outputs[1].shape[0];
    let k_dim = meta.outputs[2].shape[0];

    let spec = TensorSpec {
        name: "t",
        modes: [
            ModeProfile { dim: i_dim as u64, skew: 0.5 },
            ModeProfile { dim: j_dim as u64, skew: 0.3 },
            ModeProfile { dim: k_dim as u64, skew: 0.0 },
        ],
        nnz: n as u64,
    };
    let t = pad_coo(&low_rank_coo(&spec, n - n / 8, 4, 0.05, 11), n);
    let to_i32 = |v: &[u32]| v.iter().map(|&x| x as i32).collect::<Vec<i32>>();
    let norm = t.norm_sq() as f32;

    let mut rng = Rng::new(5);
    let mut fb: Vec<f32> = (0..j_dim * r).map(|_| rng.normal() as f32 * 0.3).collect();
    let mut fc: Vec<f32> = (0..k_dim * r).map(|_| rng.normal() as f32 * 0.3).collect();
    let mut fits = Vec::new();
    for _ in 0..5 {
        let outs = rt
            .execute(
                "als_sweep_small",
                &[
                    HostTensor::F32(t.vals.clone()),
                    HostTensor::I32(to_i32(&t.i)),
                    HostTensor::I32(to_i32(&t.j)),
                    HostTensor::I32(to_i32(&t.k)),
                    HostTensor::F32(fb.clone()),
                    HostTensor::F32(fc.clone()),
                    HostTensor::F32(vec![norm]),
                ],
            )
            .unwrap();
        // outs[0] is the new A; the next sweep only consumes B and C
        fb = outs[1].as_f32().unwrap().to_vec();
        fc = outs[2].as_f32().unwrap().to_vec();
        fits.push(outs[4].as_f32().unwrap()[0]);
    }
    assert!(
        fits.last().unwrap() > &fits[0],
        "fit not improving: {fits:?}"
    );
    assert!(fits.iter().all(|f| f.is_finite()));
}

#[test]
fn e2e_driver_2_and_4_ranks_agree() {
    // distributed invariance: the factorization result (fit trajectory)
    // must not depend on the number of simulated GPUs
    let dir = require_artifacts!();
    let topo = dgx1();
    let spec = TensorSpec {
        name: "t",
        modes: [
            ModeProfile { dim: 128, skew: 0.5 },
            ModeProfile { dim: 64, skew: 0.3 },
            ModeProfile { dim: 64, skew: 0.0 },
        ],
        nnz: 1800,
    };
    let tensor = low_rank_coo(&spec, 1800, 4, 0.05, 21);
    let mut fits = Vec::new();
    for gpus in [2usize, 4] {
        let rt = Runtime::open(&dir).unwrap();
        let mut driver = Driver::new(rt, "small", &topo, gpus, vec![Library::Nccl]);
        let report = driver.run(&tensor, 3, 21).unwrap();
        fits.push(report.iters.iter().map(|l| l.fit).collect::<Vec<_>>());
        assert!(report.final_fit() > 0.0);
    }
    for (a, b) in fits[0].iter().zip(&fits[1]) {
        assert!(
            (a - b).abs() < 5e-3,
            "fit diverges between rank counts: {:?} vs {:?}",
            fits[0], fits[1]
        );
    }
}

#[test]
fn driver_comm_times_ranked_by_library() {
    let dir = require_artifacts!();
    let topo = dgx1();
    let spec = TensorSpec {
        name: "t",
        modes: [
            ModeProfile { dim: 128, skew: 0.6 },
            ModeProfile { dim: 64, skew: 0.4 },
            ModeProfile { dim: 64, skew: 0.2 },
        ],
        nnz: 1800,
    };
    let tensor = low_rank_coo(&spec, 1800, 4, 0.05, 33);
    let rt = Runtime::open(&dir).unwrap();
    let mut driver = Driver::new(rt, "small", &topo, 8, Library::all().to_vec());
    let report = driver.run(&tensor, 2, 33).unwrap();
    assert_eq!(report.comm_totals.len(), 3);
    for (_, t) in &report.comm_totals {
        assert!(*t > 0.0 && t.is_finite());
    }
}
