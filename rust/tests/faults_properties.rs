//! Property suite for the fault & variability subsystem (DESIGN.md
//! §12). Thresholds were calibrated with a Python port of the
//! reference engine + library models swept over these exact scenario
//! shapes (the same methodology as `workload_properties.rs`):
//!
//! - **byte conservation** across capacity steps: lazy settlement at
//!   every rate change plus exact leftover charging at completion keep
//!   per-link byte totals invariant under any perturbation (measured
//!   violations ~1e-13; asserted at 1e-9);
//! - **monotonicity**: weakening any single link never *decreases* the
//!   makespan of a fixed schedule — unlike tenant-removal (which has
//!   Graham-style anomalies, see `workload_properties.rs`), link
//!   weakening measured monotone to 1 ulp across every (system,
//!   library, vector, link, factor, window) combination swept
//!   (min ratio 0.99999999999999989); asserted at 1e-9;
//! - **straggler bound**: slowing every link of one GPU by `factor`
//!   stretches the makespan by at most `1/factor` (delays and
//!   latencies do not stretch; measured worst 0.965 of the bound);
//! - **ensemble determinism**: Monte-Carlo scenario sets replay
//!   bit-identically from the seed, and so do robust verdicts;
//! - **robust dominance**: the robust selector never loses to a fixed
//!   library on its own ensemble, by construction.

use agv_bench::comm::select::{AlgoSelector, RobustObjective};
use agv_bench::comm::transport::RecoveryPolicy;
use agv_bench::comm::{run_allgatherv, CommResult, Library, Params};
use agv_bench::perturb::{
    ensemble, perturbed_allgatherv, perturbed_candidate, recovered_allgatherv, EnsembleCfg,
    Perturbation, RecoveryStrategy,
};
use agv_bench::sim::Sim;
use agv_bench::topology::systems::SystemKind;
use agv_bench::topology::Topology;
use agv_bench::util::prng::Rng;
use agv_bench::util::prop::{check, counts};

fn random_system(rng: &mut Rng) -> Topology {
    match rng.gen_range(3) {
        0 => SystemKind::Cluster.build(),
        1 => SystemKind::Dgx1.build(),
        _ => SystemKind::CsStorm.build(),
    }
}

fn random_lib(rng: &mut Rng) -> Library {
    match rng.gen_range(3) {
        0 => Library::Mpi,
        1 => Library::MpiCuda,
        _ => Library::Nccl,
    }
}

/// Total delivered hop-bytes of one perturbed run (sum of per-linkdir
/// byte counters) — the conservation quantity.
fn hop_bytes(topo: &Topology, lib: Library, cv: &[u64], perts: &[Perturbation]) -> (f64, f64) {
    let mut sim = Sim::new(topo);
    let done = agv_bench::comm::compose_allgatherv(&mut sim, lib, Params::default(), cv, None);
    agv_bench::perturb::apply(&mut sim, perts);
    let res = sim.run();
    (res.finish(done), res.linkdir_bytes.iter().sum())
}

#[test]
fn prop_byte_conservation_across_capacity_steps() {
    // the DAG is fault-invariant, so every flow still delivers every
    // byte: per-run hop-byte totals match the healthy run at 1e-9
    check("faults-conservation", 12, |rng| {
        let topo = random_system(rng);
        let lib = random_lib(rng);
        let p = 2 + rng.gen_range(7) as usize;
        let cv = counts::irregular(rng, p, 24 << 20);
        let (healthy_t, healthy_b) = hop_bytes(&topo, lib, &cv, &[]);
        // a messy timeline: static link scale + windowed straggler +
        // windowed floor, windows sized to the healthy makespan
        let link = rng.gen_range(topo.links.len() as u64) as usize;
        let rank = rng.gen_range(p as u64) as usize;
        let perts = vec![
            Perturbation::scale(link, 0.2 + 0.7 * rng.next_f64()),
            Perturbation::straggler(rank, 0.3 + 0.5 * rng.next_f64())
                .during(healthy_t * rng.next_f64(), healthy_t * rng.next_f64()),
            Perturbation::floor(link, 1.0e9).during(healthy_t * 0.5, healthy_t),
        ];
        let (_, degraded_b) = hop_bytes(&topo, lib, &cv, &perts);
        let rel = (degraded_b - healthy_b).abs() / healthy_b.max(1.0);
        if rel > 1e-9 {
            return Err(format!(
                "{}/{}: hop bytes drifted {rel} ({} vs {})",
                topo.name,
                lib.name(),
                degraded_b,
                healthy_b
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_weakening_a_link_never_decreases_makespan() {
    // fixed schedule + max-min sharing: reducing one link's capacity
    // (statically or over a window) can only slow the collective.
    // Calibration swept all links per system x 3 libraries x 3 vector
    // shapes x factors {0.05, 0.3, 0.5, 0.7} x 3 window shapes: min
    // ratio 0.99999999999999989 (1 ulp). Asserted at 1e-9.
    check("faults-monotone-link", 10, |rng| {
        let topo = random_system(rng);
        let lib = random_lib(rng);
        let p = 2 + rng.gen_range(7) as usize;
        let cv = counts::irregular(rng, p, 24 << 20);
        let healthy = run_allgatherv(lib, &topo, &cv);
        let link = rng.gen_range(topo.links.len() as u64) as usize;
        let factor = 0.05 + 0.85 * rng.next_f64();
        let windows = [
            (0.0, f64::INFINITY),
            (healthy.time * 0.2, healthy.time * 0.3),
            (healthy.time * 0.5, f64::INFINITY),
        ];
        let (start, dur) = windows[rng.gen_range(3) as usize];
        let pert = Perturbation::scale(link, factor).during(start, dur);
        let degraded =
            perturbed_allgatherv(&topo, lib, Params::default(), &cv, &[pert]);
        if degraded.time < healthy.time * (1.0 - 1e-9) {
            return Err(format!(
                "{}/{} link {link} x{factor:.3} window ({start},{dur}): \
                 weakening SPED UP the collective: {} < {}",
                topo.name,
                lib.name(),
                degraded.time,
                healthy.time
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_straggler_slowdown_bounded_by_link_scale() {
    // slowing all of one GPU's links by `factor` stretches only the
    // wire segments, never the latencies/delays: the makespan grows by
    // at most 1/factor (measured worst case 0.965 of the bound), and
    // by monotonicity it cannot shrink
    check("faults-straggler-bound", 10, |rng| {
        let topo = random_system(rng);
        let lib = random_lib(rng);
        let p = 2 + rng.gen_range(7) as usize;
        let cv = counts::irregular(rng, p, 24 << 20);
        let rank = rng.gen_range(p as u64) as usize;
        let factor = 0.25 + 0.65 * rng.next_f64();
        let healthy = run_allgatherv(lib, &topo, &cv);
        let degraded = perturbed_allgatherv(
            &topo,
            lib,
            Params::default(),
            &cv,
            &[Perturbation::straggler(rank, factor)],
        );
        let bound = healthy.time / factor;
        if degraded.time > bound * (1.0 + 1e-6) {
            return Err(format!(
                "{}/{} straggler {rank} x{factor:.3}: {} exceeds bound {bound}",
                topo.name,
                lib.name(),
                degraded.time
            ));
        }
        if degraded.time < healthy.time * (1.0 - 1e-9) {
            return Err(format!(
                "{}/{} straggler {rank} x{factor:.3}: sped up: {} < {}",
                topo.name,
                lib.name(),
                degraded.time,
                healthy.time
            ));
        }
        Ok(())
    });
}

#[test]
fn ensembles_and_robust_verdicts_are_deterministic() {
    let topo = SystemKind::CsStorm.build();
    let cfg = EnsembleCfg::quick(23).with_scenarios(5);
    let a = ensemble(&topo, &cfg);
    let b = ensemble(&topo, &cfg);
    assert_eq!(a, b, "ensemble not reproducible from its seed");
    assert_ne!(a, ensemble(&topo, &EnsembleCfg::quick(24).with_scenarios(5)));
    let counts = vec![2u64 << 20; 8];
    let sel = AlgoSelector::new(Params::default());
    for obj in [RobustObjective::Mean, RobustObjective::P95] {
        let x = sel.select_robust(&topo, &counts, &a, obj);
        let y = sel.select_robust(&topo, &counts, &b, obj);
        assert_eq!(x.candidate, y.candidate, "{}", obj.name());
        assert_eq!(x.objective.to_bits(), y.objective.to_bits());
        assert_eq!(x.mean.to_bits(), y.mean.to_bits());
        assert_eq!(x.p95.to_bits(), y.p95.to_bits());
    }
}

#[test]
fn prop_robust_selector_never_loses_to_fixed_libraries() {
    // by construction: the robust candidate set contains every fixed
    // library's default choice, scored on the same scenarios
    check("faults-robust-dominance", 5, |rng| {
        let topo = random_system(rng);
        let p = 4 + rng.gen_range(5) as usize;
        let cv = counts::irregular(rng, p, 8 << 20);
        let params = Params::default();
        let ens = ensemble(&topo, &EnsembleCfg::quick(rng.next_u64()).with_scenarios(3));
        let sel = AlgoSelector::new(params);
        let obj = if rng.gen_range(2) == 0 { RobustObjective::Mean } else { RobustObjective::P95 };
        let robust = sel.select_robust(&topo, &cv, &ens, obj);
        for cand in agv_bench::comm::select::default_candidates(&params, &cv) {
            let times: Vec<f64> = ens
                .iter()
                .map(|perts| {
                    perturbed_candidate(&topo, params, cand, &cv, perts)
                        .expect("defaults always apply")
                        .time
                })
                .collect();
            let fixed = obj.aggregate(&times);
            if robust.objective > fixed {
                return Err(format!(
                    "{}/{}: robust {} loses to {} {}",
                    topo.name,
                    obj.name(),
                    robust.objective,
                    cand.label(),
                    fixed
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_overlapping_scale_floor_windows_are_order_invariant() {
    // apply() composes overlapping effects on a link in fixed passes
    // (all active scales multiply, then all active floors clamp, then
    // outages zero), so how scale and floor windows interleave in the
    // *listing* cannot move a single bit. Kept to one scale per link —
    // two scales on one link multiply in listing order, which pins the
    // fp rounding deterministically but not permutation-invariantly.
    check("faults-order-invariance", 8, |rng| {
        let topo = random_system(rng);
        let lib = random_lib(rng);
        let p = 2 + rng.gen_range(7) as usize;
        let cv = counts::irregular(rng, p, 16 << 20);
        let healthy = run_allgatherv(lib, &topo, &cv);
        let t = healthy.time;
        let rank = rng.gen_range(p as u64) as usize;
        // a link the straggler's per-link scales cannot also touch
        let link = (0..topo.links.len())
            .map(|i| (i + rng.gen_range(topo.links.len() as u64) as usize) % topo.links.len())
            .find(|l| !topo.gpu_links(rank).contains(l))
            .expect("every system has non-GPU-incident links");
        let base = topo.links[link].class.bandwidth();
        let perts = [
            Perturbation::scale(link, 0.3 + 0.5 * rng.next_f64()).during(0.0, t * 0.7),
            Perturbation::floor(link, base * (0.2 + 0.3 * rng.next_f64())).during(t * 0.25, t),
            Perturbation::floor(link, base * (0.3 + 0.3 * rng.next_f64()))
                .during(t * 0.4, f64::INFINITY),
            Perturbation::straggler(rank, 0.4 + 0.4 * rng.next_f64()).during(t * 0.1, t * 0.8),
        ];
        let orders: [[usize; 4]; 3] = [[0, 1, 2, 3], [3, 2, 1, 0], [1, 3, 0, 2]];
        let runs: Vec<CommResult> = orders
            .iter()
            .map(|ord| {
                let set: Vec<Perturbation> = ord.iter().map(|&i| perts[i].clone()).collect();
                perturbed_allgatherv(&topo, lib, Params::default(), &cv, &set)
            })
            .collect();
        for r in &runs[1..] {
            if r.time.to_bits() != runs[0].time.to_bits() || r.flows != runs[0].flows {
                return Err(format!(
                    "{}/{} link {link}: listing order moved the result: {} vs {}",
                    topo.name,
                    lib.name(),
                    r.time,
                    runs[0].time
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_zero_magnitude_outages_are_bit_exact_and_recovery_neutral() {
    // the PR-7 extension of the zero-magnitude oracle: outage kinds
    // over empty windows are filtered with the rest (no capacity step
    // is ever emitted), and a recovery policy armed over such a set
    // never fires — the result stays bit-for-bit the healthy run
    check("faults-zeromag-outage", 6, |rng| {
        let topo = random_system(rng);
        let lib = random_lib(rng);
        let p = 2 + rng.gen_range(7) as usize;
        let cv = counts::irregular(rng, p, 16 << 20);
        let healthy = run_allgatherv(lib, &topo, &cv);
        let link = rng.gen_range(topo.links.len() as u64) as usize;
        let rank = rng.gen_range(p as u64) as usize;
        let perts = vec![
            Perturbation::link_down(link).during(rng.next_f64() * 1e-3, 0.0),
            Perturbation::gpu_down(rank).during(healthy.time * rng.next_f64(), 0.0),
            Perturbation::scale(link, 1.0),
        ];
        let degraded = perturbed_allgatherv(&topo, lib, Params::default(), &cv, &perts);
        if degraded.time.to_bits() != healthy.time.to_bits() || degraded.flows != healthy.flows {
            return Err(format!(
                "{}/{}: zero-magnitude outages moved the run: {} vs {}",
                topo.name,
                lib.name(),
                degraded.time,
                healthy.time
            ));
        }
        let rec = recovered_allgatherv(
            &topo,
            lib,
            Params::default(),
            &cv,
            &perts,
            &RecoveryPolicy::default_policy(),
        );
        if rec.strategy != RecoveryStrategy::None || rec.recovery_latency != 0.0 {
            return Err(format!(
                "{}/{}: recovery fired on a no-op set: {:?}",
                topo.name,
                lib.name(),
                rec.strategy
            ));
        }
        if rec.time().unwrap().to_bits() != healthy.time.to_bits() {
            return Err(format!(
                "{}/{}: armed-but-idle recovery moved the run: {} vs {}",
                topo.name,
                lib.name(),
                rec.time().unwrap(),
                healthy.time
            ));
        }
        Ok(())
    });
}

#[test]
fn mid_flow_bandwidth_drop_is_reflected_in_finish_time() {
    // the latent-assumption regression (ISSUE 5 satellite): both
    // engines used to snapshot link capacities once at run start; a
    // capacity cached at flow start would make this two-segment
    // integral come out as bytes/base_bw instead
    let topo = SystemKind::Dgx1.build();
    let path = topo.route_gpus(0, 1).unwrap();
    let link = path.links[0];
    let base = topo.links[link].class.bandwidth();
    let bytes = 2.0e9;
    let t1 = 0.04;
    let low = 0.25 * base;
    for reference in [false, true] {
        let mut sim = Sim::new(&topo);
        let id = sim.flow(path.clone(), bytes, 0.0, &[]);
        agv_bench::perturb::apply(
            &mut sim,
            &[Perturbation::scale(link, 0.25).during(t1, f64::INFINITY)],
        );
        let res = if reference { sim.run_reference() } else { sim.run() };
        let expect = t1 + (bytes - base * t1) / low;
        let stale = bytes / base;
        assert!(
            (res.finish(id) - expect).abs() / expect < 1e-9,
            "ref={reference}: finish {} != two-segment {expect} \
             (a stale cached capacity would give {stale})",
            res.finish(id)
        );
    }
}

#[test]
fn degradation_does_not_change_the_dag() {
    // flows/size accounting is perturbation-invariant — only timing
    // moves (the CommResult contract of perturbed_allgatherv)
    let topo = SystemKind::Cluster.build();
    let cv = vec![3u64 << 20; 8];
    for lib in Library::all() {
        let healthy: CommResult = run_allgatherv(lib, &topo, &cv);
        let degraded = perturbed_allgatherv(
            &topo,
            lib,
            Params::default(),
            &cv,
            &[Perturbation::straggler(2, 0.4)],
        );
        assert_eq!(healthy.flows, degraded.flows, "{}", lib.name());
    }
}
