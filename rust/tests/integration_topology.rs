//! Integration: Fig. 1 topology invariants across the full systems.

use agv_bench::topology::systems::{cluster, cs_storm, dgx1, SystemKind};
use agv_bench::topology::LinkClass;

#[test]
fn fig1_bandwidth_classes() {
    // paper Fig. 1 bandwidths (unidirectional): NVLink 20 GB/s class,
    // bonded 4x on CS-Storm, PCIe gen3 x16, FDR IB 56 Gbit/s
    assert!(LinkClass::NvLink.bandwidth() > 15.0e9 && LinkClass::NvLink.bandwidth() <= 20.0e9);
    assert!((LinkClass::NvLinkBonded4.bandwidth() / LinkClass::NvLink.bandwidth() - 4.0).abs() < 1e-9);
    assert!(LinkClass::PcieGen3x16.bandwidth() < LinkClass::NvLink.bandwidth());
    assert!(LinkClass::InfinibandFdr.bandwidth() < LinkClass::PcieGen3x16.bandwidth());
    // 56 Gbit/s = 7 GB/s raw; effective must be below that
    assert!(LinkClass::InfinibandFdr.bandwidth() <= 7.0e9);
}

#[test]
fn cluster_star_has_no_gpu_to_gpu_shortcut() {
    let t = cluster(16);
    for a in 0..16 {
        for b in 0..16 {
            if a == b {
                continue;
            }
            let p = t.route_gpus(a, b).unwrap();
            // GPU -> CPU -> NIC -> IB -> NIC -> CPU -> GPU: 6 hops
            assert_eq!(p.hops(), 6, "{a}->{b}");
            assert!(!t.p2p_accessible(a, b));
        }
    }
}

#[test]
fn dgx1_hybrid_cube_mesh_structure() {
    let t = dgx1();
    // 16 NVLink edges: 6 per quad + 4 cross
    let nv_edges = t.links.iter().filter(|l| l.class.is_nvlink()).count();
    assert_eq!(nv_edges, 16);
    // quads fully connected
    for base in [0usize, 4] {
        for a in base..base + 4 {
            for b in base..base + 4 {
                if a != b {
                    assert!(t.nvlink_direct(a, b), "{a}<->{b}");
                }
            }
        }
    }
    // cross links i <-> i+4 only
    for i in 0..4 {
        assert!(t.nvlink_direct(i, i + 4));
    }
    assert!(!t.nvlink_direct(0, 5));
    assert!(!t.nvlink_direct(1, 6));
}

#[test]
fn dgx1_paper_example_gpu0_reaches_567_via_two_nvlink_hops() {
    // §II-B: "GPU 0 can communicate with GPUs 5, 6 and 7 by traversing
    // two NVLink connections or by going through the PCIe network"
    let t = dgx1();
    for peer in [5usize, 6, 7] {
        let nv = t.route_nvlink_only(0, peer).unwrap();
        assert_eq!(nv.hops(), 2, "0->{peer}");
        assert!(!t.p2p_accessible(0, peer), "MVAPICH must not see P2P 0<->{peer}");
        // the PCIe fallback exists
        assert!(t.route_gpus(0, peer).is_some());
    }
}

#[test]
fn cs_storm_shared_pcie_switches() {
    let t = cs_storm();
    // 4 GPUs per switch: GPUs 0-3 share one switch (P2P among them),
    // and the switch uplink is a single PCIe link - the 16-GPU bottleneck.
    for a in 0..4 {
        for b in 0..4 {
            assert!(t.p2p_accessible(a, b), "{a}<->{b}");
        }
    }
    assert!(!t.p2p_accessible(0, 4), "different switches, same socket");
    // pairs bonded at 4x
    let p = t.route_gpus(4, 5).unwrap();
    assert_eq!(p.hops(), 1);
    assert!((t.path_bandwidth(&p) - LinkClass::NvLinkBonded4.bandwidth()).abs() < 1.0);
}

#[test]
fn per_system_gpu_inventory_and_symmetry() {
    for (kind, gpus) in [
        (SystemKind::Cluster, 16),
        (SystemKind::Dgx1, 8),
        (SystemKind::CsStorm, 16),
    ] {
        let t = kind.build();
        assert_eq!(t.num_gpus(), gpus);
        // symmetric routing: bandwidth(a->b) == bandwidth(b->a)
        for a in 0..gpus.min(6) {
            for b in 0..gpus.min(6) {
                if a == b {
                    continue;
                }
                let ab = t.path_bandwidth(&t.route_gpus(a, b).unwrap());
                let ba = t.path_bandwidth(&t.route_gpus(b, a).unwrap());
                assert!((ab - ba).abs() < 1.0, "{} {a}<->{b}", t.name);
            }
        }
    }
}
