//! Three-way differential harness for the sharded event engine
//! (DESIGN.md §15): on identical DAGs, the **sharded** driver, the
//! **unsharded** event core, and the retained O(F²·L) **reference**
//! engine must agree — finish times within the mixed
//! `1e-11 + 1e-9·|t|` tolerance, makespans within 1e-9 relative,
//! per-linkdir bytes within 1e-6 relative.
//!
//! Coverage: every library's composed Allgatherv on the three paper
//! systems plus a small fat-tree and a small dragonfly; a mid-flight
//! capacity step; and a permanent outage, where all three must produce
//! the *same stall diagnosis* (terminal time, stuck set, culprits).
//! The shard grid sweeps 1 / few / more-shards-than-components so the
//! merged-shard fallback, the round-robin bucketing, and the
//! single-shard degenerate all run.

use agv_bench::comm::{compose_allgatherv, Library, Params};
use agv_bench::sim::{run_sharded, with_reference_engine, Sim, SimOutcome, SimResult};
use agv_bench::topology::systems::SystemSpec;
use agv_bench::topology::Topology;

/// (shards, max_workers) grid every scenario runs under.
const SHARD_GRID: &[(usize, usize)] = &[(1, 1), (4, 2), (64, 8)];

/// The systems under differential test: the paper's three plus one
/// small instance of each scale fabric family.
fn systems() -> Vec<SystemSpec> {
    let mut v = SystemSpec::paper_all().to_vec();
    v.push(SystemSpec::FatTree { k: 4 });
    v.push(SystemSpec::Dragonfly { a: 2, p: 2, h: 2 });
    v
}

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() < 1e-11 + 1e-9 * b.abs()
}

/// Assert two engine results agree under the differential contract.
fn assert_results_agree(label: &str, got: &SimResult, want: &SimResult) {
    let rel = (got.makespan - want.makespan).abs() / want.makespan.abs().max(1e-300);
    assert!(rel < 1e-9, "{label}: makespan {} vs {} (rel {rel:e})", got.makespan, want.makespan);
    let (gf, wf) = (got.finish_times(), want.finish_times());
    assert_eq!(gf.len(), wf.len(), "{label}: task count");
    for (i, (a, b)) in gf.iter().zip(wf).enumerate() {
        assert!(close(*a, *b), "{label}: task {i} finish {a} vs {b}");
    }
    for (ld, (a, b)) in got.linkdir_bytes.iter().zip(&want.linkdir_bytes).enumerate() {
        let rel = (a - b).abs() / b.abs().max(1.0);
        assert!(rel < 1e-6, "{label}: linkdir {ld} bytes {a} vs {b}");
    }
}

/// Assert two outcomes describe the same terminal state: same kind,
/// same terminal time (mixed tolerance), same stall diagnosis.
fn assert_outcomes_agree(label: &str, got: &SimOutcome, want: &SimOutcome) {
    assert_eq!(got.is_completed(), want.is_completed(), "{label}: outcome kind");
    assert!(
        close(got.time(), want.time()),
        "{label}: terminal time {} vs {}",
        got.time(),
        want.time()
    );
    assert_eq!(got.culprit_links(), want.culprit_links(), "{label}: culprits");
    if let (
        SimOutcome::Stalled { stuck_tasks: gs, starved_flows: gn, .. },
        SimOutcome::Stalled { stuck_tasks: ws, starved_flows: wn, .. },
    ) = (got, want)
    {
        assert_eq!(gs, ws, "{label}: stuck task sets");
        assert_eq!(gn, wn, "{label}: starved flow counts");
    }
}

/// Run `build`'s DAG through all three engines and the shard grid:
/// event-driven (the baseline everything is compared against), the
/// O(F²·L) reference core via the thread-local override, and the
/// sharded driver at every grid point.
fn three_way(topo: &Topology, label: &str, build: impl Fn(&mut Sim)) {
    let run = || {
        let mut sim = Sim::new(topo);
        build(&mut sim);
        sim.run_outcome()
    };
    let (event, event_out) = run();
    {
        let (reference, ref_out) = with_reference_engine(&run);
        assert_results_agree(&format!("{label}/reference"), &reference, &event);
        assert_outcomes_agree(&format!("{label}/reference"), &ref_out, &event_out);
    }
    for &(shards, workers) in SHARD_GRID {
        let mut sim = Sim::new(topo);
        build(&mut sim);
        let (sharded, sharded_out, report) = run_sharded(sim, shards, workers);
        let l = format!("{label}/shards{shards}w{workers}");
        assert!(report.shards <= shards.max(1), "{l}: {report:?}");
        assert_results_agree(&l, &sharded, &event);
        assert_outcomes_agree(&l, &sharded_out, &event_out);
    }
}

/// Irregular §IV-style counts for `p` ranks.
fn counts(p: usize) -> Vec<u64> {
    let base = [64u64 << 10, 16 << 20, 256 << 10, 1 << 20];
    (0..p).map(|r| base[r % base.len()] + r as u64).collect()
}

#[test]
fn every_library_agrees_on_every_system() {
    for spec in systems() {
        let topo = spec.build();
        let p = topo.num_gpus().min(8);
        let cv = counts(p);
        for lib in Library::all() {
            three_way(&topo, &format!("{}/{}", spec.name(), lib.name()), |sim: &mut Sim| {
                compose_allgatherv(sim, lib, Params::default(), &cv, None);
            });
        }
    }
}

#[test]
fn concurrent_libraries_share_one_fabric() {
    // two independent tenants (different libraries) on one fabric: their
    // flow graphs may or may not share links — exactly what the shard
    // planner must get right — and all engines must agree either way
    for spec in [SystemSpec::parse("dgx1").unwrap(), SystemSpec::FatTree { k: 4 }] {
        let topo = spec.build();
        let p = topo.num_gpus().min(8);
        let cv = counts(p);
        three_way(&topo, &format!("{}/nccl+mpi", spec.name()), |sim: &mut Sim| {
            compose_allgatherv(sim, Library::Nccl, Params::default(), &cv, None);
            compose_allgatherv(sim, Library::Mpi, Params::default(), &cv, None);
        });
    }
}

#[test]
fn capacity_step_scenario_agrees() {
    // halve a route-0->1 link mid-flight: the step lands while flows
    // are active, so lazy settlement and shard-local cap routing both
    // run. Cross-checked on a paper system and both fabric families.
    for spec in [
        SystemSpec::parse("cs-storm").unwrap(),
        SystemSpec::FatTree { k: 4 },
        SystemSpec::Dragonfly { a: 2, p: 2, h: 2 },
    ] {
        let topo = spec.build();
        let link = topo.route_gpus(0, 1).unwrap().links[0];
        let cap = topo.links[link].class.bandwidth();
        let cv = counts(topo.num_gpus().min(8));
        three_way(&topo, &format!("{}/cap-step", spec.name()), |sim: &mut Sim| {
            compose_allgatherv(sim, Library::Nccl, Params::default(), &cv, None);
            sim.capacity_event(link, 2.0e-5, cap * 0.5);
            // independent second component: ranks at the far end
            let n = sim.topology().num_gpus();
            let path = sim.topology().route_gpus(n - 2, n - 1).unwrap();
            let lat = sim.topology().path_latency(&path);
            sim.flow(path, 3.0e6, lat, &[]);
        });
    }
}

#[test]
fn outage_scenario_agrees_on_the_stall_diagnosis() {
    // permanent zero-capacity step with a dependent task behind it: all
    // three engines must stall with the same time, stuck set, culprits,
    // while an untouched component still completes
    for spec in [SystemSpec::parse("cluster").unwrap(), SystemSpec::Dragonfly { a: 2, p: 2, h: 2 }]
    {
        let topo = spec.build();
        let link = topo.route_gpus(0, 1).unwrap().links[0];
        three_way(&topo, &format!("{}/outage", spec.name()), |sim: &mut Sim| {
            let t = sim.topology();
            let p01 = t.route_gpus(0, 1).unwrap();
            let lat = t.path_latency(&p01);
            let doomed = sim.flow(p01, 1.0e9, lat, &[]);
            sim.delay(1.0e-3, &[doomed]); // can never run
            sim.capacity_event(link, 1.0e-4, 0.0); // outage, no revival
            let n = t.num_gpus();
            let free = t.route_gpus(n - 2, n - 1).unwrap();
            let lat2 = t.path_latency(&free);
            sim.flow(free, 1.0e6, lat2, &[]); // separate component, completes
        });
    }
}

#[test]
fn sharded_leaf_rings_agree_on_small_fabrics() {
    // the exact DAG shape the scale bench times, at test-sized fabrics:
    // one ring per leaf group, every group its own component
    use agv_bench::sim::scale::{build_leaf_rings, leaf_group_size};
    for spec in [
        SystemSpec::FatTree { k: 4 },
        SystemSpec::Dragonfly { a: 2, p: 3, h: 2 },
        SystemSpec::MultiPlanePod { nodes: 3, gpus: 4, rails: 2 },
    ] {
        let topo = spec.build();
        let group = leaf_group_size(spec);
        let (event, event_out) = {
            let sim = build_leaf_rings(&topo, group, 5);
            sim.run_outcome()
        };
        assert!(event_out.is_completed());
        for &(shards, workers) in SHARD_GRID {
            let (sharded, out, _) = run_sharded(build_leaf_rings(&topo, group, 5), shards, workers);
            assert!(out.is_completed());
            assert_results_agree(&format!("{}/leaf-rings/{shards}", spec.name()), &sharded, &event);
        }
    }
}
