//! Integration: Table I calibration across the full data-set grid.

use agv_bench::tensor::datasets::{self, ROW_BYTES};
use agv_bench::tensor::messages::{message_trace, mode_counts, MsgStats};
use agv_bench::tensor::partition::{histogram_rows, profile_rows};
use agv_bench::tensor::synth::random_coo;

#[test]
fn table1_shape_full_grid() {
    // Paper Table I (avg MB, CV) at 2 and 8 GPUs; we assert ordering
    // relations and generous bands around the paper's values.
    let rows: Vec<(&str, MsgStats, MsgStats)> = datasets::all()
        .iter()
        .map(|d| (d.name, MsgStats::of(d, 2), MsgStats::of(d, 8)))
        .collect();

    // ascending average (the paper's table order)
    for w in rows.windows(2) {
        assert!(
            w[1].1.avg_mb() > w[0].1.avg_mb(),
            "{} !< {}",
            w[0].0, w[1].0
        );
    }
    // AMAZON is the regular one; NETFLIX/DELICIOUS the irregular ones
    let cv = |name: &str| {
        rows.iter().find(|r| r.0 == name).unwrap().1.cv()
    };
    assert!(cv("AMAZON") < 0.7);
    assert!(cv("NETFLIX") > 1.0);
    assert!(cv("DELICIOUS") > 1.0);
    assert!(cv("AMAZON") < cv("NELL-1"));
    assert!(cv("NELL-1") < cv("NETFLIX").max(cv("DELICIOUS")));
}

#[test]
fn delicious_spread_headline() {
    // "as much as a 25,400x difference between the smallest and largest
    // message size within a given data set" (DELICIOUS, across GPU
    // counts). At 8 GPUs our min slices get tiny (the paper's 0.006MB),
    // giving a spread in the thousands.
    let s8 = MsgStats::of(&datasets::delicious(), 8);
    assert!(s8.summary.spread() > 1_000.0, "spread {}", s8.summary.spread());
}

#[test]
fn sixteen_gpu_counts_are_consistent() {
    for d in datasets::all() {
        let counts = mode_counts(&d, 16);
        for (m, c) in counts.iter().enumerate() {
            assert_eq!(c.len(), 16);
            assert_eq!(c.iter().sum::<u64>(), d.modes[m].dim * ROW_BYTES);
            assert!(c.iter().all(|&b| b >= ROW_BYTES), "empty slice in mode {m}");
        }
    }
}

#[test]
fn message_trace_matches_mode_counts() {
    let d = datasets::amazon();
    let trace = message_trace(&d, 4);
    let counts = mode_counts(&d, 4);
    let flat: Vec<f64> = counts.iter().flat_map(|c| c.iter().map(|&b| b as f64)).collect();
    assert_eq!(trace, flat);
}

#[test]
fn analytic_profile_agrees_with_sampled_histogram() {
    // the analytic partition (paper-scale) and an exact histogram
    // partition of a *sampled* tensor from the same profile must agree
    // on slice widths within sampling noise
    let spec = agv_bench::tensor::TensorSpec {
        name: "t",
        modes: [
            agv_bench::tensor::ModeProfile { dim: 4096, skew: 0.6 },
            agv_bench::tensor::ModeProfile { dim: 512, skew: 0.3 },
            agv_bench::tensor::ModeProfile { dim: 512, skew: 0.0 },
        ],
        nnz: 200_000,
    };
    let t = random_coo(&spec, 200_000, 9);
    for mode in 0..3 {
        let analytic = profile_rows(&spec.modes[mode], 4);
        let exact = histogram_rows(&t.mode_histogram(mode), 4);
        for (a, e) in analytic.iter().zip(&exact) {
            let rel = (*a as f64 - *e as f64).abs() / (*a as f64);
            assert!(rel < 0.35, "mode {mode}: analytic {analytic:?} vs exact {exact:?}");
        }
    }
}
