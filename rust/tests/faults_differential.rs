//! Zero-perturbation differential oracle for the fault subsystem: an
//! **empty** perturbation set and a **zero-magnitude** one (scale 1.0,
//! floor at/above base bandwidth, zero-length window) must both
//! reproduce the unperturbed results **bit-exactly** — per library, per
//! system, per irregular count vector, on BOTH the event-driven and
//! reference engines (mirrors `workload_differential.rs`). The
//! mechanism under test: capacity steps that would not change a link's
//! capacity bit-for-bit are filtered before the run and never reach
//! either core, so zero perturbation means zero extra event instants,
//! zero extra settlements, zero reordered arithmetic. This is what
//! licenses every degraded number the subsystem reports: the fault
//! path IS the validated path plus real capacity steps, not a second
//! implementation.
//!
//! PR 9 adds the warm-replay oracle: every perturbation class replayed
//! warm from a recorded baseline (DESIGN.md §16) must agree with a cold
//! re-simulation — bit-exactly on the identical/cold/tail planner
//! tiers, to 1e-9 on the genuinely warm tier — on makespan, every
//! per-op finish instant, and every linkdir byte count.

use agv_bench::comm::select::{candidates, simulate};
use agv_bench::comm::transport::RecoveryPolicy;
use agv_bench::comm::{run_allgatherv, Library, Params};
use agv_bench::perturb::{
    perturbed_allgatherv, perturbed_candidate, recovered_allgatherv, Perturbation,
    RecoveryStrategy,
};
use agv_bench::sim::{with_reference_engine, Sim, SimOutcome};
use agv_bench::topology::systems::{multi_dgx, SystemKind};
use agv_bench::topology::{LinkClass, Topology};
use agv_bench::util::prng::Rng;
use agv_bench::util::prop::{check, counts};
use agv_bench::workload::{run_workload, TenantLib, WorkloadSpec};

/// Per-seed irregular vectors spanning the §IV regimes.
fn vectors(rng: &mut Rng, p: usize) -> Vec<Vec<u64>> {
    vec![
        counts::regular(p, 1 + rng.gen_range(32 << 20)),
        counts::skewed(rng, p, 48 << 20),
        counts::zero_heavy(rng, p, 32 << 20),
        counts::single_hot(rng, p, 256 << 20),
    ]
}

/// A perturbation set whose every member is a no-op: identity scales,
/// floors at or above base bandwidth, and a real degradation over an
/// empty window. Drawn per seed so placement varies.
fn zero_magnitude_set(rng: &mut Rng, topo: &Topology) -> Vec<Perturbation> {
    let link = rng.gen_range(topo.links.len() as u64) as usize;
    let rank = rng.gen_range(topo.num_gpus() as u64) as usize;
    let base = topo.links[link].class.bandwidth();
    vec![
        Perturbation::scale(link, 1.0),
        Perturbation::floor(link, base * (1.0 + rng.next_f64())),
        Perturbation::straggler(rank, 1.0),
        // severe, but over a zero-length window: never active
        Perturbation::scale(link, 0.01).during(rng.next_f64() * 1e-3, 0.0),
    ]
}

fn assert_bit_exact(
    topo: &Topology,
    lib: Library,
    cv: &[u64],
    perts: &[Perturbation],
    what: &str,
) {
    let base = run_allgatherv(lib, topo, cv);
    let pert = perturbed_allgatherv(topo, lib, Params::default(), cv, perts);
    assert_eq!(
        pert.time.to_bits(),
        base.time.to_bits(),
        "{what}/{}/{}: perturbed {} != unperturbed {} (counts {cv:?})",
        topo.name,
        lib.name(),
        pert.time,
        base.time
    );
    assert_eq!(pert.flows, base.flows, "{what}/{}/{}", topo.name, lib.name());
}

#[test]
fn empty_set_is_bit_exact_event_engine() {
    check("faults-differential-empty-event", 10, |rng| {
        for kind in SystemKind::all() {
            let topo = kind.build();
            let p = [2, 4, kind.max_gpus().min(8)][rng.gen_range(3) as usize];
            for cv in vectors(rng, p) {
                for lib in Library::all() {
                    assert_bit_exact(&topo, lib, &cv, &[], "empty/event");
                }
            }
        }
        Ok(())
    });
}

#[test]
fn zero_magnitude_set_is_bit_exact_event_engine() {
    check("faults-differential-zeromag-event", 10, |rng| {
        for kind in SystemKind::all() {
            let topo = kind.build();
            let p = [2, 4, kind.max_gpus().min(8)][rng.gen_range(3) as usize];
            let perts = zero_magnitude_set(rng, &topo);
            for cv in vectors(rng, p) {
                for lib in Library::all() {
                    assert_bit_exact(&topo, lib, &cv, &perts, "zeromag/event");
                }
            }
        }
        Ok(())
    });
}

#[test]
fn empty_and_zero_magnitude_sets_are_bit_exact_reference_engine() {
    // fewer cases: the reference core is O(F^2) by design
    check("faults-differential-reference", 3, |rng| {
        for kind in SystemKind::all() {
            let topo = kind.build();
            let p = [2, kind.max_gpus().min(8)][rng.gen_range(2) as usize];
            let perts = zero_magnitude_set(rng, &topo);
            for cv in vectors(rng, p) {
                for lib in Library::all() {
                    with_reference_engine(|| {
                        assert_bit_exact(&topo, lib, &cv, &[], "empty/reference");
                        assert_bit_exact(&topo, lib, &cv, &perts, "zeromag/reference");
                    });
                }
            }
        }
        Ok(())
    });
}

#[test]
fn every_candidate_is_bit_exact_under_zero_perturbation() {
    // the selector's compose path, including the hierarchical schedules
    // on the multi-node topology, through perturbed_candidate
    let topo = multi_dgx(2);
    let cv: Vec<u64> = (0..16).map(|r| ((r % 5) as u64 + 1) << 18).collect();
    let params = Params::default();
    let mut rng = Rng::new(7);
    let perts = zero_magnitude_set(&mut rng, &topo);
    for cand in candidates(&topo, 16) {
        let base = simulate(&topo, params, cand, &cv).expect("candidate applies");
        for (what, set) in [("empty", &vec![]), ("zeromag", &perts)] {
            let pert = perturbed_candidate(&topo, params, cand, &cv, set)
                .expect("candidate applies");
            assert_eq!(
                pert.time.to_bits(),
                base.time.to_bits(),
                "{what}/{}: {} != {}",
                cand.label(),
                pert.time,
                base.time
            );
            assert_eq!(pert.flows, base.flows, "{what}/{}", cand.label());
        }
    }
}

#[test]
fn workload_with_zero_magnitude_faults_is_bit_exact() {
    // the fault timeline rides the multi-tenant engine too: a
    // zero-magnitude timeline must not move a single finish time
    check("faults-differential-workload", 4, |rng| {
        for kind in SystemKind::all() {
            let topo = kind.build();
            let spec = WorkloadSpec::synthetic(
                3,
                2,
                kind.max_gpus().min(8),
                TenantLib::Fixed(Library::Nccl),
                4 << 20,
                rng.next_u64(),
            );
            let plain = run_workload(&topo, &spec, Params::default()).unwrap();
            let faulted = spec.clone().with_faults(zero_magnitude_set(rng, &topo));
            let noop = run_workload(&topo, &faulted, Params::default()).unwrap();
            assert_eq!(plain.makespan.to_bits(), noop.makespan.to_bits(), "{}", topo.name);
            assert_eq!(plain.total_bytes.to_bits(), noop.total_bytes.to_bits());
            assert_eq!(plain.flows, noop.flows);
            for (a, b) in plain.tenants.iter().zip(&noop.tenants) {
                for (x, y) in a.ops.iter().zip(&b.ops) {
                    assert_eq!(x.finish.to_bits(), y.finish.to_bits());
                    assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn recovery_armed_but_never_triggered_is_bit_exact_both_engines() {
    // the PR-7 anchor extension: arming the timeout-retry-reroute
    // driver changes nothing unless a hard outage actually overlaps
    // the run — over soft degradations (which freeze nothing and can
    // never trip the watchdog) the recovered result is bit-for-bit the
    // plain perturbed one, per system x library, on BOTH engines
    check("faults-recovery-neutral", 3, |rng| {
        let policy = RecoveryPolicy::default_policy();
        for kind in SystemKind::all() {
            let topo = kind.build();
            let p = kind.max_gpus().min(8);
            let cv = counts::irregular(rng, p, 8 << 20);
            let soft = vec![
                Perturbation::straggler(rng.gen_range(p as u64) as usize, 0.5),
                Perturbation::scale(rng.gen_range(topo.links.len() as u64) as usize, 0.6),
            ];
            for lib in Library::all() {
                for reference in [false, true] {
                    let run = || {
                        let base =
                            perturbed_allgatherv(&topo, lib, Params::default(), &cv, &soft);
                        let rec = recovered_allgatherv(
                            &topo,
                            lib,
                            Params::default(),
                            &cv,
                            &soft,
                            &policy,
                        );
                        (base, rec)
                    };
                    let (base, rec) =
                        if reference { with_reference_engine(run) } else { run() };
                    assert_eq!(
                        rec.strategy,
                        RecoveryStrategy::None,
                        "ref={reference} {}/{}",
                        topo.name,
                        lib.name()
                    );
                    assert_eq!(rec.recovery_latency, 0.0);
                    let r = rec.result.expect("clean recovery completes");
                    assert_eq!(
                        r.time.to_bits(),
                        base.time.to_bits(),
                        "ref={reference} {}/{}: armed driver moved the run: {} vs {}",
                        topo.name,
                        lib.name(),
                        r.time,
                        base.time
                    );
                    assert_eq!(r.flows, base.flows);
                }
            }
        }
        Ok(())
    });
}

#[test]
fn stall_diagnosis_agrees_across_engines() {
    // an unrecoverable outage must come back as a *diagnosed* stall on
    // BOTH engines: same stuck tasks, same starved-flow count, same
    // culprit links, stall instants within the engines' ~1e-9 contract
    let topo = SystemKind::Dgx1.build();
    let cv = vec![4u64 << 20; 8];
    let link = topo.route_gpus(0, 1).unwrap().links[0];
    let perts = [Perturbation::link_down(link)];
    let outcome_of = |reference: bool| {
        let run = || {
            let mut sim = Sim::new(&topo);
            agv_bench::comm::compose_allgatherv(
                &mut sim,
                Library::Nccl,
                Params::default(),
                &cv,
                None,
            );
            agv_bench::perturb::apply(&mut sim, &perts);
            sim.run_outcome().1
        };
        if reference { with_reference_engine(run) } else { run() }
    };
    let (ev, rf) = (outcome_of(false), outcome_of(true));
    match (&ev, &rf) {
        (
            SimOutcome::Stalled {
                time: te,
                stuck_tasks: se,
                starved_flows: fe,
                culprit_links: le,
            },
            SimOutcome::Stalled {
                time: tr,
                stuck_tasks: sr,
                starved_flows: fr,
                culprit_links: lr,
            },
        ) => {
            assert_eq!(se, sr, "stuck-task sets diverged");
            assert_eq!(fe, fr, "starved-flow counts diverged");
            assert_eq!(le, lr, "culprit links diverged");
            assert!(
                le.contains(&link),
                "diagnosis does not name the dead link {link}: {le:?}"
            );
            let rel = (te - tr).abs() / tr.max(1e-12);
            assert!(rel < 1e-9, "stall instants diverged: {te} vs {tr}");
        }
        _ => panic!("engines disagree on liveness: {} vs {}", ev.describe(), rf.describe()),
    }
}

#[test]
fn stalled_constructor_normalizes_ordering() {
    // PR-10 ordering-contract fix: workload::slo classifies a job as
    // completed iff its done task is ABSENT from stuck_tasks — via
    // binary_search, which silently returns nonsense on unsorted input.
    // Every engine now builds the Stalled variant through
    // SimOutcome::stalled, which owns the sort+dedup; pre-fix this
    // constructor did not exist and each stall site sorted (or forgot
    // to sort) by hand.
    match SimOutcome::stalled(1.0, vec![5, 2, 2, 9], 1, vec![3, 1, 3]) {
        SimOutcome::Stalled { time, stuck_tasks, starved_flows, culprit_links } => {
            assert_eq!(stuck_tasks, vec![2, 5, 9], "stuck tasks not sorted+deduped");
            assert_eq!(culprit_links, vec![1, 3], "culprit links not sorted+deduped");
            assert_eq!(time, 1.0);
            assert_eq!(starved_flows, 1);
        }
        other => panic!("constructor built {}", other.describe()),
    }
}

#[test]
fn stuck_tasks_are_sorted_for_binary_search_on_both_engines() {
    // the ordering contract end-to-end: a multi-tenant workload stalled
    // by a permanent outage reports its stuck tasks strictly ascending
    // on BOTH engines — exactly what slo.rs's binary_search classifier
    // requires. A multi-op DAG matters here: several gated chains starve
    // at once, so an unsorted collection order would actually surface.
    let topo = SystemKind::Dgx1.build();
    let cv = vec![2u64 << 20; 8];
    let link = topo.route_gpus(0, 1).unwrap().links[0];
    let perts = [Perturbation::link_down(link)];
    let outcome_of = |reference: bool| {
        let run = || {
            let mut sim = Sim::new(&topo);
            // three gated chains starving concurrently, like a
            // multi-tenant workload DAG
            let d1 = agv_bench::comm::compose_allgatherv(
                &mut sim,
                Library::Nccl,
                Params::default(),
                &cv,
                None,
            );
            agv_bench::comm::compose_allgatherv(
                &mut sim,
                Library::MpiCuda,
                Params::default(),
                &cv,
                Some(d1),
            );
            agv_bench::comm::compose_allgatherv(
                &mut sim,
                Library::Mpi,
                Params::default(),
                &cv,
                None,
            );
            agv_bench::perturb::apply(&mut sim, &perts);
            sim.run_outcome().1
        };
        if reference { with_reference_engine(run) } else { run() }
    };
    for reference in [false, true] {
        match outcome_of(reference) {
            SimOutcome::Stalled { stuck_tasks, culprit_links, .. } => {
                assert!(
                    stuck_tasks.len() > 1,
                    "ref={reference}: need a multi-task stall to exercise ordering"
                );
                assert!(
                    stuck_tasks.windows(2).all(|w| w[0] < w[1]),
                    "ref={reference}: stuck_tasks not strictly ascending: {stuck_tasks:?}"
                );
                assert!(
                    culprit_links.windows(2).all(|w| w[0] < w[1]),
                    "ref={reference}: culprit_links not strictly ascending: {culprit_links:?}"
                );
            }
            other => panic!("ref={reference}: expected a stall, got {}", other.describe()),
        }
    }
}

#[test]
fn midrun_link_outage_completes_on_every_system_and_library() {
    // acceptance: a single mid-run link outage on every system x
    // library completes under the default policy — natively (frozen
    // flows thaw when the window closes), by watchdog retry, or — when
    // the outage never lifts — by reroute, or by shrinking past a GPU
    // whose only fabric link died
    let policy = RecoveryPolicy::default_policy();
    for kind in SystemKind::all() {
        let topo = kind.build();
        let p = kind.max_gpus().min(8);
        let cv = vec![4u64 << 20; p];
        let link = topo.route_gpus(0, 1).unwrap().links[0];
        for lib in Library::all() {
            let healthy = run_allgatherv(lib, &topo, &cv);
            let transient =
                Perturbation::link_down(link).during(healthy.time * 0.3, healthy.time);
            let rec = recovered_allgatherv(
                &topo,
                lib,
                Params::default(),
                &cv,
                &[transient],
                &policy,
            );
            assert!(
                rec.completed(),
                "{}/{} transient: {:?}",
                topo.name,
                lib.name(),
                rec.strategy
            );
            let t = rec.time().unwrap();
            assert!(
                t.is_finite() && t >= healthy.time * (1.0 - 1e-9),
                "{}/{}: outage run {} beat the healthy run {}",
                topo.name,
                lib.name(),
                t,
                healthy.time
            );
            let rec = recovered_allgatherv(
                &topo,
                lib,
                Params::default(),
                &cv,
                &[Perturbation::link_down(link)],
                &policy,
            );
            assert!(
                rec.completed() && !matches!(rec.strategy, RecoveryStrategy::Abort),
                "{}/{} permanent: {:?}",
                topo.name,
                lib.name(),
                rec.strategy
            );
        }
    }
}

#[test]
fn warm_replay_agrees_with_cold_resimulation_across_the_grid() {
    // the PR-9 acceptance oracle: per paper system x library, a
    // baseline is recorded once and every perturbation class is run
    // both warm (fast-forward to first divergence, resume live) and
    // cold (fresh end-to-end simulation). Identical/cold/tail tiers
    // must be bit-exact — they are promises, not approximations — and
    // the warm tier must agree to 1e-9 relative on makespan, every
    // per-op finish, and every linkdir byte count.
    use agv_bench::perturb::bench::delta_ensemble;
    use agv_bench::perturb::DeltaSim;
    use agv_bench::sim::TaskId;

    fn agree(delta: &DeltaSim<'_>, done: TaskId, perts: &[Perturbation], what: &str) {
        let mode = delta.mode(perts);
        let bit_exact = mode != "warm";
        let (rw, ow) = delta.run(perts);
        let (rc, oc) = delta.run_cold(perts);
        assert_eq!(
            ow.is_completed(),
            oc.is_completed(),
            "{what}[{mode}]: liveness diverged: {} vs {}",
            ow.describe(),
            oc.describe()
        );
        if !oc.is_completed() {
            return;
        }
        let near = |a: f64, b: f64| (a - b).abs() <= 1e-9 * b.abs().max(1e-12);
        if bit_exact {
            assert_eq!(
                rw.makespan.to_bits(),
                rc.makespan.to_bits(),
                "{what}[{mode}]: makespan {} vs {}",
                rw.makespan,
                rc.makespan
            );
        }
        assert!(
            near(rw.makespan, rc.makespan),
            "{what}[{mode}]: makespan {} vs {}",
            rw.makespan,
            rc.makespan
        );
        assert!(near(rw.finish(done), rc.finish(done)), "{what}[{mode}]: collective finish");
        let (fw, fc) = (rw.finish_times(), rc.finish_times());
        assert_eq!(fw.len(), fc.len(), "{what}[{mode}]: task counts diverged");
        for (i, (a, b)) in fw.iter().zip(fc).enumerate() {
            if bit_exact {
                assert_eq!(a.to_bits(), b.to_bits(), "{what}[{mode}]: finish[{i}] {a} vs {b}");
            }
            assert!(near(*a, *b), "{what}[{mode}]: finish[{i}] {a} vs {b}");
        }
        for (i, (a, b)) in rw.linkdir_bytes.iter().zip(&rc.linkdir_bytes).enumerate() {
            assert!(
                (a - b).abs() <= 1e-9 * b.abs().max(1.0),
                "{what}[{mode}]: linkdir_bytes[{i}] {a} vs {b}"
            );
        }
    }

    check("faults-warm-vs-cold-grid", 2, |rng| {
        for kind in SystemKind::all() {
            let topo = kind.build();
            let p = kind.max_gpus().min(8);
            let cv = counts::irregular(rng, p, 8 << 20);
            for lib in Library::all() {
                let mut sim = Sim::new(&topo);
                let done = agv_bench::comm::compose_allgatherv(
                    &mut sim,
                    lib,
                    Params::default(),
                    &cv,
                    None,
                );
                let delta = DeltaSim::record(sim);
                let m = delta.baseline().makespan;
                let link = rng.gen_range(topo.links.len() as u64) as usize;
                let rank = rng.gen_range(p as u64) as usize;

                // identical tier: nothing to replay differently
                assert_eq!(delta.mode(&[]), "identical");
                agree(&delta, done, &[], "empty");
                agree(&delta, done, &zero_magnitude_set(rng, &topo), "zeromag");

                // cold tier: degradation active from t=0 (divergence at
                // the very first instant — warm start must fall back)
                let stat =
                    [Perturbation::scale(link, 0.5), Perturbation::straggler(rank, 0.4)];
                assert_eq!(delta.mode(&stat), "cold");
                agree(&delta, done, &stat, "static");

                // warm tier: degradation windows opening mid-run
                let base_bw = topo.links[link].class.bandwidth();
                let wnd = [
                    Perturbation::scale(link, 0.3).during(0.4 * m, 0.4 * m),
                    Perturbation::floor(link, base_bw * 0.2).during(0.5 * m, 0.2 * m),
                ];
                agree(&delta, done, &wnd, "midrun-degrade");

                // warm tier: a transient outage the engine rides out
                let out = [Perturbation::link_down(link).during(0.5 * m, 0.1 * m)];
                agree(&delta, done, &out, "transient-outage");

                // tail tier: the fault arrives after the baseline
                // already finished — pure replay, still Completed
                let tail = [Perturbation::link_down(link).during(2.0 * m, m)];
                assert_eq!(delta.mode(&tail), "tail");
                agree(&delta, done, &tail, "post-makespan");

                // the time-windowed ensemble class (what the benches
                // replay): a mixed draw across all four tiers
                for (i, perts) in
                    delta_ensemble(&topo, m, rng.next_u64()).iter().take(6).enumerate()
                {
                    agree(&delta, done, perts, &format!("ensemble[{i}]"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn engines_agree_on_a_genuinely_degraded_run() {
    // not a zero-magnitude case: real capacity steps through both
    // cores, agreement to the documented ~1e-9 relative contract
    let topo = SystemKind::CsStorm.build();
    let cv = vec![6u64 << 20; 8];
    let perts = [
        Perturbation::straggler(0, 0.4),
        Perturbation::scale(1, 0.6).during(1.0e-4, 2.0e-3),
        Perturbation::floor(2, LinkClass::PcieGen3x16.bandwidth() * 0.3),
    ];
    for lib in Library::all() {
        let event = perturbed_allgatherv(&topo, lib, Params::default(), &cv, &perts);
        let refr = with_reference_engine(|| {
            perturbed_allgatherv(&topo, lib, Params::default(), &cv, &perts)
        });
        assert_eq!(event.flows, refr.flows, "{}", lib.name());
        let rel = (event.time - refr.time).abs() / refr.time;
        assert!(
            rel < 1e-9,
            "{}: degraded engines diverged: {} vs {}",
            lib.name(),
            event.time,
            refr.time
        );
    }
}
