//! K-tenant contention properties of the workload engine, over
//! randomized systems / tenant counts / libraries / irregular traces:
//!
//! 1. **conservation** — the shared run moves exactly the bytes the
//!    tenants move in isolation (contention reshapes *when* bytes
//!    move, never *how many*);
//! 2. **no free lunch** — no op completes faster on a contended
//!    fabric than on an idle one;
//! 3. **monotonicity** — removing a tenant never *materially* slows
//!    the survivors, and helps in aggregate.
//!
//! Tolerance calibration (documented because the bounds are load-
//! bearing): max-min fluid sharing with multi-hop flows admits
//! Graham-style scheduling anomalies — removing a tenant shifts when
//! the survivors' flows overlap *each other*, and a rephased overlap
//! can finish later. Sweeping this exact generator (same seeds, same
//! draw order) through a port of the reference engine measured worst
//! anomalies of -4.4% for tenant-removal completion and only
//! FP-noise-level (~1e-13) violations for conservation and
//! no-free-lunch. Hence: conservation and no-free-lunch are asserted
//! tight (1e-9), monotonicity with a 10% anomaly allowance plus an
//! aggregate-direction check.

use agv_bench::comm::{Library, Params};
use agv_bench::topology::systems::SystemKind;
use agv_bench::topology::Topology;
use agv_bench::util::prng::Rng;
use agv_bench::util::prop::{check, counts};
use agv_bench::util::stats::geomean;
use agv_bench::workload::{
    isolated_times, run_workload, OpStream, TenantLib, TenantSpec, WorkloadSpec,
};

/// Largest single-rank contribution the random traces draw.
const MAX_BYTES: u64 = 16 << 20;
/// Anomaly allowance for tenant-removal monotonicity (see module docs).
const MONO_SLACK: f64 = 0.10;

fn random_system(rng: &mut Rng) -> Topology {
    match rng.gen_range(3) {
        0 => SystemKind::Cluster.build(),
        1 => SystemKind::Dgx1.build(),
        _ => SystemKind::CsStorm.build(),
    }
}

/// Random K-tenant spec: mixed libraries, random irregular traces,
/// jittered arrivals. Draw order is part of the test's identity — the
/// calibration sweep replays it seed-for-seed.
fn random_spec(rng: &mut Rng, max_gpus: usize) -> WorkloadSpec {
    let k = 2 + rng.gen_range(3) as usize;
    let ops = 1 + rng.gen_range(2) as usize;
    let tenants = (0..k)
        .map(|i| {
            let p = 2 + rng.gen_range(max_gpus as u64 - 1) as usize;
            let lib = match rng.gen_range(3) {
                0 => Library::Mpi,
                1 => Library::MpiCuda,
                _ => Library::Nccl,
            };
            let trace: Vec<Vec<u64>> =
                (0..ops).map(|_| counts::irregular(rng, p, MAX_BYTES)).collect();
            TenantSpec {
                name: format!("t{i}"),
                seed: i as u64,
                lib: TenantLib::Fixed(lib),
                op: agv_bench::comm::collective::CollectiveOp::Allgatherv,
                stream: OpStream::Trace { ops: trace },
                ops,
                start_offset: rng.gen_f64(0.0, 2.0e-3),
                gap: rng.gen_f64(0.0, 1.0e-3),
                jitter: rng.gen_f64(0.0, 0.5e-3),
            }
        })
        .collect();
    WorkloadSpec { name: "prop".into(), seed: rng.next_u64(), tenants, faults: vec![] }
}

fn sub_spec(spec: &WorkloadSpec, keep: &[usize]) -> WorkloadSpec {
    WorkloadSpec {
        name: spec.name.clone(),
        seed: spec.seed,
        tenants: keep.iter().map(|&i| spec.tenants[i].clone()).collect(),
        faults: spec.faults.clone(),
    }
}

#[test]
fn prop_byte_conservation_under_contention() {
    check("workload-conservation", 16, |rng| {
        let topo = random_system(rng);
        let spec = random_spec(rng, topo.num_gpus().min(8));
        let shared = run_workload(&topo, &spec, Params::default()).expect("valid spec");
        let mut isolated_total = 0.0;
        for i in 0..spec.tenants.len() {
            let solo = run_workload(&topo, &sub_spec(&spec, &[i]), Params::default())
                .expect("valid sub-spec");
            isolated_total += solo.total_bytes;
        }
        let rel = (shared.total_bytes - isolated_total).abs() / isolated_total.max(1.0);
        agv_bench::prop_assert!(
            rel < 1e-9,
            "bytes not conserved on {}: shared {} vs isolated sum {} (rel {rel})",
            topo.name, shared.total_bytes, isolated_total
        );
        Ok(())
    });
}

#[test]
fn prop_no_free_lunch_vs_idle_fabric() {
    check("workload-no-free-lunch", 24, |rng| {
        let topo = random_system(rng);
        let spec = random_spec(rng, topo.num_gpus().min(8));
        let shared = run_workload(&topo, &spec, Params::default()).expect("valid spec");
        let idle = isolated_times(&topo, &spec, Params::default()).expect("valid spec");
        for (t, tr) in shared.tenants.iter().enumerate() {
            for op in &tr.ops {
                let iso = idle[t][op.index];
                agv_bench::prop_assert!(
                    op.latency() >= iso * (1.0 - 1e-9) - 1e-12,
                    "free lunch on {}: tenant {t} op {} contended {} < isolated {iso}",
                    topo.name, op.index, op.latency()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_removing_a_tenant_helps_the_others() {
    // per-survivor: within the anomaly allowance; in aggregate across
    // the whole suite: removal must genuinely speed survivors up
    let mut ratios: Vec<f64> = Vec::new();
    check("workload-monotonicity", 24, |rng| {
        let topo = random_system(rng);
        let spec = random_spec(rng, topo.num_gpus().min(8));
        let k = spec.tenants.len();
        let drop = rng.gen_range(k as u64) as usize;
        let shared = run_workload(&topo, &spec, Params::default()).expect("valid spec");
        let keep: Vec<usize> = (0..k).filter(|&i| i != drop).collect();
        let without = run_workload(&topo, &sub_spec(&spec, &keep), Params::default())
            .expect("valid sub-spec");
        for (j, &i) in keep.iter().enumerate() {
            let with_t = shared.tenants[i].completion;
            let without_t = without.tenants[j].completion;
            agv_bench::prop_assert!(
                without_t <= with_t * (1.0 + MONO_SLACK),
                "removal slowed tenant {i} on {} beyond the anomaly bound: \
                 {without_t} vs {with_t} with the dropped tenant present",
                topo.name
            );
            ratios.push(with_t / without_t);
        }
        Ok(())
    });
    // calibration sweep measured geomean ~1.11 on these exact seeds;
    // anything near 1.0 would mean the suite generates no contention
    let g = geomean(&ratios);
    assert!(g > 1.02, "tenant removal barely helps (geomean {g:.4}) — no real contention?");
}

#[test]
fn contended_tenants_preserve_per_tenant_op_order() {
    // iteration k+1 gates on iteration k for every tenant, with or
    // without contention; arrivals and finishes are strictly ordered
    check("workload-op-order", 8, |rng| {
        let topo = random_system(rng);
        let spec = random_spec(rng, topo.num_gpus().min(8));
        let shared = run_workload(&topo, &spec, Params::default()).expect("valid spec");
        for tr in &shared.tenants {
            for w in tr.ops.windows(2) {
                agv_bench::prop_assert!(
                    w[1].arrival >= w[0].finish - 1e-15,
                    "op {} arrived before op {} finished ({} < {})",
                    w[1].index, w[0].index, w[1].arrival, w[0].finish
                );
                agv_bench::prop_assert!(w[1].finish > w[0].finish);
            }
            agv_bench::prop_assert!(
                (tr.completion - tr.ops.last().unwrap().finish).abs() == 0.0
            );
        }
        Ok(())
    });
}
