//! Engine scaling regression and golden-parity tests for the
//! event-driven simulator core (DESIGN.md §8).
//!
//! Scaling is asserted by **counting work** through `SimResult::stats`
//! rather than timing: wall-clock bounds are flaky on shared CI
//! machines, while the counters deterministically expose any
//! reintroduction of the old per-event linear scan / from-scratch
//! refill (which made the seed engine O(F²·L)).
//!
//! The golden tests regenerate the pre-rewrite engine's fig2/table1
//! numbers on demand (the reference core is retained in
//! `sim::reference`) instead of pinning constants, and assert the
//! event-driven engine reproduces them.

use agv_bench::comm::Library;
use agv_bench::osu::{run_osu, OsuConfig};
use agv_bench::report::table1;
use agv_bench::sim::{with_reference_engine, Sim};
use agv_bench::topology::systems::SystemKind;
use agv_bench::topology::{DeviceKind, LinkClass, Topology};

fn one_link_topo() -> Topology {
    let mut t = Topology::new("one-link");
    let g0 = t.add_device(DeviceKind::Gpu { rank: 0 }, 0, "g0");
    let g1 = t.add_device(DeviceKind::Gpu { rank: 1 }, 0, "g1");
    t.add_link(g0, g1, LinkClass::NvLink);
    t
}

/// A dependency chain of N flows over one link: exactly one flow is
/// active at a time, so every start and finish must take the O(1)
/// incremental fast path — zero full refills, zero refill work, and one
/// heap push per flow. The old engine paid a full refill per flow here.
#[test]
fn serialized_chain_takes_fast_paths_only() {
    let t = one_link_topo();
    let n = 3000usize;
    let bytes = 1.0e8;
    let lat = 1.0e-6;
    let mut sim = Sim::new(&t);
    let mut last = None;
    for _ in 0..n {
        let path = t.route_gpus(0, 1).unwrap();
        let deps: Vec<_> = last.into_iter().collect();
        last = Some(sim.flow(path, bytes, lat, &deps));
    }
    let res = sim.run();
    let s = res.stats;
    assert_eq!(s.full_refills, 0, "chain flows must never trigger a full refill");
    assert_eq!(s.refill_flow_visits, 0);
    assert_eq!(s.completions, n as u64);
    assert!(
        s.heap_pushes <= n as u64 + 8,
        "heap pushes {} not linear in N={n}",
        s.heap_pushes
    );
    assert!(
        s.events <= n as u64 + 8,
        "events {} not linear in N={n}",
        s.events
    );
    // correctness alongside the counters: the chain serializes exactly
    let solo = bytes / LinkClass::NvLink.bandwidth();
    let expect = n as f64 * (lat + solo);
    assert!(
        (res.makespan - expect).abs() / expect < 1e-9,
        "makespan {} vs analytic {expect}",
        res.makespan
    );
    assert_eq!(res.flows, n);
}

/// N equal-size independent flows sharing one link: one batched rate
/// refill at activation (N flow-visits: progressive filling freezes
/// everyone in a single round), identical rates, one simultaneous
/// completion batch, and nothing afterwards — total work linear in N.
#[test]
fn concurrent_equal_flows_need_one_refill() {
    let t = one_link_topo();
    let n = 3000usize;
    let bytes = 1.0e8;
    let mut sim = Sim::new(&t);
    for _ in 0..n {
        let path = t.route_gpus(0, 1).unwrap();
        sim.flow(path, bytes, 1.0e-6, &[]);
    }
    let res = sim.run();
    let s = res.stats;
    assert_eq!(s.full_refills, 1, "equal concurrent flows need exactly one refill");
    assert!(
        s.refill_flow_visits <= 2 * n as u64,
        "refill work {} not linear in N={n}",
        s.refill_flow_visits
    );
    assert_eq!(s.completions, n as u64);
    assert!(s.heap_pushes <= 2 * n as u64 + 8);
    // all flows share the link fairly and finish together
    let expect = 1.0e-6 + n as f64 * bytes / LinkClass::NvLink.bandwidth();
    assert!(
        (res.makespan - expect).abs() / expect < 1e-9,
        "makespan {} vs analytic {expect}",
        res.makespan
    );
    let first = res.finish(0);
    for id in 0..n {
        assert_eq!(res.finish(id).to_bits(), first.to_bits(), "flow {id} finished apart");
    }
}

/// N concurrent flows with unequal sizes on N *disjoint* links: the
/// flows never interact, so every start and finish must stay on the
/// fast paths and total work must scale linearly — doubling N must not
/// super-linearly grow any counter. The old engine paid a per-event
/// scan over all N active flows here (O(N²) total); this is the direct
/// guard against reintroducing that scan.
///
/// (Note the deliberate contrast with the shared-link cases above: N
/// concurrent *unequal* flows on one shared link genuinely change all N
/// rates at every completion under max-min — Θ(N) per event for any
/// engine — so linear total work can only be demanded of workloads
/// whose rate-change fan-out is bounded, like these.)
#[test]
fn work_counters_scale_linearly_on_disjoint_flows() {
    let run = |pairs: usize| {
        let mut t = Topology::new("parallel-links");
        for p in 0..pairs {
            let a = t.add_device(DeviceKind::Gpu { rank: 2 * p }, 0, format!("g{}", 2 * p));
            let b = t.add_device(DeviceKind::Gpu { rank: 2 * p + 1 }, 0, format!("g{}", 2 * p + 1));
            t.add_link(a, b, LinkClass::NvLink);
        }
        let mut sim = Sim::new(&t);
        for p in 0..pairs {
            let path = t.route_gpus(2 * p, 2 * p + 1).unwrap();
            // unequal sizes: completions stagger instead of batching
            sim.flow(path, 1.0e6 * (1 + p % 97) as f64, 1.0e-6, &[]);
        }
        let res = sim.run();
        assert_eq!(res.flows, pairs);
        assert_eq!(res.stats.full_refills, 0, "disjoint flows must not trigger refills");
        res.stats
    };
    let (a, b) = (run(400), run(800));
    let total = |s: agv_bench::sim::SimStats| {
        s.events + s.completions + s.heap_pushes + s.refill_flow_visits + s.settlements
    };
    let (wa, wb) = (total(a), total(b));
    // linear scaling => ratio ~2; a reintroduced per-event scan gives ~4
    assert!(
        wb < wa * 3,
        "work grew super-linearly: {wa} -> {wb} when N doubled"
    );
}

/// A capacity step on an **unloaded** linkdir costs the engine nothing:
/// zero refills, zero settlements, and bit-identical results — only the
/// `cap_events` counter moves (ISSUE 5: fault subsystem scaling
/// contract).
#[test]
fn capacity_change_on_unloaded_linkdir_costs_zero_refills() {
    let mut t = Topology::new("two-links");
    let g0 = t.add_device(DeviceKind::Gpu { rank: 0 }, 0, "g0");
    let g1 = t.add_device(DeviceKind::Gpu { rank: 1 }, 0, "g1");
    let g2 = t.add_device(DeviceKind::Gpu { rank: 2 }, 0, "g2");
    let busy = t.add_link(g0, g1, LinkClass::NvLink);
    let idle = t.add_link(g1, g2, LinkClass::NvLink);
    let build = |steps: bool| {
        let mut sim = Sim::new(&t);
        let mut last = None;
        for _ in 0..50 {
            let path = t.route_gpus(0, 1).unwrap();
            let deps: Vec<_> = last.into_iter().collect();
            last = Some(sim.flow(path, 1.0e8, 1.0e-6, &deps));
        }
        if steps {
            for k in 1..=20 {
                // real magnitude, but on the link no flow crosses
                sim.capacity_event(idle, k as f64 * 1.0e-4, 4.0e9);
            }
        }
        sim
    };
    let plain = build(false).run();
    let stepped = build(true).run();
    assert_eq!(stepped.stats.full_refills, 0, "idle-link steps must not refill");
    assert_eq!(stepped.stats.refill_flow_visits, 0);
    assert_eq!(stepped.stats.settlements, plain.stats.settlements);
    assert_eq!(stepped.stats.heap_pushes, plain.stats.heap_pushes);
    assert!(stepped.stats.cap_events > 0, "steps in the run window must be counted");
    assert_eq!(plain.makespan.to_bits(), stepped.makespan.to_bits());
    assert!((stepped.link_bytes(busy) - 50.0 * 1.0e8).abs() < 1.0);
    assert_eq!(stepped.link_bytes(idle), 0.0);
}

/// A serialized chain crossing K capacity steps pays exactly one full
/// refill per step (one flow visited each) — O(K), not O(K·N): the
/// chain's own starts/finishes stay on the fast paths throughout.
#[test]
fn chain_crossing_k_capacity_steps_does_ok_refills() {
    let t = one_link_topo();
    let n = 200usize;
    let k = 16usize;
    let bytes = 1.0e8;
    let base = LinkClass::NvLink.bandwidth();
    let mut sim = Sim::new(&t);
    let mut last = None;
    for _ in 0..n {
        let path = t.route_gpus(0, 1).unwrap();
        let deps: Vec<_> = last.into_iter().collect();
        last = Some(sim.flow(path, bytes, 0.0, &deps));
    }
    // K alternating degrade/restore steps spread across the chain's
    // lifetime (n * bytes/bw at full speed; degraded halves stretch it,
    // but all K land well inside the run)
    let full_span = n as f64 * bytes / base;
    for i in 0..k {
        let cap = if i % 2 == 0 { 0.5 * base } else { base };
        // the 0.37 offset keeps step instants off the completion grid
        // (a step coinciding bitwise with a completion still works, but
        // would merge two refill instants and break the == K count)
        sim.capacity_event(0, (i as f64 + 0.37) * full_span / (2 * k) as f64, cap);
    }
    let res = sim.run();
    let s = res.stats;
    assert_eq!(s.cap_events, 2 * k as u64, "K steps x 2 directions");
    // one full refill per step instant on the loaded direction; the
    // chain itself contributes none
    assert_eq!(s.full_refills, k as u64, "refills not O(K): {}", s.full_refills);
    assert!(
        s.refill_flow_visits <= 2 * k as u64,
        "refill work {} not O(K)",
        s.refill_flow_visits
    );
    assert_eq!(s.completions, n as u64);
    assert!(s.heap_pushes <= (n + 2 * k) as u64 + 8, "heap pushes {}", s.heap_pushes);
    // correctness: exact piecewise integral — degraded half-speed
    // segments cover half the schedule span
    assert_eq!(res.flows, n);
    assert!((res.link_bytes(0) - n as f64 * bytes).abs() / (n as f64 * bytes) < 1e-9);
}

/// Golden fig2 check: the OSU sweep — the paper artifact the engine
/// exists to produce — must come out the same from the event-driven
/// engine and the pre-rewrite reference core, on an NVLink system and
/// the cluster, for every library. Times to 1e-9 relative; flow counts
/// exactly.
#[test]
fn golden_fig2_cells_match_reference_engine() {
    let cfg = OsuConfig::default();
    for (sys, gpus) in [(SystemKind::Dgx1, 2usize), (SystemKind::Cluster, 8)] {
        let topo = sys.build();
        for lib in Library::all() {
            let new = run_osu(&cfg, &topo, lib, gpus);
            let old = with_reference_engine(|| run_osu(&cfg, &topo, lib, gpus));
            assert_eq!(new.len(), old.len());
            for (a, b) in new.iter().zip(&old) {
                assert_eq!(a.msg_size, b.msg_size);
                assert_eq!(
                    a.flows, b.flows,
                    "{} {} @{}: flow count diverged at {} bytes",
                    sys.name(), lib.name(), gpus, a.msg_size
                );
                // mixed tolerance: the reference core's 1e-6-byte
                // early-completion window shifts times absolutely
                let tol = 1e-11 + 1e-9 * b.time;
                assert!(
                    (a.time - b.time).abs() < tol,
                    "{} {} @{} msg {}: {} vs {}",
                    sys.name(), lib.name(), gpus, a.msg_size, a.time, b.time
                );
            }
        }
    }
}

/// Golden Table I check: the table derives from tensor profiles alone
/// (no simulation), so the rewrite must not move it at all — pin the
/// calibration bands EXPERIMENTS.md documents, and determinism of the
/// rendered artifact.
#[test]
fn golden_table1_stays_calibrated() {
    let rows = table1::rows();
    let by_name = |n: &str| rows.iter().find(|r| r.name == n).expect("dataset missing");

    let netflix = &by_name("NETFLIX").ours[0]; // 2 GPUs
    assert!(netflix.avg_mb() > 4.0 && netflix.avg_mb() < 9.0, "NETFLIX avg {}", netflix.avg_mb());
    assert!(netflix.max_mb() > 20.0 && netflix.max_mb() < 33.0, "NETFLIX max {}", netflix.max_mb());
    assert!(netflix.cv() > 1.1 && netflix.cv() < 2.2, "NETFLIX cv {}", netflix.cv());

    let amazon = &by_name("AMAZON").ours[0];
    assert!(amazon.avg_mb() > 40.0 && amazon.avg_mb() < 90.0, "AMAZON avg {}", amazon.avg_mb());
    assert!(amazon.cv() < 0.7, "AMAZON cv {}", amazon.cv());

    let delicious = &by_name("DELICIOUS").ours[0];
    assert!(
        delicious.min_mb() > 0.1 && delicious.min_mb() < 0.4,
        "DELICIOUS min {}",
        delicious.min_mb()
    );
    assert!(delicious.max_mb() > 400.0, "DELICIOUS max {}", delicious.max_mb());

    let nell = &by_name("NELL-1").ours[0];
    assert!(nell.min_mb() > 50.0 && nell.min_mb() < 80.0, "NELL-1 min {}", nell.min_mb());
    assert!(nell.max_mb() > 600.0 && nell.max_mb() < 1000.0, "NELL-1 max {}", nell.max_mb());
    assert!(nell.cv() > 0.8 && nell.cv() < 1.4, "NELL-1 cv {}", nell.cv());

    // artifact determinism: csv/render are pure functions
    assert_eq!(table1::csv(), table1::csv());
    assert_eq!(table1::render(), table1::render());
}
