//! Conformance lockdown of the collective suite (DESIGN.md §13).
//!
//! Three layers of evidence, mirroring the PR 3-5 harness style:
//!
//! 1. **Closed forms, machine-checked** over random P, ring orders,
//!    roots and irregular vectors: ring and halving/doubling allreduce
//!    move exactly 2(P−1)·Σcounts wire bytes in 2(P−1) resp.
//!    2·log2 P rounds and pass the coverage-union reduction oracle;
//!    binomial bcast takes ⌈log2 P⌉ rounds; scatter-allgather bcast
//!    ships segment s down popcount(s) scatter hops then P−1 ring hops;
//!    pairwise alltoallv delivers every off-diagonal block exactly once
//!    and never moves a diagonal block.
//! 2. **Chunking differential oracle**: `chunks = 1` through the
//!    op-generic `compose_collective` is **bit-exact** to the
//!    pre-existing unchunked Allgatherv path per library × system ×
//!    irregular vector, on both engine cores — and to a from-scratch
//!    rebuild of the staged-MPI allreduce out of the public transport
//!    primitives. Chunked (k > 1) runs beat the unchunked makespan on
//!    pipeline-friendly ring schedules.
//! 3. **Layer acceptance**: the fault layer's `perturbed_collective`
//!    with an empty perturbation set reproduces `run_collective`
//!    bit-for-bit (and a straggler slows every op), and `auto_collective`
//!    is the argmin over the three libraries.

use agv_bench::comm::algorithms::{
    all_delivered, binomial_bcast_msg, execute_allreduce, execute_from, halving_doubling_allreduce,
    pairwise_alltoallv, ring_allreduce, scatter_allgather_bcast,
};
use agv_bench::comm::collective::{
    auto_collective, run_collective, select_allreduce, CollectiveOp, CollectiveSpec, ReduceAlgo,
};
use agv_bench::comm::mpi::pt2pt_overhead;
use agv_bench::comm::transport::{dtoh, host_to_host, htod, op_completion, run_schedule, ChunkCfg};
use agv_bench::comm::{run_allgatherv, Library, Params};
use agv_bench::perturb::{perturbed_collective, Perturbation};
use agv_bench::sim::{with_reference_engine, Sim, TaskId};
use agv_bench::topology::systems::SystemKind;
use agv_bench::topology::Topology;
use agv_bench::util::prng::Rng;
use agv_bench::util::prop::{check, counts};

/// Random rank count in the acceptance range 2..=32.
fn rand_p(rng: &mut Rng) -> usize {
    2 + rng.gen_range(31) as usize
}

/// Random ring order over 0..p.
fn rand_order(rng: &mut Rng, p: usize) -> Vec<usize> {
    let mut order: Vec<usize> = (0..p).collect();
    rng.shuffle(&mut order);
    order
}

// -------------------------------------------------------------------------
// 1. Closed forms
// -------------------------------------------------------------------------

#[test]
fn ring_allreduce_closed_forms() {
    check("ring-allreduce-closed-forms", 24, |rng| {
        let p = rand_p(rng);
        let order = rand_order(rng, p);
        let segs = counts::reduce_widths(rng, p, 8 << 20);
        let total: u64 = segs.iter().sum();
        let rs = ring_allreduce(p, Some(&order));
        agv_bench::prop_assert!(rs.rounds() == 2 * (p - 1), "rounds {} != 2(P-1)", rs.rounds());
        agv_bench::prop_assert!(
            rs.wire_bytes(&segs) == 2 * (p as u64 - 1) * total,
            "wire bytes {} != 2(P-1)*total {}",
            rs.wire_bytes(&segs),
            2 * (p as u64 - 1) * total
        );
        if p <= 64 {
            agv_bench::prop_assert!(execute_allreduce(p, &rs), "reduction incomplete at P={p}");
        }
        Ok(())
    });
}

#[test]
fn halving_doubling_closed_forms() {
    check("halving-doubling-closed-forms", 24, |rng| {
        let p = 1 << (1 + rng.gen_range(5)); // 2, 4, ..., 32
        let segs = counts::reduce_widths(rng, p, 8 << 20);
        let total: u64 = segs.iter().sum();
        let rs = halving_doubling_allreduce(p);
        let log2p = p.trailing_zeros() as usize;
        agv_bench::prop_assert!(rs.rounds() == 2 * log2p, "rounds {} != 2 log2 P", rs.rounds());
        agv_bench::prop_assert!(
            rs.wire_bytes(&segs) == 2 * (p as u64 - 1) * total,
            "wire bytes off the 2(P-1)*total closed form"
        );
        agv_bench::prop_assert!(execute_allreduce(p, &rs), "reduction incomplete at P={p}");
        Ok(())
    });
}

#[test]
fn bcast_closed_forms() {
    check("bcast-closed-forms", 24, |rng| {
        let p = rand_p(rng);
        let root = rng.gen_range(p as u64) as usize;
        let segs = counts::reduce_widths(rng, p, 8 << 20);
        let total: u64 = segs.iter().sum();
        let log2p = (usize::BITS - (p - 1).leading_zeros()) as usize; // ceil(log2 p)

        // binomial: ceil(log2 P) rounds, the whole message on P-1 edges
        let bin = binomial_bcast_msg(p, root, p);
        agv_bench::prop_assert!(bin.steps.len() == log2p, "binomial rounds {}", bin.steps.len());
        agv_bench::prop_assert!(
            bin.wire_bytes(&segs) == (p as u64 - 1) * total,
            "binomial wire bytes {} != (P-1)*total",
            bin.wire_bytes(&segs)
        );

        // scatter-allgather: segment s crosses popcount(s) scatter hops
        // (its binomial-tree depth in relative-rank space) + P-1 ring hops
        let sag = scatter_allgather_bcast(p, root);
        agv_bench::prop_assert!(
            sag.rounds() == log2p + (p - 1),
            "SAG rounds {} != ceil(log2 P) + P-1",
            sag.rounds()
        );
        let scatter_xfers = sag.scatter.block_transfer_counts(p);
        for (s, &n) in scatter_xfers.iter().enumerate() {
            agv_bench::prop_assert!(
                n == s.count_ones() as usize,
                "segment {s}: {n} scatter transfers != popcount {}",
                s.count_ones()
            );
        }
        let gather_xfers = sag.gather.block_transfer_counts(p);
        agv_bench::prop_assert!(
            gather_xfers.iter().all(|&n| n == p - 1),
            "SAG gather is not a full ring allgather"
        );

        // delivery: root-only initial holdings reach everyone
        let mut init = vec![vec![false; p]; p];
        init[root] = vec![true; p];
        agv_bench::prop_assert!(
            all_delivered(&execute_from(p, p, &init, &[&bin])),
            "binomial bcast lost a segment"
        );
        agv_bench::prop_assert!(
            all_delivered(&execute_from(p, p, &init, &sag.phases())),
            "SAG bcast lost a segment"
        );
        Ok(())
    });
}

#[test]
fn alltoallv_exact_pairwise_delivery() {
    check("alltoallv-exact-delivery", 24, |rng| {
        let p = rand_p(rng);
        let m = counts::alltoallv_matrix(rng, p, 4 << 20);
        let sched = pairwise_alltoallv(p);
        agv_bench::prop_assert!(sched.steps.len() == p - 1, "steps {}", sched.steps.len());

        // off-diagonal blocks cross exactly one wire; diagonals never move
        let xfers = sched.block_transfer_counts(p * p);
        for src in 0..p {
            for dst in 0..p {
                let expect = usize::from(src != dst);
                agv_bench::prop_assert!(
                    xfers[src * p + dst] == expect,
                    "block ({src},{dst}) moved {} times",
                    xfers[src * p + dst]
                );
            }
        }
        let off_diag: u64 = (0..p)
            .flat_map(|s| (0..p).map(move |d| (s, d)))
            .filter(|&(s, d)| s != d)
            .map(|(s, d)| m[s * p + d])
            .sum();
        agv_bench::prop_assert!(
            sched.wire_bytes(&m) == off_diag,
            "wire bytes {} != off-diagonal sum {off_diag}",
            sched.wire_bytes(&m)
        );

        // delivery: rank i starts holding row i, must end holding column i
        let init: Vec<Vec<bool>> = (0..p)
            .map(|r| (0..p * p).map(|b| b / p == r).collect())
            .collect();
        let held = execute_from(p, p * p, &init, &[&sched]);
        for dst in 0..p {
            for src in 0..p {
                agv_bench::prop_assert!(
                    held[dst][src * p + dst],
                    "rank {dst} missing its block from {src}"
                );
            }
        }
        Ok(())
    });
}

// -------------------------------------------------------------------------
// 2. The chunking differential oracle
// -------------------------------------------------------------------------

/// Per-seed irregular vectors spanning the §IV regimes.
fn vectors(rng: &mut Rng, p: usize) -> Vec<Vec<u64>> {
    vec![
        counts::regular(p, 1 + rng.gen_range(32 << 20)),
        counts::skewed(rng, p, 48 << 20),
        counts::zero_heavy(rng, p, 32 << 20),
        counts::single_hot(rng, p, 256 << 20),
    ]
}

fn assert_allgatherv_chunks1_bit_exact(topo: &Topology, lib: Library, cv: &[u64], engine: &str) {
    let spec = CollectiveSpec::Allgatherv { counts: cv.to_vec() };
    let via = run_collective(topo, lib, Params::default(), &spec, ChunkCfg::none());
    let direct = run_allgatherv(lib, topo, cv);
    assert_eq!(
        via.time.to_bits(),
        direct.time.to_bits(),
        "{engine}/{}/{}: collective layer {} != allgatherv path {} (counts {cv:?})",
        topo.name,
        lib.name(),
        via.time,
        direct.time
    );
    assert_eq!(
        via.flows, direct.flows,
        "{engine}/{}/{}: flow counts diverged",
        topo.name,
        lib.name()
    );
}

#[test]
fn chunks1_allgatherv_is_bit_exact_event_engine() {
    check("chunks1-differential-event", 12, |rng| {
        for kind in SystemKind::all() {
            let topo = kind.build();
            let p = [2, 4, kind.max_gpus().min(8)][rng.gen_range(3) as usize];
            for cv in vectors(rng, p) {
                for lib in Library::all() {
                    assert_allgatherv_chunks1_bit_exact(&topo, lib, &cv, "event");
                }
            }
        }
        Ok(())
    });
}

#[test]
fn chunks1_allgatherv_is_bit_exact_reference_engine() {
    with_reference_engine(|| {
        check("chunks1-differential-reference", 4, |rng| {
            for kind in SystemKind::all() {
                let topo = kind.build();
                let p = [2, kind.max_gpus().min(8)][rng.gen_range(2) as usize];
                for cv in vectors(rng, p) {
                    for lib in Library::all() {
                        assert_allgatherv_chunks1_bit_exact(&topo, lib, &cv, "reference");
                    }
                }
            }
            Ok(())
        });
    });
}

/// Rebuild the staged-MPI allreduce out of the *public* transport
/// primitives — the unchunked reference the op-generic path must equal.
fn mpi_allreduce_reference(topo: &Topology, segs: &[u64]) -> (f64, usize) {
    let params = Params::default();
    let p = segs.len();
    let total: u64 = segs.iter().sum();
    let rs = match select_allreduce(&params, segs) {
        ReduceAlgo::HalvingDoubling => halving_doubling_allreduce(p),
        ReduceAlgo::Ring => ring_allreduce(p, None),
    };
    let mut sim = Sim::new(topo);
    let mut markers: Vec<Option<TaskId>> =
        (0..p).map(|r| Some(dtoh(&mut sim, topo, r, total as f64, &[]))).collect();
    for phase in rs.phases() {
        markers = run_schedule(&mut sim, p, phase, &markers, |sim, op, deps| {
            let bytes = op.bytes(segs);
            let ready = sim.delay(pt2pt_overhead(&params, bytes), deps);
            host_to_host(sim, topo, &params, op.from, op.to, bytes as f64, &[ready])
        });
    }
    let tails: Vec<TaskId> = markers
        .iter()
        .enumerate()
        .map(|(r, m)| {
            let deps: Vec<TaskId> = m.iter().copied().collect();
            htod(&mut sim, topo, r, total as f64, &deps)
        })
        .collect();
    let done = op_completion(&mut sim, &tails, None);
    let res = sim.run();
    (res.finish(done), res.flows)
}

#[test]
fn chunks1_mpi_allreduce_matches_transport_rebuild() {
    check("chunks1-mpi-allreduce-rebuild", 8, |rng| {
        for kind in SystemKind::all() {
            let topo = kind.build();
            let p = [2, 4, kind.max_gpus().min(8)][rng.gen_range(3) as usize];
            let segs = counts::reduce_widths(rng, p, 16 << 20);
            let (t_ref, f_ref) = mpi_allreduce_reference(&topo, &segs);
            let spec = CollectiveSpec::Allreduce { segs: segs.clone() };
            let via =
                run_collective(&topo, Library::Mpi, Params::default(), &spec, ChunkCfg::none());
            agv_bench::prop_assert!(
                via.time.to_bits() == t_ref.to_bits(),
                "{}: op-generic {} != rebuilt {} (segs {segs:?})",
                topo.name,
                via.time,
                t_ref
            );
            agv_bench::prop_assert!(via.flows == f_ref, "flow counts diverged on {}", topo.name);
        }
        Ok(())
    });
}

#[test]
fn chunked_pipelines_beat_unchunked_on_rings() {
    // pipeline-friendly shape: large regular segments, ring schedules,
    // chunk sizes that stay inside one protocol class (8 MB / 4 = 2 MB
    // chunks, above the 1 MB large-message switch and the eager limit)
    let topo = SystemKind::Dgx1.build();
    let params = Params::default();
    for (lib, op) in [
        (Library::Nccl, CollectiveOp::Allreduce),
        (Library::MpiCuda, CollectiveOp::Allreduce),
        (Library::Nccl, CollectiveOp::Bcast),
    ] {
        let spec = CollectiveSpec::from_vector(op, &[8 << 20; 4]);
        let plain = run_collective(&topo, lib, params, &spec, ChunkCfg::none());
        let piped = run_collective(&topo, lib, params, &spec, ChunkCfg::pipelined(4));
        assert!(
            piped.time < 0.999 * plain.time,
            "{}/{}: chunked {} not faster than unchunked {}",
            lib.name(),
            op.name(),
            piped.time,
            plain.time
        );
        assert!(piped.flows > plain.flows, "chunking emitted no extra wire flows");
    }
}

#[test]
fn chunked_collectives_agree_across_engines() {
    // contended schedules: the two cores agree to ~1e-9 relative on the
    // chunked DAGs, same as every pre-existing cross-engine check
    let topo = SystemKind::Dgx1.build();
    let params = Params::default();
    for op in CollectiveOp::all() {
        let spec = CollectiveSpec::from_vector(op, &[3 << 20, 9 << 20, 1 << 16, 5 << 20]);
        for lib in Library::all() {
            let event = run_collective(&topo, lib, params, &spec, ChunkCfg::pipelined(3));
            let refr = with_reference_engine(|| {
                run_collective(&topo, lib, params, &spec, ChunkCfg::pipelined(3))
            });
            let rel = (event.time - refr.time).abs() / event.time.max(1e-30);
            assert!(
                rel < 1e-9,
                "{}/{}: engines diverged {} vs {} (rel {rel})",
                op.name(),
                lib.name(),
                event.time,
                refr.time
            );
            assert_eq!(event.flows, refr.flows, "{}/{}", op.name(), lib.name());
        }
    }
}

#[test]
fn zero_heavy_and_all_zero_vectors_stay_finite() {
    // satellite regression: zero-byte blocks ride the staged paths for
    // free (no 3-leg latency, no handshake) and nothing divides by zero
    let params = Params::default();
    check("zero-count-collectives", 6, |rng| {
        for kind in SystemKind::all() {
            let topo = kind.build();
            let p = kind.max_gpus().min(8);
            let mut zh = counts::zero_heavy(rng, p, 16 << 20);
            zh[0] = 0; // rank 0 always empty
            for cv in [zh, vec![0; p]] {
                for op in CollectiveOp::all() {
                    let spec = CollectiveSpec::from_vector(op, &cv);
                    for lib in Library::all() {
                        let r = run_collective(&topo, lib, params, &spec, ChunkCfg::none());
                        agv_bench::prop_assert!(
                            r.time.is_finite() && r.time >= 0.0,
                            "{}/{}/{}: bad time {}",
                            kind.name(),
                            op.name(),
                            lib.name(),
                            r.time
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

// -------------------------------------------------------------------------
// 3. Layer acceptance: faults and auto-selection
// -------------------------------------------------------------------------

#[test]
fn perturbed_collective_empty_set_is_bit_exact() {
    let topo = SystemKind::Dgx1.build();
    let params = Params::default();
    for op in CollectiveOp::all() {
        let spec = CollectiveSpec::from_vector(op, &[2 << 20, 7 << 20, 1 << 12, 4 << 20]);
        for lib in Library::all() {
            for chunk in [ChunkCfg::none(), ChunkCfg::pipelined(4)] {
                let clean = run_collective(&topo, lib, params, &spec, chunk);
                let pert = perturbed_collective(&topo, lib, params, &spec, chunk, &[]);
                assert_eq!(
                    pert.time.to_bits(),
                    clean.time.to_bits(),
                    "{}/{}: empty perturbation set changed the result",
                    op.name(),
                    lib.name()
                );
                assert_eq!(pert.flows, clean.flows);
            }
        }
    }
}

#[test]
fn straggler_slows_every_collective() {
    let topo = SystemKind::Dgx1.build();
    let params = Params::default();
    let straggler = [Perturbation::straggler(0, 0.25)];
    for op in CollectiveOp::all() {
        let spec = CollectiveSpec::from_vector(op, &[8 << 20; 4]);
        for lib in Library::all() {
            let clean = run_collective(&topo, lib, params, &spec, ChunkCfg::none());
            let slow =
                perturbed_collective(&topo, lib, params, &spec, ChunkCfg::none(), &straggler);
            assert!(
                slow.time > clean.time,
                "{}/{}: straggler left no trace ({} vs {})",
                op.name(),
                lib.name(),
                slow.time,
                clean.time
            );
        }
    }
}

#[test]
fn auto_collective_argmin_on_every_system() {
    let params = Params::default();
    for kind in SystemKind::all() {
        let topo = kind.build();
        let p = kind.max_gpus().min(8);
        for op in CollectiveOp::all() {
            let spec = CollectiveSpec::from_vector(op, &vec![4 << 20; p]);
            let (winner, best) = auto_collective(&topo, params, &spec, ChunkCfg::none());
            for lib in Library::all() {
                let r = run_collective(&topo, lib, params, &spec, ChunkCfg::none());
                assert!(
                    best.time <= r.time,
                    "{}/{}: auto {} lost to {}",
                    kind.name(),
                    op.name(),
                    winner.name(),
                    lib.name()
                );
            }
        }
    }
}
