//! Differential lockdown of the workload engine against the single-op
//! path: a 1-tenant, 1-op workload with zero arrival offset must build
//! the task-for-task identical DAG as `comm::run_allgatherv` and
//! therefore reproduce its `CommResult` **bit-exactly** — per library,
//! per system, per irregular count vector, on both the event-driven
//! and reference engines. This is what licenses every contended result
//! the engine reports: the units under contention are exactly the
//! models the paper experiments validated.

use agv_bench::comm::select::auto_allgatherv;
use agv_bench::comm::{run_allgatherv, Library, Params};
use agv_bench::sim::with_reference_engine;
use agv_bench::topology::systems::SystemKind;
use agv_bench::topology::Topology;
use agv_bench::util::prng::Rng;
use agv_bench::util::prop::{check, counts};
use agv_bench::workload::{run_workload, TenantLib, WorkloadSpec};

/// Per-seed irregular vectors spanning the §IV regimes.
fn vectors(rng: &mut Rng, p: usize) -> Vec<Vec<u64>> {
    vec![
        counts::regular(p, 1 + rng.gen_range(32 << 20)),
        counts::skewed(rng, p, 48 << 20),
        counts::zero_heavy(rng, p, 32 << 20),
        counts::single_hot(rng, p, 256 << 20),
    ]
}

fn assert_single_op_matches(topo: &Topology, lib: Library, cv: &[u64], engine: &str) {
    let spec = WorkloadSpec::single_op(TenantLib::Fixed(lib), cv.to_vec(), 7);
    let w = run_workload(topo, &spec, Params::default()).expect("spec valid");
    let solo = run_allgatherv(lib, topo, cv);
    let op = &w.tenants[0].ops[0];
    assert_eq!(
        op.finish.to_bits(),
        solo.time.to_bits(),
        "{engine}/{}/{}: workload {} != isolated {} (counts {cv:?})",
        topo.name,
        lib.name(),
        op.finish,
        solo.time
    );
    assert_eq!(op.arrival.to_bits(), 0f64.to_bits());
    assert_eq!(
        op.flows, solo.flows,
        "{engine}/{}/{}: flow counts diverged",
        topo.name,
        lib.name()
    );
    assert_eq!(w.flows, solo.flows);
}

#[test]
fn one_tenant_one_op_is_bit_exact_event_engine() {
    check("workload-differential-event", 12, |rng| {
        for kind in SystemKind::all() {
            let topo = kind.build();
            let p = [2, 4, kind.max_gpus().min(8)][rng.gen_range(3) as usize];
            for cv in vectors(rng, p) {
                for lib in Library::all() {
                    assert_single_op_matches(&topo, lib, &cv, "event");
                }
            }
        }
        Ok(())
    });
}

#[test]
fn one_tenant_one_op_is_bit_exact_reference_engine() {
    // fewer cases: the reference core is O(F^2) by design
    check("workload-differential-reference", 4, |rng| {
        for kind in SystemKind::all() {
            let topo = kind.build();
            let p = [2, kind.max_gpus().min(8)][rng.gen_range(2) as usize];
            for cv in vectors(rng, p) {
                for lib in Library::all() {
                    with_reference_engine(|| {
                        assert_single_op_matches(&topo, lib, &cv, "reference")
                    });
                }
            }
        }
        Ok(())
    });
}

#[test]
fn one_tenant_one_op_auto_matches_selector() {
    // the auto tenant path freezes the selector's candidate at plan
    // time and composes it gate-less: same DAG, same argmin time
    check("workload-differential-auto", 6, |rng| {
        for kind in SystemKind::all() {
            let topo = kind.build();
            let cv = counts::irregular(rng, 4, 16 << 20);
            let spec = WorkloadSpec::single_op(TenantLib::Auto, cv.clone(), 7);
            let w = run_workload(&topo, &spec, Params::default()).expect("spec valid");
            let sel = auto_allgatherv(&topo, &cv);
            let op = &w.tenants[0].ops[0];
            assert_eq!(
                op.finish.to_bits(),
                sel.time.to_bits(),
                "{}: workload-auto {} != selector {} ({})",
                topo.name,
                op.finish,
                sel.time,
                sel.candidate.label()
            );
            assert_eq!(op.label, sel.candidate.label());
            assert_eq!(op.flows, sel.flows);
        }
        Ok(())
    });
}

#[test]
fn engines_agree_on_a_contended_workload() {
    // same multi-tenant spec through both cores: agreement to the
    // engines' documented ~1e-9 relative contract (not bit-exact:
    // settlement order differs)
    let topo = SystemKind::CsStorm.build();
    let spec = WorkloadSpec::synthetic(
        3,
        2,
        8,
        TenantLib::Fixed(Library::MpiCuda),
        8 << 20,
        21,
    );
    let event = run_workload(&topo, &spec, Params::default()).unwrap();
    let refr =
        with_reference_engine(|| run_workload(&topo, &spec, Params::default()).unwrap());
    assert_eq!(event.flows, refr.flows);
    let rel = (event.makespan - refr.makespan).abs() / refr.makespan;
    assert!(rel < 1e-9, "makespans diverged: {} vs {}", event.makespan, refr.makespan);
    for (a, b) in event.tenants.iter().zip(&refr.tenants) {
        for (x, y) in a.ops.iter().zip(&b.ops) {
            assert!(
                (x.finish - y.finish).abs() < 1e-11 + 1e-9 * y.finish.abs(),
                "tenant {} op {}: {} vs {}",
                x.tenant, x.index, x.finish, y.finish
            );
        }
    }
    let drel = (event.total_bytes - refr.total_bytes).abs() / refr.total_bytes;
    assert!(drel < 1e-6, "bytes diverged: {} vs {}", event.total_bytes, refr.total_bytes);
}
