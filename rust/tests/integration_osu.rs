//! Integration: Fig. 2 qualitative shape assertions (paper §V-B).
//!
//! We assert orderings, crossovers and factor *bands*, never absolute
//! times — our substrate is a flow-level simulator, not the authors'
//! testbed (DESIGN.md §2).

use std::sync::LazyLock;

use agv_bench::comm::Library::{Mpi, MpiCuda, Nccl};
use agv_bench::osu::{fig2_grid, Fig2Cell, OsuConfig};
use agv_bench::topology::systems::SystemKind;

static GRID: LazyLock<Vec<Fig2Cell>> = LazyLock::new(|| fig2_grid(&OsuConfig::default()));

fn cell(system: SystemKind, gpus: usize) -> &'static Fig2Cell {
    GRID.iter()
        .find(|c| c.system == system && c.gpus == gpus)
        .unwrap()
}

#[test]
fn nvlink_systems_2gpu_large_messages_cuda_and_nccl_beat_mpi() {
    // "On the DGX-1 and CS-Storm for messages larger than 16KB, both NCCL
    // and MPI-CUDA outperform traditional MPI by a significant margin"
    for sys in [SystemKind::Dgx1, SystemKind::CsStorm] {
        let c = cell(sys, 2);
        for p in c.points(Mpi) {
            if p.msg_size > 64 << 10 {
                let cuda = c.ratio_at(Mpi, MpiCuda, p.msg_size);
                let nccl = c.ratio_at(Mpi, Nccl, p.msg_size);
                assert!(cuda > 1.5, "{} @{}: MPI/MPI-CUDA {cuda}", sys.name(), p.msg_size);
                assert!(nccl > 1.5, "{} @{}: MPI/NCCL {nccl}", sys.name(), p.msg_size);
            }
        }
    }
}

#[test]
fn cs_storm_2gpu_gap_larger_than_dgx1() {
    // "The difference is much greater on the CS-Storm since there is a
    // bonded set of 4 NVLink connections"
    let m = 32 << 20;
    let dgx = cell(SystemKind::Dgx1, 2).ratio_at(Mpi, MpiCuda, m);
    let storm = cell(SystemKind::CsStorm, 2).ratio_at(Mpi, MpiCuda, m);
    assert!(storm > dgx, "storm {storm} !> dgx {dgx}");
}

#[test]
fn cluster_2gpu_modest_gain_capped() {
    // "On the cluster ... by a much smaller factor ... at most a 2.5x
    // improvement over MPI"
    let c = cell(SystemKind::Cluster, 2);
    for p in c.points(Mpi) {
        if p.msg_size >= 1 << 20 {
            let gain = c.ratio_at(Mpi, MpiCuda, p.msg_size);
            assert!(gain < 3.5, "@{}: gain {gain}", p.msg_size);
        }
    }
}

#[test]
fn dgx1_8gpu_nccl_wins_above_crossover_loses_below() {
    // "NCCL provides faster runtimes over MPI-CUDA for messages larger
    // than 64KB" (8 GPUs, DGX-1) — and the reverse at small sizes.
    let c = cell(SystemKind::Dgx1, 8);
    let large = c.ratio_at(MpiCuda, Nccl, 16 << 20);
    assert!(large > 1.0, "NCCL not winning at 16MB: {large}");
    let small = c.ratio_at(MpiCuda, Nccl, 4 << 10);
    assert!(small < 1.0, "NCCL unexpectedly winning at 4KB: {small}");
}

#[test]
fn cs_storm_8gpu_nccl_advantage_smaller_than_dgx1() {
    // "On the CS-Storm ... NCCL also provides better performance over
    // MPI-CUDA [for large sizes] ... not as significant as on the DGX-1.
    // Only pairs are connected via NVLink."
    let m = 16 << 20;
    let dgx = cell(SystemKind::Dgx1, 8).ratio_at(MpiCuda, Nccl, m);
    let storm = cell(SystemKind::CsStorm, 8).ratio_at(MpiCuda, Nccl, m);
    assert!(dgx > storm, "dgx {dgx} !> storm {storm}");
}

#[test]
fn mpicuda_protocol_drop_at_1mb_all_systems() {
    // "sudden decrease in runtime for MPI-CUDA across the systems once
    // the message sizes reach 1MB"
    for sys in SystemKind::all() {
        let c = cell(sys, 2);
        let pts = c.points(MpiCuda);
        let below = pts.iter().find(|p| p.msg_size == 512 << 10).unwrap();
        let at = pts.iter().find(|p| p.msg_size == 1 << 20).unwrap();
        // doubling the size should NOT double the time across the switch;
        // per-byte cost must drop sharply
        let per_below = below.time / below.msg_size as f64;
        let per_at = at.time / at.msg_size as f64;
        assert!(
            per_at < 0.8 * per_below,
            "{}: no drop ({per_below:.3e} -> {per_at:.3e})",
            sys.name()
        );
    }
}

#[test]
fn cluster_16gpu_beats_cs_storm_16gpu_for_mpi() {
    // "the runtime of the MPI libraries on the cluster when using 16
    // GPUs are as much as 4.5x faster than the CS-Storm" (shared PCIe)
    let m = 16 << 20;
    let clu = cell(SystemKind::Cluster, 16);
    let storm = cell(SystemKind::CsStorm, 16);
    let t_clu = clu.points(Mpi).iter().find(|p| p.msg_size == m).unwrap().time;
    let t_storm = storm.points(Mpi).iter().find(|p| p.msg_size == m).unwrap().time;
    assert!(
        t_storm > t_clu,
        "storm {t_storm} !> cluster {t_clu} (PCIe contention missing)"
    );
}

#[test]
fn dgx1_vs_cluster_nccl_8gpu_headline() {
    // §VI: "as much as a 8.3x difference ... between the DGX-1 and
    // cluster when using NCCL on the OSU benchmark"
    let dgx = cell(SystemKind::Dgx1, 8);
    let clu = cell(SystemKind::Cluster, 8);
    let max_ratio = dgx
        .points(Nccl)
        .iter()
        .zip(clu.points(Nccl))
        .map(|(d, c)| c.time / d.time)
        .fold(0.0f64, f64::max);
    assert!(max_ratio > 2.5, "DGX-1 advantage only {max_ratio}x");
}

#[test]
fn times_monotone_in_message_size() {
    use agv_bench::comm::Library;
    for c in GRID.iter() {
        for (lib, pts) in &c.series {
            for w in pts.windows(2) {
                // Exemption: MPI-CUDA's absolute time *drops* when the
                // message size crosses the 1 MB protocol switch — that is
                // the paper's §V-B observation, not a bug.
                if *lib == Library::MpiCuda && w[1].msg_size == 1 << 20 {
                    continue;
                }
                assert!(
                    w[1].time > w[0].time * 0.95,
                    "{} {} {}: non-monotone {} -> {}",
                    c.system.name(), c.gpus, lib.name(),
                    w[0].msg_size, w[1].msg_size
                );
            }
        }
    }
}
