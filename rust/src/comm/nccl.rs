//! NCCL model (paper §II-B): topology-detected rings, chunk-pipelined
//! ring broadcast, and the paper's Listing-1 Allgatherv built from a
//! series of `ncclBcast` calls (NCCL 2.0.5 has no native Allgatherv).
//!
//! The two properties that drive NCCL's behaviour in the paper:
//! 1. ring construction is NOT gated on GPUDirect P2P — NCCL happily
//!    routes over two NVLink hops on the DGX-1 (so all 8 GPUs talk over
//!    NVLink while MVAPICH falls back to PCIe for non-P2P pairs);
//! 2. the bcast-series Allgatherv serializes P stream launches (latency
//!    cost at small sizes) but each broadcast is chunk-pipelined around
//!    the ring (bandwidth cost ~ bytes/bw instead of a per-step barrier),
//!    which is exactly what wins on irregular workloads.

use crate::sim::{Sim, TaskId};
use crate::topology::Topology;

use super::{CommLibrary, CommResult, Params};

/// NCCL model: topology-detected ring + chunk-pipelined bcast series.
pub struct Nccl {
    params: Params,
}

impl Nccl {
    /// Build the model with the given protocol parameters.
    pub fn new(params: Params) -> Nccl {
        Nccl { params }
    }
}

/// NCCL topology detection: order the participating GPUs into a ring
/// that maximizes NVLink usage. Tries a Hamiltonian cycle in the NVLink
/// subgraph first (backtracking; P <= 16 and NVLink degree <= 4 keep this
/// trivial); falls back to a greedy chain preferring NVLink neighbors and
/// splicing in NVLink-isolated GPUs over PCIe.
pub fn detect_ring(topo: &Topology, p: usize) -> Vec<usize> {
    assert!(p >= 1 && p <= topo.num_gpus());
    if p == 1 {
        return vec![0];
    }
    // NVLink adjacency among ranks 0..p
    let nv = |a: usize, b: usize| topo.nvlink_direct(a, b);

    // Backtracking Hamiltonian cycle in the NVLink subgraph.
    fn ham(
        nvadj: &Vec<Vec<bool>>,
        path: &mut Vec<usize>,
        used: &mut Vec<bool>,
        p: usize,
    ) -> bool {
        if path.len() == p {
            return nvadj[*path.last().unwrap()][path[0]];
        }
        let cur = *path.last().unwrap();
        for next in 0..p {
            if !used[next] && nvadj[cur][next] {
                used[next] = true;
                path.push(next);
                if ham(nvadj, path, used, p) {
                    return true;
                }
                path.pop();
                used[next] = false;
            }
        }
        false
    }

    let nvadj: Vec<Vec<bool>> = (0..p)
        .map(|a| (0..p).map(|b| a != b && nv(a, b)).collect())
        .collect();
    let mut path = vec![0usize];
    let mut used = vec![false; p];
    used[0] = true;
    if ham(&nvadj, &mut path, &mut used, p) {
        return path;
    }

    // Greedy: follow NVLink edges where possible, lowest index otherwise.
    let mut ring = vec![0usize];
    let mut taken = vec![false; p];
    taken[0] = true;
    while ring.len() < p {
        let cur = *ring.last().unwrap();
        let next_nv = (0..p).find(|&n| !taken[n] && nvadj[cur][n]);
        let next = next_nv.unwrap_or_else(|| (0..p).find(|&n| !taken[n]).unwrap());
        taken[next] = true;
        ring.push(next);
    }
    ring
}

/// Per-hop transfer description for a ring neighbor pair.
struct Hop {
    path: crate::topology::Path,
    latency: f64,
    /// serial per-byte penalty when the wire is faster than what one NCCL
    /// ring can drive (bonded NVLink, inter-node proxy path)
    penalty_per_byte: f64,
    /// extra per-chunk overhead (net proxy on inter-node hops)
    chunk_overhead: f64,
}

impl Nccl {
    fn hop(&self, topo: &Topology, from: usize, to: usize) -> Hop {
        let p = &self.params;
        // NCCL prefers an all-NVLink route even over multiple hops.
        let (path, target_bw) = if let Some(nvp) = topo.route_nvlink_only(from, to) {
            (nvp, p.nccl_ring_link_bw)
        } else if topo.same_node(from, to) {
            let path = topo.route_gpus(from, to).expect("routable");
            let bw = topo.path_bandwidth(&path);
            (path, bw)
        } else {
            let path = topo.route_gpus(from, to).expect("routable");
            (path, p.nccl_internode_bw)
        };
        let wire_bw = topo.path_bandwidth(&path);
        let latency = topo.path_latency(&path);
        let penalty = (1.0 / target_bw - 1.0 / wire_bw).max(0.0);
        let chunk_overhead = if topo.same_node(from, to) {
            0.0
        } else {
            p.nccl_proxy_overhead
        };
        Hop { path, latency, penalty_per_byte: penalty, chunk_overhead }
    }

    /// Chunk-pipelined ring broadcast of `bytes` from `root`; returns the
    /// task completing the broadcast (all ranks received).
    fn ring_bcast(
        &self,
        sim: &mut Sim,
        topo: &Topology,
        ring: &[usize],
        root: usize,
        bytes: u64,
        entry: TaskId,
    ) -> TaskId {
        let p = ring.len();
        let params = &self.params;
        if p == 1 || bytes == 0 {
            return entry;
        }
        let root_pos = ring.iter().position(|&r| r == root).unwrap();
        // hop h: ring[root_pos+h] -> ring[root_pos+h+1]
        let hops: Vec<Hop> = (0..p - 1)
            .map(|h| {
                let from = ring[(root_pos + h) % p];
                let to = ring[(root_pos + h + 1) % p];
                self.hop(topo, from, to)
            })
            .collect();
        // NCCL-style adaptive slicing: pick the chunk count minimizing
        // (n + hops - 1) x (B/(n bw) + per-chunk overhead) — enough
        // slices to fill the ring pipeline, not so many that per-chunk
        // overheads dominate. n* = sqrt((hops-1) B / (bw ov)).
        let hop0 = &hops[0];
        let bw_est = self.params.nccl_ring_link_bw.min(
            topo.path_bandwidth(&hop0.path)
                / (1.0 + hop0.penalty_per_byte * topo.path_bandwidth(&hop0.path)),
        );
        let ov = hop0.latency + hop0.chunk_overhead + 1.0e-6;
        let ideal = (((p as f64 - 2.0).max(0.0) * bytes as f64) / (bw_est * ov))
            .sqrt()
            .round() as u64;
        let n_chunks = ideal
            .clamp(
                (bytes as f64 / params.nccl_chunk as f64).ceil() as u64,
                (bytes / params.nccl_min_chunk.max(1)).max(1),
            )
            .max(1) as usize;
        let per = bytes as f64 / n_chunks as f64;
        // grid[h]: completion of the previous chunk on hop h
        let mut prev_chunk: Vec<Option<TaskId>> = vec![None; p - 1];
        let mut last = entry;
        for _c in 0..n_chunks {
            let mut upstream: Option<TaskId> = None;
            for (h, hop) in hops.iter().enumerate() {
                let mut deps: Vec<TaskId> = Vec::new();
                match upstream {
                    Some(t) => deps.push(t),      // chunk arrived from hop h-1
                    None => deps.push(entry),     // root injects after launch
                }
                if let Some(t) = prev_chunk[h] {
                    deps.push(t); // hop serializes its own chunks
                }
                let lat = hop.latency + hop.chunk_overhead;
                let flow = sim.flow(hop.path.clone(), per, lat, &deps);
                let done = if hop.penalty_per_byte > 0.0 {
                    sim.delay(per * hop.penalty_per_byte, &[flow])
                } else {
                    flow
                };
                prev_chunk[h] = Some(done);
                upstream = Some(done);
                last = done;
            }
        }
        last
    }
}

impl Nccl {
    /// Compose an arbitrary multi-phase collective over the NCCL kernel
    /// transport (DESIGN.md §13): one launch overhead for the whole
    /// collective, then every logical send rides the NVLink-preferring
    /// hop route with the single-ring drive penalty and the inter-node
    /// proxy overhead per chunk. Chunking comes from the caller's
    /// [`ChunkCfg`] — for ring-shaped phase schedules it *is* NCCL's
    /// pipelining, made explicit at the schedule layer instead of the
    /// adaptive slicing [`Nccl::compose`] applies to its native
    /// bcast series.
    pub fn compose_phases(
        &self,
        sim: &mut Sim,
        p: usize,
        blocks: &[u64],
        phases: &[&super::algorithms::Schedule],
        chunk: super::transport::ChunkCfg,
        gate: Option<TaskId>,
    ) -> TaskId {
        use super::transport::{chunk_bytes, op_completion, run_schedule_chunked};
        let topo = sim.topology();
        assert!(p >= 1 && p <= topo.num_gpus());
        let gate_deps: Vec<TaskId> = gate.into_iter().collect();
        let launch = sim.delay(self.params.nccl_launch_overhead, &gate_deps);
        let mut markers = vec![Some(launch); p];
        for phase in phases {
            markers = run_schedule_chunked(sim, p, phase, &markers, chunk, |sim, op, j, k, deps| {
                let bytes = chunk_bytes(op.bytes(blocks), k, j) as f64;
                let hop = self.hop(topo, op.from, op.to);
                let lat = hop.latency + hop.chunk_overhead;
                let flow = sim.flow(hop.path, bytes, lat, deps);
                if hop.penalty_per_byte > 0.0 {
                    sim.delay(bytes * hop.penalty_per_byte, &[flow])
                } else {
                    flow
                }
            });
        }
        let tails: Vec<TaskId> = markers.iter().filter_map(|&f| f).collect();
        op_completion(sim, &tails, Some(launch))
    }

    /// Compose the Listing-1 bcast-series Allgatherv into a shared
    /// simulation, starting only after `gate` completes (`None` =
    /// immediately at t=0). Returns the task finishing the last
    /// broadcast (the bcasts serialize on one stream, so it is the
    /// op's completion) — the workload engine's schedule-reuse entry.
    pub fn compose(&self, sim: &mut Sim, counts: &[u64], gate: Option<TaskId>) -> TaskId {
        let topo = sim.topology();
        let p = counts.len();
        assert!(p >= 1 && p <= topo.num_gpus());
        let ring = detect_ring(topo, p);
        let mut tail: Option<TaskId> = gate;
        for root in 0..p {
            let deps: Vec<TaskId> = tail.into_iter().collect();
            let launch = sim.delay(self.params.nccl_launch_overhead, &deps);
            let done = self.ring_bcast(sim, topo, &ring, root, counts[root], launch);
            tail = Some(done);
        }
        tail.expect("p >= 1, so at least one bcast launch exists")
    }
}

impl CommLibrary for Nccl {
    fn name(&self) -> &'static str {
        "NCCL"
    }

    /// Paper Listing 1: `for g in 0..P { ncclBcast(root = g) }`, all on
    /// one stream — the broadcasts serialize, each paying a launch
    /// overhead; rdispls/recvcounts place each block, so irregular counts
    /// are natural.
    fn allgatherv(&self, topo: &Topology, counts: &[u64]) -> CommResult {
        let mut sim = Sim::new(topo);
        let done = self.compose(&mut sim, counts, None);
        let res = sim.run();
        CommResult { time: res.finish(done), flows: res.flows }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::mpi_cuda::MpiCuda;
    use crate::topology::systems::{cluster, cs_storm, dgx1};

    #[test]
    fn dgx1_ring_is_all_nvlink() {
        let t = dgx1();
        let ring = detect_ring(&t, 8);
        assert_eq!(ring.len(), 8);
        for i in 0..8 {
            let a = ring[i];
            let b = ring[(i + 1) % 8];
            assert!(t.nvlink_direct(a, b), "hop {a}->{b} not NVLink");
        }
    }

    #[test]
    fn cs_storm_ring_uses_pair_links() {
        let t = cs_storm();
        let ring = detect_ring(&t, 16);
        assert_eq!(ring.len(), 16);
        // every bonded pair should be adjacent in the ring (greedy takes
        // the NVLink neighbor first)
        for pair in 0..8 {
            let a = 2 * pair;
            let b = 2 * pair + 1;
            let pa = ring.iter().position(|&r| r == a).unwrap();
            let adj = ring[(pa + 1) % 16] == b || ring[(pa + 15) % 16] == b;
            assert!(adj, "pair ({a},{b}) split in ring {ring:?}");
        }
    }

    #[test]
    fn cluster_ring_identity_order() {
        let t = cluster(8);
        let ring = detect_ring(&t, 8);
        assert_eq!(ring, vec![0, 1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn nccl_monotone_in_size() {
        let t = dgx1();
        let lib = Nccl::new(Params::default());
        let mut last = 0.0;
        for m in [4u64 << 10, 256 << 10, 4 << 20, 64 << 20] {
            let r = lib.allgatherv(&t, &[m; 8]);
            assert!(r.time > last);
            last = r.time;
        }
    }

    #[test]
    fn nccl_beats_mpicuda_on_dgx1_8gpu_large() {
        // Fig. 2 DGX-1, 8 GPUs, messages > 64 KB: NCCL wins (2-hop NVLink).
        let t = dgx1();
        let m = 16u64 << 20;
        let nccl = Nccl::new(Params::default()).allgatherv(&t, &[m; 8]);
        let cuda = MpiCuda::new(Params::default()).allgatherv(&t, &[m; 8]);
        assert!(nccl.time < cuda.time, "nccl={} mpicuda={}", nccl.time, cuda.time);
    }

    #[test]
    fn mpicuda_beats_nccl_on_dgx1_8gpu_small() {
        // ... and loses at small sizes to the P launch overheads.
        let t = dgx1();
        let m = 8u64 << 10;
        let nccl = Nccl::new(Params::default()).allgatherv(&t, &[m; 8]);
        let cuda = MpiCuda::new(Params::default()).allgatherv(&t, &[m; 8]);
        assert!(cuda.time < nccl.time, "nccl={} mpicuda={}", nccl.time, cuda.time);
    }

    #[test]
    fn mpicuda_beats_nccl_on_cs_storm_2gpu_large() {
        // Fig. 2 CS-Storm 2 GPUs: bonded 4x NVLink favors MPI-CUDA's
        // copy engines over NCCL's single ring (up to 1.5x in the paper).
        let t = cs_storm();
        let m = 64u64 << 20;
        let nccl = Nccl::new(Params::default()).allgatherv(&t, &[m, m]);
        let cuda = MpiCuda::new(Params::default()).allgatherv(&t, &[m, m]);
        assert!(cuda.time < nccl.time, "nccl={} mpicuda={}", nccl.time, cuda.time);
    }

    #[test]
    fn nccl_wins_on_irregular_with_huge_block_2gpu() {
        // Fig. 3 NELL-1-style: a block above the IPC cliff makes MPI-CUDA
        // stage through the host while NCCL pipelines over NVLink.
        let t = dgx1();
        let counts = [61u64 << 20, 700 << 20];
        let nccl = Nccl::new(Params::default()).allgatherv(&t, &counts);
        let cuda = MpiCuda::new(Params::default()).allgatherv(&t, &counts);
        assert!(
            nccl.time < cuda.time,
            "nccl={} mpicuda={}",
            nccl.time, cuda.time
        );
    }

    #[test]
    fn zero_count_blocks_are_free_ish() {
        let t = dgx1();
        let lib = Nccl::new(Params::default());
        let some = lib.allgatherv(&t, &[1 << 20, 0, 1 << 20, 0]);
        let all = lib.allgatherv(&t, &[1 << 20, 1 << 20, 1 << 20, 1 << 20]);
        assert!(some.time < all.time);
    }
}
