//! Protocol constants and tunables for the communication models.
//!
//! Values are calibrated to the mechanism literature (MVAPICH and NCCL
//! docs/papers) at the granularity the paper's analysis uses; the
//! *qualitative* trends of Figs. 2-3 must be robust to modest changes in
//! these numbers (integration tests assert shapes, not absolutes).

/// Tunable protocol parameters.
#[derive(Clone, Copy, Debug)]
pub struct Params {
    // -------- MPI point-to-point protocol (MVAPICH) --------------------
    /// Eager/rendezvous switch: sends below this use the low-latency
    /// eager path, above pay a rendezvous handshake.
    pub eager_limit: u64,
    /// Per-send overhead of an eager message (seconds).
    pub eager_overhead: f64,
    /// Per-send overhead of a rendezvous handshake (seconds).
    pub rndv_overhead: f64,
    /// MVAPICH's mid-size GPU path stages through an intermediate host
    /// buffer below this threshold; at >= this size it switches to the
    /// pipelined large-message protocol — the "sudden decrease in runtime
    /// for MPI-CUDA once the message sizes reach 1MB" of §V-B.
    pub large_msg_protocol: u64,
    /// Bandwidth of the intermediate-buffer copy the mid-size path pays.
    pub staging_copy_bw: f64,
    /// Chunk size of pipelined host-staged GPU transfers.
    pub pipeline_chunk: u64,
    /// Per-chunk handshake/progress overhead of the host-staged pipeline
    /// (each chunk is a rendezvous-managed transfer): this is what keeps
    /// MVAPICH's staged path below wire rate on large messages.
    pub pipeline_chunk_overhead: f64,

    // -------- GPUDirect RDMA (cluster inter-node only) -----------------
    /// MV2_GPUDIRECT_LIMIT: messages at or below this size go over GDR
    /// (NIC reads GPU memory directly); larger messages fall back to the
    /// pipelined host-staged path. The paper sweeps this per data set
    /// (§V-C: optimal 512MB at 2 GPUs vs 16B at 8 GPUs on DELICIOUS).
    pub gpudirect_limit: u64,
    /// Effective GDR read bandwidth (PCIe peer read to the HCA) — lower
    /// than PCIe write bandwidth; the reason large messages avoid GDR.
    pub gdr_read_bw: f64,

    // -------- plain MPI (CUDA support disabled) -------------------------
    /// cudaMemcpy D2H/H2D effective bandwidth for the explicit staging
    /// copies the application performs around the collective.
    pub explicit_copy_bw: f64,
    /// Host-to-host intra-node copy bandwidth (shared-memory transport).
    pub host_memcpy_bw: f64,

    /// Intra-node CUDA IPC cliff: P2P copies above this size fall back to
    /// the pipelined host-staged path (staging-buffer exhaustion). This
    /// is the mechanism behind the paper's Fig. 3 observation that NCCL
    /// beats MPI-CUDA at 2 GPUs on the most irregular data sets (whose
    /// max messages are huge) but not on AMAZON or the fixed-size
    /// benchmark (whose messages stay below the cliff).
    pub ipc_large_threshold: u64,
    /// Over the cliff the fallback is a *synchronous* bounce through a
    /// small staging buffer: per-chunk stream synchronization cost.
    pub ipc_fallback_sync: f64,
    /// ... with this (small) staging-buffer chunk size.
    pub ipc_fallback_chunk: u64,

    // -------- NCCL -------------------------------------------------------
    /// Per-collective-call launch overhead (kernel launch + proxy setup).
    /// The bcast-series Allgatherv (paper Listing 1) pays this P times.
    pub nccl_launch_overhead: f64,
    /// NCCL ring slice size (pipelining granularity).
    pub nccl_chunk: u64,
    /// Minimum chunk: tiny messages are not sliced further.
    pub nccl_min_chunk: u64,
    /// A single NCCL ring drives one NVLink: on bonded-4x links (CS-Storm)
    /// the ring only exploits one of the four lanes. Effective per-ring
    /// NVLink bandwidth.
    pub nccl_ring_link_bw: f64,
    /// Effective NCCL inter-node bandwidth (IB verbs + net proxy path is
    /// below wire peak).
    pub nccl_internode_bw: f64,
    /// Per-chunk proxy/progress overhead on inter-node hops.
    pub nccl_proxy_overhead: f64,

    // -------- collective algorithm selection (MVAPICH-like) -------------
    /// Per-rank data size below which the allgatherv uses the
    /// latency-optimal log-P algorithm (Bruck / recursive doubling);
    /// above it, the bandwidth-optimal ring.
    pub allgatherv_algo_switch: u64,
}

impl Default for Params {
    fn default() -> Params {
        Params {
            eager_limit: 16 << 10,       // 16 KB
            eager_overhead: 3.0e-6,
            rndv_overhead: 12.0e-6,
            large_msg_protocol: 1 << 20, // 1 MB (the §V-B drop)
            staging_copy_bw: 5.0e9,
            pipeline_chunk: 512 << 10,
            pipeline_chunk_overhead: 30.0e-6,
            gpudirect_limit: 8 << 20,    // 8 MB default; swept in §V-C
            gdr_read_bw: 3.0e9,
            explicit_copy_bw: 10.0e9,
            host_memcpy_bw: 11.0e9,
            ipc_large_threshold: 512 << 20, // 512 MB
            ipc_fallback_sync: 20.0e-6,
            ipc_fallback_chunk: 256 << 10,
            nccl_launch_overhead: 9.0e-6,
            nccl_chunk: 1 << 20,
            nccl_min_chunk: 64 << 10,
            nccl_ring_link_bw: 18.0e9,
            nccl_internode_bw: 6.0e9,
            nccl_proxy_overhead: 2.0e-6,
            allgatherv_algo_switch: 64 << 10,
        }
    }
}

impl Params {
    /// Paper §V-C: per-data-set sweep values for MV2_GPUDIRECT_LIMIT.
    pub fn with_gpudirect_limit(mut self, limit: u64) -> Params {
        self.gpudirect_limit = limit;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let p = Params::default();
        assert!(p.eager_limit < p.large_msg_protocol);
        assert!(p.eager_overhead < p.rndv_overhead);
        assert!(p.nccl_min_chunk <= p.nccl_chunk);
        assert!(p.gdr_read_bw < p.explicit_copy_bw);
    }

    #[test]
    fn gpudirect_override() {
        let p = Params::default().with_gpudirect_limit(16);
        assert_eq!(p.gpudirect_limit, 16);
    }
}
