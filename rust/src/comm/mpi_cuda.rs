//! CUDA-aware MVAPICH ("MPI-CUDA"), paper §II-A.
//!
//! Data paths per send, mirroring MVAPICH's runtime decisions:
//! - intra-node, GPUDirect P2P available (direct NVLink or same PCIe
//!   root): direct device copy. Mid-size messages (< the 1 MB large-
//!   message protocol switch) pay an intermediate staging-buffer copy —
//!   removing it at 1 MB is the sudden runtime drop of §V-B;
//! - intra-node, message above the IPC cliff: pipelined host staging
//!   even though P2P exists (staging-buffer exhaustion);
//! - intra-node, no P2P (e.g. DGX-1 GPU 0 -> 5: two NVLink hops MVAPICH
//!   cannot see, §II-B): pipelined host staging over PCIe/QPI;
//! - inter-node (cluster): GPUDirect RDMA when the message fits under
//!   `MV2_GPUDIRECT_LIMIT`, else pipelined host staging over IB.
//!
//! The collective algorithm (Bruck vs ring) is selected exactly like the
//! host MPI — on mean count — so irregular workloads can mis-select.

use crate::sim::Sim;
use crate::topology::Topology;

use super::mpi::{pt2pt_overhead, select_algorithm};
use super::transport::{
    chunk_bytes, direct_flow, gdr_send, op_completion, run_schedule, run_schedule_chunked,
    staged_pipeline, staged_serial, ChunkCfg,
};
use super::{CommLibrary, CommResult, Params};

/// CUDA-aware MVAPICH model: GPUDirect P2P/RDMA with staged fallbacks.
pub struct MpiCuda {
    params: Params,
}

impl MpiCuda {
    /// Build the model with the given protocol parameters.
    pub fn new(params: Params) -> MpiCuda {
        MpiCuda { params }
    }

    /// Compose the CUDA-aware collective into a shared simulation,
    /// starting only after `gate` completes (`None` = immediately at
    /// t=0). Returns the task finishing when every rank has received
    /// every block — the workload engine's schedule-reuse entry point.
    pub fn compose_with(
        &self,
        sim: &mut Sim,
        counts: &[u64],
        sched: &super::algorithms::Schedule,
        gate: Option<crate::sim::TaskId>,
    ) -> crate::sim::TaskId {
        let topo = sim.topology();
        let p = counts.len();
        assert!(p >= 1 && p <= topo.num_gpus());
        let entry = vec![gate; p];
        let finals = run_schedule(sim, p, sched, &entry, |sim, op, deps| {
            self.send(sim, topo, op.from, op.to, op.bytes(counts), deps)
        });
        let tails: Vec<crate::sim::TaskId> = finals.iter().filter_map(|&f| f).collect();
        op_completion(sim, &tails, gate)
    }

    /// Compose an arbitrary multi-phase collective over the CUDA-aware
    /// transport (DESIGN.md §13): each chunk of each logical send rides
    /// the same per-send data-path dispatch as
    /// [`MpiCuda::compose_with`] (P2P / staged / GDR by chunk size). At
    /// `chunk.chunks == 1` and an allgatherv phase list this builds the
    /// task-for-task identical DAG as `compose_with` — the collective
    /// layer's chunks=1 differential relies on it.
    pub fn compose_phases(
        &self,
        sim: &mut Sim,
        p: usize,
        blocks: &[u64],
        phases: &[&super::algorithms::Schedule],
        chunk: ChunkCfg,
        gate: Option<crate::sim::TaskId>,
    ) -> crate::sim::TaskId {
        let topo = sim.topology();
        assert!(p >= 1 && p <= topo.num_gpus());
        let mut markers = vec![gate; p];
        for phase in phases {
            markers = run_schedule_chunked(sim, p, phase, &markers, chunk, |sim, op, j, k, deps| {
                self.send(sim, topo, op.from, op.to, chunk_bytes(op.bytes(blocks), k, j), deps)
            });
        }
        let tails: Vec<crate::sim::TaskId> = markers.iter().filter_map(|&f| f).collect();
        op_completion(sim, &tails, gate)
    }

    /// Run the CUDA-aware collective with an explicit schedule in a
    /// fresh simulation (the auto-selection engine simulates candidate
    /// algorithms — including the hierarchical two-level ones — through
    /// this entry point); [`CommLibrary::allgatherv`] composes it with
    /// the MVAPICH mean-size selection.
    pub fn allgatherv_with(
        &self,
        topo: &Topology,
        counts: &[u64],
        sched: &super::algorithms::Schedule,
    ) -> CommResult {
        let mut sim = Sim::new(topo);
        let done = self.compose_with(&mut sim, counts, sched, None);
        let res = sim.run();
        CommResult { time: res.finish(done), flows: res.flows }
    }

    /// Emit one CUDA-aware send; returns its completion task.
    fn send(
        &self,
        sim: &mut Sim,
        topo: &Topology,
        from: usize,
        to: usize,
        bytes: u64,
        deps: &[crate::sim::TaskId],
    ) -> crate::sim::TaskId {
        let p = &self.params;
        let ready = sim.delay(pt2pt_overhead(p, bytes), deps);
        let b = bytes as f64;
        if topo.same_node(from, to) {
            if bytes > p.ipc_large_threshold {
                // IPC cliff: synchronous small-buffer staging fallback.
                staged_serial(sim, topo, p, from, to, b, &[ready])
            } else if topo.p2p_accessible(from, to) {
                if bytes > p.eager_limit && bytes < p.large_msg_protocol {
                    // mid-size path: extra staging-buffer copy then copy out
                    let copy = sim.delay(b / p.staging_copy_bw, &[ready]);
                    direct_flow(sim, topo, from, to, b, 0.0, &[copy])
                } else {
                    direct_flow(sim, topo, from, to, b, 0.0, &[ready])
                }
            } else {
                staged_pipeline(sim, topo, p, from, to, b, &[ready])
            }
        } else {
            // inter-node (cluster)
            if bytes <= p.gpudirect_limit {
                // the mid-size intermediate-buffer copy applies to the
                // GDR path too (it is a property of MVAPICH's GPU
                // point-to-point protocol, not of the wire) — its removal
                // at the 1 MB switch is visible on all three systems
                // (paper §V-B).
                let entry = if bytes > p.eager_limit && bytes < p.large_msg_protocol {
                    sim.delay(b / p.staging_copy_bw, &[ready])
                } else {
                    ready
                };
                gdr_send(sim, topo, p, from, to, b, &[entry])
            } else {
                staged_pipeline(sim, topo, p, from, to, b, &[ready])
            }
        }
    }
}

impl CommLibrary for MpiCuda {
    fn name(&self) -> &'static str {
        "MPI-CUDA"
    }

    fn allgatherv(&self, topo: &Topology, counts: &[u64]) -> CommResult {
        self.allgatherv_with(topo, counts, &select_algorithm(&self.params, counts))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::mpi::Mpi;
    use crate::topology::systems::{cluster, cs_storm, dgx1};

    #[test]
    fn beats_plain_mpi_on_nvlink_pair() {
        // Fig. 2: 2 GPUs on DGX-1/CS-Storm, messages > 16 KB: MPI-CUDA
        // outruns MPI "by a significant margin".
        for topo in [dgx1(), cs_storm()] {
            let m = 16u64 << 20;
            let cuda = MpiCuda::new(Params::default()).allgatherv(&topo, &[m, m]);
            let plain = Mpi::new(Params::default()).allgatherv(&topo, &[m, m]);
            assert!(
                plain.time > 2.0 * cuda.time,
                "{}: cuda={} plain={}",
                topo.name, cuda.time, plain.time
            );
        }
    }

    #[test]
    fn protocol_switch_drop_at_1mb() {
        // §V-B: sudden decrease in MPI-CUDA runtime at the 1 MB switch.
        let topo = dgx1();
        let lib = MpiCuda::new(Params::default());
        let below = lib.allgatherv(&topo, &[(1 << 20) - 4096; 2]);
        let above = lib.allgatherv(&topo, &[1 << 20; 2]);
        assert!(
            above.time < below.time,
            "no drop: below={} above={}",
            below.time, above.time
        );
    }

    #[test]
    fn gdr_limit_changes_cluster_time() {
        // §V-C: MV2_GPUDIRECT_LIMIT materially changes runtime.
        let topo = cluster(8);
        let counts: Vec<u64> = (0..8).map(|r| (1u64 + r) << 20).collect();
        let small = MpiCuda::new(Params::default().with_gpudirect_limit(16))
            .allgatherv(&topo, &counts);
        let large = MpiCuda::new(Params::default().with_gpudirect_limit(512 << 20))
            .allgatherv(&topo, &counts);
        let ratio = small.time.max(large.time) / small.time.min(large.time);
        assert!(ratio > 1.2, "limit insensitive: ratio={ratio}");
    }

    #[test]
    fn ipc_cliff_slows_huge_messages() {
        let topo = dgx1();
        let lib = MpiCuda::new(Params::default());
        let under = lib.allgatherv(&topo, &[400u64 << 20; 2]);
        let over = lib.allgatherv(&topo, &[600u64 << 20; 2]);
        // crossing the 512 MB cliff must cost more than pro-rata
        let per_byte_under = under.time / (400 << 20) as f64;
        let per_byte_over = over.time / (600 << 20) as f64;
        assert!(
            per_byte_over > 1.5 * per_byte_under,
            "no cliff: {per_byte_under} vs {per_byte_over}"
        );
    }

    #[test]
    fn dgx1_8gpu_slower_than_2gpu_per_byte() {
        // MPI-CUDA cannot ride 2-hop NVLink: at 8 GPUs some ring hops
        // stage through hosts, so per-byte cost rises vs the 2-GPU case.
        let topo = dgx1();
        let lib = MpiCuda::new(Params::default());
        let m = 32u64 << 20;
        let two = lib.allgatherv(&topo, &[m; 2]);
        let eight = lib.allgatherv(&topo, &[m; 8]);
        let per_two = two.time / (2.0 * m as f64);
        let per_eight = eight.time / (8.0 * m as f64);
        assert!(per_eight > per_two, "2gpu/byte={per_two} 8gpu/byte={per_eight}");
    }
}
