//! Transports: how a logical send becomes simulator flows for each
//! library's data path (paper §II).
//!
//! The building blocks are the data paths the paper describes:
//! - explicit device<->host staging copies (plain MPI, §II-A);
//! - GPUDirect P2P direct copies over NVLink/PCIe (CUDA-aware MPI);
//! - pipelined host-staged chunks when P2P is unavailable;
//! - GPUDirect RDMA to the NIC for inter-node sends (MVAPICH-GDR),
//!   gated by `MV2_GPUDIRECT_LIMIT`;
//! - host<->host transfers over shared memory / QPI / InfiniBand.

use crate::sim::{Sim, TaskId};
use crate::topology::Topology;

use super::algorithms::{Schedule, SendOp};
use super::params::Params;

/// Device-to-host copy of a GPU's buffer (cudaMemcpy D2H): a flow from
/// the GPU to its host CPU over the PCIe hierarchy — it contends with
/// everything else crossing those switches.
pub fn dtoh(sim: &mut Sim, topo: &Topology, rank: usize, bytes: f64, deps: &[TaskId]) -> TaskId {
    let gpu = topo.gpu(rank);
    let cpu = topo.host_cpu(gpu);
    let path = topo.route(gpu, cpu).expect("GPU must reach its host CPU");
    let lat = topo.path_latency(&path);
    sim.flow(path, bytes, lat, deps)
}

/// Host-to-device copy (cudaMemcpy H2D).
pub fn htod(sim: &mut Sim, topo: &Topology, rank: usize, bytes: f64, deps: &[TaskId]) -> TaskId {
    let gpu = topo.gpu(rank);
    let cpu = topo.host_cpu(gpu);
    let path = topo.route(cpu, gpu).expect("host CPU must reach its GPU");
    let lat = topo.path_latency(&path);
    sim.flow(path, bytes, lat, deps)
}

/// Host-to-host transfer between the CPUs owning two GPUs' hierarchies.
/// Same socket: a memcpy (pure delay at memory bandwidth). Otherwise a
/// flow over QPI (intra-node) or PCIe+IB (inter-node).
pub fn host_to_host(
    sim: &mut Sim,
    topo: &Topology,
    params: &Params,
    from: usize,
    to: usize,
    bytes: f64,
    deps: &[TaskId],
) -> TaskId {
    let cpu_s = topo.host_cpu(topo.gpu(from));
    let cpu_r = topo.host_cpu(topo.gpu(to));
    if cpu_s == cpu_r {
        // same root complex: shared-memory copy
        return sim.delay(bytes / params.host_memcpy_bw, deps);
    }
    let path = topo.route(cpu_s, cpu_r).expect("hosts must be routable");
    let lat = topo.path_latency(&path);
    sim.flow(path, bytes, lat, deps)
}

/// Direct GPU-to-GPU flow along the widest route (GPUDirect P2P copy, or
/// any single-flow device copy).
pub fn direct_flow(
    sim: &mut Sim,
    topo: &Topology,
    from: usize,
    to: usize,
    bytes: f64,
    extra_latency: f64,
    deps: &[TaskId],
) -> TaskId {
    let path = topo.route_gpus(from, to).expect("GPUs must be routable");
    let lat = topo.path_latency(&path) + extra_latency;
    sim.flow(path, bytes, lat, deps)
}

/// Pipelined host-staged transfer: D2H, (host-to-host), H2D in chunks of
/// `params.pipeline_chunk`, with chunk k's leg j depending on leg j-1 of
/// chunk k and leg j of chunk k-1 — the classic MVAPICH GPU pipeline.
/// Returns the completion of the last chunk's H2D.
pub fn staged_pipeline(
    sim: &mut Sim,
    topo: &Topology,
    params: &Params,
    from: usize,
    to: usize,
    bytes: f64,
    deps: &[TaskId],
) -> TaskId {
    let chunk = params.pipeline_chunk as f64;
    let n_chunks = ((bytes / chunk).ceil() as usize).max(1);
    let per = bytes / n_chunks as f64;
    let mut prev_leg1: Option<TaskId> = None;
    let mut prev_leg2: Option<TaskId> = None;
    let mut prev_leg3: Option<TaskId> = None;
    let mut last = None;
    for _ in 0..n_chunks {
        let mut d1: Vec<TaskId> = deps.to_vec();
        if let Some(t) = prev_leg1 {
            d1 = vec![t]; // sender serializes its own D2H chunks
        }
        let leg1 = dtoh(sim, topo, from, per, &d1);
        let mut d2 = vec![leg1];
        if let Some(t) = prev_leg2 {
            d2.push(t);
        }
        // per-chunk rendezvous/progress handshake before the wire leg
        let hs = sim.delay(params.pipeline_chunk_overhead, &d2);
        let leg2 = host_to_host(sim, topo, params, from, to, per, &[hs]);
        let mut d3 = vec![leg2];
        if let Some(t) = prev_leg3 {
            d3.push(t);
        }
        let leg3 = htod(sim, topo, to, per, &d3);
        prev_leg1 = Some(leg1);
        prev_leg2 = Some(leg2);
        prev_leg3 = Some(leg3);
        last = Some(leg3);
    }
    last.unwrap()
}

/// Synchronous staged bounce: the fallback past the CUDA-IPC cliff.
/// Each small chunk runs D2H -> host copy -> H2D *serially* with a stream
/// synchronization between chunks — no pipelining at all. This is what
/// makes the paper's 729 MB-class NELL-1 messages so much slower under
/// MPI-CUDA at 2 GPUs than the same volume at 8 (Fig. 3, §V-C).
pub fn staged_serial(
    sim: &mut Sim,
    topo: &Topology,
    params: &Params,
    from: usize,
    to: usize,
    bytes: f64,
    deps: &[TaskId],
) -> TaskId {
    let chunk = params.ipc_fallback_chunk as f64;
    let n_chunks = ((bytes / chunk).ceil() as usize).max(1);
    let per = bytes / n_chunks as f64;
    let mut prev: Option<TaskId> = None;
    for _ in 0..n_chunks {
        let d: Vec<TaskId> = prev.map(|t| vec![t]).unwrap_or_else(|| deps.to_vec());
        let leg1 = dtoh(sim, topo, from, per, &d);
        let leg2 = host_to_host(sim, topo, params, from, to, per, &[leg1]);
        let leg3 = htod(sim, topo, to, per, &[leg2]);
        prev = Some(sim.delay(params.ipc_fallback_sync, &[leg3]));
    }
    prev.unwrap()
}

/// GPUDirect RDMA send (cluster inter-node, size <= MV2_GPUDIRECT_LIMIT):
/// the HCA reads GPU memory directly — one flow along the full GPU->GPU
/// route plus a serial penalty modeling the reduced PCIe peer-read
/// bandwidth of GDR (the reason MVAPICH avoids GDR for large messages).
pub fn gdr_send(
    sim: &mut Sim,
    topo: &Topology,
    params: &Params,
    from: usize,
    to: usize,
    bytes: f64,
    deps: &[TaskId],
) -> TaskId {
    let path = topo.route_gpus(from, to).expect("GPUs must be routable");
    let wire_bw = topo.path_bandwidth(&path);
    let lat = topo.path_latency(&path);
    let flow = sim.flow(path, bytes, lat, deps);
    let penalty = (1.0 / params.gdr_read_bw - 1.0 / wire_bw).max(0.0) * bytes;
    if penalty > 0.0 {
        sim.delay(penalty, &[flow])
    } else {
        flow
    }
}

/// Fold a composed op's per-rank tail tasks into a single completion
/// task — the handle a dependent iteration (or a workload arrival gate)
/// waits on. A single tail is returned as-is; several are joined; an
/// empty tail set (a 1-rank schedule moves no data) degrades to the
/// gate itself or, lacking one, a zero-delay root task. Because every
/// task a composition emits is an ancestor of one of its tails, the
/// completion task finishes exactly when the op's subgraph does.
pub fn op_completion(sim: &mut Sim, tails: &[TaskId], gate: Option<TaskId>) -> TaskId {
    match tails {
        [] => gate.unwrap_or_else(|| sim.join(&[])),
        [one] => *one,
        many => sim.join(many),
    }
}

/// Run a [`Schedule`] with per-rank step barriers: a rank's step-s+1
/// operations wait on everything it sent or received in step s (blocking
/// MPI collective semantics — the reason a dominant block serializes a
/// ring but not a pipelined broadcast).
///
/// `send` emits the transport tasks for one logical op and returns the
/// completion task.
pub fn run_schedule<F>(
    sim: &mut Sim,
    p: usize,
    schedule: &Schedule,
    entry: &[Option<TaskId>],
    mut send: F,
) -> Vec<Option<TaskId>>
where
    F: FnMut(&mut Sim, &SendOp, &[TaskId]) -> TaskId,
{
    // marker[r]: task after which rank r may proceed to the next step
    let mut marker: Vec<Option<TaskId>> = vec![None; p];
    if !entry.is_empty() {
        assert_eq!(entry.len(), p, "one entry marker per rank");
        marker.copy_from_slice(entry);
    }
    for step in &schedule.steps {
        let mut step_events: Vec<(usize, TaskId)> = Vec::new();
        for op in step {
            let mut deps: Vec<TaskId> = Vec::new();
            if let Some(t) = marker[op.from] {
                deps.push(t);
            }
            if let Some(t) = marker[op.to] {
                if Some(t) != marker[op.from] {
                    deps.push(t);
                }
            }
            let done = send(sim, op, &deps);
            step_events.push((op.from, done));
            step_events.push((op.to, done));
        }
        // fold step events into per-rank markers
        for r in 0..p {
            let mut evs: Vec<TaskId> =
                step_events.iter().filter(|&&(rr, _)| rr == r).map(|&(_, t)| t).collect();
            if let Some(t) = marker[r] {
                evs.push(t);
            }
            evs.sort_unstable();
            evs.dedup();
            marker[r] = match evs.len() {
                0 => None,
                1 => Some(evs[0]),
                _ => Some(sim.join(&evs)),
            };
        }
    }
    marker
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::algorithms::ring_allgatherv;
    use crate::topology::systems::{cluster, dgx1};

    #[test]
    fn staged_pipeline_overlaps_chunks() {
        // pipelined staging should be much faster than serial 3-leg
        let t = dgx1();
        let params = Params::default();
        let bytes = 64.0 * 1024.0 * 1024.0;
        // pipelined
        let mut sim = Sim::new(&t);
        let id = staged_pipeline(&mut sim, &t, &params, 0, 5, bytes, &[]);
        let piped = sim.run().finish(id);
        // serial (one giant chunk)
        let big = Params { pipeline_chunk: u64::MAX, ..params };
        let mut sim = Sim::new(&t);
        let id = staged_pipeline(&mut sim, &t, &big, 0, 5, bytes, &[]);
        let serial = sim.run().finish(id);
        assert!(piped < 0.7 * serial, "piped={piped} serial={serial}");
    }

    #[test]
    fn host_to_host_same_socket_is_memcpy() {
        let t = dgx1();
        let params = Params::default();
        let mut sim = Sim::new(&t);
        // GPUs 0 and 2 hang off different switches but the same socket
        let id = host_to_host(&mut sim, &t, &params, 0, 2, 1.0e9, &[]);
        let time = sim.run().finish(id);
        assert!((time - 1.0e9 / params.host_memcpy_bw).abs() < 1e-9);
    }

    #[test]
    fn gdr_penalty_only_when_slower_than_wire() {
        let t = cluster(2);
        let params = Params::default();
        let bytes = 8.0e6;
        let mut sim = Sim::new(&t);
        let id = gdr_send(&mut sim, &t, &params, 0, 1, bytes, &[]);
        let time = sim.run().finish(id);
        // serial time must be ~ bytes / gdr_read_bw (3 GB/s < IB 6.2)
        let expect = bytes / params.gdr_read_bw;
        assert!((time - expect) / expect < 0.1, "time={time} expect={expect}");
    }

    #[test]
    fn run_schedule_ring_dependencies_serialize_steps() {
        let t = dgx1();
        let p = 4;
        let sched = ring_allgatherv(p, None);
        let bytes = 16.0e6;
        let mut sim = Sim::new(&t);
        let finals = run_schedule(&mut sim, p, &sched, &[], |sim, op, deps| {
            direct_flow(sim, &t, op.from, op.to, bytes, 0.0, deps)
        });
        assert_eq!(finals.len(), p);
        let res = sim.run();
        let total = finals
            .iter()
            .map(|&f| res.finish(f.unwrap()))
            .fold(0.0, f64::max);
        // P-1 steps, each >= bytes/nvlink_bw
        let hop = bytes / 18.0e9;
        assert!(total >= (p - 1) as f64 * hop * 0.99, "total={total}");
    }

    #[test]
    fn dtoh_htod_are_pcie_limited() {
        let t = dgx1();
        let mut sim = Sim::new(&t);
        let bytes = 1.0e9;
        let a = dtoh(&mut sim, &t, 0, bytes, &[]);
        let res = sim.run();
        let expect = bytes / 12.5e9; // PCIe gen3 x16 effective
        assert!((res.finish(a) - expect) / expect < 0.01);
    }
}
