//! Transports: how a logical send becomes simulator flows for each
//! library's data path (paper §II).
//!
//! The building blocks are the data paths the paper describes:
//! - explicit device<->host staging copies (plain MPI, §II-A);
//! - GPUDirect P2P direct copies over NVLink/PCIe (CUDA-aware MPI);
//! - pipelined host-staged chunks when P2P is unavailable;
//! - GPUDirect RDMA to the NIC for inter-node sends (MVAPICH-GDR),
//!   gated by `MV2_GPUDIRECT_LIMIT`;
//! - host<->host transfers over shared memory / QPI / InfiniBand.

use crate::sim::{Sim, TaskId};
use crate::topology::Topology;

use super::algorithms::{Schedule, SendOp};
use super::params::Params;

/// Device-to-host copy of a GPU's buffer (cudaMemcpy D2H): a flow from
/// the GPU to its host CPU over the PCIe hierarchy — it contends with
/// everything else crossing those switches.
pub fn dtoh(sim: &mut Sim, topo: &Topology, rank: usize, bytes: f64, deps: &[TaskId]) -> TaskId {
    let gpu = topo.gpu(rank);
    let cpu = topo.host_cpu(gpu);
    let path = topo.route(gpu, cpu).expect("GPU must reach its host CPU");
    let lat = topo.path_latency(&path);
    sim.flow(path, bytes, lat, deps)
}

/// Host-to-device copy (cudaMemcpy H2D).
pub fn htod(sim: &mut Sim, topo: &Topology, rank: usize, bytes: f64, deps: &[TaskId]) -> TaskId {
    let gpu = topo.gpu(rank);
    let cpu = topo.host_cpu(gpu);
    let path = topo.route(cpu, gpu).expect("host CPU must reach its GPU");
    let lat = topo.path_latency(&path);
    sim.flow(path, bytes, lat, deps)
}

/// Host-to-host transfer between the CPUs owning two GPUs' hierarchies.
/// Same socket: a memcpy (pure delay at memory bandwidth). Otherwise a
/// flow over QPI (intra-node) or PCIe+IB (inter-node).
pub fn host_to_host(
    sim: &mut Sim,
    topo: &Topology,
    params: &Params,
    from: usize,
    to: usize,
    bytes: f64,
    deps: &[TaskId],
) -> TaskId {
    let cpu_s = topo.host_cpu(topo.gpu(from));
    let cpu_r = topo.host_cpu(topo.gpu(to));
    if cpu_s == cpu_r {
        // same root complex: shared-memory copy
        return sim.delay(bytes / params.host_memcpy_bw, deps);
    }
    let path = topo.route(cpu_s, cpu_r).expect("hosts must be routable");
    let lat = topo.path_latency(&path);
    sim.flow(path, bytes, lat, deps)
}

/// Direct GPU-to-GPU flow along the widest route (GPUDirect P2P copy, or
/// any single-flow device copy).
pub fn direct_flow(
    sim: &mut Sim,
    topo: &Topology,
    from: usize,
    to: usize,
    bytes: f64,
    extra_latency: f64,
    deps: &[TaskId],
) -> TaskId {
    let path = topo.route_gpus(from, to).expect("GPUs must be routable");
    let lat = topo.path_latency(&path) + extra_latency;
    sim.flow(path, bytes, lat, deps)
}

/// Pipelined host-staged transfer: D2H, (host-to-host), H2D in chunks of
/// `params.pipeline_chunk`, with chunk k's leg j depending on leg j-1 of
/// chunk k and leg j of chunk k-1 — the classic MVAPICH GPU pipeline.
/// Returns the completion of the last chunk's H2D.
pub fn staged_pipeline(
    sim: &mut Sim,
    topo: &Topology,
    params: &Params,
    from: usize,
    to: usize,
    bytes: f64,
    deps: &[TaskId],
) -> TaskId {
    if bytes <= 0.0 {
        // zero-byte block (zero-heavy §IV vectors): nothing to stage —
        // no 3-leg chunk, no per-chunk handshake, just the dependency
        return sim.delay(0.0, deps);
    }
    let chunk = params.pipeline_chunk as f64;
    let n_chunks = ((bytes / chunk).ceil() as usize).max(1);
    let per = bytes / n_chunks as f64;
    let mut prev_leg1: Option<TaskId> = None;
    let mut prev_leg2: Option<TaskId> = None;
    let mut prev_leg3: Option<TaskId> = None;
    let mut last = None;
    for _ in 0..n_chunks {
        let mut d1: Vec<TaskId> = deps.to_vec();
        if let Some(t) = prev_leg1 {
            d1 = vec![t]; // sender serializes its own D2H chunks
        }
        let leg1 = dtoh(sim, topo, from, per, &d1);
        let mut d2 = vec![leg1];
        if let Some(t) = prev_leg2 {
            d2.push(t);
        }
        // per-chunk rendezvous/progress handshake before the wire leg
        let hs = sim.delay(params.pipeline_chunk_overhead, &d2);
        let leg2 = host_to_host(sim, topo, params, from, to, per, &[hs]);
        let mut d3 = vec![leg2];
        if let Some(t) = prev_leg3 {
            d3.push(t);
        }
        let leg3 = htod(sim, topo, to, per, &d3);
        prev_leg1 = Some(leg1);
        prev_leg2 = Some(leg2);
        prev_leg3 = Some(leg3);
        last = Some(leg3);
    }
    last.unwrap()
}

/// Synchronous staged bounce: the fallback past the CUDA-IPC cliff.
/// Each small chunk runs D2H -> host copy -> H2D *serially* with a stream
/// synchronization between chunks — no pipelining at all. This is what
/// makes the paper's 729 MB-class NELL-1 messages so much slower under
/// MPI-CUDA at 2 GPUs than the same volume at 8 (Fig. 3, §V-C).
pub fn staged_serial(
    sim: &mut Sim,
    topo: &Topology,
    params: &Params,
    from: usize,
    to: usize,
    bytes: f64,
    deps: &[TaskId],
) -> TaskId {
    if bytes <= 0.0 {
        // zero-byte block: no bounce, no stream sync (see staged_pipeline)
        return sim.delay(0.0, deps);
    }
    let chunk = params.ipc_fallback_chunk as f64;
    let n_chunks = ((bytes / chunk).ceil() as usize).max(1);
    let per = bytes / n_chunks as f64;
    let mut prev: Option<TaskId> = None;
    for _ in 0..n_chunks {
        let d: Vec<TaskId> = prev.map(|t| vec![t]).unwrap_or_else(|| deps.to_vec());
        let leg1 = dtoh(sim, topo, from, per, &d);
        let leg2 = host_to_host(sim, topo, params, from, to, per, &[leg1]);
        let leg3 = htod(sim, topo, to, per, &[leg2]);
        prev = Some(sim.delay(params.ipc_fallback_sync, &[leg3]));
    }
    prev.unwrap()
}

/// GPUDirect RDMA send (cluster inter-node, size <= MV2_GPUDIRECT_LIMIT):
/// the HCA reads GPU memory directly — one flow along the full GPU->GPU
/// route plus a serial penalty modeling the reduced PCIe peer-read
/// bandwidth of GDR (the reason MVAPICH avoids GDR for large messages).
pub fn gdr_send(
    sim: &mut Sim,
    topo: &Topology,
    params: &Params,
    from: usize,
    to: usize,
    bytes: f64,
    deps: &[TaskId],
) -> TaskId {
    let path = topo.route_gpus(from, to).expect("GPUs must be routable");
    let wire_bw = topo.path_bandwidth(&path);
    let lat = topo.path_latency(&path);
    let flow = sim.flow(path, bytes, lat, deps);
    let penalty = (1.0 / params.gdr_read_bw - 1.0 / wire_bw).max(0.0) * bytes;
    if penalty > 0.0 {
        sim.delay(penalty, &[flow])
    } else {
        flow
    }
}

/// Fold a composed op's per-rank tail tasks into a single completion
/// task — the handle a dependent iteration (or a workload arrival gate)
/// waits on. A single tail is returned as-is; several are joined; an
/// empty tail set (a 1-rank schedule moves no data) degrades to the
/// gate itself or, lacking one, a zero-delay root task. Because every
/// task a composition emits is an ancestor of one of its tails, the
/// completion task finishes exactly when the op's subgraph does.
pub fn op_completion(sim: &mut Sim, tails: &[TaskId], gate: Option<TaskId>) -> TaskId {
    match tails {
        [] => gate.unwrap_or_else(|| sim.join(&[])),
        [one] => *one,
        many => sim.join(many),
    }
}

/// Run a [`Schedule`] with per-rank step barriers: a rank's step-s+1
/// operations wait on everything it sent or received in step s (blocking
/// MPI collective semantics — the reason a dominant block serializes a
/// ring but not a pipelined broadcast).
///
/// `send` emits the transport tasks for one logical op and returns the
/// completion task.
pub fn run_schedule<F>(
    sim: &mut Sim,
    p: usize,
    schedule: &Schedule,
    entry: &[Option<TaskId>],
    mut send: F,
) -> Vec<Option<TaskId>>
where
    F: FnMut(&mut Sim, &SendOp, &[TaskId]) -> TaskId,
{
    // marker[r]: task after which rank r may proceed to the next step
    let mut marker: Vec<Option<TaskId>> = vec![None; p];
    if !entry.is_empty() {
        assert_eq!(entry.len(), p, "one entry marker per rank");
        marker.copy_from_slice(entry);
    }
    for step in &schedule.steps {
        let mut step_events: Vec<(usize, TaskId)> = Vec::new();
        for op in step {
            let mut deps: Vec<TaskId> = Vec::new();
            if let Some(t) = marker[op.from] {
                deps.push(t);
            }
            if let Some(t) = marker[op.to] {
                if Some(t) != marker[op.from] {
                    deps.push(t);
                }
            }
            let done = send(sim, op, &deps);
            step_events.push((op.from, done));
            step_events.push((op.to, done));
        }
        // fold step events into per-rank markers
        for r in 0..p {
            let mut evs: Vec<TaskId> =
                step_events.iter().filter(|&&(rr, _)| rr == r).map(|&(_, t)| t).collect();
            if let Some(t) = marker[r] {
                evs.push(t);
            }
            evs.sort_unstable();
            evs.dedup();
            marker[r] = match evs.len() {
                0 => None,
                1 => Some(evs[0]),
                _ => Some(sim.join(&evs)),
            };
        }
    }
    marker
}

/// Hard-fault recovery policy for a collective (DESIGN.md §14): how
/// long a send may sit without progress before the op is declared
/// faulted, and how aggressively to retry before repairing the
/// schedule.
///
/// The policy drives the abort-and-restart state machine in
/// [`crate::perturb::recovery`] (NCCL-style semantics: a faulted
/// collective is torn down and re-issued, not patched mid-flight):
/// detection costs `timeout` seconds after the stall instant, then up
/// to `max_retries` re-issues separated by exponential backoff
/// (`backoff_base * 2^k`, capped at `backoff_cap`), then schedule
/// repair — reroute around dead links, or communicator shrink when a
/// rank is gone. [`RecoveryPolicy::disabled`] — and any policy on a
/// run that never stalls — leaves results bit-identical to the
/// recovery-free path (`tests/faults_differential.rs`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RecoveryPolicy {
    /// Seconds of zero progress before the op is declared faulted.
    pub timeout: f64,
    /// Re-issue attempts before falling back to schedule repair.
    pub max_retries: usize,
    /// First retry backoff (seconds); doubles per attempt.
    pub backoff_base: f64,
    /// Upper bound on a single backoff step (seconds).
    pub backoff_cap: f64,
}

impl RecoveryPolicy {
    /// No recovery: a stall is reported as-is (the pre-PR-7 behavior).
    pub fn disabled() -> RecoveryPolicy {
        RecoveryPolicy { timeout: 0.0, max_retries: 0, backoff_base: 0.0, backoff_cap: 0.0 }
    }

    /// Millisecond-scale defaults sized for the paper's systems: 1 ms
    /// detection, 3 retries backing off 1 -> 2 -> 4 ms (capped 10 ms).
    pub fn default_policy() -> RecoveryPolicy {
        RecoveryPolicy {
            timeout: 1.0e-3,
            max_retries: 3,
            backoff_base: 1.0e-3,
            backoff_cap: 10.0e-3,
        }
    }

    /// Is any recovery mechanism active?
    pub fn enabled(&self) -> bool {
        self.max_retries > 0 || self.timeout > 0.0
    }

    /// Backoff before retry `k` (0-based): `base * 2^k`, capped.
    pub fn backoff(&self, k: usize) -> f64 {
        let exp = 2.0_f64.powi(k.min(63) as i32);
        (self.backoff_base * exp).min(self.backoff_cap)
    }
}

/// How a logical send is segmented into wire flows (DESIGN.md §13).
///
/// `chunks = 1` reproduces the unchunked schedule **task-for-task**:
/// [`run_schedule_chunked`] then builds the identical DAG as
/// [`run_schedule`], which the chunking differential oracle in
/// `tests/collective_conformance.rs` locks down bit-exactly. `chunks =
/// k > 1` splits every logical send into k wire flows; chunk j of step
/// s depends on chunk j of step s−1 at the endpoints (the NCCL-style
/// ring pipeline), so a chunk can race ahead down the ring while the
/// tail of the previous step is still on the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkCfg {
    /// Wire chunks per logical send (>= 1).
    pub chunks: usize,
}

impl ChunkCfg {
    /// One flow per logical send — the unchunked baseline.
    pub fn none() -> ChunkCfg {
        ChunkCfg { chunks: 1 }
    }

    /// Pipeline each logical send as `k` wire chunks (clamped to >= 1).
    pub fn pipelined(k: usize) -> ChunkCfg {
        ChunkCfg { chunks: k.max(1) }
    }
}

/// Size of chunk `j` when `bytes` is split into `k` integer chunks:
/// the remainder spreads one byte at a time over the leading chunks, so
/// the k sizes always sum to `bytes` exactly and `k = 1` returns
/// `bytes` unchanged.
pub fn chunk_bytes(bytes: u64, k: usize, j: usize) -> u64 {
    debug_assert!(j < k);
    let (k, j) = (k as u64, j as u64);
    bytes / k + u64::from(j < bytes % k)
}

/// Run a [`Schedule`] with per-(rank, chunk) step barriers: each of the
/// `cfg.chunks` chunk lanes is an independent copy of the
/// [`run_schedule`] dependency structure — chunk j of a step-s+1 op
/// waits on chunk j of what its endpoints did in step s — while the
/// chunks of one logical op serialize on its wire (`prev`). The lanes
/// only meet in the final per-rank fold, which joins a rank's chunk
/// markers into the one completion marker callers already expect.
///
/// `send` emits the transport tasks for chunk `j` of `k` of one logical
/// op; at `k = 1` the emitted DAG is task-for-task identical to
/// [`run_schedule`]'s (same task creation order, same dependency lists,
/// same joins) — the invariant the `chunks=1` differential relies on.
pub fn run_schedule_chunked<F>(
    sim: &mut Sim,
    p: usize,
    schedule: &Schedule,
    entry: &[Option<TaskId>],
    cfg: ChunkCfg,
    mut send: F,
) -> Vec<Option<TaskId>>
where
    F: FnMut(&mut Sim, &SendOp, usize, usize, &[TaskId]) -> TaskId,
{
    let k = cfg.chunks.max(1);
    // marker[r][j]: task after which chunk lane j of rank r may proceed
    let mut marker: Vec<Vec<Option<TaskId>>> = vec![vec![None; k]; p];
    if !entry.is_empty() {
        assert_eq!(entry.len(), p, "one entry marker per rank");
        for (r, &e) in entry.iter().enumerate() {
            for j in 0..k {
                marker[r][j] = e;
            }
        }
    }
    for step in &schedule.steps {
        let mut step_events: Vec<(usize, usize, TaskId)> = Vec::new();
        for op in step {
            let mut prev: Option<TaskId> = None;
            for j in 0..k {
                let mut deps: Vec<TaskId> = Vec::new();
                if let Some(t) = marker[op.from][j] {
                    deps.push(t);
                }
                if let Some(t) = marker[op.to][j] {
                    if Some(t) != marker[op.from][j] {
                        deps.push(t);
                    }
                }
                if let Some(t) = prev {
                    if !deps.contains(&t) {
                        deps.push(t);
                    }
                }
                let done = send(sim, op, j, k, &deps);
                step_events.push((op.from, j, done));
                step_events.push((op.to, j, done));
                prev = Some(done);
            }
        }
        // fold step events into per-(rank, chunk) markers
        for r in 0..p {
            for j in 0..k {
                let mut evs: Vec<TaskId> = step_events
                    .iter()
                    .filter(|&&(rr, jj, _)| rr == r && jj == j)
                    .map(|&(_, _, t)| t)
                    .collect();
                if let Some(t) = marker[r][j] {
                    evs.push(t);
                }
                evs.sort_unstable();
                evs.dedup();
                marker[r][j] = match evs.len() {
                    0 => None,
                    1 => Some(evs[0]),
                    _ => Some(sim.join(&evs)),
                };
            }
        }
    }
    // fold the chunk lanes into one completion marker per rank
    (0..p)
        .map(|r| {
            let mut evs: Vec<TaskId> = marker[r].iter().filter_map(|&t| t).collect();
            evs.sort_unstable();
            evs.dedup();
            match evs.len() {
                0 => None,
                1 => Some(evs[0]),
                _ => Some(sim.join(&evs)),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::algorithms::ring_allgatherv;
    use crate::topology::systems::{cluster, dgx1};

    #[test]
    fn staged_pipeline_overlaps_chunks() {
        // pipelined staging should be much faster than serial 3-leg
        let t = dgx1();
        let params = Params::default();
        let bytes = 64.0 * 1024.0 * 1024.0;
        // pipelined
        let mut sim = Sim::new(&t);
        let id = staged_pipeline(&mut sim, &t, &params, 0, 5, bytes, &[]);
        let piped = sim.run().finish(id);
        // serial (one giant chunk)
        let big = Params { pipeline_chunk: u64::MAX, ..params };
        let mut sim = Sim::new(&t);
        let id = staged_pipeline(&mut sim, &t, &big, 0, 5, bytes, &[]);
        let serial = sim.run().finish(id);
        assert!(piped < 0.7 * serial, "piped={piped} serial={serial}");
    }

    #[test]
    fn host_to_host_same_socket_is_memcpy() {
        let t = dgx1();
        let params = Params::default();
        let mut sim = Sim::new(&t);
        // GPUs 0 and 2 hang off different switches but the same socket
        let id = host_to_host(&mut sim, &t, &params, 0, 2, 1.0e9, &[]);
        let time = sim.run().finish(id);
        assert!((time - 1.0e9 / params.host_memcpy_bw).abs() < 1e-9);
    }

    #[test]
    fn gdr_penalty_only_when_slower_than_wire() {
        let t = cluster(2);
        let params = Params::default();
        let bytes = 8.0e6;
        let mut sim = Sim::new(&t);
        let id = gdr_send(&mut sim, &t, &params, 0, 1, bytes, &[]);
        let time = sim.run().finish(id);
        // serial time must be ~ bytes / gdr_read_bw (3 GB/s < IB 6.2)
        let expect = bytes / params.gdr_read_bw;
        assert!((time - expect) / expect < 0.1, "time={time} expect={expect}");
    }

    #[test]
    fn run_schedule_ring_dependencies_serialize_steps() {
        let t = dgx1();
        let p = 4;
        let sched = ring_allgatherv(p, None);
        let bytes = 16.0e6;
        let mut sim = Sim::new(&t);
        let finals = run_schedule(&mut sim, p, &sched, &[], |sim, op, deps| {
            direct_flow(sim, &t, op.from, op.to, bytes, 0.0, deps)
        });
        assert_eq!(finals.len(), p);
        let res = sim.run();
        let total = finals
            .iter()
            .map(|&f| res.finish(f.unwrap()))
            .fold(0.0, f64::max);
        // P-1 steps, each >= bytes/nvlink_bw
        let hop = bytes / 18.0e9;
        assert!(total >= (p - 1) as f64 * hop * 0.99, "total={total}");
    }

    #[test]
    fn staged_paths_zero_bytes_are_free() {
        // regression: a zero-byte block used to emit one full 3-leg
        // chunk (plus handshake / stream-sync delay) in both staged
        // paths; it must now cost exactly nothing beyond its deps
        let t = dgx1();
        let params = Params::default();
        for staged in [staged_pipeline, staged_serial] {
            let mut sim = Sim::new(&t);
            let gate = sim.delay(3.5e-6, &[]);
            let before = sim.task_count();
            let id = staged(&mut sim, &t, &params, 0, 5, 0.0, &[gate]);
            assert_eq!(sim.task_count() - before, 1, "zero-byte send must be one no-op task");
            assert_eq!(sim.flow_tasks_since(before), 0);
            let res = sim.run();
            assert_eq!(res.finish(id).to_bits(), 3.5e-6f64.to_bits());
        }
    }

    #[test]
    fn chunked_runner_at_one_chunk_matches_run_schedule_exactly() {
        // k=1 must build the task-for-task identical DAG: same task
        // count, same completion times to the bit, on several schedules
        let t = dgx1();
        let params = Params::default();
        for p in [2usize, 4, 8] {
            let sched = ring_allgatherv(p, None);
            let bytes: Vec<u64> = (0..p as u64).map(|b| (b + 1) * 1_000_003).collect();
            let run = |chunked: bool| {
                let mut sim = Sim::new(&t);
                let gate = sim.delay(1.0e-6, &[]);
                let entry = vec![Some(gate); p];
                let finals = if chunked {
                    run_schedule_chunked(
                        &mut sim,
                        p,
                        &sched,
                        &entry,
                        ChunkCfg::none(),
                        |sim, op, j, k, deps| {
                            let b = chunk_bytes(op.bytes(&bytes), k, j) as f64;
                            staged_pipeline(sim, &t, &params, op.from, op.to, b, deps)
                        },
                    )
                } else {
                    run_schedule(&mut sim, p, &sched, &entry, |sim, op, deps| {
                        staged_pipeline(
                            sim,
                            &t,
                            &params,
                            op.from,
                            op.to,
                            op.bytes(&bytes) as f64,
                            deps,
                        )
                    })
                };
                let tasks = sim.task_count();
                let res = sim.run();
                let times: Vec<u64> =
                    finals.iter().map(|&f| res.finish(f.unwrap()).to_bits()).collect();
                (tasks, times)
            };
            assert_eq!(run(true), run(false), "p={p}: chunks=1 DAG diverged");
        }
    }

    #[test]
    fn recovery_policy_backoff_is_bounded_exponential() {
        let p = RecoveryPolicy::default_policy();
        assert!(p.enabled());
        assert_eq!(p.backoff(0), 1.0e-3);
        assert_eq!(p.backoff(1), 2.0e-3);
        assert_eq!(p.backoff(2), 4.0e-3);
        assert_eq!(p.backoff(5), 10.0e-3, "capped");
        assert_eq!(p.backoff(400), 10.0e-3, "huge k must not overflow");
        assert!(!RecoveryPolicy::disabled().enabled());
    }

    #[test]
    fn chunk_bytes_partitions_exactly() {
        for (bytes, k) in [(10u64, 3usize), (0, 4), (7, 7), (129, 8), (1, 5)] {
            let total: u64 = (0..k).map(|j| chunk_bytes(bytes, k, j)).sum();
            assert_eq!(total, bytes, "bytes={bytes} k={k}");
        }
        assert_eq!(chunk_bytes(42, 1, 0), 42);
    }

    #[test]
    fn chunk_pipelining_overlaps_ring_steps() {
        // NVLink ring of direct flows, large blocks: 4-way chunking must
        // beat the step-barriered unchunked schedule (chunk j of step
        // s+1 starts while chunks j+1.. of step s are still on the wire)
        let t = dgx1();
        let p = 4;
        let sched = ring_allgatherv(p, None);
        let bytes = vec![32u64 << 20; p];
        let run = |cfg: ChunkCfg| {
            let mut sim = Sim::new(&t);
            let finals =
                run_schedule_chunked(&mut sim, p, &sched, &[], cfg, |sim, op, j, k, deps| {
                    let b = chunk_bytes(op.bytes(&bytes), k, j) as f64;
                    direct_flow(sim, &t, op.from, op.to, b, 0.0, deps)
                });
            let res = sim.run();
            finals.iter().map(|&f| res.finish(f.unwrap())).fold(0.0, f64::max)
        };
        let unchunked = run(ChunkCfg::none());
        let chunked = run(ChunkCfg::pipelined(4));
        assert!(
            chunked < 0.999 * unchunked,
            "chunked={chunked} unchunked={unchunked}"
        );
    }

    #[test]
    fn dtoh_htod_are_pcie_limited() {
        let t = dgx1();
        let mut sim = Sim::new(&t);
        let bytes = 1.0e9;
        let a = dtoh(&mut sim, &t, 0, bytes, &[]);
        let res = sim.run();
        let expect = bytes / 12.5e9; // PCIe gen3 x16 effective
        assert!((res.finish(a) - expect) / expect < 0.01);
    }
}
