//! The op-generic collective layer (DESIGN.md §13): allreduce,
//! broadcast and alltoallv next to the paper's Allgatherv, all
//! dispatched over the **same** per-library compose entry points so the
//! selector, fault and workload layers accept the new ops without
//! forked code paths.
//!
//! A [`CollectiveSpec`] pairs an op with its count shape (per-rank
//! contributions, vector segments, a root message, or a src×dst count
//! matrix); [`compose_collective`] lowers it to the library-agnostic
//! phase [`Schedule`]s of `comm::algorithms` and hands those to the
//! library transports:
//! - **MPI**: explicit D2H staging of what each rank contributes, the
//!   phases host-to-host with eager/rendezvous overheads per chunk,
//!   H2D of what each rank must end up holding
//!   ([`super::mpi::Mpi::compose_phases`]);
//! - **MPI-CUDA**: every chunk rides the per-send CUDA-aware data-path
//!   dispatch (P2P / staged / GDR by chunk size);
//! - **NCCL**: one kernel-launch overhead per collective, then sends on
//!   the NVLink-preferring hop route; the caller's
//!   [`ChunkCfg`] over a ring-shaped schedule *is* the NCCL pipeline.
//!   NCCL Allgatherv keeps delegating to the native Listing-1 bcast
//!   series ([`super::nccl::Nccl::compose`]), whose adaptive slicing
//!   already plays the chunking role.
//!
//! Modeling choices, shared with the paper's Allgatherv measurements:
//! reduction arithmetic is free (the paper times data movement; on-GPU
//! adds overlap the wire at tens of GB/s), and MPI staging accounts
//! exactly for the device bytes an op touches — an allreduce stages the
//! whole vector both ways, a bcast stages down only at the root, an
//! alltoallv never stages its resident diagonal block.
//!
//! The lockdown mirrors PRs 4–5: `tests/collective_conformance.rs`
//! machine-checks the closed forms (2(P−1)·Σcounts allreduce wire
//! bytes, ⌈log2 P⌉ rounds for halving/doubling and binomial bcast,
//! exact pairwise delivery) and pins `chunks = 1` **bit-exact** against
//! the pre-existing unchunked Allgatherv path per library × system ×
//! irregular vector, on both engine cores.

use crate::sim::{Sim, TaskId};
use crate::topology::Topology;

use super::algorithms::{
    binomial_bcast_msg, halving_doubling_allreduce, pairwise_alltoallv, ring_allreduce,
    ring_bcast_msg, scatter_allgather_bcast, Schedule,
};
use super::mpi::{select_algorithm, Mpi};
use super::mpi_cuda::MpiCuda;
use super::nccl::{detect_ring, Nccl};
use super::transport::ChunkCfg;
use super::{CommResult, Library, Params};

/// The collective operations the simulator models.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollectiveOp {
    /// Irregular all-gather (the paper's op).
    Allgatherv,
    /// Sum-reduce a vector and leave the result everywhere.
    Allreduce,
    /// One root's message to every rank.
    Bcast,
    /// Personalized all-to-all with per-(src, dst) counts.
    Alltoallv,
}

impl CollectiveOp {
    /// CLI/report name.
    pub fn name(self) -> &'static str {
        match self {
            CollectiveOp::Allgatherv => "allgatherv",
            CollectiveOp::Allreduce => "allreduce",
            CollectiveOp::Bcast => "bcast",
            CollectiveOp::Alltoallv => "alltoallv",
        }
    }

    /// Parse an op name as accepted by `agv collective --op`.
    pub fn parse(s: &str) -> Option<CollectiveOp> {
        match s.to_ascii_lowercase().as_str() {
            "allgatherv" | "allgather" => Some(CollectiveOp::Allgatherv),
            "allreduce" => Some(CollectiveOp::Allreduce),
            "bcast" | "broadcast" => Some(CollectiveOp::Bcast),
            "alltoallv" | "alltoall" => Some(CollectiveOp::Alltoallv),
            _ => None,
        }
    }

    /// All ops, Allgatherv first.
    pub fn all() -> [CollectiveOp; 4] {
        [
            CollectiveOp::Allgatherv,
            CollectiveOp::Allreduce,
            CollectiveOp::Bcast,
            CollectiveOp::Alltoallv,
        ]
    }
}

/// One collective call: the op plus its count shape. Counts are bytes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CollectiveSpec {
    /// Rank r contributes `counts[r]`; everyone ends with all of it.
    Allgatherv {
        /// Per-rank contribution bytes.
        counts: Vec<u64>,
    },
    /// The reduced vector cut into P segments of `segs[s]` bytes each
    /// (irregular splits model ragged reduction layouts).
    Allreduce {
        /// Per-segment bytes; `segs.len()` is the rank count.
        segs: Vec<u64>,
    },
    /// `root`'s message, cut into P segments of `segs[s]` bytes.
    Bcast {
        /// Per-segment bytes; `segs.len()` is the rank count.
        segs: Vec<u64>,
        /// Broadcasting rank.
        root: usize,
    },
    /// Src-major flattened count matrix: `counts[src * p + dst]` bytes
    /// from src to dst.
    Alltoallv {
        /// Flattened p×p matrix.
        counts: Vec<u64>,
        /// Rank count.
        p: usize,
    },
}

impl CollectiveSpec {
    /// Which op this spec is.
    pub fn op(&self) -> CollectiveOp {
        match self {
            CollectiveSpec::Allgatherv { .. } => CollectiveOp::Allgatherv,
            CollectiveSpec::Allreduce { .. } => CollectiveOp::Allreduce,
            CollectiveSpec::Bcast { .. } => CollectiveOp::Bcast,
            CollectiveSpec::Alltoallv { .. } => CollectiveOp::Alltoallv,
        }
    }

    /// Number of participating ranks.
    pub fn ranks(&self) -> usize {
        match self {
            CollectiveSpec::Allgatherv { counts } => counts.len(),
            CollectiveSpec::Allreduce { segs } => segs.len(),
            CollectiveSpec::Bcast { segs, .. } => segs.len(),
            CollectiveSpec::Alltoallv { p, .. } => *p,
        }
    }

    /// Total payload bytes of the op (gathered buffer, reduced vector,
    /// root message, or whole count matrix respectively).
    pub fn total_bytes(&self) -> u64 {
        match self {
            CollectiveSpec::Allgatherv { counts } => counts.iter().sum(),
            CollectiveSpec::Allreduce { segs } => segs.iter().sum(),
            CollectiveSpec::Bcast { segs, .. } => segs.iter().sum(),
            CollectiveSpec::Alltoallv { counts, .. } => counts.iter().sum(),
        }
    }

    /// Check shape invariants, panicking with a precise message.
    fn assert_valid(&self) {
        match self {
            CollectiveSpec::Allgatherv { counts } => {
                assert!(!counts.is_empty(), "allgatherv needs at least one rank")
            }
            CollectiveSpec::Allreduce { segs } => {
                assert!(!segs.is_empty(), "allreduce needs at least one rank")
            }
            CollectiveSpec::Bcast { segs, root } => {
                assert!(*root < segs.len(), "bcast root {root} out of range");
            }
            CollectiveSpec::Alltoallv { counts, p } => {
                assert_eq!(counts.len(), p * p, "alltoallv needs a p*p count matrix");
                assert!(*p >= 1, "alltoallv needs at least one rank");
            }
        }
    }

    /// Build a spec for `op` from a per-rank count vector — the mapping
    /// the workload engine's tenant streams use. Allgatherv and
    /// allreduce take the vector as contributions / segment sizes;
    /// bcast roots at rank 0 with the vector as segment sizes;
    /// alltoallv becomes the row-uniform matrix where rank src sends
    /// `counts[src]` bytes to each peer (zero diagonal).
    pub fn from_vector(op: CollectiveOp, counts: &[u64]) -> CollectiveSpec {
        let p = counts.len();
        match op {
            CollectiveOp::Allgatherv => CollectiveSpec::Allgatherv { counts: counts.to_vec() },
            CollectiveOp::Allreduce => CollectiveSpec::Allreduce { segs: counts.to_vec() },
            CollectiveOp::Bcast => CollectiveSpec::Bcast { segs: counts.to_vec(), root: 0 },
            CollectiveOp::Alltoallv => {
                let mut m = vec![0u64; p * p];
                for src in 0..p {
                    for dst in 0..p {
                        if src != dst {
                            m[src * p + dst] = counts[src];
                        }
                    }
                }
                CollectiveSpec::Alltoallv { counts: m, p }
            }
        }
    }

    /// The library-agnostic phase schedules and their block-size vector
    /// for `lib` on `topo`: MPI and MPI-CUDA follow the MVAPICH-style
    /// mean-size algorithm switches, NCCL runs ring-family schedules
    /// over its detected ring. (NCCL Allgatherv never reaches this —
    /// [`compose_collective`] delegates it to the native bcast series.)
    pub fn phases_for(
        &self,
        topo: &Topology,
        lib: Library,
        params: &Params,
    ) -> (Vec<Schedule>, Vec<u64>) {
        self.assert_valid();
        let p = self.ranks();
        match self {
            CollectiveSpec::Allgatherv { counts } => {
                (vec![select_algorithm(params, counts)], counts.clone())
            }
            CollectiveSpec::Allreduce { segs } => {
                let phases = match lib {
                    Library::Nccl => {
                        let ring = detect_ring(topo, p);
                        let rs = ring_allreduce(p, Some(&ring));
                        vec![rs.reduce, rs.gather]
                    }
                    _ => match select_allreduce(params, segs) {
                        ReduceAlgo::HalvingDoubling => {
                            let rs = halving_doubling_allreduce(p);
                            vec![rs.reduce, rs.gather]
                        }
                        ReduceAlgo::Ring => {
                            let rs = ring_allreduce(p, None);
                            vec![rs.reduce, rs.gather]
                        }
                    },
                };
                (phases, segs.clone())
            }
            CollectiveSpec::Bcast { segs, root } => {
                let phases = match lib {
                    Library::Nccl => {
                        let ring = detect_ring(topo, p);
                        vec![ring_bcast_msg(p, *root, p, Some(&ring))]
                    }
                    _ => match select_bcast(params, segs) {
                        BcastAlgo::Binomial => vec![binomial_bcast_msg(p, *root, p)],
                        BcastAlgo::ScatterAllgather => {
                            let b = scatter_allgather_bcast(p, *root);
                            vec![b.scatter, b.gather]
                        }
                    },
                };
                (phases, segs.clone())
            }
            CollectiveSpec::Alltoallv { counts, .. } => {
                (vec![pairwise_alltoallv(p)], counts.clone())
            }
        }
    }

    /// Per-rank explicit-staging byte counts for the plain-MPI
    /// transport: (D2H before the collective, H2D after it).
    pub fn mpi_staging(&self) -> (Vec<u64>, Vec<u64>) {
        let p = self.ranks();
        match self {
            CollectiveSpec::Allgatherv { counts } => {
                let total: u64 = counts.iter().sum();
                (counts.clone(), vec![total; p])
            }
            CollectiveSpec::Allreduce { segs } => {
                // every rank contributes and receives the whole vector
                let total: u64 = segs.iter().sum();
                (vec![total; p], vec![total; p])
            }
            CollectiveSpec::Bcast { segs, root } => {
                let total: u64 = segs.iter().sum();
                let down = (0..p).map(|r| if r == *root { total } else { 0 }).collect();
                let up = (0..p).map(|r| if r == *root { 0 } else { total }).collect();
                (down, up)
            }
            CollectiveSpec::Alltoallv { counts, .. } => {
                // the diagonal block stays resident on its device
                let down = (0..p)
                    .map(|src| (0..p).filter(|&d| d != src).map(|d| counts[src * p + d]).sum())
                    .collect();
                let up = (0..p)
                    .map(|dst| (0..p).filter(|&s| s != dst).map(|s| counts[s * p + dst]).sum())
                    .collect();
                (down, up)
            }
        }
    }
}

/// Which allreduce algorithm the MVAPICH-style mean-size rule picks:
/// latency-optimal recursive halving/doubling for short vectors on
/// power-of-two rank counts, bandwidth-optimal ring otherwise — the
/// same mean-count rule whose irregular-vector misselections the paper
/// documents for Allgatherv (§V-C).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceAlgo {
    /// Reduce-scatter + allgather ring, 2(P−1) rounds.
    Ring,
    /// Recursive halving + doubling, 2·log2 P rounds (power-of-two P).
    HalvingDoubling,
}

/// MVAPICH-style allreduce algorithm selection on the mean segment size.
pub fn select_allreduce(params: &Params, segs: &[u64]) -> ReduceAlgo {
    let p = segs.len();
    let avg = segs.iter().sum::<u64>() / p.max(1) as u64;
    if p.is_power_of_two() && avg <= params.allgatherv_algo_switch {
        ReduceAlgo::HalvingDoubling
    } else {
        ReduceAlgo::Ring
    }
}

/// Which broadcast algorithm the MPI paths pick.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BcastAlgo {
    /// Binomial tree, ⌈log2 P⌉ rounds, ships the whole message per hop.
    Binomial,
    /// Scatter + ring allgather (van de Geijn), bandwidth-optimal.
    ScatterAllgather,
}

/// MVAPICH-style bcast algorithm selection on the mean segment size.
pub fn select_bcast(params: &Params, segs: &[u64]) -> BcastAlgo {
    let p = segs.len();
    let avg = segs.iter().sum::<u64>() / p.max(1) as u64;
    if avg <= params.allgatherv_algo_switch {
        BcastAlgo::Binomial
    } else {
        BcastAlgo::ScatterAllgather
    }
}

/// Compose one collective into a **shared** simulation behind an
/// optional gate — the same contract as [`super::compose_allgatherv`],
/// which the fault layer (`perturb::perturbed_collective`) and the
/// workload engine reuse verbatim. `chunk` segments every logical send
/// into wire chunks; `ChunkCfg::none()` reproduces the unchunked DAG
/// task-for-task (for Allgatherv that means **bit-exact** agreement
/// with [`super::compose_allgatherv`] — the conformance differential).
pub fn compose_collective(
    sim: &mut Sim,
    lib: Library,
    params: Params,
    spec: &CollectiveSpec,
    chunk: ChunkCfg,
    gate: Option<TaskId>,
) -> TaskId {
    spec.assert_valid();
    if let (Library::Nccl, CollectiveSpec::Allgatherv { counts }) = (lib, spec) {
        // the native Listing-1 bcast series: its adaptive slicing is
        // NCCL's own chunking, so `chunk` does not apply here
        return Nccl::new(params).compose(sim, counts, gate);
    }
    let p = spec.ranks();
    let topo = sim.topology();
    let (phases, blocks) = spec.phases_for(topo, lib, &params);
    let refs: Vec<&Schedule> = phases.iter().collect();
    match lib {
        Library::Mpi => {
            let (down, up) = spec.mpi_staging();
            Mpi::new(params).compose_phases(sim, p, &blocks, &refs, &down, &up, chunk, gate)
        }
        Library::MpiCuda => {
            MpiCuda::new(params).compose_phases(sim, p, &blocks, &refs, chunk, gate)
        }
        Library::Nccl => Nccl::new(params).compose_phases(sim, p, &blocks, &refs, chunk, gate),
    }
}

/// Run one collective in a fresh simulation (the one-shot form, like
/// [`super::run_allgatherv`] for the paper's op).
pub fn run_collective(
    topo: &Topology,
    lib: Library,
    params: Params,
    spec: &CollectiveSpec,
    chunk: ChunkCfg,
) -> CommResult {
    let mut sim = Sim::new(topo);
    let done = compose_collective(&mut sim, lib, params, spec, chunk, None);
    let res = sim.run();
    CommResult { time: res.finish(done), flows: res.flows }
}

/// Auto-select the fastest library for one spec by simulating all
/// three — the selector story for the non-Allgatherv ops (Allgatherv
/// additionally has the full per-algorithm candidate machinery in
/// [`super::select`]). Ties break toward the paper's plotting order.
pub fn auto_collective(
    topo: &Topology,
    params: Params,
    spec: &CollectiveSpec,
    chunk: ChunkCfg,
) -> (Library, CommResult) {
    let mut best: Option<(Library, CommResult)> = None;
    for lib in Library::all() {
        let r = run_collective(topo, lib, params, spec, chunk);
        if best.map(|(_, b)| r.time < b.time).unwrap_or(true) {
            best = Some((lib, r));
        }
    }
    best.expect("three libraries evaluated")
}

/// The `bench_collectives` measurement grid and its deterministic
/// `BENCH_collectives.json` payload: per system × op, the three
/// library times, the auto verdict, and the 4-way chunk-pipelining
/// speedup — simulated metrics only, byte-reproducible from the seed
/// (`tests/workload_determinism.rs` pins this).
pub mod bench {
    use super::*;
    use crate::topology::systems::SystemKind;
    use crate::util::json::{obj, Json};
    use crate::util::prng::Rng;
    use crate::util::prop::counts;

    /// The bench grid: every paper system × every collective op, with
    /// a seeded irregular count shape per case.
    pub fn bench_cases(seed: u64) -> Vec<(String, Topology, CollectiveSpec)> {
        let mut rng = Rng::new(seed ^ 0xC0_11EC_71);
        let mut out = Vec::new();
        for kind in SystemKind::all() {
            let topo = kind.build();
            let p = topo.num_gpus().min(8);
            for op in CollectiveOp::all() {
                let spec = match op {
                    CollectiveOp::Allgatherv => CollectiveSpec::Allgatherv {
                        counts: counts::irregular(&mut rng, p, 16 << 20),
                    },
                    CollectiveOp::Allreduce => CollectiveSpec::Allreduce {
                        segs: counts::reduce_widths(&mut rng, p, 16 << 20),
                    },
                    CollectiveOp::Bcast => CollectiveSpec::Bcast {
                        segs: counts::reduce_widths(&mut rng, p, 16 << 20),
                        root: rng.gen_range(p as u64) as usize,
                    },
                    CollectiveOp::Alltoallv => CollectiveSpec::Alltoallv {
                        counts: counts::alltoallv_matrix(&mut rng, p, 4 << 20),
                        p,
                    },
                };
                out.push((format!("{}/{}", kind.name(), op.name()), kind.build(), spec));
            }
        }
        out
    }

    /// Simulated metrics of one bench case as a JSON object.
    fn case_doc(label: &str, topo: &Topology, spec: &CollectiveSpec) -> Json {
        let params = Params::default();
        let mut fields = vec![
            ("case", Json::Str(label.to_string())),
            ("op", Json::Str(spec.op().name().to_string())),
            ("gpus", Json::Num(spec.ranks() as f64)),
            ("total_bytes", Json::Num(spec.total_bytes() as f64)),
        ];
        let mut times = Vec::new();
        for lib in Library::all() {
            let r = run_collective(topo, lib, params, spec, ChunkCfg::none());
            times.push((lib, r));
        }
        for &(lib, r) in &times {
            fields.push((
                match lib {
                    Library::Mpi => "mpi_s",
                    Library::MpiCuda => "mpi_cuda_s",
                    Library::Nccl => "nccl_s",
                },
                Json::Num(r.time),
            ));
        }
        let (winner, best) = auto_collective(topo, params, spec, ChunkCfg::none());
        fields.push(("auto", Json::Str(winner.name().to_string())));
        fields.push(("auto_s", Json::Num(best.time)));
        fields.push(("flows", Json::Num(best.flows as f64)));
        // chunk-pipelining gain on the winner (NCCL Allgatherv is its
        // own pipeline, so the ratio degrades to 1.0 there)
        let chunked = run_collective(topo, winner, params, spec, ChunkCfg::pipelined(4));
        fields.push(("chunked4_s", Json::Num(chunked.time)));
        fields.push(("chunk_speedup", Json::Num(best.time / chunked.time.max(1e-30))));
        obj(fields)
    }

    /// The full deterministic `BENCH_collectives.json` document; cases
    /// fan out over the bounded worker pool in submission order.
    pub fn bench_doc(seed: u64) -> Json {
        let cases = bench_cases(seed);
        let jobs: Vec<_> = cases
            .iter()
            .map(|(label, topo, spec)| move || case_doc(label, topo, spec))
            .collect();
        let docs = crate::util::pool::parallel_map(jobs);
        obj(vec![
            ("bench", Json::Str("bench_collectives".to_string())),
            ("seed", Json::Num(seed as f64)),
            ("cases", Json::Arr(docs)),
        ])
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn cases_cover_every_system_and_op() {
            let cases = bench_cases(42);
            assert_eq!(cases.len(), SystemKind::all().len() * CollectiveOp::all().len());
            for kind in SystemKind::all() {
                for op in CollectiveOp::all() {
                    let label = format!("{}/{}", kind.name(), op.name());
                    assert!(cases.iter().any(|(l, ..)| *l == label), "{label} missing");
                }
            }
        }

        #[test]
        fn doc_is_simulated_only_and_sane() {
            let doc = bench_doc(7);
            let cases = doc.get("cases").unwrap().as_arr().unwrap();
            assert_eq!(cases.len(), 12);
            for c in cases {
                assert!(c.get("auto_s").unwrap().as_f64().unwrap() > 0.0);
                assert!(c.get("mean_s").is_none(), "wall-clock field leaked into the artifact");
                let speedup = c.get("chunk_speedup").unwrap().as_f64().unwrap();
                assert!(speedup.is_finite() && speedup > 0.0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::systems::SystemKind;

    #[test]
    fn op_parse_roundtrip() {
        for op in CollectiveOp::all() {
            assert_eq!(CollectiveOp::parse(op.name()), Some(op));
        }
        assert_eq!(CollectiveOp::parse("broadcast"), Some(CollectiveOp::Bcast));
        assert_eq!(CollectiveOp::parse("reduce-scatter"), None);
    }

    #[test]
    fn every_op_runs_on_every_system_and_library() {
        for kind in SystemKind::all() {
            let topo = kind.build();
            let p = topo.num_gpus().min(4);
            let base: Vec<u64> = (0..p as u64).map(|i| (i + 1) << 18).collect();
            for op in CollectiveOp::all() {
                let spec = CollectiveSpec::from_vector(op, &base);
                for lib in Library::all() {
                    let r = run_collective(&topo, lib, Params::default(), &spec, ChunkCfg::none());
                    assert!(
                        r.time > 0.0 && r.time.is_finite(),
                        "{}/{}/{}: bad time {}",
                        kind.name(),
                        op.name(),
                        lib.name(),
                        r.time
                    );
                    assert!(r.flows > 0 || p == 1, "no flows simulated");
                }
            }
        }
    }

    #[test]
    fn auto_collective_is_argmin_over_libraries() {
        let topo = SystemKind::Dgx1.build();
        let spec = CollectiveSpec::from_vector(CollectiveOp::Allreduce, &[4 << 20; 8]);
        let (winner, best) = auto_collective(&topo, Params::default(), &spec, ChunkCfg::none());
        for lib in Library::all() {
            let r = run_collective(&topo, lib, Params::default(), &spec, ChunkCfg::none());
            assert!(best.time <= r.time, "auto {} lost to {}", winner.name(), lib.name());
        }
    }

    #[test]
    fn allreduce_selection_follows_mean_rule() {
        let params = Params::default();
        assert_eq!(select_allreduce(&params, &[1024; 8]), ReduceAlgo::HalvingDoubling);
        assert_eq!(select_allreduce(&params, &[10 << 20; 8]), ReduceAlgo::Ring);
        // non-power-of-two P can never pick halving/doubling
        assert_eq!(select_allreduce(&params, &[1024; 6]), ReduceAlgo::Ring);
        // irregular: small mean, huge tail — the paper's misselection
        let mut segs = vec![1024u64; 8];
        segs[3] = 400 << 10;
        assert_eq!(select_allreduce(&params, &segs), ReduceAlgo::HalvingDoubling);
    }

    #[test]
    fn from_vector_alltoallv_is_row_uniform_zero_diagonal() {
        let spec = CollectiveSpec::from_vector(CollectiveOp::Alltoallv, &[10, 20, 30]);
        match &spec {
            CollectiveSpec::Alltoallv { counts, p } => {
                assert_eq!(*p, 3);
                for src in 0..3 {
                    assert_eq!(counts[src * 3 + src], 0);
                    for dst in 0..3 {
                        if src != dst {
                            assert_eq!(counts[src * 3 + dst], [10, 20, 30][src]);
                        }
                    }
                }
            }
            _ => panic!("wrong variant"),
        }
        assert_eq!(spec.total_bytes(), 2 * (10 + 20 + 30));
    }

    #[test]
    fn mpi_staging_accounts_device_bytes() {
        let spec = CollectiveSpec::Bcast { segs: vec![4, 6], root: 1 };
        let (down, up) = spec.mpi_staging();
        assert_eq!(down, vec![0, 10]);
        assert_eq!(up, vec![10, 0]);

        let spec = CollectiveSpec::Alltoallv { counts: vec![0, 5, 7, 0], p: 2 };
        let (down, up) = spec.mpi_staging();
        assert_eq!(down, vec![5, 7]);
        assert_eq!(up, vec![7, 5]);
    }

    #[test]
    fn chunking_never_changes_delivery_only_timing() {
        // same spec, chunked vs not: both finite, flows scale with k
        let topo = SystemKind::Dgx1.build();
        let spec = CollectiveSpec::from_vector(CollectiveOp::Allreduce, &[8 << 20; 4]);
        let a = run_collective(&topo, Library::MpiCuda, Params::default(), &spec, ChunkCfg::none());
        let b = run_collective(
            &topo,
            Library::MpiCuda,
            Params::default(),
            &spec,
            ChunkCfg::pipelined(4),
        );
        assert!(a.time.is_finite() && b.time.is_finite());
        assert!(b.flows >= a.flows, "chunking cannot reduce flow count");
    }
}
