//! Communication library models (paper §II): traditional MPI, CUDA-aware
//! MVAPICH ("MPI-CUDA") and NCCL, each implementing the irregular
//! [`CommLibrary::allgatherv`] collective over a simulated topology.
//!
//! Structure:
//! - [`algorithms`]: *logical* collective schedules (ring, Bruck,
//!   recursive doubling, broadcast trees, bcast-series) — library-agnostic
//!   lists of (step, from, to, block) send operations, property-tested
//!   for delivery correctness;
//! - [`transport`]: how one logical send becomes simulator flows for a
//!   given library (host staging, GPUDirect P2P, GDR, pipelined chunks);
//! - [`mpi`] / [`mpi_cuda`] / [`nccl`]: the three libraries, composing an
//!   algorithm choice with a transport;
//! - [`select`]: the `auto` choice — simulates every applicable
//!   (library, algorithm) candidate (including the hierarchical
//!   two-level schedules) on the actual counts and topology, returns
//!   the argmin, and caches decisions per irregularity bucket;
//! - [`params`]: protocol constants and tunables, including the
//!   `MV2_GPUDIRECT_LIMIT` knob the paper sweeps in §V-C;
//! - [`collective`]: the op-generic layer (DESIGN.md §13) — allreduce,
//!   broadcast and alltoallv specs dispatched over the same per-library
//!   compose entry points, with `transport::ChunkCfg` wire chunking.
//!
//! Every library exposes its collective in two forms: a one-shot
//! [`CommLibrary::allgatherv`] that runs in a `Sim` of its own, and a
//! *compose* entry point (`Mpi::compose_with`, `MpiCuda::compose_with`,
//! `Nccl::compose`, or [`compose_allgatherv`] / [`select::compose`] over
//! all of them) that builds the identical subgraph into a **shared**
//! `Sim` behind an optional gate task — what the multi-tenant
//! [`crate::workload`] engine batches concurrent jobs through
//! (DESIGN.md §9).

pub mod algorithms;
pub mod collective;
pub mod mpi;
pub mod mpi_cuda;
pub mod nccl;
pub mod params;
pub mod select;
pub mod transport;

use crate::topology::Topology;

pub use params::Params;

/// Result of one simulated collective.
#[derive(Clone, Copy, Debug)]
pub struct CommResult {
    /// Total wall-clock communication time (seconds), including any
    /// host<->device staging — matching the paper's measurement ("time to
    /// complete the Allgatherv procedure ... including the time to move
    /// data between the host and GPUs, when applicable").
    pub time: f64,
    /// Number of point-to-point flows simulated.
    pub flows: usize,
}

/// A GPU collective communication library model.
pub trait CommLibrary {
    /// Human-readable library name ("MPI", "MPI-CUDA", "NCCL").
    fn name(&self) -> &'static str;

    /// Irregular all-gather: rank r contributes `counts[r]` bytes; on
    /// completion every rank holds all `counts.iter().sum()` bytes.
    /// Rank r runs on GPU r (the paper's sequential rank->device binding,
    /// §III-B). `counts.len()` must not exceed `topo.num_gpus()`.
    fn allgatherv(&self, topo: &Topology, counts: &[u64]) -> CommResult;
}

/// The three libraries of the paper, by name.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Library {
    /// Traditional MPI (MVAPICH, CUDA support disabled): explicit
    /// host staging around a host-to-host collective (§II-A).
    Mpi,
    /// CUDA-aware MVAPICH with GPUDirect P2P/RDMA data paths (§II-A).
    MpiCuda,
    /// NCCL 2.x with the paper's Listing-1 bcast-series Allgatherv
    /// (§II-B).
    Nccl,
}

impl Library {
    /// Display name used in every table/figure.
    pub fn name(self) -> &'static str {
        match self {
            Library::Mpi => "MPI",
            Library::MpiCuda => "MPI-CUDA",
            Library::Nccl => "NCCL",
        }
    }

    /// Parse a library name as accepted by the `agv` CLI's `--lib` flag.
    pub fn parse(s: &str) -> Option<Library> {
        match s.to_ascii_lowercase().as_str() {
            "mpi" => Some(Library::Mpi),
            "mpi-cuda" | "mpicuda" | "cuda" | "mvapich" => Some(Library::MpiCuda),
            "nccl" => Some(Library::Nccl),
            _ => None,
        }
    }

    /// All three libraries, in the paper's plotting order.
    pub fn all() -> [Library; 3] {
        [Library::Mpi, Library::MpiCuda, Library::Nccl]
    }

    /// Instantiate the library model with the given protocol parameters.
    pub fn build(self, params: Params) -> Box<dyn CommLibrary> {
        match self {
            Library::Mpi => Box::new(mpi::Mpi::new(params)),
            Library::MpiCuda => Box::new(mpi_cuda::MpiCuda::new(params)),
            Library::Nccl => Box::new(nccl::Nccl::new(params)),
        }
    }
}

/// Convenience: run a library's allgatherv with default parameters.
///
/// ```
/// use agv_bench::comm::{run_allgatherv, Library};
/// use agv_bench::topology::systems::SystemKind;
///
/// // Irregular contributions on a DGX-1: one dominant block.
/// let topo = SystemKind::Dgx1.build();
/// let counts = [64 << 10, 16 << 20, 256 << 10, 1 << 20];
/// let r = run_allgatherv(Library::Nccl, &topo, &counts);
/// assert!(r.time > 0.0 && r.time.is_finite());
/// assert!(r.flows > 0);
/// ```
pub fn run_allgatherv(lib: Library, topo: &Topology, counts: &[u64]) -> CommResult {
    lib.build(Params::default()).allgatherv(topo, counts)
}

/// Compose one library's Allgatherv into a **shared** simulation,
/// starting only after `gate` completes (`None` = immediately at t=0).
/// Exactly the subgraph [`run_allgatherv`] builds — same MVAPICH
/// mean-size algorithm selection, same transports — so a gate-less
/// composition in a fresh `Sim` reproduces `run_allgatherv` bit-for-bit
/// (the workload differential tests pin this). Returns the op's
/// completion task; the caller owns running the `Sim` and reading the
/// finish time.
pub fn compose_allgatherv(
    sim: &mut crate::sim::Sim,
    lib: Library,
    params: Params,
    counts: &[u64],
    gate: Option<crate::sim::TaskId>,
) -> crate::sim::TaskId {
    match lib {
        Library::Mpi => {
            let sched = mpi::select_algorithm(&params, counts);
            mpi::Mpi::new(params).compose_with(sim, counts, &sched, gate)
        }
        Library::MpiCuda => {
            let sched = mpi::select_algorithm(&params, counts);
            mpi_cuda::MpiCuda::new(params).compose_with(sim, counts, &sched, gate)
        }
        Library::Nccl => nccl::Nccl::new(params).compose(sim, counts, gate),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn library_parse_roundtrip() {
        for l in Library::all() {
            assert_eq!(Library::parse(l.name()), Some(l));
        }
        assert_eq!(Library::parse("mvapich"), Some(Library::MpiCuda));
        assert_eq!(Library::parse("x"), None);
    }
}
