//! Logical collective schedules, independent of transport.
//!
//! A schedule is a sequence of steps; step `s+1` of a rank depends on that
//! rank's sends/receives of step `s`. Each [`SendOp`] moves one or more
//! *blocks* (rank contributions) between ranks. Schedules carry block
//! identity so (a) a logical executor can verify every rank ends up with
//! every block — the delivery-correctness property tests below — and
//! (b) irregular byte counts are preserved per block.
//!
//! Implemented:
//! - [`ring_allgatherv`]: bandwidth-optimal, P-1 steps (MVAPICH large);
//! - [`recursive_doubling_allgatherv`]: log2 P steps, power-of-two P
//!   (MVAPICH small, power-of-two);
//! - [`bruck_allgatherv`]: ceil(log2 P) steps, any P (MVAPICH small);
//! - [`binomial_bcast`]: log-tree broadcast (MPI_Bcast);
//! - [`bcast_series_allgatherv`]: the paper's Listing 1 — Allgatherv as a
//!   series of P broadcasts (what NCCL must do lacking a native routine).

/// One logical point-to-point send: `blocks` identifies which ranks'
/// contributions travel (byte size resolved against `counts`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SendOp {
    /// Sending rank.
    pub from: usize,
    /// Receiving rank.
    pub to: usize,
    /// Which ranks' contributions travel in this send.
    pub blocks: Vec<usize>,
}

impl SendOp {
    /// Byte size of the send given per-rank contribution counts.
    pub fn bytes(&self, counts: &[u64]) -> u64 {
        self.blocks.iter().map(|&b| counts[b]).sum()
    }
}

/// A schedule: steps of concurrent sends. Step boundaries are
/// synchronization points per rank (a rank's step-s+1 ops depend on its
/// step-s ops; different ranks proceed independently unless data flows).
#[derive(Clone, Debug, Default)]
pub struct Schedule {
    /// Steps of concurrent sends, in dependency order.
    pub steps: Vec<Vec<SendOp>>,
}

impl Schedule {
    /// Total number of point-to-point sends across all steps.
    pub fn num_sends(&self) -> usize {
        self.steps.iter().map(|s| s.len()).sum()
    }

    /// Total number of (send, block) transfers — the volume proxy the
    /// conservation property tests assert on.
    pub fn total_block_transfers(&self) -> usize {
        self.steps
            .iter()
            .flat_map(|s| s.iter().map(|op| op.blocks.len()))
            .sum()
    }
}

/// Ring allgatherv: at step s, rank i forwards block (i - s + P) % P to
/// rank (i + 1) % P. After P-1 steps everyone has everything. The
/// `order` permutation maps logical ring position -> rank, letting NCCL
/// run the same schedule over a topology-derived ring.
pub fn ring_allgatherv(p: usize, order: Option<&[usize]>) -> Schedule {
    assert!(p >= 1);
    let identity: Vec<usize> = (0..p).collect();
    let ring = order.unwrap_or(&identity);
    assert_eq!(ring.len(), p);
    let mut steps = Vec::new();
    for s in 0..p.saturating_sub(1) {
        let mut ops = Vec::new();
        for pos in 0..p {
            let from = ring[pos];
            let to = ring[(pos + 1) % p];
            let block = ring[(pos + p - s) % p];
            ops.push(SendOp { from, to, blocks: vec![block] });
        }
        steps.push(ops);
    }
    Schedule { steps }
}

/// Recursive doubling: requires power-of-two P; at step s ranks exchange
/// everything they hold with their partner at distance 2^s.
pub fn recursive_doubling_allgatherv(p: usize) -> Schedule {
    assert!(p.is_power_of_two(), "recursive doubling needs power-of-two P");
    let mut held: Vec<Vec<usize>> = (0..p).map(|r| vec![r]).collect();
    let mut steps = Vec::new();
    let mut dist = 1;
    while dist < p {
        let mut ops = Vec::new();
        let mut new_held = held.clone();
        for r in 0..p {
            let partner = r ^ dist;
            ops.push(SendOp { from: r, to: partner, blocks: held[r].clone() });
            new_held[partner].extend(held[r].iter().copied());
        }
        for h in new_held.iter_mut() {
            h.sort_unstable();
            h.dedup();
        }
        held = new_held;
        steps.push(ops);
        dist <<= 1;
    }
    Schedule { steps }
}

/// Bruck allgather(v): works for any P in ceil(log2 P) steps; rank r
/// sends everything it holds to rank (r - 2^s + P) % P at step s.
pub fn bruck_allgatherv(p: usize) -> Schedule {
    assert!(p >= 1);
    let mut held: Vec<Vec<usize>> = (0..p).map(|r| vec![r]).collect();
    let mut steps = Vec::new();
    let mut dist = 1;
    while dist < p {
        let mut ops = Vec::new();
        let mut new_held = held.clone();
        for r in 0..p {
            let to = (r + p - dist) % p;
            // send the blocks the receiver does not yet have
            let missing: Vec<usize> = held[r]
                .iter()
                .copied()
                .filter(|b| !held[to].contains(b))
                .collect();
            if !missing.is_empty() {
                new_held[to].extend(missing.iter().copied());
                ops.push(SendOp { from: r, to, blocks: missing });
            }
        }
        for h in new_held.iter_mut() {
            h.sort_unstable();
            h.dedup();
        }
        held = new_held;
        steps.push(ops);
        dist <<= 1;
    }
    Schedule { steps }
}

/// Binomial-tree broadcast of `root`'s block to all P ranks (MPI_Bcast).
pub fn binomial_bcast(p: usize, root: usize) -> Schedule {
    assert!(root < p);
    // Relative rank space: rr = (r - root) mod p; rr 0 is the root.
    // Distance halves each step so every sender already holds the data:
    // step 0 only the root sends (to rr = 2^(k-1)), step 1 both holders
    // send, etc.
    let mut steps = Vec::new();
    if p > 1 {
        let mut dist = p.next_power_of_two() / 2;
        while dist >= 1 {
            let mut ops = Vec::new();
            for rr in (0..p).step_by(2 * dist) {
                if rr + dist < p {
                    let from = (rr + root) % p;
                    let to = (rr + dist + root) % p;
                    ops.push(SendOp { from, to, blocks: vec![root] });
                }
            }
            steps.push(ops);
            dist /= 2;
        }
    }
    Schedule { steps }
}

/// Ring broadcast (what NCCL uses): root sends around the ring; with
/// chunk pipelining the transport turns this into a pipeline. `order`
/// gives the ring permutation (topology-detected for NCCL).
pub fn ring_bcast(p: usize, root: usize, order: Option<&[usize]>) -> Schedule {
    let identity: Vec<usize> = (0..p).collect();
    let ring = order.unwrap_or(&identity);
    assert_eq!(ring.len(), p);
    let root_pos = ring.iter().position(|&r| r == root).expect("root not in ring");
    let mut steps = Vec::new();
    for s in 0..p.saturating_sub(1) {
        let from = ring[(root_pos + s) % p];
        let to = ring[(root_pos + s + 1) % p];
        steps.push(vec![SendOp { from, to, blocks: vec![root] }]);
    }
    Schedule { steps }
}

/// Paper Listing 1: Allgatherv recreated as a series of broadcasts, one
/// per rank (NCCL has no native Allgatherv). Broadcasts execute
/// back-to-back on the stream; each contributes its own schedule and the
/// transport layer adds the per-call launch overhead.
pub fn bcast_series_allgatherv(p: usize, order: Option<&[usize]>) -> Vec<Schedule> {
    (0..p).map(|root| ring_bcast(p, root, order)).collect()
}

// ---------------------------------------------------------------------------
// Logical executor: verifies delivery correctness of any schedule.
// ---------------------------------------------------------------------------

/// Execute a schedule over per-rank block sets; returns the final
/// holdings. A send is only legal if the sender holds every block it
/// ships at that step (asserted).
pub fn execute(p: usize, schedules: &[&Schedule]) -> Vec<Vec<bool>> {
    let mut held = vec![vec![false; p]; p];
    for (r, h) in held.iter_mut().enumerate() {
        h[r] = true;
    }
    for sched in schedules {
        for step in &sched.steps {
            // all sends in a step read pre-step state
            let snapshot = held.clone();
            for op in step {
                for &b in &op.blocks {
                    assert!(
                        snapshot[op.from][b],
                        "rank {} sends block {} it does not hold",
                        op.from, b
                    );
                    held[op.to][b] = true;
                }
            }
        }
    }
    held
}

/// True iff every rank holds every block.
pub fn all_delivered(held: &[Vec<bool>]) -> bool {
    held.iter().all(|h| h.iter().all(|&x| x))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;

    #[test]
    fn ring_delivers_all_p() {
        for p in 1..=17 {
            let s = ring_allgatherv(p, None);
            assert!(all_delivered(&execute(p, &[&s])), "p={p}");
            assert_eq!(s.steps.len(), p.saturating_sub(1));
        }
    }

    #[test]
    fn ring_with_permuted_order() {
        let order = [3usize, 1, 4, 0, 2];
        let s = ring_allgatherv(5, Some(&order));
        assert!(all_delivered(&execute(5, &[&s])));
    }

    #[test]
    fn recursive_doubling_delivers_powers_of_two() {
        for p in [1usize, 2, 4, 8, 16] {
            let s = recursive_doubling_allgatherv(p);
            assert!(all_delivered(&execute(p, &[&s])), "p={p}");
            assert_eq!(s.steps.len(), (p as f64).log2() as usize);
        }
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn recursive_doubling_rejects_non_pow2() {
        let _ = recursive_doubling_allgatherv(6);
    }

    #[test]
    fn bruck_delivers_any_p() {
        for p in 1..=17 {
            let s = bruck_allgatherv(p);
            assert!(all_delivered(&execute(p, &[&s])), "p={p}");
            assert!(s.steps.len() <= (p as f64).log2().ceil() as usize + 1);
        }
    }

    #[test]
    fn binomial_bcast_reaches_everyone() {
        for p in 1..=17 {
            for root in [0, p / 2, p - 1] {
                let s = binomial_bcast(p, root.min(p - 1));
                let held = execute(p, &[&s]);
                for r in 0..p {
                    assert!(held[r][root.min(p - 1)], "p={p} root={root} rank={r}");
                }
            }
        }
    }

    #[test]
    fn bcast_series_is_a_valid_allgatherv() {
        for p in 1..=16 {
            let series = bcast_series_allgatherv(p, None);
            assert_eq!(series.len(), p);
            let refs: Vec<&Schedule> = series.iter().collect();
            assert!(all_delivered(&execute(p, &refs)), "p={p}");
        }
    }

    #[test]
    fn sendop_bytes_uses_counts() {
        let op = SendOp { from: 0, to: 1, blocks: vec![0, 2] };
        assert_eq!(op.bytes(&[10, 20, 30]), 40);
    }

    #[test]
    fn ring_step_volume_is_irregular_counts() {
        // with irregular counts the per-step bytes differ per rank
        let counts = [100u64, 5, 60];
        let s = ring_allgatherv(3, None);
        let step0: Vec<u64> = s.steps[0].iter().map(|op| op.bytes(&counts)).collect();
        assert_eq!(step0.len(), 3);
        assert!(step0.contains(&100) && step0.contains(&5) && step0.contains(&60));
    }

    #[test]
    fn prop_random_ring_orders_deliver() {
        check("ring-orders", 64, |rng| {
            let p = 2 + rng.gen_range(14) as usize;
            let mut order: Vec<usize> = (0..p).collect();
            rng.shuffle(&mut order);
            let s = ring_allgatherv(p, Some(&order));
            prop_assert!(all_delivered(&execute(p, &[&s])), "p={p} order={order:?}");
            Ok(())
        });
    }

    #[test]
    fn prop_bcast_series_any_order() {
        check("bcast-series-orders", 32, |rng| {
            let p = 2 + rng.gen_range(10) as usize;
            let mut order: Vec<usize> = (0..p).collect();
            rng.shuffle(&mut order);
            let series = bcast_series_allgatherv(p, Some(&order));
            let refs: Vec<&Schedule> = series.iter().collect();
            prop_assert!(all_delivered(&execute(p, &refs)), "p={p}");
            Ok(())
        });
    }

    #[test]
    fn prop_block_conservation_ring() {
        // every ring send ships exactly one block, P*(P-1) transfers total
        check("ring-conservation", 32, |rng| {
            let p = 2 + rng.gen_range(14) as usize;
            let s = ring_allgatherv(p, None);
            prop_assert!(s.total_block_transfers() == p * (p - 1));
            Ok(())
        });
    }
}
