//! Logical collective schedules, independent of transport.
//!
//! A schedule is a sequence of steps; step `s+1` of a rank depends on that
//! rank's sends/receives of step `s`. Each [`SendOp`] moves one or more
//! *blocks* (rank contributions) between ranks. Schedules carry block
//! identity so (a) a logical executor can verify every rank ends up with
//! every block — the delivery-correctness property tests below — and
//! (b) irregular byte counts are preserved per block.
//!
//! Implemented:
//! - [`ring_allgatherv`]: bandwidth-optimal, P-1 steps (MVAPICH large);
//! - [`recursive_doubling_allgatherv`]: log2 P steps, power-of-two P
//!   (MVAPICH small, power-of-two);
//! - [`bruck_allgatherv`]: ceil(log2 P) steps, any P (MVAPICH small);
//! - [`binomial_bcast`]: log-tree broadcast (MPI_Bcast);
//! - [`bcast_series_allgatherv`]: the paper's Listing 1 — Allgatherv as a
//!   series of P broadcasts (what NCCL must do lacking a native routine).
//!
//! The collective suite (DESIGN.md §13) widens the block-index space:
//! - [`ring_allreduce`] / [`halving_doubling_allreduce`]: two-phase
//!   [`ReduceSchedule`]s over P vector *segments* (reduce-scatter then
//!   allgather; recursive halving then doubling);
//! - [`binomial_bcast_msg`] / [`scatter_allgather_bcast`] /
//!   [`ring_bcast_msg`]: broadcast of a root *message* split into
//!   segments (vs [`binomial_bcast`]'s single rank-contribution block);
//! - [`pairwise_alltoallv`]: P² (src, dst) blocks, one step per offset.
//!
//! Delivery oracles: [`execute`] (allgatherv holdings),
//! [`execute_from`] (arbitrary initial holdings — bcast, alltoallv) and
//! [`execute_allreduce`] (contribution-coverage bitmasks, which reject
//! schedules that double-add a contribution or forward a partial sum as
//! final).

/// One logical point-to-point send: `blocks` identifies which ranks'
/// contributions travel (byte size resolved against `counts`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SendOp {
    /// Sending rank.
    pub from: usize,
    /// Receiving rank.
    pub to: usize,
    /// Which ranks' contributions travel in this send.
    pub blocks: Vec<usize>,
}

impl SendOp {
    /// Byte size of the send given per-rank contribution counts.
    pub fn bytes(&self, counts: &[u64]) -> u64 {
        self.blocks.iter().map(|&b| counts[b]).sum()
    }
}

/// A schedule: steps of concurrent sends. Step boundaries are
/// synchronization points per rank (a rank's step-s+1 ops depend on its
/// step-s ops; different ranks proceed independently unless data flows).
#[derive(Clone, Debug, Default)]
pub struct Schedule {
    /// Steps of concurrent sends, in dependency order.
    pub steps: Vec<Vec<SendOp>>,
}

impl Schedule {
    /// Total number of point-to-point sends across all steps.
    pub fn num_sends(&self) -> usize {
        self.steps.iter().map(|s| s.len()).sum()
    }

    /// Total number of (send, block) transfers — the volume proxy the
    /// conservation property tests assert on.
    pub fn total_block_transfers(&self) -> usize {
        self.steps
            .iter()
            .flat_map(|s| s.iter().map(|op| op.blocks.len()))
            .sum()
    }

    /// Total bytes this schedule puts on the wire given per-block sizes
    /// — what the closed-form conformance oracles compare against.
    pub fn wire_bytes(&self, counts: &[u64]) -> u64 {
        self.steps
            .iter()
            .flat_map(|s| s.iter().map(|op| op.bytes(counts)))
            .sum()
    }

    /// Per-block transfer counts (how many sends ship each block).
    pub fn block_transfer_counts(&self, blocks: usize) -> Vec<usize> {
        let mut per = vec![0usize; blocks];
        for op in self.steps.iter().flatten() {
            for &b in &op.blocks {
                per[b] += 1;
            }
        }
        per
    }
}

/// Ring allgatherv: at step s, rank i forwards block (i - s + P) % P to
/// rank (i + 1) % P. After P-1 steps everyone has everything. The
/// `order` permutation maps logical ring position -> rank, letting NCCL
/// run the same schedule over a topology-derived ring.
pub fn ring_allgatherv(p: usize, order: Option<&[usize]>) -> Schedule {
    assert!(p >= 1);
    let identity: Vec<usize> = (0..p).collect();
    let ring = order.unwrap_or(&identity);
    assert_eq!(ring.len(), p);
    let mut steps = Vec::new();
    for s in 0..p.saturating_sub(1) {
        let mut ops = Vec::new();
        for pos in 0..p {
            let from = ring[pos];
            let to = ring[(pos + 1) % p];
            let block = ring[(pos + p - s) % p];
            ops.push(SendOp { from, to, blocks: vec![block] });
        }
        steps.push(ops);
    }
    Schedule { steps }
}

/// Recursive doubling: requires power-of-two P; at step s ranks exchange
/// everything they hold with their partner at distance 2^s.
///
/// Closed form (no held-set bookkeeping, so schedule generation is
/// output-linear and survives the 4096-rank fabrics): entering the step
/// with distance `dist = 2^s`, rank r holds exactly the aligned block
/// window `[(r / dist)·dist, (r / dist)·dist + dist)` — its own block
/// widened by each earlier exchange — and ships that whole window,
/// ascending, to `r ^ dist`. The test module keeps the original
/// set-tracking builder as an executable specification and asserts
/// step-for-step, op-for-op equality.
pub fn recursive_doubling_allgatherv(p: usize) -> Schedule {
    assert!(p.is_power_of_two(), "recursive doubling needs power-of-two P");
    let mut steps = Vec::new();
    let mut dist = 1;
    while dist < p {
        let mut ops = Vec::with_capacity(p);
        for r in 0..p {
            let base = r & !(dist - 1);
            ops.push(SendOp { from: r, to: r ^ dist, blocks: (base..base + dist).collect() });
        }
        steps.push(ops);
        dist <<= 1;
    }
    Schedule { steps }
}

/// Bruck allgather(v): works for any P in ceil(log2 P) steps; rank r
/// sends everything it holds to rank (r - 2^s + P) % P at step s.
///
/// Closed form (the original membership-scanning builder was O(P³) and
/// dominated schedule generation at 4096 ranks): entering the step with
/// distance `dist`, rank r holds the cyclic window {r, r+1, …, r+dist−1}
/// (mod P) and its receiver `(r − dist) mod P` holds the window just
/// behind it, so the blocks the receiver is missing are exactly
/// `{(r + i) mod P : i < min(dist, P − dist)}` — the leading part of
/// r's window that the two windows don't share once they wrap. Blocks
/// are listed in ascending numeric order, matching the sorted held-set
/// order of the original builder (kept in the test module as the
/// executable specification, asserted equal for every P up to 33).
pub fn bruck_allgatherv(p: usize) -> Schedule {
    assert!(p >= 1);
    let mut steps = Vec::new();
    let mut dist = 1;
    while dist < p {
        let m = dist.min(p - dist);
        let mut ops = Vec::with_capacity(p);
        for r in 0..p {
            let mut blocks: Vec<usize> = (0..m).map(|i| (r + i) % p).collect();
            blocks.sort_unstable();
            ops.push(SendOp { from: r, to: (r + p - dist) % p, blocks });
        }
        steps.push(ops);
        dist <<= 1;
    }
    Schedule { steps }
}

/// Binomial-tree broadcast of `root`'s block to all P ranks (MPI_Bcast).
pub fn binomial_bcast(p: usize, root: usize) -> Schedule {
    assert!(root < p);
    // Relative rank space: rr = (r - root) mod p; rr 0 is the root.
    // Distance halves each step so every sender already holds the data:
    // step 0 only the root sends (to rr = 2^(k-1)), step 1 both holders
    // send, etc.
    let mut steps = Vec::new();
    if p > 1 {
        let mut dist = p.next_power_of_two() / 2;
        while dist >= 1 {
            let mut ops = Vec::new();
            for rr in (0..p).step_by(2 * dist) {
                if rr + dist < p {
                    let from = (rr + root) % p;
                    let to = (rr + dist + root) % p;
                    ops.push(SendOp { from, to, blocks: vec![root] });
                }
            }
            steps.push(ops);
            dist /= 2;
        }
    }
    Schedule { steps }
}

/// Ring broadcast (what NCCL uses): root sends around the ring; with
/// chunk pipelining the transport turns this into a pipeline. `order`
/// gives the ring permutation (topology-detected for NCCL).
pub fn ring_bcast(p: usize, root: usize, order: Option<&[usize]>) -> Schedule {
    let identity: Vec<usize> = (0..p).collect();
    let ring = order.unwrap_or(&identity);
    assert_eq!(ring.len(), p);
    let root_pos = ring.iter().position(|&r| r == root).expect("root not in ring");
    let mut steps = Vec::new();
    for s in 0..p.saturating_sub(1) {
        let from = ring[(root_pos + s) % p];
        let to = ring[(root_pos + s + 1) % p];
        steps.push(vec![SendOp { from, to, blocks: vec![root] }]);
    }
    Schedule { steps }
}

/// Paper Listing 1: Allgatherv recreated as a series of broadcasts, one
/// per rank (NCCL has no native Allgatherv). Broadcasts execute
/// back-to-back on the stream; each contributes its own schedule and the
/// transport layer adds the per-call launch overhead.
pub fn bcast_series_allgatherv(p: usize, order: Option<&[usize]>) -> Vec<Schedule> {
    (0..p).map(|root| ring_bcast(p, root, order)).collect()
}

/// Which algorithm the group leaders run among themselves in a
/// hierarchical schedule (phase 2 of [`hierarchical_allgatherv`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LeaderAlgo {
    /// Ring over the leader set: G-1 steps, bandwidth-optimal — each
    /// group's block set crosses every inter-group boundary exactly once.
    Ring,
    /// Bruck over the leader set: ceil(log2 G) steps, latency-optimal.
    Bruck,
}

/// Two-level (hierarchical) Allgatherv over a node grouping (Awan et
/// al.'s dense-GPU two-level design; see DESIGN.md §3):
///
/// 1. **intra-group exchange** — one step in which every member sends
///    its own block to every other member of its group (the NVLink mesh
///    absorbs the fan-out; afterwards each member, including the group
///    leader `groups[g][0]`, holds its whole group);
/// 2. **inter-group allgatherv among the leaders** — ring or Bruck over
///    the leader set, moving whole *group block sets*; only these sends
///    cross group (node) boundaries;
/// 3. **intra-group dissemination of the remote blocks** — a binomial
///    tree per group, rooted at the leader, shipping every block *not*
///    in the group (members already own the local ones from phase 1).
///    The power-of-two strides land on NVLink edges on DGX-class nodes.
///
/// Every block still moves exactly P-1 times (the delivery-minimal
/// count shared by all flat Allgatherv schedules here): local members
/// get it in phase 1, leaders in phase 2, remote members in phase 3 —
/// the conformance harness asserts this closed form per block.
///
/// `groups` must partition `0..p`; group g's leader is `groups[g][0]`.
pub fn hierarchical_allgatherv(p: usize, groups: &[Vec<usize>], inter: LeaderAlgo) -> Schedule {
    assert!(p >= 1 && !groups.is_empty(), "need ranks and at least one group");
    let mut seen = vec![false; p];
    for g in groups {
        assert!(!g.is_empty(), "empty group");
        for &r in g {
            assert!(r < p && !seen[r], "groups must partition 0..{p}: rank {r}");
            seen[r] = true;
        }
    }
    assert!(seen.iter().all(|&s| s), "groups must cover every rank 0..{p}");
    let g_count = groups.len();
    let leaders: Vec<usize> = groups.iter().map(|g| g[0]).collect();
    let mut steps: Vec<Vec<SendOp>> = Vec::new();

    // Phase 1: one-step all-pairs exchange inside each group.
    let mut exchange = Vec::new();
    for g in groups {
        for &from in g {
            for &to in g {
                if from != to {
                    exchange.push(SendOp { from, to, blocks: vec![from] });
                }
            }
        }
    }
    if !exchange.is_empty() {
        steps.push(exchange);
    }

    // Phase 2: allgatherv among the leaders; the unit of exchange is a
    // whole group's block set.
    match inter {
        LeaderAlgo::Ring => {
            // step s: leader at position i forwards group (i - s) mod G.
            for s in 0..g_count.saturating_sub(1) {
                let mut ops = Vec::new();
                for pos in 0..g_count {
                    let src_group = (pos + g_count - s) % g_count;
                    ops.push(SendOp {
                        from: leaders[pos],
                        to: leaders[(pos + 1) % g_count],
                        blocks: groups[src_group].clone(),
                    });
                }
                steps.push(ops);
            }
        }
        LeaderAlgo::Bruck => {
            // held group-ids per leader position; send what the receiver
            // is missing (exactly one delivery per (group, leader)).
            let mut held: Vec<Vec<usize>> = (0..g_count).map(|i| vec![i]).collect();
            let mut dist = 1;
            while dist < g_count {
                let mut ops = Vec::new();
                let mut new_held = held.clone();
                for pos in 0..g_count {
                    let to_pos = (pos + g_count - dist) % g_count;
                    let missing: Vec<usize> = held[pos]
                        .iter()
                        .copied()
                        .filter(|gi| !held[to_pos].contains(gi))
                        .collect();
                    if !missing.is_empty() {
                        new_held[to_pos].extend(missing.iter().copied());
                        let blocks: Vec<usize> = missing
                            .iter()
                            .flat_map(|&gi| groups[gi].iter().copied())
                            .collect();
                        ops.push(SendOp {
                            from: leaders[pos],
                            to: leaders[to_pos],
                            blocks,
                        });
                    }
                }
                for h in new_held.iter_mut() {
                    h.sort_unstable();
                    h.dedup();
                }
                held = new_held;
                steps.push(ops);
                dist <<= 1;
            }
        }
    }

    // Phase 3: per-group binomial dissemination of the remote blocks,
    // rooted at the leader (relative index 0). Rounds are merged across
    // groups so independent groups proceed concurrently.
    let mut rounds: Vec<Vec<SendOp>> = Vec::new();
    for g in groups {
        let k = g.len();
        if k < 2 || g_count < 2 {
            continue; // nothing remote, or nobody to forward to
        }
        let in_group = |b: usize| g.contains(&b);
        let remote: Vec<usize> = (0..p).filter(|&b| !in_group(b)).collect();
        let mut round = 0usize;
        let mut dist = k.next_power_of_two() / 2;
        while dist >= 1 {
            let mut ops = Vec::new();
            for rr in (0..k).step_by(2 * dist) {
                if rr + dist < k {
                    ops.push(SendOp {
                        from: g[rr],
                        to: g[rr + dist],
                        blocks: remote.clone(),
                    });
                }
            }
            if rounds.len() <= round {
                rounds.push(Vec::new());
            }
            rounds[round].extend(ops);
            round += 1;
            dist /= 2;
        }
    }
    steps.extend(rounds.into_iter().filter(|r| !r.is_empty()));

    Schedule { steps }
}

// ---------------------------------------------------------------------------
// Collective suite: allreduce, message broadcast, alltoallv (DESIGN.md §13).
// ---------------------------------------------------------------------------

/// A two-phase reduction schedule: a reduce phase whose receives *add*
/// into the destination buffer, then a gather phase whose receives copy
/// final values. Keeping the phases apart (instead of tagging
/// [`SendOp`]s) is what lets [`execute_allreduce`] verify reduction
/// correctness — a send in `reduce` merges contribution coverage, a
/// send in `gather` must ship an already fully-reduced segment.
///
/// Block indices are vector *segments* `0..P` (the reduced vector cut
/// into P pieces, irregular sizes allowed); `counts[s]` is segment s's
/// byte size.
#[derive(Clone, Debug)]
pub struct ReduceSchedule {
    /// Reduce-scatter phase: receives accumulate.
    pub reduce: Schedule,
    /// Allgather phase: receives copy final segments.
    pub gather: Schedule,
}

impl ReduceSchedule {
    /// Total synchronized rounds across both phases.
    pub fn rounds(&self) -> usize {
        self.reduce.steps.len() + self.gather.steps.len()
    }

    /// The phases in execution order, for the phase-agnostic transports.
    pub fn phases(&self) -> [&Schedule; 2] {
        [&self.reduce, &self.gather]
    }

    /// Total wire bytes across both phases.
    pub fn wire_bytes(&self, counts: &[u64]) -> u64 {
        self.reduce.wire_bytes(counts) + self.gather.wire_bytes(counts)
    }
}

/// Ring allreduce: reduce-scatter then allgather around one ring, the
/// bandwidth-optimal 2(P−1)-step schedule NCCL rings implement. During
/// reduce-scatter step s, ring position i sends segment (i − s) mod P to
/// position i+1 (receiver adds); after P−1 steps position i owns the
/// fully reduced segment (i+1) mod P. The allgather phase then rotates
/// the reduced segments the rest of the way: step s, position i sends
/// segment (i + 1 − s) mod P. Every segment crosses exactly 2(P−1)
/// wires, so total wire bytes are 2(P−1)·Σcounts — the closed form the
/// conformance harness machine-checks. `order` maps ring position →
/// rank (segment indices are position-based and unaffected).
pub fn ring_allreduce(p: usize, order: Option<&[usize]>) -> ReduceSchedule {
    assert!(p >= 1);
    let identity: Vec<usize> = (0..p).collect();
    let ring = order.unwrap_or(&identity);
    assert_eq!(ring.len(), p);
    let mut reduce = Vec::new();
    let mut gather = Vec::new();
    for s in 0..p.saturating_sub(1) {
        let mut rs_ops = Vec::new();
        let mut ag_ops = Vec::new();
        for i in 0..p {
            let from = ring[i];
            let to = ring[(i + 1) % p];
            rs_ops.push(SendOp { from, to, blocks: vec![(i + p - s) % p] });
            ag_ops.push(SendOp { from, to, blocks: vec![(i + 1 + p - s) % p] });
        }
        reduce.push(rs_ops);
        gather.push(ag_ops);
    }
    ReduceSchedule {
        reduce: Schedule { steps: reduce },
        gather: Schedule { steps: gather },
    }
}

/// Recursive-halving/doubling allreduce (power-of-two P): the
/// latency-optimal 2·log2 P-round schedule MVAPICH picks for short
/// vectors. The halving phase bisects each rank's working segment set
/// by the partner-distance bit (keep the half containing yourself, send
/// the half containing the partner, receiver adds); after log2 P rounds
/// rank r owns the fully reduced segment r. The doubling phase is
/// exactly [`recursive_doubling_allgatherv`] over the segments. Both
/// phases move every segment P−1 times, so the 2(P−1)·Σcounts wire-byte
/// closed form is shared with [`ring_allreduce`].
pub fn halving_doubling_allreduce(p: usize) -> ReduceSchedule {
    assert!(p.is_power_of_two(), "recursive halving/doubling needs power-of-two P");
    let mut held: Vec<Vec<usize>> = (0..p).map(|_| (0..p).collect()).collect();
    let mut steps = Vec::new();
    let mut dist = p / 2;
    while dist >= 1 {
        let mut ops = Vec::new();
        let mut new_held = held.clone();
        for r in 0..p {
            let partner = r ^ dist;
            let send_blocks: Vec<usize> = held[r]
                .iter()
                .copied()
                .filter(|&s| (s & dist) == (partner & dist))
                .collect();
            new_held[r].retain(|&s| (s & dist) == (r & dist));
            ops.push(SendOp { from: r, to: partner, blocks: send_blocks });
        }
        held = new_held;
        steps.push(ops);
        dist /= 2;
    }
    ReduceSchedule {
        reduce: Schedule { steps },
        gather: recursive_doubling_allgatherv(p),
    }
}

/// Binomial-tree broadcast of a root *message* split into `segs`
/// segments: the [`binomial_bcast`] tree, but every edge ships the whole
/// segment list (block indices 0..segs, sized by the counts vector).
/// ⌈log2 P⌉ rounds; each segment crosses P−1 wires.
pub fn binomial_bcast_msg(p: usize, root: usize, segs: usize) -> Schedule {
    assert!(root < p);
    let all: Vec<usize> = (0..segs).collect();
    let mut steps = Vec::new();
    if p > 1 {
        let mut dist = p.next_power_of_two() / 2;
        while dist >= 1 {
            let mut ops = Vec::new();
            for rr in (0..p).step_by(2 * dist) {
                if rr + dist < p {
                    let from = (rr + root) % p;
                    let to = (rr + dist + root) % p;
                    ops.push(SendOp { from, to, blocks: all.clone() });
                }
            }
            steps.push(ops);
            dist /= 2;
        }
    }
    Schedule { steps }
}

/// Ring broadcast of a segmented root message (NCCL's pipeline shape):
/// each ring hop forwards all `segs` segments; with a chunked transport
/// ([`crate::comm::transport::ChunkCfg`]) the hops overlap into the
/// classic NCCL pipeline. P−1 rounds.
pub fn ring_bcast_msg(p: usize, root: usize, segs: usize, order: Option<&[usize]>) -> Schedule {
    let identity: Vec<usize> = (0..p).collect();
    let ring = order.unwrap_or(&identity);
    assert_eq!(ring.len(), p);
    let root_pos = ring.iter().position(|&r| r == root).expect("root not in ring");
    let all: Vec<usize> = (0..segs).collect();
    let mut steps = Vec::new();
    for s in 0..p.saturating_sub(1) {
        let from = ring[(root_pos + s) % p];
        let to = ring[(root_pos + s + 1) % p];
        steps.push(vec![SendOp { from, to, blocks: all.clone() }]);
    }
    Schedule { steps }
}

/// The two phases of a scatter-allgather broadcast.
#[derive(Clone, Debug)]
pub struct BcastSchedule {
    /// Binomial scatter: each subtree edge ships the subtree's segments.
    pub scatter: Schedule,
    /// Ring allgather of the scattered segments.
    pub gather: Schedule,
}

impl BcastSchedule {
    /// Total synchronized rounds: ⌈log2 P⌉ + (P−1).
    pub fn rounds(&self) -> usize {
        self.scatter.steps.len() + self.gather.steps.len()
    }

    /// The phases in execution order.
    pub fn phases(&self) -> [&Schedule; 2] {
        [&self.scatter, &self.gather]
    }

    /// Total wire bytes across both phases.
    pub fn wire_bytes(&self, counts: &[u64]) -> u64 {
        self.scatter.wire_bytes(counts) + self.gather.wire_bytes(counts)
    }
}

/// Scatter-allgather (van de Geijn) broadcast: the bandwidth-optimal
/// large-message MPI_Bcast. The root's message is cut into P segments;
/// a binomial scatter ships each subtree its segment range (segment s
/// travels popcount(s) hops — its depth in the tree, in relative-rank
/// space), leaving relative rank x owning segment x; a ring allgather
/// then moves every segment the remaining P−1 times. Block indices are
/// segments 0..P in relative-rank space (rel x = (rank − root) mod P).
pub fn scatter_allgather_bcast(p: usize, root: usize) -> BcastSchedule {
    assert!(root < p);
    let abs = |rr: usize| (rr + root) % p;
    let mut scatter = Vec::new();
    if p > 1 {
        let mut dist = p.next_power_of_two() / 2;
        while dist >= 1 {
            let mut ops = Vec::new();
            for rr in (0..p).step_by(2 * dist) {
                if rr + dist < p {
                    let hi = (rr + 2 * dist).min(p);
                    ops.push(SendOp {
                        from: abs(rr),
                        to: abs(rr + dist),
                        blocks: (rr + dist..hi).collect(),
                    });
                }
            }
            scatter.push(ops);
            dist /= 2;
        }
    }
    // ring allgather over the scattered segments: rel rank i starts
    // owning segment i; step s, rel i forwards segment (i − s) mod p
    let mut gather = Vec::new();
    for s in 0..p.saturating_sub(1) {
        let mut ops = Vec::new();
        for i in 0..p {
            ops.push(SendOp {
                from: abs(i),
                to: abs((i + 1) % p),
                blocks: vec![(i + p - s) % p],
            });
        }
        gather.push(ops);
    }
    BcastSchedule {
        scatter: Schedule { steps: scatter },
        gather: Schedule { steps: gather },
    }
}

/// Pairwise-exchange alltoallv: P−1 steps; at step s (1-based), rank i
/// sends its block for rank (i + s) mod P. Block indices are the P²
/// (src, dst) pairs flattened src-major — block `src·P + dst` holds the
/// bytes src sends dst (`counts[src * p + dst]`), so irregular count
/// *matrices* are preserved per pair. Every off-diagonal block crosses
/// exactly one wire; diagonal blocks never move.
pub fn pairwise_alltoallv(p: usize) -> Schedule {
    assert!(p >= 1);
    let mut steps = Vec::new();
    for s in 1..p {
        let mut ops = Vec::new();
        for i in 0..p {
            let to = (i + s) % p;
            ops.push(SendOp { from: i, to, blocks: vec![i * p + to] });
        }
        steps.push(ops);
    }
    Schedule { steps }
}

// ---------------------------------------------------------------------------
// Logical executor: verifies delivery correctness of any schedule.
// ---------------------------------------------------------------------------

/// Execute a schedule over per-rank block sets; returns the final
/// holdings. A send is only legal if the sender holds every block it
/// ships at that step (asserted). Initial holdings are the allgatherv
/// convention — rank r holds block r; use [`execute_from`] for other
/// collectives.
pub fn execute(p: usize, schedules: &[&Schedule]) -> Vec<Vec<bool>> {
    let mut init = vec![vec![false; p]; p];
    for (r, h) in init.iter_mut().enumerate() {
        h[r] = true;
    }
    execute_from(p, p, &init, schedules)
}

/// Execute schedules over an arbitrary block space with explicit
/// initial holdings (`init[r][b]`): the general delivery oracle behind
/// broadcast (root holds every segment) and alltoallv (rank i holds row
/// i of the count matrix). Same step-snapshot and send-legality rules
/// as [`execute`].
pub fn execute_from(
    p: usize,
    blocks: usize,
    init: &[Vec<bool>],
    schedules: &[&Schedule],
) -> Vec<Vec<bool>> {
    assert_eq!(init.len(), p, "one initial holding set per rank");
    let mut held: Vec<Vec<bool>> = init.to_vec();
    for h in &held {
        assert_eq!(h.len(), blocks, "one holding flag per block");
    }
    for sched in schedules {
        for step in &sched.steps {
            // all sends in a step read pre-step state
            let snapshot = held.clone();
            for op in step {
                for &b in &op.blocks {
                    assert!(
                        snapshot[op.from][b],
                        "rank {} sends block {} it does not hold",
                        op.from, b
                    );
                    held[op.to][b] = true;
                }
            }
        }
    }
    held
}

/// True iff every rank holds every block.
pub fn all_delivered(held: &[Vec<bool>]) -> bool {
    held.iter().all(|h| h.iter().all(|&x| x))
}

/// Verify a [`ReduceSchedule`] computes a correct allreduce over P
/// segments, P ≤ 64. The reduce phase tracks per-(rank, segment)
/// contribution *coverage* bitmasks (a receive unions the sender's
/// pre-step coverage into the receiver's — the algebra of `+=` on
/// disjoint partial sums); the gather phase then only lets a rank
/// forward a segment whose coverage is complete (asserted), which is
/// what rejects schedules that ship partial sums as final or fold the
/// same contribution in twice. Returns true iff every rank ends holding
/// the fully reduced value of every segment.
pub fn execute_allreduce(p: usize, rs: &ReduceSchedule) -> bool {
    assert!(p <= 64, "coverage masks are u64");
    let full: u64 = if p == 64 { u64::MAX } else { (1u64 << p) - 1 };
    // cov[r][s]: which ranks' contributions are folded into r's copy of s
    let mut cov = vec![vec![0u64; p]; p];
    for (r, row) in cov.iter_mut().enumerate() {
        for c in row.iter_mut() {
            *c = 1 << r;
        }
    }
    for step in &rs.reduce.steps {
        let snapshot = cov.clone();
        for op in step {
            for &s in &op.blocks {
                // a partial sum overlapping the receiver's coverage
                // would fold some contribution in twice
                assert!(
                    snapshot[op.from][s] & cov[op.to][s] == 0,
                    "segment {s}: rank {} double-adds contributions at rank {}",
                    op.from, op.to
                );
                cov[op.to][s] |= snapshot[op.from][s];
            }
        }
    }
    // fin[r][s]: r holds the final (fully reduced) segment s
    let mut fin: Vec<Vec<bool>> = cov
        .iter()
        .map(|row| row.iter().map(|&c| c == full).collect())
        .collect();
    for step in &rs.gather.steps {
        let snapshot = fin.clone();
        for op in step {
            for &s in &op.blocks {
                assert!(
                    snapshot[op.from][s],
                    "rank {} forwards segment {} before it is fully reduced",
                    op.from, s
                );
                fin[op.to][s] = true;
            }
        }
    }
    fin.iter().all(|row| row.iter().all(|&x| x))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;

    /// The original set-tracking recursive-doubling builder, kept as the
    /// executable specification for the closed-form rewrite.
    fn reference_recursive_doubling(p: usize) -> Schedule {
        assert!(p.is_power_of_two());
        let mut held: Vec<Vec<usize>> = (0..p).map(|r| vec![r]).collect();
        let mut steps = Vec::new();
        let mut dist = 1;
        while dist < p {
            let mut ops = Vec::new();
            let mut new_held = held.clone();
            for r in 0..p {
                let partner = r ^ dist;
                ops.push(SendOp { from: r, to: partner, blocks: held[r].clone() });
                new_held[partner].extend(held[r].iter().copied());
            }
            for h in new_held.iter_mut() {
                h.sort_unstable();
                h.dedup();
            }
            held = new_held;
            steps.push(ops);
            dist <<= 1;
        }
        Schedule { steps }
    }

    /// The original O(P³) membership-scanning Bruck builder, kept as the
    /// executable specification for the closed-form rewrite.
    fn reference_bruck(p: usize) -> Schedule {
        assert!(p >= 1);
        let mut held: Vec<Vec<usize>> = (0..p).map(|r| vec![r]).collect();
        let mut steps = Vec::new();
        let mut dist = 1;
        while dist < p {
            let mut ops = Vec::new();
            let mut new_held = held.clone();
            for r in 0..p {
                let to = (r + p - dist) % p;
                let missing: Vec<usize> = held[r]
                    .iter()
                    .copied()
                    .filter(|b| !held[to].contains(b))
                    .collect();
                if !missing.is_empty() {
                    new_held[to].extend(missing.iter().copied());
                    ops.push(SendOp { from: r, to, blocks: missing });
                }
            }
            for h in new_held.iter_mut() {
                h.sort_unstable();
                h.dedup();
            }
            held = new_held;
            steps.push(ops);
            dist <<= 1;
        }
        Schedule { steps }
    }

    #[test]
    fn closed_form_recursive_doubling_matches_reference() {
        // identical output, not merely equivalent delivery: same steps,
        // same op order, same block order
        for p in [1usize, 2, 4, 8, 16, 32] {
            assert_eq!(
                recursive_doubling_allgatherv(p).steps,
                reference_recursive_doubling(p).steps,
                "p={p}"
            );
        }
    }

    #[test]
    fn closed_form_bruck_matches_reference() {
        for p in 1..=33usize {
            assert_eq!(bruck_allgatherv(p).steps, reference_bruck(p).steps, "p={p}");
        }
    }

    #[test]
    fn ring_delivers_all_p() {
        for p in 1..=17 {
            let s = ring_allgatherv(p, None);
            assert!(all_delivered(&execute(p, &[&s])), "p={p}");
            assert_eq!(s.steps.len(), p.saturating_sub(1));
        }
    }

    #[test]
    fn ring_with_permuted_order() {
        let order = [3usize, 1, 4, 0, 2];
        let s = ring_allgatherv(5, Some(&order));
        assert!(all_delivered(&execute(5, &[&s])));
    }

    #[test]
    fn recursive_doubling_delivers_powers_of_two() {
        for p in [1usize, 2, 4, 8, 16] {
            let s = recursive_doubling_allgatherv(p);
            assert!(all_delivered(&execute(p, &[&s])), "p={p}");
            assert_eq!(s.steps.len(), (p as f64).log2() as usize);
        }
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn recursive_doubling_rejects_non_pow2() {
        let _ = recursive_doubling_allgatherv(6);
    }

    #[test]
    fn bruck_delivers_any_p() {
        for p in 1..=17 {
            let s = bruck_allgatherv(p);
            assert!(all_delivered(&execute(p, &[&s])), "p={p}");
            assert!(s.steps.len() <= (p as f64).log2().ceil() as usize + 1);
        }
    }

    #[test]
    fn binomial_bcast_reaches_everyone() {
        for p in 1..=17 {
            for root in [0, p / 2, p - 1] {
                let s = binomial_bcast(p, root.min(p - 1));
                let held = execute(p, &[&s]);
                for r in 0..p {
                    assert!(held[r][root.min(p - 1)], "p={p} root={root} rank={r}");
                }
            }
        }
    }

    #[test]
    fn bcast_series_is_a_valid_allgatherv() {
        for p in 1..=16 {
            let series = bcast_series_allgatherv(p, None);
            assert_eq!(series.len(), p);
            let refs: Vec<&Schedule> = series.iter().collect();
            assert!(all_delivered(&execute(p, &refs)), "p={p}");
        }
    }

    #[test]
    fn sendop_bytes_uses_counts() {
        let op = SendOp { from: 0, to: 1, blocks: vec![0, 2] };
        assert_eq!(op.bytes(&[10, 20, 30]), 40);
    }

    #[test]
    fn sendop_bytes_zero_counts_and_empty_blocks() {
        // zero-count blocks contribute nothing (the §IV zero-heavy
        // vectors exercise this through every schedule); an empty block
        // list is a zero-byte send, not an error
        let op = SendOp { from: 0, to: 1, blocks: vec![0, 1, 2] };
        assert_eq!(op.bytes(&[0, 0, 0]), 0);
        assert_eq!(op.bytes(&[0, 7, 0]), 7);
        let empty = SendOp { from: 0, to: 1, blocks: vec![] };
        assert_eq!(empty.bytes(&[1, 2, 3]), 0);
    }

    #[test]
    fn ring_step_volume_is_irregular_counts() {
        // with irregular counts the per-step bytes differ per rank
        let counts = [100u64, 5, 60];
        let s = ring_allgatherv(3, None);
        let step0: Vec<u64> = s.steps[0].iter().map(|op| op.bytes(&counts)).collect();
        assert_eq!(step0.len(), 3);
        assert!(step0.contains(&100) && step0.contains(&5) && step0.contains(&60));
    }

    #[test]
    fn prop_random_ring_orders_deliver() {
        check("ring-orders", 64, |rng| {
            let p = 2 + rng.gen_range(14) as usize;
            let mut order: Vec<usize> = (0..p).collect();
            rng.shuffle(&mut order);
            let s = ring_allgatherv(p, Some(&order));
            prop_assert!(all_delivered(&execute(p, &[&s])), "p={p} order={order:?}");
            Ok(())
        });
    }

    #[test]
    fn prop_bcast_series_any_order() {
        check("bcast-series-orders", 32, |rng| {
            let p = 2 + rng.gen_range(10) as usize;
            let mut order: Vec<usize> = (0..p).collect();
            rng.shuffle(&mut order);
            let series = bcast_series_allgatherv(p, Some(&order));
            let refs: Vec<&Schedule> = series.iter().collect();
            prop_assert!(all_delivered(&execute(p, &refs)), "p={p}");
            Ok(())
        });
    }

    #[test]
    fn hierarchical_delivers_all_groupings() {
        // contiguous node-style groupings of every shape
        for p in 1..=12usize {
            for gsize in 1..=p {
                let groups: Vec<Vec<usize>> =
                    (0..p).collect::<Vec<_>>().chunks(gsize).map(|c| c.to_vec()).collect();
                for inter in [LeaderAlgo::Ring, LeaderAlgo::Bruck] {
                    let s = hierarchical_allgatherv(p, &groups, inter);
                    assert!(
                        all_delivered(&execute(p, &[&s])),
                        "p={p} gsize={gsize} inter={inter:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn hierarchical_is_delivery_minimal() {
        // every block moves exactly p-1 times — the same closed form as
        // the flat schedules (conformance harness contract)
        let p = 16;
        let groups: Vec<Vec<usize>> =
            (0..p).collect::<Vec<_>>().chunks(8).map(|c| c.to_vec()).collect();
        for inter in [LeaderAlgo::Ring, LeaderAlgo::Bruck] {
            let s = hierarchical_allgatherv(p, &groups, inter);
            let mut per_block = vec![0usize; p];
            for op in s.steps.iter().flatten() {
                for &b in &op.blocks {
                    per_block[b] += 1;
                }
            }
            assert!(per_block.iter().all(|&n| n == p - 1), "{inter:?}: {per_block:?}");
            assert_eq!(s.total_block_transfers(), p * (p - 1));
        }
    }

    #[test]
    fn hierarchical_step_count_beats_flat_ring() {
        // 4 nodes x 8 GPUs: phase 1 (1) + ring leaders (3) + binomial (3)
        // steps, far below the flat ring's p-1 = 31 synchronized steps.
        let p = 32;
        let groups: Vec<Vec<usize>> =
            (0..p).collect::<Vec<_>>().chunks(8).map(|c| c.to_vec()).collect();
        let s = hierarchical_allgatherv(p, &groups, LeaderAlgo::Ring);
        assert!(all_delivered(&execute(p, &[&s])));
        assert_eq!(s.steps.len(), 1 + 3 + 3);
        assert!(s.steps.len() < ring_allgatherv(p, None).steps.len());
    }

    #[test]
    fn hierarchical_noncontiguous_groups_and_leaders() {
        // groups need not be contiguous or sorted; the leader is the
        // first listed member
        let groups = vec![vec![3, 0, 5], vec![1, 4], vec![2]];
        for inter in [LeaderAlgo::Ring, LeaderAlgo::Bruck] {
            let s = hierarchical_allgatherv(6, &groups, inter);
            assert!(all_delivered(&execute(6, &[&s])), "{inter:?}");
            assert_eq!(s.total_block_transfers(), 6 * 5, "{inter:?}");
        }
    }

    #[test]
    #[should_panic(expected = "partition")]
    fn hierarchical_rejects_non_partition() {
        let _ = hierarchical_allgatherv(4, &[vec![0, 1], vec![1, 2, 3]], LeaderAlgo::Ring);
    }

    #[test]
    fn ring_allreduce_reduces_and_delivers() {
        for p in 1..=17 {
            let rs = ring_allreduce(p, None);
            assert!(execute_allreduce(p, &rs), "p={p}");
            assert_eq!(rs.rounds(), 2 * p.saturating_sub(1));
            // every segment crosses exactly 2(P-1) wires
            for phase in rs.phases() {
                let per = phase.block_transfer_counts(p);
                assert!(per.iter().all(|&n| n == p - 1), "p={p}: {per:?}");
            }
        }
    }

    #[test]
    fn ring_allreduce_with_permuted_order() {
        let order = [3usize, 1, 4, 0, 2];
        let rs = ring_allreduce(5, Some(&order));
        assert!(execute_allreduce(5, &rs));
    }

    #[test]
    fn halving_doubling_reduces_powers_of_two() {
        for p in [1usize, 2, 4, 8, 16, 32] {
            let rs = halving_doubling_allreduce(p);
            assert!(execute_allreduce(p, &rs), "p={p}");
            let logp = (p as f64).log2() as usize;
            assert_eq!(rs.rounds(), 2 * logp);
            let mut per = vec![0usize; p];
            for (b, n) in rs.reduce.block_transfer_counts(p).iter().enumerate() {
                per[b] += n;
            }
            for (b, n) in rs.gather.block_transfer_counts(p).iter().enumerate() {
                per[b] += n;
            }
            assert!(per.iter().all(|&n| n == 2 * (p - 1) || p == 1), "p={p}: {per:?}");
        }
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn halving_doubling_rejects_non_pow2() {
        let _ = halving_doubling_allreduce(12);
    }

    #[test]
    fn bcast_msg_schedules_deliver_from_root() {
        for p in 1..=13usize {
            for root in [0, p / 2, p - 1] {
                // only the root holds the message segments initially
                let init: Vec<Vec<bool>> =
                    (0..p).map(|r| vec![r == root; p]).collect();
                let b = binomial_bcast_msg(p, root, p);
                assert!(all_delivered(&execute_from(p, p, &init, &[&b])), "binomial p={p}");
                let log2p = if p > 1 { (p as f64).log2().ceil() as usize } else { 0 };
                assert_eq!(b.steps.len(), log2p);
                let sag = scatter_allgather_bcast(p, root);
                assert!(
                    all_delivered(&execute_from(p, p, &init, &[&sag.scatter, &sag.gather])),
                    "sag p={p} root={root}"
                );
                let r = ring_bcast_msg(p, root, p, None);
                assert!(all_delivered(&execute_from(p, p, &init, &[&r])), "ring p={p}");
            }
        }
    }

    #[test]
    fn scatter_allgather_closed_forms() {
        for p in [2usize, 3, 5, 8, 13, 16] {
            let sag = scatter_allgather_bcast(p, 0);
            assert_eq!(sag.rounds(), (p as f64).log2().ceil() as usize + (p - 1));
            // scatter ships segment s once per binomial-tree ancestor
            // hop: popcount(s) in relative-rank space
            let per = sag.scatter.block_transfer_counts(p);
            for (s, &n) in per.iter().enumerate() {
                assert_eq!(n, s.count_ones() as usize, "p={p} seg={s}");
            }
            // the ring allgather moves every segment the other P-1 times
            let per = sag.gather.block_transfer_counts(p);
            assert!(per.iter().all(|&n| n == p - 1), "p={p}: {per:?}");
        }
    }

    #[test]
    fn pairwise_alltoallv_is_exact() {
        for p in 1..=13usize {
            let s = pairwise_alltoallv(p);
            assert_eq!(s.steps.len(), p.saturating_sub(1));
            // rank i starts holding row i; must end holding column i too
            let init: Vec<Vec<bool>> = (0..p)
                .map(|i| (0..p * p).map(|b| b / p == i).collect())
                .collect();
            let held = execute_from(p, p * p, &init, &[&s]);
            for r in 0..p {
                for src in 0..p {
                    assert!(held[r][src * p + r], "p={p} rank {r} missing block ({src},{r})");
                }
            }
            // every off-diagonal (src, dst) block crosses exactly one wire
            let per = s.block_transfer_counts(p * p);
            for src in 0..p {
                for dst in 0..p {
                    let expect = usize::from(src != dst);
                    assert_eq!(per[src * p + dst], expect, "p={p} ({src},{dst})");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "double-adds")]
    fn allreduce_oracle_rejects_double_add() {
        // folding the same contribution in twice must be caught
        let bad = ReduceSchedule {
            reduce: Schedule {
                steps: vec![
                    vec![SendOp { from: 0, to: 1, blocks: vec![0] }],
                    vec![SendOp { from: 0, to: 1, blocks: vec![0] }],
                ],
            },
            gather: Schedule::default(),
        };
        let _ = execute_allreduce(2, &bad);
    }

    #[test]
    #[should_panic(expected = "fully reduced")]
    fn allreduce_oracle_rejects_partial_forward() {
        // gather phase may only ship fully reduced segments
        let bad = ReduceSchedule {
            reduce: Schedule::default(),
            gather: Schedule {
                steps: vec![vec![SendOp { from: 0, to: 1, blocks: vec![0] }]],
            },
        };
        let _ = execute_allreduce(2, &bad);
    }

    #[test]
    fn prop_block_conservation_ring() {
        // every ring send ships exactly one block, P*(P-1) transfers total
        check("ring-conservation", 32, |rng| {
            let p = 2 + rng.gen_range(14) as usize;
            let s = ring_allgatherv(p, None);
            prop_assert!(s.total_block_transfers() == p * (p - 1));
            Ok(())
        });
    }
}
