//! Logical collective schedules, independent of transport.
//!
//! A schedule is a sequence of steps; step `s+1` of a rank depends on that
//! rank's sends/receives of step `s`. Each [`SendOp`] moves one or more
//! *blocks* (rank contributions) between ranks. Schedules carry block
//! identity so (a) a logical executor can verify every rank ends up with
//! every block — the delivery-correctness property tests below — and
//! (b) irregular byte counts are preserved per block.
//!
//! Implemented:
//! - [`ring_allgatherv`]: bandwidth-optimal, P-1 steps (MVAPICH large);
//! - [`recursive_doubling_allgatherv`]: log2 P steps, power-of-two P
//!   (MVAPICH small, power-of-two);
//! - [`bruck_allgatherv`]: ceil(log2 P) steps, any P (MVAPICH small);
//! - [`binomial_bcast`]: log-tree broadcast (MPI_Bcast);
//! - [`bcast_series_allgatherv`]: the paper's Listing 1 — Allgatherv as a
//!   series of P broadcasts (what NCCL must do lacking a native routine).

/// One logical point-to-point send: `blocks` identifies which ranks'
/// contributions travel (byte size resolved against `counts`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SendOp {
    /// Sending rank.
    pub from: usize,
    /// Receiving rank.
    pub to: usize,
    /// Which ranks' contributions travel in this send.
    pub blocks: Vec<usize>,
}

impl SendOp {
    /// Byte size of the send given per-rank contribution counts.
    pub fn bytes(&self, counts: &[u64]) -> u64 {
        self.blocks.iter().map(|&b| counts[b]).sum()
    }
}

/// A schedule: steps of concurrent sends. Step boundaries are
/// synchronization points per rank (a rank's step-s+1 ops depend on its
/// step-s ops; different ranks proceed independently unless data flows).
#[derive(Clone, Debug, Default)]
pub struct Schedule {
    /// Steps of concurrent sends, in dependency order.
    pub steps: Vec<Vec<SendOp>>,
}

impl Schedule {
    /// Total number of point-to-point sends across all steps.
    pub fn num_sends(&self) -> usize {
        self.steps.iter().map(|s| s.len()).sum()
    }

    /// Total number of (send, block) transfers — the volume proxy the
    /// conservation property tests assert on.
    pub fn total_block_transfers(&self) -> usize {
        self.steps
            .iter()
            .flat_map(|s| s.iter().map(|op| op.blocks.len()))
            .sum()
    }
}

/// Ring allgatherv: at step s, rank i forwards block (i - s + P) % P to
/// rank (i + 1) % P. After P-1 steps everyone has everything. The
/// `order` permutation maps logical ring position -> rank, letting NCCL
/// run the same schedule over a topology-derived ring.
pub fn ring_allgatherv(p: usize, order: Option<&[usize]>) -> Schedule {
    assert!(p >= 1);
    let identity: Vec<usize> = (0..p).collect();
    let ring = order.unwrap_or(&identity);
    assert_eq!(ring.len(), p);
    let mut steps = Vec::new();
    for s in 0..p.saturating_sub(1) {
        let mut ops = Vec::new();
        for pos in 0..p {
            let from = ring[pos];
            let to = ring[(pos + 1) % p];
            let block = ring[(pos + p - s) % p];
            ops.push(SendOp { from, to, blocks: vec![block] });
        }
        steps.push(ops);
    }
    Schedule { steps }
}

/// Recursive doubling: requires power-of-two P; at step s ranks exchange
/// everything they hold with their partner at distance 2^s.
pub fn recursive_doubling_allgatherv(p: usize) -> Schedule {
    assert!(p.is_power_of_two(), "recursive doubling needs power-of-two P");
    let mut held: Vec<Vec<usize>> = (0..p).map(|r| vec![r]).collect();
    let mut steps = Vec::new();
    let mut dist = 1;
    while dist < p {
        let mut ops = Vec::new();
        let mut new_held = held.clone();
        for r in 0..p {
            let partner = r ^ dist;
            ops.push(SendOp { from: r, to: partner, blocks: held[r].clone() });
            new_held[partner].extend(held[r].iter().copied());
        }
        for h in new_held.iter_mut() {
            h.sort_unstable();
            h.dedup();
        }
        held = new_held;
        steps.push(ops);
        dist <<= 1;
    }
    Schedule { steps }
}

/// Bruck allgather(v): works for any P in ceil(log2 P) steps; rank r
/// sends everything it holds to rank (r - 2^s + P) % P at step s.
pub fn bruck_allgatherv(p: usize) -> Schedule {
    assert!(p >= 1);
    let mut held: Vec<Vec<usize>> = (0..p).map(|r| vec![r]).collect();
    let mut steps = Vec::new();
    let mut dist = 1;
    while dist < p {
        let mut ops = Vec::new();
        let mut new_held = held.clone();
        for r in 0..p {
            let to = (r + p - dist) % p;
            // send the blocks the receiver does not yet have
            let missing: Vec<usize> = held[r]
                .iter()
                .copied()
                .filter(|b| !held[to].contains(b))
                .collect();
            if !missing.is_empty() {
                new_held[to].extend(missing.iter().copied());
                ops.push(SendOp { from: r, to, blocks: missing });
            }
        }
        for h in new_held.iter_mut() {
            h.sort_unstable();
            h.dedup();
        }
        held = new_held;
        steps.push(ops);
        dist <<= 1;
    }
    Schedule { steps }
}

/// Binomial-tree broadcast of `root`'s block to all P ranks (MPI_Bcast).
pub fn binomial_bcast(p: usize, root: usize) -> Schedule {
    assert!(root < p);
    // Relative rank space: rr = (r - root) mod p; rr 0 is the root.
    // Distance halves each step so every sender already holds the data:
    // step 0 only the root sends (to rr = 2^(k-1)), step 1 both holders
    // send, etc.
    let mut steps = Vec::new();
    if p > 1 {
        let mut dist = p.next_power_of_two() / 2;
        while dist >= 1 {
            let mut ops = Vec::new();
            for rr in (0..p).step_by(2 * dist) {
                if rr + dist < p {
                    let from = (rr + root) % p;
                    let to = (rr + dist + root) % p;
                    ops.push(SendOp { from, to, blocks: vec![root] });
                }
            }
            steps.push(ops);
            dist /= 2;
        }
    }
    Schedule { steps }
}

/// Ring broadcast (what NCCL uses): root sends around the ring; with
/// chunk pipelining the transport turns this into a pipeline. `order`
/// gives the ring permutation (topology-detected for NCCL).
pub fn ring_bcast(p: usize, root: usize, order: Option<&[usize]>) -> Schedule {
    let identity: Vec<usize> = (0..p).collect();
    let ring = order.unwrap_or(&identity);
    assert_eq!(ring.len(), p);
    let root_pos = ring.iter().position(|&r| r == root).expect("root not in ring");
    let mut steps = Vec::new();
    for s in 0..p.saturating_sub(1) {
        let from = ring[(root_pos + s) % p];
        let to = ring[(root_pos + s + 1) % p];
        steps.push(vec![SendOp { from, to, blocks: vec![root] }]);
    }
    Schedule { steps }
}

/// Paper Listing 1: Allgatherv recreated as a series of broadcasts, one
/// per rank (NCCL has no native Allgatherv). Broadcasts execute
/// back-to-back on the stream; each contributes its own schedule and the
/// transport layer adds the per-call launch overhead.
pub fn bcast_series_allgatherv(p: usize, order: Option<&[usize]>) -> Vec<Schedule> {
    (0..p).map(|root| ring_bcast(p, root, order)).collect()
}

/// Which algorithm the group leaders run among themselves in a
/// hierarchical schedule (phase 2 of [`hierarchical_allgatherv`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LeaderAlgo {
    /// Ring over the leader set: G-1 steps, bandwidth-optimal — each
    /// group's block set crosses every inter-group boundary exactly once.
    Ring,
    /// Bruck over the leader set: ceil(log2 G) steps, latency-optimal.
    Bruck,
}

/// Two-level (hierarchical) Allgatherv over a node grouping (Awan et
/// al.'s dense-GPU two-level design; see DESIGN.md §3):
///
/// 1. **intra-group exchange** — one step in which every member sends
///    its own block to every other member of its group (the NVLink mesh
///    absorbs the fan-out; afterwards each member, including the group
///    leader `groups[g][0]`, holds its whole group);
/// 2. **inter-group allgatherv among the leaders** — ring or Bruck over
///    the leader set, moving whole *group block sets*; only these sends
///    cross group (node) boundaries;
/// 3. **intra-group dissemination of the remote blocks** — a binomial
///    tree per group, rooted at the leader, shipping every block *not*
///    in the group (members already own the local ones from phase 1).
///    The power-of-two strides land on NVLink edges on DGX-class nodes.
///
/// Every block still moves exactly P-1 times (the delivery-minimal
/// count shared by all flat Allgatherv schedules here): local members
/// get it in phase 1, leaders in phase 2, remote members in phase 3 —
/// the conformance harness asserts this closed form per block.
///
/// `groups` must partition `0..p`; group g's leader is `groups[g][0]`.
pub fn hierarchical_allgatherv(p: usize, groups: &[Vec<usize>], inter: LeaderAlgo) -> Schedule {
    assert!(p >= 1 && !groups.is_empty(), "need ranks and at least one group");
    let mut seen = vec![false; p];
    for g in groups {
        assert!(!g.is_empty(), "empty group");
        for &r in g {
            assert!(r < p && !seen[r], "groups must partition 0..{p}: rank {r}");
            seen[r] = true;
        }
    }
    assert!(seen.iter().all(|&s| s), "groups must cover every rank 0..{p}");
    let g_count = groups.len();
    let leaders: Vec<usize> = groups.iter().map(|g| g[0]).collect();
    let mut steps: Vec<Vec<SendOp>> = Vec::new();

    // Phase 1: one-step all-pairs exchange inside each group.
    let mut exchange = Vec::new();
    for g in groups {
        for &from in g {
            for &to in g {
                if from != to {
                    exchange.push(SendOp { from, to, blocks: vec![from] });
                }
            }
        }
    }
    if !exchange.is_empty() {
        steps.push(exchange);
    }

    // Phase 2: allgatherv among the leaders; the unit of exchange is a
    // whole group's block set.
    match inter {
        LeaderAlgo::Ring => {
            // step s: leader at position i forwards group (i - s) mod G.
            for s in 0..g_count.saturating_sub(1) {
                let mut ops = Vec::new();
                for pos in 0..g_count {
                    let src_group = (pos + g_count - s) % g_count;
                    ops.push(SendOp {
                        from: leaders[pos],
                        to: leaders[(pos + 1) % g_count],
                        blocks: groups[src_group].clone(),
                    });
                }
                steps.push(ops);
            }
        }
        LeaderAlgo::Bruck => {
            // held group-ids per leader position; send what the receiver
            // is missing (exactly one delivery per (group, leader)).
            let mut held: Vec<Vec<usize>> = (0..g_count).map(|i| vec![i]).collect();
            let mut dist = 1;
            while dist < g_count {
                let mut ops = Vec::new();
                let mut new_held = held.clone();
                for pos in 0..g_count {
                    let to_pos = (pos + g_count - dist) % g_count;
                    let missing: Vec<usize> = held[pos]
                        .iter()
                        .copied()
                        .filter(|gi| !held[to_pos].contains(gi))
                        .collect();
                    if !missing.is_empty() {
                        new_held[to_pos].extend(missing.iter().copied());
                        let blocks: Vec<usize> = missing
                            .iter()
                            .flat_map(|&gi| groups[gi].iter().copied())
                            .collect();
                        ops.push(SendOp {
                            from: leaders[pos],
                            to: leaders[to_pos],
                            blocks,
                        });
                    }
                }
                for h in new_held.iter_mut() {
                    h.sort_unstable();
                    h.dedup();
                }
                held = new_held;
                steps.push(ops);
                dist <<= 1;
            }
        }
    }

    // Phase 3: per-group binomial dissemination of the remote blocks,
    // rooted at the leader (relative index 0). Rounds are merged across
    // groups so independent groups proceed concurrently.
    let mut rounds: Vec<Vec<SendOp>> = Vec::new();
    for g in groups {
        let k = g.len();
        if k < 2 || g_count < 2 {
            continue; // nothing remote, or nobody to forward to
        }
        let in_group = |b: usize| g.contains(&b);
        let remote: Vec<usize> = (0..p).filter(|&b| !in_group(b)).collect();
        let mut round = 0usize;
        let mut dist = k.next_power_of_two() / 2;
        while dist >= 1 {
            let mut ops = Vec::new();
            for rr in (0..k).step_by(2 * dist) {
                if rr + dist < k {
                    ops.push(SendOp {
                        from: g[rr],
                        to: g[rr + dist],
                        blocks: remote.clone(),
                    });
                }
            }
            if rounds.len() <= round {
                rounds.push(Vec::new());
            }
            rounds[round].extend(ops);
            round += 1;
            dist /= 2;
        }
    }
    steps.extend(rounds.into_iter().filter(|r| !r.is_empty()));

    Schedule { steps }
}

// ---------------------------------------------------------------------------
// Logical executor: verifies delivery correctness of any schedule.
// ---------------------------------------------------------------------------

/// Execute a schedule over per-rank block sets; returns the final
/// holdings. A send is only legal if the sender holds every block it
/// ships at that step (asserted).
pub fn execute(p: usize, schedules: &[&Schedule]) -> Vec<Vec<bool>> {
    let mut held = vec![vec![false; p]; p];
    for (r, h) in held.iter_mut().enumerate() {
        h[r] = true;
    }
    for sched in schedules {
        for step in &sched.steps {
            // all sends in a step read pre-step state
            let snapshot = held.clone();
            for op in step {
                for &b in &op.blocks {
                    assert!(
                        snapshot[op.from][b],
                        "rank {} sends block {} it does not hold",
                        op.from, b
                    );
                    held[op.to][b] = true;
                }
            }
        }
    }
    held
}

/// True iff every rank holds every block.
pub fn all_delivered(held: &[Vec<bool>]) -> bool {
    held.iter().all(|h| h.iter().all(|&x| x))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;

    #[test]
    fn ring_delivers_all_p() {
        for p in 1..=17 {
            let s = ring_allgatherv(p, None);
            assert!(all_delivered(&execute(p, &[&s])), "p={p}");
            assert_eq!(s.steps.len(), p.saturating_sub(1));
        }
    }

    #[test]
    fn ring_with_permuted_order() {
        let order = [3usize, 1, 4, 0, 2];
        let s = ring_allgatherv(5, Some(&order));
        assert!(all_delivered(&execute(5, &[&s])));
    }

    #[test]
    fn recursive_doubling_delivers_powers_of_two() {
        for p in [1usize, 2, 4, 8, 16] {
            let s = recursive_doubling_allgatherv(p);
            assert!(all_delivered(&execute(p, &[&s])), "p={p}");
            assert_eq!(s.steps.len(), (p as f64).log2() as usize);
        }
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn recursive_doubling_rejects_non_pow2() {
        let _ = recursive_doubling_allgatherv(6);
    }

    #[test]
    fn bruck_delivers_any_p() {
        for p in 1..=17 {
            let s = bruck_allgatherv(p);
            assert!(all_delivered(&execute(p, &[&s])), "p={p}");
            assert!(s.steps.len() <= (p as f64).log2().ceil() as usize + 1);
        }
    }

    #[test]
    fn binomial_bcast_reaches_everyone() {
        for p in 1..=17 {
            for root in [0, p / 2, p - 1] {
                let s = binomial_bcast(p, root.min(p - 1));
                let held = execute(p, &[&s]);
                for r in 0..p {
                    assert!(held[r][root.min(p - 1)], "p={p} root={root} rank={r}");
                }
            }
        }
    }

    #[test]
    fn bcast_series_is_a_valid_allgatherv() {
        for p in 1..=16 {
            let series = bcast_series_allgatherv(p, None);
            assert_eq!(series.len(), p);
            let refs: Vec<&Schedule> = series.iter().collect();
            assert!(all_delivered(&execute(p, &refs)), "p={p}");
        }
    }

    #[test]
    fn sendop_bytes_uses_counts() {
        let op = SendOp { from: 0, to: 1, blocks: vec![0, 2] };
        assert_eq!(op.bytes(&[10, 20, 30]), 40);
    }

    #[test]
    fn sendop_bytes_zero_counts_and_empty_blocks() {
        // zero-count blocks contribute nothing (the §IV zero-heavy
        // vectors exercise this through every schedule); an empty block
        // list is a zero-byte send, not an error
        let op = SendOp { from: 0, to: 1, blocks: vec![0, 1, 2] };
        assert_eq!(op.bytes(&[0, 0, 0]), 0);
        assert_eq!(op.bytes(&[0, 7, 0]), 7);
        let empty = SendOp { from: 0, to: 1, blocks: vec![] };
        assert_eq!(empty.bytes(&[1, 2, 3]), 0);
    }

    #[test]
    fn ring_step_volume_is_irregular_counts() {
        // with irregular counts the per-step bytes differ per rank
        let counts = [100u64, 5, 60];
        let s = ring_allgatherv(3, None);
        let step0: Vec<u64> = s.steps[0].iter().map(|op| op.bytes(&counts)).collect();
        assert_eq!(step0.len(), 3);
        assert!(step0.contains(&100) && step0.contains(&5) && step0.contains(&60));
    }

    #[test]
    fn prop_random_ring_orders_deliver() {
        check("ring-orders", 64, |rng| {
            let p = 2 + rng.gen_range(14) as usize;
            let mut order: Vec<usize> = (0..p).collect();
            rng.shuffle(&mut order);
            let s = ring_allgatherv(p, Some(&order));
            prop_assert!(all_delivered(&execute(p, &[&s])), "p={p} order={order:?}");
            Ok(())
        });
    }

    #[test]
    fn prop_bcast_series_any_order() {
        check("bcast-series-orders", 32, |rng| {
            let p = 2 + rng.gen_range(10) as usize;
            let mut order: Vec<usize> = (0..p).collect();
            rng.shuffle(&mut order);
            let series = bcast_series_allgatherv(p, Some(&order));
            let refs: Vec<&Schedule> = series.iter().collect();
            prop_assert!(all_delivered(&execute(p, &refs)), "p={p}");
            Ok(())
        });
    }

    #[test]
    fn hierarchical_delivers_all_groupings() {
        // contiguous node-style groupings of every shape
        for p in 1..=12usize {
            for gsize in 1..=p {
                let groups: Vec<Vec<usize>> =
                    (0..p).collect::<Vec<_>>().chunks(gsize).map(|c| c.to_vec()).collect();
                for inter in [LeaderAlgo::Ring, LeaderAlgo::Bruck] {
                    let s = hierarchical_allgatherv(p, &groups, inter);
                    assert!(
                        all_delivered(&execute(p, &[&s])),
                        "p={p} gsize={gsize} inter={inter:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn hierarchical_is_delivery_minimal() {
        // every block moves exactly p-1 times — the same closed form as
        // the flat schedules (conformance harness contract)
        let p = 16;
        let groups: Vec<Vec<usize>> =
            (0..p).collect::<Vec<_>>().chunks(8).map(|c| c.to_vec()).collect();
        for inter in [LeaderAlgo::Ring, LeaderAlgo::Bruck] {
            let s = hierarchical_allgatherv(p, &groups, inter);
            let mut per_block = vec![0usize; p];
            for op in s.steps.iter().flatten() {
                for &b in &op.blocks {
                    per_block[b] += 1;
                }
            }
            assert!(per_block.iter().all(|&n| n == p - 1), "{inter:?}: {per_block:?}");
            assert_eq!(s.total_block_transfers(), p * (p - 1));
        }
    }

    #[test]
    fn hierarchical_step_count_beats_flat_ring() {
        // 4 nodes x 8 GPUs: phase 1 (1) + ring leaders (3) + binomial (3)
        // steps, far below the flat ring's p-1 = 31 synchronized steps.
        let p = 32;
        let groups: Vec<Vec<usize>> =
            (0..p).collect::<Vec<_>>().chunks(8).map(|c| c.to_vec()).collect();
        let s = hierarchical_allgatherv(p, &groups, LeaderAlgo::Ring);
        assert!(all_delivered(&execute(p, &[&s])));
        assert_eq!(s.steps.len(), 1 + 3 + 3);
        assert!(s.steps.len() < ring_allgatherv(p, None).steps.len());
    }

    #[test]
    fn hierarchical_noncontiguous_groups_and_leaders() {
        // groups need not be contiguous or sorted; the leader is the
        // first listed member
        let groups = vec![vec![3, 0, 5], vec![1, 4], vec![2]];
        for inter in [LeaderAlgo::Ring, LeaderAlgo::Bruck] {
            let s = hierarchical_allgatherv(6, &groups, inter);
            assert!(all_delivered(&execute(6, &[&s])), "{inter:?}");
            assert_eq!(s.total_block_transfers(), 6 * 5, "{inter:?}");
        }
    }

    #[test]
    #[should_panic(expected = "partition")]
    fn hierarchical_rejects_non_partition() {
        let _ = hierarchical_allgatherv(4, &[vec![0, 1], vec![1, 2, 3]], LeaderAlgo::Ring);
    }

    #[test]
    fn prop_block_conservation_ring() {
        // every ring send ships exactly one block, P*(P-1) transfers total
        check("ring-conservation", 32, |rng| {
            let p = 2 + rng.gen_range(14) as usize;
            let s = ring_allgatherv(p, None);
            prop_assert!(s.total_block_transfers() == p * (p - 1));
            Ok(())
        });
    }
}
