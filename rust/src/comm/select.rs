//! Auto-selection of a (library, algorithm) pair per Allgatherv call.
//!
//! The paper's core finding is that *no single library wins*: NCCL and
//! MVAPICH flip between systems, GPU counts and irregularity regimes
//! (§V-B/§V-C). This module closes that gap the way the simulator makes
//! cheap: [`AlgoSelector`] simulates every applicable **candidate** —
//! flat ring / topology-ordered ring / Bruck / recursive doubling on
//! the MPI and MPI-CUDA transports, the hierarchical two-level
//! schedules where the node grouping is non-trivial, and NCCL's
//! Listing-1 bcast series — on the *actual count vector and topology*,
//! and returns the argmin.
//!
//! A **decision table** keyed by (system, gpus, irregularity bucket)
//! caches past winners: a bucket hit shrinks the candidate set to the
//! remembered winner plus the three library defaults (four simulations
//! instead of ~a dozen) — so a cached decision can still never lose to
//! a fixed library; a miss runs the exhaustive argmin and records the
//! winner. Buckets combine a mean-size class with a
//! coefficient-of-variation class, so regular benchmark sweeps and the
//! paper's heavy-tailed tensor modes land in different rows
//! (DESIGN.md §3).

use std::collections::HashMap;

use crate::topology::routing::bandwidth_ring;
use crate::topology::systems::node_groups;
use crate::topology::Topology;

use super::algorithms::{
    bruck_allgatherv, hierarchical_allgatherv, recursive_doubling_allgatherv, ring_allgatherv,
    LeaderAlgo, Schedule,
};
use super::{mpi, mpi_cuda, nccl, CommLibrary, CommResult, Library, Params};

/// Allgatherv algorithm choices the selector can simulate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// Flat ring in rank order (the MVAPICH large-message default).
    Ring,
    /// Flat ring over the bandwidth-greedy topology ordering
    /// ([`bandwidth_ring`]).
    RingTopo,
    /// Bruck (the MVAPICH small-message default; any P).
    Bruck,
    /// Recursive doubling (power-of-two P only).
    RecursiveDoubling,
    /// The paper's Listing-1 broadcast series (NCCL's native strategy).
    BcastSeries,
    /// Two-level: intra-node exchange, ring among node leaders,
    /// binomial dissemination of the remote blocks.
    HierarchicalRing,
    /// Two-level with Bruck among the node leaders.
    HierarchicalBruck,
}

impl Algo {
    /// CLI/report name.
    pub fn name(self) -> &'static str {
        match self {
            Algo::Ring => "ring",
            Algo::RingTopo => "ring-topo",
            Algo::Bruck => "bruck",
            Algo::RecursiveDoubling => "rec-dbl",
            Algo::BcastSeries => "bcast-series",
            Algo::HierarchicalRing => "hier-ring",
            Algo::HierarchicalBruck => "hier-bruck",
        }
    }

    /// Parse an algorithm name as printed by [`Algo::name`].
    pub fn parse(s: &str) -> Option<Algo> {
        match s.to_ascii_lowercase().as_str() {
            "ring" => Some(Algo::Ring),
            "ring-topo" | "ringtopo" => Some(Algo::RingTopo),
            "bruck" => Some(Algo::Bruck),
            "rec-dbl" | "recdbl" | "recursive-doubling" => Some(Algo::RecursiveDoubling),
            "bcast-series" | "bcastseries" => Some(Algo::BcastSeries),
            "hier-ring" | "hierring" => Some(Algo::HierarchicalRing),
            "hier-bruck" | "hierbruck" => Some(Algo::HierarchicalBruck),
            _ => None,
        }
    }

    /// All algorithms, in candidate-enumeration order.
    pub fn all() -> [Algo; 7] {
        [
            Algo::Ring,
            Algo::RingTopo,
            Algo::Bruck,
            Algo::RecursiveDoubling,
            Algo::BcastSeries,
            Algo::HierarchicalRing,
            Algo::HierarchicalBruck,
        ]
    }

    /// Build this algorithm's logical schedule on a topology, if it
    /// applies there. `None` means inapplicable: recursive doubling on
    /// non-power-of-two P, topology ring when the ordering degenerates
    /// to rank order (duplicate of [`Algo::Ring`]), hierarchical on a
    /// trivial grouping (one node, or one GPU per node — the flat
    /// schedules already are those shapes), and [`Algo::BcastSeries`],
    /// which is NCCL-native and has no step-schedule form.
    pub fn schedule(self, topo: &Topology, p: usize) -> Option<Schedule> {
        match self {
            Algo::Ring => Some(ring_allgatherv(p, None)),
            Algo::RingTopo => {
                let order = bandwidth_ring(topo, p);
                if order == (0..p).collect::<Vec<_>>() {
                    None
                } else {
                    Some(ring_allgatherv(p, Some(&order)))
                }
            }
            Algo::Bruck => Some(bruck_allgatherv(p)),
            Algo::RecursiveDoubling => {
                if p.is_power_of_two() {
                    Some(recursive_doubling_allgatherv(p))
                } else {
                    None
                }
            }
            Algo::BcastSeries => None,
            Algo::HierarchicalRing | Algo::HierarchicalBruck => {
                let mut groups = node_groups(topo, p);
                if groups.len() < 2 || groups.len() == p {
                    return None;
                }
                // order the leader ring by link bandwidth, not group
                // discovery order (identical on homogeneous fabrics,
                // where ties resolve back to rank order)
                let leaders: Vec<usize> = groups.iter().map(|g| g[0]).collect();
                let order = crate::topology::routing::bandwidth_ring_over(topo, &leaders);
                groups.sort_by_key(|g| order.iter().position(|&l| l == g[0]).unwrap());
                let inter = if self == Algo::HierarchicalRing {
                    LeaderAlgo::Ring
                } else {
                    LeaderAlgo::Bruck
                };
                Some(hierarchical_allgatherv(p, &groups, inter))
            }
        }
    }

    /// The six schedule-driven algorithms, in the deterministic order
    /// [`candidates`] and [`AlgoSelector::evaluate`] enumerate them.
    fn scheduled() -> [Algo; 6] {
        [
            Algo::Ring,
            Algo::RingTopo,
            Algo::Bruck,
            Algo::RecursiveDoubling,
            Algo::HierarchicalRing,
            Algo::HierarchicalBruck,
        ]
    }
}

/// One (library, algorithm) pair the selector can pick.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Candidate {
    /// Library whose transport executes the schedule.
    pub lib: Library,
    /// Algorithm the schedule implements.
    pub algo: Algo,
}

impl Candidate {
    /// Report label, e.g. "MPI-CUDA/hier-ring".
    pub fn label(self) -> String {
        format!("{}/{}", self.lib.name(), self.algo.name())
    }
}

/// The candidate set for a topology and rank count: every applicable
/// schedule-driven algorithm on the MPI and MPI-CUDA transports, plus
/// NCCL's bcast series. Order is deterministic and matches
/// [`AlgoSelector::evaluate`] (ties in the argmin break toward the
/// earlier candidate).
pub fn candidates(topo: &Topology, p: usize) -> Vec<Candidate> {
    let mut out = Vec::new();
    for algo in Algo::scheduled() {
        if algo.schedule(topo, p).is_some() {
            for lib in [Library::Mpi, Library::MpiCuda] {
                out.push(Candidate { lib, algo });
            }
        }
    }
    out.push(Candidate { lib: Library::Nccl, algo: Algo::BcastSeries });
    out
}

/// The three fixed libraries' *default* (library, algorithm) choices
/// for a count vector — what each library would run on its own: the
/// MVAPICH mean-size switch for MPI and MPI-CUDA, the bcast series for
/// NCCL. The decision table's hit path always re-simulates these, so a
/// cached decision can never lose to a fixed library.
pub fn default_candidates(params: &Params, counts: &[u64]) -> [Candidate; 3] {
    let p = counts.len();
    // keep in sync with mpi::select_algorithm (asserted equal-to-the-
    // library in this module's tests)
    let avg = counts.iter().sum::<u64>() / p.max(1) as u64;
    let def = if avg <= params.allgatherv_algo_switch { Algo::Bruck } else { Algo::Ring };
    [
        Candidate { lib: Library::Mpi, algo: def },
        Candidate { lib: Library::MpiCuda, algo: def },
        Candidate { lib: Library::Nccl, algo: Algo::BcastSeries },
    ]
}

/// Simulate one candidate on the actual counts; `None` if the pair is
/// inapplicable (algorithm unavailable on this topology, or a
/// library/algorithm mismatch such as NCCL with a step schedule).
pub fn simulate(
    topo: &Topology,
    params: Params,
    cand: Candidate,
    counts: &[u64],
) -> Option<CommResult> {
    let p = counts.len();
    match (cand.lib, cand.algo) {
        (Library::Nccl, Algo::BcastSeries) => {
            Some(nccl::Nccl::new(params).allgatherv(topo, counts))
        }
        (Library::Nccl, _) | (_, Algo::BcastSeries) => None,
        (Library::Mpi, algo) => {
            let sched = algo.schedule(topo, p)?;
            Some(mpi::Mpi::new(params).allgatherv_with(topo, counts, &sched))
        }
        (Library::MpiCuda, algo) => {
            let sched = algo.schedule(topo, p)?;
            Some(mpi_cuda::MpiCuda::new(params).allgatherv_with(topo, counts, &sched))
        }
    }
}

/// Compose one candidate's collective into a **shared** simulation
/// behind an optional gate task — the workload engine's auto-tenant
/// path. Builds the identical subgraph [`simulate`] runs in isolation
/// (same schedule construction, same transports), so a gate-less
/// composition reproduces the [`simulate`] time bit-for-bit. `None` if
/// the pair is inapplicable, exactly as for [`simulate`].
pub fn compose(
    sim: &mut crate::sim::Sim,
    params: Params,
    cand: Candidate,
    counts: &[u64],
    gate: Option<crate::sim::TaskId>,
) -> Option<crate::sim::TaskId> {
    let topo = sim.topology();
    let p = counts.len();
    match (cand.lib, cand.algo) {
        (Library::Nccl, Algo::BcastSeries) => {
            Some(nccl::Nccl::new(params).compose(sim, counts, gate))
        }
        (Library::Nccl, _) | (_, Algo::BcastSeries) => None,
        (Library::Mpi, algo) => {
            let sched = algo.schedule(topo, p)?;
            Some(mpi::Mpi::new(params).compose_with(sim, counts, &sched, gate))
        }
        (Library::MpiCuda, algo) => {
            let sched = algo.schedule(topo, p)?;
            Some(mpi_cuda::MpiCuda::new(params).compose_with(sim, counts, &sched, gate))
        }
    }
}

/// Decision-table bucket of a count vector: 4 mean-size classes × 4
/// irregularity (coefficient-of-variation) classes. Two vectors in the
/// same bucket on the same (system, gpus) share a cached decision.
pub fn irregularity_bucket(counts: &[u64]) -> u8 {
    let p = counts.len().max(1) as f64;
    let mean = counts.iter().map(|&c| c as f64).sum::<f64>() / p;
    let size_class: u8 = if mean < (64u64 << 10) as f64 {
        0
    } else if mean < (1u64 << 20) as f64 {
        1
    } else if mean < (64u64 << 20) as f64 {
        2
    } else {
        3
    };
    // all-zero vectors are perfectly regular; guard the division
    let cv = if mean > 0.0 {
        let var = counts
            .iter()
            .map(|&c| {
                let d = c as f64 - mean;
                d * d
            })
            .sum::<f64>()
            / p;
        var.sqrt() / mean
    } else {
        0.0
    };
    let cv_class: u8 = if cv < 0.1 {
        0
    } else if cv < 0.75 {
        1
    } else if cv < 1.5 {
        2
    } else {
        3
    };
    size_class * 4 + cv_class
}

/// The selector's verdict for one call.
#[derive(Clone, Copy, Debug)]
pub struct Selection {
    /// Winning (library, algorithm) pair.
    pub candidate: Candidate,
    /// Simulated Allgatherv time of the winner on the actual counts.
    pub time: f64,
    /// Point-to-point flows the winning simulation executed.
    pub flows: usize,
    /// Whether the decision came from the table (the time is still
    /// re-simulated on the actual counts).
    pub cached: bool,
}

/// Key of the decision table: (system name, rank count, bucket).
type CacheKey = (String, usize, u8);

/// Simulation-driven (library, algorithm) auto-selection with a
/// decision-table cache (module docs).
pub struct AlgoSelector {
    params: Params,
    table: HashMap<CacheKey, Candidate>,
    hits: usize,
    misses: usize,
}

impl AlgoSelector {
    /// Build a selector with the given protocol parameters and an empty
    /// decision table.
    pub fn new(params: Params) -> AlgoSelector {
        AlgoSelector { params, table: HashMap::new(), hits: 0, misses: 0 }
    }

    /// Simulate every applicable candidate, in [`candidates`] order.
    /// Each algorithm's schedule is built once and shared between the
    /// MPI and MPI-CUDA transports (the schedule is the expensive part
    /// for the topology-derived orderings).
    pub fn evaluate(&self, topo: &Topology, counts: &[u64]) -> Vec<(Candidate, CommResult)> {
        let p = counts.len();
        let mut out = Vec::new();
        for algo in Algo::scheduled() {
            if let Some(sched) = algo.schedule(topo, p) {
                out.push((
                    Candidate { lib: Library::Mpi, algo },
                    mpi::Mpi::new(self.params).allgatherv_with(topo, counts, &sched),
                ));
                out.push((
                    Candidate { lib: Library::MpiCuda, algo },
                    mpi_cuda::MpiCuda::new(self.params).allgatherv_with(topo, counts, &sched),
                ));
            }
        }
        let nccl_cand = Candidate { lib: Library::Nccl, algo: Algo::BcastSeries };
        out.push((nccl_cand, nccl::Nccl::new(self.params).allgatherv(topo, counts)));
        out
    }

    /// Exhaustive argmin over the candidate set, bypassing the decision
    /// table. Ties break toward the earlier candidate.
    pub fn select_fresh(&self, topo: &Topology, counts: &[u64]) -> Selection {
        let evals = self.evaluate(topo, counts);
        let mut best: Option<(Candidate, CommResult)> = None;
        for &(c, r) in &evals {
            match best {
                Some((_, br)) if br.time <= r.time => {}
                _ => best = Some((c, r)),
            }
        }
        let (candidate, res) = best.expect("the NCCL bcast-series candidate always applies");
        Selection { candidate, time: res.time, flows: res.flows, cached: false }
    }

    /// Table-backed selection: a bucket hit shrinks the candidate set
    /// to the remembered winner plus the three library defaults
    /// ([`default_candidates`]) and takes their argmin on the actual
    /// counts — four simulations instead of ~a dozen, and never worse
    /// than any fixed library by construction. A miss runs
    /// [`AlgoSelector::select_fresh`] and records the winner.
    pub fn select(&mut self, topo: &Topology, counts: &[u64]) -> Selection {
        let key = (topo.name.clone(), counts.len(), irregularity_bucket(counts));
        if let Some(&cached) = self.table.get(&key) {
            let mut shortlist = default_candidates(&self.params, counts).to_vec();
            if !shortlist.contains(&cached) {
                shortlist.insert(0, cached);
            }
            let mut best: Option<(Candidate, CommResult)> = None;
            for cand in shortlist {
                if let Some(r) = simulate(topo, self.params, cand, counts) {
                    match best {
                        Some((_, br)) if br.time <= r.time => {}
                        _ => best = Some((cand, r)),
                    }
                }
            }
            if let Some((candidate, res)) = best {
                self.hits += 1;
                return Selection {
                    candidate,
                    time: res.time,
                    flows: res.flows,
                    cached: true,
                };
            }
        }
        self.misses += 1;
        let sel = self.select_fresh(topo, counts);
        self.table.insert(key, sel.candidate);
        sel
    }

    /// (hits, misses) of the decision table so far.
    pub fn cache_stats(&self) -> (usize, usize) {
        (self.hits, self.misses)
    }
}

/// Aggregation objective of the robust selector: what "fastest over the
/// ensemble" means (DESIGN.md §12).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RobustObjective {
    /// Argmin of the mean makespan over the scenarios.
    Mean,
    /// Argmin of the 95th-percentile makespan — the tail-averse choice.
    P95,
    /// Outage-aware argmin (DESIGN.md §14): scenarios are scored by
    /// their *effective cost* — completion time **plus recovery
    /// latency** (the recovery-cost term, preferring clean completions
    /// over recovered ones at equal makespan), `INFINITY` for an
    /// aborted scenario — and aggregated as the mean over completed
    /// scenarios **divided by the completion probability** (charging
    /// the expected re-issues of an unreliable pick). On all-finite
    /// inputs this degenerates to [`RobustObjective::Mean`].
    Outage,
}

impl RobustObjective {
    /// CLI/report name.
    pub fn name(self) -> &'static str {
        match self {
            RobustObjective::Mean => "mean",
            RobustObjective::P95 => "p95",
            RobustObjective::Outage => "outage",
        }
    }

    /// Parse a `--robust` value.
    pub fn parse(s: &str) -> Option<RobustObjective> {
        match s.to_ascii_lowercase().as_str() {
            "mean" => Some(RobustObjective::Mean),
            "p95" => Some(RobustObjective::P95),
            "outage" => Some(RobustObjective::Outage),
            _ => None,
        }
    }

    /// Aggregate per-scenario times under this objective. Panics on an
    /// empty slice (as [`crate::util::stats::percentile`] does) — a
    /// silent 0.0 mean would win every argmin with no data behind it.
    /// Only [`RobustObjective::Outage`] tolerates non-finite entries
    /// (`INFINITY` = the scenario aborted); under it a candidate that
    /// never completes scores `INFINITY` and can only win by default.
    pub fn aggregate(self, times: &[f64]) -> f64 {
        assert!(!times.is_empty(), "cannot aggregate zero scenarios");
        match self {
            RobustObjective::Mean => times.iter().sum::<f64>() / times.len() as f64,
            RobustObjective::P95 => crate::util::stats::percentile(times, 95.0),
            RobustObjective::Outage => {
                let done: Vec<f64> = times.iter().copied().filter(|t| t.is_finite()).collect();
                if done.is_empty() {
                    return f64::INFINITY;
                }
                let q = done.len() as f64 / times.len() as f64;
                (done.iter().sum::<f64>() / done.len() as f64) / q
            }
        }
    }
}

/// The robust selector's verdict for one call over one ensemble.
#[derive(Clone, Copy, Debug)]
pub struct RobustSelection {
    /// Winning (library, algorithm) pair under the objective.
    pub candidate: Candidate,
    /// The winner's aggregated (objective) makespan over the ensemble.
    pub objective: f64,
    /// The winner's mean makespan over the ensemble.
    pub mean: f64,
    /// The winner's p95 makespan over the ensemble.
    pub p95: f64,
    /// The winner's time on the *healthy* (unperturbed) fabric.
    pub healthy: f64,
    /// Scenarios evaluated.
    pub scenarios: usize,
}

impl AlgoSelector {
    /// Simulate every applicable candidate under **every scenario** of a
    /// perturbation ensemble, in [`candidates`] order. Each algorithm's
    /// schedule is built once and shared across both MPI transports and
    /// all scenarios, and each candidate's *simulation* is run cold
    /// exactly once: a [`crate::perturb::DeltaSim`] baseline is recorded
    /// per candidate and every scenario replays against it, resuming
    /// live simulation only from its first divergence point (DESIGN.md
    /// §16). Healthy scenarios are pure replays; perturbed ones agree
    /// with a cold run to 1e-9 (`tests/faults_differential.rs`).
    /// Returns per-candidate per-scenario makespans.
    pub fn evaluate_robust(
        &self,
        topo: &Topology,
        counts: &[u64],
        ensemble: &[Vec<crate::perturb::Perturbation>],
    ) -> Vec<(Candidate, Vec<f64>)> {
        assert!(!ensemble.is_empty(), "robust evaluation needs at least one scenario");
        let p = counts.len();
        let replay_all = |done: crate::sim::TaskId,
                          delta: &crate::perturb::DeltaSim| -> Vec<f64> {
            ensemble
                .iter()
                .map(|perts| {
                    let (res, out) = delta.run(perts);
                    if !out.is_completed() {
                        panic!("simulation deadlock: {}", out.describe());
                    }
                    res.finish(done)
                })
                .collect()
        };
        let run_sched = |lib: Library, sched: &Schedule| -> Vec<f64> {
            let mut sim = crate::sim::Sim::new(topo);
            let done = match lib {
                Library::Mpi => {
                    mpi::Mpi::new(self.params).compose_with(&mut sim, counts, sched, None)
                }
                _ => mpi_cuda::MpiCuda::new(self.params)
                    .compose_with(&mut sim, counts, sched, None),
            };
            replay_all(done, &crate::perturb::DeltaSim::record(sim))
        };
        let mut out = Vec::new();
        for algo in Algo::scheduled() {
            if let Some(sched) = algo.schedule(topo, p) {
                for lib in [Library::Mpi, Library::MpiCuda] {
                    out.push((Candidate { lib, algo }, run_sched(lib, &sched)));
                }
            }
        }
        let mut sim = crate::sim::Sim::new(topo);
        let done = nccl::Nccl::new(self.params).compose(&mut sim, counts, None);
        let nccl_times = replay_all(done, &crate::perturb::DeltaSim::record(sim));
        out.push((Candidate { lib: Library::Nccl, algo: Algo::BcastSeries }, nccl_times));
        out
    }

    /// Robust selection: argmin of the aggregated (mean or p95) makespan
    /// over a perturbation ensemble — "which library wins on the machine
    /// *as it is today*". The candidate set contains every fixed
    /// library's default choice, and every candidate is scored on the
    /// **same scenarios**, so the verdict can never lose to a fixed
    /// library on its own ensemble, by construction
    /// (`tests/faults_properties.rs`). Ties break toward the earlier
    /// candidate, as in [`AlgoSelector::select_fresh`].
    pub fn select_robust(
        &self,
        topo: &Topology,
        counts: &[u64],
        ensemble: &[Vec<crate::perturb::Perturbation>],
        objective: RobustObjective,
    ) -> RobustSelection {
        let evals = self.evaluate_robust(topo, counts, ensemble);
        let (candidate, agg, times) = robust_argmin(&evals, objective);
        let healthy = simulate(topo, self.params, candidate, counts)
            .expect("the winner simulates on its own topology")
            .time;
        RobustSelection {
            candidate,
            objective: agg,
            mean: RobustObjective::Mean.aggregate(times),
            p95: RobustObjective::P95.aggregate(times),
            healthy,
            scenarios: ensemble.len(),
        }
    }
}

/// The outage-aware selector's verdict over one outage ensemble.
#[derive(Clone, Copy, Debug)]
pub struct OutageRobustSelection {
    /// Winning (library, algorithm) pair under
    /// [`RobustObjective::Outage`].
    pub candidate: Candidate,
    /// The winner's aggregated effective cost (lower is better).
    pub score: f64,
    /// Fraction of scenarios the winner completed (full or shrunk
    /// membership), recovery included.
    pub completion_prob: f64,
    /// Mean makespan over the winner's completed scenarios.
    pub mean_time: f64,
    /// Mean recovery latency over the winner's completed scenarios
    /// (0.0 when every completion was clean).
    pub mean_recovery: f64,
    /// The winner's time on the healthy (unperturbed) fabric.
    pub healthy: f64,
    /// Scenarios evaluated.
    pub scenarios: usize,
}

/// Effective per-scenario cost of a recovery outcome, as
/// [`RobustObjective::Outage`] consumes it: completion time plus
/// recovery latency when completed, `INFINITY` when aborted.
pub fn effective_cost(rec: &crate::perturb::Recovered) -> f64 {
    match rec.time() {
        Some(t) => t + rec.recovery_latency,
        None => f64::INFINITY,
    }
}

impl AlgoSelector {
    /// Run every applicable candidate through the recovery driver
    /// ([`crate::perturb::recovery::recovered_candidate`]) under every
    /// scenario of an outage ensemble, in [`candidates`] order. Unlike
    /// [`AlgoSelector::evaluate_robust`], scenarios that stall do not
    /// panic: they retry, reroute, shrink or abort per `policy`, and
    /// the full [`crate::perturb::Recovered`] verdicts come back so
    /// callers can report strategies, not just times.
    ///
    /// Each candidate is cold-simulated once: a
    /// [`crate::perturb::DeltaSim`] baseline is recorded off the
    /// ungated composition and every scenario's attempt-0 (and
    /// watchdog budget) replays against it. Gated retries and repair
    /// compositions still run cold inside the driver — they change the
    /// DAG, so there is nothing to replay.
    pub fn evaluate_outage(
        &self,
        topo: &Topology,
        counts: &[u64],
        ensemble: &[Vec<crate::perturb::Perturbation>],
        policy: &crate::comm::transport::RecoveryPolicy,
    ) -> Vec<(Candidate, Vec<crate::perturb::Recovered>)> {
        assert!(!ensemble.is_empty(), "outage evaluation needs at least one scenario");
        let p = counts.len();
        let mut out = Vec::new();
        for cand in candidates(topo, p) {
            let mut sim = crate::sim::Sim::new(topo);
            let Some(done) = compose(&mut sim, self.params, cand, counts, None) else {
                continue; // inapplicable, exactly as recovered_candidate reports
            };
            let delta = crate::perturb::DeltaSim::record(sim);
            let mut recs = Vec::with_capacity(ensemble.len());
            let mut applicable = true;
            for perts in ensemble {
                match crate::perturb::recovery::recovered_candidate_warm(
                    topo, self.params, cand, counts, perts, policy, &delta, done,
                ) {
                    Some(rec) => recs.push(rec),
                    None => {
                        applicable = false;
                        break;
                    }
                }
            }
            if applicable {
                out.push((cand, recs));
            }
        }
        out
    }

    /// Outage-aware robust selection: argmin of the
    /// [`RobustObjective::Outage`] effective cost — completion
    /// probability and recovery cost folded into the score — over an
    /// outage ensemble, recovery supervised by `policy`. Ties break
    /// toward the earlier candidate, as everywhere in this module.
    pub fn select_outage_robust(
        &self,
        topo: &Topology,
        counts: &[u64],
        ensemble: &[Vec<crate::perturb::Perturbation>],
        policy: &crate::comm::transport::RecoveryPolicy,
    ) -> OutageRobustSelection {
        let evals = self.evaluate_outage(topo, counts, ensemble, policy);
        let costed: Vec<(Candidate, Vec<f64>)> = evals
            .iter()
            .map(|(c, recs)| (*c, recs.iter().map(effective_cost).collect()))
            .collect();
        let (candidate, score, _) = robust_argmin(&costed, RobustObjective::Outage);
        let recs = &evals.iter().find(|(c, _)| *c == candidate).unwrap().1;
        let done: Vec<&crate::perturb::Recovered> =
            recs.iter().filter(|r| r.completed()).collect();
        let healthy = simulate(topo, self.params, candidate, counts)
            .expect("the winner simulates on its own topology")
            .time;
        let (mean_time, mean_recovery) = if done.is_empty() {
            (f64::INFINITY, 0.0)
        } else {
            let n = done.len() as f64;
            (
                done.iter().map(|r| r.time().unwrap()).sum::<f64>() / n,
                done.iter().map(|r| r.recovery_latency).sum::<f64>() / n,
            )
        };
        OutageRobustSelection {
            candidate,
            score,
            completion_prob: done.len() as f64 / recs.len() as f64,
            mean_time,
            mean_recovery,
            healthy,
            scenarios: ensemble.len(),
        }
    }
}

/// Argmin of the aggregated makespan over the result of
/// [`AlgoSelector::evaluate_robust`]; ties break toward the earlier
/// candidate, exactly as in [`AlgoSelector::select_fresh`]. Shared by
/// [`AlgoSelector::select_robust`] and the `agv faults` report so the
/// two can never diverge on aggregation or tie-breaking. Returns the
/// winner, its aggregated makespan, and its per-scenario times.
pub fn robust_argmin(
    evals: &[(Candidate, Vec<f64>)],
    objective: RobustObjective,
) -> (Candidate, f64, &[f64]) {
    let mut best: Option<(Candidate, f64, &Vec<f64>)> = None;
    for (c, times) in evals {
        let agg = objective.aggregate(times);
        match best {
            Some((_, ba, _)) if ba <= agg => {}
            _ => best = Some((*c, agg, times)),
        }
    }
    let (candidate, agg, times) =
        best.expect("the NCCL bcast-series candidate always applies");
    (candidate, agg, times)
}

/// One-shot exhaustive auto-selection with default parameters (the
/// `auto` counterpart of [`crate::comm::run_allgatherv`]).
pub fn auto_allgatherv(topo: &Topology, counts: &[u64]) -> Selection {
    AlgoSelector::new(Params::default()).select_fresh(topo, counts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_allgatherv;
    use crate::topology::systems::{multi_dgx, SystemKind};

    #[test]
    fn candidate_sets_follow_topology() {
        // DGX-1 @ 8: power-of-two so rec-dbl applies; one node, so no
        // hierarchical candidates
        let dgx = SystemKind::Dgx1.build();
        let c8 = candidates(&dgx, 8);
        assert!(c8.iter().any(|c| c.algo == Algo::RecursiveDoubling));
        assert!(!c8.iter().any(|c| matches!(
            c.algo,
            Algo::HierarchicalRing | Algo::HierarchicalBruck
        )));
        assert!(c8.iter().any(|c| c.lib == Library::Nccl && c.algo == Algo::BcastSeries));
        // cluster: one GPU per node — hierarchical degenerates to flat
        let clu = SystemKind::Cluster.build();
        assert!(!candidates(&clu, 8).iter().any(|c| matches!(
            c.algo,
            Algo::HierarchicalRing | Algo::HierarchicalBruck
        )));
        // multi-DGX @ 16: both hierarchical variants available
        let m = multi_dgx(2);
        let c16 = candidates(&m, 16);
        for algo in [Algo::HierarchicalRing, Algo::HierarchicalBruck] {
            assert!(
                c16.iter().any(|c| c.lib == Library::MpiCuda && c.algo == algo),
                "{algo:?} missing"
            );
        }
    }

    #[test]
    fn every_candidate_simulates() {
        let m = multi_dgx(2);
        let counts = vec![1u64 << 20; 16];
        for cand in candidates(&m, 16) {
            let r = simulate(&m, Params::default(), cand, &counts)
                .unwrap_or_else(|| panic!("{} did not simulate", cand.label()));
            assert!(r.time > 0.0 && r.time.is_finite(), "{}", cand.label());
            assert!(r.flows > 0, "{}", cand.label());
        }
    }

    #[test]
    fn algo_parse_roundtrip() {
        for a in Algo::all() {
            assert_eq!(Algo::parse(a.name()), Some(a));
        }
        assert_eq!(Algo::parse("nope"), None);
    }

    #[test]
    fn bucket_classes() {
        // regular small vs regular large: different size classes
        let small = irregularity_bucket(&[4 << 10; 8]);
        let large = irregularity_bucket(&[128 << 20; 8]);
        assert_ne!(small, large);
        // single hot rank: maximal CV class within its size class
        let hot = irregularity_bucket(&[1 << 10, 1 << 10, 1 << 10, 512 << 20]);
        assert_eq!(hot % 4, 3);
        // regular vectors land in CV class 0; all-zero is regular too
        assert_eq!(irregularity_bucket(&[7 << 20; 4]) % 4, 0);
        assert_eq!(irregularity_bucket(&[0; 8]), 0);
    }

    #[test]
    fn fresh_selection_is_argmin_and_never_loses_to_fixed_libraries() {
        let sel = AlgoSelector::new(Params::default());
        for topo in [SystemKind::Dgx1.build(), multi_dgx(2)] {
            let p = if topo.num_gpus() >= 16 { 16 } else { 8 };
            let counts: Vec<u64> = (0..p).map(|r| ((r as u64 % 3) + 1) << 18).collect();
            let evals = sel.evaluate(&topo, &counts);
            let s = sel.select_fresh(&topo, &counts);
            let min = evals.iter().map(|(_, r)| r.time).fold(f64::INFINITY, f64::min);
            assert_eq!(s.time.to_bits(), min.to_bits(), "{}", topo.name);
            // the candidate set contains each library's default choice,
            // so auto can never lose to a fixed library
            for lib in Library::all() {
                let fixed = run_allgatherv(lib, &topo, &counts).time;
                assert!(
                    s.time <= fixed,
                    "{}: auto {} slower than {} {}",
                    topo.name, s.time, lib.name(), fixed
                );
            }
        }
    }

    #[test]
    fn decision_table_hits_within_bucket() {
        let topo = multi_dgx(2);
        let mut sel = AlgoSelector::new(Params::default());
        let a = sel.select(&topo, &[1 << 20; 16]);
        assert!(!a.cached);
        // same bucket (same size class, still regular): table hit — the
        // shortlist argmin still can't lose to any library default
        let b = sel.select(&topo, &[2 << 20; 16]);
        assert!(b.cached);
        for lib in Library::all() {
            let fixed = run_allgatherv(lib, &topo, &[2 << 20; 16]).time;
            assert!(b.time <= fixed, "cached pick loses to {}", lib.name());
        }
        assert_eq!(sel.cache_stats(), (1, 1));
        // different size class: miss again
        let c = sel.select(&topo, &[1 << 10; 16]);
        assert!(!c.cached);
        assert_eq!(sel.cache_stats(), (1, 2));
    }

    #[test]
    fn default_candidates_track_the_mean_size_switch() {
        let p = Params::default();
        // small mean: Bruck on both MPI transports (mirrors
        // mpi::select_algorithm), NCCL always bcast-series
        let small = default_candidates(&p, &[1024; 8]);
        assert!(small.iter().take(2).all(|c| c.algo == Algo::Bruck));
        assert_eq!(small[2].algo, Algo::BcastSeries);
        let large = default_candidates(&p, &[10 << 20; 8]);
        assert!(large.iter().take(2).all(|c| c.algo == Algo::Ring));
        // the defaults simulate to exactly the libraries' own times
        let topo = SystemKind::Dgx1.build();
        let counts = [10u64 << 20; 8];
        for cand in default_candidates(&p, &counts) {
            let via_cand = simulate(&topo, p, cand, &counts).unwrap().time;
            let via_lib = run_allgatherv(cand.lib, &topo, &counts).time;
            assert_eq!(via_cand.to_bits(), via_lib.to_bits(), "{}", cand.label());
        }
    }

    #[test]
    fn auto_allgatherv_one_shot() {
        let topo = SystemKind::CsStorm.build();
        let s = auto_allgatherv(&topo, &[4 << 20; 16]);
        assert!(s.time > 0.0 && s.time.is_finite());
        assert!(s.candidate.label().contains('/'));
    }

    #[test]
    fn robust_objective_parse_and_aggregate() {
        for o in [RobustObjective::Mean, RobustObjective::P95] {
            assert_eq!(RobustObjective::parse(o.name()), Some(o));
        }
        assert_eq!(RobustObjective::parse("median"), None);
        let times = [1.0, 2.0, 3.0, 10.0];
        assert!((RobustObjective::Mean.aggregate(&times) - 4.0).abs() < 1e-12);
        assert!(RobustObjective::P95.aggregate(&times) > 3.0);
    }

    #[test]
    fn robust_with_one_healthy_scenario_matches_fresh() {
        // an ensemble of one empty scenario is just the healthy fabric:
        // same candidate order, same sims, so the robust verdict must
        // equal select_fresh bit-for-bit
        let sel = AlgoSelector::new(Params::default());
        let topo = SystemKind::Dgx1.build();
        let counts: Vec<u64> = (0..8).map(|r| ((r % 4) as u64 + 1) << 19).collect();
        let fresh = sel.select_fresh(&topo, &counts);
        let robust =
            sel.select_robust(&topo, &counts, &[vec![]], RobustObjective::Mean);
        assert_eq!(robust.candidate, fresh.candidate);
        assert_eq!(robust.objective.to_bits(), fresh.time.to_bits());
        assert_eq!(robust.healthy.to_bits(), fresh.time.to_bits());
        assert_eq!(robust.scenarios, 1);
    }

    #[test]
    fn outage_objective_degenerates_to_mean_on_finite_inputs() {
        assert_eq!(RobustObjective::parse("outage"), Some(RobustObjective::Outage));
        let times = [1.0, 2.0, 3.0, 10.0];
        assert_eq!(
            RobustObjective::Outage.aggregate(&times).to_bits(),
            RobustObjective::Mean.aggregate(&times).to_bits()
        );
        // one abort out of four: mean of the survivors / (3/4)
        let mixed = [1.0, 2.0, f64::INFINITY, 3.0];
        let expect = (6.0 / 3.0) / 0.75;
        assert!((RobustObjective::Outage.aggregate(&mixed) - expect).abs() < 1e-12);
        assert_eq!(RobustObjective::Outage.aggregate(&[f64::INFINITY]), f64::INFINITY);
    }

    #[test]
    fn outage_selection_completes_under_transient_outages() {
        let topo = SystemKind::Dgx1.build();
        let counts = vec![8u64 << 20; 8];
        let link = topo.route_gpus(0, 1).unwrap().links[0];
        // one healthy scenario, one transient outage every candidate
        // must ride out (or never touch)
        let ens = vec![
            vec![],
            vec![crate::perturb::Perturbation::link_down(link).during(1.0e-3, 2.0e-3)],
        ];
        let sel = AlgoSelector::new(Params::default());
        let policy = crate::comm::transport::RecoveryPolicy::default_policy();
        let s = sel.select_outage_robust(&topo, &counts, &ens, &policy);
        assert_eq!(s.scenarios, 2);
        assert_eq!(s.completion_prob, 1.0, "{}", s.candidate.label());
        assert!(s.score.is_finite() && s.score > 0.0);
        assert!(s.mean_time >= s.healthy);
        assert!(s.mean_recovery >= 0.0);
        // with recovery disabled the stalled scenario aborts, so the
        // completion-probability term must reshape the verdict's score
        let s2 = sel.select_outage_robust(
            &topo,
            &counts,
            &ens,
            &crate::comm::transport::RecoveryPolicy::disabled(),
        );
        assert!(s2.completion_prob <= 1.0);
        assert!(s2.score >= s2.mean_time || !s2.score.is_finite());
    }

    #[test]
    fn robust_never_loses_to_fixed_defaults_on_its_ensemble() {
        let params = Params::default();
        let sel = AlgoSelector::new(params);
        let topo = SystemKind::CsStorm.build();
        let counts = vec![2u64 << 20; 8];
        let ens = crate::perturb::ensemble(
            &topo,
            &crate::perturb::EnsembleCfg::quick(11).with_scenarios(4),
        );
        for objective in [RobustObjective::Mean, RobustObjective::P95] {
            let robust = sel.select_robust(&topo, &counts, &ens, objective);
            assert!(robust.objective.is_finite() && robust.objective > 0.0);
            for cand in default_candidates(&params, &counts) {
                let times: Vec<f64> = ens
                    .iter()
                    .map(|perts| {
                        crate::perturb::perturbed_candidate(&topo, params, cand, &counts, perts)
                            .expect("defaults always apply")
                            .time
                    })
                    .collect();
                let fixed = objective.aggregate(&times);
                assert!(
                    robust.objective <= fixed,
                    "{}: robust {} loses to {} {}",
                    objective.name(),
                    robust.objective,
                    cand.label(),
                    fixed
                );
            }
        }
    }
}
