//! Traditional MPI (MVAPICH with CUDA support disabled), paper §II-A.
//!
//! Without CUDA awareness the application performs *explicit* staging:
//! every rank copies its contribution device->host before the collective
//! and the full gathered buffer host->device afterwards — the paper's
//! measurements for "MPI" include these copies. The collective itself is
//! host-to-host: Bruck (latency-optimal) below the MVAPICH size switch,
//! ring (bandwidth-optimal) above it. The selection is driven by the
//! *average* per-rank count — exactly what goes wrong on highly irregular
//! workloads (§V-C), where the mean says "small" while the heavy tail is
//! hundreds of MB.

use crate::sim::{Sim, TaskId};
use crate::topology::Topology;

use super::algorithms::{bruck_allgatherv, ring_allgatherv, Schedule};
use super::transport::{
    chunk_bytes, dtoh, host_to_host, htod, op_completion, run_schedule, run_schedule_chunked,
    ChunkCfg,
};
use super::{CommLibrary, CommResult, Params};

/// Traditional MPI model: explicit staging + host-to-host collective.
pub struct Mpi {
    params: Params,
}

impl Mpi {
    /// Build the model with the given protocol parameters.
    pub fn new(params: Params) -> Mpi {
        Mpi { params }
    }

    /// Compose the staged host collective into a shared simulation,
    /// starting only after `gate` completes (`None` = immediately at
    /// t=0). Returns the task that finishes when every rank holds the
    /// gathered buffer on device. This is the schedule-reuse entry the
    /// workload engine batches tenants through; [`Mpi::allgatherv_with`]
    /// is the same subgraph run in a Sim of its own.
    pub fn compose_with(
        &self,
        sim: &mut Sim,
        counts: &[u64],
        sched: &Schedule,
        gate: Option<TaskId>,
    ) -> TaskId {
        let topo = sim.topology();
        let p = counts.len();
        assert!(p >= 1 && p <= topo.num_gpus());
        let total: u64 = counts.iter().sum();
        let gate_deps: Vec<TaskId> = gate.into_iter().collect();

        // Explicit D2H of each rank's own contribution.
        let entry: Vec<Option<TaskId>> = (0..p)
            .map(|r| Some(dtoh(sim, topo, r, counts[r] as f64, &gate_deps)))
            .collect();

        let params = self.params;
        let finals = run_schedule(sim, p, sched, &entry, |sim, op, deps| {
            let bytes = op.bytes(counts);
            let ready = sim.delay(pt2pt_overhead(&params, bytes), deps);
            host_to_host(sim, topo, &params, op.from, op.to, bytes as f64, &[ready])
        });

        // Explicit H2D of the full gathered buffer on every rank.
        let mut tails = Vec::new();
        for (r, f) in finals.iter().enumerate() {
            let deps: Vec<_> = f.or(entry[r]).into_iter().collect();
            tails.push(htod(sim, topo, r, total as f64, &deps));
        }
        op_completion(sim, &tails, gate)
    }

    /// Compose an arbitrary multi-phase collective over the staged host
    /// transport (DESIGN.md §13): explicit D2H of `stage_down[r]` bytes
    /// per rank, the phase schedules host-to-host with per-chunk
    /// eager/rendezvous overheads, then H2D of `stage_up[r]` bytes per
    /// rank. `blocks` sizes the schedules' block-index space (rank
    /// counts, vector segments, or a flattened count matrix). At
    /// `chunk.chunks == 1` and an allgatherv phase list this builds the
    /// task-for-task identical DAG as [`Mpi::compose_with`] — the
    /// collective layer's chunks=1 differential relies on it.
    #[allow(clippy::too_many_arguments)]
    pub fn compose_phases(
        &self,
        sim: &mut Sim,
        p: usize,
        blocks: &[u64],
        phases: &[&Schedule],
        stage_down: &[u64],
        stage_up: &[u64],
        chunk: ChunkCfg,
        gate: Option<TaskId>,
    ) -> TaskId {
        let topo = sim.topology();
        assert!(p >= 1 && p <= topo.num_gpus());
        assert_eq!(stage_down.len(), p);
        assert_eq!(stage_up.len(), p);
        let gate_deps: Vec<TaskId> = gate.into_iter().collect();

        // Explicit D2H of what each rank contributes to the wire.
        let mut markers: Vec<Option<TaskId>> = (0..p)
            .map(|r| Some(dtoh(sim, topo, r, stage_down[r] as f64, &gate_deps)))
            .collect();

        let params = self.params;
        for phase in phases {
            markers = run_schedule_chunked(sim, p, phase, &markers, chunk, |sim, op, j, k, deps| {
                let bytes = chunk_bytes(op.bytes(blocks), k, j);
                let ready = sim.delay(pt2pt_overhead(&params, bytes), deps);
                host_to_host(sim, topo, &params, op.from, op.to, bytes as f64, &[ready])
            });
        }

        // Explicit H2D of what each rank must end up holding on device.
        let mut tails = Vec::new();
        for (r, m) in markers.iter().enumerate() {
            let deps: Vec<TaskId> = m.iter().copied().collect();
            tails.push(htod(sim, topo, r, stage_up[r] as f64, &deps));
        }
        op_completion(sim, &tails, gate)
    }

    /// Run the staged host collective with an explicit schedule in a
    /// fresh simulation. The auto-selection engine (`comm::select`)
    /// simulates candidate algorithms through this entry point;
    /// [`CommLibrary::allgatherv`] composes it with the MVAPICH
    /// mean-size selection.
    pub fn allgatherv_with(&self, topo: &Topology, counts: &[u64], sched: &Schedule) -> CommResult {
        let mut sim = Sim::new(topo);
        let done = self.compose_with(&mut sim, counts, sched, None);
        let res = sim.run();
        CommResult { time: res.finish(done), flows: res.flows }
    }
}

/// MVAPICH-style algorithm selection, shared with the CUDA-aware path.
pub fn select_algorithm(params: &Params, counts: &[u64]) -> Schedule {
    let p = counts.len();
    let avg = counts.iter().sum::<u64>() / p.max(1) as u64;
    if avg <= params.allgatherv_algo_switch {
        bruck_allgatherv(p)
    } else {
        ring_allgatherv(p, None)
    }
}

/// Per-send protocol overhead (eager vs rendezvous handshake).
pub fn pt2pt_overhead(params: &Params, bytes: u64) -> f64 {
    if bytes <= params.eager_limit {
        params.eager_overhead
    } else {
        params.rndv_overhead
    }
}

impl CommLibrary for Mpi {
    fn name(&self) -> &'static str {
        "MPI"
    }

    fn allgatherv(&self, topo: &Topology, counts: &[u64]) -> CommResult {
        self.allgatherv_with(topo, counts, &select_algorithm(&self.params, counts))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::systems::{cluster, dgx1};

    #[test]
    fn algorithm_selection_by_avg() {
        let p = Params::default();
        // small average -> Bruck (log P steps)
        let s = select_algorithm(&p, &[1024; 8]);
        assert_eq!(s.steps.len(), 3);
        // large average -> ring (P-1 steps)
        let s = select_algorithm(&p, &[10 << 20; 8]);
        assert_eq!(s.steps.len(), 7);
        // irregular with small mean but huge tail -> still Bruck
        // (the misselection the paper's irregular workloads expose)
        let mut counts = vec![1024u64; 8];
        counts[3] = 400 << 10;
        let s = select_algorithm(&p, &counts);
        assert_eq!(s.steps.len(), 3);
    }

    #[test]
    fn mpi_includes_staging_time() {
        // on a 2-GPU run the time must exceed D2H + wire + H2D lower bound
        let t = cluster(2);
        let lib = Mpi::new(Params::default());
        let m = 64u64 << 20;
        let r = lib.allgatherv(&t, &[m, m]);
        let wire = m as f64 / 6.2e9;
        let h2d = 2.0 * m as f64 / 12.5e9;
        assert!(r.time > wire + h2d, "time={} lower bound={}", r.time, wire + h2d);
    }

    #[test]
    fn mpi_monotone_in_size() {
        let t = dgx1();
        let lib = Mpi::new(Params::default());
        let mut last = 0.0;
        for m in [64u64 << 10, 1 << 20, 16 << 20, 64 << 20] {
            let r = lib.allgatherv(&t, &[m; 8]);
            assert!(r.time > last, "size {m}: {} !> {last}", r.time);
            last = r.time;
        }
    }

    #[test]
    fn mpi_single_rank_degenerate() {
        let t = dgx1();
        let lib = Mpi::new(Params::default());
        let r = lib.allgatherv(&t, &[1 << 20]);
        assert!(r.time > 0.0);
    }
}
