//! Fault-supervised workload execution and failure-aware SLO
//! reporting (DESIGN.md §14).
//!
//! [`super::run_workload`] is fail-fast: a hard outage that starves the
//! shared DAG panics with the stall diagnosis. This module is the
//! production-shaped alternative: the shared run executes through
//! [`crate::sim::Sim::run_outcome`], and when it stalls, every job
//! (tenant op) whose completion task is stuck is **re-issued** through
//! the timeout–retry–reroute–shrink driver
//! ([`crate::perturb::recovery::recover_with`]) against the same
//! absolute fault timeline — or aborted outright when the recovery
//! policy is disabled. The run then reports job-level SLOs: goodput,
//! completed vs recovered vs aborted ops, and recovery-latency
//! percentiles.
//!
//! Two timeline caveats, both deliberate: re-issued jobs run on an
//! otherwise idle fabric (an operator restarting a wedged job after its
//! peers drained), and a job that was merely queued behind a stalled
//! predecessor may re-issue cleanly (strategy
//! [`RecoveryStrategy::None`], zero recovery latency).
//!
//! The PR-5 anchor contract extends here: with an empty fault set — or
//! recovery armed but never triggered — the supervised run's
//! [`WorkloadResult`] is bit-identical to [`super::run_workload`]'s,
//! because both paths share the engine's `compose_workload` and
//! `collect_result` verbatim and `run_outcome` is bit-exact to `run`
//! on completed paths (`tests/faults_differential.rs`).

use crate::comm::collective::{compose_collective, CollectiveSpec};
use crate::comm::select::compose as compose_candidate;
use crate::comm::transport::{ChunkCfg, RecoveryPolicy};
use crate::comm::Params;
use crate::perturb::recovery::{recover_with, RecoveryStrategy};
use crate::sim::{Sim, SimOutcome};
use crate::topology::Topology;
use crate::util::error::Result;
use crate::util::stats::percentile;

use super::engine::{self, OpPlan, WorkloadResult};
use super::spec::WorkloadSpec;

/// One job that failed in the shared run and went through the recovery
/// driver (or straight to abort). The authoritative record for the op —
/// the stalled shared run's [`super::OpRecord`] for the same (tenant,
/// index) only shows the stall time.
#[derive(Clone, Debug)]
pub struct ReissuedOp {
    /// Index of the owning tenant in the spec.
    pub tenant: usize,
    /// Op index within the tenant's stream.
    pub index: usize,
    /// Library (or "LIB/algo") label that ran the op.
    pub label: String,
    /// How the re-issue completed ([`RecoveryStrategy::Abort`] = it
    /// did not).
    pub strategy: RecoveryStrategy,
    /// Completion time on the driver's absolute timeline, if completed.
    pub finish: Option<f64>,
    /// Completion minus first stall (the driver's recovery-latency
    /// accounting; 0.0 for a clean re-issue or an abort).
    pub recovery_latency: f64,
}

/// Job-level service levels of one supervised run.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadSlo {
    /// Ops across all tenants.
    pub total_ops: usize,
    /// Ops that completed in the shared run, no recovery involved.
    pub completed_ops: usize,
    /// Failed ops the recovery driver completed (full or shrunk
    /// membership).
    pub recovered_ops: usize,
    /// Failed ops that exhausted every strategy (or had recovery
    /// disabled).
    pub aborted_ops: usize,
    /// Payload bytes of completed + recovered ops; a shrunk completion
    /// contributes only its survivors' counts.
    pub delivered_bytes: f64,
    /// `delivered_bytes / makespan` — the failure-aware throughput
    /// (0.0 when nothing completed).
    pub goodput: f64,
    /// Last completion over clean and re-issued ops; the stall time if
    /// everything aborted. Always finite.
    pub makespan: f64,
    /// Median recovery latency over recovered ops (0.0 when none).
    pub recovery_p50: f64,
    /// 95th-percentile recovery latency over recovered ops.
    pub recovery_p95: f64,
    /// Worst recovery latency over recovered ops.
    pub recovery_max: f64,
}

/// Outcome of [`run_workload_recovered`].
#[derive(Clone, Debug)]
pub struct RecoveredWorkload {
    /// The shared run's aggregation. On a clean run, bit-identical to
    /// [`super::run_workload`]; on a stalled run, finish times of
    /// failed ops read as the stall time (see [`ReissuedOp`]).
    pub result: WorkloadResult,
    /// Whether the shared run stalled.
    pub stalled: bool,
    /// The stall diagnosis ([`SimOutcome::describe`]), if any.
    pub diagnosis: Option<String>,
    /// Every failed op's recovery verdict, in (tenant, op) order.
    pub reissued: Vec<ReissuedOp>,
    /// Job-level service levels.
    pub slo: WorkloadSlo,
}

/// Run a workload under fault supervision: execute the shared DAG,
/// re-issue stalled jobs through the recovery driver per `policy`,
/// aggregate failure-aware SLOs (module docs).
pub fn run_workload_recovered(
    topo: &Topology,
    spec: &WorkloadSpec,
    params: Params,
    policy: &RecoveryPolicy,
) -> Result<RecoveredWorkload> {
    let plans = engine::plan(topo, spec, params)?;
    let mut sim = Sim::new(topo);
    let pending = engine::compose_workload(&mut sim, spec, params, &plans);
    crate::perturb::apply(&mut sim, &spec.faults);
    let (res, outcome) = sim.run_outcome();

    let (stalled, diagnosis, stuck) = match &outcome {
        SimOutcome::Completed { .. } => (false, None, Vec::new()),
        SimOutcome::Stalled { stuck_tasks, .. } => {
            (true, Some(outcome.describe()), stuck_tasks.clone())
        }
    };

    let mut reissued = Vec::new();
    let mut delivered = 0.0f64;
    let mut completed_ops = 0usize;
    let mut recovered_ops = 0usize;
    let mut aborted_ops = 0usize;
    let mut recovery_lat: Vec<f64> = Vec::new();
    let mut makespan: f64 = 0.0;

    for p in &pending {
        if stuck.binary_search(&p.done).is_err() {
            // completed in the shared run
            completed_ops += 1;
            delivered += p.bytes as f64;
            makespan = makespan.max(res.finish(p.done));
            continue;
        }
        let plan = &plans[p.tenant][p.index];
        let rec = if policy.enabled() {
            recover_with(topo, &plan.counts, &spec.faults, policy, |sim, cv, gate| {
                match plan.plan {
                    OpPlan::Lib(lib) => {
                        let cspec = CollectiveSpec::from_vector(plan.op, cv);
                        Some(compose_collective(sim, lib, params, &cspec, ChunkCfg::none(), gate))
                    }
                    OpPlan::Cand(cand) => compose_candidate(sim, params, cand, cv, gate),
                }
            })
        } else {
            None
        };
        match rec {
            Some(r) if r.completed() => {
                recovered_ops += 1;
                recovery_lat.push(r.recovery_latency);
                let mut bytes = p.bytes as f64;
                if let RecoveryStrategy::Shrink { dead_ranks, .. } = &r.strategy {
                    bytes -= dead_ranks.iter().map(|&d| plan.counts[d] as f64).sum::<f64>();
                }
                delivered += bytes;
                makespan = makespan.max(r.time().unwrap());
                reissued.push(ReissuedOp {
                    tenant: p.tenant,
                    index: p.index,
                    label: p.label.clone(),
                    strategy: r.strategy,
                    finish: r.time(),
                    recovery_latency: r.recovery_latency,
                });
            }
            _ => {
                aborted_ops += 1;
                reissued.push(ReissuedOp {
                    tenant: p.tenant,
                    index: p.index,
                    label: p.label.clone(),
                    strategy: RecoveryStrategy::Abort,
                    finish: None,
                    recovery_latency: 0.0,
                });
            }
        }
    }

    if completed_ops + recovered_ops == 0 {
        makespan = outcome.time();
    }
    let (p50, p95, pmax) = if recovery_lat.is_empty() {
        (0.0, 0.0, 0.0)
    } else {
        (
            percentile(&recovery_lat, 50.0),
            percentile(&recovery_lat, 95.0),
            recovery_lat.iter().fold(0.0f64, |a, &b| a.max(b)),
        )
    };
    let slo = WorkloadSlo {
        total_ops: pending.len(),
        completed_ops,
        recovered_ops,
        aborted_ops,
        delivered_bytes: delivered,
        goodput: if makespan > 0.0 { delivered / makespan } else { 0.0 },
        makespan,
        recovery_p50: p50,
        recovery_p95: p95,
        recovery_max: pmax,
    };
    Ok(RecoveredWorkload {
        result: engine::collect_result(topo, spec, &res, pending),
        stalled,
        diagnosis,
        reissued,
        slo,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Library;
    use crate::perturb::Perturbation;
    use crate::topology::systems::SystemKind;
    use crate::workload::spec::TenantLib;
    use crate::workload::run_workload;

    #[test]
    fn pristine_supervised_run_is_bit_exact_to_run_workload() {
        let topo = SystemKind::Dgx1.build();
        let spec = WorkloadSpec::synthetic(3, 2, 8, TenantLib::Fixed(Library::Nccl), 4 << 20, 7);
        let plain = run_workload(&topo, &spec, Params::default()).unwrap();
        let sup = run_workload_recovered(
            &topo,
            &spec,
            Params::default(),
            &RecoveryPolicy::default_policy(),
        )
        .unwrap();
        assert!(!sup.stalled);
        assert!(sup.reissued.is_empty());
        assert_eq!(sup.slo.completed_ops, sup.slo.total_ops);
        assert_eq!(sup.slo.aborted_ops, 0);
        assert_eq!(sup.result.makespan.to_bits(), plain.makespan.to_bits());
        for (a, b) in sup
            .result
            .all_ops()
            .zip(plain.all_ops())
        {
            assert_eq!(a.finish.to_bits(), b.finish.to_bits());
            assert_eq!(a.flows, b.flows);
        }
        assert_eq!(sup.slo.makespan.to_bits(), {
            let last = plain.all_ops().map(|o| o.finish).fold(0.0f64, f64::max);
            last.to_bits()
        });
        assert!(sup.slo.goodput > 0.0);
    }

    #[test]
    fn permanent_outage_recovers_stalled_jobs() {
        let topo = SystemKind::Dgx1.build();
        let link = topo.route_gpus(0, 1).unwrap().links[0];
        let spec =
            WorkloadSpec::synthetic(2, 2, 8, TenantLib::Fixed(Library::Nccl), 4 << 20, 3)
                .with_faults(vec![Perturbation::link_down(link)]);
        let sup = run_workload_recovered(
            &topo,
            &spec,
            Params::default(),
            &RecoveryPolicy::default_policy(),
        )
        .unwrap();
        assert!(sup.stalled, "a permanent outage must stall the shared run");
        assert!(sup.diagnosis.as_deref().unwrap().contains("stalled"));
        assert!(sup.slo.recovered_ops > 0, "{:?}", sup.slo);
        assert_eq!(sup.slo.aborted_ops, 0, "{:?}", sup.reissued);
        assert_eq!(
            sup.slo.completed_ops + sup.slo.recovered_ops,
            sup.slo.total_ops
        );
        assert!(sup.slo.goodput > 0.0 && sup.slo.goodput.is_finite());
        assert!(sup.slo.makespan.is_finite());
        assert!(sup.slo.recovery_max >= sup.slo.recovery_p95);
        assert!(sup.slo.recovery_p95 >= sup.slo.recovery_p50);
        for r in &sup.reissued {
            assert!(r.finish.unwrap().is_finite(), "{:?}", r.strategy);
            assert!(!matches!(r.strategy, RecoveryStrategy::Abort));
        }
    }

    #[test]
    fn all_aborted_run_reports_the_stall_time_and_zero_goodput() {
        // the everything-failed edge: every op aborts, so nothing ever
        // finished — the SLO makespan must fall back to the stall
        // instant (bit-exactly outcome.time(), never 0.0 or the last
        // pre-stall partial progress) and goodput must be exactly 0
        let topo = SystemKind::Dgx1.build();
        let link = topo.route_gpus(0, 1).unwrap().links[0];
        let spec =
            WorkloadSpec::synthetic(2, 1, 8, TenantLib::Fixed(Library::Nccl), 4 << 20, 11)
                .with_faults(vec![Perturbation::link_down(link)]);
        let sup =
            run_workload_recovered(&topo, &spec, Params::default(), &RecoveryPolicy::disabled())
                .unwrap();
        assert_eq!(sup.slo.aborted_ops, sup.slo.total_ops, "{:?}", sup.slo);
        assert_eq!(sup.slo.completed_ops + sup.slo.recovered_ops, 0);
        assert_eq!(sup.slo.delivered_bytes, 0.0);
        assert_eq!(sup.slo.goodput, 0.0);
        // replay the same stalled DAG to pin the fallback instant
        let plans = engine::plan(&topo, &spec, Params::default()).unwrap();
        let mut sim = Sim::new(&topo);
        engine::compose_workload(&mut sim, &spec, Params::default(), &plans);
        crate::perturb::apply(&mut sim, &spec.faults);
        let (_, outcome) = sim.run_outcome();
        assert!(!outcome.is_completed());
        assert_eq!(sup.slo.makespan.to_bits(), outcome.time().to_bits());
    }

    #[test]
    fn shrink_recovery_subtracts_exactly_the_dead_ranks_bytes() {
        // delivered-bytes accounting under membership shrink: a
        // permanently dead GPU cannot be retried or rerouted around, so
        // the op completes shrunk and the SLO must bill the survivors'
        // counts only — total minus exactly the dead ranks' counts
        let topo = SystemKind::Dgx1.build();
        let spec =
            WorkloadSpec::synthetic(1, 1, 4, TenantLib::Fixed(Library::Nccl), 4 << 20, 23)
                .with_faults(vec![Perturbation::gpu_down(2)]);
        let sup = run_workload_recovered(
            &topo,
            &spec,
            Params::default(),
            &RecoveryPolicy::default_policy(),
        )
        .unwrap();
        assert!(sup.stalled, "a dead participant must stall the op");
        assert_eq!(sup.slo.recovered_ops, 1, "{:?}", sup.reissued);
        let plans = engine::plan(&topo, &spec, Params::default()).unwrap();
        let counts = &plans[0][0].counts;
        match &sup.reissued[0].strategy {
            RecoveryStrategy::Shrink { dead_ranks, .. } => {
                assert!(dead_ranks.contains(&2), "{dead_ranks:?}");
                let expect = counts.iter().sum::<u64>() as f64
                    - dead_ranks.iter().map(|&d| counts[d] as f64).sum::<f64>();
                assert_eq!(sup.slo.delivered_bytes.to_bits(), expect.to_bits());
                assert!(sup.slo.delivered_bytes > 0.0);
            }
            other => panic!("expected a shrink recovery, got {other:?}"),
        }
        assert!(sup.slo.goodput > 0.0);
    }

    #[test]
    fn disabled_policy_aborts_stalled_jobs() {
        let topo = SystemKind::Dgx1.build();
        let link = topo.route_gpus(0, 1).unwrap().links[0];
        let spec =
            WorkloadSpec::synthetic(2, 1, 8, TenantLib::Fixed(Library::Nccl), 4 << 20, 3)
                .with_faults(vec![Perturbation::link_down(link)]);
        let sup = run_workload_recovered(
            &topo,
            &spec,
            Params::default(),
            &RecoveryPolicy::disabled(),
        )
        .unwrap();
        assert!(sup.stalled);
        assert!(sup.slo.aborted_ops > 0);
        assert_eq!(sup.slo.recovered_ops, 0);
        assert!(sup.slo.makespan.is_finite());
        for r in &sup.reissued {
            assert_eq!(r.strategy, RecoveryStrategy::Abort);
            assert!(r.finish.is_none());
        }
    }
}
