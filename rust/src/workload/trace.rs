//! Explicit workload traces: one op per line, comma-separated per-rank
//! byte counts (`agv workload --trace FILE`).
//!
//! ```text
//! # tenant-0: three irregular ops on 4 ranks
//! 4KB, 16MB, 0, 1MB
//! 512KB, 512KB, 512KB, 512KB
//! 0, 0, 700MB, 61MB
//! ```
//!
//! Sizes accept the `agv` CLI's byte suffixes ([`parse_bytes`]); `#`
//! starts a comment. Malformed input is rejected with a clean
//! [`crate::util::error::Error`] naming the offending line — never a
//! panic (pinned by `tests/cli_smoke.rs`).

use crate::anyhow;
use crate::util::cli::parse_bytes;
use crate::util::error::Result;

/// Parse a trace document into per-op count vectors. Every op must
/// span the same number of ranks; at least one op is required.
pub fn parse_trace(text: &str) -> Result<Vec<Vec<u64>>> {
    let mut ops: Vec<Vec<u64>> = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut counts = Vec::new();
        for tok in line.split(',') {
            let tok = tok.trim();
            let c = tok.parse::<u64>().ok().or_else(|| parse_bytes(tok)).ok_or_else(|| {
                anyhow!(
                    "trace line {}: bad count `{tok}` (expected a byte size like 16MB)",
                    idx + 1
                )
            })?;
            counts.push(c);
        }
        if let Some(first) = ops.first() {
            if counts.len() != first.len() {
                return Err(anyhow!(
                    "trace line {}: {} counts, but the first op has {} — every op must span \
                     the same ranks",
                    idx + 1,
                    counts.len(),
                    first.len()
                ));
            }
        }
        ops.push(counts);
    }
    if ops.is_empty() {
        return Err(anyhow!("trace holds no ops (only blank lines/comments)"));
    }
    Ok(ops)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sizes_comments_and_blanks() {
        let ops = parse_trace(
            "# a comment\n4KB, 16MB, 0, 1MB\n\n512, 512, 512, 512 # trailing comment\n",
        )
        .unwrap();
        assert_eq!(ops, vec![vec![4096, 16 << 20, 0, 1 << 20], vec![512; 4]]);
    }

    #[test]
    fn rejects_bad_count_with_line_number() {
        let err = parse_trace("1KB, 2KB\n1KB, junk\n").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 2") && msg.contains("junk"), "{msg}");
    }

    #[test]
    fn rejects_ragged_ops() {
        let err = parse_trace("1, 2, 3\n4, 5\n").unwrap_err();
        assert!(format!("{err:#}").contains("same ranks"));
    }

    #[test]
    fn rejects_empty_trace() {
        assert!(parse_trace("# nothing\n\n").is_err());
        assert!(parse_trace("").is_err());
    }
}
