//! The admission loop: compose every tenant's op stream into one
//! shared simulation and run it once (DESIGN.md §9).
//!
//! Gating DAG shape, per tenant:
//!
//! ```text
//! [delay start+jitter] -> op 0 -> [delay gap+jitter] -> op 1 -> ...
//! ```
//!
//! Each op subgraph is built by the communication libraries' *compose*
//! entry points — the exact schedule logic `run_allgatherv` uses, not
//! a re-derivation — behind the arrival-delay gate. A zero-delay first
//! op gets **no** gate task at all, so a 1-tenant 1-op workload is the
//! task-for-task identical DAG to the isolated run (the differential
//! tests compare the two bit-for-bit on both engines). All tenants'
//! chains live in one [`Sim`], so their flows share link capacity
//! under the same max-min contention model the paper's §V-B
//! measurements validate.

use crate::comm::collective::{compose_collective, CollectiveOp, CollectiveSpec};
use crate::comm::select::{compose as compose_candidate, AlgoSelector, Candidate};
use crate::comm::transport::ChunkCfg;
use crate::comm::{Library, Params};
use crate::sim::{Sim, TaskId};
use crate::topology::Topology;
use crate::util::error::Result;
use crate::util::stats::percentile;

use super::spec::{TenantLib, TenantSpec, WorkloadSpec};

/// One tenant op as planned for composition: the resolved count vector
/// plus how it will be built into the shared sim. Crate-visible so the
/// cpals contended-refacto hook can reuse a tenant's plan across its
/// full and isolated runs (plans are removal-invariant).
#[derive(Clone, Debug)]
pub(crate) struct PlannedOp {
    pub(crate) op: CollectiveOp,
    pub(crate) counts: Vec<u64>,
    pub(crate) plan: OpPlan,
    pub(crate) label: String,
}

#[derive(Clone, Debug)]
pub(crate) enum OpPlan {
    /// Fixed library with its own MVAPICH-style algorithm selection.
    Lib(Library),
    /// Auto-selected (library, algorithm) pair, frozen at plan time.
    Cand(Candidate),
}

/// Resolve every tenant's op counts and (library, algorithm) choices.
/// Auto tenants run the [`AlgoSelector`] here, on isolated candidate
/// sims — so contended and isolated executions of the same spec use
/// identical plans, and `--lib auto` exercises the selector (and its
/// decision table) per op exactly as `run_osu_auto` does.
pub(crate) fn plan(
    topo: &Topology,
    spec: &WorkloadSpec,
    params: Params,
) -> Result<Vec<Vec<PlannedOp>>> {
    spec.validate(topo)?;
    let mut plans = Vec::with_capacity(spec.tenants.len());
    for ten in &spec.tenants {
        let mut ops = Vec::with_capacity(ten.ops);
        let mut selector = AlgoSelector::new(params);
        for k in 0..ten.ops {
            let counts = ten.stream.counts(k, spec.op_seed(ten, k));
            let (plan, label) = match &ten.lib {
                TenantLib::Fixed(lib) => (OpPlan::Lib(*lib), lib.name().to_string()),
                TenantLib::Auto => {
                    let sel = selector.select(topo, &counts);
                    (OpPlan::Cand(sel.candidate), sel.candidate.label())
                }
            };
            ops.push(PlannedOp { op: ten.op, counts, plan, label });
        }
        plans.push(ops);
    }
    Ok(plans)
}

/// Compose one planned op into the shared sim behind `gate`. Every
/// fixed-library op — Allgatherv included — routes through the
/// op-generic [`compose_collective`] (DESIGN.md §13): at
/// `ChunkCfg::none()` the Allgatherv spec builds the task-for-task
/// identical DAG as `compose_allgatherv`, so the pre-existing
/// differential tests lock the shared dispatch rather than a fork.
pub(crate) fn compose_planned(
    sim: &mut Sim,
    params: Params,
    op: &PlannedOp,
    gate: Option<TaskId>,
) -> TaskId {
    match op.plan {
        OpPlan::Lib(lib) => {
            let spec = CollectiveSpec::from_vector(op.op, &op.counts);
            compose_collective(sim, lib, params, &spec, ChunkCfg::none(), gate)
        }
        OpPlan::Cand(cand) => compose_candidate(sim, params, cand, &op.counts, gate)
            .expect("a selected candidate always composes on its own topology"),
    }
}

/// One completed collective of one tenant.
#[derive(Clone, Debug)]
pub struct OpRecord {
    /// Index of the owning tenant in the spec.
    pub tenant: usize,
    /// Op index within the tenant's stream.
    pub index: usize,
    /// Library (or "LIB/algo" candidate) label that ran the op.
    pub label: String,
    /// Sum of the op's per-rank counts (bytes contributed once).
    pub bytes: u64,
    /// Virtual time the op became eligible (its gate completed).
    pub arrival: f64,
    /// Virtual time every rank finished the collective.
    pub finish: f64,
    /// Point-to-point flows the op's subgraph contains.
    pub flows: usize,
}

impl OpRecord {
    /// Completion latency the tenant observed (finish - arrival).
    pub fn latency(&self) -> f64 {
        self.finish - self.arrival
    }
}

/// All completions of one tenant, in op order.
#[derive(Clone, Debug)]
pub struct TenantResult {
    /// Tenant name from the spec.
    pub name: String,
    /// Per-op completion records.
    pub ops: Vec<OpRecord>,
    /// Virtual time the tenant's last op finished.
    pub completion: f64,
}

impl TenantResult {
    /// Observed per-op latencies, in op order.
    pub fn latencies(&self) -> Vec<f64> {
        self.ops.iter().map(|o| o.latency()).collect()
    }

    /// q-th percentile (0..=100) of the tenant's op latencies.
    pub fn latency_percentile(&self, q: f64) -> f64 {
        percentile(&self.latencies(), q)
    }
}

/// Outcome of one shared multi-tenant run.
#[derive(Clone, Debug)]
pub struct WorkloadResult {
    /// Per-tenant completions, in spec order.
    pub tenants: Vec<TenantResult>,
    /// Virtual time the last task of the shared DAG finished.
    pub makespan: f64,
    /// Total point-to-point flows simulated.
    pub flows: usize,
    /// Total bytes carried summed over every (link, direction) — each
    /// byte counted once per hop (the conservation property compares
    /// this against the sum of isolated per-op volumes).
    pub total_bytes: f64,
    /// Achieved fabric utilization: carried bytes over the aggregate
    /// capacity-time `sum(linkdir bandwidth) x makespan`.
    pub utilization: f64,
    /// Utilization of the hottest (link, direction) over the makespan.
    pub peak_utilization: f64,
}

impl WorkloadResult {
    /// Every op of every tenant, flattened in (tenant, op) order.
    pub fn all_ops(&self) -> impl Iterator<Item = &OpRecord> {
        self.tenants.iter().flat_map(|t| t.ops.iter())
    }
}

/// Run a workload spec on a topology: plan, compose everything into
/// one shared [`Sim`], execute, aggregate per tenant.
pub fn run_workload(
    topo: &Topology,
    spec: &WorkloadSpec,
    params: Params,
) -> Result<WorkloadResult> {
    let plans = plan(topo, spec, params)?;
    Ok(run_planned(topo, spec, params, &plans))
}

/// [`run_workload`] plus the idle baseline of [`isolated_times`], from
/// a **single** planning pass — auto tenants run the selector's
/// candidate simulations once instead of twice (what `agv workload`'s
/// idle-vs-contended sections use).
pub fn run_workload_with_baseline(
    topo: &Topology,
    spec: &WorkloadSpec,
    params: Params,
) -> Result<(WorkloadResult, Vec<Vec<f64>>)> {
    let plans = plan(topo, spec, params)?;
    let contended = run_planned(topo, spec, params, &plans);
    Ok((contended, isolated_planned(topo, params, &plans)))
}

/// One composed tenant op awaiting execution in the shared sim:
/// bookkeeping `run_planned` / the SLO runner turn into [`OpRecord`]s.
#[derive(Clone)]
pub(crate) struct PendingOp {
    pub(crate) tenant: usize,
    pub(crate) index: usize,
    pub(crate) label: String,
    pub(crate) bytes: u64,
    pub(crate) gate: Option<TaskId>,
    pub(crate) done: TaskId,
    pub(crate) flows: usize,
}

/// Compose every planned op into the shared sim — the gating DAG of the
/// module docs. Shared verbatim by the fail-fast path ([`run_planned`])
/// and the fault-supervised path ([`crate::workload::slo`]), so the two
/// can never diverge on DAG shape (the never-triggered bit-exactness
/// contract rides on that).
pub(crate) fn compose_workload(
    sim: &mut Sim,
    spec: &WorkloadSpec,
    params: Params,
    plans: &[Vec<PlannedOp>],
) -> Vec<PendingOp> {
    let mut pending: Vec<PendingOp> = Vec::new();
    for (t, (ten, tplan)) in spec.tenants.iter().zip(plans).enumerate() {
        let mut rng = ten.arrival_rng(spec.seed);
        let mut prev: Option<TaskId> = None;
        for (k, op) in tplan.iter().enumerate() {
            let delay = ten.arrival_delay(k, &mut rng);
            // Zero extra delay needs no gate task: op 0 starts as a DAG
            // root (the differential-identity case), later ops gate
            // directly on their predecessor.
            let gate = if delay == 0.0 {
                prev
            } else {
                let deps: Vec<TaskId> = prev.into_iter().collect();
                Some(sim.delay(delay, &deps))
            };
            let mark = sim.task_count();
            let done = compose_planned(sim, params, op, gate);
            pending.push(PendingOp {
                tenant: t,
                index: k,
                label: op.label.clone(),
                bytes: op.counts.iter().sum(),
                gate,
                done,
                flows: sim.flow_tasks_since(mark),
            });
            prev = Some(done);
        }
    }
    pending
}

/// Compose and execute the planned ops in one shared sim.
pub(crate) fn run_planned(
    topo: &Topology,
    spec: &WorkloadSpec,
    params: Params,
    plans: &[Vec<PlannedOp>],
) -> WorkloadResult {
    let mut sim = Sim::new(topo);
    let pending = compose_workload(&mut sim, spec, params, plans);

    // Fault timeline: the shared fabric degrades at the spec's scheduled
    // windows (DESIGN.md §12). An empty set emits no capacity steps, so
    // the pristine path stays bit-exact to the pre-fault engine.
    crate::perturb::apply(&mut sim, &spec.faults);

    let res = sim.run();
    collect_result(topo, spec, &res, pending)
}

/// Turn a finished shared run into the per-tenant aggregation. Also the
/// tail of the fault-supervised path, on whatever `SimResult` the
/// outcome-returning run produced.
pub(crate) fn collect_result(
    topo: &Topology,
    spec: &WorkloadSpec,
    res: &crate::sim::SimResult,
    pending: Vec<PendingOp>,
) -> WorkloadResult {
    let mut tenants: Vec<TenantResult> = spec
        .tenants
        .iter()
        .map(|t| TenantResult { name: t.name.clone(), ops: Vec::new(), completion: 0.0 })
        .collect();
    for p in pending {
        let rec = OpRecord {
            tenant: p.tenant,
            index: p.index,
            label: p.label,
            bytes: p.bytes,
            arrival: p.gate.map(|g| res.finish(g)).unwrap_or(0.0),
            finish: res.finish(p.done),
            flows: p.flows,
        };
        let t = &mut tenants[p.tenant];
        t.completion = t.completion.max(rec.finish);
        t.ops.push(rec);
    }

    let total_bytes: f64 = res.linkdir_bytes.iter().sum();
    let cap_total: f64 = topo.links.iter().map(|l| 2.0 * l.class.bandwidth()).sum();
    let (utilization, peak_utilization) = if res.makespan > 0.0 && cap_total > 0.0 {
        let peak = res
            .linkdir_bytes
            .iter()
            .enumerate()
            .map(|(ld, &b)| b / topo.links[ld / 2].class.bandwidth())
            .fold(0.0, f64::max);
        (total_bytes / (cap_total * res.makespan), peak / res.makespan)
    } else {
        (0.0, 0.0)
    };
    WorkloadResult {
        tenants,
        makespan: res.makespan,
        flows: res.flows,
        total_bytes,
        utilization,
        peak_utilization,
    }
}

/// Delta-simulation executor for **fault-timeline ensembles** over one
/// workload DAG (DESIGN.md §16): the planned ops are composed and
/// cold-simulated exactly once at record time, and every fault
/// timeline then replays against that baseline, resuming live
/// simulation only from its first divergence point. The spec's own
/// `faults` field is deliberately *not* recorded — the baseline is the
/// unperturbed fabric, and scenarios arrive per [`WorkloadDelta::run`]
/// call. An empty timeline is a pure replay, bit-exact to
/// [`run_workload`] on a fault-free spec; perturbed timelines agree
/// with a cold run to 1e-9 (`tests/faults_differential.rs`).
pub struct WorkloadDelta<'a> {
    topo: &'a Topology,
    spec: &'a WorkloadSpec,
    pub(crate) delta: crate::perturb::DeltaSim<'a>,
    pending: Vec<PendingOp>,
}

impl<'a> WorkloadDelta<'a> {
    /// Plan, compose and cold-simulate the unperturbed workload once.
    pub fn record(
        topo: &'a Topology,
        spec: &'a WorkloadSpec,
        params: Params,
    ) -> Result<WorkloadDelta<'a>> {
        let plans = plan(topo, spec, params)?;
        Ok(Self::from_plans(topo, spec, params, &plans))
    }

    /// [`WorkloadDelta::record`] from an already-planned op list (the
    /// bench grids plan once and share plans across systems' runs).
    pub(crate) fn from_plans(
        topo: &'a Topology,
        spec: &'a WorkloadSpec,
        params: Params,
        plans: &[Vec<PlannedOp>],
    ) -> WorkloadDelta<'a> {
        let mut sim = Sim::new(topo);
        let pending = compose_workload(&mut sim, spec, params, plans);
        WorkloadDelta { topo, spec, delta: crate::perturb::DeltaSim::record(sim), pending }
    }

    /// Replay one fault timeline against the recorded baseline. Panics
    /// on a deadlocked scenario exactly as [`run_planned`]'s `sim.run()`
    /// does.
    pub fn run(&self, faults: &[crate::perturb::Perturbation]) -> WorkloadResult {
        let (res, out) = self.delta.run(faults);
        if !out.is_completed() {
            panic!("simulation deadlock: {}", out.describe());
        }
        collect_result(self.topo, self.spec, &res, self.pending.clone())
    }

    /// Cold reference run of the same timeline on the pristine DAG —
    /// what `make bench-delta` and the differential tests compare
    /// [`WorkloadDelta::run`] against.
    pub fn run_cold(&self, faults: &[crate::perturb::Perturbation]) -> WorkloadResult {
        let (res, out) = self.delta.run_cold(faults);
        if !out.is_completed() {
            panic!("simulation deadlock: {}", out.describe());
        }
        collect_result(self.topo, self.spec, &res, self.pending.clone())
    }
}

/// Per-tenant per-op *isolated* completion times: every planned op
/// composed alone in a fresh sim with no gate — exactly the time
/// `run_allgatherv` (or the selector) would report for that op on an
/// idle fabric. The baseline the slowdown columns and the no-free-
/// lunch property compare against.
pub fn isolated_times(
    topo: &Topology,
    spec: &WorkloadSpec,
    params: Params,
) -> Result<Vec<Vec<f64>>> {
    let plans = plan(topo, spec, params)?;
    Ok(isolated_planned(topo, params, &plans))
}

fn isolated_planned(topo: &Topology, params: Params, plans: &[Vec<PlannedOp>]) -> Vec<Vec<f64>> {
    plans
        .iter()
        .map(|tplan| {
            tplan
                .iter()
                .map(|op| {
                    let mut sim = Sim::new(topo);
                    let done = compose_planned(&mut sim, params, op, None);
                    sim.run().finish(done)
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_allgatherv;
    use crate::topology::systems::SystemKind;
    use crate::workload::spec::OpStream;

    #[test]
    fn single_op_matches_isolated_library_run() {
        // the unit-level version of tests/workload_differential.rs
        let topo = SystemKind::Dgx1.build();
        let counts = vec![64u64 << 10, 3 << 20, 0, 777];
        for lib in Library::all() {
            let spec = WorkloadSpec::single_op(TenantLib::Fixed(lib), counts.clone(), 1);
            let w = run_workload(&topo, &spec, Params::default()).unwrap();
            let solo = run_allgatherv(lib, &topo, &counts);
            let op = &w.tenants[0].ops[0];
            assert_eq!(op.finish.to_bits(), solo.time.to_bits(), "{}", lib.name());
            assert_eq!(op.arrival, 0.0);
            assert_eq!(op.flows, solo.flows, "{}", lib.name());
            assert_eq!(w.flows, solo.flows, "{}", lib.name());
        }
    }

    #[test]
    fn single_collective_op_matches_isolated_run() {
        // the non-Allgatherv twin of single_op_matches_isolated_library_run:
        // a 1-tenant 1-op workload is the identical DAG to run_collective
        let topo = SystemKind::Dgx1.build();
        let counts = vec![64u64 << 10, 3 << 20, 1 << 16, 777];
        for op in CollectiveOp::all() {
            for lib in Library::all() {
                let spec = crate::workload::spec::WorkloadSpec::single_collective(
                    TenantLib::Fixed(lib),
                    op,
                    counts.clone(),
                    1,
                );
                let w = run_workload(&topo, &spec, Params::default()).unwrap();
                let solo = crate::comm::collective::run_collective(
                    &topo,
                    lib,
                    Params::default(),
                    &CollectiveSpec::from_vector(op, &counts),
                    ChunkCfg::none(),
                );
                let rec = &w.tenants[0].ops[0];
                assert_eq!(
                    rec.finish.to_bits(),
                    solo.time.to_bits(),
                    "{}/{}",
                    op.name(),
                    lib.name()
                );
                assert_eq!(rec.flows, solo.flows, "{}/{}", op.name(), lib.name());
            }
        }
    }

    #[test]
    fn two_tenants_contend_and_iterations_chain() {
        let topo = SystemKind::CsStorm.build();
        let mk = |seed: u64, offset: f64| TenantSpec {
            name: format!("t{seed}"),
            seed,
            lib: TenantLib::Fixed(Library::MpiCuda),
            op: CollectiveOp::Allgatherv,
            stream: OpStream::Fixed { counts: vec![4 << 20; 8] },
            ops: 2,
            start_offset: offset,
            gap: 0.0,
            jitter: 0.0,
        };
        let spec = WorkloadSpec {
            name: "pair".into(),
            seed: 3,
            tenants: vec![mk(0, 0.0), mk(1, 50.0e-6)],
            faults: vec![],
        };
        let w = run_workload(&topo, &spec, Params::default()).unwrap();
        let iso = isolated_times(&topo, &spec, Params::default()).unwrap();
        for (t, tr) in w.tenants.iter().enumerate() {
            assert_eq!(tr.ops.len(), 2);
            // op 1 gates on op 0: arrivals are ordered
            assert!(tr.ops[1].arrival >= tr.ops[0].finish - 1e-15);
            for (k, op) in tr.ops.iter().enumerate() {
                assert!(
                    op.latency() >= iso[t][k] * (1.0 - 1e-9),
                    "tenant {t} op {k}: contended {} < isolated {}",
                    op.latency(), iso[t][k]
                );
            }
        }
        // identical tenants on a shared fabric must actually contend
        let slow = w.tenants[0].ops[0].latency() / iso[0][0];
        assert!(slow > 1.05, "no contention visible: slowdown {slow}");
        assert_eq!(w.flows, w.all_ops().map(|o| o.flows).sum::<usize>());
        assert!(w.utilization > 0.0 && w.utilization <= 1.0 + 1e-9);
        assert!(w.peak_utilization >= w.utilization - 1e-12);
        assert!(w.peak_utilization <= 1.0 + 1e-9);
    }

    #[test]
    fn auto_tenant_plans_compose_and_run() {
        let topo = SystemKind::Cluster.build();
        let spec = WorkloadSpec::synthetic(2, 2, 4, TenantLib::Auto, 8 << 20, 11);
        let w = run_workload(&topo, &spec, Params::default()).unwrap();
        for op in w.all_ops() {
            assert!(op.label.contains('/'), "auto label missing algo: {}", op.label);
            assert!(op.finish > op.arrival);
        }
    }

    #[test]
    fn makespan_covers_every_tenant() {
        let topo = SystemKind::Dgx1.build();
        let spec = WorkloadSpec::synthetic(3, 2, 8, TenantLib::Fixed(Library::Nccl), 1 << 22, 5);
        let w = run_workload(&topo, &spec, Params::default()).unwrap();
        let last = w.tenants.iter().map(|t| t.completion).fold(0.0, f64::max);
        assert_eq!(w.makespan.to_bits(), last.to_bits());
    }

    #[test]
    fn with_baseline_matches_the_two_pass_path() {
        // single planning pass == separate run_workload + isolated_times
        let topo = SystemKind::Cluster.build();
        let spec = WorkloadSpec::synthetic(2, 2, 4, TenantLib::Auto, 4 << 20, 17);
        let (w, idle) = run_workload_with_baseline(&topo, &spec, Params::default()).unwrap();
        let w2 = run_workload(&topo, &spec, Params::default()).unwrap();
        let idle2 = isolated_times(&topo, &spec, Params::default()).unwrap();
        assert_eq!(w.makespan.to_bits(), w2.makespan.to_bits());
        for (a, b) in idle.iter().flatten().zip(idle2.iter().flatten()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn mid_flight_fault_degrades_the_workload() {
        let topo = SystemKind::Dgx1.build();
        let base = WorkloadSpec::synthetic(2, 2, 8, TenantLib::Fixed(Library::Nccl), 8 << 20, 4);
        let healthy = run_workload(&topo, &base, Params::default()).unwrap();
        // a straggler GPU appears a quarter of the way in and stays
        let fault = crate::perturb::Perturbation::straggler(0, 0.3)
            .during(healthy.makespan * 0.25, f64::INFINITY);
        let degraded =
            run_workload(&topo, &base.clone().with_faults(vec![fault]), Params::default())
                .unwrap();
        assert!(
            degraded.makespan > healthy.makespan,
            "mid-flight straggler left no trace: {} vs {}",
            degraded.makespan,
            healthy.makespan
        );
        // the DAG and its delivered bytes are fault-invariant
        assert_eq!(degraded.flows, healthy.flows);
        let drel =
            (degraded.total_bytes - healthy.total_bytes).abs() / healthy.total_bytes;
        assert!(drel < 1e-9, "bytes not conserved across capacity steps: {drel}");
    }

    #[test]
    fn invalid_spec_is_a_clean_error() {
        let topo = SystemKind::Dgx1.build();
        let spec = WorkloadSpec::single_op(TenantLib::Auto, vec![1 << 20; 16], 0);
        let err = run_workload(&topo, &spec, Params::default()).unwrap_err();
        assert!(format!("{err:#}").contains("8 GPUs"), "{err:#}");
    }
}
