//! Multi-tenant workload engine: N concurrent Allgatherv jobs sharing
//! one fabric (DESIGN.md §9).
//!
//! The paper measures every collective on an otherwise idle machine,
//! but its own fidelity argument — concurrent flows crossing shared
//! PCIe switches and IB uplinks slow each other down (§V-B) — is
//! exactly what a production cluster serving many jobs looks like.
//! This module closes that gap without duplicating any schedule logic:
//!
//! - [`spec`]: a [`WorkloadSpec`] names tenants, each with an op
//!   stream ([`OpStream`]: fixed vectors, explicit traces, OSU
//!   message-size distributions, or tensor-dataset mode traces), a
//!   library choice ([`TenantLib`]: one of the paper's three, or the
//!   simulation-driven `auto` selector), and a deterministic-PRNG
//!   arrival model (start offset + inter-op gap + seeded jitter);
//! - [`engine`]: the admission loop composes every op's schedule into
//!   a **single shared [`crate::sim::Sim`]** through the libraries'
//!   compose entry points (`Mpi/MpiCuda::compose_with`,
//!   `Nccl::compose`, `select::compose`), gating op k+1 of a tenant on
//!   its op k plus an arrival-delay task, then runs the whole DAG once
//!   — tenants contend for links exactly as the paper's §V-B flows do;
//! - [`trace`]: parses explicit trace files for the `agv workload
//!   --trace` path (clean [`crate::util::error`] rejection, no panic);
//! - fault timelines: a [`WorkloadSpec::faults`] set compiles into
//!   capacity steps on the shared sim ([`crate::perturb`]), so
//!   multi-tenant runs degrade mid-flight; an empty set is bit-exact to
//!   the pristine engine (DESIGN.md §12); fault *ensembles* over one
//!   DAG compose once and replay warm-started through
//!   [`WorkloadDelta`] (DESIGN.md §16);
//! - [`slo`]: the fault-supervised runner — hard outages stall jobs,
//!   stalled jobs are re-issued through the timeout–retry–reroute–
//!   shrink driver ([`crate::perturb::recovery`]) or aborted, and the
//!   run reports failure-aware SLOs: goodput, completed vs recovered
//!   vs aborted ops, recovery-latency percentiles (DESIGN.md §14);
//! - [`bench`]: the deterministic measurement grid behind
//!   `bench_workload` / `BENCH_workload.json` (simulated metrics only,
//!   so the artifact is byte-reproducible from its seed);
//! - [`serve`]: the open-loop serving engine (`agv serve`,
//!   DESIGN.md §17) — jobs arrive via seeded Poisson or trace
//!   inter-arrival streams, pass an admission policy (FIFO / per-tenant
//!   fair / reject-on-depth), and execute on the shared fabric;
//!   steady-state tail latencies (MSER warm-up truncation) and
//!   knee-point capacity curves come out of `bench_serve` /
//!   `BENCH_serve.json`. Its zero-arrival-rate limit is bit-exact to
//!   [`run_workload`] per library × system on both engines.
//!
//! The anchor contract, pinned by `tests/workload_differential.rs`: a
//! 1-tenant, 1-op workload with zero arrival offset builds the *task-
//! for-task identical* DAG as [`crate::comm::run_allgatherv`] and
//! therefore reproduces its `CommResult` bit-for-bit on both engines —
//! contention results extrapolate from the single-op models the paper
//! experiments validated, not from a second implementation.

pub mod bench;
pub mod engine;
pub mod serve;
pub mod slo;
pub mod spec;
pub mod trace;

pub use engine::{
    isolated_times, run_workload, run_workload_with_baseline, OpRecord, TenantResult,
    WorkloadDelta, WorkloadResult,
};
pub use serve::{
    run_serve, ArrivalProcess, JobRecord, QueuePolicy, ServeDelta, ServeResult, ServeSpec,
};
pub use slo::{run_workload_recovered, RecoveredWorkload, ReissuedOp, WorkloadSlo};
pub use spec::{OpStream, TenantLib, TenantSpec, WorkloadSpec};
pub use trace::parse_trace;
