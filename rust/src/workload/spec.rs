//! Workload specifications: which tenants run, what each one sends,
//! and when (DESIGN.md §9).
//!
//! Everything here is deterministic in the spec's seed: op count
//! vectors and arrival jitter derive from [`crate::util::prng::Rng`]
//! streams keyed by `(workload seed, tenant seed, op index)`, so a
//! spec replays bit-identically, and removing one tenant leaves every
//! other tenant's ops and arrivals untouched (the monotonicity
//! property tests depend on that removal invariance).

use crate::anyhow;
use crate::comm::collective::CollectiveOp;
use crate::comm::Library;
use crate::osu::distributions::Distribution;
use crate::tensor::messages::mode_counts;
use crate::tensor::TensorSpec;
use crate::topology::Topology;
use crate::util::error::Result;
use crate::util::prng::Rng;

/// Which library a tenant runs its collectives through.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TenantLib {
    /// One of the paper's three libraries, with its own MVAPICH-style
    /// algorithm selection.
    Fixed(Library),
    /// Per-op simulation-driven (library, algorithm) selection via
    /// [`crate::comm::select::AlgoSelector`] — the decision table warms
    /// across the tenant's stream exactly as in `run_osu_auto`.
    Auto,
}

impl TenantLib {
    /// Parse a `--lib` value: the three library names or `auto`.
    pub fn parse(s: &str) -> Option<TenantLib> {
        if s.eq_ignore_ascii_case("auto") {
            return Some(TenantLib::Auto);
        }
        Library::parse(s).map(TenantLib::Fixed)
    }

    /// Report label ("MPI-CUDA", "auto").
    pub fn label(&self) -> &'static str {
        match self {
            TenantLib::Fixed(l) => l.name(),
            TenantLib::Auto => "auto",
        }
    }
}

/// How a tenant's per-op count vectors are generated.
#[derive(Clone, Debug)]
pub enum OpStream {
    /// The same explicit vector every op (the OSU fixed-size shape,
    /// or any hand-rolled irregular vector).
    Fixed {
        /// Per-rank byte counts of every op.
        counts: Vec<u64>,
    },
    /// An explicit trace of count vectors, cycled if the tenant issues
    /// more ops than the trace holds (see [`crate::workload::trace`]).
    Trace {
        /// Per-op per-rank byte counts.
        ops: Vec<Vec<u64>>,
    },
    /// Per-op draws from one of the OSU message-size distributions
    /// (§VI future-work benchmark): fixed total volume, shape from the
    /// distribution, deterministic per-op seed.
    Distribution {
        /// Which distribution shapes each op's counts.
        dist: Distribution,
        /// Ranks participating in each op.
        gpus: usize,
        /// Total bytes per op, split across ranks by `dist`.
        total: u64,
    },
    /// The tensor-dataset message trace: op k uses mode k%3's DFacTo
    /// partition counts — one CP-ALS iteration every three ops, the
    /// ReFacTo communication pattern as a tenant.
    TensorModes {
        /// Which Table I data set generates the mode counts.
        spec: TensorSpec,
        /// Ranks (partition parts) of the factorization.
        gpus: usize,
    },
}

impl OpStream {
    /// Rank count every op of this stream spans.
    pub fn gpus(&self) -> usize {
        match self {
            OpStream::Fixed { counts } => counts.len(),
            OpStream::Trace { ops } => ops.first().map(|c| c.len()).unwrap_or(0),
            OpStream::Distribution { gpus, .. } => *gpus,
            OpStream::TensorModes { gpus, .. } => *gpus,
        }
    }

    /// Count vector of op `k` (deterministic in `seed`).
    pub fn counts(&self, k: usize, seed: u64) -> Vec<u64> {
        match self {
            OpStream::Fixed { counts } => counts.clone(),
            OpStream::Trace { ops } => ops[k % ops.len()].clone(),
            OpStream::Distribution { dist, gpus, total } => dist.counts(*gpus, *total, seed),
            OpStream::TensorModes { spec, gpus } => mode_counts(spec, *gpus)[k % 3].clone(),
        }
    }
}

/// One tenant: a stream of `ops` gated collectives on one library.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    /// Report name ("tenant-0", "refacto", ...).
    pub name: String,
    /// Identity salt for this tenant's PRNG streams. Must be unique
    /// within a workload; kept explicit (not the vector index) so that
    /// removing a tenant does not reseed the survivors.
    pub seed: u64,
    /// Library (or auto selection) running the tenant's collectives.
    pub lib: TenantLib,
    /// Which collective the stream issues: each op's count vector maps
    /// to the op's spec via
    /// [`crate::comm::collective::CollectiveSpec::from_vector`]
    /// (allgatherv contributions, allreduce/bcast segment widths, or a
    /// row-uniform alltoallv matrix). Auto selection requires
    /// [`CollectiveOp::Allgatherv`] (the candidate machinery is
    /// Allgatherv-specific); `validate` rejects other combinations.
    pub op: CollectiveOp,
    /// Per-op count-vector generator.
    pub stream: OpStream,
    /// Number of collectives the tenant issues (>= 1).
    pub ops: usize,
    /// Virtual seconds before the tenant's first op may start.
    pub start_offset: f64,
    /// Think time between an op's completion and the next op's
    /// earliest start (iteration k+1 gates on iteration k).
    pub gap: f64,
    /// Uniform-[0, jitter) seconds added to every pre-op delay, drawn
    /// from the tenant's deterministic arrival PRNG.
    pub jitter: f64,
}

impl TenantSpec {
    /// A tenant with immediate, jitter-free arrivals (op k+1 starts
    /// the instant op k completes; op 0 starts at t=0).
    pub fn immediate(name: &str, seed: u64, lib: TenantLib, stream: OpStream, ops: usize) -> Self {
        TenantSpec {
            name: name.to_string(),
            seed,
            lib,
            op: CollectiveOp::Allgatherv,
            stream,
            ops,
            start_offset: 0.0,
            gap: 0.0,
            jitter: 0.0,
        }
    }

    /// The same tenant issuing a different collective op.
    pub fn with_op(mut self, op: CollectiveOp) -> TenantSpec {
        self.op = op;
        self
    }

    /// The tenant's arrival PRNG (deterministic, removal-invariant).
    pub fn arrival_rng(&self, workload_seed: u64) -> Rng {
        Rng::new(workload_seed ^ self.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Delay between op `k`'s gate dependencies completing and the op
    /// becoming eligible. Draws from `rng` in op order, so callers
    /// must iterate k = 0, 1, 2, ...
    ///
    /// Exactly **one** draw per call, unconditionally: `gen_f64(0.0,
    /// 0.0)` consumes the draw and contributes exactly `+0.0`, and for
    /// any positive jitter the value is bit-identical to the old
    /// conditional draw. Draw-stability matters because the serving
    /// engine ([`crate::workload::serve`]) multiplexes its open-loop
    /// inter-arrival draws onto this same tenant stream: with the old
    /// `if jitter > 0.0` guard, toggling jitter between 0 and >0
    /// realigned every later draw (the PR 9 `ensemble.rs::severity`
    /// bug class).
    pub fn arrival_delay(&self, k: usize, rng: &mut Rng) -> f64 {
        let base = if k == 0 { self.start_offset } else { self.gap };
        base + rng.gen_f64(0.0, self.jitter)
    }
}

/// A complete multi-tenant workload over one topology.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    /// Report name.
    pub name: String,
    /// Master seed every per-tenant PRNG stream derives from.
    pub seed: u64,
    /// The tenants sharing the fabric.
    pub tenants: Vec<TenantSpec>,
    /// Fault timeline applied to the shared fabric (DESIGN.md §12):
    /// links degrade / stragglers appear mid-flight at their scheduled
    /// windows. Empty = pristine fabric, bit-exact to the pre-fault
    /// engine (`tests/faults_differential.rs`). The idle baseline
    /// ([`crate::workload::isolated_times`]) stays *healthy*, so
    /// slowdown columns report contention + degradation together.
    pub faults: Vec<crate::perturb::Perturbation>,
}

/// Default stagger between consecutive tenants' first ops (seconds) in
/// [`WorkloadSpec::synthetic`] — a fraction of a typical MB-scale
/// collective, so the streams genuinely overlap.
pub const SYNTHETIC_STAGGER: f64 = 200.0e-6;
/// Default inter-op think time of a synthetic tenant (seconds).
pub const SYNTHETIC_GAP: f64 = 1.0e-3;
/// Default arrival-jitter bound of a synthetic tenant (seconds).
pub const SYNTHETIC_JITTER: f64 = 500.0e-6;

impl WorkloadSpec {
    /// One tenant, one op, zero offsets: the configuration the
    /// differential tests pin against [`crate::comm::run_allgatherv`].
    pub fn single_op(lib: TenantLib, counts: Vec<u64>, seed: u64) -> WorkloadSpec {
        WorkloadSpec {
            name: "single-op".to_string(),
            seed,
            tenants: vec![TenantSpec::immediate(
                "tenant-0",
                0,
                lib,
                OpStream::Fixed { counts },
                1,
            )],
            faults: Vec::new(),
        }
    }

    /// [`WorkloadSpec::single_op`] for an arbitrary collective — the
    /// differential anchor for the non-Allgatherv ops (pinned against
    /// [`crate::comm::collective::run_collective`]).
    pub fn single_collective(
        lib: TenantLib,
        op: CollectiveOp,
        counts: Vec<u64>,
        seed: u64,
    ) -> WorkloadSpec {
        let mut spec = WorkloadSpec::single_op(lib, counts, seed);
        spec.tenants[0].op = op;
        spec
    }

    /// The same workload on a degraded fabric (replaces the fault
    /// timeline).
    pub fn with_faults(mut self, faults: Vec<crate::perturb::Perturbation>) -> WorkloadSpec {
        self.faults = faults;
        self
    }

    /// A synthetic contended workload: `tenants` streams of `ops`
    /// collectives each, cycling through the OSU message-size
    /// distributions (tenant i draws from distribution i mod 5), with
    /// staggered starts and seeded jitter so arrivals interleave.
    pub fn synthetic(
        tenants: usize,
        ops: usize,
        gpus: usize,
        lib: TenantLib,
        total: u64,
        seed: u64,
    ) -> WorkloadSpec {
        let dists = Distribution::all();
        WorkloadSpec {
            name: format!("synthetic-{tenants}x{ops}"),
            seed,
            tenants: (0..tenants)
                .map(|i| TenantSpec {
                    name: format!("tenant-{i}"),
                    seed: i as u64,
                    lib: lib.clone(),
                    op: CollectiveOp::Allgatherv,
                    stream: OpStream::Distribution {
                        dist: dists[i % dists.len()],
                        gpus,
                        total,
                    },
                    ops,
                    start_offset: i as f64 * SYNTHETIC_STAGGER,
                    gap: SYNTHETIC_GAP,
                    jitter: SYNTHETIC_JITTER,
                })
                .collect(),
            faults: Vec::new(),
        }
    }

    /// Deterministic per-op seed for a tenant's stream draws.
    pub fn op_seed(&self, tenant: &TenantSpec, k: usize) -> u64 {
        self.seed
            ^ tenant.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ (k as u64).wrapping_mul(0xD1B5_4A32_D192_ED03)
    }

    /// Check the spec can run on `topo`; every violation is a clean
    /// [`crate::util::error::Error`] naming the offending tenant (the
    /// CLI surfaces these instead of panicking).
    pub fn validate(&self, topo: &Topology) -> Result<()> {
        if self.tenants.is_empty() {
            return Err(anyhow!("workload `{}` has no tenants", self.name));
        }
        crate::perturb::validate(topo, &self.faults)?;
        let mut seeds = std::collections::BTreeSet::new();
        for t in &self.tenants {
            if !seeds.insert(t.seed) {
                return Err(anyhow!(
                    "tenant `{}`: duplicate tenant seed {} (seeds key the PRNG streams)",
                    t.name, t.seed
                ));
            }
            if t.ops == 0 {
                return Err(anyhow!("tenant `{}`: needs at least one op", t.name));
            }
            if t.lib == TenantLib::Auto && t.op != CollectiveOp::Allgatherv {
                return Err(anyhow!(
                    "tenant `{}`: auto selection supports allgatherv only, not {}",
                    t.name,
                    t.op.name()
                ));
            }
            let gpus = t.stream.gpus();
            if gpus == 0 {
                return Err(anyhow!("tenant `{}`: empty count vector", t.name));
            }
            if gpus > topo.num_gpus() {
                return Err(anyhow!(
                    "tenant `{}`: spans {gpus} ranks but `{}` has {} GPUs",
                    t.name, topo.name, topo.num_gpus()
                ));
            }
            if let OpStream::Trace { ops } = &t.stream {
                for (k, op) in ops.iter().enumerate() {
                    if op.len() != gpus {
                        return Err(anyhow!(
                            "tenant `{}`: trace op {k} has {} counts, expected {gpus}",
                            t.name, op.len()
                        ));
                    }
                }
            }
            for (what, v) in [
                ("start-offset", t.start_offset),
                ("gap", t.gap),
                ("jitter", t.jitter),
            ] {
                if !v.is_finite() || v < 0.0 {
                    return Err(anyhow!(
                        "tenant `{}`: {what} must be finite and non-negative, got {v}",
                        t.name
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::datasets;
    use crate::topology::systems::SystemKind;

    #[test]
    fn tenant_lib_parse() {
        assert_eq!(TenantLib::parse("auto"), Some(TenantLib::Auto));
        assert_eq!(TenantLib::parse("nccl"), Some(TenantLib::Fixed(Library::Nccl)));
        assert_eq!(TenantLib::parse("mvapich"), Some(TenantLib::Fixed(Library::MpiCuda)));
        assert_eq!(TenantLib::parse("nope"), None);
        assert_eq!(TenantLib::Auto.label(), "auto");
    }

    #[test]
    fn streams_are_deterministic_and_shaped() {
        let d = OpStream::Distribution {
            dist: Distribution::RandomZipf,
            gpus: 8,
            total: 1 << 24,
        };
        assert_eq!(d.gpus(), 8);
        assert_eq!(d.counts(0, 7), d.counts(0, 7));
        assert_ne!(d.counts(0, 7), d.counts(0, 8), "seed must matter");
        let t = OpStream::TensorModes { spec: datasets::netflix(), gpus: 4 };
        assert_eq!(t.counts(0, 0), t.counts(3, 1), "mode cycle has period 3");
        assert_ne!(t.counts(0, 0), t.counts(1, 0));
        let tr = OpStream::Trace { ops: vec![vec![1, 2], vec![3, 4]] };
        assert_eq!(tr.counts(2, 0), vec![1, 2], "trace cycles");
    }

    #[test]
    fn synthetic_spec_validates_everywhere() {
        for k in SystemKind::all() {
            let topo = k.build();
            let s = WorkloadSpec::synthetic(4, 3, 2, TenantLib::Fixed(Library::Nccl), 1 << 20, 1);
            s.validate(&topo).unwrap();
        }
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let topo = SystemKind::Dgx1.build();
        let empty =
            WorkloadSpec { name: "x".into(), seed: 0, tenants: vec![], faults: vec![] };
        assert!(empty.validate(&topo).is_err());
        let mut wide = WorkloadSpec::single_op(TenantLib::Auto, vec![1; 9], 0);
        assert!(wide.validate(&topo).is_err(), "9 ranks on an 8-GPU system");
        wide.tenants[0].stream = OpStream::Fixed { counts: vec![1; 8] };
        wide.tenants[0].ops = 0;
        assert!(wide.validate(&topo).is_err(), "zero ops");
        let ragged = WorkloadSpec {
            name: "r".into(),
            seed: 0,
            tenants: vec![TenantSpec::immediate(
                "t",
                0,
                TenantLib::Auto,
                OpStream::Trace { ops: vec![vec![1, 2], vec![3]] },
                2,
            )],
            faults: vec![],
        };
        assert!(ragged.validate(&topo).is_err(), "ragged trace");
        let faulty = WorkloadSpec::single_op(TenantLib::Auto, vec![1; 4], 0)
            .with_faults(vec![crate::perturb::Perturbation::scale(999, 0.5)]);
        assert!(faulty.validate(&topo).is_err(), "out-of-range fault link");
        let mut dup = WorkloadSpec::synthetic(2, 1, 2, TenantLib::Auto, 1 << 20, 0);
        dup.tenants[1].seed = dup.tenants[0].seed;
        assert!(dup.validate(&topo).is_err(), "duplicate tenant seeds");
        let mut neg = WorkloadSpec::synthetic(1, 1, 2, TenantLib::Auto, 1 << 20, 0);
        neg.tenants[0].gap = -1.0;
        assert!(neg.validate(&topo).is_err(), "negative gap");
        // auto selection is allgatherv-only: other ops are a clean error
        let auto_reduce = WorkloadSpec::single_collective(
            TenantLib::Auto,
            CollectiveOp::Allreduce,
            vec![1 << 20; 4],
            0,
        );
        let err = auto_reduce.validate(&topo).unwrap_err();
        assert!(format!("{err:#}").contains("allgatherv only"), "{err:#}");
        let fixed_reduce = WorkloadSpec::single_collective(
            TenantLib::Fixed(Library::Nccl),
            CollectiveOp::Allreduce,
            vec![1 << 20; 4],
            0,
        );
        fixed_reduce.validate(&topo).unwrap();
    }

    #[test]
    fn arrival_delay_draw_structure_is_jitter_invariant() {
        // Draw-stability regression (mirrors the PR 9 ensemble.rs fix):
        // every arrival_delay call must consume exactly one draw whether
        // jitter is zero or positive, so downstream draws multiplexed on
        // the same stream (the serve engine's inter-arrival samples) do
        // not shift when jitter is toggled. Pre-fix, the zero-jitter
        // tenant skipped its draws and the two streams diverged.
        let spec = WorkloadSpec::synthetic(2, 4, 2, TenantLib::Auto, 1 << 20, 5);
        let mut jittered = spec.tenants[0].clone();
        let mut flat = spec.tenants[0].clone();
        flat.jitter = 0.0;
        let mut rng_j = jittered.arrival_rng(spec.seed);
        let mut rng_f = flat.arrival_rng(spec.seed);
        for k in 0..4 {
            let dj = jittered.arrival_delay(k, &mut rng_j);
            let df = flat.arrival_delay(k, &mut rng_f);
            let base = if k == 0 { flat.start_offset } else { flat.gap };
            assert_eq!(df.to_bits(), base.to_bits(), "zero jitter adds exactly +0.0");
            assert!(dj >= base);
            // same stream position after k+1 delays: the next raw draw
            // must be identical on both streams
            assert_eq!(rng_j.next_u64(), rng_f.next_u64(), "draw structure diverged at k={k}");
        }
        // consuming a draw means re-splitting the same rng differs
        jittered.jitter = 0.0;
        let mut a = jittered.arrival_rng(spec.seed);
        let mut b = jittered.arrival_rng(spec.seed);
        let _ = jittered.arrival_delay(0, &mut a);
        assert_ne!(a.next_u64(), b.next_u64(), "delay must consume a draw even at jitter=0");
    }

    #[test]
    fn arrival_streams_are_removal_invariant() {
        let spec = WorkloadSpec::synthetic(3, 4, 2, TenantLib::Auto, 1 << 20, 9);
        let draws = |t: &TenantSpec| {
            let mut rng = t.arrival_rng(spec.seed);
            (0..4).map(|k| t.arrival_delay(k, &mut rng)).collect::<Vec<_>>()
        };
        let full: Vec<_> = spec.tenants.iter().map(draws).collect();
        // drop tenant 1: tenants 0 and 2 keep their exact arrival draws
        let survivors = [&spec.tenants[0], &spec.tenants[2]];
        for (orig, t) in [0usize, 2].into_iter().zip(survivors) {
            assert_eq!(full[orig], draws(t));
        }
        // jitter draws are non-trivial and within bounds
        for (t, ds) in spec.tenants.iter().zip(&full) {
            for (k, &d) in ds.iter().enumerate() {
                let base = if k == 0 { t.start_offset } else { t.gap };
                assert!(d >= base && d < base + t.jitter);
            }
        }
    }
}
