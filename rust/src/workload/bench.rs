//! The `bench_workload` measurement grid and its deterministic
//! `BENCH_workload.json` payload.
//!
//! The JSON artifact contains **simulated** metrics only (makespan,
//! latency percentiles, utilization, flow counts) — no wall-clock
//! fields — so a fixed seed reproduces the file byte-for-byte run
//! over run (`tests/workload_determinism.rs` pins this, guarding the
//! PRNG-offset and pool fan-out paths). Wall-clock timing of the same
//! cases is printed by the bench binary but never written to the
//! artifact.

use crate::comm::{Library, Params};
use crate::topology::systems::SystemKind;
use crate::topology::Topology;
use crate::util::json::{obj, Json};
use crate::util::stats::percentile;

use super::engine::{run_workload, WorkloadDelta};
use super::spec::{TenantLib, WorkloadSpec};

/// The bench grid: per paper system a 4-tenant NCCL contention case,
/// plus one auto-selection case on the DGX-1 (the selector under
/// contention). Deterministic in `seed`.
pub fn bench_cases(seed: u64) -> Vec<(String, Topology, WorkloadSpec)> {
    let mut out = Vec::new();
    for kind in SystemKind::all() {
        let topo = kind.build();
        let gpus = topo.num_gpus().min(8);
        let spec = WorkloadSpec::synthetic(
            4,
            4,
            gpus,
            TenantLib::Fixed(Library::Nccl),
            16 << 20,
            seed,
        );
        out.push((format!("{}/4x4/nccl", kind.name()), topo, spec));
    }
    let topo = SystemKind::Dgx1.build();
    let spec = WorkloadSpec::synthetic(2, 2, 8, TenantLib::Auto, 8 << 20, seed);
    out.push(("dgx1/2x2/auto".to_string(), topo, spec));
    out
}

/// Simulated metrics of one bench case as a JSON object.
fn case_doc(label: &str, topo: &Topology, spec: &WorkloadSpec) -> Json {
    let res = run_workload(topo, spec, Params::default()).expect("bench spec must validate");
    let lats: Vec<f64> = res.all_ops().map(|o| o.latency()).collect();
    obj(vec![
        ("case", Json::Str(label.to_string())),
        ("tenants", Json::Num(spec.tenants.len() as f64)),
        ("ops", Json::Num(lats.len() as f64)),
        ("makespan_s", Json::Num(res.makespan)),
        ("p50_latency_s", Json::Num(percentile(&lats, 50.0))),
        ("p99_latency_s", Json::Num(percentile(&lats, 99.0))),
        ("utilization", Json::Num(res.utilization)),
        ("peak_utilization", Json::Num(res.peak_utilization)),
        ("flows", Json::Num(res.flows as f64)),
        ("total_bytes", Json::Num(res.total_bytes)),
    ])
}

/// Deterministic delta-simulation metrics of one workload case
/// (DESIGN.md §16): the multi-tenant DAG is composed and cold-run once
/// ([`WorkloadDelta::record`]), then every scenario of the
/// time-windowed fault ensemble ([`crate::perturb::bench::delta_ensemble`])
/// runs both warm and cold. Reports the replay-tier mix and the
/// cold/warm work-unit ratio — simulated work only, byte-reproducible
/// from the seed. Warm-vs-cold makespan agreement to 1e-9 is asserted
/// per scenario as a tripwire.
fn delta_case_doc(label: &str, topo: &Topology, spec: &WorkloadSpec, seed: u64) -> Json {
    use crate::sim::replay::work_units;
    let wd = WorkloadDelta::record(topo, spec, Params::default())
        .expect("bench spec must validate");
    let ens =
        crate::perturb::bench::delta_ensemble(topo, wd.delta.baseline().makespan, seed);
    let mut warm_units = 0u64;
    let mut cold_units = 0u64;
    let (mut n_identical, mut n_cold, mut n_tail, mut n_warm) = (0u64, 0u64, 0u64, 0u64);
    let mut max_rel = 0.0f64;
    for perts in &ens {
        let mode = wd.delta.mode(perts);
        let (rw, ow) = wd.delta.run(perts);
        let (rc, oc) = wd.delta.run_cold(perts);
        assert!(
            ow.is_completed() && oc.is_completed(),
            "{label}: transient-fault timeline did not complete"
        );
        match mode {
            "identical" => n_identical += 1,
            "cold" => n_cold += 1,
            "tail" => n_tail += 1,
            _ => n_warm += 1,
        }
        // pure replays (identical/tail) execute zero live events; their
        // returned stats are the baseline's and are not billed
        if !matches!(mode, "identical" | "tail") {
            warm_units += work_units(&rw.stats);
        }
        cold_units += work_units(&rc.stats);
        let rel = (rw.makespan - rc.makespan).abs() / rc.makespan.abs().max(1e-300);
        assert!(rel < 1e-9, "{label}: warm {} vs cold {}", rw.makespan, rc.makespan);
        max_rel = max_rel.max(rel);
    }
    obj(vec![
        ("case", Json::Str(label.to_string())),
        ("scenarios", Json::Num(ens.len() as f64)),
        ("identical", Json::Num(n_identical as f64)),
        ("cold", Json::Num(n_cold as f64)),
        ("tail", Json::Num(n_tail as f64)),
        ("warm", Json::Num(n_warm as f64)),
        ("warm_work_units", Json::Num(warm_units as f64)),
        ("cold_work_units", Json::Num(cold_units as f64)),
        ("work_ratio", Json::Num(cold_units as f64 / warm_units.max(1) as f64)),
        ("max_rel_err", Json::Num(max_rel)),
    ])
}

/// The full deterministic `BENCH_workload.json` document. Cases fan
/// out over the bounded worker pool ([`crate::util::pool`]); results
/// come back in case order, so the render is byte-stable.
pub fn bench_doc(seed: u64) -> Json {
    let cases = bench_cases(seed);
    let jobs: Vec<_> = cases
        .iter()
        .map(|(label, topo, spec)| move || case_doc(label, topo, spec))
        .collect();
    let docs = crate::util::pool::parallel_map(jobs);
    let delta_jobs: Vec<_> = cases
        .iter()
        .map(|(label, topo, spec)| move || delta_case_doc(label, topo, spec, seed))
        .collect();
    let delta_docs = crate::util::pool::parallel_map(delta_jobs);
    obj(vec![
        ("bench", Json::Str("bench_workload".to_string())),
        ("seed", Json::Num(seed as f64)),
        ("cases", Json::Arr(docs)),
        ("delta_sim", Json::Arr(delta_docs)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_cover_all_systems_plus_auto() {
        let cases = bench_cases(42);
        assert_eq!(cases.len(), 4);
        for kind in SystemKind::all() {
            assert!(cases.iter().any(|(l, ..)| l.starts_with(kind.name())));
        }
        assert!(cases.iter().any(|(l, ..)| l.ends_with("auto")));
    }

    #[test]
    fn doc_has_simulated_metrics_and_no_wall_clock() {
        let doc = bench_doc(7);
        let cases = doc.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 4);
        for c in cases {
            assert!(c.get("makespan_s").unwrap().as_f64().unwrap() > 0.0);
            assert!(c.get("mean_s").is_none(), "wall-clock field leaked into the artifact");
            let u = c.get("utilization").unwrap().as_f64().unwrap();
            assert!(u > 0.0 && u <= 1.0);
        }
        // the delta-sim grid: tier counts partition the scenarios and
        // warm replay never costs more work than cold re-simulation
        let deltas = doc.get("delta_sim").unwrap().as_arr().unwrap();
        assert_eq!(deltas.len(), 4);
        for d in deltas {
            let n = d.get("scenarios").unwrap().as_f64().unwrap();
            assert_eq!(n, 32.0);
            let tiers: f64 = ["identical", "cold", "tail", "warm"]
                .iter()
                .map(|k| d.get(k).unwrap().as_f64().unwrap())
                .sum();
            assert_eq!(tiers, n, "replay tiers must partition the scenarios");
            let warm = d.get("warm_work_units").unwrap().as_f64().unwrap();
            let cold = d.get("cold_work_units").unwrap().as_f64().unwrap();
            assert!(warm <= cold, "replay cost {warm} exceeds cold cost {cold}");
            assert!(d.get("work_ratio").unwrap().as_f64().unwrap() >= 1.0);
            assert!(d.get("max_rel_err").unwrap().as_f64().unwrap() < 1e-9);
        }
    }
}
