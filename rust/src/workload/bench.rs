//! The `bench_workload` measurement grid and its deterministic
//! `BENCH_workload.json` payload.
//!
//! The JSON artifact contains **simulated** metrics only (makespan,
//! latency percentiles, utilization, flow counts) — no wall-clock
//! fields — so a fixed seed reproduces the file byte-for-byte run
//! over run (`tests/workload_determinism.rs` pins this, guarding the
//! PRNG-offset and pool fan-out paths). Wall-clock timing of the same
//! cases is printed by the bench binary but never written to the
//! artifact.

use crate::comm::{Library, Params};
use crate::topology::systems::SystemKind;
use crate::topology::Topology;
use crate::util::json::{obj, Json};
use crate::util::stats::percentile;

use super::engine::run_workload;
use super::spec::{TenantLib, WorkloadSpec};

/// The bench grid: per paper system a 4-tenant NCCL contention case,
/// plus one auto-selection case on the DGX-1 (the selector under
/// contention). Deterministic in `seed`.
pub fn bench_cases(seed: u64) -> Vec<(String, Topology, WorkloadSpec)> {
    let mut out = Vec::new();
    for kind in SystemKind::all() {
        let topo = kind.build();
        let gpus = topo.num_gpus().min(8);
        let spec = WorkloadSpec::synthetic(
            4,
            4,
            gpus,
            TenantLib::Fixed(Library::Nccl),
            16 << 20,
            seed,
        );
        out.push((format!("{}/4x4/nccl", kind.name()), topo, spec));
    }
    let topo = SystemKind::Dgx1.build();
    let spec = WorkloadSpec::synthetic(2, 2, 8, TenantLib::Auto, 8 << 20, seed);
    out.push(("dgx1/2x2/auto".to_string(), topo, spec));
    out
}

/// Simulated metrics of one bench case as a JSON object.
fn case_doc(label: &str, topo: &Topology, spec: &WorkloadSpec) -> Json {
    let res = run_workload(topo, spec, Params::default()).expect("bench spec must validate");
    let lats: Vec<f64> = res.all_ops().map(|o| o.latency()).collect();
    obj(vec![
        ("case", Json::Str(label.to_string())),
        ("tenants", Json::Num(spec.tenants.len() as f64)),
        ("ops", Json::Num(lats.len() as f64)),
        ("makespan_s", Json::Num(res.makespan)),
        ("p50_latency_s", Json::Num(percentile(&lats, 50.0))),
        ("p99_latency_s", Json::Num(percentile(&lats, 99.0))),
        ("utilization", Json::Num(res.utilization)),
        ("peak_utilization", Json::Num(res.peak_utilization)),
        ("flows", Json::Num(res.flows as f64)),
        ("total_bytes", Json::Num(res.total_bytes)),
    ])
}

/// The full deterministic `BENCH_workload.json` document. Cases fan
/// out over the bounded worker pool ([`crate::util::pool`]); results
/// come back in case order, so the render is byte-stable.
pub fn bench_doc(seed: u64) -> Json {
    let cases = bench_cases(seed);
    let jobs: Vec<_> = cases
        .iter()
        .map(|(label, topo, spec)| move || case_doc(label, topo, spec))
        .collect();
    let docs = crate::util::pool::parallel_map(jobs);
    obj(vec![
        ("bench", Json::Str("bench_workload".to_string())),
        ("seed", Json::Num(seed as f64)),
        ("cases", Json::Arr(docs)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_cover_all_systems_plus_auto() {
        let cases = bench_cases(42);
        assert_eq!(cases.len(), 4);
        for kind in SystemKind::all() {
            assert!(cases.iter().any(|(l, ..)| l.starts_with(kind.name())));
        }
        assert!(cases.iter().any(|(l, ..)| l.ends_with("auto")));
    }

    #[test]
    fn doc_has_simulated_metrics_and_no_wall_clock() {
        let doc = bench_doc(7);
        let cases = doc.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 4);
        for c in cases {
            assert!(c.get("makespan_s").unwrap().as_f64().unwrap() > 0.0);
            assert!(c.get("mean_s").is_none(), "wall-clock field leaked into the artifact");
            let u = c.get("utilization").unwrap().as_f64().unwrap();
            assert!(u > 0.0 && u <= 1.0);
        }
    }
}
