//! Open-loop serving engine: jobs arrive via seeded Poisson or trace
//! inter-arrival streams, pass an admission/queueing policy, and
//! execute as collectives on the shared fabric (DESIGN.md §17).
//!
//! The closed-loop workload engine ([`super::engine`]) replays a fixed
//! tenant list to completion — it can say how long a batch takes, but
//! not the production question: at what offered load does a fabric's
//! tail latency knee over? This module reframes the same planned op
//! streams as a long-running service:
//!
//! - **Arrivals** ([`ArrivalProcess`]): per tenant, job k arrives at an
//!   absolute instant `t_k = t_{k-1} + arrival_delay(k) + open_gap(k)`
//!   where `open_gap` is an Exp(rate) draw (Poisson) or a cycled trace
//!   gap. Both draws come from the tenant's **one** arrival RNG stream
//!   in a fixed per-job order — which is exactly why
//!   [`super::spec::TenantSpec::arrival_delay`] must consume a draw
//!   unconditionally (the PR 10 draw-stability fix): a zero-jitter
//!   tenant would otherwise shift every inter-arrival sample.
//! - **Admission** ([`QueuePolicy`]): FIFO (global sliding window of
//!   `depth` jobs in service), per-tenant fair (window per tenant), or
//!   reject-on-depth (per-tenant serialized service with a bounded
//!   system: a job arriving while `depth` jobs are already waiting or
//!   in flight is rejected). Rejection verdicts are decided on a
//!   pristine pass (congestion-pessimistic single iteration, see
//!   [`compose_serve`]) so they are deterministic and fault-invariant.
//! - **Warm-up** ([`warmup_cutoff`]): the MSER truncation rule on the
//!   completion-ordered latency series drops the transient prefix
//!   before percentiles are computed.
//! - **Warm-start** ([`ServeDelta`]): the serving DAG is composed and
//!   cold-simulated once; fault-timeline ensembles then replay against
//!   the recorded baseline via [`crate::perturb::DeltaSim`]
//!   (DESIGN.md §16), so a long horizon amortizes baseline recording
//!   instead of re-simulating per scenario.
//!
//! The anchor contract (ROADMAP item 2, pinned in
//! `tests/workload_determinism.rs` on both engines): at zero arrival
//! rate ([`ArrivalProcess::Closed`]) the engine delegates composition
//! verbatim to [`super::engine`]'s `compose_workload`, building the
//! task-for-task identical DAG — so the closed-loop limit is bit-exact
//! to [`super::run_workload`] per library × system.

use crate::anyhow;
use crate::comm::Params;
use crate::sim::{Sim, SimResult, TaskId};
use crate::topology::Topology;
use crate::util::error::Result;
use crate::util::stats::percentile;

use super::engine::{self, PlannedOp};
use super::spec::WorkloadSpec;

/// How jobs arrive at the service.
#[derive(Clone, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// The zero-arrival-rate limit: no open-loop gaps at all — job k+1
    /// gates on job k exactly as the closed-loop workload engine does.
    /// Composition delegates to `compose_workload` verbatim, so this is
    /// bit-exact to [`super::run_workload`] (the differential anchor).
    Closed,
    /// Seeded Poisson arrivals: each tenant adds an Exp(`rate`) draw to
    /// every inter-arrival (jobs/second per tenant, finite and > 0).
    Poisson {
        /// Mean arrival rate per tenant, jobs per second.
        rate: f64,
    },
    /// Explicit inter-arrival gaps (seconds), cycled when a tenant
    /// issues more jobs than the trace holds.
    Trace {
        /// Inter-arrival gaps, all finite and non-negative.
        gaps: Vec<f64>,
    },
}

impl ArrivalProcess {
    /// `--rate` semantics: 0 is the closed-loop limit, anything
    /// positive is Poisson. (The CLI rejects negative/non-finite rates
    /// before this.)
    pub fn from_rate(rate: f64) -> ArrivalProcess {
        if rate == 0.0 {
            ArrivalProcess::Closed
        } else {
            ArrivalProcess::Poisson { rate }
        }
    }

    /// Report label ("closed", "poisson(250/s)", "trace(8)").
    pub fn label(&self) -> String {
        match self {
            ArrivalProcess::Closed => "closed".to_string(),
            ArrivalProcess::Poisson { rate } => format!("poisson({rate:.1}/s)"),
            ArrivalProcess::Trace { gaps } => format!("trace({})", gaps.len()),
        }
    }
}

/// Admission-control / queueing policy of the service.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueuePolicy {
    /// Global FIFO window: at most `depth` jobs (across all tenants,
    /// in arrival order) in service at once; later jobs queue.
    Fifo {
        /// Jobs in service at once.
        depth: usize,
    },
    /// Per-tenant fair window: each tenant independently keeps up to
    /// `depth` of its own jobs in service — one tenant's burst cannot
    /// head-of-line-block another's.
    Fair {
        /// Jobs in service at once, per tenant.
        depth: usize,
    },
    /// Bounded per-tenant system: service is serialized per tenant and
    /// a job arriving while `depth` jobs are already in the system
    /// (waiting + in flight) is rejected outright.
    RejectOnDepth {
        /// Maximum jobs in system per tenant.
        depth: usize,
    },
}

impl QueuePolicy {
    /// Parse a `--policy` value ("fifo", "fair", "reject") with the
    /// given window depth.
    pub fn parse(s: &str, depth: usize) -> Option<QueuePolicy> {
        if s.eq_ignore_ascii_case("fifo") {
            Some(QueuePolicy::Fifo { depth })
        } else if s.eq_ignore_ascii_case("fair") {
            Some(QueuePolicy::Fair { depth })
        } else if s.eq_ignore_ascii_case("reject") {
            Some(QueuePolicy::RejectOnDepth { depth })
        } else {
            None
        }
    }

    /// The policy's window depth.
    pub fn depth(&self) -> usize {
        match self {
            QueuePolicy::Fifo { depth }
            | QueuePolicy::Fair { depth }
            | QueuePolicy::RejectOnDepth { depth } => *depth,
        }
    }

    /// Report label ("fifo(4)", "fair(4)", "reject(4)").
    pub fn label(&self) -> String {
        match self {
            QueuePolicy::Fifo { depth } => format!("fifo({depth})"),
            QueuePolicy::Fair { depth } => format!("fair({depth})"),
            QueuePolicy::RejectOnDepth { depth } => format!("reject({depth})"),
        }
    }
}

/// A complete serving configuration: the tenants and their planned op
/// streams ([`WorkloadSpec`] — `ops` is the job horizon per tenant),
/// the arrival process, and the admission policy.
#[derive(Clone, Debug)]
pub struct ServeSpec {
    /// Tenants, op streams, seed, and fault timeline. In open-loop
    /// modes the spec's `start_offset`/`gap`/`jitter` act as a minimum
    /// inter-arrival floor underneath the open-loop gaps.
    pub workload: WorkloadSpec,
    /// How jobs arrive.
    pub arrivals: ArrivalProcess,
    /// Admission policy. Ignored in [`ArrivalProcess::Closed`] mode,
    /// where each tenant's own op chain is the only gating (the anchor
    /// contract requires the closed DAG to be exactly the workload
    /// engine's).
    pub policy: QueuePolicy,
}

impl ServeSpec {
    /// A synthetic open-loop serving spec: the §9 synthetic tenants
    /// with their closed-loop pacing (start offsets and think-time
    /// gaps) stripped, so arrivals are governed by the open-loop
    /// process alone plus the seeded jitter.
    #[allow(clippy::too_many_arguments)]
    pub fn synthetic(
        tenants: usize,
        jobs: usize,
        gpus: usize,
        lib: super::spec::TenantLib,
        total: u64,
        seed: u64,
        arrivals: ArrivalProcess,
        policy: QueuePolicy,
    ) -> ServeSpec {
        let mut workload = WorkloadSpec::synthetic(tenants, jobs, gpus, lib, total, seed);
        workload.name = format!("serve-{tenants}x{jobs}");
        for t in &mut workload.tenants {
            t.start_offset = 0.0;
            t.gap = 0.0;
        }
        ServeSpec { workload, arrivals, policy }
    }

    /// Check the spec can run on `topo` (clean errors, CLI-surfaced).
    pub fn validate(&self, topo: &Topology) -> Result<()> {
        self.workload.validate(topo)?;
        if self.policy.depth() == 0 {
            return Err(anyhow!(
                "serve policy {}: depth must be >= 1",
                self.policy.label()
            ));
        }
        match &self.arrivals {
            ArrivalProcess::Closed => {}
            ArrivalProcess::Poisson { rate } => {
                if !rate.is_finite() || *rate <= 0.0 {
                    return Err(anyhow!(
                        "poisson arrival rate must be finite and positive, got {rate}"
                    ));
                }
            }
            ArrivalProcess::Trace { gaps } => {
                if gaps.is_empty() {
                    return Err(anyhow!("trace arrivals need at least one inter-arrival gap"));
                }
                for (i, g) in gaps.iter().enumerate() {
                    if !g.is_finite() || *g < 0.0 {
                        return Err(anyhow!(
                            "trace gap {i} must be finite and non-negative, got {g}"
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

/// One job of the service, in (tenant, index) order.
#[derive(Clone, Debug)]
pub struct JobRecord {
    /// Index of the owning tenant in the spec.
    pub tenant: usize,
    /// Job index within the tenant's stream.
    pub index: usize,
    /// Library (or "LIB/algo") label that ran the job.
    pub label: String,
    /// Sum of the job's per-rank counts.
    pub bytes: u64,
    /// Absolute arrival instant (open-loop: the arrival stream; closed:
    /// the instant the job's gate completed, matching
    /// [`super::OpRecord::arrival`]).
    pub arrival: f64,
    /// Instant the admission gate released the job into service
    /// (equals `arrival` when it never queued).
    pub admitted: f64,
    /// Completion instant; equals `arrival` for rejected jobs.
    pub finish: f64,
    /// Whether admission rejected the job ([`QueuePolicy::RejectOnDepth`]).
    pub rejected: bool,
    /// Point-to-point flows of the job's subgraph (0 if rejected).
    pub flows: usize,
}

impl JobRecord {
    /// Response time the client observed: queueing wait + service.
    pub fn latency(&self) -> f64 {
        self.finish - self.arrival
    }

    /// Queueing wait before admission.
    pub fn wait(&self) -> f64 {
        self.admitted - self.arrival
    }
}

/// Aggregated outcome of one serving run. Percentiles are over the
/// **steady-state** completion-ordered latency series (warm-up prefix
/// dropped per [`warmup_cutoff`]).
#[derive(Clone, Debug)]
pub struct ServeResult {
    /// Every job, in (tenant, index) order.
    pub jobs: Vec<JobRecord>,
    /// Jobs that completed (admitted and finished).
    pub completed: usize,
    /// Jobs admission rejected.
    pub rejected: usize,
    /// Completed jobs excluded from the percentiles as warm-up.
    pub warmup_jobs: usize,
    /// Aggregate offered load (jobs/second across all tenants; 0.0 in
    /// closed mode).
    pub offered_rate: f64,
    /// Completed jobs per second of makespan.
    pub throughput: f64,
    /// Median steady-state response latency (seconds).
    pub p50: f64,
    /// 95th-percentile steady-state response latency.
    pub p95: f64,
    /// 99.9th-percentile steady-state response latency.
    pub p999: f64,
    /// Mean steady-state response latency.
    pub mean_latency: f64,
    /// Mean steady-state queueing wait.
    pub mean_wait: f64,
    /// Virtual time the last task of the serving DAG finished.
    pub makespan: f64,
    /// Total point-to-point flows simulated.
    pub flows: usize,
}

/// One composed (or rejected) job awaiting execution: the static
/// skeleton [`aggregate`] turns into a [`JobRecord`] once times exist.
#[derive(Clone, Debug)]
struct JobSkeleton {
    tenant: usize,
    index: usize,
    label: String,
    bytes: u64,
    /// Static arrival instant (open-loop). Closed-loop jobs have none
    /// and read their arrival off the gate task at collect time.
    arrival: Option<f64>,
    gate: Option<TaskId>,
    /// `None` = rejected: the job composed no tasks at all.
    done: Option<TaskId>,
    flows: usize,
}

/// Per-job `(tenant, index, arrival)` in global arrival order (ties
/// broken by tenant then index — deterministic total order).
fn arrival_order(spec: &ServeSpec, plans: &[Vec<PlannedOp>]) -> Vec<(usize, usize, f64)> {
    let mut order = Vec::new();
    for (t, ten) in spec.workload.tenants.iter().enumerate() {
        let mut rng = ten.arrival_rng(spec.workload.seed);
        let mut now = 0.0f64;
        for k in 0..plans[t].len() {
            // one arrival_delay draw, then the open-loop gap draw, both
            // on the tenant's single arrival stream (fixed draw order)
            let mut d = ten.arrival_delay(k, &mut rng);
            d += match &spec.arrivals {
                ArrivalProcess::Closed => 0.0,
                ArrivalProcess::Poisson { rate } => {
                    // u in [0,1) => 1-u in (0,1] => a finite Exp(rate) draw
                    let u = rng.next_f64();
                    -(1.0 - u).ln() / rate
                }
                ArrivalProcess::Trace { gaps } => gaps[k % gaps.len()],
            };
            now += d;
            order.push((t, k, now));
        }
    }
    order.sort_by(|a, b| a.2.total_cmp(&b.2).then(a.0.cmp(&b.0)).then(a.1.cmp(&b.1)));
    order
}

/// Compose the admitted jobs of an open-loop run into `sim`, in global
/// arrival order. Each job gets an absolute arrival marker task and an
/// admission gate joining the marker with its window predecessor's
/// completion; the collective composes behind the gate via the planned
/// op's compose entry point. Returns skeletons aligned to `order`.
fn compose_open(
    sim: &mut Sim,
    params: Params,
    spec: &ServeSpec,
    plans: &[Vec<PlannedOp>],
    order: &[(usize, usize, f64)],
    admitted: &[bool],
) -> Vec<JobSkeleton> {
    let depth = spec.policy.depth();
    let mut global_dones: Vec<TaskId> = Vec::new();
    let mut tenant_dones: Vec<Vec<TaskId>> = vec![Vec::new(); plans.len()];
    let mut out = Vec::with_capacity(order.len());
    for (i, &(t, k, arrival)) in order.iter().enumerate() {
        let op = &plans[t][k];
        let bytes: u64 = op.counts.iter().sum();
        if !admitted[i] {
            out.push(JobSkeleton {
                tenant: t,
                index: k,
                label: op.label.clone(),
                bytes,
                arrival: Some(arrival),
                gate: None,
                done: None,
                flows: 0,
            });
            continue;
        }
        let arrive = sim.delay(arrival, &[]);
        let pred = match spec.policy {
            QueuePolicy::Fifo { .. } => {
                global_dones.len().checked_sub(depth).map(|j| global_dones[j])
            }
            QueuePolicy::Fair { .. } => {
                tenant_dones[t].len().checked_sub(depth).map(|j| tenant_dones[t][j])
            }
            // service is serialized per tenant; depth bounds the system
            QueuePolicy::RejectOnDepth { .. } => tenant_dones[t].last().copied(),
        };
        let gate = match pred {
            None => arrive,
            Some(p) => sim.delay(0.0, &[arrive, p]),
        };
        let mark = sim.task_count();
        let done = engine::compose_planned(sim, params, op, Some(gate));
        let flows = sim.flow_tasks_since(mark);
        global_dones.push(done);
        tenant_dones[t].push(done);
        out.push(JobSkeleton {
            tenant: t,
            index: k,
            label: op.label.clone(),
            bytes,
            arrival: Some(arrival),
            gate: Some(gate),
            done: Some(done),
            flows,
        });
    }
    out
}

/// Reject-on-depth admission verdicts: iterate jobs in global arrival
/// order and reject a job when its tenant already has `depth` accepted
/// jobs in the system (arrived, not yet finished) at its arrival
/// instant. In-system membership uses the all-admitted pristine pass's
/// finish times, so verdicts are **congestion-pessimistic** (a job we
/// reject here may have drained earlier once rejections thin the
/// queue) and computed in a single iteration — deterministic, and
/// independent of the fault timeline.
fn reject_verdicts(
    order: &[(usize, usize, f64)],
    finishes: &[f64],
    tenants: usize,
    depth: usize,
) -> Vec<bool> {
    let mut accepted_fin: Vec<Vec<f64>> = vec![Vec::new(); tenants];
    let mut admitted = Vec::with_capacity(order.len());
    for (i, &(t, _, arrival)) in order.iter().enumerate() {
        let in_system = accepted_fin[t].iter().filter(|&&f| f > arrival).count();
        if in_system >= depth {
            admitted.push(false);
        } else {
            accepted_fin[t].push(finishes[i]);
            admitted.push(true);
        }
    }
    admitted
}

/// Compose the whole service into `sim` and return job skeletons in
/// (tenant, index) order. Closed mode delegates to the workload
/// engine's `compose_workload` verbatim (the bit-exactness anchor);
/// reject-on-depth first runs a pristine all-admitted pass in a
/// scratch sim to decide verdicts, then composes only admitted jobs.
fn compose_serve(
    sim: &mut Sim,
    spec: &ServeSpec,
    params: Params,
    plans: &[Vec<PlannedOp>],
) -> Vec<JobSkeleton> {
    let mut skel = match &spec.arrivals {
        ArrivalProcess::Closed => engine::compose_workload(sim, &spec.workload, params, plans)
            .into_iter()
            .map(|p| JobSkeleton {
                tenant: p.tenant,
                index: p.index,
                label: p.label,
                bytes: p.bytes,
                arrival: None,
                gate: p.gate,
                done: Some(p.done),
                flows: p.flows,
            })
            .collect::<Vec<_>>(),
        _ => {
            let order = arrival_order(spec, plans);
            let admitted = if let QueuePolicy::RejectOnDepth { depth } = spec.policy {
                let mut scratch = Sim::new(sim.topology());
                let all = vec![true; order.len()];
                let skel1 = compose_open(&mut scratch, params, spec, plans, &order, &all);
                let res1 = scratch.run();
                let fin: Vec<f64> =
                    skel1.iter().map(|s| res1.finish(s.done.expect("all admitted"))).collect();
                reject_verdicts(&order, &fin, plans.len(), depth)
            } else {
                vec![true; order.len()]
            };
            compose_open(sim, params, spec, plans, &order, &admitted)
        }
    };
    skel.sort_by(|a, b| (a.tenant, a.index).cmp(&(b.tenant, b.index)));
    skel
}

/// Aggregate offered load of the spec (jobs/second across tenants).
fn offered_rate(spec: &ServeSpec, skel: &[JobSkeleton]) -> f64 {
    match &spec.arrivals {
        ArrivalProcess::Closed => 0.0,
        ArrivalProcess::Poisson { rate } => rate * spec.workload.tenants.len() as f64,
        ArrivalProcess::Trace { .. } => {
            let span = skel.iter().filter_map(|s| s.arrival).fold(0.0f64, f64::max);
            if span > 0.0 {
                skel.len() as f64 / span
            } else {
                0.0
            }
        }
    }
}

/// MSER steady-state truncation: drop the transient prefix `d*` of a
/// completion-ordered series, where `d*` minimizes
/// `sum_{i>=d}(x_i - mean_{i>=d})^2 / (n-d)^2` over the first half of
/// the series. Series shorter than 8 observations are kept whole.
pub fn warmup_cutoff(xs: &[f64]) -> usize {
    let n = xs.len();
    if n < 8 {
        return 0;
    }
    let mut scores = vec![f64::INFINITY; n];
    let (mut sum, mut sumsq) = (0.0f64, 0.0f64);
    for d in (0..n).rev() {
        sum += xs[d];
        sumsq += xs[d] * xs[d];
        let m = (n - d) as f64;
        let sse = (sumsq - sum * sum / m).max(0.0);
        scores[d] = sse / (m * m);
    }
    let mut best = 0usize;
    for (d, &s) in scores.iter().enumerate().take(n / 2 + 1) {
        if s < scores[best] {
            best = d;
        }
    }
    best
}

/// p95 knee threshold: the knee is the last load point whose p95 stays
/// within this factor of the lowest-load p95.
pub const KNEE_FACTOR: f64 = 2.0;

/// Index of the knee point on a load sweep's p95 series (ascending
/// offered load): the last point before the first to exceed
/// `factor * p95[0]`; the final point when none does.
pub fn knee_index(p95: &[f64], factor: f64) -> usize {
    assert!(!p95.is_empty() && factor >= 1.0);
    let limit = factor * p95[0];
    for (i, &v) in p95.iter().enumerate() {
        if v > limit {
            return i.saturating_sub(1);
        }
    }
    p95.len() - 1
}

/// Turn a finished run into job records and steady-state aggregates.
fn aggregate(offered: f64, res: &SimResult, skel: &[JobSkeleton]) -> ServeResult {
    let jobs: Vec<JobRecord> = skel
        .iter()
        .map(|s| match s.done {
            Some(done) => {
                let arrival =
                    s.arrival.unwrap_or_else(|| s.gate.map(|g| res.finish(g)).unwrap_or(0.0));
                let admitted = s.gate.map(|g| res.finish(g)).unwrap_or(arrival);
                JobRecord {
                    tenant: s.tenant,
                    index: s.index,
                    label: s.label.clone(),
                    bytes: s.bytes,
                    arrival,
                    admitted,
                    finish: res.finish(done),
                    rejected: false,
                    flows: s.flows,
                }
            }
            None => {
                let a = s.arrival.unwrap_or(0.0);
                JobRecord {
                    tenant: s.tenant,
                    index: s.index,
                    label: s.label.clone(),
                    bytes: s.bytes,
                    arrival: a,
                    admitted: a,
                    finish: a,
                    rejected: true,
                    flows: 0,
                }
            }
        })
        .collect();

    // completion-ordered latency series of completed jobs (stable sort:
    // ties keep (tenant, index) order)
    let mut done_jobs: Vec<&JobRecord> = jobs.iter().filter(|j| !j.rejected).collect();
    done_jobs.sort_by(|a, b| a.finish.total_cmp(&b.finish));
    let lats: Vec<f64> = done_jobs.iter().map(|j| j.latency()).collect();
    let warmup = warmup_cutoff(&lats);
    let steady = &lats[warmup..];
    let (p50, p95, p999, mean_latency) = if steady.is_empty() {
        (0.0, 0.0, 0.0, 0.0)
    } else {
        (
            percentile(steady, 50.0),
            percentile(steady, 95.0),
            percentile(steady, 99.9),
            steady.iter().sum::<f64>() / steady.len() as f64,
        )
    };
    let waits: Vec<f64> = done_jobs[warmup..].iter().map(|j| j.wait()).collect();
    let mean_wait =
        if waits.is_empty() { 0.0 } else { waits.iter().sum::<f64>() / waits.len() as f64 };
    let completed = done_jobs.len();
    let rejected = jobs.len() - completed;
    let throughput = if res.makespan > 0.0 { completed as f64 / res.makespan } else { 0.0 };
    ServeResult {
        jobs,
        completed,
        rejected,
        warmup_jobs: warmup,
        offered_rate: offered,
        throughput,
        p50,
        p95,
        p999,
        mean_latency,
        mean_wait,
        makespan: res.makespan,
        flows: res.flows,
    }
}

/// Run a serving spec on a topology: plan, compose the service into
/// one shared [`Sim`], execute, aggregate steady-state SLOs.
pub fn run_serve(topo: &Topology, spec: &ServeSpec, params: Params) -> Result<ServeResult> {
    spec.validate(topo)?;
    let plans = engine::plan(topo, &spec.workload, params)?;
    Ok(run_serve_planned(topo, spec, params, &plans))
}

/// [`run_serve`] from an already-planned op list — plans depend only on
/// the workload (counts and libraries), never on arrivals, so a load
/// sweep plans once and recomposes per rate point.
pub(crate) fn run_serve_planned(
    topo: &Topology,
    spec: &ServeSpec,
    params: Params,
    plans: &[Vec<PlannedOp>],
) -> ServeResult {
    let mut sim = Sim::new(topo);
    let skel = compose_serve(&mut sim, spec, params, plans);
    let offered = offered_rate(spec, &skel);
    crate::perturb::apply(&mut sim, &spec.workload.faults);
    let res = sim.run();
    aggregate(offered, &res, &skel)
}

/// Isolated service time of the first planned job — the scale the load
/// sweeps derive their saturation rate `1 / (tenants * s0)` from.
pub(crate) fn base_service_time(
    topo: &Topology,
    params: Params,
    plans: &[Vec<PlannedOp>],
) -> f64 {
    let mut sim = Sim::new(topo);
    let done = engine::compose_planned(&mut sim, params, &plans[0][0], None);
    sim.run().finish(done)
}

/// Delta-simulation executor for fault-timeline ensembles over one
/// serving DAG (DESIGN.md §16, the ROADMAP item-4 follow-up): the
/// service is composed and cold-simulated exactly once at record time
/// — including admission verdicts, which are decided on the pristine
/// fabric and therefore frozen into the baseline — and every fault
/// timeline then replays warm from the recorded baseline via
/// [`crate::perturb::DeltaSim`]. An empty timeline is a pure replay,
/// bit-exact to [`run_serve`] on a fault-free spec; perturbed
/// timelines agree with a cold run to 1e-9.
pub struct ServeDelta<'a> {
    offered: f64,
    pub(crate) delta: crate::perturb::DeltaSim<'a>,
    skel: Vec<JobSkeleton>,
}

impl<'a> ServeDelta<'a> {
    /// Plan, compose and cold-simulate the unperturbed service once.
    pub fn record(topo: &'a Topology, spec: &ServeSpec, params: Params) -> Result<ServeDelta<'a>> {
        spec.validate(topo)?;
        let plans = engine::plan(topo, &spec.workload, params)?;
        let mut sim = Sim::new(topo);
        let skel = compose_serve(&mut sim, spec, params, &plans);
        let offered = offered_rate(spec, &skel);
        Ok(ServeDelta { offered, delta: crate::perturb::DeltaSim::record(sim), skel })
    }

    /// Replay one fault timeline against the recorded baseline. Panics
    /// on a deadlocked scenario exactly as [`run_serve`]'s `sim.run()`
    /// does.
    pub fn run(&self, faults: &[crate::perturb::Perturbation]) -> ServeResult {
        let (res, out) = self.delta.run(faults);
        if !out.is_completed() {
            panic!("simulation deadlock: {}", out.describe());
        }
        aggregate(self.offered, &res, &self.skel)
    }

    /// Cold reference run of the same timeline on the pristine DAG —
    /// what the bench and differential tests compare [`ServeDelta::run`]
    /// against.
    pub fn run_cold(&self, faults: &[crate::perturb::Perturbation]) -> ServeResult {
        let (res, out) = self.delta.run_cold(faults);
        if !out.is_completed() {
            panic!("simulation deadlock: {}", out.describe());
        }
        aggregate(self.offered, &res, &self.skel)
    }
}

/// The `bench_serve` measurement grid and its deterministic
/// `BENCH_serve.json` payload: latency-vs-offered-load knee curves per
/// system, a policy comparison, the zero-rate anchor (asserted
/// bit-exact in-process), and the `delta_sim` warm-vs-cold work-unit
/// subtree. Simulated metrics only — byte-reproducible from the seed
/// (`tests/workload_determinism.rs` pins this).
pub mod bench {
    use super::*;
    use crate::comm::Library;
    use crate::topology::systems::SystemKind;
    use crate::util::json::{obj, Json};
    use crate::workload::engine::run_workload;
    use crate::workload::spec::TenantLib;

    /// Offered-load fractions of the saturation rate swept per case.
    pub const RHO_GRID: [f64; 5] = [0.25, 0.5, 0.75, 1.0, 1.25];

    /// The bench grid: per paper system a 2-tenant NCCL serving case
    /// (FIFO window 4, 10 jobs per tenant). The Poisson rate here is a
    /// placeholder — the curve sweeps `RHO_GRID` times the saturation
    /// rate derived from the system's own isolated service time.
    pub fn bench_cases(seed: u64) -> Vec<(String, Topology, ServeSpec)> {
        let mut out = Vec::new();
        for kind in SystemKind::all() {
            let topo = kind.build();
            let gpus = topo.num_gpus().min(8);
            let spec = ServeSpec::synthetic(
                2,
                10,
                gpus,
                TenantLib::Fixed(Library::Nccl),
                4 << 20,
                seed,
                ArrivalProcess::Poisson { rate: 1.0 },
                QueuePolicy::Fifo { depth: 4 },
            );
            out.push((format!("{}/2x10/nccl", kind.name()), topo, spec));
        }
        out
    }

    /// One system's latency-vs-offered-load curve with its knee point.
    fn curve_doc(label: &str, topo: &Topology, base: &ServeSpec) -> Json {
        let params = Params::default();
        let plans =
            engine::plan(topo, &base.workload, params).expect("bench spec must validate");
        let s0 = base_service_time(topo, params, &plans);
        let tenants = base.workload.tenants.len() as f64;
        let sat = 1.0 / (tenants * s0);
        let mut points = Vec::new();
        let mut p95s = Vec::new();
        for &rho in RHO_GRID.iter() {
            let mut spec = base.clone();
            spec.arrivals = ArrivalProcess::Poisson { rate: rho * sat };
            let r = run_serve_planned(topo, &spec, params, &plans);
            p95s.push(r.p95);
            points.push(obj(vec![
                ("rho", Json::Num(rho)),
                ("rate_per_tenant_hz", Json::Num(rho * sat)),
                ("offered_hz", Json::Num(r.offered_rate)),
                ("p50_s", Json::Num(r.p50)),
                ("p95_s", Json::Num(r.p95)),
                ("p999_s", Json::Num(r.p999)),
                ("throughput_hz", Json::Num(r.throughput)),
                ("completed", Json::Num(r.completed as f64)),
                ("rejected", Json::Num(r.rejected as f64)),
                ("warmup_jobs", Json::Num(r.warmup_jobs as f64)),
            ]));
        }
        let knee = knee_index(&p95s, KNEE_FACTOR);
        obj(vec![
            ("case", Json::Str(label.to_string())),
            ("policy", Json::Str(base.policy.label())),
            ("saturation_hz", Json::Num(sat)),
            ("knee_rho", Json::Num(RHO_GRID[knee])),
            ("knee_offered_hz", Json::Num(RHO_GRID[knee] * sat * tenants)),
            ("points", Json::Arr(points)),
        ])
    }

    /// The three policies at saturation on the DGX-1 (window depth 2,
    /// so reject-on-depth genuinely rejects).
    fn policy_docs(seed: u64) -> Vec<Json> {
        let params = Params::default();
        let topo = SystemKind::Dgx1.build();
        let base = ServeSpec::synthetic(
            2,
            10,
            8,
            TenantLib::Fixed(Library::Nccl),
            4 << 20,
            seed,
            ArrivalProcess::Poisson { rate: 1.0 },
            QueuePolicy::Fifo { depth: 2 },
        );
        let plans =
            engine::plan(&topo, &base.workload, params).expect("bench spec must validate");
        let s0 = base_service_time(&topo, params, &plans);
        let sat = 1.0 / (base.workload.tenants.len() as f64 * s0);
        [
            QueuePolicy::Fifo { depth: 2 },
            QueuePolicy::Fair { depth: 2 },
            QueuePolicy::RejectOnDepth { depth: 2 },
        ]
        .into_iter()
        .map(|policy| {
            let mut spec = base.clone();
            spec.policy = policy;
            spec.arrivals = ArrivalProcess::Poisson { rate: sat };
            let r = run_serve_planned(&topo, &spec, params, &plans);
            obj(vec![
                ("policy", Json::Str(policy.label())),
                ("completed", Json::Num(r.completed as f64)),
                ("rejected", Json::Num(r.rejected as f64)),
                ("p95_s", Json::Num(r.p95)),
                ("throughput_hz", Json::Num(r.throughput)),
                ("mean_wait_s", Json::Num(r.mean_wait)),
            ])
        })
        .collect()
    }

    /// The zero-arrival-rate anchor, per system × library: a closed
    /// serve run's makespan, asserted bit-exact against
    /// [`run_workload`] in-process (a tripwire — the artifact never
    /// silently records a broken anchor).
    fn zero_rate_docs(seed: u64) -> Vec<Json> {
        let mut out = Vec::new();
        for kind in SystemKind::all() {
            let topo = kind.build();
            let gpus = topo.num_gpus().min(8);
            for lib in Library::all() {
                let wspec =
                    WorkloadSpec::synthetic(2, 3, gpus, TenantLib::Fixed(lib), 4 << 20, seed);
                let serve = ServeSpec {
                    workload: wspec.clone(),
                    arrivals: ArrivalProcess::Closed,
                    policy: QueuePolicy::Fifo { depth: 4 },
                };
                let sr =
                    run_serve(&topo, &serve, Params::default()).expect("anchor spec validates");
                let wr =
                    run_workload(&topo, &wspec, Params::default()).expect("anchor spec validates");
                assert_eq!(
                    sr.makespan.to_bits(),
                    wr.makespan.to_bits(),
                    "zero-rate anchor broke on {}/{}",
                    kind.name(),
                    lib.name()
                );
                out.push(obj(vec![
                    ("case", Json::Str(format!("{}/{}", kind.name(), lib.name()))),
                    ("makespan_s", Json::Num(sr.makespan)),
                    ("jobs", Json::Num(sr.completed as f64)),
                ]));
            }
        }
        out
    }

    /// Deterministic delta-simulation metrics of one serving case: the
    /// open-loop DAG records once ([`ServeDelta::record`]), then every
    /// scenario of the time-windowed fault ensemble runs both warm and
    /// cold. Reports the replay-tier mix and the cold/warm work-unit
    /// ratio; warm-vs-cold makespan agreement to 1e-9 is asserted per
    /// scenario as a tripwire.
    fn delta_case_doc(label: &str, topo: &Topology, base: &ServeSpec, seed: u64) -> Json {
        use crate::sim::replay::work_units;
        let sd = ServeDelta::record(topo, base, Params::default())
            .expect("bench spec must validate");
        let ens =
            crate::perturb::bench::delta_ensemble(topo, sd.delta.baseline().makespan, seed);
        let mut warm_units = 0u64;
        let mut cold_units = 0u64;
        let (mut n_identical, mut n_cold, mut n_tail, mut n_warm) = (0u64, 0u64, 0u64, 0u64);
        let mut max_rel = 0.0f64;
        for perts in &ens {
            let mode = sd.delta.mode(perts);
            let (rw, ow) = sd.delta.run(perts);
            let (rc, oc) = sd.delta.run_cold(perts);
            assert!(
                ow.is_completed() && oc.is_completed(),
                "{label}: transient-fault timeline did not complete"
            );
            match mode {
                "identical" => n_identical += 1,
                "cold" => n_cold += 1,
                "tail" => n_tail += 1,
                _ => n_warm += 1,
            }
            // pure replays (identical/tail) execute zero live events;
            // their returned stats are the baseline's and are not billed
            if !matches!(mode, "identical" | "tail") {
                warm_units += work_units(&rw.stats);
            }
            cold_units += work_units(&rc.stats);
            let rel = (rw.makespan - rc.makespan).abs() / rc.makespan.abs().max(1e-300);
            assert!(rel < 1e-9, "{label}: warm {} vs cold {}", rw.makespan, rc.makespan);
            max_rel = max_rel.max(rel);
        }
        obj(vec![
            ("case", Json::Str(label.to_string())),
            ("scenarios", Json::Num(ens.len() as f64)),
            ("identical", Json::Num(n_identical as f64)),
            ("cold", Json::Num(n_cold as f64)),
            ("tail", Json::Num(n_tail as f64)),
            ("warm", Json::Num(n_warm as f64)),
            ("warm_work_units", Json::Num(warm_units as f64)),
            ("cold_work_units", Json::Num(cold_units as f64)),
            ("work_ratio", Json::Num(cold_units as f64 / warm_units.max(1) as f64)),
            ("max_rel_err", Json::Num(max_rel)),
        ])
    }

    /// The full deterministic `BENCH_serve.json` document. Curve and
    /// delta cases fan out over the bounded worker pool; results come
    /// back in case order, so the render is byte-stable.
    pub fn bench_doc(seed: u64) -> Json {
        let cases = bench_cases(seed);
        let jobs: Vec<_> = cases
            .iter()
            .map(|(label, topo, spec)| move || curve_doc(label, topo, spec))
            .collect();
        let curve_docs = crate::util::pool::parallel_map(jobs);
        let delta_jobs: Vec<_> = cases
            .iter()
            .map(|(label, topo, spec)| move || delta_case_doc(label, topo, spec, seed))
            .collect();
        let delta_docs = crate::util::pool::parallel_map(delta_jobs);
        obj(vec![
            ("bench", Json::Str("bench_serve".to_string())),
            ("seed", Json::Num(seed as f64)),
            ("curves", Json::Arr(curve_docs)),
            ("policies", Json::Arr(policy_docs(seed))),
            ("zero_rate", Json::Arr(zero_rate_docs(seed))),
            ("delta_sim", Json::Arr(delta_docs)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Library;
    use crate::perturb::Perturbation;
    use crate::topology::systems::SystemKind;
    use crate::workload::run_workload;
    use crate::workload::spec::TenantLib;

    fn open_spec(seed: u64, rate: f64, policy: QueuePolicy) -> ServeSpec {
        ServeSpec::synthetic(
            2,
            8,
            4,
            TenantLib::Fixed(Library::Nccl),
            2 << 20,
            seed,
            ArrivalProcess::from_rate(rate),
            policy,
        )
    }

    #[test]
    fn closed_serve_is_bit_exact_to_run_workload() {
        // the zero-arrival-rate anchor, event engine, every library
        // (the cross-engine version lives in tests/workload_determinism.rs)
        let topo = SystemKind::Dgx1.build();
        for lib in Library::all() {
            let wspec = WorkloadSpec::synthetic(3, 2, 8, TenantLib::Fixed(lib), 4 << 20, 7);
            let serve = ServeSpec {
                workload: wspec.clone(),
                arrivals: ArrivalProcess::Closed,
                policy: QueuePolicy::Fifo { depth: 4 },
            };
            let sr = run_serve(&topo, &serve, Params::default()).unwrap();
            let wr = run_workload(&topo, &wspec, Params::default()).unwrap();
            assert_eq!(sr.makespan.to_bits(), wr.makespan.to_bits(), "{}", lib.name());
            assert_eq!(sr.flows, wr.flows, "{}", lib.name());
            assert_eq!(sr.rejected, 0);
            assert_eq!(sr.offered_rate, 0.0);
            for (j, o) in sr.jobs.iter().zip(wr.all_ops()) {
                assert_eq!(j.finish.to_bits(), o.finish.to_bits(), "{}", lib.name());
                assert_eq!(j.arrival.to_bits(), o.arrival.to_bits(), "{}", lib.name());
                assert_eq!(j.latency().to_bits(), o.latency().to_bits(), "{}", lib.name());
                assert_eq!(j.flows, o.flows);
            }
        }
    }

    #[test]
    fn closed_mode_ignores_the_policy() {
        let topo = SystemKind::Dgx1.build();
        let mut a = open_spec(3, 0.0, QueuePolicy::Fifo { depth: 1 });
        let ra = run_serve(&topo, &a, Params::default()).unwrap();
        a.policy = QueuePolicy::RejectOnDepth { depth: 1 };
        let rb = run_serve(&topo, &a, Params::default()).unwrap();
        assert_eq!(ra.makespan.to_bits(), rb.makespan.to_bits());
        assert_eq!(rb.rejected, 0);
    }

    #[test]
    fn open_loop_runs_are_deterministic_and_ordered() {
        let topo = SystemKind::Dgx1.build();
        let spec = open_spec(11, 300.0, QueuePolicy::Fifo { depth: 4 });
        let a = run_serve(&topo, &spec, Params::default()).unwrap();
        let b = run_serve(&topo, &spec, Params::default()).unwrap();
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.jobs.len(), 16);
        assert_eq!(a.completed, 16);
        assert_eq!(a.rejected, 0);
        assert!(a.offered_rate > 0.0 && a.throughput > 0.0);
        assert!(a.p999 >= a.p95 && a.p95 >= a.p50 && a.p50 > 0.0);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.finish.to_bits(), y.finish.to_bits());
        }
        // per tenant: arrivals strictly ordered, service causal
        for t in 0..2 {
            let ten: Vec<_> = a.jobs.iter().filter(|j| j.tenant == t).collect();
            for w in ten.windows(2) {
                assert!(w[1].arrival >= w[0].arrival);
            }
            for j in ten {
                assert!(j.admitted >= j.arrival - 1e-12);
                assert!(j.finish > j.admitted);
            }
        }
    }

    #[test]
    fn fair_equals_fifo_for_one_tenant_and_differs_under_cross_tenant_load() {
        let topo = SystemKind::Dgx1.build();
        // one tenant: the global window IS the tenant window, so the
        // two policies build the identical DAG — bit-exact results
        let one = |policy| {
            let spec = ServeSpec::synthetic(
                1,
                8,
                4,
                TenantLib::Fixed(Library::Nccl),
                2 << 20,
                5,
                ArrivalProcess::Poisson { rate: 500.0 },
                policy,
            );
            run_serve(&topo, &spec, Params::default()).unwrap()
        };
        let rf = one(QueuePolicy::Fifo { depth: 1 });
        let ra = one(QueuePolicy::Fair { depth: 1 });
        assert_eq!(rf.makespan.to_bits(), ra.makespan.to_bits());
        assert_eq!(rf.p95.to_bits(), ra.p95.to_bits());
        // two tenants at overload (jobs far larger than the arrival
        // gaps can drain): the global depth-1 window serializes across
        // tenants, per-tenant windows overlap them — the DAGs genuinely
        // differ
        let overload = |policy| {
            ServeSpec::synthetic(
                2,
                8,
                4,
                TenantLib::Fixed(Library::Nccl),
                64 << 20,
                5,
                ArrivalProcess::Poisson { rate: 20_000.0 },
                policy,
            )
        };
        let fifo = overload(QueuePolicy::Fifo { depth: 1 });
        let fair = overload(QueuePolicy::Fair { depth: 1 });
        let rf = run_serve(&topo, &fifo, Params::default()).unwrap();
        let ra = run_serve(&topo, &fair, Params::default()).unwrap();
        assert_eq!(rf.completed, 16);
        assert_eq!(ra.completed, 16);
        assert_ne!(
            rf.makespan.to_bits(),
            ra.makespan.to_bits(),
            "policies built the same DAG under saturating cross-tenant load"
        );
    }

    #[test]
    fn reject_on_depth_rejects_under_overload() {
        let topo = SystemKind::Dgx1.build();
        // very high rate + depth 1 + jobs far larger than the arrival
        // gaps can drain: most jobs find the system full
        let spec = ServeSpec::synthetic(
            2,
            8,
            4,
            TenantLib::Fixed(Library::Nccl),
            64 << 20,
            9,
            ArrivalProcess::Poisson { rate: 50_000.0 },
            QueuePolicy::RejectOnDepth { depth: 1 },
        );
        let r = run_serve(&topo, &spec, Params::default()).unwrap();
        assert_eq!(r.completed + r.rejected, 16);
        assert!(r.rejected > 0, "overload must reject: {r:?}");
        assert!(r.completed >= 2, "the first job per tenant is always admitted");
        for j in r.jobs.iter().filter(|j| j.rejected) {
            assert_eq!(j.finish.to_bits(), j.arrival.to_bits());
            assert_eq!(j.flows, 0);
        }
        // deterministic verdicts
        let r2 = run_serve(&topo, &spec, Params::default()).unwrap();
        let v1: Vec<bool> = r.jobs.iter().map(|j| j.rejected).collect();
        let v2: Vec<bool> = r2.jobs.iter().map(|j| j.rejected).collect();
        assert_eq!(v1, v2);
    }

    #[test]
    fn warmup_cutoff_drops_the_transient_prefix() {
        assert_eq!(warmup_cutoff(&[1.0; 4]), 0, "short series kept whole");
        assert_eq!(warmup_cutoff(&[2.0; 16]), 0, "steady series has no cutoff");
        let mut xs = vec![10.0; 4];
        xs.extend(vec![1.0; 12]);
        assert_eq!(warmup_cutoff(&xs), 4, "inflated prefix truncated");
    }

    #[test]
    fn knee_index_finds_the_last_point_before_the_blowup() {
        assert_eq!(knee_index(&[1.0, 1.1, 1.3, 5.0, 9.0], 2.0), 2);
        assert_eq!(knee_index(&[1.0, 1.1, 1.2], 2.0), 2, "no blowup: last point");
        assert_eq!(knee_index(&[1.0, 9.0], 2.0), 0);
    }

    #[test]
    fn serve_delta_replays_fault_timelines_warm() {
        let topo = SystemKind::Dgx1.build();
        let spec = open_spec(13, 400.0, QueuePolicy::Fifo { depth: 4 });
        let sd = ServeDelta::record(&topo, &spec, Params::default()).unwrap();
        let plain = run_serve(&topo, &spec, Params::default()).unwrap();
        // empty timeline: pure replay, bit-exact to the plain run
        let replay = sd.run(&[]);
        assert_eq!(replay.makespan.to_bits(), plain.makespan.to_bits());
        assert_eq!(replay.p95.to_bits(), plain.p95.to_bits());
        // a mid-run transient degradation: warm vs cold agree to 1e-9
        let link = topo.route_gpus(0, 1).unwrap().links[0];
        let faults = vec![Perturbation::scale(link, 0.4)
            .during(plain.makespan * 0.3, plain.makespan * 0.7)];
        let warm = sd.run(&faults);
        let cold = sd.run_cold(&faults);
        let rel = (warm.makespan - cold.makespan).abs() / cold.makespan;
        assert!(rel < 1e-9, "warm {} vs cold {}", warm.makespan, cold.makespan);
        assert!(warm.completed == plain.completed, "the fault must not lose jobs");
    }

    #[test]
    fn invalid_serve_specs_are_clean_errors() {
        let topo = SystemKind::Dgx1.build();
        let mut bad = open_spec(1, 100.0, QueuePolicy::Fifo { depth: 0 });
        let err = run_serve(&topo, &bad, Params::default()).unwrap_err();
        assert!(format!("{err:#}").contains("depth"), "{err:#}");
        bad.policy = QueuePolicy::Fifo { depth: 4 };
        bad.arrivals = ArrivalProcess::Poisson { rate: -2.0 };
        let err = run_serve(&topo, &bad, Params::default()).unwrap_err();
        assert!(format!("{err:#}").contains("positive"), "{err:#}");
        bad.arrivals = ArrivalProcess::Trace { gaps: vec![] };
        let err = run_serve(&topo, &bad, Params::default()).unwrap_err();
        assert!(format!("{err:#}").contains("trace"), "{err:#}");
        bad.arrivals = ArrivalProcess::Trace { gaps: vec![1.0e-3, f64::NAN] };
        let err = run_serve(&topo, &bad, Params::default()).unwrap_err();
        assert!(format!("{err:#}").contains("finite"), "{err:#}");
    }

    #[test]
    fn trace_arrivals_cycle_and_offered_rate_is_measured() {
        let topo = SystemKind::Dgx1.build();
        let mut spec = open_spec(2, 100.0, QueuePolicy::Fifo { depth: 4 });
        spec.arrivals = ArrivalProcess::Trace { gaps: vec![2.0e-3, 1.0e-3] };
        let r = run_serve(&topo, &spec, Params::default()).unwrap();
        assert_eq!(r.completed, 16);
        assert!(r.offered_rate > 0.0);
        assert!(r.p50 > 0.0);
    }

    #[test]
    fn queue_policy_and_arrival_parsing() {
        assert_eq!(QueuePolicy::parse("fifo", 4), Some(QueuePolicy::Fifo { depth: 4 }));
        assert_eq!(QueuePolicy::parse("FAIR", 2), Some(QueuePolicy::Fair { depth: 2 }));
        assert_eq!(
            QueuePolicy::parse("reject", 1),
            Some(QueuePolicy::RejectOnDepth { depth: 1 })
        );
        assert_eq!(QueuePolicy::parse("nope", 4), None);
        assert_eq!(ArrivalProcess::from_rate(0.0), ArrivalProcess::Closed);
        assert_eq!(
            ArrivalProcess::from_rate(250.0),
            ArrivalProcess::Poisson { rate: 250.0 }
        );
        assert_eq!(QueuePolicy::Fifo { depth: 4 }.label(), "fifo(4)");
        assert!(ArrivalProcess::Closed.label().contains("closed"));
    }
}
