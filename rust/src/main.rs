//! `agv` — the leader binary: regenerate every table/figure of the paper,
//! explore topologies, sweep parameters, and run the end-to-end
//! factorization. See `agv help`.

use std::path::PathBuf;

use agv_bench::anyhow;
use agv_bench::comm::select::{AlgoSelector, RobustObjective};
use agv_bench::comm::transport::RecoveryPolicy;
use agv_bench::comm::{Library, Params};
use agv_bench::cpals::comm_model::{
    gdr_limit_sweep, refacto_comm, refacto_comm_auto, refacto_comm_contended,
    refacto_comm_degraded, ContentionCfg, DEFAULT_ITERS,
};
use agv_bench::cpals::driver::Driver;
use agv_bench::osu::distributions::Distribution;
use agv_bench::perturb::{self, EnsembleCfg, Perturbation};
use agv_bench::report::{
    auto as report_auto, faults as report_faults, fig2, fig3, findings,
    serve as report_serve, table1, workload as report_workload, write_csv,
};
use agv_bench::runtime::{default_artifacts_dir, Runtime};
use agv_bench::tensor::messages::mode_counts;
use agv_bench::tensor::{datasets, synth};
use agv_bench::topology::systems::{SystemKind, SystemSpec};
use agv_bench::util::cli::{parse_bytes, Args};
use agv_bench::util::{fmt_bytes, fmt_time};
use agv_bench::workload::{
    parse_trace, run_serve, run_workload_recovered, ArrivalProcess, OpStream, QueuePolicy,
    ServeSpec, TenantLib, WorkloadSpec,
};

const HELP: &str = "\
agv — reproduction of 'An Empirical Evaluation of Allgatherv on Multi-GPU Systems' (CCGRID'18)

USAGE: agv <command> [options]

COMMANDS
  topo [--list] [--system S]   Fig. 1: print the three system topologies (--system: one
                               system or parametric fabric; --list: the accepted specs)
  fig2 [--csv-dir DIR]         Fig. 2: OSU Allgatherv sweep (all systems/libraries)
  table1 [--csv-dir DIR]       Table I: data set message statistics vs paper
  fig3 [--iters N] [--csv-dir DIR]
                               Fig. 3: ReFacTo communication time grid
  findings                     §VI headline ratios, ours vs paper
  auto [--dataset D] [--gpus N] [--system S] [--csv-dir DIR] [--perturb SPEC]
       [--robust [mean|p95|outage]]
                               auto-selected (library, algorithm) vs each fixed library
                               (--perturb: argmin on the degraded fabric; --robust:
                               argmin of mean/p95 over a seeded fault ensemble)
  osu --system S --gpus N [--lib L] [--perturb SPEC]
                               one OSU sweep (L: mpi|mpi-cuda|nccl|auto;
                               --perturb runs the sweep on a degraded fabric)
  refacto --dataset D --system S --gpus N [--lib L] [--iters N] [--perturb SPEC]
                               one ReFacTo communication simulation (--lib auto picks per mode;
                               --perturb reports healthy vs degraded totals)
  sweep-gdr [--dataset D] [--gpus N] [--limits CSV]
                               MV2_GPUDIRECT_LIMIT sweep (paper §V-C)
  faults [--seed N] [--csv-dir DIR] | faults --list-links --system S
                               fault & variability study: healthy-vs-degraded per system,
                               flat-vs-hierarchical fragility ranking, robust-vs-fresh
                               selector verdicts (--list-links prints --perturb link ids)
  faults --outage [--seed N] [--csv-dir DIR]
                               hard-fault study: link/GPU outages per system x library,
                               timeout-retry-reroute-shrink recovery verdicts, plus
                               outage-robust selection over a seeded outage ensemble
  workload [--system S|all] [--tenants K] [--ops N] [--lib L|auto] [--gpus N]
           [--total BYTES] [--dist D] [--trace FILE] [--gap SECS] [--seed N]
           [--csv-dir DIR] [--refacto DATASET [--iters N]] [--perturb SPEC]
           [--recover [--timeout SECS] [--retries N]]
                               multi-tenant contended Allgatherv study: K concurrent
                               tenants share one fabric; idle-vs-contended latency
                               (--perturb degrades the shared fabric mid-flight;
                               --gap overrides every tenant's inter-op gap;
                               --recover supervises hard outages: stalled jobs are
                               re-issued via timeout-retry-reroute-shrink and the
                               run reports goodput + recovery-latency SLOs)

  serve [--system S|all] [--tenants K] [--jobs N] [--lib L|auto] [--gpus N]
        [--total BYTES] [--dist D] [--rate R] [--policy fifo|fair|reject]
        [--depth K] [--seed N] [--csv-dir DIR]
                               open-loop serving study: jobs arrive via seeded Poisson
                               streams, pass admission control (fifo/fair window, or
                               reject-on-depth), run on the shared fabric; without
                               --rate sweeps offered load and reports the p95 knee
                               capacity per system; --rate R pins one offered load
                               (R jobs/s per tenant; --rate 0 = the closed-loop limit,
                               bit-exact to the workload engine)
  collective [--op O] [--system S] [--gpus N] [--total BYTES] [--chunks K]
             [--root R] [--seed N] [--perturb SPEC]
                               op-generic collective study (O: allgatherv|allreduce|
                               bcast|alltoallv): the §IV count shapes per library with
                               the auto verdict; --chunks K pipelines every logical
                               send as K wire chunks (NCCL-style ring pipelining)
  --system S                   a paper system (cluster|dgx1|cs-storm) or a parametric
                               fabric: fat-tree:k=<even> | dragonfly:a=<n>,p=<n>,h=<n>
                               | multi-plane-pod:nodes=<n>,gpus=<n>,rails=<n>
  --perturb SPEC               comma-separated faults: link:<id>:<factor>[:<start>[:<dur>]]
                               | floor:<id>:<bytes/s>[:<start>[:<dur>]]
                               | straggler:<rank>:<factor>[:<start>[:<dur>]]
                               | down:<id>[:<start>[:<dur>]] | gpudown:<rank>[:<start>[:<dur>]]
                               (outages are total; omitted duration = forever)
  e2e [--config small|e2e] [--system S] [--gpus N] [--iters N] [--seed N]
      [--artifacts DIR]        end-to-end factorization (real compute via PJRT)
  artifacts [--artifacts DIR]  list AOT artifacts and their shapes
  help                         this text
";

fn main() {
    let args = Args::from_env();
    let cmd = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match cmd.as_str() {
        "topo" => cmd_topo(&args),
        "fig2" => cmd_fig2(&args),
        "table1" => cmd_table1(&args),
        "fig3" => cmd_fig3(&args),
        "findings" => cmd_findings(),
        "auto" => cmd_auto(&args),
        "osu" => cmd_osu(&args),
        "refacto" => cmd_refacto(&args),
        "sweep-gdr" => cmd_sweep_gdr(&args),
        "faults" => cmd_faults(&args),
        "workload" => {
            if let Err(e) = cmd_workload(&args) {
                eprintln!("workload failed: {e:#}");
                std::process::exit(1);
            }
        }
        "serve" => {
            if let Err(e) = cmd_serve(&args) {
                eprintln!("serve failed: {e:#}");
                std::process::exit(1);
            }
        }
        "collective" => {
            if let Err(e) = cmd_collective(&args) {
                eprintln!("collective failed: {e:#}");
                std::process::exit(1);
            }
        }
        "e2e" => cmd_e2e(&args),
        "artifacts" => cmd_artifacts(&args),
        "help" | "--help" | "-h" => print!("{HELP}"),
        other => {
            eprintln!("unknown command `{other}`\n");
            print!("{HELP}");
            std::process::exit(2);
        }
    }
}

fn csv_dir(args: &Args) -> Option<PathBuf> {
    args.get("csv-dir").map(PathBuf::from)
}

/// Unwrap a parsed numeric flag; a malformed value is a usage error
/// (clean message, exit 2), never a panic.
fn num_arg<T>(parsed: agv_bench::util::error::Result<T>) -> T {
    parsed.unwrap_or_else(|e| {
        eprintln!("{e:#}");
        std::process::exit(2);
    })
}

fn system_arg(args: &Args) -> SystemSpec {
    let s = args.get_or("system", "dgx1");
    parse_system(s)
}

/// Parse one `--system` value — a paper system or a parametric fabric
/// spec. Malformed specs are usage errors: clean hint, exit 2.
fn parse_system(s: &str) -> SystemSpec {
    SystemSpec::parse(s).unwrap_or_else(|e| {
        eprintln!("--system: {e:#}");
        std::process::exit(2);
    })
}

fn library_arg(args: &Args) -> Option<Library> {
    args.get("lib").map(|s| {
        Library::parse(s).unwrap_or_else(|| {
            eprintln!("unknown library `{s}` (mpi|mpi-cuda|nccl)");
            std::process::exit(2);
        })
    })
}

/// Parse `--perturb SPEC` (None when absent; exits 2 on a bad spec —
/// target ranges are validated later against the concrete topology).
fn perturb_arg(args: &Args) -> Option<Vec<Perturbation>> {
    args.get("perturb").map(|s| {
        perturb::parse_list(s).unwrap_or_else(|e| {
            eprintln!("--perturb: {e:#}");
            std::process::exit(2);
        })
    })
}

/// Exit 2 with a clean message if the fault set does not fit the
/// topology (bad link id / GPU rank / magnitude).
fn check_perturbations(topo: &agv_bench::topology::Topology, perts: &[Perturbation]) {
    if let Err(e) = perturb::validate(topo, perts) {
        eprintln!("--perturb: {e:#}");
        std::process::exit(2);
    }
}

/// Exit 2 if the fault set contains a permanent (infinite-duration)
/// outage. The fail-fast commands run [`agv_bench::sim::Sim::run`],
/// which treats a starved DAG as a hard error; permanent hard faults
/// belong to the recovery-aware surfaces (`hint` names the right one).
/// Transient outages revive and complete natively, so they pass.
fn reject_permanent_outages(perts: &[Perturbation], hint: &str) {
    let fatal = perts.iter().any(|p| {
        matches!(p, Perturbation::LinkDown { .. } | Perturbation::GpuDown { .. })
            && p.window().1.is_infinite()
    });
    if fatal {
        eprintln!(
            "--perturb: a permanent link/GPU outage can starve this fail-fast command \
             (it would stall, not finish slowly); {hint}"
        );
        std::process::exit(2);
    }
}

/// Parse `--robust [mean|p95]` (bare flag defaults to mean).
fn robust_arg(args: &Args) -> Option<RobustObjective> {
    if args.flag("robust") {
        return Some(RobustObjective::Mean);
    }
    args.get("robust").map(|s| {
        RobustObjective::parse(s).unwrap_or_else(|| {
            eprintln!("unknown robust objective `{s}` (mean|p95|outage)");
            std::process::exit(2);
        })
    })
}

fn cmd_topo(args: &Args) {
    if args.flag("list") || args.get("list").is_some() {
        println!("systems accepted by --system:");
        for k in SystemSpec::paper_all() {
            println!("  {:<44} {:>5} GPUs (paper Fig. 1)", k.name(), k.max_gpus());
        }
        println!("  fat-tree:k=<even>                            k^3/4 hosts, full-bisection Clos");
        println!("  dragonfly:a=<n>,p=<n>,h=<n>                  a*h+1 groups of a routers, p hosts each");
        println!("  multi-plane-pod:nodes=<n>,gpus=<n>,rails=<n> rail-optimized, one plane per rail");
        return;
    }
    let specs: Vec<SystemSpec> = match args.get("system") {
        Some(_) => vec![system_arg(args)],
        None => SystemSpec::paper_all().to_vec(),
    };
    for spec in specs {
        let t = spec.build();
        println!("== {} ==", t.name);
        println!(
            "  devices: {}  links: {}  GPUs: {}",
            t.devices.len(),
            t.links.len(),
            t.num_gpus()
        );
        let n = t.num_gpus();
        if n <= 16 {
            println!("  GPUDirect P2P matrix (rows/cols = GPU ranks, '+' = P2P):");
            for a in 0..n {
                let row: String = (0..n)
                    .map(|b| if t.p2p_accessible(a, b) { '+' } else { '.' })
                    .collect();
                println!("    {a:>2} {row}");
            }
        } else {
            println!("  GPUDirect P2P matrix omitted ({n} GPUs; printed for 16 or fewer)");
        }
        println!("  sample routes:");
        for (a, b) in [(0usize, 1usize), (0, n / 2), (0, n - 1)] {
            if a == b || b >= n {
                continue; // degenerate 1-GPU fabrics have no routes to show
            }
            let p = t.route_gpus(a, b).unwrap();
            let bw = t.path_bandwidth(&p);
            println!(
                "    gpu{a} -> gpu{b}: {} hops, bottleneck {:.1} GB/s{}",
                p.hops(),
                bw / 1e9,
                t.route_nvlink_only(a, b)
                    .map(|nv| format!(" (NVLink-only: {} hops)", nv.hops()))
                    .unwrap_or_default()
            );
        }
        println!();
    }
}

fn cmd_fig2(args: &Args) {
    let cells = fig2::grid();
    print!("{}", fig2::render(&cells));
    if let Some(dir) = csv_dir(args) {
        for cell in &cells {
            let p = write_csv(&dir, &fig2::csv_name(cell), &fig2::csv(cell)).unwrap();
            eprintln!("wrote {}", p.display());
        }
    }
}

fn cmd_table1(args: &Args) {
    print!("{}", table1::render());
    if let Some(dir) = csv_dir(args) {
        let p = write_csv(&dir, "table1.csv", &table1::csv()).unwrap();
        eprintln!("wrote {}", p.display());
    }
}

fn cmd_fig3(args: &Args) {
    let iters = num_arg(args.get_usize("iters", DEFAULT_ITERS));
    let panels = fig3::panels(iters);
    print!("{}", fig3::render(&panels));
    if let Some(dir) = csv_dir(args) {
        let p = write_csv(&dir, "fig3.csv", &fig3::csv(&panels)).unwrap();
        eprintln!("wrote {}", p.display());
    }
}

fn cmd_findings() {
    print!("{}", findings::render(&findings::compute()));
}

/// Is `--lib auto` requested? (Handled before [`library_arg`], which
/// only knows the three fixed libraries.)
fn auto_lib(args: &Args) -> bool {
    args.get("lib").is_some_and(|s| s.eq_ignore_ascii_case("auto"))
}

fn cmd_auto(args: &Args) {
    let specs = match args.get("dataset") {
        Some(d) => vec![datasets::by_name(d).unwrap_or_else(|| {
            eprintln!("unknown dataset `{d}`");
            std::process::exit(2);
        })],
        None => datasets::all(),
    };
    let gpus_filter = args.get("gpus").map(|_| num_arg(args.get_usize("gpus", 8)));
    let system_override = args.get("system").map(|_| system_arg(args));
    let perts = perturb_arg(args);
    if let Some(ps) = &perts {
        reject_permanent_outages(ps, "use `agv faults --outage` for hard-fault studies");
    }
    let objective = robust_arg(args);
    if perts.is_some() || objective.is_some() {
        // degraded-fabric selection: argmin of the aggregated makespan
        // over the fault scenarios (an explicit --perturb set is a
        // one-scenario ensemble; otherwise a seeded Monte-Carlo one)
        let objective = objective.unwrap_or(RobustObjective::Mean);
        let seed = num_arg(args.get_u64("seed", 42));
        let gpus = gpus_filter.unwrap_or(8);
        if csv_dir(args).is_some() {
            eprintln!("--csv-dir is not supported with --perturb/--robust (console output only)");
        }
        println!(
            "AUTO on the degraded fabric — objective {} ({})",
            objective.name(),
            match &perts {
                Some(ps) =>
                    ps.iter().map(|p| p.label()).collect::<Vec<_>>().join(", "),
                None => format!("seeded ensemble, seed {seed}"),
            }
        );
        let systems: Vec<SystemSpec> = match system_override {
            Some(s) => vec![s],
            None => SystemSpec::paper_all().to_vec(),
        };
        for spec_sys in systems {
            let topo = spec_sys.build();
            if gpus > topo.num_gpus() {
                continue;
            }
            let ens = match &perts {
                Some(ps) => {
                    // a hand-written set may name links/ranks only some
                    // systems have: skip those systems instead of dying
                    // mid-report
                    if let Err(e) = perturb::validate(&topo, ps) {
                        println!("== {} @ {gpus} GPUs — skipped ({e:#}) ==", spec_sys.name());
                        continue;
                    }
                    vec![ps.clone()]
                }
                None => perturb::ensemble(&topo, &EnsembleCfg::quick(seed)),
            };
            let sel = AlgoSelector::new(Params::default());
            println!("== {} @ {gpus} GPUs ==", spec_sys.name());
            for spec in &specs {
                let counts = mode_counts(spec, gpus);
                for (m, cv) in counts.iter().enumerate() {
                    let fresh = sel.select_fresh(&topo, cv);
                    let rob = sel.select_robust(&topo, cv, &ens, objective);
                    println!(
                        "  {:<10} mode {m}: healthy {} {:>12} | degraded {} {:>12}{}",
                        spec.name,
                        fresh.candidate.label(),
                        fmt_time(fresh.time),
                        rob.candidate.label(),
                        fmt_time(rob.objective),
                        if fresh.candidate == rob.candidate { "" } else { "   <-- flips" }
                    );
                }
            }
        }
        return;
    }
    let rows = report_auto::grid(&specs, gpus_filter, system_override);
    print!("{}", report_auto::render(&rows));
    if let Some(dir) = csv_dir(args) {
        let p = write_csv(&dir, "auto.csv", &report_auto::csv(&rows)).unwrap();
        eprintln!("wrote {}", p.display());
    }
}

fn cmd_faults(args: &Args) {
    if args.flag("list-links") || args.get("list-links").is_some() {
        let spec = match args.get("list-links") {
            Some(s) => parse_system(s),
            None => system_arg(args),
        };
        print!("{}", report_faults::links_table(&spec.build()));
        return;
    }
    let seed = num_arg(args.get_u64("seed", 42));
    if args.flag("outage") || args.get("outage").is_some() {
        let report = report_faults::outage_study(Params::default(), seed);
        print!("{}", report_faults::render_outage(&report));
        if let Some(dir) = csv_dir(args) {
            let p =
                write_csv(&dir, "faults_outage.csv", &report_faults::csv_outage(&report)).unwrap();
            eprintln!("wrote {}", p.display());
        }
        return;
    }
    let report = report_faults::study(Params::default(), seed);
    print!("{}", report_faults::render(&report));
    if let Some(dir) = csv_dir(args) {
        let p = write_csv(&dir, "faults.csv", &report_faults::csv(&report)).unwrap();
        eprintln!("wrote {}", p.display());
    }
}

fn cmd_osu(args: &Args) {
    let system = system_arg(args);
    let gpus = num_arg(args.get_usize("gpus", 2));
    let cfg = agv_bench::osu::OsuConfig::default();
    let topo = system.build();
    if let Some(perts) = perturb_arg(args) {
        check_perturbations(&topo, &perts);
        reject_permanent_outages(&perts, "use `agv faults --outage` for hard-fault studies");
        let labels: Vec<String> = perts.iter().map(|p| p.label()).collect();
        if auto_lib(args) {
            // per size: argmin on the degraded fabric (one-scenario
            // robust selection)
            println!(
                "OSU Allgatherv — {} @ {gpus} GPUs, degraded [{}] (auto on the degraded fabric)",
                system.name(),
                labels.join(", ")
            );
            println!("{:>10} {:>14}  choice", "size", "degraded");
            let sel = AlgoSelector::new(cfg.params);
            for m in agv_bench::osu::sweep_sizes(&cfg, gpus) {
                let counts = vec![m; gpus];
                let r = sel.select_robust(
                    &topo,
                    &counts,
                    std::slice::from_ref(&perts),
                    RobustObjective::Mean,
                );
                println!(
                    "{:>10} {:>14}  {}",
                    fmt_bytes(m),
                    fmt_time(r.objective),
                    r.candidate.label()
                );
            }
            return;
        }
        let libs = library_arg(args)
            .map(|l| vec![l])
            .unwrap_or_else(|| Library::all().to_vec());
        println!(
            "OSU Allgatherv — {} @ {gpus} GPUs, degraded [{}]",
            system.name(),
            labels.join(", ")
        );
        println!(
            "{:>10} {}",
            "size",
            libs.iter().map(|l| format!("{:>14}", l.name())).collect::<String>()
        );
        for m in agv_bench::osu::sweep_sizes(&cfg, gpus) {
            let counts = vec![m; gpus];
            let mut line = format!("{:>10}", fmt_bytes(m));
            for &l in &libs {
                let r = perturb::perturbed_allgatherv(&topo, l, cfg.params, &counts, &perts);
                line.push_str(&format!("{:>14}", fmt_time(r.time)));
            }
            println!("{line}");
        }
        return;
    }
    if auto_lib(args) {
        println!("OSU Allgatherv — {} @ {gpus} GPUs (auto selection)", system.name());
        println!("{:>10} {:>14}  choice", "size", "auto");
        for (pt, cand) in agv_bench::osu::run_osu_auto(&cfg, &topo, gpus) {
            println!(
                "{:>10} {:>14}  {}",
                fmt_bytes(pt.msg_size),
                fmt_time(pt.time),
                cand.label()
            );
        }
        return;
    }
    let libs = library_arg(args)
        .map(|l| vec![l])
        .unwrap_or_else(|| Library::all().to_vec());
    println!("OSU Allgatherv — {} @ {gpus} GPUs", system.name());
    println!(
        "{:>10} {}",
        "size",
        libs.iter().map(|l| format!("{:>14}", l.name())).collect::<String>()
    );
    let results: Vec<_> = libs
        .iter()
        .map(|&l| agv_bench::osu::run_osu(&cfg, &topo, l, gpus))
        .collect();
    for i in 0..results[0].len() {
        let mut line = format!("{:>10}", fmt_bytes(results[0][i].msg_size));
        for r in &results {
            line.push_str(&format!("{:>14}", fmt_time(r[i].time)));
        }
        println!("{line}");
    }
}

fn cmd_refacto(args: &Args) {
    let system = system_arg(args);
    let gpus = num_arg(args.get_usize("gpus", 8));
    let iters = num_arg(args.get_usize("iters", DEFAULT_ITERS));
    let dname = args.get_or("dataset", "netflix");
    let spec = datasets::by_name(dname).unwrap_or_else(|| {
        eprintln!("unknown dataset `{dname}`");
        std::process::exit(2);
    });
    let topo = system.build();
    if let Some(perts) = perturb_arg(args) {
        check_perturbations(&topo, &perts);
        reject_permanent_outages(&perts, "use `agv faults --outage` for hard-fault studies");
        if auto_lib(args) {
            eprintln!(
                "--lib auto with --perturb is served by `agv auto --perturb` \
                 (degraded-fabric selection)"
            );
            std::process::exit(2);
        }
        let labels: Vec<String> = perts.iter().map(|p| p.label()).collect();
        let libs = library_arg(args)
            .map(|l| vec![l])
            .unwrap_or_else(|| Library::all().to_vec());
        println!(
            "ReFacTo communication — {} on {} @ {gpus} GPUs, {iters} iterations, degraded [{}]",
            spec.name,
            system.name(),
            labels.join(", ")
        );
        for lib in libs {
            let r =
                refacto_comm_degraded(&topo, lib, Params::default(), &spec, gpus, iters, &perts);
            println!(
                "  {:<9} healthy {:>12}  degraded {:>12}  slowdown {:>5.2}x",
                lib.name(),
                fmt_time(r.healthy_total),
                fmt_time(r.degraded_total),
                r.slowdown,
            );
        }
        return;
    }
    if auto_lib(args) {
        let r = refacto_comm_auto(&topo, Params::default(), &spec, gpus, iters);
        println!(
            "ReFacTo communication — {} on {} @ {gpus} GPUs, {iters} iterations (auto selection)",
            spec.name,
            system.name()
        );
        println!("  auto      total {:>12}", fmt_time(r.total_time));
        for (m, sel) in r.per_mode.iter().enumerate() {
            println!(
                "    mode {m}: {:>12}/iter via {}{}",
                fmt_time(sel.time),
                sel.candidate.label(),
                if sel.cached { "  [cached]" } else { "" },
            );
        }
        println!(
            "  decision-table cache: {} hits / {} misses",
            r.cache_hits, r.cache_misses
        );
        return;
    }
    let libs = library_arg(args)
        .map(|l| vec![l])
        .unwrap_or_else(|| Library::all().to_vec());
    println!(
        "ReFacTo communication — {} on {} @ {gpus} GPUs, {iters} iterations",
        spec.name,
        system.name()
    );
    for lib in libs {
        let r = refacto_comm(&topo, lib, Params::default(), &spec, gpus, iters);
        println!(
            "  {:<9} total {:>12}   per-mode/iter {} | {} | {}",
            lib.name(),
            fmt_time(r.total_time),
            fmt_time(r.per_mode[0]),
            fmt_time(r.per_mode[1]),
            fmt_time(r.per_mode[2]),
        );
    }
}

fn cmd_sweep_gdr(args: &Args) {
    let dname = args.get_or("dataset", "delicious");
    let spec = datasets::by_name(dname).expect("unknown dataset");
    let gpus = num_arg(args.get_usize("gpus", 8));
    let limits: Vec<u64> = args
        .get("limits")
        .map(|s| s.split(',').map(|x| parse_bytes(x).expect("bad size")).collect())
        .unwrap_or_else(|| vec![16, 64 << 10, 1 << 20, 4 << 20, 8 << 20, 64 << 20, 512 << 20]);
    let topo = SystemKind::Cluster.build();
    println!(
        "MV2_GPUDIRECT_LIMIT sweep — {} on cluster @ {gpus} GPUs (paper §V-C)",
        spec.name
    );
    let sweep = gdr_limit_sweep(&topo, &spec, gpus, 1, &limits);
    let best = sweep.iter().min_by(|a, b| a.1.total_cmp(&b.1)).unwrap().0;
    for (limit, time) in &sweep {
        println!(
            "  limit {:>8}  comm/iter {:>12}{}",
            fmt_bytes(*limit),
            fmt_time(*time),
            if *limit == best { "   <-- best" } else { "" }
        );
    }
}

fn cmd_collective(args: &Args) -> agv_bench::util::error::Result<()> {
    use agv_bench::comm::collective::{
        auto_collective, run_collective, CollectiveOp, CollectiveSpec,
    };
    use agv_bench::comm::transport::ChunkCfg;
    use agv_bench::util::prng::Rng;
    use agv_bench::util::prop::counts;

    let op = {
        let s = args.get_or("op", "allgatherv");
        CollectiveOp::parse(s)
            .ok_or_else(|| anyhow!("unknown op `{s}` (allgatherv|allreduce|bcast|alltoallv)"))?
    };
    // bad system specs are usage errors (exit 2 with the grammar hint),
    // unlike the runtime failures this fn returns as Err (exit 1)
    let topo = system_arg(args).build();
    let gpus = args.get_usize("gpus", topo.num_gpus().min(8))?;
    if gpus == 0 || gpus > topo.num_gpus() {
        return Err(anyhow!("--gpus {gpus}: `{}` has {} GPUs", topo.name, topo.num_gpus()));
    }
    let total = match args.get("total") {
        Some(s) => parse_bytes(s).ok_or_else(|| anyhow!("--total: bad size `{s}`"))?,
        None => 64 << 20,
    };
    let root = args.get_usize("root", 0)?;
    if root >= gpus {
        return Err(anyhow!("--root {root}: op spans ranks 0..{gpus}"));
    }
    let chunks = args.get_usize("chunks", 1)?.max(1);
    let seed = args.get_u64("seed", 42)?;
    let perts = perturb_arg(args).unwrap_or_default();
    perturb::validate(&topo, &perts)?;
    reject_permanent_outages(&perts, "use `agv faults --outage` for hard-fault studies");

    let per_rank = (total / gpus as u64).max(1);
    let mut rng = Rng::new(seed);
    let shapes: Vec<(&str, Vec<u64>)> = vec![
        ("regular", counts::regular(gpus, per_rank)),
        ("skewed", counts::skewed(&mut rng, gpus, per_rank)),
        ("zero-heavy", counts::zero_heavy(&mut rng, gpus, per_rank)),
        ("single-hot", counts::single_hot(&mut rng, gpus, per_rank * gpus as u64)),
    ];

    let chunk = ChunkCfg::pipelined(chunks);
    println!(
        "collective {} on {} ({gpus} GPUs, ~{} total, chunks {chunks}, seed {seed})",
        op.name(),
        topo.name,
        fmt_bytes(total),
    );
    println!();
    let degraded = !perts.is_empty();
    let head_extra = if degraded { "  degraded" } else { "" };
    println!("{:<12} {:>12} {:>12} {:>12}   auto{head_extra}", "shape", "MPI", "MPI-CUDA", "NCCL");
    for (label, cv) in &shapes {
        let mut spec = CollectiveSpec::from_vector(op, cv);
        if let CollectiveSpec::Bcast { root: r, .. } = &mut spec {
            *r = root;
        }
        let mut row = format!("{label:<12}");
        for lib in Library::all() {
            let r = run_collective(&topo, lib, Params::default(), &spec, chunk);
            row.push_str(&format!(" {:>12}", fmt_time(r.time)));
        }
        let (winner, best) = auto_collective(&topo, Params::default(), &spec, chunk);
        row.push_str(&format!("   {} {}", winner.name(), fmt_time(best.time)));
        if degraded {
            let d = perturb::perturbed_collective(
                &topo,
                winner,
                Params::default(),
                &spec,
                chunk,
                &perts,
            );
            row.push_str(&format!("  {}", fmt_time(d.time)));
        }
        println!("{row}");
    }
    if chunks > 1 {
        println!();
        println!("(chunked pipelining: every logical send split into {chunks} wire chunks;");
        println!(" compare against `--chunks 1` for the unpipelined baseline)");
    }
    Ok(())
}

fn cmd_workload(args: &Args) -> agv_bench::util::error::Result<()> {
    let tenants = args.get_usize("tenants", 4)?;
    let ops = args.get_usize("ops", 4)?;
    let seed = args.get_u64("seed", 42)?;
    let lib = {
        let s = args.get_or("lib", "nccl");
        TenantLib::parse(s)
            .ok_or_else(|| anyhow!("unknown library `{s}` (mpi|mpi-cuda|nccl|auto)"))?
    };
    let total = match args.get("total") {
        Some(s) => parse_bytes(s).ok_or_else(|| anyhow!("--total: bad size `{s}`"))?,
        None => 16 << 20,
    };
    let dist = args
        .get("dist")
        .map(|s| {
            Distribution::parse(s).ok_or_else(|| {
                anyhow!("unknown distribution `{s}` (uniform|linear|geometric|spike|random-zipf)")
            })
        })
        .transpose()?;
    let trace_ops = args
        .get("trace")
        .map(|f| -> agv_bench::util::error::Result<Vec<Vec<u64>>> {
            use agv_bench::util::error::Context;
            let text =
                std::fs::read_to_string(f).with_context(|| format!("reading trace `{f}`"))?;
            parse_trace(&text).with_context(|| format!("parsing trace `{f}`"))
        })
        .transpose()?;
    let gpus_flag = args.get("gpus").map(|_| args.get_usize("gpus", 8)).transpose()?;
    let gap_flag = args.get("gap").map(|_| args.get_f64("gap", 0.0)).transpose()?;
    let mut systems: Vec<SystemSpec> = match args.get_or("system", "all") {
        "all" => SystemSpec::paper_all().to_vec(),
        s => vec![parse_system(s)],
    };

    let perts = perturb_arg(args);
    if let Some(ps) = &perts {
        // a hand-written fault set may name links/ranks only some
        // systems have: skip those systems instead of aborting the
        // whole multi-system study (mirrors `agv auto --perturb`)
        systems.retain(|&kind| {
            let topo = kind.build();
            match perturb::validate(&topo, ps) {
                Ok(()) => true,
                Err(e) => {
                    eprintln!("skipping {}: --perturb {e:#}", kind.name());
                    false
                }
            }
        });
        if systems.is_empty() {
            return Err(anyhow!("--perturb fits none of the selected systems"));
        }
    }

    // --refacto: the cpals hook — the data set's comm pattern as one
    // tenant among synthetic background tenants.
    if let Some(dname) = args.get("refacto") {
        for flag in ["trace", "dist", "total", "ops", "perturb", "gap", "timeout", "retries"] {
            if args.get(flag).is_some() {
                return Err(anyhow!(
                    "--{flag} does not apply to --refacto (its tenant replays the data set's \
                     mode trace; use --tenants/--iters/--gpus/--lib/--seed)"
                ));
            }
        }
        if args.flag("recover") {
            return Err(anyhow!(
                "--recover does not apply to --refacto (the contended replay is fail-fast; \
                 use the synthetic workload for supervised recovery)"
            ));
        }
        let spec = datasets::by_name(dname).ok_or_else(|| anyhow!("unknown dataset `{dname}`"))?;
        let iters = args.get_usize("iters", 2)?;
        if iters == 0 {
            return Err(anyhow!("--iters must be at least 1"));
        }
        let background = tenants.saturating_sub(1);
        println!(
            "CONTENDED REFACTO — {} as one tenant among {background} synthetic tenants \
             ({iters} iterations, lib {})",
            spec.name,
            lib.label()
        );
        for &kind in &systems {
            let topo = kind.build();
            let gpus = gpus_flag.unwrap_or(topo.num_gpus().min(8));
            if gpus == 0 || gpus > topo.num_gpus() {
                return Err(anyhow!(
                    "--gpus {gpus} out of range for `{}` (1..={})",
                    topo.name,
                    topo.num_gpus()
                ));
            }
            let cfg = ContentionCfg { gpus, iters, background, seed };
            let r = refacto_comm_contended(&topo, lib.clone(), Params::default(), &spec, &cfg);
            println!(
                "  {:<10} @ {gpus} GPUs: idle {:>12}  contended {:>12}  slowdown {:>5.2}x  p99/op {:>12}",
                kind.name(),
                fmt_time(r.isolated),
                fmt_time(r.contended),
                r.slowdown,
                fmt_time(r.p99_latency),
            );
        }
        return Ok(());
    }

    let mk_spec = |max_gpus: usize| -> WorkloadSpec {
        let gpus = gpus_flag.unwrap_or(max_gpus.min(8));
        let mut spec = WorkloadSpec::synthetic(tenants, ops, gpus, lib.clone(), total, seed);
        if let Some(ps) = &perts {
            // validated per system by spec.validate inside the study
            spec = spec.with_faults(ps.clone());
        }
        if let Some(d) = dist {
            for t in &mut spec.tenants {
                if let OpStream::Distribution { dist, .. } = &mut t.stream {
                    *dist = d;
                }
            }
        }
        if let Some(tr) = &trace_ops {
            if let Some(t0) = spec.tenants.first_mut() {
                t0.name = "trace".to_string();
                // without an explicit --ops, replay the whole trace once
                if args.get("ops").is_none() {
                    t0.ops = tr.len();
                }
                t0.stream = OpStream::Trace { ops: tr.clone() };
            }
        }
        if let Some(g) = gap_flag {
            // negatives rejected by spec.validate per system
            for t in &mut spec.tenants {
                t.gap = g;
            }
        }
        spec
    };

    // --recover (or an explicit policy knob): supervised execution —
    // hard outages stall jobs, stalled jobs re-issue through the
    // timeout-retry-reroute-shrink driver, failure-aware SLOs out.
    let recover =
        args.flag("recover") || args.get("timeout").is_some() || args.get("retries").is_some();
    if recover {
        let mut policy = RecoveryPolicy::default_policy();
        policy.timeout = args.get_f64("timeout", policy.timeout)?;
        policy.max_retries = args.get_usize("retries", policy.max_retries)?;
        if policy.timeout <= 0.0 {
            return Err(anyhow!("--timeout must be positive seconds, got {}", policy.timeout));
        }
        println!(
            "SUPERVISED WORKLOAD — hard-fault recovery (timeout {}, {} retries)",
            fmt_time(policy.timeout),
            policy.max_retries
        );
        for &kind in &systems {
            let topo = kind.build();
            let spec = mk_spec(topo.num_gpus());
            spec.validate(&topo)?;
            let sup = run_workload_recovered(&topo, &spec, Params::default(), &policy)?;
            println!("== {} ==", kind.name());
            match &sup.diagnosis {
                Some(d) => println!("  shared run {d}"),
                None => println!("  shared run completed at {}", fmt_time(sup.result.makespan)),
            }
            let s = &sup.slo;
            println!(
                "  ops: {} clean, {} recovered, {} aborted of {}",
                s.completed_ops, s.recovered_ops, s.aborted_ops, s.total_ops
            );
            println!(
                "  goodput {}/s over makespan {} ({} delivered)",
                fmt_bytes(s.goodput as u64),
                fmt_time(s.makespan),
                fmt_bytes(s.delivered_bytes as u64)
            );
            if s.recovered_ops > 0 {
                println!(
                    "  recovery latency p50 {}  p95 {}  max {}",
                    fmt_time(s.recovery_p50),
                    fmt_time(s.recovery_p95),
                    fmt_time(s.recovery_max)
                );
            }
            for r in &sup.reissued {
                println!(
                    "    tenant{} op{} [{}]: {}{}",
                    r.tenant,
                    r.index,
                    r.label,
                    r.strategy.label(),
                    r.finish.map(|f| format!(" at {}", fmt_time(f))).unwrap_or_default()
                );
            }
        }
        return Ok(());
    }
    if let Some(ps) = &perts {
        // without --recover the shared run is fail-fast (Sim::run):
        // permanent outages would stall it, not finish slowly
        reject_permanent_outages(ps, "add --recover for supervised hard-fault execution");
    }
    let sections = report_workload::study(&systems, Params::default(), mk_spec)?;
    print!("{}", report_workload::render(&sections));
    if let Some(dir) = csv_dir(args) {
        let p = write_csv(&dir, "workload.csv", &report_workload::csv(&sections))?;
        eprintln!("wrote {}", p.display());
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> agv_bench::util::error::Result<()> {
    // usage errors (malformed numerics, unknown enum values) exit 2
    // before any simulation; runtime failures return Err (exit 1)
    let tenants = num_arg(args.get_usize("tenants", 2));
    let jobs = num_arg(args.get_usize("jobs", 8));
    let seed = num_arg(args.get_u64("seed", 42));
    let depth = num_arg(args.get_usize("depth", 4));
    if depth == 0 {
        eprintln!("--depth must be at least 1");
        std::process::exit(2);
    }
    let rate = args.get("rate").map(|_| num_arg(args.get_f64("rate", 0.0)));
    if let Some(r) = rate {
        if !r.is_finite() || r < 0.0 {
            eprintln!("--rate must be finite non-negative jobs/second per tenant, got {r}");
            std::process::exit(2);
        }
    }
    let policy = {
        let s = args.get_or("policy", "fifo");
        QueuePolicy::parse(s, depth).unwrap_or_else(|| {
            eprintln!("unknown policy `{s}` (fifo|fair|reject)");
            std::process::exit(2);
        })
    };
    let lib = {
        let s = args.get_or("lib", "nccl");
        TenantLib::parse(s).unwrap_or_else(|| {
            eprintln!("unknown library `{s}` (mpi|mpi-cuda|nccl|auto)");
            std::process::exit(2);
        })
    };
    let total = match args.get("total") {
        Some(s) => parse_bytes(s).unwrap_or_else(|| {
            eprintln!("--total: bad size `{s}`");
            std::process::exit(2);
        }),
        None => 4 << 20,
    };
    let dist = args.get("dist").map(|s| {
        Distribution::parse(s).unwrap_or_else(|| {
            eprintln!("unknown distribution `{s}` (uniform|linear|geometric|spike|random-zipf)");
            std::process::exit(2);
        })
    });
    let gpus_flag = args.get("gpus").map(|_| num_arg(args.get_usize("gpus", 8)));
    let systems: Vec<SystemSpec> = match args.get_or("system", "all") {
        "all" => SystemSpec::paper_all().to_vec(),
        s => vec![parse_system(s)],
    };

    let mk_spec = |max_gpus: usize| -> ServeSpec {
        let gpus = gpus_flag.unwrap_or(max_gpus.min(8));
        let mut spec = ServeSpec::synthetic(
            tenants,
            jobs,
            gpus,
            lib.clone(),
            total,
            seed,
            // placeholder: the sweep overrides per rho, the pinned
            // path overrides with --rate
            ArrivalProcess::Poisson { rate: 1.0 },
            policy,
        );
        if let Some(d) = dist {
            for t in &mut spec.workload.tenants {
                if let OpStream::Distribution { dist, .. } = &mut t.stream {
                    *dist = d;
                }
            }
        }
        spec
    };

    match rate {
        // no --rate: sweep offered load against each system's own
        // saturation rate and report the p95 knee capacity
        None => {
            let sections = report_serve::study(
                &systems,
                Params::default(),
                &report_serve::DEFAULT_RHOS,
                mk_spec,
            )?;
            print!("{}", report_serve::render(&sections));
            if let Some(dir) = csv_dir(args) {
                let p = write_csv(&dir, "serve.csv", &report_serve::csv(&sections))?;
                eprintln!("wrote {}", p.display());
            }
        }
        // --rate R: one pinned offered load per system (R = 0 is the
        // closed-loop limit, bit-exact to the workload engine)
        Some(r) => {
            println!(
                "SERVE — {} per tenant, policy {}, {tenants} tenants x {jobs} jobs",
                if r == 0.0 {
                    "closed loop (zero arrival rate)".to_string()
                } else {
                    format!("poisson {r} jobs/s")
                },
                policy.label(),
            );
            for &kind in &systems {
                let topo = kind.build();
                let mut spec = mk_spec(topo.num_gpus());
                spec.arrivals = ArrivalProcess::from_rate(r);
                let res = run_serve(&topo, &spec, Params::default())?;
                println!(
                    "== {} — {} completed, {} rejected ({} warm-up), makespan {} ==",
                    kind.name(),
                    res.completed,
                    res.rejected,
                    res.warmup_jobs,
                    fmt_time(res.makespan),
                );
                println!(
                    "  latency p50 {}  p95 {}  p99.9 {}  mean {}  wait {}",
                    fmt_time(res.p50),
                    fmt_time(res.p95),
                    fmt_time(res.p999),
                    fmt_time(res.mean_latency),
                    fmt_time(res.mean_wait),
                );
                println!(
                    "  offered {:.2} jobs/s, served {:.2} jobs/s, {} flows",
                    res.offered_rate, res.throughput, res.flows
                );
            }
        }
    }
    Ok(())
}

fn cmd_e2e(args: &Args) {
    let config = args.get_or("config", "small").to_string();
    let system = system_arg(args);
    let gpus = num_arg(args.get_usize("gpus", 8));
    let iters = num_arg(args.get_usize("iters", 10));
    let seed = num_arg(args.get_u64("seed", 42));
    let dir = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    let runtime = Runtime::open(&dir).unwrap_or_else(|e| {
        eprintln!("cannot open artifacts: {e:#}");
        std::process::exit(1);
    });
    let topo = system.build();
    let mut driver = Driver::new(runtime, &config, &topo, gpus, Library::all().to_vec());
    let ([di, dj, dk], n_pad, rank) = driver.shapes().unwrap_or_else(|e| {
        eprintln!("cannot read artifact shapes: {e:#}");
        std::process::exit(1);
    });
    println!(
        "e2e factorization: config={config} dims={di}x{dj}x{dk} nnz<={n_pad} R={rank} on {} @ {gpus} GPUs",
        system.name()
    );
    let spec = agv_bench::tensor::TensorSpec {
        name: "e2e-synth",
        modes: [
            agv_bench::tensor::ModeProfile { dim: di as u64, skew: 0.6 },
            agv_bench::tensor::ModeProfile { dim: dj as u64, skew: 0.4 },
            agv_bench::tensor::ModeProfile { dim: dk as u64, skew: 0.2 },
        ],
        nnz: (n_pad - n_pad / 8) as u64,
    };
    let tensor = synth::low_rank_coo(&spec, n_pad - n_pad / 8, 8, 0.05, seed);
    let report = driver.run(&tensor, iters, seed).unwrap_or_else(|e| {
        eprintln!("factorization failed: {e:#}");
        std::process::exit(1);
    });
    println!("iter  fit       compute(real)   comm/iter(sim: MPI | MPI-CUDA | NCCL)");
    for l in &report.iters {
        println!(
            "{:>4}  {:<8.5} {:>12}    {} | {} | {}",
            l.iter,
            l.fit,
            fmt_time(l.compute_secs),
            fmt_time(l.comm_secs[0].1),
            fmt_time(l.comm_secs[1].1),
            fmt_time(l.comm_secs[2].1),
        );
    }
    println!(
        "final fit {:.5}; compute total {}",
        report.final_fit(),
        fmt_time(report.compute_total)
    );
    for (lib, t) in &report.comm_totals {
        println!("  simulated comm total {:<9} {}", lib.name(), fmt_time(*t));
    }
    let labels: Vec<String> = report
        .auto_comm
        .per_mode
        .iter()
        .map(|s| s.candidate.label())
        .collect();
    println!(
        "  simulated comm total {:<9} {} ({})",
        "auto",
        fmt_time(report.auto_comm.total),
        labels.join(" | ")
    );
}

fn cmd_artifacts(args: &Args) {
    let dir = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    match Runtime::open(&dir) {
        Ok(rt) => {
            println!("artifacts in {} (platform: {}):", dir.display(), rt.platform());
            for name in rt.artifacts() {
                let m = rt.meta(name).unwrap();
                println!(
                    "  {:<28} {} inputs, {} outputs, file {}",
                    name,
                    m.inputs.len(),
                    m.outputs.len(),
                    m.file
                );
            }
        }
        Err(e) => {
            eprintln!("cannot open artifacts: {e:#}");
            std::process::exit(1);
        }
    }
}
