//! The three systems of the paper, as described in §V-A and Fig. 1.
//!
//! - `cluster(n)`: n-node FDR InfiniBand star, one K40m per node on
//!   PCIe 3.0 x16, NIC per node, single IB switch. (Paper: 16 nodes.)
//! - `dgx1()`: 8 P100s in NVLink hybrid cube-mesh (4 connection points
//!   per GPU, 20 GB/s each), two quads, PCIe switches pairing GPUs under
//!   two Xeon sockets joined by QPI.
//! - `cs_storm()`: 16 P100s in pairs bonded by 4 NVLinks (80 GB/s per
//!   pair); pairs hang off shared PCIe switches (4 GPUs per switch),
//!   two switches per socket, QPI between sockets.

use super::{DeviceKind, LinkClass, Topology};

/// Which of the paper's systems to build (plus GPU-count slicing as in
/// the experiments: the paper runs 2/8/16 GPUs where the system allows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    /// 16-node K40m cluster, FDR InfiniBand star.
    Cluster,
    /// NVIDIA DGX-1: 8 P100s in the NVLink hybrid cube-mesh.
    Dgx1,
    /// Cray CS-Storm: 16 P100s in 4x-NVLink-bonded pairs.
    CsStorm,
}

impl SystemKind {
    /// CLI/report name ("cluster", "dgx1", "cs-storm").
    pub fn name(self) -> &'static str {
        match self {
            SystemKind::Cluster => "cluster",
            SystemKind::Dgx1 => "dgx1",
            SystemKind::CsStorm => "cs-storm",
        }
    }

    /// Parse a system name as accepted by the `agv` CLI's `--system`.
    pub fn parse(s: &str) -> Option<SystemKind> {
        match s.to_ascii_lowercase().as_str() {
            "cluster" => Some(SystemKind::Cluster),
            "dgx1" | "dgx-1" => Some(SystemKind::Dgx1),
            "cs-storm" | "csstorm" | "storm" => Some(SystemKind::CsStorm),
            _ => None,
        }
    }

    /// Max GPUs the paper uses on this system.
    pub fn max_gpus(self) -> usize {
        match self {
            SystemKind::Cluster => 16,
            SystemKind::Dgx1 => 8,
            SystemKind::CsStorm => 16,
        }
    }

    /// Construct the full topology of this system (Fig. 1).
    pub fn build(self) -> Topology {
        match self {
            SystemKind::Cluster => cluster(16),
            SystemKind::Dgx1 => dgx1(),
            SystemKind::CsStorm => cs_storm(),
        }
    }

    /// All three systems, in the paper's plotting order.
    pub fn all() -> [SystemKind; 3] {
        [SystemKind::Cluster, SystemKind::Dgx1, SystemKind::CsStorm]
    }
}

/// Group the first `p` GPU ranks by host node — the grouping the
/// hierarchical two-level schedules are parameterized by (DESIGN.md §3).
/// Groups appear in order of their lowest rank; members stay in rank
/// order, so `groups[g][0]` (the hierarchical leader) is the lowest
/// rank on its node. Single-node systems collapse to one group; the
/// one-GPU-per-node cluster yields `p` singleton groups; `multi_dgx(n)`
/// yields one 8-member group per node.
pub fn node_groups(topo: &Topology, p: usize) -> Vec<Vec<usize>> {
    assert!(p >= 1 && p <= topo.num_gpus(), "p={p} exceeds {} GPUs", topo.num_gpus());
    let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
    for r in 0..p {
        let node = topo.devices[topo.gpu(r)].node;
        match groups.iter_mut().find(|(n, _)| *n == node) {
            Some((_, members)) => members.push(r),
            None => groups.push((node, vec![r])),
        }
    }
    groups.into_iter().map(|(_, members)| members).collect()
}

/// Traditional cluster: `n` nodes, 1 GPU each, FDR IB star (Fig. 1 left).
pub fn cluster(n: usize) -> Topology {
    let mut t = Topology::new(format!("cluster-{n}"));
    let ib = t.add_device(DeviceKind::IbSwitch, usize::MAX, "ib-switch");
    for node in 0..n {
        let cpu = t.add_device(DeviceKind::Cpu { socket: 0 }, node, format!("n{node}.cpu"));
        let gpu = t.add_device(DeviceKind::Gpu { rank: node }, node, format!("n{node}.k40m"));
        let nic = t.add_device(DeviceKind::Nic, node, format!("n{node}.hca"));
        // Each GPU has exclusive access to its local PCIe bus (paper §V-B).
        t.add_link(gpu, cpu, LinkClass::PcieGen3x16);
        t.add_link(cpu, nic, LinkClass::PcieGen3x16);
        t.add_link(nic, ib, LinkClass::InfinibandFdr);
    }
    t
}

/// NVIDIA DGX-1 (P100): hybrid cube-mesh (Fig. 1 right).
///
/// NVLink edges: each quad {0,1,2,3} and {4,5,6,7} is fully connected
/// (6 edges each) and the quads are joined by 0-4, 1-5, 2-6, 3-7 —
/// exactly 4 NVLink connection points per GPU. Any GPU reaches any other
/// in at most two NVLink hops (the property NCCL exploits, §V-B).
///
/// PCIe: GPUs {0,1} and {2,3} under switches on socket 0; {4,5}, {6,7}
/// on socket 1; QPI joins the sockets.
pub fn dgx1() -> Topology {
    let mut t = Topology::new("dgx1");
    let cpu0 = t.add_device(DeviceKind::Cpu { socket: 0 }, 0, "cpu0");
    let cpu1 = t.add_device(DeviceKind::Cpu { socket: 1 }, 0, "cpu1");
    t.add_link(cpu0, cpu1, LinkClass::Qpi);
    let mut gpus = Vec::new();
    for rank in 0..8 {
        gpus.push(t.add_device(DeviceKind::Gpu { rank }, 0, format!("p100-{rank}")));
    }
    // PCIe fan-out: pairs of GPUs behind a switch, two switches per socket.
    for (sw_idx, pair) in [[0, 1], [2, 3], [4, 5], [6, 7]].iter().enumerate() {
        let cpu = if sw_idx < 2 { cpu0 } else { cpu1 };
        let sw = t.add_device(DeviceKind::PcieSwitch, 0, format!("plx{sw_idx}"));
        t.add_link(sw, cpu, LinkClass::PcieGen3x16);
        for &g in pair {
            t.add_link(gpus[g], sw, LinkClass::PcieGen3x16);
        }
    }
    // NVLink hybrid cube-mesh.
    let quad_edges = |base: usize| {
        [
            (base, base + 1),
            (base, base + 2),
            (base, base + 3),
            (base + 1, base + 2),
            (base + 1, base + 3),
            (base + 2, base + 3),
        ]
    };
    for (a, b) in quad_edges(0).into_iter().chain(quad_edges(4)) {
        t.add_link(gpus[a], gpus[b], LinkClass::NvLink);
    }
    for i in 0..4 {
        t.add_link(gpus[i], gpus[i + 4], LinkClass::NvLink);
    }
    t
}

/// Cray CS-Storm: 16 P100s, NVLink-bonded pairs, shared PCIe switches
/// (Fig. 1 middle).
pub fn cs_storm() -> Topology {
    let mut t = Topology::new("cs-storm");
    let cpu0 = t.add_device(DeviceKind::Cpu { socket: 0 }, 0, "cpu0");
    let cpu1 = t.add_device(DeviceKind::Cpu { socket: 1 }, 0, "cpu1");
    t.add_link(cpu0, cpu1, LinkClass::Qpi);
    let mut gpus = Vec::new();
    for rank in 0..16 {
        gpus.push(t.add_device(DeviceKind::Gpu { rank }, 0, format!("p100-{rank}")));
    }
    // Bonded 4x NVLink within each pair (2i, 2i+1): 80 GB/s.
    for i in 0..8 {
        t.add_link(gpus[2 * i], gpus[2 * i + 1], LinkClass::NvLinkBonded4);
    }
    // PCIe switches: 4 GPUs (2 pairs) per switch, 2 switches per socket.
    // Sharing a switch is what degrades CS-Storm at 16 GPUs vs the
    // cluster's exclusive per-GPU PCIe (paper §V-B).
    for sw_idx in 0..4 {
        let cpu = if sw_idx < 2 { cpu0 } else { cpu1 };
        let sw = t.add_device(DeviceKind::PcieSwitch, 0, format!("plx{sw_idx}"));
        t.add_link(sw, cpu, LinkClass::PcieGen3x16);
        for g in 0..4 {
            t.add_link(gpus[sw_idx * 4 + g], sw, LinkClass::PcieGen3x16);
        }
    }
    t
}

/// Future-work extension (paper §VI: "systems with more GPUs per node"):
/// a cluster of `nodes` DGX-1-class machines joined by an FDR IB star.
/// GPU ranks are dense: node n hosts ranks 8n..8n+8 with the full
/// hybrid cube-mesh inside each node; inter-node traffic crosses
/// PCIe -> NIC -> IB exactly like the paper's cluster.
pub fn multi_dgx(nodes: usize) -> Topology {
    assert!(nodes >= 1);
    let mut t = Topology::new(format!("multi-dgx-{nodes}"));
    let ib = t.add_device(DeviceKind::IbSwitch, usize::MAX, "ib-switch");
    for node in 0..nodes {
        let cpu0 = t.add_device(DeviceKind::Cpu { socket: 0 }, node, format!("n{node}.cpu0"));
        let cpu1 = t.add_device(DeviceKind::Cpu { socket: 1 }, node, format!("n{node}.cpu1"));
        t.add_link(cpu0, cpu1, LinkClass::Qpi);
        let nic = t.add_device(DeviceKind::Nic, node, format!("n{node}.hca"));
        t.add_link(cpu0, nic, LinkClass::PcieGen3x16);
        t.add_link(nic, ib, LinkClass::InfinibandFdr);
        let mut gpus = Vec::new();
        for g in 0..8 {
            gpus.push(t.add_device(
                DeviceKind::Gpu { rank: node * 8 + g },
                node,
                format!("n{node}.p100-{g}"),
            ));
        }
        for (sw_idx, pair) in [[0usize, 1], [2, 3], [4, 5], [6, 7]].iter().enumerate() {
            let cpu = if sw_idx < 2 { cpu0 } else { cpu1 };
            let sw = t.add_device(DeviceKind::PcieSwitch, node, format!("n{node}.plx{sw_idx}"));
            t.add_link(sw, cpu, LinkClass::PcieGen3x16);
            for &g in pair {
                t.add_link(gpus[g], sw, LinkClass::PcieGen3x16);
            }
        }
        let quad_edges = |base: usize| {
            [
                (base, base + 1),
                (base, base + 2),
                (base, base + 3),
                (base + 1, base + 2),
                (base + 1, base + 3),
                (base + 2, base + 3),
            ]
        };
        for (a, b) in quad_edges(0).into_iter().chain(quad_edges(4)) {
            t.add_link(gpus[a], gpus[b], LinkClass::NvLink);
        }
        for i in 0..4 {
            t.add_link(gpus[i], gpus[i + 4], LinkClass::NvLink);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_shape() {
        let t = cluster(16);
        assert_eq!(t.num_gpus(), 16);
        // 16 nodes x 3 devices + 1 switch
        assert_eq!(t.devices.len(), 49);
        // every pair crosses IB; no P2P anywhere
        assert!(!t.p2p_accessible(0, 1));
        assert!(!t.same_node(0, 1));
        let p = t.route_gpus(0, 15).unwrap();
        assert!((t.path_bandwidth(&p) - LinkClass::InfinibandFdr.bandwidth()).abs() < 1.0);
    }

    #[test]
    fn dgx1_every_gpu_has_four_nvlinks() {
        let t = dgx1();
        assert_eq!(t.num_gpus(), 8);
        for r in 0..8 {
            let d = t.gpu(r);
            let nv = t
                .neighbors(d)
                .iter()
                .filter(|&&(l, _)| t.links[l].class.is_nvlink())
                .count();
            assert_eq!(nv, 4, "gpu {r} has {nv} NVLinks");
        }
    }

    #[test]
    fn dgx1_two_hop_nvlink_everywhere() {
        // "any GPU can be reached by another with at most two NVLink hops"
        let t = dgx1();
        for a in 0..8 {
            for b in 0..8 {
                let p = t.route_nvlink_only(a, b).unwrap();
                assert!(p.hops() <= 2, "gpu {a}->{b} needs {} hops", p.hops());
            }
        }
    }

    #[test]
    fn dgx1_p2p_matches_paper_example() {
        // Paper §II-B: GPU 0 cannot P2P with GPUs 5, 6, 7 (two NVLink
        // hops, different PCIe root for 4-7) but can with 1-4.
        let t = dgx1();
        for peer in [1, 2, 3, 4] {
            assert!(t.p2p_accessible(0, peer), "0<->{peer}");
        }
        for peer in [5, 6, 7] {
            assert!(!t.p2p_accessible(0, peer), "0<->{peer}");
            // ...yet NCCL finds a 2-hop NVLink route:
            assert_eq!(t.route_nvlink_only(0, peer).unwrap().hops(), 2);
        }
    }

    #[test]
    fn cs_storm_pairs_bonded() {
        let t = cs_storm();
        assert_eq!(t.num_gpus(), 16);
        for i in 0..8 {
            assert!(t.nvlink_direct(2 * i, 2 * i + 1));
            let p = t.route_gpus(2 * i, 2 * i + 1).unwrap();
            assert!(
                (t.path_bandwidth(&p) - LinkClass::NvLinkBonded4.bandwidth()).abs() < 1.0
            );
        }
        // Across pairs: no NVLink at all.
        assert!(t.route_nvlink_only(0, 2).is_none());
        // Same switch: P2P over PCIe works for 0<->2 (switch 0 hosts 0-3).
        assert!(t.p2p_accessible(0, 2));
        // Across sockets (0 on sw0/cpu0, 15 on sw3/cpu1): no P2P.
        assert!(!t.p2p_accessible(0, 15));
    }

    #[test]
    fn multi_dgx_structure() {
        let t = multi_dgx(2);
        assert_eq!(t.num_gpus(), 16);
        // intra-node: 2-hop NVLink everywhere, as on a single DGX-1
        for a in 0..8 {
            for b in 0..8 {
                assert!(t.route_nvlink_only(a, b).unwrap().hops() <= 2);
            }
        }
        // inter-node: no NVLink, no P2P, IB bottleneck
        assert!(t.route_nvlink_only(0, 8).is_none());
        assert!(!t.p2p_accessible(0, 8));
        let p = t.route_gpus(0, 8).unwrap();
        assert!((t.path_bandwidth(&p) - LinkClass::InfinibandFdr.bandwidth()).abs() < 1.0);
        assert!(t.same_node(0, 7));
        assert!(!t.same_node(7, 8));
    }

    #[test]
    fn node_groups_shapes() {
        // single-node systems: one group holding every rank
        for t in [dgx1(), cs_storm()] {
            let g = node_groups(&t, t.num_gpus());
            assert_eq!(g.len(), 1, "{}", t.name);
            assert_eq!(g[0], (0..t.num_gpus()).collect::<Vec<_>>());
        }
        // one-GPU-per-node cluster: p singleton groups
        let c = cluster(16);
        let g = node_groups(&c, 8);
        assert_eq!(g.len(), 8);
        assert!(g.iter().enumerate().all(|(i, m)| m == &vec![i]));
        // multi-DGX: 8-member groups in node order, leaders at 8k
        let m = multi_dgx(3);
        let g = node_groups(&m, 24);
        assert_eq!(g.len(), 3);
        for (n, members) in g.iter().enumerate() {
            assert_eq!(members, &(8 * n..8 * n + 8).collect::<Vec<_>>());
        }
        // slicing mid-node leaves a ragged last group
        let g = node_groups(&m, 10);
        assert_eq!(g, vec![(0..8).collect::<Vec<_>>(), vec![8, 9]]);
    }

    #[test]
    fn remap_gpus_swaps_bindings() {
        let t = cs_storm();
        // "spread" mapping: ranks 0..8 land on one GPU of each pair —
        // a sequential 8-rank job then has NO NVLink pairs at all.
        let spread: Vec<usize> = (0..16).map(|r| (r % 8) * 2 + r / 8).collect();
        let t2 = t.remap_gpus(&spread);
        assert!(t.nvlink_direct(0, 1), "sequential pairs bonded");
        assert!(!t2.nvlink_direct(0, 1), "spread mapping splits pairs");
        // the permutation is total: every device still owns one rank
        for r in 0..16 {
            assert!(matches!(
                t2.devices[t2.gpu(r)].kind,
                crate::topology::DeviceKind::Gpu { rank } if rank == r
            ));
        }
    }

    #[test]
    #[should_panic(expected = "not a permutation")]
    fn remap_rejects_non_permutation() {
        let t = dgx1();
        let _ = t.remap_gpus(&[0, 0, 1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn system_kind_roundtrip() {
        for k in SystemKind::all() {
            assert_eq!(SystemKind::parse(k.name()), Some(k));
            let t = k.build();
            assert_eq!(t.num_gpus(), k.max_gpus());
        }
        assert_eq!(SystemKind::parse("DGX-1"), Some(SystemKind::Dgx1));
        assert_eq!(SystemKind::parse("nope"), None);
    }

    #[test]
    fn all_gpu_pairs_routable_on_all_systems() {
        for k in SystemKind::all() {
            let t = k.build();
            for a in 0..t.num_gpus() {
                for b in 0..t.num_gpus() {
                    assert!(t.route_gpus(a, b).is_some(), "{} {a}->{b}", t.name);
                }
            }
        }
    }
}
