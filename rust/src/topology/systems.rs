//! The three systems of the paper, as described in §V-A and Fig. 1.
//!
//! - `cluster(n)`: n-node FDR InfiniBand star, one K40m per node on
//!   PCIe 3.0 x16, NIC per node, single IB switch. (Paper: 16 nodes.)
//! - `dgx1()`: 8 P100s in NVLink hybrid cube-mesh (4 connection points
//!   per GPU, 20 GB/s each), two quads, PCIe switches pairing GPUs under
//!   two Xeon sockets joined by QPI.
//! - `cs_storm()`: 16 P100s in pairs bonded by 4 NVLinks (80 GB/s per
//!   pair); pairs hang off shared PCIe switches (4 GPUs per switch),
//!   two switches per socket, QPI between sockets.

use super::fabrics::{dragonfly, fat_tree, multi_plane_pod};
use super::{DeviceKind, LinkClass, Topology};
use crate::util::error::{Error, Result};

/// Which of the paper's systems to build (plus GPU-count slicing as in
/// the experiments: the paper runs 2/8/16 GPUs where the system allows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    /// 16-node K40m cluster, FDR InfiniBand star.
    Cluster,
    /// NVIDIA DGX-1: 8 P100s in the NVLink hybrid cube-mesh.
    Dgx1,
    /// Cray CS-Storm: 16 P100s in 4x-NVLink-bonded pairs.
    CsStorm,
}

impl SystemKind {
    /// CLI/report name ("cluster", "dgx1", "cs-storm").
    pub fn name(self) -> &'static str {
        match self {
            SystemKind::Cluster => "cluster",
            SystemKind::Dgx1 => "dgx1",
            SystemKind::CsStorm => "cs-storm",
        }
    }

    /// Parse a system name as accepted by the `agv` CLI's `--system`.
    pub fn parse(s: &str) -> Option<SystemKind> {
        match s.to_ascii_lowercase().as_str() {
            "cluster" => Some(SystemKind::Cluster),
            "dgx1" | "dgx-1" => Some(SystemKind::Dgx1),
            "cs-storm" | "csstorm" | "storm" => Some(SystemKind::CsStorm),
            _ => None,
        }
    }

    /// Max GPUs the paper uses on this system.
    pub fn max_gpus(self) -> usize {
        match self {
            SystemKind::Cluster => 16,
            SystemKind::Dgx1 => 8,
            SystemKind::CsStorm => 16,
        }
    }

    /// Construct the full topology of this system (Fig. 1).
    pub fn build(self) -> Topology {
        match self {
            SystemKind::Cluster => cluster(16),
            SystemKind::Dgx1 => dgx1(),
            SystemKind::CsStorm => cs_storm(),
        }
    }

    /// All three systems, in the paper's plotting order.
    pub fn all() -> [SystemKind; 3] {
        [SystemKind::Cluster, SystemKind::Dgx1, SystemKind::CsStorm]
    }
}

/// A parsed `--system` argument: one of the paper's hand-built systems
/// or a parametric large-scale fabric (DESIGN.md §15), e.g.
/// `fat-tree:k=16`, `dragonfly:a=8,p=4,h=4`,
/// `multi-plane-pod:nodes=64,gpus=8,rails=4`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemSpec {
    /// One of the paper's three 16-GPU systems.
    Paper(SystemKind),
    /// k-ary fat-tree, `fat-tree:k=<even>` — k³/4 hosts.
    FatTree {
        /// Switch arity (even, >= 2).
        k: usize,
    },
    /// Canonical dragonfly, `dragonfly:a=<n>,p=<n>,h=<n>` —
    /// (a·h+1)·a·p hosts.
    Dragonfly {
        /// Routers per group.
        a: usize,
        /// Hosts per router.
        p: usize,
        /// Global ports per router.
        h: usize,
    },
    /// Rail-optimized multi-plane pod,
    /// `multi-plane-pod:nodes=<n>,gpus=<n>,rails=<n>` (alias `pod:`).
    MultiPlanePod {
        /// Number of DGX-class nodes.
        nodes: usize,
        /// GPUs per node (NVLink full mesh).
        gpus: usize,
        /// NICs/planes per node.
        rails: usize,
    },
}

/// Parse `key=value` fields in `keys` order; every key required exactly
/// once, nothing else accepted.
fn parse_fields(family: &str, params: &str, keys: &[&str]) -> Result<Vec<usize>> {
    let mut vals: Vec<Option<usize>> = vec![None; keys.len()];
    for part in params.split(',') {
        let (k, v) = part.split_once('=').ok_or_else(|| {
            Error::msg(format!(
                "malformed field '{part}' in --system {family} spec (expected key=value)"
            ))
        })?;
        let (k, v) = (k.trim(), v.trim());
        let idx = keys.iter().position(|&n| n == k).ok_or_else(|| {
            Error::msg(format!(
                "unknown field '{k}' for --system {family} (accepted: {})",
                keys.join(", ")
            ))
        })?;
        let n: usize = v.parse().map_err(|_| {
            Error::msg(format!("field '{k}' must be a non-negative integer, got '{v}'"))
        })?;
        if vals[idx].replace(n).is_some() {
            return Err(Error::msg(format!("duplicate field '{k}' in --system {family} spec")));
        }
    }
    keys.iter()
        .zip(&vals)
        .map(|(k, v)| {
            v.ok_or_else(|| Error::msg(format!("--system {family} spec is missing '{k}='")))
        })
        .collect()
}

impl SystemSpec {
    /// The accepted `--system` grammar, for error hints and `agv topo
    /// --list`.
    pub fn grammar() -> &'static str {
        "cluster | dgx1 | cs-storm | fat-tree:k=<even> | \
         dragonfly:a=<n>,p=<n>,h=<n> | multi-plane-pod:nodes=<n>,gpus=<n>,rails=<n>"
    }

    /// Parse a `--system` argument. Plain names resolve to the paper
    /// systems; `family:key=value,...` specs resolve to parametric
    /// fabrics. Every rejection names the offending field and shows a
    /// valid example.
    pub fn parse(s: &str) -> Result<SystemSpec> {
        let s = s.trim();
        let Some((family, params)) = s.split_once(':') else {
            if let Some(k) = SystemKind::parse(s) {
                return Ok(SystemSpec::Paper(k));
            }
            return Err(Error::msg(format!(
                "unknown system '{s}' (accepted: {})",
                SystemSpec::grammar()
            )));
        };
        match family.trim().to_ascii_lowercase().as_str() {
            "fat-tree" | "fattree" | "ft" => {
                let v = parse_fields("fat-tree", params, &["k"])?;
                let k = v[0];
                if k < 2 || k % 2 != 0 {
                    return Err(Error::msg(format!(
                        "fat-tree arity must be even and >= 2, got k={k} \
                         (try --system fat-tree:k=16)"
                    )));
                }
                Ok(SystemSpec::FatTree { k })
            }
            "dragonfly" | "dfly" => {
                let v = parse_fields("dragonfly", params, &["a", "p", "h"])?;
                let (a, p, h) = (v[0], v[1], v[2]);
                if a == 0 {
                    return Err(Error::msg(
                        "dragonfly needs at least one router per group (a >= 1)",
                    ));
                }
                if p == 0 {
                    return Err(Error::msg(
                        "dragonfly needs at least one host per router (p >= 1)",
                    ));
                }
                if h == 0 {
                    return Err(Error::msg(
                        "h=0 leaves dragonfly groups disconnected; use h >= 1",
                    ));
                }
                Ok(SystemSpec::Dragonfly { a, p, h })
            }
            "multi-plane-pod" | "pod" => {
                let v = parse_fields("multi-plane-pod", params, &["nodes", "gpus", "rails"])?;
                let (nodes, gpus, rails) = (v[0], v[1], v[2]);
                if nodes == 0 {
                    return Err(Error::msg("pod needs at least one node (nodes >= 1)"));
                }
                if gpus == 0 {
                    return Err(Error::msg("pod needs at least one GPU per node (gpus >= 1)"));
                }
                if rails == 0 {
                    return Err(Error::msg(
                        "zero rails leaves pod nodes unreachable; use rails >= 1",
                    ));
                }
                Ok(SystemSpec::MultiPlanePod { nodes, gpus, rails })
            }
            other => Err(Error::msg(format!(
                "unknown system family '{other}' (accepted: {})",
                SystemSpec::grammar()
            ))),
        }
    }

    /// Report/CSV-safe name (no commas), matching the built topology's
    /// `name`: e.g. "fat-tree-k16", "dragonfly-8x4x4", "pod-64x8x4".
    pub fn name(self) -> String {
        match self {
            SystemSpec::Paper(k) => k.name().to_string(),
            SystemSpec::FatTree { k } => format!("fat-tree-k{k}"),
            SystemSpec::Dragonfly { a, p, h } => format!("dragonfly-{a}x{p}x{h}"),
            SystemSpec::MultiPlanePod { nodes, gpus, rails } => {
                format!("pod-{nodes}x{gpus}x{rails}")
            }
        }
    }

    /// Total GPU endpoints of the built system.
    pub fn max_gpus(self) -> usize {
        match self {
            SystemSpec::Paper(k) => k.max_gpus(),
            SystemSpec::FatTree { k } => k * k * k / 4,
            SystemSpec::Dragonfly { a, p, h } => (a * h + 1) * a * p,
            SystemSpec::MultiPlanePod { nodes, gpus, .. } => nodes * gpus,
        }
    }

    /// Construct the topology.
    pub fn build(self) -> Topology {
        match self {
            SystemSpec::Paper(k) => k.build(),
            SystemSpec::FatTree { k } => fat_tree(k),
            SystemSpec::Dragonfly { a, p, h } => dragonfly(a, p, h),
            SystemSpec::MultiPlanePod { nodes, gpus, rails } => {
                multi_plane_pod(nodes, gpus, rails)
            }
        }
    }

    /// The paper's three systems as specs, in plotting order.
    pub fn paper_all() -> [SystemSpec; 3] {
        [
            SystemSpec::Paper(SystemKind::Cluster),
            SystemSpec::Paper(SystemKind::Dgx1),
            SystemSpec::Paper(SystemKind::CsStorm),
        ]
    }
}

/// Group the first `p` GPU ranks by host node — the grouping the
/// hierarchical two-level schedules are parameterized by (DESIGN.md §3).
/// Groups appear in order of their lowest rank; members stay in rank
/// order, so `groups[g][0]` (the hierarchical leader) is the lowest
/// rank on its node. Single-node systems collapse to one group; the
/// one-GPU-per-node cluster yields `p` singleton groups; `multi_dgx(n)`
/// yields one 8-member group per node.
pub fn node_groups(topo: &Topology, p: usize) -> Vec<Vec<usize>> {
    assert!(p >= 1 && p <= topo.num_gpus(), "p={p} exceeds {} GPUs", topo.num_gpus());
    let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
    for r in 0..p {
        let node = topo.devices[topo.gpu(r)].node;
        match groups.iter_mut().find(|(n, _)| *n == node) {
            Some((_, members)) => members.push(r),
            None => groups.push((node, vec![r])),
        }
    }
    groups.into_iter().map(|(_, members)| members).collect()
}

/// Traditional cluster: `n` nodes, 1 GPU each, FDR IB star (Fig. 1 left).
pub fn cluster(n: usize) -> Topology {
    let mut t = Topology::new(format!("cluster-{n}"));
    let ib = t.add_device(DeviceKind::IbSwitch, usize::MAX, "ib-switch");
    for node in 0..n {
        let cpu = t.add_device(DeviceKind::Cpu { socket: 0 }, node, format!("n{node}.cpu"));
        let gpu = t.add_device(DeviceKind::Gpu { rank: node }, node, format!("n{node}.k40m"));
        let nic = t.add_device(DeviceKind::Nic, node, format!("n{node}.hca"));
        // Each GPU has exclusive access to its local PCIe bus (paper §V-B).
        t.add_link(gpu, cpu, LinkClass::PcieGen3x16);
        t.add_link(cpu, nic, LinkClass::PcieGen3x16);
        t.add_link(nic, ib, LinkClass::InfinibandFdr);
    }
    t
}

/// NVIDIA DGX-1 (P100): hybrid cube-mesh (Fig. 1 right).
///
/// NVLink edges: each quad {0,1,2,3} and {4,5,6,7} is fully connected
/// (6 edges each) and the quads are joined by 0-4, 1-5, 2-6, 3-7 —
/// exactly 4 NVLink connection points per GPU. Any GPU reaches any other
/// in at most two NVLink hops (the property NCCL exploits, §V-B).
///
/// PCIe: GPUs {0,1} and {2,3} under switches on socket 0; {4,5}, {6,7}
/// on socket 1; QPI joins the sockets.
pub fn dgx1() -> Topology {
    let mut t = Topology::new("dgx1");
    let cpu0 = t.add_device(DeviceKind::Cpu { socket: 0 }, 0, "cpu0");
    let cpu1 = t.add_device(DeviceKind::Cpu { socket: 1 }, 0, "cpu1");
    t.add_link(cpu0, cpu1, LinkClass::Qpi);
    let mut gpus = Vec::new();
    for rank in 0..8 {
        gpus.push(t.add_device(DeviceKind::Gpu { rank }, 0, format!("p100-{rank}")));
    }
    // PCIe fan-out: pairs of GPUs behind a switch, two switches per socket.
    for (sw_idx, pair) in [[0, 1], [2, 3], [4, 5], [6, 7]].iter().enumerate() {
        let cpu = if sw_idx < 2 { cpu0 } else { cpu1 };
        let sw = t.add_device(DeviceKind::PcieSwitch, 0, format!("plx{sw_idx}"));
        t.add_link(sw, cpu, LinkClass::PcieGen3x16);
        for &g in pair {
            t.add_link(gpus[g], sw, LinkClass::PcieGen3x16);
        }
    }
    // NVLink hybrid cube-mesh.
    let quad_edges = |base: usize| {
        [
            (base, base + 1),
            (base, base + 2),
            (base, base + 3),
            (base + 1, base + 2),
            (base + 1, base + 3),
            (base + 2, base + 3),
        ]
    };
    for (a, b) in quad_edges(0).into_iter().chain(quad_edges(4)) {
        t.add_link(gpus[a], gpus[b], LinkClass::NvLink);
    }
    for i in 0..4 {
        t.add_link(gpus[i], gpus[i + 4], LinkClass::NvLink);
    }
    t
}

/// Cray CS-Storm: 16 P100s, NVLink-bonded pairs, shared PCIe switches
/// (Fig. 1 middle).
pub fn cs_storm() -> Topology {
    let mut t = Topology::new("cs-storm");
    let cpu0 = t.add_device(DeviceKind::Cpu { socket: 0 }, 0, "cpu0");
    let cpu1 = t.add_device(DeviceKind::Cpu { socket: 1 }, 0, "cpu1");
    t.add_link(cpu0, cpu1, LinkClass::Qpi);
    let mut gpus = Vec::new();
    for rank in 0..16 {
        gpus.push(t.add_device(DeviceKind::Gpu { rank }, 0, format!("p100-{rank}")));
    }
    // Bonded 4x NVLink within each pair (2i, 2i+1): 80 GB/s.
    for i in 0..8 {
        t.add_link(gpus[2 * i], gpus[2 * i + 1], LinkClass::NvLinkBonded4);
    }
    // PCIe switches: 4 GPUs (2 pairs) per switch, 2 switches per socket.
    // Sharing a switch is what degrades CS-Storm at 16 GPUs vs the
    // cluster's exclusive per-GPU PCIe (paper §V-B).
    for sw_idx in 0..4 {
        let cpu = if sw_idx < 2 { cpu0 } else { cpu1 };
        let sw = t.add_device(DeviceKind::PcieSwitch, 0, format!("plx{sw_idx}"));
        t.add_link(sw, cpu, LinkClass::PcieGen3x16);
        for g in 0..4 {
            t.add_link(gpus[sw_idx * 4 + g], sw, LinkClass::PcieGen3x16);
        }
    }
    t
}

/// Future-work extension (paper §VI: "systems with more GPUs per node"):
/// a cluster of `nodes` DGX-1-class machines joined by an FDR IB star.
/// GPU ranks are dense: node n hosts ranks 8n..8n+8 with the full
/// hybrid cube-mesh inside each node; inter-node traffic crosses
/// PCIe -> NIC -> IB exactly like the paper's cluster.
pub fn multi_dgx(nodes: usize) -> Topology {
    assert!(nodes >= 1);
    let mut t = Topology::new(format!("multi-dgx-{nodes}"));
    let ib = t.add_device(DeviceKind::IbSwitch, usize::MAX, "ib-switch");
    for node in 0..nodes {
        let cpu0 = t.add_device(DeviceKind::Cpu { socket: 0 }, node, format!("n{node}.cpu0"));
        let cpu1 = t.add_device(DeviceKind::Cpu { socket: 1 }, node, format!("n{node}.cpu1"));
        t.add_link(cpu0, cpu1, LinkClass::Qpi);
        let nic = t.add_device(DeviceKind::Nic, node, format!("n{node}.hca"));
        t.add_link(cpu0, nic, LinkClass::PcieGen3x16);
        t.add_link(nic, ib, LinkClass::InfinibandFdr);
        let mut gpus = Vec::new();
        for g in 0..8 {
            gpus.push(t.add_device(
                DeviceKind::Gpu { rank: node * 8 + g },
                node,
                format!("n{node}.p100-{g}"),
            ));
        }
        for (sw_idx, pair) in [[0usize, 1], [2, 3], [4, 5], [6, 7]].iter().enumerate() {
            let cpu = if sw_idx < 2 { cpu0 } else { cpu1 };
            let sw = t.add_device(DeviceKind::PcieSwitch, node, format!("n{node}.plx{sw_idx}"));
            t.add_link(sw, cpu, LinkClass::PcieGen3x16);
            for &g in pair {
                t.add_link(gpus[g], sw, LinkClass::PcieGen3x16);
            }
        }
        let quad_edges = |base: usize| {
            [
                (base, base + 1),
                (base, base + 2),
                (base, base + 3),
                (base + 1, base + 2),
                (base + 1, base + 3),
                (base + 2, base + 3),
            ]
        };
        for (a, b) in quad_edges(0).into_iter().chain(quad_edges(4)) {
            t.add_link(gpus[a], gpus[b], LinkClass::NvLink);
        }
        for i in 0..4 {
            t.add_link(gpus[i], gpus[i + 4], LinkClass::NvLink);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_shape() {
        let t = cluster(16);
        assert_eq!(t.num_gpus(), 16);
        // 16 nodes x 3 devices + 1 switch
        assert_eq!(t.devices.len(), 49);
        // every pair crosses IB; no P2P anywhere
        assert!(!t.p2p_accessible(0, 1));
        assert!(!t.same_node(0, 1));
        let p = t.route_gpus(0, 15).unwrap();
        assert!((t.path_bandwidth(&p) - LinkClass::InfinibandFdr.bandwidth()).abs() < 1.0);
    }

    #[test]
    fn dgx1_every_gpu_has_four_nvlinks() {
        let t = dgx1();
        assert_eq!(t.num_gpus(), 8);
        for r in 0..8 {
            let d = t.gpu(r);
            let nv = t
                .neighbors(d)
                .iter()
                .filter(|&&(l, _)| t.links[l].class.is_nvlink())
                .count();
            assert_eq!(nv, 4, "gpu {r} has {nv} NVLinks");
        }
    }

    #[test]
    fn dgx1_two_hop_nvlink_everywhere() {
        // "any GPU can be reached by another with at most two NVLink hops"
        let t = dgx1();
        for a in 0..8 {
            for b in 0..8 {
                let p = t.route_nvlink_only(a, b).unwrap();
                assert!(p.hops() <= 2, "gpu {a}->{b} needs {} hops", p.hops());
            }
        }
    }

    #[test]
    fn dgx1_p2p_matches_paper_example() {
        // Paper §II-B: GPU 0 cannot P2P with GPUs 5, 6, 7 (two NVLink
        // hops, different PCIe root for 4-7) but can with 1-4.
        let t = dgx1();
        for peer in [1, 2, 3, 4] {
            assert!(t.p2p_accessible(0, peer), "0<->{peer}");
        }
        for peer in [5, 6, 7] {
            assert!(!t.p2p_accessible(0, peer), "0<->{peer}");
            // ...yet NCCL finds a 2-hop NVLink route:
            assert_eq!(t.route_nvlink_only(0, peer).unwrap().hops(), 2);
        }
    }

    #[test]
    fn cs_storm_pairs_bonded() {
        let t = cs_storm();
        assert_eq!(t.num_gpus(), 16);
        for i in 0..8 {
            assert!(t.nvlink_direct(2 * i, 2 * i + 1));
            let p = t.route_gpus(2 * i, 2 * i + 1).unwrap();
            assert!(
                (t.path_bandwidth(&p) - LinkClass::NvLinkBonded4.bandwidth()).abs() < 1.0
            );
        }
        // Across pairs: no NVLink at all.
        assert!(t.route_nvlink_only(0, 2).is_none());
        // Same switch: P2P over PCIe works for 0<->2 (switch 0 hosts 0-3).
        assert!(t.p2p_accessible(0, 2));
        // Across sockets (0 on sw0/cpu0, 15 on sw3/cpu1): no P2P.
        assert!(!t.p2p_accessible(0, 15));
    }

    #[test]
    fn multi_dgx_structure() {
        let t = multi_dgx(2);
        assert_eq!(t.num_gpus(), 16);
        // intra-node: 2-hop NVLink everywhere, as on a single DGX-1
        for a in 0..8 {
            for b in 0..8 {
                assert!(t.route_nvlink_only(a, b).unwrap().hops() <= 2);
            }
        }
        // inter-node: no NVLink, no P2P, IB bottleneck
        assert!(t.route_nvlink_only(0, 8).is_none());
        assert!(!t.p2p_accessible(0, 8));
        let p = t.route_gpus(0, 8).unwrap();
        assert!((t.path_bandwidth(&p) - LinkClass::InfinibandFdr.bandwidth()).abs() < 1.0);
        assert!(t.same_node(0, 7));
        assert!(!t.same_node(7, 8));
    }

    #[test]
    fn node_groups_shapes() {
        // single-node systems: one group holding every rank
        for t in [dgx1(), cs_storm()] {
            let g = node_groups(&t, t.num_gpus());
            assert_eq!(g.len(), 1, "{}", t.name);
            assert_eq!(g[0], (0..t.num_gpus()).collect::<Vec<_>>());
        }
        // one-GPU-per-node cluster: p singleton groups
        let c = cluster(16);
        let g = node_groups(&c, 8);
        assert_eq!(g.len(), 8);
        assert!(g.iter().enumerate().all(|(i, m)| m == &vec![i]));
        // multi-DGX: 8-member groups in node order, leaders at 8k
        let m = multi_dgx(3);
        let g = node_groups(&m, 24);
        assert_eq!(g.len(), 3);
        for (n, members) in g.iter().enumerate() {
            assert_eq!(members, &(8 * n..8 * n + 8).collect::<Vec<_>>());
        }
        // slicing mid-node leaves a ragged last group
        let g = node_groups(&m, 10);
        assert_eq!(g, vec![(0..8).collect::<Vec<_>>(), vec![8, 9]]);
    }

    #[test]
    fn remap_gpus_swaps_bindings() {
        let t = cs_storm();
        // "spread" mapping: ranks 0..8 land on one GPU of each pair —
        // a sequential 8-rank job then has NO NVLink pairs at all.
        let spread: Vec<usize> = (0..16).map(|r| (r % 8) * 2 + r / 8).collect();
        let t2 = t.remap_gpus(&spread);
        assert!(t.nvlink_direct(0, 1), "sequential pairs bonded");
        assert!(!t2.nvlink_direct(0, 1), "spread mapping splits pairs");
        // the permutation is total: every device still owns one rank
        for r in 0..16 {
            assert!(matches!(
                t2.devices[t2.gpu(r)].kind,
                crate::topology::DeviceKind::Gpu { rank } if rank == r
            ));
        }
    }

    #[test]
    #[should_panic(expected = "not a permutation")]
    fn remap_rejects_non_permutation() {
        let t = dgx1();
        let _ = t.remap_gpus(&[0, 0, 1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn system_kind_roundtrip() {
        for k in SystemKind::all() {
            assert_eq!(SystemKind::parse(k.name()), Some(k));
            let t = k.build();
            assert_eq!(t.num_gpus(), k.max_gpus());
        }
        assert_eq!(SystemKind::parse("DGX-1"), Some(SystemKind::Dgx1));
        assert_eq!(SystemKind::parse("nope"), None);
    }

    #[test]
    fn system_spec_accepts_canonical_forms() {
        for (s, gpus) in [
            ("cluster", 16),
            ("dgx1", 8),
            ("cs-storm", 16),
            ("fat-tree:k=4", 16),
            ("FAT-TREE:k=4", 16),
            ("ft:k=2", 2),
            ("dragonfly:a=2,p=2,h=2", 20),
            ("dragonfly:h=2,a=2,p=2", 20), // field order is free
            ("pod:nodes=3,gpus=4,rails=2", 12),
            ("multi-plane-pod:nodes=2,gpus=8,rails=4", 16),
        ] {
            let spec = SystemSpec::parse(s).unwrap_or_else(|e| panic!("{s}: {e:#}"));
            assert_eq!(spec.max_gpus(), gpus, "{s}");
            let t = spec.build();
            assert_eq!(t.num_gpus(), gpus, "{s}");
            assert_eq!(t.name, spec.name(), "{s}");
            assert!(!spec.name().contains(','), "CSV-unsafe name for {s}");
        }
    }

    #[test]
    fn system_spec_rejection_matrix() {
        // (spec, fragment the hint must contain)
        for (s, hint) in [
            ("fat-tree:k=5", "even"),
            ("fat-tree:k=0", "even"),
            ("fat-tree:k=-4", "integer"),
            ("fat-tree:k=4,k=4", "duplicate"),
            ("fat-tree:", "expected key=value"),
            ("fat-tree:arity=4", "unknown field"),
            ("dragonfly:a=2,p=2", "missing 'h='"),
            ("dragonfly:a=0,p=1,h=1", "router per group"),
            ("dragonfly:a=1,p=0,h=1", "host per router"),
            ("dragonfly:a=1,p=1,h=0", "disconnected"),
            ("pod:nodes=0,gpus=8,rails=1", "at least one node"),
            ("pod:nodes=2,gpus=0,rails=1", "GPU per node"),
            ("pod:nodes=2,gpus=8,rails=0", "zero rails"),
            ("torus:k=4", "unknown system family"),
            ("nope", "unknown system"),
        ] {
            let err = SystemSpec::parse(s).expect_err(s);
            let msg = format!("{err:#}");
            assert!(msg.contains(hint), "{s}: hint '{hint}' not in '{msg}'");
        }
    }

    #[test]
    fn fabric_node_groups_feed_hierarchical_schedules() {
        // pod: gpus-per-node groups, leaders at node boundaries
        let t = SystemSpec::parse("pod:nodes=4,gpus=4,rails=2").unwrap().build();
        let g = node_groups(&t, 16);
        assert_eq!(g.len(), 4);
        for (n, members) in g.iter().enumerate() {
            assert_eq!(members, &(4 * n..4 * n + 4).collect::<Vec<_>>());
        }
        // fat-tree / dragonfly: one single-GPU host per node
        let ft = SystemSpec::parse("fat-tree:k=4").unwrap().build();
        assert_eq!(node_groups(&ft, 16).len(), 16);
    }

    #[test]
    fn all_gpu_pairs_routable_on_all_systems() {
        for k in SystemKind::all() {
            let t = k.build();
            for a in 0..t.num_gpus() {
                for b in 0..t.num_gpus() {
                    assert!(t.route_gpus(a, b).is_some(), "{} {a}->{b}", t.name);
                }
            }
        }
    }
}
