//! GPU network topology substrate (paper Fig. 1, §V-A).
//!
//! Models a multi-GPU system as a graph of devices (GPUs, CPUs/root
//! complexes, PCIe switches, NICs, IB switches) connected by typed links
//! (NVLink, bonded NVLink, PCIe, QPI, FDR InfiniBand). The three systems
//! the paper evaluates — the 16-node K40m cluster, NVIDIA's DGX-1 and
//! Cray's CS-Storm — are constructed in [`systems`] with the bandwidths
//! Fig. 1 reports.
//!
//! The topology answers the questions the communication libraries ask:
//! - what is the route between two endpoints (`route`)?
//! - is GPUDirect P2P possible between two GPUs (`p2p_accessible`)?
//!   (MVAPICH requires it for direct copies; NCCL does NOT, which is the
//!   paper's explanation of NCCL's DGX-1 advantage — §II-B)
//! - which links are NVLink, so NCCL's ring search can prefer them?

pub mod fabrics;
pub mod routing;
pub mod systems;

pub use fabrics::{dragonfly, fat_tree, multi_plane_pod};
pub use routing::Path;

/// Index of a device in [`Topology::devices`].
pub type DeviceId = usize;
/// Index of a link in [`Topology::links`].
pub type LinkId = usize;

/// Device classes in a multi-GPU system.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DeviceKind {
    /// A GPU; `rank` is the MPI-rank-visible ordinal (device ID).
    Gpu { rank: usize },
    /// CPU socket / PCIe root complex; `node` is the host it belongs to.
    Cpu { socket: usize },
    /// PCIe switch fanning out several GPUs (CS-Storm, DGX-1).
    PcieSwitch,
    /// Host channel adapter (InfiniBand NIC).
    Nic,
    /// Top-of-rack InfiniBand switch (cluster star topology).
    IbSwitch,
}

/// A device plus the host node it lives on (nodes matter for "intra- vs
/// inter-node" decisions: GDR only applies across nodes, P2P within one).
#[derive(Clone, Debug)]
pub struct Device {
    /// What the device is.
    pub kind: DeviceKind,
    /// Host node index it lives on.
    pub node: usize,
    /// Human-readable name for reports.
    pub name: String,
}

/// Link technology classes with the paper's unidirectional bandwidths.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkClass {
    /// Single NVLink 1.0 connection point: 20 GB/s unidirectional.
    NvLink,
    /// CS-Storm bonded set of 4 NVLinks: 80 GB/s unidirectional.
    NvLinkBonded4,
    /// PCIe 3.0 x16: ~16 GB/s peak, ~12.5 GB/s effective.
    PcieGen3x16,
    /// QPI between sockets.
    Qpi,
    /// 56 Gbit/s FDR InfiniBand: 7 GB/s peak, ~6.2 GB/s effective.
    InfinibandFdr,
}

impl LinkClass {
    /// Effective unidirectional bandwidth in bytes/second.
    pub fn bandwidth(self) -> f64 {
        match self {
            LinkClass::NvLink => 18.0e9,        // 20 GB/s peak, ~90% achievable
            LinkClass::NvLinkBonded4 => 72.0e9, // 4x bonded
            LinkClass::PcieGen3x16 => 12.5e9,   // protocol overhead off 15.75
            LinkClass::Qpi => 16.0e9,
            LinkClass::InfinibandFdr => 6.2e9,  // 56 Gbit/s minus encoding
        }
    }

    /// Per-hop wire latency in seconds.
    pub fn latency(self) -> f64 {
        match self {
            LinkClass::NvLink | LinkClass::NvLinkBonded4 => 1.3e-6,
            LinkClass::PcieGen3x16 => 1.5e-6,
            LinkClass::Qpi => 0.5e-6,
            LinkClass::InfinibandFdr => 1.0e-6,
        }
    }

    /// Is this an NVLink-class link (single or bonded)?
    pub fn is_nvlink(self) -> bool {
        matches!(self, LinkClass::NvLink | LinkClass::NvLinkBonded4)
    }
}

/// An undirected physical link between two devices.
///
/// Bandwidth is modeled per direction (full duplex): the simulator tracks
/// contention separately for each direction.
#[derive(Clone, Debug)]
pub struct Link {
    /// One endpoint.
    pub a: DeviceId,
    /// The other endpoint.
    pub b: DeviceId,
    /// Link technology (bandwidth/latency class).
    pub class: LinkClass,
}

/// A complete system topology.
#[derive(Clone, Debug)]
pub struct Topology {
    /// System name (e.g. "dgx1", "cluster-16").
    pub name: String,
    /// All devices, indexed by [`DeviceId`].
    pub devices: Vec<Device>,
    /// All links, indexed by [`LinkId`].
    pub links: Vec<Link>,
    /// adjacency: device -> [(link, peer device)]
    adj: Vec<Vec<(LinkId, DeviceId)>>,
    /// GPU rank -> device id (dense, rank i at index i).
    gpus: Vec<DeviceId>,
    /// Per-link dead flags (DESIGN.md §14): a dead link keeps its id —
    /// so perturbation targets and byte accounting stay stable — but is
    /// invisible to routing, P2P detection and host-CPU discovery.
    /// Empty set on every constructed system; only
    /// [`Topology::with_links_down`] sets flags.
    dead: Vec<bool>,
    /// Structural routing tables for parametric fabrics (DESIGN.md
    /// §15). `None` on the hand-built paper systems; the [`fabrics`]
    /// builders attach one so [`Topology::route`] stays O(path length)
    /// at thousands of endpoints. Shared via `Arc` so masked clones
    /// ([`Topology::with_links_down`]) stay cheap.
    fabric: Option<std::sync::Arc<fabrics::Fabric>>,
}

impl Topology {
    /// Create an empty topology with the given name.
    pub fn new(name: impl Into<String>) -> Topology {
        Topology {
            name: name.into(),
            devices: Vec::new(),
            links: Vec::new(),
            adj: Vec::new(),
            gpus: Vec::new(),
            dead: Vec::new(),
            fabric: None,
        }
    }

    /// Register a device; GPUs must be added in rank order.
    pub fn add_device(&mut self, kind: DeviceKind, node: usize, name: impl Into<String>) -> DeviceId {
        let id = self.devices.len();
        if let DeviceKind::Gpu { rank } = kind {
            assert_eq!(rank, self.gpus.len(), "GPU ranks must be added in order");
            self.gpus.push(id);
        }
        self.devices.push(Device { kind, node, name: name.into() });
        self.adj.push(Vec::new());
        id
    }

    /// Connect two distinct devices with an undirected link.
    pub fn add_link(&mut self, a: DeviceId, b: DeviceId, class: LinkClass) -> LinkId {
        assert!(a < self.devices.len() && b < self.devices.len());
        assert_ne!(a, b, "self-links are not allowed");
        let id = self.links.len();
        self.links.push(Link { a, b, class });
        self.adj[a].push((id, b));
        self.adj[b].push((id, a));
        self.dead.push(false);
        id
    }

    /// The same topology with `links` marked **dead** — the masked
    /// fabric a recovery reroute plans against
    /// ([`crate::perturb::recovery`]). Link ids are preserved (the
    /// fault windows and byte accounting still name them); routing,
    /// [`Topology::p2p_accessible`], [`Topology::nvlink_direct`] and
    /// host-CPU discovery all skip dead links. Out-of-range ids are
    /// ignored.
    pub fn with_links_down(&self, links: &[LinkId]) -> Topology {
        let mut t = self.clone();
        for &l in links {
            if l < t.dead.len() {
                t.dead[l] = true;
            }
        }
        t
    }

    /// Is this link usable (not masked dead)?
    pub fn link_alive(&self, l: LinkId) -> bool {
        !self.dead.get(l).copied().unwrap_or(false)
    }

    /// Ids of every masked-dead link, ascending.
    pub fn dead_links(&self) -> Vec<LinkId> {
        (0..self.links.len()).filter(|&l| !self.link_alive(l)).collect()
    }

    /// Can ranks `0..p` still run a collective on this (possibly
    /// masked) fabric? Requires every GPU to reach its host CPU and
    /// every GPU pair to be routable — the pre-flight check a recovery
    /// reroute performs before composing on the masked topology (a mask
    /// that severs a rank needs communicator shrink instead).
    pub fn serviceable(&self, p: usize) -> bool {
        if p == 0 || p > self.num_gpus() {
            return false;
        }
        let cpus: Vec<Option<DeviceId>> =
            (0..p).map(|r| self.try_host_cpu(self.gpu(r))).collect();
        if cpus.iter().any(|c| c.is_none()) {
            return false;
        }
        for a in 0..p {
            for b in (a + 1)..p {
                if self.route_gpus(a, b).is_none() {
                    return false;
                }
                if self.route(cpus[a].unwrap(), cpus[b].unwrap()).is_none() {
                    return false;
                }
            }
        }
        true
    }

    /// Number of GPUs registered.
    pub fn num_gpus(&self) -> usize {
        self.gpus.len()
    }

    /// Device id of GPU with the given rank.
    pub fn gpu(&self, rank: usize) -> DeviceId {
        self.gpus[rank]
    }

    /// Adjacent (link, peer) pairs of a device.
    pub fn neighbors(&self, d: DeviceId) -> &[(LinkId, DeviceId)] {
        &self.adj[d]
    }

    /// Links incident to the GPU with the given rank, in link-id order —
    /// the target set of a *straggler* perturbation (a slow GPU throttles
    /// every lane in and out of it, DESIGN.md §12).
    pub fn gpu_links(&self, rank: usize) -> Vec<LinkId> {
        let mut out: Vec<LinkId> =
            self.adj[self.gpu(rank)].iter().map(|&(l, _)| l).collect();
        out.sort_unstable();
        out
    }

    /// The CPU socket that owns a device's PCIe hierarchy (walks up
    /// through PCIe switches). Used for host-staging endpoints.
    pub fn host_cpu(&self, d: DeviceId) -> DeviceId {
        self.try_host_cpu(d)
            .unwrap_or_else(|| panic!("device {d} has no host CPU reachable over PCIe"))
    }

    /// [`Topology::host_cpu`] without the panic: `None` when no live
    /// PCIe/QPI path leads to a CPU (a masked-dead uplink can sever a
    /// GPU from its host — [`Topology::serviceable`] surfaces that as a
    /// shrink condition instead of a crash).
    pub fn try_host_cpu(&self, d: DeviceId) -> Option<DeviceId> {
        // BFS limited to live PCIe links until a CPU is reached.
        let mut visited = vec![false; self.devices.len()];
        let mut queue = std::collections::VecDeque::new();
        queue.push_back(d);
        visited[d] = true;
        while let Some(cur) = queue.pop_front() {
            if matches!(self.devices[cur].kind, DeviceKind::Cpu { .. }) {
                return Some(cur);
            }
            for &(l, peer) in &self.adj[cur] {
                if !visited[peer]
                    && self.link_alive(l)
                    && self.devices[peer].node == self.devices[d].node
                    && matches!(self.links[l].class, LinkClass::PcieGen3x16 | LinkClass::Qpi)
                {
                    visited[peer] = true;
                    queue.push_back(peer);
                }
            }
        }
        None
    }

    /// Are two GPUs on the same host node?
    pub fn same_node(&self, rank_a: usize, rank_b: usize) -> bool {
        self.devices[self.gpu(rank_a)].node == self.devices[self.gpu(rank_b)].node
    }

    /// Is there a *direct* live NVLink connection between two GPUs?
    pub fn nvlink_direct(&self, rank_a: usize, rank_b: usize) -> bool {
        let (da, db) = (self.gpu(rank_a), self.gpu(rank_b));
        self.adj[da]
            .iter()
            .any(|&(l, peer)| peer == db && self.link_alive(l) && self.links[l].class.is_nvlink())
    }

    /// GPUDirect P2P capability (the rule MVAPICH is constrained by,
    /// §II-B): P2P works iff the GPUs share a node AND are connected by a
    /// direct NVLink OR hang off the same PCIe switch/root complex
    /// *without* crossing QPI. Notably, multi-hop NVLink (e.g. DGX-1
    /// GPU 0 -> 5) is NOT P2P-capable — MVAPICH falls back to PCIe/host
    /// for those pairs while NCCL does not.
    pub fn p2p_accessible(&self, rank_a: usize, rank_b: usize) -> bool {
        if rank_a == rank_b {
            return true;
        }
        if !self.same_node(rank_a, rank_b) {
            return false;
        }
        if self.nvlink_direct(rank_a, rank_b) {
            return true;
        }
        // Same PCIe switch hierarchy: reachable over PCIe links without
        // transiting the root complex (peer-to-peer through the CPU/QPI
        // is not supported — the reason CS-Storm GPUs on different
        // switches and DGX-1 cross-quad pairs fall back to host staging).
        let (da, db) = (self.gpu(rank_a), self.gpu(rank_b));
        let mut visited = vec![false; self.devices.len()];
        let mut queue = std::collections::VecDeque::new();
        queue.push_back(da);
        visited[da] = true;
        while let Some(cur) = queue.pop_front() {
            if cur == db {
                return true;
            }
            if cur != da && matches!(self.devices[cur].kind, DeviceKind::Cpu { .. }) {
                continue; // endpoints may touch the CPU; transit may not
            }
            for &(l, peer) in &self.adj[cur] {
                if !visited[peer]
                    && self.link_alive(l)
                    && self.links[l].class == LinkClass::PcieGen3x16
                {
                    visited[peer] = true;
                    queue.push_back(peer);
                }
            }
        }
        false
    }

    /// Route between two devices: maximize bottleneck bandwidth, then
    /// minimize hop count (a "widest-shortest" path, which is how both
    /// NVLink-first and PCIe-fallback routing behave in practice).
    pub fn route(&self, from: DeviceId, to: DeviceId) -> Option<Path> {
        // Parametric fabrics carry structural tables that assemble the
        // canonical minimal route in O(path length); a miss (dead link
        // on the canonical route, endpoint outside the tables) falls
        // back to the Dijkstra search below, preserving the masked-
        // fabric reroute semantics.
        if let Some(f) = &self.fabric {
            if let Some(p) = f.try_route(self, from, to) {
                return Some(p);
            }
        }
        routing::widest_shortest_path(self, from, to)
    }

    /// Route between GPUs by rank.
    pub fn route_gpus(&self, rank_a: usize, rank_b: usize) -> Option<Path> {
        self.route(self.gpu(rank_a), self.gpu(rank_b))
    }

    /// Route restricted to NVLink fabric only (what NCCL's topology
    /// detection searches). None if the GPUs aren't NVLink-connected.
    pub fn route_nvlink_only(&self, rank_a: usize, rank_b: usize) -> Option<Path> {
        routing::nvlink_path(self, self.gpu(rank_a), self.gpu(rank_b))
    }

    /// Re-map MPI ranks to GPUs (paper §III-B: ReFacTo "added the
    /// capability to associate the MPI ranks with specific GPUs, allowing
    /// for more flexibility on systems where a sequential assignment
    /// would not be optimal"). `perm[rank] = old GPU rank`; returns a
    /// topology whose GPU registry is permuted accordingly — every
    /// communication model then sees the new binding transparently.
    pub fn remap_gpus(&self, perm: &[usize]) -> Topology {
        assert_eq!(perm.len(), self.gpus.len(), "permutation must cover all GPUs");
        let mut seen = vec![false; perm.len()];
        for &p in perm {
            assert!(p < perm.len() && !seen[p], "not a permutation: {perm:?}");
            seen[p] = true;
        }
        let mut t = self.clone();
        t.name = format!("{}-remapped", self.name);
        for (new_rank, &old_rank) in perm.iter().enumerate() {
            let dev = self.gpus[old_rank];
            t.gpus[new_rank] = dev;
            if let DeviceKind::Gpu { rank } = &mut t.devices[dev].kind {
                *rank = new_rank;
            }
        }
        t
    }

    /// Bottleneck bandwidth along a path.
    pub fn path_bandwidth(&self, path: &Path) -> f64 {
        path.links
            .iter()
            .map(|&l| self.links[l].class.bandwidth())
            .fold(f64::INFINITY, f64::min)
    }

    /// Sum of per-hop latencies along a path.
    pub fn path_latency(&self, path: &Path) -> f64 {
        path.links.iter().map(|&l| self.links[l].class.latency()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_gpu_nvlink() -> Topology {
        let mut t = Topology::new("test");
        let cpu = t.add_device(DeviceKind::Cpu { socket: 0 }, 0, "cpu0");
        let g0 = t.add_device(DeviceKind::Gpu { rank: 0 }, 0, "gpu0");
        let g1 = t.add_device(DeviceKind::Gpu { rank: 1 }, 0, "gpu1");
        t.add_link(g0, cpu, LinkClass::PcieGen3x16);
        t.add_link(g1, cpu, LinkClass::PcieGen3x16);
        t.add_link(g0, g1, LinkClass::NvLink);
        t
    }

    #[test]
    fn gpu_registry() {
        let t = two_gpu_nvlink();
        assert_eq!(t.num_gpus(), 2);
        assert_eq!(t.devices[t.gpu(0)].name, "gpu0");
        assert_eq!(t.devices[t.gpu(1)].name, "gpu1");
    }

    #[test]
    fn nvlink_direct_detection() {
        let t = two_gpu_nvlink();
        assert!(t.nvlink_direct(0, 1));
        assert!(t.p2p_accessible(0, 1));
    }

    #[test]
    fn route_prefers_nvlink_over_pcie() {
        let t = two_gpu_nvlink();
        let p = t.route_gpus(0, 1).unwrap();
        assert_eq!(p.links.len(), 1);
        assert!(t.links[p.links[0]].class.is_nvlink());
        assert!((t.path_bandwidth(&p) - LinkClass::NvLink.bandwidth()).abs() < 1.0);
    }

    #[test]
    fn host_cpu_walks_pcie() {
        let t = two_gpu_nvlink();
        let cpu = t.host_cpu(t.gpu(0));
        assert!(matches!(t.devices[cpu].kind, DeviceKind::Cpu { .. }));
    }

    #[test]
    fn gpu_links_are_incident_and_sorted() {
        let t = two_gpu_nvlink();
        // gpu0: PCIe link 0 + NVLink link 2
        let ls = t.gpu_links(0);
        assert_eq!(ls, vec![0, 2]);
        for l in ls {
            let link = &t.links[l];
            assert!(link.a == t.gpu(0) || link.b == t.gpu(0));
        }
        // DGX-1: 4 NVLinks + 1 PCIe per GPU
        let d = crate::topology::systems::dgx1();
        for r in 0..8 {
            assert_eq!(d.gpu_links(r).len(), 5, "gpu {r}");
        }
    }

    #[test]
    fn p2p_same_pcie_switch_without_nvlink() {
        let mut t = Topology::new("pcie-only");
        let cpu = t.add_device(DeviceKind::Cpu { socket: 0 }, 0, "cpu0");
        let sw = t.add_device(DeviceKind::PcieSwitch, 0, "plx0");
        let g0 = t.add_device(DeviceKind::Gpu { rank: 0 }, 0, "gpu0");
        let g1 = t.add_device(DeviceKind::Gpu { rank: 1 }, 0, "gpu1");
        let g2 = t.add_device(DeviceKind::Gpu { rank: 2 }, 0, "gpu2");
        t.add_link(sw, cpu, LinkClass::PcieGen3x16);
        t.add_link(g0, sw, LinkClass::PcieGen3x16);
        t.add_link(g1, sw, LinkClass::PcieGen3x16);
        t.add_link(g2, cpu, LinkClass::PcieGen3x16); // directly on the root
        // same switch: P2P works without NVLink
        assert!(t.p2p_accessible(0, 1));
        assert!(!t.nvlink_direct(0, 1));
        // through the root complex: no P2P
        assert!(!t.p2p_accessible(0, 2));
        assert!(t.route_gpus(0, 2).is_some());
    }

    #[test]
    fn p2p_blocked_across_qpi() {
        // GPUs on different sockets joined only via QPI: no P2P.
        let mut t = Topology::new("qpi-split");
        let cpu0 = t.add_device(DeviceKind::Cpu { socket: 0 }, 0, "cpu0");
        let cpu1 = t.add_device(DeviceKind::Cpu { socket: 1 }, 0, "cpu1");
        let g0 = t.add_device(DeviceKind::Gpu { rank: 0 }, 0, "gpu0");
        let g1 = t.add_device(DeviceKind::Gpu { rank: 1 }, 0, "gpu1");
        t.add_link(g0, cpu0, LinkClass::PcieGen3x16);
        t.add_link(g1, cpu1, LinkClass::PcieGen3x16);
        t.add_link(cpu0, cpu1, LinkClass::Qpi);
        assert!(!t.p2p_accessible(0, 1));
        // ... but still routable (through QPI).
        assert!(t.route_gpus(0, 1).is_some());
    }

    #[test]
    fn p2p_blocked_across_nodes() {
        let mut t = Topology::new("two-node");
        let sw = t.add_device(DeviceKind::IbSwitch, usize::MAX, "ib");
        for n in 0..2 {
            let cpu = t.add_device(DeviceKind::Cpu { socket: 0 }, n, "cpu");
            let g = t.add_device(DeviceKind::Gpu { rank: n }, n, "gpu");
            let nic = t.add_device(DeviceKind::Nic, n, "nic");
            t.add_link(g, cpu, LinkClass::PcieGen3x16);
            t.add_link(cpu, nic, LinkClass::PcieGen3x16);
            t.add_link(nic, sw, LinkClass::InfinibandFdr);
        }
        assert!(!t.p2p_accessible(0, 1));
        let p = t.route_gpus(0, 1).unwrap();
        // bottleneck must be the IB link
        assert!((t.path_bandwidth(&p) - LinkClass::InfinibandFdr.bandwidth()).abs() < 1.0);
    }

    #[test]
    fn masked_uplink_severs_a_gpu_and_serviceability_sees_it() {
        let t = two_gpu_nvlink();
        assert!(t.serviceable(2));
        // gpu0's only PCIe uplink is link 0: masking it leaves gpu0
        // routable to gpu1 over NVLink but hostless -> not serviceable
        let masked = t.with_links_down(&[0]);
        assert!(masked.try_host_cpu(masked.gpu(0)).is_none());
        assert!(masked.route_gpus(0, 1).is_some(), "NVLink route survives");
        assert!(!masked.serviceable(2));
        assert!(masked.serviceable(1), "rank 1 alone is fine");
        // masking every incident link of gpu1 severs it completely
        let dead1 = t.with_links_down(&t.gpu_links(1));
        assert!(dead1.route_gpus(0, 1).is_none());
        assert!(!dead1.serviceable(2));
        // dgx1: one dead NVLink still leaves the fabric serviceable
        let d = crate::topology::systems::dgx1();
        let nv = d.gpu_links(0).into_iter().find(|&l| d.links[l].class.is_nvlink()).unwrap();
        assert!(d.with_links_down(&[nv]).serviceable(8));
    }

    #[test]
    #[should_panic(expected = "self-links")]
    fn self_link_rejected() {
        let mut t = Topology::new("bad");
        let g = t.add_device(DeviceKind::Gpu { rank: 0 }, 0, "g");
        t.add_link(g, g, LinkClass::NvLink);
    }
}
