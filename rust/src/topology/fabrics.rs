//! Parametric large-scale fabrics (DESIGN.md §15): the thousand-GPU
//! topologies production Allgatherv actually runs on, beyond the
//! paper's three 16-GPU systems.
//!
//! Three canonical parametrizations:
//! - [`fat_tree`]`(k)` — k-ary fat-tree (Al-Fares et al.): k pods of
//!   k/2 edge + k/2 aggregation switches, (k/2)² cores, k³/4 hosts,
//!   full bisection (per switch, uplink capacity == host capacity);
//! - [`dragonfly`]`(a, p, h)` — canonical group/router/global-link
//!   parametrization (Kim et al.): g = a·h + 1 groups of `a` fully
//!   meshed routers, `p` hosts per router, `h` global ports per router,
//!   exactly one global link between every group pair;
//! - [`multi_plane_pod`]`(nodes, gpus_per_node, rails)` — rail-optimized
//!   multi-plane DGX pods: NVLink full mesh inside each node, `rails`
//!   NICs per node each wired to its own plane switch, GPU i using rail
//!   i mod rails.
//!
//! Every host is the cluster idiom of [`super::systems`]: a cpu + gpu
//! (+ nic) chain, so MPI host staging, `node_groups`, `gpu_links`,
//! `bandwidth_ring_over` and `with_links_down` all work unchanged.
//!
//! At these sizes the O(V²) Dijkstra in [`super::routing`] is far too
//! slow to call per GPU pair, so each builder attaches a [`Fabric`] —
//! structural routing tables keyed by [`DeviceId`] (stable across
//! [`Topology::remap_gpus`]) that assemble the canonical minimal route
//! in O(path length). ECMP choices are determinized by the destination
//! host index. A structural route that would cross a masked-dead link
//! (or touch a device the tables do not know) returns `None`, and
//! [`Topology::route`] falls back to the Dijkstra search — exactly the
//! `with_links_down` reroute semantics of the paper systems.

use std::sync::Arc;

use super::routing::Path;
use super::{DeviceId, DeviceKind, LinkClass, LinkId, Topology};

// ---------------------------------------------------------------------------
// Structural routing tables
// ---------------------------------------------------------------------------

/// Where a device sits inside a host's gpu -> cpu -> nic chain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ChainPos {
    /// The GPU at the bottom of the chain.
    Gpu,
    /// The host CPU in the middle.
    Cpu,
    /// The NIC attaching the host to its leaf switch.
    Nic,
}

/// One host's chain and its attachment point. `c0`/`c1` are fabric
/// coordinates: (pod, edge) on a fat-tree, (group, router) on a
/// dragonfly.
#[derive(Clone, Debug)]
struct Host {
    gpu: DeviceId,
    cpu: DeviceId,
    nic: DeviceId,
    l_gpu_cpu: LinkId,
    l_cpu_nic: LinkId,
    l_nic_leaf: LinkId,
    leaf: DeviceId,
    c0: usize,
    c1: usize,
}

impl Host {
    /// The chain from `pos` up to (and including) the leaf switch.
    fn chain_up(&self, pos: ChainPos) -> (Vec<DeviceId>, Vec<LinkId>) {
        match pos {
            ChainPos::Gpu => (
                vec![self.gpu, self.cpu, self.nic, self.leaf],
                vec![self.l_gpu_cpu, self.l_cpu_nic, self.l_nic_leaf],
            ),
            ChainPos::Cpu => {
                (vec![self.cpu, self.nic, self.leaf], vec![self.l_cpu_nic, self.l_nic_leaf])
            }
            ChainPos::Nic => (vec![self.nic, self.leaf], vec![self.l_nic_leaf]),
        }
    }
}

/// Switch-level core of a host-chain fabric.
#[derive(Debug)]
enum TreeCore {
    /// k-ary fat-tree switch stages.
    FatTree {
        /// k/2 — hosts per edge, edges per pod, uplinks per switch.
        half_k: usize,
        /// `aggs[pod][a]` — aggregation switch devices.
        aggs: Vec<Vec<DeviceId>>,
        /// `cores[a * half_k + c]` — core switch devices.
        cores: Vec<DeviceId>,
        /// `edge_agg[pod][e][a]` — link edge(pod,e) <-> agg(pod,a).
        edge_agg: Vec<Vec<Vec<LinkId>>>,
        /// `agg_core[pod][a][c]` — link agg(pod,a) <-> core(a·k/2+c).
        agg_core: Vec<Vec<Vec<LinkId>>>,
    },
    /// Dragonfly local meshes + global links.
    Dragonfly {
        /// `routers[group][r]` — router devices.
        routers: Vec<Vec<DeviceId>>,
        /// `local[group][i][j]` — intra-group mesh link (i != j).
        local: Vec<Vec<Vec<LinkId>>>,
        /// `global[gi][gj]` — (link, router idx in gi, router idx in
        /// gj) of the single global link between the groups (gi != gj).
        global: Vec<Vec<(LinkId, usize, usize)>>,
    },
}

impl TreeCore {
    /// The switch segment from leaf (c0a, c1a) to leaf (c0b, c1b):
    /// intermediate devices (exclusive of both leaves) and the links,
    /// `links.len() == devices.len() + 1`. `dst_host` determinizes the
    /// ECMP choice.
    fn segment(
        &self,
        (c0a, c1a): (usize, usize),
        (c0b, c1b): (usize, usize),
        dst_host: usize,
    ) -> (Vec<DeviceId>, Vec<LinkId>) {
        match self {
            TreeCore::FatTree { half_k, aggs, cores, edge_agg, agg_core } => {
                let a = dst_host % half_k;
                if c0a == c0b {
                    // same pod: up to one aggregation switch and down
                    (vec![aggs[c0a][a]], vec![edge_agg[c0a][c1a][a], edge_agg[c0a][c1b][a]])
                } else {
                    // cross-pod: edge -> agg -> core -> agg -> edge
                    let c = (dst_host / half_k) % half_k;
                    (
                        vec![aggs[c0a][a], cores[a * half_k + c], aggs[c0b][a]],
                        vec![
                            edge_agg[c0a][c1a][a],
                            agg_core[c0a][a][c],
                            agg_core[c0b][a][c],
                            edge_agg[c0b][c1b][a],
                        ],
                    )
                }
            }
            TreeCore::Dragonfly { routers, local, global } => {
                if c0a == c0b {
                    // same group: one local mesh hop
                    (vec![], vec![local[c0a][c1a][c1b]])
                } else {
                    // minimal global route: local detour to the router
                    // owning the global link, cross, local detour down
                    let (gl, ra, rb) = global[c0a][c0b];
                    let mut devices = Vec::new();
                    let mut links = Vec::new();
                    if c1a != ra {
                        links.push(local[c0a][c1a][ra]);
                        devices.push(routers[c0a][ra]);
                    }
                    links.push(gl);
                    if rb != c1b {
                        devices.push(routers[c0b][rb]);
                        links.push(local[c0b][rb][c1b]);
                    }
                    (devices, links)
                }
            }
        }
    }
}

/// Host-chain fabric (fat-tree or dragonfly): per-host chains plus the
/// switch core.
#[derive(Debug)]
struct TreeFabric {
    hosts: Vec<Host>,
    /// Device -> (host index, chain position); `None` for switches.
    host_of: Vec<Option<(usize, ChainPos)>>,
    core: TreeCore,
}

impl TreeFabric {
    fn route(&self, from: DeviceId, to: DeviceId) -> Option<Path> {
        let (ha, pa) = self.host_of.get(from).copied().flatten()?;
        let (hb, pb) = self.host_of.get(to).copied().flatten()?;
        let (a_devs, a_links) = self.hosts[ha].chain_up(pa);
        let (b_devs, b_links) = self.hosts[hb].chain_up(pb);
        if self.hosts[ha].leaf == self.hosts[hb].leaf {
            return Some(join_at_suffix(a_devs, a_links, b_devs, b_links));
        }
        let ca = (self.hosts[ha].c0, self.hosts[ha].c1);
        let cb = (self.hosts[hb].c0, self.hosts[hb].c1);
        let (mid_devs, mid_links) = self.core.segment(ca, cb, hb);
        let mut devices = a_devs;
        devices.extend(mid_devs);
        devices.extend(b_devs.into_iter().rev());
        let mut links = a_links;
        links.extend(mid_links);
        links.extend(b_links.into_iter().rev());
        Some(Path { devices, links })
    }
}

/// Join two up-chains that end at the same device by trimming their
/// longest common suffix; the first shared device is the junction.
fn join_at_suffix(
    a_devs: Vec<DeviceId>,
    a_links: Vec<LinkId>,
    b_devs: Vec<DeviceId>,
    b_links: Vec<LinkId>,
) -> Path {
    let (la, lb) = (a_devs.len(), b_devs.len());
    let mut s = 0;
    while s < la && s < lb && a_devs[la - 1 - s] == b_devs[lb - 1 - s] {
        s += 1;
    }
    debug_assert!(s >= 1, "chains must share their leaf");
    let mut devices: Vec<DeviceId> = a_devs[..=la - s].to_vec();
    devices.extend(b_devs[..lb - s].iter().rev());
    let mut links: Vec<LinkId> = a_links[..la - s].to_vec();
    links.extend(b_links[..lb - s].iter().rev());
    Path { devices, links }
}

/// Where a device sits inside a multi-plane pod.
#[derive(Clone, Copy, Debug)]
enum PodLoc {
    /// GPU `idx` of a node.
    Gpu { node: usize, idx: usize },
    /// A node's CPU.
    Cpu { node: usize },
    /// Rail NIC `rail` of a node.
    Nic { node: usize, rail: usize },
}

impl PodLoc {
    fn node(self) -> usize {
        match self {
            PodLoc::Gpu { node, .. } | PodLoc::Cpu { node } | PodLoc::Nic { node, .. } => node,
        }
    }
}

/// One pod node's devices and links.
#[derive(Debug)]
struct PodNode {
    cpu: DeviceId,
    gpus: Vec<DeviceId>,
    nics: Vec<DeviceId>,
    l_gpu_cpu: Vec<LinkId>,
    l_nic_cpu: Vec<LinkId>,
    l_nic_plane: Vec<LinkId>,
    /// NVLink full mesh: `mesh[i][j]` (i != j).
    mesh: Vec<Vec<LinkId>>,
}

/// Rail-optimized multi-plane pod fabric.
#[derive(Debug)]
struct PodFabric {
    rails: usize,
    nodes: Vec<PodNode>,
    planes: Vec<DeviceId>,
    loc: Vec<Option<PodLoc>>,
}

impl PodFabric {
    /// Chain from a device up to its node's rail-`r` NIC.
    fn up_to_nic(&self, l: PodLoc, r: usize) -> (Vec<DeviceId>, Vec<LinkId>) {
        let n = &self.nodes[l.node()];
        match l {
            PodLoc::Gpu { idx, .. } => (
                vec![n.gpus[idx], n.cpu, n.nics[r]],
                vec![n.l_gpu_cpu[idx], n.l_nic_cpu[r]],
            ),
            PodLoc::Cpu { .. } => (vec![n.cpu, n.nics[r]], vec![n.l_nic_cpu[r]]),
            PodLoc::Nic { rail, .. } if rail == r => (vec![n.nics[r]], vec![]),
            PodLoc::Nic { rail, .. } => (
                vec![n.nics[rail], n.cpu, n.nics[r]],
                vec![n.l_nic_cpu[rail], n.l_nic_cpu[r]],
            ),
        }
    }

    fn route(&self, from: DeviceId, to: DeviceId) -> Option<Path> {
        let la = self.loc.get(from).copied().flatten()?;
        let lb = self.loc.get(to).copied().flatten()?;
        let (na, nb) = (la.node(), lb.node());
        if na == nb {
            let n = &self.nodes[na];
            let (devices, links) = match (la, lb) {
                (PodLoc::Gpu { idx: i, .. }, PodLoc::Gpu { idx: j, .. }) => {
                    (vec![n.gpus[i], n.gpus[j]], vec![n.mesh[i][j]])
                }
                (PodLoc::Gpu { idx, .. }, PodLoc::Cpu { .. }) => {
                    (vec![n.gpus[idx], n.cpu], vec![n.l_gpu_cpu[idx]])
                }
                (PodLoc::Cpu { .. }, PodLoc::Gpu { idx, .. }) => {
                    (vec![n.cpu, n.gpus[idx]], vec![n.l_gpu_cpu[idx]])
                }
                (PodLoc::Cpu { .. }, PodLoc::Nic { rail, .. }) => {
                    (vec![n.cpu, n.nics[rail]], vec![n.l_nic_cpu[rail]])
                }
                (PodLoc::Nic { rail, .. }, PodLoc::Cpu { .. }) => {
                    (vec![n.nics[rail], n.cpu], vec![n.l_nic_cpu[rail]])
                }
                (PodLoc::Gpu { idx, .. }, PodLoc::Nic { rail, .. }) => (
                    vec![n.gpus[idx], n.cpu, n.nics[rail]],
                    vec![n.l_gpu_cpu[idx], n.l_nic_cpu[rail]],
                ),
                (PodLoc::Nic { rail, .. }, PodLoc::Gpu { idx, .. }) => (
                    vec![n.nics[rail], n.cpu, n.gpus[idx]],
                    vec![n.l_nic_cpu[rail], n.l_gpu_cpu[idx]],
                ),
                (PodLoc::Nic { rail: i, .. }, PodLoc::Nic { rail: j, .. }) => (
                    vec![n.nics[i], n.cpu, n.nics[j]],
                    vec![n.l_nic_cpu[i], n.l_nic_cpu[j]],
                ),
                (PodLoc::Cpu { .. }, PodLoc::Cpu { .. }) => return None, // from == to
            };
            return Some(Path { devices, links });
        }
        // Cross-node: pick the rail (source GPU's rail, else the
        // destination GPU's, else a destination-node hash).
        let r = match (la, lb) {
            (PodLoc::Gpu { idx, .. }, _) => idx % self.rails,
            (_, PodLoc::Gpu { idx, .. }) => idx % self.rails,
            _ => nb % self.rails,
        };
        let (mut devices, mut links) = self.up_to_nic(la, r);
        let (down_devs, down_links) = self.up_to_nic(lb, r);
        links.push(self.nodes[na].l_nic_plane[r]);
        devices.push(self.planes[r]);
        links.push(self.nodes[nb].l_nic_plane[r]);
        devices.extend(down_devs.into_iter().rev());
        links.extend(down_links.into_iter().rev());
        Some(Path { devices, links })
    }
}

/// Structural routing tables a parametric fabric attaches to its
/// [`Topology`]. [`Topology::route`] consults this first and falls back
/// to the Dijkstra search when the answer is `None`.
#[derive(Debug)]
pub(crate) enum Fabric {
    /// Host-chain fabric (fat-tree or dragonfly).
    Tree(TreeFabric),
    /// Rail-optimized multi-plane pod.
    Pod(PodFabric),
}

impl Fabric {
    /// The canonical minimal route, or `None` when an endpoint is
    /// outside the tables, `from == to`, or the route would cross a
    /// dead link (the caller then falls back to Dijkstra).
    pub(crate) fn try_route(
        &self,
        topo: &Topology,
        from: DeviceId,
        to: DeviceId,
    ) -> Option<Path> {
        if from == to {
            return None;
        }
        let path = match self {
            Fabric::Tree(t) => t.route(from, to)?,
            Fabric::Pod(p) => p.route(from, to)?,
        };
        if path.links.iter().any(|&l| !topo.link_alive(l)) {
            return None;
        }
        Some(path)
    }
}

// ---------------------------------------------------------------------------
// Builders
// ---------------------------------------------------------------------------

/// Add one cpu + gpu + nic host chained to `leaf`, returning its
/// [`Host`] record. Mirrors the paper cluster's per-node idiom.
fn add_host(
    t: &mut Topology,
    rank: usize,
    node: usize,
    prefix: &str,
    leaf: DeviceId,
    (c0, c1): (usize, usize),
) -> Host {
    let cpu = t.add_device(DeviceKind::Cpu { socket: 0 }, node, format!("{prefix}.cpu"));
    let gpu = t.add_device(DeviceKind::Gpu { rank }, node, format!("{prefix}.gpu"));
    let nic = t.add_device(DeviceKind::Nic, node, format!("{prefix}.hca"));
    let l_gpu_cpu = t.add_link(gpu, cpu, LinkClass::PcieGen3x16);
    let l_cpu_nic = t.add_link(cpu, nic, LinkClass::PcieGen3x16);
    let l_nic_leaf = t.add_link(nic, leaf, LinkClass::InfinibandFdr);
    Host { gpu, cpu, nic, l_gpu_cpu, l_cpu_nic, l_nic_leaf, leaf, c0, c1 }
}

/// Record a host's three chain devices in the device->host map.
fn index_host(host_of: &mut Vec<Option<(usize, ChainPos)>>, h: usize, host: &Host) {
    let max = host.gpu.max(host.cpu).max(host.nic);
    if host_of.len() <= max {
        host_of.resize(max + 1, None);
    }
    host_of[host.gpu] = Some((h, ChainPos::Gpu));
    host_of[host.cpu] = Some((h, ChainPos::Cpu));
    host_of[host.nic] = Some((h, ChainPos::Nic));
}

/// k-ary fat-tree (k even, k >= 2): k pods × (k/2 edge + k/2 agg)
/// switches, (k/2)² cores, k/2 hosts per edge — k³/4 single-GPU hosts
/// with full bisection bandwidth (every switch stage has equal up- and
/// down-capacity). Host ranks are dense in (pod, edge, slot) order;
/// every host is its own node.
pub fn fat_tree(k: usize) -> Topology {
    assert!(k >= 2 && k % 2 == 0, "fat-tree arity must be even and >= 2, got {k}");
    let half = k / 2;
    let mut t = Topology::new(format!("fat-tree-k{k}"));
    let cores: Vec<DeviceId> = (0..half * half)
        .map(|c| t.add_device(DeviceKind::IbSwitch, usize::MAX, format!("core{c}")))
        .collect();
    let mut hosts = Vec::with_capacity(k * half * half);
    let mut host_of: Vec<Option<(usize, ChainPos)>> = Vec::new();
    let mut aggs = Vec::with_capacity(k);
    let mut edge_agg = Vec::with_capacity(k);
    let mut agg_core = Vec::with_capacity(k);
    for pod in 0..k {
        let edges: Vec<DeviceId> = (0..half)
            .map(|e| t.add_device(DeviceKind::IbSwitch, usize::MAX, format!("p{pod}.edge{e}")))
            .collect();
        let pod_aggs: Vec<DeviceId> = (0..half)
            .map(|a| t.add_device(DeviceKind::IbSwitch, usize::MAX, format!("p{pod}.agg{a}")))
            .collect();
        for (e, &edge) in edges.iter().enumerate() {
            for slot in 0..half {
                let rank = hosts.len();
                let node = rank;
                let prefix = format!("p{pod}.e{e}.h{slot}");
                let host = add_host(&mut t, rank, node, &prefix, edge, (pod, e));
                index_host(&mut host_of, rank, &host);
                hosts.push(host);
            }
        }
        let ea: Vec<Vec<LinkId>> = edges
            .iter()
            .map(|&edge| {
                pod_aggs
                    .iter()
                    .map(|&agg| t.add_link(edge, agg, LinkClass::InfinibandFdr))
                    .collect()
            })
            .collect();
        let ac: Vec<Vec<LinkId>> = pod_aggs
            .iter()
            .enumerate()
            .map(|(a, &agg)| {
                (0..half)
                    .map(|c| t.add_link(agg, cores[a * half + c], LinkClass::InfinibandFdr))
                    .collect()
            })
            .collect();
        aggs.push(pod_aggs);
        edge_agg.push(ea);
        agg_core.push(ac);
    }
    host_of.resize(t.devices.len(), None);
    t.fabric = Some(Arc::new(Fabric::Tree(TreeFabric {
        hosts,
        host_of,
        core: TreeCore::FatTree { half_k: half, aggs, cores, edge_agg, agg_core },
    })));
    t
}

/// Canonical dragonfly (a routers/group, p hosts/router, h global
/// ports/router): g = a·h + 1 groups, routers fully meshed within a
/// group, exactly one global link between every group pair (absolute
/// arrangement: offset o = gj − gi is served by router (o−1)/h on the
/// source side). g·a·p single-GPU hosts, ranks dense in (group, router,
/// slot) order; every host is its own node.
pub fn dragonfly(a: usize, p: usize, h: usize) -> Topology {
    assert!(a >= 1, "dragonfly needs at least one router per group");
    assert!(p >= 1, "dragonfly needs at least one host per router");
    assert!(h >= 1, "dragonfly needs at least one global port per router");
    let g = a * h + 1;
    let mut t = Topology::new(format!("dragonfly-{a}x{p}x{h}"));
    let mut routers = Vec::with_capacity(g);
    let mut local = Vec::with_capacity(g);
    let mut hosts = Vec::new();
    let mut host_of: Vec<Option<(usize, ChainPos)>> = Vec::new();
    for gi in 0..g {
        let rs: Vec<DeviceId> = (0..a)
            .map(|r| t.add_device(DeviceKind::IbSwitch, usize::MAX, format!("g{gi}.r{r}")))
            .collect();
        for (r, &router) in rs.iter().enumerate() {
            for slot in 0..p {
                let rank = hosts.len();
                let prefix = format!("g{gi}.r{r}.h{slot}");
                let host = add_host(&mut t, rank, rank, &prefix, router, (gi, r));
                index_host(&mut host_of, rank, &host);
                hosts.push(host);
            }
        }
        // intra-group full mesh
        let mut mesh = vec![vec![0 as LinkId; a]; a];
        for i in 0..a {
            for j in (i + 1)..a {
                let l = t.add_link(rs[i], rs[j], LinkClass::InfinibandFdr);
                mesh[i][j] = l;
                mesh[j][i] = l;
            }
        }
        routers.push(rs);
        local.push(mesh);
    }
    // global links: one per group pair, absolute arrangement
    let mut global = vec![vec![(0 as LinkId, 0usize, 0usize); g]; g];
    for gi in 0..g {
        for gj in (gi + 1)..g {
            let o = gj - gi; // offset 1..=a*h
            let ri = (o - 1) / h;
            let rj = (g - o - 1) / h; // gi as seen from gj: offset g - o
            let l = t.add_link(routers[gi][ri], routers[gj][rj], LinkClass::InfinibandFdr);
            global[gi][gj] = (l, ri, rj);
            global[gj][gi] = (l, rj, ri);
        }
    }
    host_of.resize(t.devices.len(), None);
    t.fabric = Some(Arc::new(Fabric::Tree(TreeFabric {
        hosts,
        host_of,
        core: TreeCore::Dragonfly { routers, local, global },
    })));
    t
}

/// Rail-optimized multi-plane DGX pod: `nodes` hosts of
/// `gpus_per_node` GPUs in an NVLink full mesh (each on PCIe to the
/// node CPU), `rails` NICs per node, NIC r wired to plane switch r.
/// Inter-node traffic from GPU i rides rail i mod rails, so
/// same-rail GPUs never contend with other rails' planes. Ranks are
/// dense in (node, gpu) order.
pub fn multi_plane_pod(nodes: usize, gpus_per_node: usize, rails: usize) -> Topology {
    assert!(nodes >= 1, "pod needs at least one node");
    assert!(gpus_per_node >= 1, "pod needs at least one GPU per node");
    assert!(rails >= 1, "pod needs at least one rail");
    let mut t = Topology::new(format!("pod-{nodes}x{gpus_per_node}x{rails}"));
    let planes: Vec<DeviceId> = (0..rails)
        .map(|r| t.add_device(DeviceKind::IbSwitch, usize::MAX, format!("plane{r}")))
        .collect();
    let mut pod_nodes = Vec::with_capacity(nodes);
    let mut loc: Vec<Option<PodLoc>> = vec![None; rails];
    for node in 0..nodes {
        let cpu = t.add_device(DeviceKind::Cpu { socket: 0 }, node, format!("n{node}.cpu"));
        let gpus: Vec<DeviceId> = (0..gpus_per_node)
            .map(|i| {
                t.add_device(
                    DeviceKind::Gpu { rank: node * gpus_per_node + i },
                    node,
                    format!("n{node}.gpu{i}"),
                )
            })
            .collect();
        let nics: Vec<DeviceId> = (0..rails)
            .map(|r| t.add_device(DeviceKind::Nic, node, format!("n{node}.hca{r}")))
            .collect();
        let l_gpu_cpu: Vec<LinkId> =
            gpus.iter().map(|&g| t.add_link(g, cpu, LinkClass::PcieGen3x16)).collect();
        let l_nic_cpu: Vec<LinkId> =
            nics.iter().map(|&n| t.add_link(cpu, n, LinkClass::PcieGen3x16)).collect();
        let l_nic_plane: Vec<LinkId> = nics
            .iter()
            .zip(&planes)
            .map(|(&n, &pl)| t.add_link(n, pl, LinkClass::InfinibandFdr))
            .collect();
        let mut mesh = vec![vec![0 as LinkId; gpus_per_node]; gpus_per_node];
        for i in 0..gpus_per_node {
            for j in (i + 1)..gpus_per_node {
                let l = t.add_link(gpus[i], gpus[j], LinkClass::NvLink);
                mesh[i][j] = l;
                mesh[j][i] = l;
            }
        }
        loc.resize(t.devices.len(), None);
        loc[cpu] = Some(PodLoc::Cpu { node });
        for (i, &gd) in gpus.iter().enumerate() {
            loc[gd] = Some(PodLoc::Gpu { node, idx: i });
        }
        for (r, &nd) in nics.iter().enumerate() {
            loc[nd] = Some(PodLoc::Nic { node, rail: r });
        }
        pod_nodes.push(PodNode { cpu, gpus, nics, l_gpu_cpu, l_nic_cpu, l_nic_plane, mesh });
    }
    loc.resize(t.devices.len(), None);
    t.fabric = Some(Arc::new(Fabric::Pod(PodFabric { rails, nodes: pod_nodes, planes, loc })));
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::routing::widest_shortest_path;
    use crate::topology::systems::node_groups;

    /// Every (devices, links) pair is consistent and every link is a
    /// real edge between its neighbors.
    fn assert_valid_path(t: &Topology, p: &Path) {
        assert_eq!(p.links.len() + 1, p.devices.len());
        for (i, &l) in p.links.iter().enumerate() {
            let (a, b) = (p.devices[i], p.devices[i + 1]);
            let link = &t.links[l];
            assert!(
                (link.a == a && link.b == b) || (link.a == b && link.b == a),
                "link {l} does not join devices {a} and {b}"
            );
            assert!(t.link_alive(l));
        }
        // no device revisited
        let mut seen = p.devices.clone();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), p.devices.len(), "path revisits a device: {p:?}");
    }

    /// Structural routes must match Dijkstra on (bottleneck bw, hops) —
    /// the widest-shortest criterion — for every GPU pair.
    fn assert_matches_dijkstra(t: &Topology) {
        for a in 0..t.num_gpus() {
            for b in 0..t.num_gpus() {
                if a == b {
                    continue;
                }
                let fast = t.route_gpus(a, b).expect("structural route");
                assert_valid_path(t, &fast);
                let slow = widest_shortest_path(t, t.gpu(a), t.gpu(b)).expect("dijkstra");
                assert_eq!(
                    t.path_bandwidth(&fast).to_bits(),
                    t.path_bandwidth(&slow).to_bits(),
                    "{}: {a}->{b} bandwidth mismatch",
                    t.name
                );
                assert_eq!(fast.hops(), slow.hops(), "{}: {a}->{b} hop mismatch", t.name);
            }
        }
    }

    #[test]
    fn fat_tree_counts_and_routes() {
        let t = fat_tree(4);
        assert_eq!(t.num_gpus(), 16); // k^3/4
        assert_matches_dijkstra(&t);
        // host chains work: staging endpoints + per-host node groups
        assert!(t.try_host_cpu(t.gpu(0)).is_some());
        assert_eq!(node_groups(&t, 16).len(), 16);
    }

    #[test]
    fn fat_tree_k2_degenerate() {
        let t = fat_tree(2);
        assert_eq!(t.num_gpus(), 2);
        assert_matches_dijkstra(&t);
    }

    #[test]
    fn dragonfly_counts_and_routes() {
        let t = dragonfly(2, 2, 2);
        assert_eq!(t.num_gpus(), (2 * 2 + 1) * 2 * 2); // g*a*p = 20
        assert_matches_dijkstra(&t);
    }

    #[test]
    fn dragonfly_minimal_degenerate() {
        let t = dragonfly(1, 1, 1);
        assert_eq!(t.num_gpus(), 2);
        assert_matches_dijkstra(&t);
    }

    #[test]
    fn pod_counts_and_routes() {
        let t = multi_plane_pod(3, 4, 2);
        assert_eq!(t.num_gpus(), 12);
        assert_matches_dijkstra(&t);
        // node grouping: gpus_per_node members per node
        let g = node_groups(&t, 12);
        assert_eq!(g.len(), 3);
        assert!(g.iter().all(|m| m.len() == 4));
        // intra-node pairs ride the NVLink mesh directly
        assert!(t.nvlink_direct(0, 3));
        let p = t.route_gpus(0, 3).unwrap();
        assert_eq!(p.hops(), 1);
        // rails split inter-node traffic: gpu0 (rail 0) and gpu1
        // (rail 1) reach node 1 over disjoint planes
        let p0 = t.route_gpus(0, 4).unwrap();
        let p1 = t.route_gpus(1, 4).unwrap();
        let ib0: Vec<_> =
            p0.links.iter().filter(|&&l| t.links[l].class == LinkClass::InfinibandFdr).collect();
        let ib1: Vec<_> =
            p1.links.iter().filter(|&&l| t.links[l].class == LinkClass::InfinibandFdr).collect();
        assert!(ib0.iter().all(|l| !ib1.contains(l)), "rails share an IB link");
    }

    #[test]
    fn dead_structural_link_falls_back_to_dijkstra() {
        let t = fat_tree(4);
        let p = t.route_gpus(0, 15).unwrap();
        // kill the first switch-level hop of the structural route
        let dead = *p.links.iter().find(|&&l| {
            t.links[l].class == LinkClass::InfinibandFdr
                && t.devices[t.links[l].a].node == usize::MAX
        }).unwrap();
        let masked = t.with_links_down(&[dead]);
        let rerouted = masked.route_gpus(0, 15).expect("fat-tree has path diversity");
        assert!(rerouted.links.iter().all(|&l| masked.link_alive(l)));
        assert_valid_path(&masked, &rerouted);
    }

    #[test]
    fn remap_keeps_structural_routing_consistent() {
        let t = multi_plane_pod(2, 2, 1);
        let perm = vec![3, 2, 1, 0];
        let t2 = t.remap_gpus(&perm);
        // new rank 0 is old rank 3 (node 1); new rank 3 is old rank 0
        let p = t2.route_gpus(0, 3).unwrap();
        assert_eq!(p.devices[0], t.gpu(3));
        assert_eq!(*p.devices.last().unwrap(), t.gpu(0));
        assert_valid_path(&t2, &p);
    }

    #[test]
    fn gpu_links_entries_are_incident() {
        for t in [fat_tree(4), dragonfly(2, 1, 1), multi_plane_pod(2, 3, 2)] {
            for r in 0..t.num_gpus() {
                for l in t.gpu_links(r) {
                    let link = &t.links[l];
                    assert!(link.a == t.gpu(r) || link.b == t.gpu(r), "{} rank {r}", t.name);
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "even")]
    fn odd_arity_rejected() {
        let _ = fat_tree(5);
    }
}
