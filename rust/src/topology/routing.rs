//! Path search over the device graph.
//!
//! Two searches are provided:
//! - [`widest_shortest_path`]: maximize bottleneck bandwidth, tie-break on
//!   hop count then total latency. This is the "sensible driver" route a
//!   GPU-to-GPU copy takes (NVLink if direct, else PCIe/QPI/IB).
//! - [`nvlink_path`]: BFS restricted to NVLink-class links — the search
//!   NCCL's topology detection performs. It finds multi-hop NVLink routes
//!   (e.g. DGX-1 GPU 0 -> GPU 5 in two hops) that GPUDirect-P2P-gated
//!   libraries cannot use (paper §II-B).

use super::{DeviceId, LinkId, Topology};

/// A route: the device sequence and the links traversed (links.len() ==
/// devices.len() - 1).
#[derive(Clone, Debug, PartialEq)]
pub struct Path {
    /// Devices visited, endpoints included.
    pub devices: Vec<DeviceId>,
    /// Links traversed between consecutive devices.
    pub links: Vec<LinkId>,
}

impl Path {
    /// Number of links traversed.
    pub fn hops(&self) -> usize {
        self.links.len()
    }
}

/// Widest-shortest path: Dijkstra on (−bottleneck_bw, hops, latency).
pub fn widest_shortest_path(topo: &Topology, from: DeviceId, to: DeviceId) -> Option<Path> {
    if from == to {
        return Some(Path { devices: vec![from], links: vec![] });
    }
    let n = topo.devices.len();
    // best[(bw, hops, lat)] per device; we maximize bw then minimize hops/lat.
    #[derive(Clone, Copy, PartialEq)]
    struct Cost {
        bw: f64,
        hops: usize,
        lat: f64,
    }
    impl Cost {
        fn better_than(&self, o: &Cost) -> bool {
            if self.bw != o.bw {
                return self.bw > o.bw;
            }
            if self.hops != o.hops {
                return self.hops < o.hops;
            }
            self.lat < o.lat
        }
    }
    let mut best: Vec<Option<Cost>> = vec![None; n];
    let mut prev: Vec<Option<(DeviceId, LinkId)>> = vec![None; n];
    best[from] = Some(Cost { bw: f64::INFINITY, hops: 0, lat: 0.0 });
    // Simple O(V^2) scan — topologies have < 100 devices.
    let mut done = vec![false; n];
    loop {
        let mut cur: Option<DeviceId> = None;
        for d in 0..n {
            if !done[d] && best[d].is_some() {
                if let Some(c) = cur {
                    if best[d].unwrap().better_than(&best[c].unwrap()) {
                        cur = Some(d);
                    }
                } else {
                    cur = Some(d);
                }
            }
        }
        let Some(cur) = cur else { break };
        if cur == to {
            break;
        }
        done[cur] = true;
        let cost = best[cur].unwrap();
        for &(l, peer) in topo.neighbors(cur) {
            if done[peer] || !topo.link_alive(l) {
                continue;
            }
            let link = &topo.links[l];
            let cand = Cost {
                bw: cost.bw.min(link.class.bandwidth()),
                hops: cost.hops + 1,
                lat: cost.lat + link.class.latency(),
            };
            let improves = match best[peer] {
                None => true,
                Some(existing) => cand.better_than(&existing),
            };
            if improves {
                best[peer] = Some(cand);
                prev[peer] = Some((cur, l));
            }
        }
    }
    best[to]?;
    let mut devices = vec![to];
    let mut links = Vec::new();
    let mut cur = to;
    while let Some((p, l)) = prev[cur] {
        devices.push(p);
        links.push(l);
        cur = p;
    }
    devices.reverse();
    links.reverse();
    debug_assert_eq!(devices[0], from);
    Some(Path { devices, links })
}

/// Bandwidth-greedy ring ordering of GPU ranks `0..p`: starting at rank
/// 0, repeatedly append the unvisited rank whose route from the current
/// chain end has the highest bottleneck bandwidth (ties: fewer hops,
/// then lower rank). Unlike [`nvlink_path`]-based detection this uses
/// the *actual link bandwidths*, so it keeps CS-Storm's bonded-4x pairs
/// adjacent, prefers NVLink over PCIe on the DGX-1, and degrades to
/// rank order on the homogeneous cluster — the ordering the
/// topology-aware ring schedules run over (DESIGN.md §3).
pub fn bandwidth_ring(topo: &Topology, p: usize) -> Vec<usize> {
    assert!(p >= 1 && p <= topo.num_gpus());
    let ranks: Vec<usize> = (0..p).collect();
    bandwidth_ring_over(topo, &ranks)
}

/// [`bandwidth_ring`] over an arbitrary rank set (e.g. the leader set of
/// a hierarchical schedule). The chain starts at `ranks[0]`; the result
/// is a permutation of `ranks`.
pub fn bandwidth_ring_over(topo: &Topology, ranks: &[usize]) -> Vec<usize> {
    assert!(!ranks.is_empty(), "bandwidth ring needs at least one rank");
    let mut ring = vec![ranks[0]];
    let mut left: Vec<usize> = ranks[1..].to_vec();
    while !left.is_empty() {
        let cur = *ring.last().unwrap();
        let mut best_i = 0usize;
        let mut best: Option<(f64, usize, usize)> = None; // (bw, hops, rank)
        for (i, &r) in left.iter().enumerate() {
            let path = topo.route_gpus(cur, r).expect("ring ranks must be routable");
            let bw = topo.path_bandwidth(&path);
            let hops = path.hops();
            let better = match best {
                None => true,
                Some((bb, bh, br)) => {
                    bw > bb || (bw == bb && (hops < bh || (hops == bh && r < br)))
                }
            };
            if better {
                best = Some((bw, hops, r));
                best_i = i;
            }
        }
        ring.push(left.remove(best_i));
    }
    ring
}

/// BFS over NVLink-class links only (fewest NVLink hops).
pub fn nvlink_path(topo: &Topology, from: DeviceId, to: DeviceId) -> Option<Path> {
    if from == to {
        return Some(Path { devices: vec![from], links: vec![] });
    }
    let n = topo.devices.len();
    let mut prev: Vec<Option<(DeviceId, LinkId)>> = vec![None; n];
    let mut visited = vec![false; n];
    let mut queue = std::collections::VecDeque::new();
    visited[from] = true;
    queue.push_back(from);
    while let Some(cur) = queue.pop_front() {
        if cur == to {
            break;
        }
        for &(l, peer) in topo.neighbors(cur) {
            if !visited[peer] && topo.link_alive(l) && topo.links[l].class.is_nvlink() {
                visited[peer] = true;
                prev[peer] = Some((cur, l));
                queue.push_back(peer);
            }
        }
    }
    if !visited[to] {
        return None;
    }
    let mut devices = vec![to];
    let mut links = Vec::new();
    let mut cur = to;
    while let Some((p, l)) = prev[cur] {
        devices.push(p);
        links.push(l);
        cur = p;
    }
    devices.reverse();
    links.reverse();
    Some(Path { devices, links })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{DeviceKind, LinkClass};

    /// Diamond: g0 -(nvlink)- g1 -(nvlink)- g3, and g0 -(pcie)- g2 -(pcie)- g3.
    fn diamond() -> Topology {
        let mut t = Topology::new("diamond");
        let g0 = t.add_device(DeviceKind::Gpu { rank: 0 }, 0, "g0");
        let g1 = t.add_device(DeviceKind::Gpu { rank: 1 }, 0, "g1");
        let g2 = t.add_device(DeviceKind::Gpu { rank: 2 }, 0, "g2");
        let g3 = t.add_device(DeviceKind::Gpu { rank: 3 }, 0, "g3");
        t.add_link(g0, g1, LinkClass::NvLink);
        t.add_link(g1, g3, LinkClass::NvLink);
        t.add_link(g0, g2, LinkClass::PcieGen3x16);
        t.add_link(g2, g3, LinkClass::PcieGen3x16);
        t
    }

    #[test]
    fn widest_takes_two_hop_nvlink_over_two_hop_pcie() {
        let t = diamond();
        let p = t.route_gpus(0, 3).unwrap();
        assert_eq!(p.hops(), 2);
        assert!(p.links.iter().all(|&l| t.links[l].class.is_nvlink()));
    }

    #[test]
    fn nvlink_path_multi_hop() {
        let t = diamond();
        let p = t.route_nvlink_only(0, 3).unwrap();
        assert_eq!(p.hops(), 2);
        assert_eq!(p.devices, vec![t.gpu(0), t.gpu(1), t.gpu(3)]);
    }

    #[test]
    fn nvlink_path_absent_when_disconnected() {
        let mut t = Topology::new("split");
        let g0 = t.add_device(DeviceKind::Gpu { rank: 0 }, 0, "g0");
        let g1 = t.add_device(DeviceKind::Gpu { rank: 1 }, 0, "g1");
        t.add_link(g0, g1, LinkClass::PcieGen3x16);
        assert!(t.route_nvlink_only(0, 1).is_none());
        assert!(t.route_gpus(0, 1).is_some());
    }

    #[test]
    fn identity_path() {
        let t = diamond();
        let p = t.route(t.gpu(0), t.gpu(0)).unwrap();
        assert_eq!(p.hops(), 0);
        assert_eq!(p.devices, vec![t.gpu(0)]);
    }

    #[test]
    fn unreachable_is_none() {
        let mut t = Topology::new("islands");
        let g0 = t.add_device(DeviceKind::Gpu { rank: 0 }, 0, "g0");
        let _g1 = t.add_device(DeviceKind::Gpu { rank: 1 }, 1, "g1");
        let _ = g0;
        assert!(t.route_gpus(0, 1).is_none());
    }

    #[test]
    fn bandwidth_ring_is_permutation_everywhere() {
        use crate::topology::systems::SystemKind;
        for k in SystemKind::all() {
            let t = k.build();
            for p in 1..=t.num_gpus() {
                let ring = bandwidth_ring(&t, p);
                let mut sorted = ring.clone();
                sorted.sort_unstable();
                assert_eq!(sorted, (0..p).collect::<Vec<_>>(), "{} p={p}", t.name);
            }
        }
    }

    #[test]
    fn bandwidth_ring_keeps_cs_storm_pairs_adjacent() {
        let t = crate::topology::systems::cs_storm();
        let ring = bandwidth_ring(&t, 16);
        for pair in 0..8 {
            let (a, b) = (2 * pair, 2 * pair + 1);
            let pa = ring.iter().position(|&r| r == a).unwrap();
            let adj = ring[(pa + 1) % 16] == b || ring[(pa + 15) % 16] == b;
            assert!(adj, "bonded pair ({a},{b}) split in {ring:?}");
        }
    }

    #[test]
    fn bandwidth_ring_identity_on_homogeneous_cluster() {
        // all routes bottleneck on the same IB link: ties resolve to
        // rank order, so the cluster keeps the identity ring
        let t = crate::topology::systems::cluster(8);
        assert_eq!(bandwidth_ring(&t, 8), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn bandwidth_ring_prefers_nvlink_on_dgx1() {
        // every greedy chain hop on the DGX-1 should be an NVLink route
        // (18 GB/s beats any PCIe/QPI alternative)
        let t = crate::topology::systems::dgx1();
        let ring = bandwidth_ring(&t, 8);
        for w in ring.windows(2) {
            let p = t.route_gpus(w[0], w[1]).unwrap();
            assert!(
                p.links.iter().all(|&l| t.links[l].class.is_nvlink()),
                "chain hop {}->{} left NVLink: {ring:?}",
                w[0], w[1]
            );
        }
    }

    #[test]
    fn bandwidth_ring_over_subset() {
        let t = crate::topology::systems::cs_storm();
        // leader-style subset: one GPU of each of four pairs
        let ring = bandwidth_ring_over(&t, &[0, 2, 4, 6]);
        let mut sorted = ring.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 2, 4, 6]);
        assert_eq!(ring[0], 0);
    }

    #[test]
    fn dead_links_are_invisible_to_both_searches() {
        // kill the diamond's g0-g1 NVLink: the widest path detours over
        // PCIe, and the NVLink-only search loses g0 entirely
        let t = diamond();
        let nv01 = 0; // add order: g0-g1 NVLink is link 0
        let masked = t.with_links_down(&[nv01]);
        assert!(!masked.link_alive(nv01));
        assert_eq!(masked.dead_links(), vec![nv01]);
        let p = masked.route_gpus(0, 3).unwrap();
        assert!(
            p.links.iter().all(|&l| masked.link_alive(l)),
            "detour crossed a dead link: {p:?}"
        );
        assert!(p.links.iter().all(|&l| !masked.links[l].class.is_nvlink()));
        assert!(masked.route_nvlink_only(0, 3).is_none());
        assert!(!masked.nvlink_direct(0, 1));
        // the unmasked topology is untouched
        assert!(t.link_alive(nv01));
        assert!(t.route_nvlink_only(0, 3).is_some());
    }

    #[test]
    fn path_endpoints_consistent() {
        let t = diamond();
        for a in 0..4 {
            for b in 0..4 {
                let p = t.route_gpus(a, b).unwrap();
                assert_eq!(p.devices[0], t.gpu(a));
                assert_eq!(*p.devices.last().unwrap(), t.gpu(b));
                assert_eq!(p.links.len() + 1, p.devices.len());
            }
        }
    }
}
