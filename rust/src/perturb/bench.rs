//! The `bench_faults` measurement grid and its deterministic
//! `BENCH_faults.json` payload.
//!
//! As with `BENCH_workload.json`, the artifact holds **simulated**
//! metrics only (healthy/degraded times, slowdowns, robust-selector
//! verdicts) — no wall-clock fields — so a fixed seed reproduces the
//! file byte-for-byte run over run (`tests/workload_determinism.rs`
//! pins this). Wall-clock timing of the scenario fan-out is printed by
//! the bench binary but never written to the artifact.

use crate::comm::select::{AlgoSelector, RobustObjective};
use crate::comm::transport::RecoveryPolicy;
use crate::comm::{run_allgatherv, Library, Params};
use crate::topology::systems::SystemKind;
use crate::topology::Topology;
use crate::util::json::{obj, Json};

use super::recovery::recovered_allgatherv;
use super::{ensemble, perturbed_allgatherv, EnsembleCfg, Perturbation};

/// The bench grid: per paper system the canonical straggler scenario
/// (GPU 0 at half speed) on a regular 4 MB vector. Deterministic in
/// `seed` (which keys the robust-selection ensembles only — the
/// scenarios themselves are fixed).
pub fn bench_cases(seed: u64) -> Vec<(String, Topology, Vec<u64>, Vec<Perturbation>)> {
    let _ = seed;
    let mut out = Vec::new();
    for kind in SystemKind::all() {
        let topo = kind.build();
        let gpus = topo.num_gpus().min(8);
        let counts = vec![4u64 << 20; gpus];
        let perts = vec![Perturbation::straggler(0, 0.5)];
        out.push((format!("{}/straggler0x0.50", kind.name()), topo, counts, perts));
    }
    out
}

/// Simulated metrics of one bench case as a JSON object: per-library
/// healthy vs degraded times plus the p95-robust selector verdict on a
/// seeded ensemble.
fn case_doc(
    label: &str,
    topo: &Topology,
    counts: &[u64],
    perts: &[Perturbation],
    seed: u64,
) -> Json {
    let params = Params::default();
    let libs: Vec<Json> = Library::all()
        .into_iter()
        .map(|lib| {
            let healthy = run_allgatherv(lib, topo, counts);
            let degraded = perturbed_allgatherv(topo, lib, params, counts, perts);
            obj(vec![
                ("lib", Json::Str(lib.name().to_string())),
                ("healthy_s", Json::Num(healthy.time)),
                ("degraded_s", Json::Num(degraded.time)),
                ("slowdown", Json::Num(degraded.time / healthy.time)),
            ])
        })
        .collect();
    let ens = ensemble(topo, &EnsembleCfg::quick(seed));
    let sel = AlgoSelector::new(params);
    let robust = sel.select_robust(topo, counts, &ens, RobustObjective::P95);
    obj(vec![
        ("case", Json::Str(label.to_string())),
        ("gpus", Json::Num(counts.len() as f64)),
        ("libs", Json::Arr(libs)),
        (
            "robust",
            obj(vec![
                ("objective", Json::Str(RobustObjective::P95.name().to_string())),
                ("winner", Json::Str(robust.candidate.label())),
                ("objective_s", Json::Num(robust.objective)),
                ("mean_s", Json::Num(robust.mean)),
                ("p95_s", Json::Num(robust.p95)),
                ("healthy_s", Json::Num(robust.healthy)),
                ("scenarios", Json::Num(robust.scenarios as f64)),
            ]),
        ),
    ])
}

/// Time-windowed fault ensemble for the delta-simulation grid
/// (DESIGN.md §16): fault starts uniform over **eight baseline
/// makespans** — faults are not synchronized to the collective, so
/// most arrive mid-run or after it — with sub-makespan windows and a
/// quarter of scenarios carrying a transient hard outage. The regime
/// the warm-start tier targets: a healthy prefix worth skipping.
pub fn delta_ensemble(topo: &Topology, makespan: f64, seed: u64) -> Vec<Vec<Perturbation>> {
    let cfg = EnsembleCfg {
        scenarios: 32,
        seed,
        degraded_links: 1,
        straggler_prob: 0.5,
        severity: (0.3, 0.8),
        window: 8.0 * makespan,
        duration: (0.2 * makespan, 0.6 * makespan),
        outage_prob: 0.25,
        outage_duration: (0.05 * makespan, 0.2 * makespan),
    };
    ensemble(topo, &cfg)
}

/// Deterministic delta-simulation metrics of one case (DESIGN.md §16):
/// per library, the unperturbed baseline is recorded once and every
/// scenario of [`delta_ensemble`] runs both warm and cold. The doc
/// reports the replay-tier mix and the cold/warm **work-unit** ratio
/// ([`crate::sim::replay::work_units`]) — simulated work, not
/// wall-clock, so the subtree reproduces byte-for-byte from its seed
/// (`tests/workload_determinism.rs` pins it). Warm-vs-cold makespan
/// agreement to 1e-9 is asserted on every scenario as a tripwire.
fn delta_case_doc(label: &str, topo: &Topology, counts: &[u64], seed: u64) -> Json {
    use crate::sim::replay::work_units;
    let params = Params::default();
    let mut warm_units = 0u64;
    let mut cold_units = 0u64;
    let (mut n_identical, mut n_cold, mut n_tail, mut n_warm) = (0u64, 0u64, 0u64, 0u64);
    let mut max_rel = 0.0f64;
    let mut scenarios = 0u64;
    for lib in Library::all() {
        let mut sim = crate::sim::Sim::new(topo);
        let done = crate::comm::compose_allgatherv(&mut sim, lib, params, counts, None);
        let delta = super::DeltaSim::record(sim);
        let ens = delta_ensemble(topo, delta.baseline().makespan, seed);
        for perts in &ens {
            let mode = delta.mode(perts);
            let (rw, ow) = delta.run(perts);
            let (rc, oc) = delta.run_cold(perts);
            assert!(
                ow.is_completed() && oc.is_completed(),
                "{label}/{}: transient-fault scenario did not complete",
                lib.name()
            );
            match mode {
                "identical" => n_identical += 1,
                "cold" => n_cold += 1,
                "tail" => n_tail += 1,
                _ => n_warm += 1,
            }
            // the two pure-replay tiers execute zero live events; the
            // stats they return are the baseline's and must not be
            // billed as replay cost
            if !matches!(mode, "identical" | "tail") {
                warm_units += work_units(&rw.stats);
            }
            cold_units += work_units(&rc.stats);
            let (tw, tc) = (rw.finish(done), rc.finish(done));
            let rel = (tw - tc).abs() / tc.abs().max(1e-300);
            assert!(rel < 1e-9, "{label}/{}: warm {tw} vs cold {tc}", lib.name());
            max_rel = max_rel.max(rel);
            scenarios += 1;
        }
    }
    obj(vec![
        ("case", Json::Str(label.to_string())),
        ("scenarios", Json::Num(scenarios as f64)),
        ("identical", Json::Num(n_identical as f64)),
        ("cold", Json::Num(n_cold as f64)),
        ("tail", Json::Num(n_tail as f64)),
        ("warm", Json::Num(n_warm as f64)),
        ("warm_work_units", Json::Num(warm_units as f64)),
        ("cold_work_units", Json::Num(cold_units as f64)),
        ("work_ratio", Json::Num(cold_units as f64 / warm_units.max(1) as f64)),
        ("max_rel_err", Json::Num(max_rel)),
    ])
}

/// Simulated metrics of one hard-outage case: the canonical
/// link-on-route(0,1) outage per system, transient and permanent, run
/// through the timeout–retry–reroute–shrink driver
/// ([`crate::perturb::recovery`]) for every library. Deterministic by
/// construction — the scenarios are fixed, the driver draws nothing.
fn outage_case_doc(kind: SystemKind) -> Json {
    let params = Params::default();
    let policy = RecoveryPolicy::default_policy();
    let topo = kind.build();
    let gpus = topo.num_gpus().min(8);
    let counts = vec![4u64 << 20; gpus];
    let link = topo
        .route_gpus(0, 1)
        .expect("paper systems route any GPU pair")
        .links[0];
    let h_max = Library::all()
        .into_iter()
        .map(|l| run_allgatherv(l, &topo, &counts).time)
        .fold(0.0f64, f64::max);
    let scenarios = [
        (
            "transient",
            Perturbation::link_down(link).during(h_max * 0.25, h_max * 0.5),
        ),
        ("permanent", Perturbation::link_down(link)),
    ];
    let mut rows = Vec::new();
    for (label, pert) in &scenarios {
        for lib in Library::all() {
            let rec =
                recovered_allgatherv(&topo, lib, params, &counts, std::slice::from_ref(pert), &policy);
            rows.push(obj(vec![
                ("scenario", Json::Str(label.to_string())),
                ("lib", Json::Str(lib.name().to_string())),
                ("strategy", Json::Str(rec.strategy.label())),
                (
                    "recovered_s",
                    rec.time().map(Json::Num).unwrap_or(Json::Null),
                ),
                ("recovery_latency_s", Json::Num(rec.recovery_latency)),
                ("survivors", Json::Num(rec.survivors as f64)),
            ]));
        }
    }
    obj(vec![
        ("case", Json::Str(format!("{}/link{link}-outage", kind.name()))),
        ("gpus", Json::Num(gpus as f64)),
        ("rows", Json::Arr(rows)),
    ])
}

/// The full deterministic `BENCH_faults.json` document. Cases fan out
/// over the bounded worker pool ([`crate::util::pool`]); results come
/// back in case order, so the render is byte-stable.
pub fn bench_doc(seed: u64) -> Json {
    let cases = bench_cases(seed);
    let jobs: Vec<_> = cases
        .iter()
        .map(|(label, topo, counts, perts)| {
            move || case_doc(label, topo, counts, perts, seed)
        })
        .collect();
    let docs = crate::util::pool::parallel_map(jobs);
    let outage_jobs: Vec<_> = SystemKind::all()
        .into_iter()
        .map(|kind| move || outage_case_doc(kind))
        .collect();
    let outage_docs = crate::util::pool::parallel_map(outage_jobs);
    let delta_jobs: Vec<_> = cases
        .iter()
        .map(|(label, topo, counts, _)| move || delta_case_doc(label, topo, counts, seed))
        .collect();
    let delta_docs = crate::util::pool::parallel_map(delta_jobs);
    obj(vec![
        ("bench", Json::Str("bench_faults".to_string())),
        ("seed", Json::Num(seed as f64)),
        ("cases", Json::Arr(docs)),
        ("outage_cases", Json::Arr(outage_docs)),
        ("delta_sim", Json::Arr(delta_docs)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_cover_all_systems() {
        let cases = bench_cases(42);
        assert_eq!(cases.len(), 3);
        for kind in SystemKind::all() {
            assert!(cases.iter().any(|(l, ..)| l.starts_with(kind.name())));
        }
    }

    #[test]
    fn doc_reports_degradation_and_robust_verdicts() {
        let doc = bench_doc(7);
        let cases = doc.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 3);
        for c in cases {
            let libs = c.get("libs").unwrap().as_arr().unwrap();
            assert_eq!(libs.len(), 3);
            for l in libs {
                let slow = l.get("slowdown").unwrap().as_f64().unwrap();
                assert!(
                    slow >= 1.0 - 1e-9,
                    "straggler sped {} up: {slow}",
                    l.get("lib").unwrap().as_str().unwrap()
                );
            }
            let robust = c.get("robust").unwrap();
            assert!(robust.get("winner").unwrap().as_str().unwrap().contains('/'));
            let p95 = robust.get("p95_s").unwrap().as_f64().unwrap();
            let mean = robust.get("mean_s").unwrap().as_f64().unwrap();
            assert!(p95 >= mean - 1e-12, "p95 {p95} below mean {mean}");
            assert!(c.get("mean_s").is_none(), "wall-clock field leaked into the artifact");
        }
        // the hard-outage grid: every (system, scenario, library) cell
        // completes — natively, by watchdog retry, by reroute, or by
        // shrinking past a GPU whose only link died
        // the delta-sim grid: every scenario agreed warm-vs-cold (the
        // doc builder asserts 1e-9 per scenario), the tier counts add
        // up, and replaying never costs more work than cold re-runs
        let deltas = doc.get("delta_sim").unwrap().as_arr().unwrap();
        assert_eq!(deltas.len(), 3);
        for d in deltas {
            let n = d.get("scenarios").unwrap().as_f64().unwrap();
            assert_eq!(n, 96.0, "3 libraries x 32 scenarios");
            let tiers: f64 = ["identical", "cold", "tail", "warm"]
                .iter()
                .map(|k| d.get(k).unwrap().as_f64().unwrap())
                .sum();
            assert_eq!(tiers, n, "replay tiers must partition the scenarios");
            let warm = d.get("warm_work_units").unwrap().as_f64().unwrap();
            let cold = d.get("cold_work_units").unwrap().as_f64().unwrap();
            assert!(warm <= cold, "replay cost {warm} exceeds cold cost {cold}");
            let ratio = d.get("work_ratio").unwrap().as_f64().unwrap();
            assert!(ratio >= 1.0, "delta tier slower than cold: {ratio}");
            assert!(
                d.get("max_rel_err").unwrap().as_f64().unwrap() < 1e-9,
                "warm-vs-cold tolerance breached"
            );
        }
        let outages = doc.get("outage_cases").unwrap().as_arr().unwrap();
        assert_eq!(outages.len(), 3);
        for c in outages {
            let rows = c.get("rows").unwrap().as_arr().unwrap();
            assert_eq!(rows.len(), 6, "2 scenarios x 3 libraries");
            for r in rows {
                let strategy = r.get("strategy").unwrap().as_str().unwrap();
                assert_ne!(strategy, "ABORT", "unrecovered outage cell: {r:?}");
                let t = r.get("recovered_s").unwrap().as_f64().unwrap();
                assert!(t.is_finite() && t > 0.0);
            }
        }
    }
}
