//! The `bench_faults` measurement grid and its deterministic
//! `BENCH_faults.json` payload.
//!
//! As with `BENCH_workload.json`, the artifact holds **simulated**
//! metrics only (healthy/degraded times, slowdowns, robust-selector
//! verdicts) — no wall-clock fields — so a fixed seed reproduces the
//! file byte-for-byte run over run (`tests/workload_determinism.rs`
//! pins this). Wall-clock timing of the scenario fan-out is printed by
//! the bench binary but never written to the artifact.

use crate::comm::select::{AlgoSelector, RobustObjective};
use crate::comm::transport::RecoveryPolicy;
use crate::comm::{run_allgatherv, Library, Params};
use crate::topology::systems::SystemKind;
use crate::topology::Topology;
use crate::util::json::{obj, Json};

use super::recovery::recovered_allgatherv;
use super::{ensemble, perturbed_allgatherv, EnsembleCfg, Perturbation};

/// The bench grid: per paper system the canonical straggler scenario
/// (GPU 0 at half speed) on a regular 4 MB vector. Deterministic in
/// `seed` (which keys the robust-selection ensembles only — the
/// scenarios themselves are fixed).
pub fn bench_cases(seed: u64) -> Vec<(String, Topology, Vec<u64>, Vec<Perturbation>)> {
    let _ = seed;
    let mut out = Vec::new();
    for kind in SystemKind::all() {
        let topo = kind.build();
        let gpus = topo.num_gpus().min(8);
        let counts = vec![4u64 << 20; gpus];
        let perts = vec![Perturbation::straggler(0, 0.5)];
        out.push((format!("{}/straggler0x0.50", kind.name()), topo, counts, perts));
    }
    out
}

/// Simulated metrics of one bench case as a JSON object: per-library
/// healthy vs degraded times plus the p95-robust selector verdict on a
/// seeded ensemble.
fn case_doc(
    label: &str,
    topo: &Topology,
    counts: &[u64],
    perts: &[Perturbation],
    seed: u64,
) -> Json {
    let params = Params::default();
    let libs: Vec<Json> = Library::all()
        .into_iter()
        .map(|lib| {
            let healthy = run_allgatherv(lib, topo, counts);
            let degraded = perturbed_allgatherv(topo, lib, params, counts, perts);
            obj(vec![
                ("lib", Json::Str(lib.name().to_string())),
                ("healthy_s", Json::Num(healthy.time)),
                ("degraded_s", Json::Num(degraded.time)),
                ("slowdown", Json::Num(degraded.time / healthy.time)),
            ])
        })
        .collect();
    let ens = ensemble(topo, &EnsembleCfg::quick(seed));
    let sel = AlgoSelector::new(params);
    let robust = sel.select_robust(topo, counts, &ens, RobustObjective::P95);
    obj(vec![
        ("case", Json::Str(label.to_string())),
        ("gpus", Json::Num(counts.len() as f64)),
        ("libs", Json::Arr(libs)),
        (
            "robust",
            obj(vec![
                ("objective", Json::Str(RobustObjective::P95.name().to_string())),
                ("winner", Json::Str(robust.candidate.label())),
                ("objective_s", Json::Num(robust.objective)),
                ("mean_s", Json::Num(robust.mean)),
                ("p95_s", Json::Num(robust.p95)),
                ("healthy_s", Json::Num(robust.healthy)),
                ("scenarios", Json::Num(robust.scenarios as f64)),
            ]),
        ),
    ])
}

/// Simulated metrics of one hard-outage case: the canonical
/// link-on-route(0,1) outage per system, transient and permanent, run
/// through the timeout–retry–reroute–shrink driver
/// ([`crate::perturb::recovery`]) for every library. Deterministic by
/// construction — the scenarios are fixed, the driver draws nothing.
fn outage_case_doc(kind: SystemKind) -> Json {
    let params = Params::default();
    let policy = RecoveryPolicy::default_policy();
    let topo = kind.build();
    let gpus = topo.num_gpus().min(8);
    let counts = vec![4u64 << 20; gpus];
    let link = topo
        .route_gpus(0, 1)
        .expect("paper systems route any GPU pair")
        .links[0];
    let h_max = Library::all()
        .into_iter()
        .map(|l| run_allgatherv(l, &topo, &counts).time)
        .fold(0.0f64, f64::max);
    let scenarios = [
        (
            "transient",
            Perturbation::link_down(link).during(h_max * 0.25, h_max * 0.5),
        ),
        ("permanent", Perturbation::link_down(link)),
    ];
    let mut rows = Vec::new();
    for (label, pert) in &scenarios {
        for lib in Library::all() {
            let rec =
                recovered_allgatherv(&topo, lib, params, &counts, std::slice::from_ref(pert), &policy);
            rows.push(obj(vec![
                ("scenario", Json::Str(label.to_string())),
                ("lib", Json::Str(lib.name().to_string())),
                ("strategy", Json::Str(rec.strategy.label())),
                (
                    "recovered_s",
                    rec.time().map(Json::Num).unwrap_or(Json::Null),
                ),
                ("recovery_latency_s", Json::Num(rec.recovery_latency)),
                ("survivors", Json::Num(rec.survivors as f64)),
            ]));
        }
    }
    obj(vec![
        ("case", Json::Str(format!("{}/link{link}-outage", kind.name()))),
        ("gpus", Json::Num(gpus as f64)),
        ("rows", Json::Arr(rows)),
    ])
}

/// The full deterministic `BENCH_faults.json` document. Cases fan out
/// over the bounded worker pool ([`crate::util::pool`]); results come
/// back in case order, so the render is byte-stable.
pub fn bench_doc(seed: u64) -> Json {
    let cases = bench_cases(seed);
    let jobs: Vec<_> = cases
        .iter()
        .map(|(label, topo, counts, perts)| {
            move || case_doc(label, topo, counts, perts, seed)
        })
        .collect();
    let docs = crate::util::pool::parallel_map(jobs);
    let outage_jobs: Vec<_> = SystemKind::all()
        .into_iter()
        .map(|kind| move || outage_case_doc(kind))
        .collect();
    let outage_docs = crate::util::pool::parallel_map(outage_jobs);
    obj(vec![
        ("bench", Json::Str("bench_faults".to_string())),
        ("seed", Json::Num(seed as f64)),
        ("cases", Json::Arr(docs)),
        ("outage_cases", Json::Arr(outage_docs)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_cover_all_systems() {
        let cases = bench_cases(42);
        assert_eq!(cases.len(), 3);
        for kind in SystemKind::all() {
            assert!(cases.iter().any(|(l, ..)| l.starts_with(kind.name())));
        }
    }

    #[test]
    fn doc_reports_degradation_and_robust_verdicts() {
        let doc = bench_doc(7);
        let cases = doc.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 3);
        for c in cases {
            let libs = c.get("libs").unwrap().as_arr().unwrap();
            assert_eq!(libs.len(), 3);
            for l in libs {
                let slow = l.get("slowdown").unwrap().as_f64().unwrap();
                assert!(
                    slow >= 1.0 - 1e-9,
                    "straggler sped {} up: {slow}",
                    l.get("lib").unwrap().as_str().unwrap()
                );
            }
            let robust = c.get("robust").unwrap();
            assert!(robust.get("winner").unwrap().as_str().unwrap().contains('/'));
            let p95 = robust.get("p95_s").unwrap().as_f64().unwrap();
            let mean = robust.get("mean_s").unwrap().as_f64().unwrap();
            assert!(p95 >= mean - 1e-12, "p95 {p95} below mean {mean}");
            assert!(c.get("mean_s").is_none(), "wall-clock field leaked into the artifact");
        }
        // the hard-outage grid: every (system, scenario, library) cell
        // completes — natively, by watchdog retry, by reroute, or by
        // shrinking past a GPU whose only link died
        let outages = doc.get("outage_cases").unwrap().as_arr().unwrap();
        assert_eq!(outages.len(), 3);
        for c in outages {
            let rows = c.get("rows").unwrap().as_arr().unwrap();
            assert_eq!(rows.len(), 6, "2 scenarios x 3 libraries");
            for r in rows {
                let strategy = r.get("strategy").unwrap().as_str().unwrap();
                assert_ne!(strategy, "ABORT", "unrecovered outage cell: {r:?}");
                let t = r.get("recovered_s").unwrap().as_f64().unwrap();
                assert!(t.is_finite() && t > 0.0);
            }
        }
    }
}
