//! Hard-fault recovery driver (DESIGN.md §14): timeout -> bounded
//! retries -> schedule repair, over the stall-diagnosing engines.
//!
//! The model is NCCL-style **abort-and-restart**: a collective that
//! stalls (every surviving flow frozen on zero-capacity links,
//! [`crate::sim::SimOutcome::Stalled`]) is torn down and re-issued from
//! scratch, never patched mid-flight. Each re-issue is a fresh gated
//! composition at an absolute restart instant — the same compose entry
//! points the workload engine gates arrivals through — against the
//! *same absolute fault windows*, so a transient outage that has closed
//! by the restart lets the retry complete, while a permanent one fails
//! every retry and escalates.
//!
//! Detection has two triggers. A **stall**
//! ([`crate::sim::SimOutcome::Stalled`]) can only come from a
//! *permanent* fault: a finite outage window always leaves its revival
//! capacity step pending, so the engine freezes the affected flows and
//! completes once the window closes rather than stalling. That native
//! ride-out is where the **watchdog** fires instead: a run that
//! completed, but that an overlapping outage window delayed past
//! `pristine time + timeout`, is treated as watchdog-aborted at that
//! deadline and re-issued — NCCL's per-op timeout semantics. Soft
//! degradations (scales, floors, stragglers) never trip the watchdog,
//! however slow: recovery stays outage-only, and soft-fault results
//! remain bit-identical to [`super::perturbed_allgatherv`]. The
//! strategy ladder:
//!
//! 1. **Retry** (up to [`RecoveryPolicy::max_retries`]): restart at
//!    `deadline + backoff(k)`; a re-issue whose own latency fits the
//!    per-op budget wins — transient outages recover here. If every
//!    retry busts the budget, the natively-completed result stands
//!    (strategy [`RecoveryStrategy::None`]): a slow completion beats a
//!    restart loop.
//! 2. **Reroute**: mask every culprit link dead
//!    ([`Topology::with_links_down`]) and recompose — the library's own
//!    routing/P2P detection then detours around the dead lanes. Only
//!    attempted when the masked fabric is still
//!    [`Topology::serviceable`]; wins against permanent link outages.
//! 3. **Shrink**: when a rank itself is gone (permanent
//!    [`Perturbation::GpuDown`], or every incident link dead), complete
//!    on the survivors — counts restricted to live ranks, GPU registry
//!    remapped so survivors are ranks `0..p'`
//!    ([`Topology::remap_gpus`]), delivery semantics re-checked against
//!    the shrunk membership by the conformance harness.
//! 4. **Abort**: nothing applies; the diagnosed stall is reported.
//!
//! The correctness spine carries over from the zero-perturbation
//! oracle: attempt 0 *is* the [`super::perturbed_allgatherv`] path, so
//! a run that never stalls returns results bit-identical to recovery
//! disabled, on both engine cores (`tests/faults_differential.rs`).

use crate::comm::select::{compose as compose_candidate, Candidate};
use crate::comm::transport::RecoveryPolicy;
use crate::comm::{compose_allgatherv, CommResult, Library, Params};
use crate::sim::{Sim, SimOutcome, TaskId};
use crate::topology::{LinkId, Topology};

use super::{apply, Perturbation};

/// How a collective ultimately completed (or failed).
#[derive(Clone, Debug, PartialEq)]
pub enum RecoveryStrategy {
    /// Completed on the first attempt; recovery never triggered.
    None,
    /// A re-issue succeeded after `attempts` retries (transient fault).
    Retry {
        /// Retries consumed, counting the successful one.
        attempts: usize,
    },
    /// Completed on the fabric with `masked_links` routed around.
    Reroute {
        /// Links masked dead for the repair composition.
        masked_links: Vec<LinkId>,
    },
    /// Completed on the surviving ranks only.
    Shrink {
        /// Ranks excluded from the shrunk communicator.
        dead_ranks: Vec<usize>,
        /// Links masked dead for the repair composition.
        masked_links: Vec<LinkId>,
    },
    /// Unrecoverable: every strategy failed or recovery was disabled.
    Abort,
}

impl RecoveryStrategy {
    /// Short report label ("clean", "retry x2", "reroute(3 links)",
    /// "shrink(-1 rank)", "ABORT").
    pub fn label(&self) -> String {
        match self {
            RecoveryStrategy::None => "clean".to_string(),
            RecoveryStrategy::Retry { attempts } => format!("retry x{attempts}"),
            RecoveryStrategy::Reroute { masked_links } => {
                format!("reroute({} links)", masked_links.len())
            }
            RecoveryStrategy::Shrink { dead_ranks, .. } => {
                format!("shrink(-{} ranks)", dead_ranks.len())
            }
            RecoveryStrategy::Abort => "ABORT".to_string(),
        }
    }
}

/// Outcome of a recovery-supervised collective.
#[derive(Clone, Debug)]
pub struct Recovered {
    /// The completed run (`None` iff aborted). On the clean path this
    /// is bit-identical to the recovery-free perturbed run.
    pub result: Option<CommResult>,
    /// Which strategy completed the op.
    pub strategy: RecoveryStrategy,
    /// First stall instant, if the op ever stalled.
    pub stall_time: Option<f64>,
    /// Completion time minus first stall (0.0 on the clean path) — the
    /// cost the fault added end-to-end, detection and backoff included.
    pub recovery_latency: f64,
    /// Ranks the completed collective actually served.
    pub survivors: usize,
}

impl Recovered {
    /// Did the op complete (on full or shrunk membership)?
    pub fn completed(&self) -> bool {
        self.result.is_some()
    }

    /// Completion time, if any.
    pub fn time(&self) -> Option<f64> {
        self.result.map(|r| r.time)
    }

    fn abort(stall: f64) -> Recovered {
        Recovered {
            result: None,
            strategy: RecoveryStrategy::Abort,
            stall_time: Some(stall),
            recovery_latency: 0.0,
            survivors: 0,
        }
    }
}

/// Rank-addressed perturbations lowered to their per-link form:
/// `Straggler` becomes one `LinkScale` per incident link, `GpuDown` one
/// `LinkDown` per incident link. Link ids survive
/// [`Topology::remap_gpus`] (ranks do not), so the lowered set pins the
/// *physical* fault windows for shrunk-membership repair runs.
pub fn lower_to_links(topo: &Topology, perts: &[Perturbation]) -> Vec<Perturbation> {
    let mut out = Vec::with_capacity(perts.len());
    for p in perts {
        match *p {
            Perturbation::Straggler { rank, factor, start, duration } => {
                for link in topo.gpu_links(rank) {
                    out.push(Perturbation::LinkScale { link, factor, start, duration });
                }
            }
            Perturbation::GpuDown { rank, start, duration } => {
                for link in topo.gpu_links(rank) {
                    out.push(Perturbation::LinkDown { link, start, duration });
                }
            }
            other => out.push(other),
        }
    }
    out
}

/// Ranks dead for good at/after `stall`: a permanent
/// [`Perturbation::GpuDown`] covering the stall instant, with the
/// window open-ended.
fn permanently_down_ranks(perts: &[Perturbation], p: usize, stall: f64) -> Vec<usize> {
    let mut out: Vec<usize> = perts
        .iter()
        .filter_map(|q| match *q {
            Perturbation::GpuDown { rank, start, duration }
                if rank < p && start <= stall && duration.is_infinite() =>
            {
                Some(rank)
            }
            _ => None,
        })
        .collect();
    out.sort_unstable();
    out.dedup();
    out
}

/// Supervise one collective under a recovery policy. `compose` builds
/// the op into a fresh `Sim` behind an optional gate and returns its
/// completion task (`None` = the op is inapplicable on that fabric —
/// then `recover_with` returns `None` too, exactly as
/// [`crate::comm::select::simulate`] does).
///
/// Attempt 0 is the exact [`super::perturbed_allgatherv`] shape (no
/// gate, same compose, same `apply`), so a run that completes without
/// stalling is bit-identical to the recovery-free path.
pub fn recover_with<F>(
    topo: &Topology,
    counts: &[u64],
    perts: &[Perturbation],
    policy: &RecoveryPolicy,
    compose: F,
) -> Option<Recovered>
where
    F: for<'t> Fn(&mut Sim<'t>, &[u64], Option<TaskId>) -> Option<TaskId>,
{
    recover_with_warm(topo, counts, perts, policy, compose, None)
}

/// [`recover_with`] with the attempt-0 and watchdog-budget runs served
/// by a pre-recorded delta-simulation baseline instead of cold
/// simulations: the outage-aware selector records one
/// [`crate::perturb::DeltaSim`] per candidate and replays every
/// scenario of the ensemble against it (DESIGN.md §16). Only the
/// ungated attempt-0 shape can warm-start — gated retries, rerouted
/// and shrunk repair runs compose a *different* DAG (gate task, masked
/// fabric, remapped ranks) and stay on the cold path. `warm` carries
/// the baseline and the completion task of its composition.
pub(crate) fn recover_with_warm<F>(
    topo: &Topology,
    counts: &[u64],
    perts: &[Perturbation],
    policy: &RecoveryPolicy,
    compose: F,
    warm: Option<(&crate::perturb::DeltaSim<'_>, TaskId)>,
) -> Option<Recovered>
where
    F: for<'t> Fn(&mut Sim<'t>, &[u64], Option<TaskId>) -> Option<TaskId>,
{
    let p = counts.len();
    let attempt = |t: &Topology,
                   cv: &[u64],
                   ps: &[Perturbation],
                   at: f64|
     -> Option<(CommResult, SimOutcome)> {
        let mut sim = Sim::new(t);
        let gate = if at > 0.0 { Some(sim.delay(at, &[])) } else { None };
        let done = compose(&mut sim, cv, gate)?;
        apply(&mut sim, ps);
        let (res, outcome) = sim.run_outcome();
        Some((CommResult { time: res.finish(done), flows: res.flows }, outcome))
    };

    let replay = |d: &crate::perturb::DeltaSim<'_>,
                  done: TaskId,
                  ps: &[Perturbation]|
     -> (CommResult, SimOutcome) {
        let (res, outcome) = d.run(ps);
        (CommResult { time: res.finish(done), flows: res.flows }, outcome)
    };
    let (res0, out0) = match warm {
        Some((d, done)) => replay(d, done, perts),
        None => attempt(topo, counts, perts, 0.0)?,
    };
    let SimOutcome::Stalled { time: first_stall, culprit_links, .. } = out0 else {
        // Completed natively. Watchdog check (module docs): did an
        // overlapping outage window freeze the op past its per-op
        // deadline? Soft degradations never reach this block.
        let clean = Recovered {
            result: Some(res0),
            strategy: RecoveryStrategy::None,
            stall_time: None,
            recovery_latency: 0.0,
            survivors: p,
        };
        let outage_overlap = perts.iter().any(|q| {
            matches!(q, Perturbation::LinkDown { .. } | Perturbation::GpuDown { .. }) && {
                let (start, duration) = q.window();
                start < res0.time && duration > 0.0
            }
        });
        if !policy.enabled() || !outage_overlap {
            return Some(clean);
        }
        // the per-op budget: pristine-fabric time plus the timeout
        // (same compose, no perturbations — cheap and deterministic;
        // with a baseline on hand it is literally the recorded run)
        let base = match warm {
            Some((d, done)) => replay(d, done, &[]).0,
            None => attempt(topo, counts, &[], 0.0)?.0,
        };
        let budget = base.time + policy.timeout;
        if res0.time <= budget {
            return Some(clean);
        }
        let mut now = budget; // the watchdog-abort instant
        for k in 0..policy.max_retries {
            now += policy.backoff(k);
            let (res, outcome) = attempt(topo, counts, perts, now)?;
            if !outcome.is_completed() {
                break; // a later window is permanent: keep the native result
            }
            if res.time - now <= budget {
                return Some(Recovered {
                    result: Some(res),
                    strategy: RecoveryStrategy::Retry { attempts: k + 1 },
                    stall_time: Some(budget),
                    recovery_latency: res.time - budget,
                    survivors: p,
                });
            }
        }
        return Some(clean);
    };
    if !policy.enabled() {
        return Some(Recovered::abort(first_stall));
    }

    let mut dead: Vec<LinkId> = culprit_links;
    let mut now = first_stall + policy.timeout;

    // 1. bounded exponential-backoff retries (beats transient outages)
    for k in 0..policy.max_retries {
        now += policy.backoff(k);
        let (res, outcome) = attempt(topo, counts, perts, now)?;
        match outcome {
            SimOutcome::Completed { .. } => {
                return Some(Recovered {
                    result: Some(res),
                    strategy: RecoveryStrategy::Retry { attempts: k + 1 },
                    stall_time: Some(first_stall),
                    recovery_latency: res.time - first_stall,
                    survivors: p,
                });
            }
            SimOutcome::Stalled { time, culprit_links, .. } => {
                for l in culprit_links {
                    if !dead.contains(&l) {
                        dead.push(l);
                    }
                }
                now = time + policy.timeout;
            }
        }
    }
    dead.sort_unstable();

    // 2. reroute: recompose on the fabric with the culprits masked dead
    let masked = topo.with_links_down(&dead);
    if masked.serviceable(p) {
        now += policy.backoff(policy.max_retries);
        if let Some((res, outcome)) = attempt(&masked, counts, perts, now) {
            match outcome {
                SimOutcome::Completed { .. } => {
                    return Some(Recovered {
                        result: Some(res),
                        strategy: RecoveryStrategy::Reroute { masked_links: dead },
                        stall_time: Some(first_stall),
                        recovery_latency: res.time - first_stall,
                        survivors: p,
                    });
                }
                SimOutcome::Stalled { time, culprit_links, .. } => {
                    for l in culprit_links {
                        if !dead.contains(&l) {
                            dead.push(l);
                        }
                    }
                    dead.sort_unstable();
                    now = time + policy.timeout;
                }
            }
        }
    }

    // 3. communicator shrink: complete on the survivors
    let masked = topo.with_links_down(&dead);
    let gone_by_pert = permanently_down_ranks(perts, p, first_stall);
    let survivors: Vec<usize> = (0..p)
        .filter(|&r| {
            !gone_by_pert.contains(&r)
                && masked.gpu_links(r).iter().any(|&l| masked.link_alive(l))
                && masked.try_host_cpu(masked.gpu(r)).is_some()
        })
        .collect();
    if survivors.len() >= 2 && survivors.len() < p {
        // GPU registry remapped so survivors are ranks 0..p' — every
        // schedule generator and conformance check then sees a dense
        // communicator of p' ranks
        let mut perm = survivors.clone();
        for r in 0..topo.num_gpus() {
            if !perm.contains(&r) {
                perm.push(r);
            }
        }
        let shrunk = masked.remap_gpus(&perm);
        if shrunk.serviceable(survivors.len()) {
            let shrunk_counts: Vec<u64> = survivors.iter().map(|&r| counts[r]).collect();
            // rank-addressed windows must keep their physical targets
            // across the remap: lower them to link form first
            let lowered = lower_to_links(topo, perts);
            now += policy.backoff(policy.max_retries);
            if let Some((res, outcome)) = attempt(&shrunk, &shrunk_counts, &lowered, now) {
                if outcome.is_completed() {
                    let dead_ranks: Vec<usize> =
                        (0..p).filter(|r| !survivors.contains(r)).collect();
                    return Some(Recovered {
                        result: Some(res),
                        strategy: RecoveryStrategy::Shrink { dead_ranks, masked_links: dead },
                        stall_time: Some(first_stall),
                        recovery_latency: res.time - first_stall,
                        survivors: survivors.len(),
                    });
                }
            }
        }
    }

    Some(Recovered::abort(first_stall))
}

/// [`super::perturbed_allgatherv`] under a recovery policy: identical
/// when the run completes cleanly; otherwise retries, reroutes or
/// shrinks per the module-level state machine.
pub fn recovered_allgatherv(
    topo: &Topology,
    lib: Library,
    params: Params,
    counts: &[u64],
    perts: &[Perturbation],
    policy: &RecoveryPolicy,
) -> Recovered {
    recover_with(topo, counts, perts, policy, |sim, cv, gate| {
        Some(compose_allgatherv(sim, lib, params, cv, gate))
    })
    .expect("allgatherv composes for every library")
}

/// [`super::perturbed_candidate`] under a recovery policy — the
/// outage-aware robust selector's scenario evaluator. `None` iff the
/// candidate is inapplicable on the healthy fabric.
pub fn recovered_candidate(
    topo: &Topology,
    params: Params,
    cand: Candidate,
    counts: &[u64],
    perts: &[Perturbation],
    policy: &RecoveryPolicy,
) -> Option<Recovered> {
    recover_with(topo, counts, perts, policy, |sim, cv, gate| {
        compose_candidate(sim, params, cand, cv, gate)
    })
}

/// [`recovered_candidate`] with the attempt-0 run replayed against a
/// shared delta-simulation baseline — the ensemble fast path of
/// [`crate::comm::select::AlgoSelector::evaluate_outage`]. `done` is
/// the completion task of the composition `delta` recorded.
pub(crate) fn recovered_candidate_warm(
    topo: &Topology,
    params: Params,
    cand: Candidate,
    counts: &[u64],
    perts: &[Perturbation],
    policy: &RecoveryPolicy,
    delta: &crate::perturb::DeltaSim<'_>,
    done: TaskId,
) -> Option<Recovered> {
    recover_with_warm(
        topo,
        counts,
        perts,
        policy,
        |sim, cv, gate| compose_candidate(sim, params, cand, cv, gate),
        Some((delta, done)),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perturb::perturbed_allgatherv;
    use crate::topology::systems::SystemKind;

    fn nvlink_on_route(topo: &Topology) -> LinkId {
        let path = topo.route_gpus(0, 1).unwrap();
        path.links[0]
    }

    #[test]
    fn clean_run_is_bit_exact_with_recovery_armed() {
        let t = SystemKind::Dgx1.build();
        let counts = vec![4u64 << 20; 8];
        let policy = RecoveryPolicy::default_policy();
        for lib in Library::all() {
            let plain = perturbed_allgatherv(&t, lib, Params::default(), &counts, &[]);
            let rec = recovered_allgatherv(&t, lib, Params::default(), &counts, &[], &policy);
            assert_eq!(rec.strategy, RecoveryStrategy::None, "{}", lib.name());
            assert_eq!(rec.recovery_latency, 0.0);
            let r = rec.result.unwrap();
            assert_eq!(plain.time.to_bits(), r.time.to_bits(), "{}", lib.name());
            assert_eq!(plain.flows, r.flows);
        }
    }

    #[test]
    fn transient_outage_recovers_by_retry() {
        // an NVLink dead over [1ms, 3ms): the engine freezes affected
        // flows and completes natively once the window closes, so the
        // WATCHDOG is what fires — libraries whose schedule crosses the
        // link bust the per-op budget and re-issue; libraries that
        // never touch it stay clean
        let t = SystemKind::Dgx1.build();
        let counts = vec![16u64 << 20; 8];
        let link = nvlink_on_route(&t);
        let perts = [Perturbation::link_down(link).during(1.0e-3, 2.0e-3)];
        let policy = RecoveryPolicy::default_policy();
        let mut retried = 0usize;
        for lib in Library::all() {
            let rec = recovered_allgatherv(&t, lib, Params::default(), &counts, &perts, &policy);
            let res = rec.result.unwrap_or_else(|| {
                panic!("{}: {:?} did not complete", lib.name(), rec.strategy)
            });
            assert_eq!(rec.survivors, 8, "{}", lib.name());
            assert!(res.time.is_finite() && res.time > 0.0);
            match rec.strategy {
                RecoveryStrategy::Retry { attempts } => {
                    retried += 1;
                    assert!(attempts >= 1);
                    assert!(rec.recovery_latency > 0.0, "{}", lib.name());
                    // the re-issue started after the watchdog deadline,
                    // i.e. after the window closed
                    assert!(res.time > 3.0e-3, "{}: {}", lib.name(), res.time);
                }
                RecoveryStrategy::None => {
                    assert_eq!(rec.recovery_latency, 0.0);
                }
                ref other => panic!("{}: {other:?}", lib.name()),
            }
        }
        assert!(retried > 0, "no library exercised the watchdog-retry path");
    }

    #[test]
    fn permanent_link_outage_recovers_by_reroute() {
        let t = SystemKind::Dgx1.build();
        let counts = vec![8u64 << 20; 8];
        let link = nvlink_on_route(&t);
        let perts = [Perturbation::link_down(link)];
        let policy = RecoveryPolicy::default_policy();
        for lib in Library::all() {
            let rec = recovered_allgatherv(&t, lib, Params::default(), &counts, &perts, &policy);
            if !rec.completed() {
                panic!("{}: aborted instead of rerouting", lib.name());
            }
            match &rec.strategy {
                // libraries whose schedule never crossed the dead link
                // complete cleanly — equally valid
                RecoveryStrategy::None => {}
                RecoveryStrategy::Reroute { masked_links } => {
                    assert!(masked_links.contains(&link), "{}", lib.name());
                }
                other => panic!("{}: {other:?}", lib.name()),
            }
        }
    }

    #[test]
    fn permanent_gpu_outage_shrinks_to_survivors() {
        let t = SystemKind::Dgx1.build();
        let counts = vec![4u64 << 20; 8];
        let perts = [Perturbation::gpu_down(3)];
        let policy = RecoveryPolicy::default_policy();
        for lib in Library::all() {
            let rec = recovered_allgatherv(&t, lib, Params::default(), &counts, &perts, &policy);
            let res = rec
                .result
                .unwrap_or_else(|| panic!("{}: {:?}", lib.name(), rec.strategy));
            match &rec.strategy {
                RecoveryStrategy::Shrink { dead_ranks, .. } => {
                    assert_eq!(dead_ranks, &vec![3], "{}", lib.name());
                    assert_eq!(rec.survivors, 7);
                }
                other => panic!("{}: expected shrink, got {other:?}", lib.name()),
            }
            assert!(res.time.is_finite() && res.time > 0.0);
        }
    }

    #[test]
    fn disabled_policy_reports_abort_on_stall() {
        let t = SystemKind::Dgx1.build();
        let counts = vec![8u64 << 20; 8];
        let link = nvlink_on_route(&t);
        let perts = [Perturbation::link_down(link)];
        let rec = recovered_allgatherv(
            &t,
            Library::Nccl,
            Params::default(),
            &counts,
            &perts,
            &RecoveryPolicy::disabled(),
        );
        assert_eq!(rec.strategy, RecoveryStrategy::Abort);
        assert!(!rec.completed());
        assert!(rec.stall_time.unwrap().is_finite());
    }

    #[test]
    fn lower_to_links_pins_physical_targets() {
        let t = SystemKind::CsStorm.build();
        let perts = [
            Perturbation::scale(0, 0.5),
            Perturbation::straggler(3, 0.25).during(0.1, 0.2),
            Perturbation::gpu_down(2),
        ];
        let lowered = lower_to_links(&t, &perts);
        assert_eq!(lowered[0], perts[0], "link-addressed entries pass through");
        let n3 = t.gpu_links(3).len();
        let n2 = t.gpu_links(2).len();
        assert_eq!(lowered.len(), 1 + n3 + n2);
        for q in &lowered[1..1 + n3] {
            match *q {
                Perturbation::LinkScale { factor, start, duration, .. } => {
                    assert_eq!((factor, start, duration), (0.25, 0.1, 0.2));
                }
                ref other => panic!("{other:?}"),
            }
        }
        for q in &lowered[1 + n3..] {
            assert!(matches!(q, Perturbation::LinkDown { .. }), "{q:?}");
        }
    }
}
