//! Fault & variability subsystem (DESIGN.md §12): degraded links,
//! straggler GPUs, and time-varying bandwidth over the paper's systems.
//!
//! The paper benchmarks every collective on a pristine, idle machine;
//! production fabrics are not pristine — NVLink/PCIe lanes degrade,
//! GPUs straggle (clock throttling, ECC retirement), and InfiniBand
//! bandwidth varies with cluster-wide load ("Monitoring Collective
//! Communication Among GPUs", PAPERS.md). This module models those
//! effects as **piecewise-constant capacity profiles** compiled onto
//! the simulator's capacity-step substrate
//! ([`crate::sim::Sim::capacity_event`]):
//!
//! - [`Perturbation`]: scale a link, drop a link to an absolute
//!   bandwidth floor, slow a whole GPU (every incident link), or — the
//!   hard-fault regime of DESIGN.md §14 — kill a link or a GPU outright
//!   (capacity exactly 0), each over an optional
//!   `[start, start+duration)` window;
//! - [`apply`]: compose a perturbation set into per-link capacity
//!   steps — overlapping scales multiply, floors clamp, outages zero —
//!   and emit them into a `Sim`;
//! - [`ensemble`]: seeded Monte-Carlo scenario sets over severity /
//!   duration / placement distributions, for robust selection
//!   ([`crate::comm::select::AlgoSelector::select_robust`]) and the
//!   `agv faults` fragility study;
//! - [`perturbed_allgatherv`] / [`perturbed_candidate`]: one collective
//!   on a degraded fabric, through the same *compose* entry points the
//!   workload engine uses.
//!
//! The anchor contract, pinned by `tests/faults_differential.rs`: an
//! **empty** perturbation set and a **zero-magnitude** one (scale 1.0,
//! floor at/above base bandwidth, zero-length window) both produce
//! results bit-identical to the unperturbed simulation, on both engine
//! cores — capacity steps that would not change a link's capacity
//! bit-for-bit are filtered before the run and never reach either
//! engine. Every degraded number extrapolates from the exact models the
//! paper experiments validated, not from a second implementation.

pub mod bench;
pub mod ensemble;
pub mod recovery;

pub use ensemble::{ensemble, EnsembleCfg};
pub use recovery::{recovered_allgatherv, Recovered, RecoveryStrategy};

use std::collections::BTreeMap;

use crate::anyhow;
use crate::comm::{compose_allgatherv, CommResult, Library, Params};
use crate::sim::Sim;
use crate::topology::{LinkId, Topology};
use crate::util::error::Result;

/// One fault or variability effect on the fabric, active over
/// `[start, start + duration)` (duration may be `f64::INFINITY` for a
/// static degradation).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Perturbation {
    /// Multiply one link's capacity (both directions) by `factor` —
    /// a contended or degraded lane. `factor` must be positive and
    /// finite; values above 1.0 model recovering/overprovisioned links.
    LinkScale {
        /// Target link.
        link: LinkId,
        /// Capacity multiplier (1.0 = no effect).
        factor: f64,
        /// Window start (virtual seconds).
        start: f64,
        /// Window length (virtual seconds; `INFINITY` = forever).
        duration: f64,
    },
    /// Clamp one link's capacity to an absolute bandwidth floor in
    /// bytes/s — e.g. an FDR lane renegotiated down, or a QoS cap. A
    /// floor at or above the link's base bandwidth is a no-op.
    LinkFloor {
        /// Target link.
        link: LinkId,
        /// Absolute capacity ceiling the link is dropped to (bytes/s).
        floor_bw: f64,
        /// Window start (virtual seconds).
        start: f64,
        /// Window length (virtual seconds; `INFINITY` = forever).
        duration: f64,
    },
    /// Straggler GPU: scale **every link incident to the GPU** by
    /// `factor` — a throttled or oversubscribed device slows all its
    /// lanes at once ([`Topology::gpu_links`]).
    Straggler {
        /// GPU rank (rank, not device id).
        rank: usize,
        /// Capacity multiplier on every incident link.
        factor: f64,
        /// Window start (virtual seconds).
        start: f64,
        /// Window length (virtual seconds; `INFINITY` = forever).
        duration: f64,
    },
    /// Hard link outage: the link's capacity drops to **exactly zero**
    /// over the window — a dead lane (DESIGN.md §14). Unlike
    /// [`Perturbation::LinkScale`]/[`Perturbation::LinkFloor`] (clamped
    /// to positive capacities), an outage overrides every scale and
    /// floor active at the same instant; flows crossing the link freeze
    /// and the run ends [`crate::sim::SimOutcome::Stalled`] unless the
    /// window closes or the recovery layer reroutes around it.
    LinkDown {
        /// Target link.
        link: LinkId,
        /// Window start (virtual seconds).
        start: f64,
        /// Window length (virtual seconds; `INFINITY` = crashed for good).
        duration: f64,
    },
    /// Hard GPU outage: **every link incident to the GPU** drops to
    /// zero over the window — a crashed device. Completing a collective
    /// past a permanent GPU outage requires communicator-shrink
    /// semantics ([`crate::perturb::recovery`]).
    GpuDown {
        /// GPU rank (rank, not device id).
        rank: usize,
        /// Window start (virtual seconds).
        start: f64,
        /// Window length (virtual seconds; `INFINITY` = crashed for good).
        duration: f64,
    },
}

impl Perturbation {
    /// Static link scaling, active from t=0 forever.
    pub fn scale(link: LinkId, factor: f64) -> Perturbation {
        Perturbation::LinkScale { link, factor, start: 0.0, duration: f64::INFINITY }
    }

    /// Static link floor, active from t=0 forever.
    pub fn floor(link: LinkId, floor_bw: f64) -> Perturbation {
        Perturbation::LinkFloor { link, floor_bw, start: 0.0, duration: f64::INFINITY }
    }

    /// Static straggler GPU, active from t=0 forever.
    pub fn straggler(rank: usize, factor: f64) -> Perturbation {
        Perturbation::Straggler { rank, factor, start: 0.0, duration: f64::INFINITY }
    }

    /// Permanent link outage, dead from t=0 onward.
    pub fn link_down(link: LinkId) -> Perturbation {
        Perturbation::LinkDown { link, start: 0.0, duration: f64::INFINITY }
    }

    /// Permanent GPU outage, crashed from t=0 onward.
    pub fn gpu_down(rank: usize) -> Perturbation {
        Perturbation::GpuDown { rank, start: 0.0, duration: f64::INFINITY }
    }

    /// The same perturbation restricted to `[start, start+duration)`.
    pub fn during(mut self, new_start: f64, new_duration: f64) -> Perturbation {
        match &mut self {
            Perturbation::LinkScale { start, duration, .. }
            | Perturbation::LinkFloor { start, duration, .. }
            | Perturbation::Straggler { start, duration, .. }
            | Perturbation::LinkDown { start, duration, .. }
            | Perturbation::GpuDown { start, duration, .. } => {
                *start = new_start;
                *duration = new_duration;
            }
        }
        self
    }

    /// (start, duration) window of this perturbation.
    pub fn window(&self) -> (f64, f64) {
        match *self {
            Perturbation::LinkScale { start, duration, .. }
            | Perturbation::LinkFloor { start, duration, .. }
            | Perturbation::Straggler { start, duration, .. }
            | Perturbation::LinkDown { start, duration, .. }
            | Perturbation::GpuDown { start, duration, .. } => (start, duration),
        }
    }

    /// Short report label ("link3 x0.50", "gpu2 straggler x0.25",
    /// "link1 DOWN", ...).
    pub fn label(&self) -> String {
        match *self {
            Perturbation::LinkScale { link, factor, .. } => format!("link{link} x{factor:.2}"),
            Perturbation::LinkFloor { link, floor_bw, .. } => {
                format!("link{link} floor {:.1}GB/s", floor_bw / 1e9)
            }
            Perturbation::Straggler { rank, factor, .. } => {
                format!("gpu{rank} straggler x{factor:.2}")
            }
            Perturbation::LinkDown { link, .. } => format!("link{link} DOWN"),
            Perturbation::GpuDown { rank, .. } => format!("gpu{rank} DOWN"),
        }
    }

    /// Canonical `--perturb` grammar form of this perturbation; the
    /// exact inverse of [`parse_list`]:
    /// `parse_list(&p.spec()).unwrap() == vec![p]` for every variant
    /// (pinned by `parse_list_roundtrip_and_rejections`). Infinite
    /// durations and zero starts render as the grammar's defaults.
    pub fn spec(&self) -> String {
        let head = match *self {
            Perturbation::LinkScale { link, factor, .. } => format!("link:{link}:{factor}"),
            Perturbation::LinkFloor { link, floor_bw, .. } => format!("floor:{link}:{floor_bw}"),
            Perturbation::Straggler { rank, factor, .. } => format!("straggler:{rank}:{factor}"),
            Perturbation::LinkDown { link, .. } => format!("down:{link}"),
            Perturbation::GpuDown { rank, .. } => format!("gpudown:{rank}"),
        };
        let (start, duration) = self.window();
        if duration.is_finite() {
            format!("{head}:{start}:{duration}")
        } else if start != 0.0 {
            format!("{head}:{start}")
        } else {
            head
        }
    }
}

/// Check a perturbation set against a topology; every violation is a
/// clean [`crate::util::error::Error`] (the CLI and workload specs
/// surface these instead of panicking).
pub fn validate(topo: &Topology, perts: &[Perturbation]) -> Result<()> {
    for (i, p) in perts.iter().enumerate() {
        let (start, duration) = p.window();
        if !start.is_finite() || start < 0.0 {
            return Err(anyhow!("perturbation {i}: start must be finite and >= 0, got {start}"));
        }
        if duration.is_nan() || duration < 0.0 {
            return Err(anyhow!("perturbation {i}: duration must be >= 0, got {duration}"));
        }
        match *p {
            Perturbation::LinkScale { link, factor, .. } => {
                if link >= topo.links.len() {
                    return Err(anyhow!(
                        "perturbation {i}: link {link} out of range (`{}` has {} links)",
                        topo.name,
                        topo.links.len()
                    ));
                }
                check_factor(i, "scale factor", factor)?;
            }
            Perturbation::LinkFloor { link, floor_bw, .. } => {
                if link >= topo.links.len() {
                    return Err(anyhow!(
                        "perturbation {i}: link {link} out of range (`{}` has {} links)",
                        topo.name,
                        topo.links.len()
                    ));
                }
                if !floor_bw.is_finite() || floor_bw <= 0.0 {
                    return Err(anyhow!(
                        "perturbation {i}: floor bandwidth must be finite and > 0, got {floor_bw}"
                    ));
                }
            }
            Perturbation::Straggler { rank, factor, .. } => {
                if rank >= topo.num_gpus() {
                    return Err(anyhow!(
                        "perturbation {i}: GPU rank {rank} out of range (`{}` has {} GPUs)",
                        topo.name,
                        topo.num_gpus()
                    ));
                }
                check_factor(i, "straggler factor", factor)?;
            }
            Perturbation::LinkDown { link, .. } => {
                if link >= topo.links.len() {
                    return Err(anyhow!(
                        "perturbation {i}: link {link} out of range (`{}` has {} links)",
                        topo.name,
                        topo.links.len()
                    ));
                }
            }
            Perturbation::GpuDown { rank, .. } => {
                if rank >= topo.num_gpus() {
                    return Err(anyhow!(
                        "perturbation {i}: GPU rank {rank} out of range (`{}` has {} GPUs)",
                        topo.name,
                        topo.num_gpus()
                    ));
                }
            }
        }
    }
    Ok(())
}

/// Scale factors outside `[1e-6, 1e6]` are rejected up front: they
/// model nothing physical, and extreme stacked products could push the
/// composed capacity outside f64's positive range (the defensive clamp
/// in [`apply`] is the backstop, this is the clean error).
fn check_factor(i: usize, what: &str, factor: f64) -> Result<()> {
    if !factor.is_finite() || !(1e-6..=1e6).contains(&factor) {
        return Err(anyhow!(
            "perturbation {i}: {what} must be within [1e-6, 1e6], got {factor}"
        ));
    }
    Ok(())
}

/// A link-local effect over a window (straggler and GPU outage
/// expanded to their incident links).
#[derive(Clone, Copy, Debug)]
enum Effect {
    Scale(f64),
    Floor(f64),
    Down,
}

/// Compile a perturbation set into per-link **capacity steps** and emit
/// them into `sim`. Overlapping effects on one link compose at every
/// breakpoint: the effective capacity is `base x prod(active scales)`,
/// clamped by `min` with every active floor — scales all apply before
/// any floor, so the result does not depend on how scales and floors
/// interleave in the listing (scales multiply in listing order, which
/// pins the fp rounding deterministically). A step that would leave the
/// capacity bit-identical is filtered by the engine's timeline builder,
/// so zero-magnitude perturbations emit nothing — the
/// differential-oracle contract (module docs).
///
/// Panics on an invalid set; run [`validate`] first for a clean error.
pub fn apply(sim: &mut Sim, perts: &[Perturbation]) {
    for (link, time, capacity) in compile(sim.topology(), perts) {
        sim.capacity_event(link, time, capacity);
    }
}

/// The compile half of [`apply`]: compose a perturbation set into
/// `(link, time, capacity)` steps *without* a `Sim` to emit them into.
/// Emission order and every capacity bit are identical to what
/// [`apply`] pushes — [`DeltaSim`] feeds these straight to the replay
/// layer, so a warm-started scenario sees exactly the capacity steps a
/// cold run would.
pub(crate) fn compile(topo: &Topology, perts: &[Perturbation]) -> Vec<(LinkId, f64, f64)> {
    // per-link list of (start, end, effect), in perturbation order
    let mut by_link: BTreeMap<LinkId, Vec<(f64, f64, Effect)>> = BTreeMap::new();
    for p in perts {
        let (start, duration) = p.window();
        if duration <= 0.0 {
            continue; // empty window: no effect at any instant
        }
        let end = start + duration;
        match *p {
            Perturbation::LinkScale { link, factor, .. } => {
                by_link.entry(link).or_default().push((start, end, Effect::Scale(factor)));
            }
            Perturbation::LinkFloor { link, floor_bw, .. } => {
                by_link.entry(link).or_default().push((start, end, Effect::Floor(floor_bw)));
            }
            Perturbation::Straggler { rank, factor, .. } => {
                for link in topo.gpu_links(rank) {
                    by_link
                        .entry(link)
                        .or_default()
                        .push((start, end, Effect::Scale(factor)));
                }
            }
            Perturbation::LinkDown { link, .. } => {
                by_link.entry(link).or_default().push((start, end, Effect::Down));
            }
            Perturbation::GpuDown { rank, .. } => {
                for link in topo.gpu_links(rank) {
                    by_link.entry(link).or_default().push((start, end, Effect::Down));
                }
            }
        }
    }
    let mut out = Vec::new();
    for (link, effects) in by_link {
        let base = topo.links[link].class.bandwidth();
        // breakpoints: every window start and every finite window end
        let mut ts: Vec<f64> = effects
            .iter()
            .flat_map(|&(s, e, _)| [s, e])
            .filter(|t| t.is_finite())
            .collect();
        ts.sort_by(f64::total_cmp);
        ts.dedup_by(|a, b| a.to_bits() == b.to_bits());
        for t in ts {
            // three passes — all active scales multiply first, then all
            // active floors clamp, then any active outage zeroes — so
            // the effective capacity is independent of the order
            // perturbations were listed in
            let mut cap = base;
            for &(s, e, eff) in &effects {
                if s <= t && t < e {
                    if let Effect::Scale(f) = eff {
                        cap *= f;
                    }
                }
            }
            for &(s, e, eff) in &effects {
                if s <= t && t < e {
                    if let Effect::Floor(bw) = eff {
                        cap = cap.min(bw);
                    }
                }
            }
            // backstop for pathological stacked products that escape
            // the validate() factor bounds: keep the step inside f64's
            // positive range instead of tripping the engine's assert
            // (identity for every physically meaningful capacity)
            cap = cap.clamp(f64::MIN_POSITIVE, f64::MAX);
            // outages win over everything — a floor must not resurrect
            // a dead link, so the exact 0.0 bypasses the clamp above
            if effects
                .iter()
                .any(|&(s, e, eff)| s <= t && t < e && matches!(eff, Effect::Down))
            {
                cap = 0.0;
            }
            out.push((link, t, cap));
        }
    }
    out
}

/// Warm-started delta-simulation over one composed DAG (DESIGN.md
/// §16): record the unperturbed baseline once, then run each perturbed
/// scenario by fast-forwarding the baseline's event log to the
/// scenario's first divergence point and simulating live only from
/// there ([`crate::sim::replay`]).
///
/// This is the second caching tier for ensemble consumers — the first
/// is the build-once schedule cache (compose once, simulate many). The
/// contract: [`DeltaSim::run`] agrees with [`DeltaSim::run_cold`] to
/// the engine's ~1e-9 relative tolerance, and is **bit-exact** whenever
/// the scenario cannot diverge mid-run (empty/zero-magnitude sets,
/// divergence at t=0, perturbations past the baseline makespan, or a
/// reference-engine scope).
pub struct DeltaSim<'t> {
    baseline: crate::sim::Baseline<'t>,
}

impl<'t> DeltaSim<'t> {
    /// Record the unperturbed baseline from a fully composed `Sim`.
    /// Panics if the builder already carries capacity events.
    pub fn record(sim: Sim<'t>) -> DeltaSim<'t> {
        DeltaSim { baseline: crate::sim::Baseline::record(sim) }
    }

    /// The unperturbed baseline result.
    pub fn baseline(&self) -> &crate::sim::SimResult {
        self.baseline.result()
    }

    /// The unperturbed baseline outcome.
    pub fn baseline_outcome(&self) -> &crate::sim::SimOutcome {
        self.baseline.outcome()
    }

    /// Run one perturbed scenario, warm-started from the baseline's
    /// divergence point. Panics on an invalid set; run [`validate`]
    /// first for a clean error.
    pub fn run(&self, perts: &[Perturbation]) -> (crate::sim::SimResult, crate::sim::SimOutcome) {
        self.baseline.replay(self.steps(perts))
    }

    /// Cold re-run of the same scenario from the pristine DAG —
    /// bit-exact to composing and running it fresh. The differential
    /// reference for [`DeltaSim::run`] in tests and `make bench-delta`.
    pub fn run_cold(
        &self,
        perts: &[Perturbation],
    ) -> (crate::sim::SimResult, crate::sim::SimOutcome) {
        self.baseline.replay_cold(self.steps(perts))
    }

    /// Which replay tier one scenario takes: `"identical"` (pure
    /// replay of the baseline), `"tail"` (every step lands past the
    /// baseline makespan — also a pure replay), `"cold"` (divergence
    /// at t=0, or a reference-engine scope), or `"warm"` (genuine
    /// mid-run resume). The bench grids cost scenarios by tier: the
    /// two pure-replay tiers execute zero live events.
    pub fn mode(&self, perts: &[Perturbation]) -> &'static str {
        use crate::sim::replay::ReplayMode;
        match self.baseline.plan(&self.steps(perts)) {
            ReplayMode::Identical => "identical",
            ReplayMode::Cold => "cold",
            ReplayMode::Tail => "tail",
            ReplayMode::Warm => "warm",
        }
    }

    fn steps(&self, perts: &[Perturbation]) -> Vec<crate::sim::engine::CapEvent> {
        let topo = self.baseline.topo();
        compile(topo, perts)
            .into_iter()
            .map(|(link, time, capacity)| {
                assert!(link < topo.links.len(), "perturbation targets link {link} off-topology");
                crate::sim::engine::CapEvent { time, link, capacity }
            })
            .collect()
    }
}

/// Run one library's Allgatherv on a **perturbed** fabric in a fresh
/// simulation: the identical compose path `run_allgatherv` uses (same
/// schedule selection, same transports), plus the perturbation set's
/// capacity steps. With an empty or zero-magnitude set this reproduces
/// [`crate::comm::run_allgatherv`] bit-for-bit
/// (`tests/faults_differential.rs`).
pub fn perturbed_allgatherv(
    topo: &Topology,
    lib: Library,
    params: Params,
    counts: &[u64],
    perts: &[Perturbation],
) -> CommResult {
    let mut sim = Sim::new(topo);
    let done = compose_allgatherv(&mut sim, lib, params, counts, None);
    apply(&mut sim, perts);
    let res = sim.run();
    CommResult { time: res.finish(done), flows: res.flows }
}

/// [`perturbed_allgatherv`] for any [`CollectiveSpec`] op — allreduce,
/// bcast and alltoallv ride the same compose-then-perturb contract as
/// the paper's Allgatherv (DESIGN.md §13), so the fault model needs no
/// per-op code. With an empty `perts` this reproduces
/// [`crate::comm::collective::run_collective`] bit-for-bit.
pub fn perturbed_collective(
    topo: &Topology,
    lib: Library,
    params: Params,
    spec: &crate::comm::collective::CollectiveSpec,
    chunk: crate::comm::transport::ChunkCfg,
    perts: &[Perturbation],
) -> CommResult {
    let mut sim = Sim::new(topo);
    let done =
        crate::comm::collective::compose_collective(&mut sim, lib, params, spec, chunk, None);
    apply(&mut sim, perts);
    let res = sim.run();
    CommResult { time: res.finish(done), flows: res.flows }
}

/// [`perturbed_allgatherv`] for a specific (library, algorithm)
/// candidate — the robust selector's scenario evaluator. `None` iff the
/// candidate is inapplicable, exactly as for
/// [`crate::comm::select::simulate`] (which this reproduces bit-for-bit
/// when `perts` is empty).
pub fn perturbed_candidate(
    topo: &Topology,
    params: Params,
    cand: crate::comm::select::Candidate,
    counts: &[u64],
    perts: &[Perturbation],
) -> Option<CommResult> {
    let mut sim = Sim::new(topo);
    let done = crate::comm::select::compose(&mut sim, params, cand, counts, None)?;
    apply(&mut sim, perts);
    let res = sim.run();
    Some(CommResult { time: res.finish(done), flows: res.flows })
}

/// Parse a comma-separated `--perturb` specification. Grammar, one
/// perturbation per item (start/duration in seconds, default `0` /
/// forever; bandwidths accept `K`/`M`/`G` suffixes via
/// [`crate::util::cli::parse_bytes`]):
///
/// ```text
/// link:<id>:<factor>[:<start>[:<duration>]]
/// floor:<id>:<bytes-per-sec>[:<start>[:<duration>]]
/// straggler:<rank>:<factor>[:<start>[:<duration>]]
/// down:<id>[:<start>[:<duration>]]
/// gpudown:<rank>[:<start>[:<duration>]]
/// ```
///
/// e.g. `--perturb straggler:0:0.5,floor:2:1GB:0.001:0.01` or
/// `--perturb down:3:0.001:0.01` (link 3 dead for 10 ms). `down` and
/// `gpudown` take no magnitude — an outage is total by definition. Link
/// ids are per-topology; `agv faults --system S --list-links` prints
/// them.
pub fn parse_list(spec: &str) -> Result<Vec<Perturbation>> {
    let mut out = Vec::new();
    for item in spec.split(',').filter(|s| !s.is_empty()) {
        let parts: Vec<&str> = item.split(':').collect();
        // outage kinds carry no magnitude field; everything else does
        let has_magnitude = !matches!(parts[0], "down" | "gpudown");
        let (min_parts, max_parts) = if has_magnitude { (3, 5) } else { (2, 4) };
        if parts.len() < min_parts || parts.len() > max_parts {
            let grammar = if has_magnitude {
                "kind:target:magnitude[:start[:duration]]"
            } else {
                "kind:target[:start[:duration]]"
            };
            return Err(anyhow!("perturbation `{item}`: expected {grammar}"));
        }
        let target: usize = parts[1]
            .parse()
            .map_err(|_| anyhow!("perturbation `{item}`: bad target `{}`", parts[1]))?;
        let start_idx = if has_magnitude { 3 } else { 2 };
        let start: f64 = match parts.get(start_idx) {
            Some(s) => s
                .parse()
                .map_err(|_| anyhow!("perturbation `{item}`: bad start `{s}`"))?,
            None => 0.0,
        };
        let duration: f64 = match parts.get(start_idx + 1) {
            Some(s) => s
                .parse()
                .map_err(|_| anyhow!("perturbation `{item}`: bad duration `{s}`"))?,
            None => f64::INFINITY,
        };
        let pert = match parts[0] {
            "link" => {
                let factor: f64 = parts[2]
                    .parse()
                    .map_err(|_| anyhow!("perturbation `{item}`: bad factor `{}`", parts[2]))?;
                Perturbation::LinkScale { link: target, factor, start, duration }
            }
            "floor" => {
                let floor_bw = crate::util::cli::parse_bytes(parts[2])
                    .ok_or_else(|| anyhow!("perturbation `{item}`: bad bandwidth `{}`", parts[2]))?
                    as f64;
                Perturbation::LinkFloor { link: target, floor_bw, start, duration }
            }
            "straggler" => {
                let factor: f64 = parts[2]
                    .parse()
                    .map_err(|_| anyhow!("perturbation `{item}`: bad factor `{}`", parts[2]))?;
                Perturbation::Straggler { rank: target, factor, start, duration }
            }
            "down" => Perturbation::LinkDown { link: target, start, duration },
            "gpudown" => Perturbation::GpuDown { rank: target, start, duration },
            other => {
                return Err(anyhow!(
                    "perturbation `{item}`: unknown kind `{other}` (link|floor|straggler|down|gpudown)"
                ))
            }
        };
        out.push(pert);
    }
    if out.is_empty() {
        return Err(anyhow!("--perturb: empty specification"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_allgatherv;
    use crate::topology::systems::SystemKind;
    use crate::topology::LinkClass;

    #[test]
    fn constructors_and_windows() {
        let p = Perturbation::scale(3, 0.5);
        assert_eq!(p.window(), (0.0, f64::INFINITY));
        let q = p.during(1.0, 2.0);
        assert_eq!(q.window(), (1.0, 2.0));
        assert!(Perturbation::straggler(0, 0.25).label().contains("straggler"));
        assert!(Perturbation::floor(2, 1.0e9).label().contains("floor"));
    }

    #[test]
    fn validate_rejects_bad_sets() {
        let t = SystemKind::Dgx1.build();
        assert!(validate(&t, &[Perturbation::scale(0, 0.5)]).is_ok());
        assert!(validate(&t, &[Perturbation::scale(999, 0.5)]).is_err(), "link range");
        assert!(validate(&t, &[Perturbation::scale(0, 0.0)]).is_err(), "zero factor");
        assert!(validate(&t, &[Perturbation::scale(0, f64::NAN)]).is_err(), "nan factor");
        assert!(validate(&t, &[Perturbation::straggler(99, 0.5)]).is_err(), "rank range");
        assert!(validate(&t, &[Perturbation::floor(0, -1.0)]).is_err(), "negative floor");
        assert!(
            validate(&t, &[Perturbation::scale(0, 0.5).during(-1.0, 1.0)]).is_err(),
            "negative start"
        );
        assert!(
            validate(&t, &[Perturbation::scale(0, 0.5).during(0.0, f64::NAN)]).is_err(),
            "nan duration"
        );
        assert!(validate(&t, &[Perturbation::link_down(0)]).is_ok());
        assert!(validate(&t, &[Perturbation::gpu_down(0)]).is_ok());
        assert!(validate(&t, &[Perturbation::link_down(999)]).is_err(), "outage link range");
        assert!(validate(&t, &[Perturbation::gpu_down(99)]).is_err(), "outage rank range");
        assert!(
            validate(&t, &[Perturbation::link_down(0).during(-1.0, 1.0)]).is_err(),
            "outage negative start"
        );
    }

    #[test]
    fn outage_forces_exact_zero_and_floors_cannot_resurrect_it() {
        // a floor above the base bandwidth plus a scale above 1.0 are
        // both active during the outage window: the composed step must
        // still be exactly 0.0 — outages win over every other effect
        let t = SystemKind::Dgx1.build();
        let link = t.gpu_links(0)[0];
        let base = t.links[link].class.bandwidth();
        let perts = [
            Perturbation::scale(link, 2.0),
            Perturbation::floor(link, 2.0 * base),
            Perturbation::link_down(link).during(0.001, 0.002),
        ];
        let mut sim = Sim::new(&t);
        apply(&mut sim, &perts);
        // breakpoints: 0 (scale+floor), 0.001 (down), 0.003 (restored)
        let expect = [(0.0, 2.0 * base), (0.001, 0.0), (0.003, 2.0 * base)];
        assert_eq!(sim.cap_events.len(), expect.len());
        for (ev, (t_e, cap_e)) in sim.cap_events.iter().zip(expect) {
            assert_eq!(ev.time.to_bits(), t_e.to_bits());
            assert_eq!(ev.capacity.to_bits(), cap_e.to_bits());
        }
        // listing order must not matter
        let mut reordered = Sim::new(&t);
        apply(&mut reordered, &[perts[2], perts[0], perts[1]]);
        assert_eq!(sim.cap_events, reordered.cap_events);
    }

    #[test]
    fn gpu_down_kills_every_incident_link() {
        let t = SystemKind::CsStorm.build();
        let mut sim = Sim::new(&t);
        apply(&mut sim, &[Perturbation::gpu_down(3)]);
        let links: Vec<_> = sim.cap_events.iter().map(|e| e.link).collect();
        assert_eq!(links, t.gpu_links(3));
        for ev in &sim.cap_events {
            assert_eq!(ev.time, 0.0);
            assert_eq!(ev.capacity.to_bits(), 0.0_f64.to_bits());
        }
    }

    #[test]
    fn transient_outage_freezes_then_completes() {
        // one flow over a link dead for [t1, t2): finish = t2 + what was
        // left at t1, on both engines — the unit-level liveness anchor
        use crate::sim::with_reference_engine;
        let t = SystemKind::Dgx1.build();
        let path = t.route_gpus(0, 1).unwrap();
        let link = path.links[0];
        let bw = t.path_bandwidth(&path);
        let bytes = 8.0 * bw * 0.01; // 80 ms of work at full rate
        let (t1, t2) = (0.01, 0.04);
        let run = || {
            let mut sim = Sim::new(&t);
            let f = sim.flow(path.clone(), bytes, 0.0, &[]);
            apply(&mut sim, &[Perturbation::link_down(link).during(t1, t2 - t1)]);
            let (res, outcome) = sim.run_outcome();
            assert!(outcome.is_completed(), "{}", outcome.describe());
            res.finish(f)
        };
        let expect = t2 + (bytes - bw * t1) / bw;
        let event = run();
        let reference = with_reference_engine(run);
        assert!((event - expect).abs() / expect < 1e-9, "event {event} vs {expect}");
        assert!((reference - expect).abs() / expect < 1e-9, "ref {reference} vs {expect}");
    }

    #[test]
    fn overlapping_scales_multiply_and_floors_clamp() {
        // two overlapping windows on one NVLink: [0,2) x0.5 and [1,3) x0.5,
        // plus a floor at 2 GB/s over [1.5, 2.5)
        let t = SystemKind::Dgx1.build();
        let link = t.gpu_links(0)[0];
        let base = t.links[link].class.bandwidth();
        let perts = [
            Perturbation::scale(link, 0.5).during(0.0, 2.0),
            Perturbation::scale(link, 0.5).during(1.0, 2.0),
            Perturbation::floor(link, 2.0e9).during(1.5, 1.0),
        ];
        let mut sim = Sim::new(&t);
        apply(&mut sim, &perts);
        // breakpoints 0, 1, 1.5, 2, 2.5, 3 -> capacities
        // .5B, .25B, min(.25B, 2e9), min(.5B, 2e9), .5B, B
        let expect = [
            (0.0, 0.5 * base),
            (1.0, 0.25 * base),
            (1.5, (0.25 * base).min(2.0e9)),
            (2.0, (0.5 * base).min(2.0e9)),
            (2.5, 0.5 * base),
            (3.0, base),
        ];
        assert_eq!(sim.cap_events.len(), expect.len());
        for (ev, (t_e, cap_e)) in sim.cap_events.iter().zip(expect) {
            assert_eq!(ev.link, link);
            assert_eq!(ev.time.to_bits(), t_e.to_bits());
            assert_eq!(ev.capacity.to_bits(), cap_e.to_bits());
        }
        // composition is listing-order independent: scales apply before
        // floors regardless of how the set was written (floor-first
        // would otherwise scale the floored value)
        let mut reordered = Sim::new(&t);
        apply(&mut reordered, &[perts[2], perts[1], perts[0]]);
        assert_eq!(sim.cap_events, reordered.cap_events);
    }

    #[test]
    fn straggler_touches_every_incident_link() {
        let t = SystemKind::CsStorm.build();
        let mut sim = Sim::new(&t);
        apply(&mut sim, &[Perturbation::straggler(3, 0.5)]);
        let links: Vec<_> = sim.cap_events.iter().map(|e| e.link).collect();
        assert_eq!(links, t.gpu_links(3));
    }

    #[test]
    fn empty_window_emits_nothing() {
        let t = SystemKind::Dgx1.build();
        let mut sim = Sim::new(&t);
        apply(&mut sim, &[Perturbation::scale(0, 0.25).during(1.0, 0.0)]);
        assert!(sim.cap_events.is_empty());
    }

    #[test]
    fn perturbed_allgatherv_with_empty_set_is_bit_exact() {
        // the unit-level anchor of tests/faults_differential.rs
        let t = SystemKind::Dgx1.build();
        let counts = vec![3u64 << 20, 64 << 10, 0, 9 << 20];
        for lib in Library::all() {
            let base = run_allgatherv(lib, &t, &counts);
            let none = perturbed_allgatherv(&t, lib, Params::default(), &counts, &[]);
            assert_eq!(base.time.to_bits(), none.time.to_bits(), "{}", lib.name());
            assert_eq!(base.flows, none.flows);
        }
    }

    #[test]
    fn degrading_the_nccl_ring_slows_nccl() {
        // halve every NVLink on the DGX-1: NCCL's all-NVLink ring must
        // slow down materially (roughly 2x at bandwidth-bound sizes)
        let t = SystemKind::Dgx1.build();
        let counts = vec![16u64 << 20; 8];
        let perts: Vec<Perturbation> = (0..t.links.len())
            .filter(|&l| t.links[l].class.is_nvlink())
            .map(|l| Perturbation::scale(l, 0.5))
            .collect();
        let healthy = run_allgatherv(Library::Nccl, &t, &counts);
        let degraded =
            perturbed_allgatherv(&t, Library::Nccl, Params::default(), &counts, &perts);
        let slow = degraded.time / healthy.time;
        assert!(slow > 1.5, "halving NVLink left NCCL at {slow}x");
        assert_eq!(degraded.flows, healthy.flows, "perturbation must not change the DAG");
    }

    #[test]
    fn parse_list_roundtrip_and_rejections() {
        let ps = parse_list("link:3:0.5,straggler:0:0.25:0.001,floor:2:1GB:0:0.01").unwrap();
        assert_eq!(ps.len(), 3);
        assert_eq!(ps[0], Perturbation::scale(3, 0.5));
        assert_eq!(
            ps[1],
            Perturbation::Straggler { rank: 0, factor: 0.25, start: 0.001, duration: f64::INFINITY }
        );
        match ps[2] {
            Perturbation::LinkFloor { link, floor_bw, start, duration } => {
                assert_eq!(link, 2);
                assert_eq!(floor_bw, (1u64 << 30) as f64);
                assert_eq!(start, 0.0);
                assert_eq!(duration, 0.01);
            }
            _ => panic!("wrong kind"),
        }
        for bad in ["", "link:3", "warp:3:0.5", "link:x:0.5", "link:3:abc", "link:3:0.5:z"] {
            assert!(parse_list(bad).is_err(), "`{bad}` parsed");
        }
        // outage kinds: no magnitude field
        let downs = parse_list("down:3,gpudown:1:0.001,down:0:0.001:0.01").unwrap();
        assert_eq!(downs[0], Perturbation::link_down(3));
        assert_eq!(
            downs[1],
            Perturbation::GpuDown { rank: 1, start: 0.001, duration: f64::INFINITY }
        );
        assert_eq!(
            downs[2],
            Perturbation::LinkDown { link: 0, start: 0.001, duration: 0.01 }
        );
        for bad in ["down", "down:x", "down:3:y", "down:3:0:1:2", "gpudown:1:0:z"] {
            assert!(parse_list(bad).is_err(), "`{bad}` parsed");
        }
    }

    #[test]
    fn rejection_matrix_pins_clear_messages() {
        let msg = |s: &str| parse_list(s).unwrap_err().to_string();
        assert!(msg("warp:3:0.5").contains("unknown kind `warp` (link|floor|straggler|down|gpudown)"));
        assert!(msg("link:3").contains("expected kind:target:magnitude[:start[:duration]]"));
        assert!(msg("down:3:0:1:2").contains("expected kind:target[:start[:duration]]"));
        assert!(msg("link:x:0.5").contains("bad target `x`"));
        assert!(msg("link:3:abc").contains("bad factor `abc`"));
        assert!(msg("floor:3:junk").contains("bad bandwidth `junk`"));
        assert!(msg("link:3:0.5:z").contains("bad start `z`"));
        assert!(msg("link:3:0.5:0:z").contains("bad duration `z`"));
        assert!(msg("").contains("empty specification"));
        // out-of-range values parse but fail validate() with the window
        // checks the CLI surfaces before running anything
        let t = SystemKind::Dgx1.build();
        let neg_start = parse_list("down:0:-1").unwrap();
        assert!(validate(&t, &neg_start).unwrap_err().to_string().contains("start must be"));
        let zero_dur = parse_list("link:0:0.5:0:0").unwrap();
        assert!(validate(&t, &zero_dur).is_ok(), "zero duration is a validated no-op");
        let mut sim = Sim::new(&t);
        apply(&mut sim, &zero_dur);
        assert!(sim.cap_events.is_empty(), "zero-duration window must emit nothing");
    }

    #[test]
    fn every_label_form_round_trips_through_spec() {
        let t = SystemKind::Dgx1.build();
        let all_forms = [
            Perturbation::scale(3, 0.5),
            Perturbation::scale(3, 0.5).during(0.001, 0.25),
            Perturbation::floor(2, (1u64 << 30) as f64),
            Perturbation::floor(2, (1u64 << 30) as f64).during(0.5, 1.5),
            Perturbation::straggler(0, 0.25),
            Perturbation::straggler(7, 0.75).during(0.125, 0.25),
            Perturbation::link_down(1),
            Perturbation::link_down(1).during(0.001, 0.01),
            Perturbation::gpu_down(4),
            Perturbation::gpu_down(4).during(0.25, f64::INFINITY),
        ];
        for p in all_forms {
            let parsed = parse_list(&p.spec()).unwrap_or_else(|e| {
                panic!("`{}` (from {:?}) did not parse: {e:#}", p.spec(), p)
            });
            assert_eq!(parsed, vec![p], "spec `{}`", p.spec());
            assert!(!p.label().is_empty());
            validate(&t, &[p]).unwrap();
        }
        // the comma-joined set round-trips as a list too
        let joined: String =
            all_forms.iter().map(|p| p.spec()).collect::<Vec<_>>().join(",");
        assert_eq!(parse_list(&joined).unwrap(), all_forms.to_vec());
        // label forms are distinct and human-scannable
        assert_eq!(Perturbation::link_down(1).label(), "link1 DOWN");
        assert_eq!(Perturbation::gpu_down(4).label(), "gpu4 DOWN");
    }

    #[test]
    fn floor_on_ib_uplink_is_the_cluster_bottleneck() {
        // drop one node's IB leaf link to 1 GB/s: every library's 8-rank
        // collective slows (all schedules move bytes through that node)
        let t = SystemKind::Cluster.build();
        let ib = (0..t.links.len())
            .find(|&l| t.links[l].class == LinkClass::InfinibandFdr)
            .expect("cluster has IB links");
        let counts = vec![4u64 << 20; 8];
        for lib in Library::all() {
            let healthy = run_allgatherv(lib, &t, &counts);
            let degraded = perturbed_allgatherv(
                &t,
                lib,
                Params::default(),
                &counts,
                &[Perturbation::floor(ib, 1.0e9)],
            );
            assert!(
                degraded.time > healthy.time,
                "{}: degraded {} !> healthy {}",
                lib.name(),
                degraded.time,
                healthy.time
            );
        }
    }
}
