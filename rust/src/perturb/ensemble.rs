//! Seeded Monte-Carlo perturbation ensembles (DESIGN.md §12).
//!
//! A robust selection or fragility study needs a *distribution* over
//! fault scenarios, not one hand-picked case. [`ensemble`] draws
//! `scenarios` independent perturbation sets from an [`EnsembleCfg`]:
//! per scenario, `degraded_links` distinct links scaled by a severity
//! factor drawn uniformly from `severity`, plus (with probability
//! `straggler_prob`) one straggler GPU. Windows are static
//! (`[0, INFINITY)`) unless `window > 0`, in which case starts are
//! uniform in `[0, window)` and lengths uniform in `duration` — the
//! time-varying-bandwidth regime for workload runs.
//!
//! Everything derives from the seed through per-scenario
//! [`crate::util::prng::Rng`] forks keyed by the scenario index, so an
//! ensemble replays bit-identically (`tests/faults_properties.rs` pins
//! this) and scenario k does not depend on how many scenarios follow it.

use crate::topology::Topology;
use crate::util::prng::Rng;

use super::Perturbation;

/// Parameters of a Monte-Carlo perturbation ensemble.
#[derive(Clone, Copy, Debug)]
pub struct EnsembleCfg {
    /// Number of independent scenarios to draw.
    pub scenarios: usize,
    /// Master seed; every draw derives from it deterministically.
    pub seed: u64,
    /// Distinct degraded links per scenario.
    pub degraded_links: usize,
    /// Probability a scenario also has one straggler GPU.
    pub straggler_prob: f64,
    /// Severity range: capacity scale factors drawn uniformly from
    /// `[severity.0, severity.1)` (lower = more severe).
    pub severity: (f64, f64),
    /// Start-time window: 0.0 = static faults from t=0; > 0 draws each
    /// fault's start uniformly from `[0, window)`.
    pub window: f64,
    /// Fault length range (seconds), used only when `window > 0`.
    pub duration: (f64, f64),
    /// Probability a scenario also has one **hard link outage**
    /// ([`Perturbation::LinkDown`]); 0.0 (the default) draws none and
    /// keeps every pre-outage ensemble bit-identical.
    pub outage_prob: f64,
    /// Outage length range (seconds). Ensemble outages are always
    /// transient — a permanent outage is a hand-written scenario, not a
    /// Monte-Carlo draw (the recovery layer is what handles those).
    pub outage_duration: (f64, f64),
}

impl EnsembleCfg {
    /// A small static-fault ensemble: 8 scenarios, one degraded link
    /// each (severity 0.25..0.9), straggler in half of them — the
    /// default behind `--robust` and the `agv faults` fragility study.
    pub fn quick(seed: u64) -> EnsembleCfg {
        EnsembleCfg {
            scenarios: 8,
            seed,
            degraded_links: 1,
            straggler_prob: 0.5,
            severity: (0.25, 0.9),
            window: 0.0,
            duration: (0.0, 0.0),
            outage_prob: 0.0,
            outage_duration: (0.0, 0.0),
        }
    }

    /// `quick` with an explicit scenario count.
    pub fn with_scenarios(mut self, scenarios: usize) -> EnsembleCfg {
        self.scenarios = scenarios;
        self
    }

    /// Add transient hard link outages: each scenario gains one
    /// [`Perturbation::LinkDown`] with probability `prob`, lasting
    /// uniformly within `duration` seconds — the outage-ensemble regime
    /// behind `agv faults --outage` and outage-aware robust selection.
    pub fn with_outages(mut self, prob: f64, duration: (f64, f64)) -> EnsembleCfg {
        self.outage_prob = prob;
        self.outage_duration = duration;
        self
    }
}

/// Draw the ensemble over a topology. Scenario `k` is a function of
/// `(cfg.seed, k)` alone — deterministic and index-stable.
pub fn ensemble(topo: &Topology, cfg: &EnsembleCfg) -> Vec<Vec<Perturbation>> {
    assert!(cfg.scenarios >= 1, "ensemble needs at least one scenario");
    assert!(
        cfg.severity.0 > 0.0 && cfg.severity.1 >= cfg.severity.0,
        "severity range must be positive and ordered, got {:?}",
        cfg.severity
    );
    let links = topo.links.len() as u64;
    let gpus = topo.num_gpus() as u64;
    (0..cfg.scenarios)
        .map(|k| {
            // keyed directly by (seed, index): independent of scenario count
            let mut rng = Rng::new(
                cfg.seed ^ (k as u64).wrapping_mul(0xD1B5_4A32_D192_ED03),
            );
            let mut perts = Vec::new();
            let mut window = |rng: &mut Rng| -> (f64, f64) {
                if cfg.window > 0.0 {
                    let start = rng.gen_f64(0.0, cfg.window);
                    let dur = if cfg.duration.1 > cfg.duration.0 {
                        rng.gen_f64(cfg.duration.0, cfg.duration.1)
                    } else {
                        cfg.duration.0.max(0.0)
                    };
                    (start, dur)
                } else {
                    (0.0, f64::INFINITY)
                }
            };
            let n_links = (cfg.degraded_links as u64).min(links) as usize;
            let mut chosen: Vec<u64> = Vec::with_capacity(n_links);
            while chosen.len() < n_links {
                let l = rng.gen_range(links);
                if !chosen.contains(&l) {
                    chosen.push(l);
                }
            }
            for l in chosen {
                let factor = severity(&mut rng, cfg);
                let (start, duration) = window(&mut rng);
                perts.push(Perturbation::LinkScale { link: l as usize, factor, start, duration });
            }
            if cfg.straggler_prob > 0.0 && rng.next_f64() < cfg.straggler_prob {
                let rank = rng.gen_range(gpus) as usize;
                let factor = severity(&mut rng, cfg);
                let (start, duration) = window(&mut rng);
                perts.push(Perturbation::Straggler { rank, factor, start, duration });
            }
            // outages draw last, and only when enabled: a pre-outage
            // config consumes exactly the same random stream as before,
            // so every existing ensemble replays bit-identically
            if cfg.outage_prob > 0.0 && rng.next_f64() < cfg.outage_prob {
                let link = rng.gen_range(links) as usize;
                let start =
                    if cfg.window > 0.0 { rng.gen_f64(0.0, cfg.window) } else { 0.0 };
                let duration = if cfg.outage_duration.1 > cfg.outage_duration.0 {
                    rng.gen_f64(cfg.outage_duration.0, cfg.outage_duration.1)
                } else {
                    cfg.outage_duration.0.max(0.0)
                };
                perts.push(Perturbation::LinkDown { link, start, duration });
            }
            perts
        })
        .collect()
}

fn severity(rng: &mut Rng, cfg: &EnsembleCfg) -> f64 {
    // Draw unconditionally: a collapsed severity range must consume
    // exactly one random like a genuine one, otherwise every subsequent
    // draw in the scenario (windows, durations, straggler and outage
    // coin-flips) shifts when the range degenerates. The draw is
    // *discarded*, never skipped, when there is nothing to draw from.
    let draw = rng.gen_f64(cfg.severity.0, cfg.severity.1);
    if cfg.severity.1 > cfg.severity.0 {
        draw
    } else {
        cfg.severity.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perturb::validate;
    use crate::topology::systems::SystemKind;

    #[test]
    fn ensembles_are_deterministic_and_valid() {
        for kind in SystemKind::all() {
            let topo = kind.build();
            let cfg = EnsembleCfg::quick(17);
            let a = ensemble(&topo, &cfg);
            let b = ensemble(&topo, &cfg);
            assert_eq!(a, b, "{}: same seed diverged", topo.name);
            assert_eq!(a.len(), 8);
            for scenario in &a {
                assert!(!scenario.is_empty());
                validate(&topo, scenario).unwrap();
            }
            let c = ensemble(&topo, &EnsembleCfg::quick(18));
            assert_ne!(a, c, "{}: seed does not matter", topo.name);
        }
    }

    #[test]
    fn scenario_k_is_stable_under_count_changes() {
        let topo = SystemKind::Dgx1.build();
        let small = ensemble(&topo, &EnsembleCfg::quick(7).with_scenarios(3));
        let large = ensemble(&topo, &EnsembleCfg::quick(7).with_scenarios(9));
        assert_eq!(small[..], large[..3], "prefix changed with scenario count");
    }

    #[test]
    fn time_varying_windows_land_in_range() {
        let topo = SystemKind::Cluster.build();
        let cfg = EnsembleCfg {
            scenarios: 16,
            seed: 5,
            degraded_links: 2,
            straggler_prob: 1.0,
            severity: (0.3, 0.6),
            window: 0.01,
            duration: (0.001, 0.004),
            outage_prob: 0.0,
            outage_duration: (0.0, 0.0),
        };
        let e = ensemble(&topo, &cfg);
        let mut saw_straggler = false;
        for scenario in &e {
            assert_eq!(scenario.len(), 3, "2 links + 1 straggler");
            for p in scenario {
                let (start, dur) = p.window();
                assert!((0.0..0.01).contains(&start));
                assert!((0.001..0.004).contains(&dur));
                if matches!(p, Perturbation::Straggler { .. }) {
                    saw_straggler = true;
                }
            }
        }
        assert!(saw_straggler);
    }

    #[test]
    fn degenerate_severity_range_does_not_shift_the_stream() {
        // Regression: `severity` used to skip its draw entirely when
        // the range collapsed, so `severity: (0.5, 0.5)` shifted every
        // subsequent random in the scenario — different links, windows,
        // coin-flips. Scenario k must now be identical in every
        // non-severity field between a collapsed and a genuine range.
        let topo = SystemKind::Dgx1.build();
        let mut degenerate = EnsembleCfg::quick(11);
        degenerate.severity = (0.5, 0.5);
        degenerate.window = 0.01;
        degenerate.duration = (0.001, 0.004);
        degenerate = degenerate.with_outages(0.5, (0.001, 0.002));
        let mut ranged = degenerate;
        ranged.severity = (0.5, 0.9);
        let a = ensemble(&topo, &degenerate);
        let b = ensemble(&topo, &ranged);
        assert_eq!(a.len(), b.len());
        for (k, (sa, sb)) in a.iter().zip(&b).enumerate() {
            assert_eq!(sa.len(), sb.len(), "scenario {k}: draw structure diverged");
            for (pa, pb) in sa.iter().zip(sb) {
                match (pa, pb) {
                    (
                        Perturbation::LinkScale { link: la, factor: fa, start: ta, duration: da },
                        Perturbation::LinkScale { link: lb, factor: fb, start: tb, duration: db },
                    ) => {
                        assert_eq!(la, lb, "scenario {k}: degraded link shifted");
                        assert_eq!(*fa, 0.5, "collapsed range must yield its lower bound");
                        assert!((0.5..0.9).contains(fb));
                        assert_eq!(ta.to_bits(), tb.to_bits(), "scenario {k}: window start");
                        assert_eq!(da.to_bits(), db.to_bits(), "scenario {k}: window length");
                    }
                    (
                        Perturbation::Straggler { rank: ra, factor: fa, start: ta, duration: da },
                        Perturbation::Straggler { rank: rb, factor: _, start: tb, duration: db },
                    ) => {
                        assert_eq!(ra, rb, "scenario {k}: straggler rank shifted");
                        assert_eq!(*fa, 0.5);
                        assert_eq!(ta.to_bits(), tb.to_bits());
                        assert_eq!(da.to_bits(), db.to_bits());
                    }
                    (
                        Perturbation::LinkDown { link: la, start: ta, duration: da },
                        Perturbation::LinkDown { link: lb, start: tb, duration: db },
                    ) => {
                        assert_eq!(la, lb, "scenario {k}: outage link shifted");
                        assert_eq!(ta.to_bits(), tb.to_bits());
                        assert_eq!(da.to_bits(), db.to_bits());
                    }
                    other => panic!("scenario {k}: perturbation kind shifted: {other:?}"),
                }
            }
        }
    }

    #[test]
    fn outage_draws_extend_without_disturbing_the_prefix() {
        // enabling outages must not change the scale/straggler draws a
        // config produced before the knob existed: the outage draw
        // consumes randoms only after every existing draw
        let topo = SystemKind::Dgx1.build();
        let plain = ensemble(&topo, &EnsembleCfg::quick(9));
        let outaged =
            ensemble(&topo, &EnsembleCfg::quick(9).with_outages(1.0, (0.001, 0.002)));
        assert_eq!(plain.len(), outaged.len());
        let mut saw_outage = false;
        for (a, b) in plain.iter().zip(&outaged) {
            assert_eq!(a[..], b[..a.len()], "pre-outage draws disturbed");
            for p in &b[a.len()..] {
                match *p {
                    Perturbation::LinkDown { link, start, duration } => {
                        saw_outage = true;
                        assert!(link < topo.links.len());
                        assert_eq!(start, 0.0, "static config: outage at t=0");
                        assert!((0.001..0.002).contains(&duration));
                    }
                    ref other => panic!("unexpected extra draw {other:?}"),
                }
            }
            validate(&topo, b).unwrap();
        }
        assert!(saw_outage, "outage_prob 1.0 drew no outage");
    }
}
