//! Message-size distribution benchmark — the Träff et al. extension the
//! paper's future work proposes ("incorporate the message size
//! distribution benchmarks developed by Träff et al. into a GPU-based
//! benchmark", §VI).
//!
//! Where the OSU benchmark sends one fixed size per sweep point, this
//! harness fixes the *total* volume and varies how it is distributed
//! across ranks — isolating the irregularity dimension that the tensor
//! case study exposes, on a controlled synthetic workload.

use crate::comm::{Library, Params};
use crate::topology::Topology;
use crate::util::prng::Rng;
use crate::util::stats::Summary;

/// Träff-style message-size distributions over P ranks with fixed total.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Distribution {
    /// every rank contributes total/P (the OSU regime)
    Uniform,
    /// counts grow linearly: rank r gets ~2(r+1)/(P(P+1)) of the total
    Linear,
    /// counts halve rank to rank (heavy head)
    Geometric,
    /// one rank holds `spike_frac` of the total, the rest share evenly —
    /// the dominant-block shape of NELL-1/DELICIOUS modes
    Spike,
    /// random Zipf-weighted shuffle (seeded, deterministic)
    RandomZipf,
}

impl Distribution {
    /// Report label of the distribution.
    pub fn name(self) -> &'static str {
        match self {
            Distribution::Uniform => "uniform",
            Distribution::Linear => "linear",
            Distribution::Geometric => "geometric",
            Distribution::Spike => "spike",
            Distribution::RandomZipf => "random-zipf",
        }
    }

    /// Parse a distribution name as printed by [`Distribution::name`]
    /// (the `agv workload --dist` flag).
    pub fn parse(s: &str) -> Option<Distribution> {
        match s.to_ascii_lowercase().as_str() {
            "uniform" => Some(Distribution::Uniform),
            "linear" => Some(Distribution::Linear),
            "geometric" => Some(Distribution::Geometric),
            "spike" => Some(Distribution::Spike),
            "random-zipf" | "randomzipf" | "zipf" => Some(Distribution::RandomZipf),
            _ => None,
        }
    }

    /// All distributions, mildest first.
    pub fn all() -> [Distribution; 5] {
        [
            Distribution::Uniform,
            Distribution::Linear,
            Distribution::Geometric,
            Distribution::Spike,
            Distribution::RandomZipf,
        ]
    }

    /// Per-rank counts summing (approximately, by rounding) to `total`.
    pub fn counts(self, p: usize, total: u64, seed: u64) -> Vec<u64> {
        assert!(p >= 1);
        match self {
            Distribution::Uniform => vec![total / p as u64; p],
            Distribution::Linear => {
                let denom = (p * (p + 1) / 2) as f64;
                (0..p)
                    .map(|r| ((r + 1) as f64 / denom * total as f64) as u64)
                    .collect()
            }
            Distribution::Geometric => {
                let norm: f64 = (0..p).map(|r| 0.5f64.powi(r as i32)).sum();
                (0..p)
                    .map(|r| (0.5f64.powi(r as i32) / norm * total as f64) as u64)
                    .collect()
            }
            Distribution::Spike => {
                let spike = (0.75 * total as f64) as u64;
                let rest = (total - spike) / (p as u64 - 1).max(1);
                let mut c = vec![rest; p];
                c[0] = spike;
                c
            }
            Distribution::RandomZipf => {
                let mut rng = Rng::new(seed);
                let mut weights: Vec<f64> =
                    (0..p).map(|r| 1.0 / (r + 1) as f64).collect();
                rng.shuffle(&mut weights);
                let norm: f64 = weights.iter().sum();
                weights
                    .iter()
                    .map(|w| (w / norm * total as f64) as u64)
                    .collect()
            }
        }
    }
}

/// One measured cell of the distribution study.
#[derive(Clone, Debug)]
pub struct DistPoint {
    /// Which distribution generated the counts.
    pub dist: Distribution,
    /// Which library ran the collective.
    pub library: Library,
    /// Simulated collective time in seconds.
    pub time: f64,
    /// CV of the counts actually used (the irregularity knob)
    pub cv: f64,
}

/// Run every (distribution x library) cell at a fixed total volume.
pub fn distribution_study(
    topo: &Topology,
    gpus: usize,
    total: u64,
    params: Params,
    seed: u64,
) -> Vec<DistPoint> {
    let mut out = Vec::new();
    for dist in Distribution::all() {
        let counts = dist.counts(gpus, total, seed);
        let cv = Summary::of(&counts.iter().map(|&c| c as f64).collect::<Vec<_>>()).cv;
        for lib in Library::all() {
            let r = lib.build(params).allgatherv(topo, &counts);
            out.push(DistPoint { dist, library: lib, time: r.time, cv });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::systems::dgx1;

    #[test]
    fn counts_sum_close_to_total() {
        let total = 256 << 20;
        for d in Distribution::all() {
            let c = d.counts(8, total, 7);
            let sum: u64 = c.iter().sum();
            let rel = (sum as f64 - total as f64).abs() / total as f64;
            assert!(rel < 0.01, "{}: sum {sum}", d.name());
            assert_eq!(c.len(), 8);
        }
    }

    #[test]
    fn irregularity_ordering() {
        let total = 256 << 20;
        let cv = |d: Distribution| {
            let c = d.counts(8, total, 7);
            Summary::of(&c.iter().map(|&x| x as f64).collect::<Vec<_>>()).cv
        };
        assert_eq!(cv(Distribution::Uniform), 0.0);
        assert!(cv(Distribution::Linear) > 0.0);
        assert!(cv(Distribution::Spike) > cv(Distribution::Linear));
        assert!(cv(Distribution::Geometric) > cv(Distribution::Linear));
    }

    #[test]
    fn irregular_distributions_favor_nccl_on_dgx1() {
        // the controlled version of the Fig. 3 finding: at equal total
        // volume, growing irregularity moves the MPI-CUDA/NCCL ratio in
        // NCCL's favor (ring step barriers vs pipelined broadcasts)
        let topo = dgx1();
        let study = distribution_study(&topo, 8, 512 << 20, Params::default(), 3);
        let ratio = |d: Distribution| {
            let t = |l: Library| {
                study
                    .iter()
                    .find(|p| p.dist == d && p.library == l)
                    .unwrap()
                    .time
            };
            t(Library::MpiCuda) / t(Library::Nccl)
        };
        assert!(
            ratio(Distribution::Spike) > ratio(Distribution::Uniform),
            "spike {} !> uniform {}",
            ratio(Distribution::Spike),
            ratio(Distribution::Uniform)
        );
    }

    #[test]
    fn parse_roundtrip() {
        for d in Distribution::all() {
            assert_eq!(Distribution::parse(d.name()), Some(d));
        }
        assert_eq!(Distribution::parse("zipf"), Some(Distribution::RandomZipf));
        assert_eq!(Distribution::parse("nope"), None);
    }

    #[test]
    fn deterministic_random_zipf() {
        let a = Distribution::RandomZipf.counts(8, 1 << 30, 5);
        let b = Distribution::RandomZipf.counts(8, 1 << 30, 5);
        assert_eq!(a, b);
        let c = Distribution::RandomZipf.counts(8, 1 << 30, 6);
        assert_ne!(a, c);
    }
}
