//! OSU micro-benchmark port for Allgatherv (paper §V-B, Fig. 2).
//!
//! The OSU benchmark sends *fixed-size* messages to and from every rank:
//! per-rank message size M, N ranks, total volume M x N. The paper caps
//! the total maximum volume at 1024 MB and sweeps M from 4 KB up to
//! (1024 / N) MB; we reproduce that sweep for every (system, library,
//! GPU-count) combination of Fig. 2. The paper's NCCL entry is the
//! Listing-1 bcast-series (our [`crate::comm::nccl`] does exactly that),
//! which is also how we "extended the OSU benchmark to allow for NCCL".
//!
//! [`distributions`] adds the Träff-style message-size-distribution
//! variant the paper lists as future work.

pub mod distributions;

use crate::comm::{CommResult, Library, Params};
use crate::topology::systems::SystemKind;
use crate::topology::Topology;

/// Benchmark configuration mirroring the paper's setup.
#[derive(Clone, Copy, Debug)]
pub struct OsuConfig {
    /// Cap on M x N (paper: 1024 MB).
    pub total_volume_cap: u64,
    /// Smallest per-rank message (paper: 4 KB).
    pub min_msg: u64,
    /// Protocol parameters handed to every library model.
    pub params: Params,
}

impl Default for OsuConfig {
    fn default() -> OsuConfig {
        OsuConfig {
            total_volume_cap: 1024 << 20,
            min_msg: 4 << 10,
            params: Params::default(),
        }
    }
}

/// One measured point: per-rank message size -> total communication time.
#[derive(Clone, Copy, Debug)]
pub struct OsuPoint {
    /// Per-rank message size in bytes.
    pub msg_size: u64,
    /// Total simulated collective time in seconds.
    pub time: f64,
    /// Point-to-point flows the simulation executed.
    pub flows: usize,
}

/// The message-size sweep for N ranks: powers of two from `min_msg` to
/// (total_volume_cap / N).
pub fn sweep_sizes(cfg: &OsuConfig, n: usize) -> Vec<u64> {
    let max = cfg.total_volume_cap / n as u64;
    let mut sizes = Vec::new();
    let mut m = cfg.min_msg;
    while m <= max {
        sizes.push(m);
        m *= 2;
    }
    sizes
}

/// Run the benchmark for one (topology, library, GPU count) combination.
pub fn run_osu(cfg: &OsuConfig, topo: &Topology, lib: Library, gpus: usize) -> Vec<OsuPoint> {
    assert!(gpus >= 1 && gpus <= topo.num_gpus());
    let library = lib.build(cfg.params);
    sweep_sizes(cfg, gpus)
        .into_iter()
        .map(|m| {
            let counts = vec![m; gpus];
            let CommResult { time, flows } = library.allgatherv(topo, &counts);
            OsuPoint { msg_size: m, time, flows }
        })
        .collect()
}

/// The auto-selection variant of [`run_osu`]: per message size the
/// [`crate::comm::select::AlgoSelector`] picks the fastest
/// (library, algorithm) pair for that size's regular count vector; the
/// winner typically flips across the sweep (log-step schedules at
/// small sizes, bandwidth-optimal ones at large) — the paper's
/// "no single library wins" finding per point. The sweep is the
/// decision table's home workload: consecutive sizes share an
/// irregularity bucket, so most points ride the cached shortlist
/// (which still never loses to a fixed library).
pub fn run_osu_auto(
    cfg: &OsuConfig,
    topo: &Topology,
    gpus: usize,
) -> Vec<(OsuPoint, crate::comm::select::Candidate)> {
    assert!(gpus >= 1 && gpus <= topo.num_gpus());
    let mut selector = crate::comm::select::AlgoSelector::new(cfg.params);
    sweep_sizes(cfg, gpus)
        .into_iter()
        .map(|m| {
            let counts = vec![m; gpus];
            let sel = selector.select(topo, &counts);
            (OsuPoint { msg_size: m, time: sel.time, flows: sel.flows }, sel.candidate)
        })
        .collect()
}

/// A full Fig. 2 cell: all three libraries on one system at one GPU count.
#[derive(Clone, Debug)]
pub struct Fig2Cell {
    /// Which system the cell belongs to.
    pub system: SystemKind,
    /// GPU count of the cell.
    pub gpus: usize,
    /// One sweep per library.
    pub series: Vec<(Library, Vec<OsuPoint>)>,
}

/// The GPU counts the paper plots per system (2 and 8 everywhere; 16 on
/// the cluster and CS-Storm).
pub fn gpu_counts(system: SystemKind) -> Vec<usize> {
    match system {
        SystemKind::Dgx1 => vec![2, 8],
        _ => vec![2, 8, 16],
    }
}

/// Reproduce the whole Fig. 2 grid. Cells fan out over the bounded
/// worker pool ([`crate::util::pool`]) — each (system, GPU-count) cell
/// is an independent pure simulation.
pub fn fig2_grid(cfg: &OsuConfig) -> Vec<Fig2Cell> {
    let mut jobs: Vec<Box<dyn FnOnce() -> Fig2Cell + Send>> = Vec::new();
    for system in SystemKind::all() {
        for gpus in gpu_counts(system) {
            let cfg = *cfg;
            jobs.push(Box::new(move || {
                let topo = system.build();
                let series = Library::all()
                    .into_iter()
                    .map(|lib| (lib, run_osu(&cfg, &topo, lib, gpus)))
                    .collect();
                Fig2Cell { system, gpus, series }
            }));
        }
    }
    crate::util::pool::parallel_map(jobs)
}

/// Serial variant of [`fig2_grid`] for callers that must avoid worker
/// threads (single-threaded profiling, engine A/B comparisons through
/// the thread-local reference override).
pub fn fig2_grid_serial(cfg: &OsuConfig) -> Vec<Fig2Cell> {
    let mut cells = Vec::new();
    for system in SystemKind::all() {
        let topo = system.build();
        for gpus in gpu_counts(system) {
            let series = Library::all()
                .into_iter()
                .map(|lib| (lib, run_osu(cfg, &topo, lib, gpus)))
                .collect();
            cells.push(Fig2Cell { system, gpus, series });
        }
    }
    cells
}

impl Fig2Cell {
    /// The sweep points of one library (panics if absent).
    pub fn points(&self, lib: Library) -> &[OsuPoint] {
        &self
            .series
            .iter()
            .find(|(l, _)| *l == lib)
            .expect("library missing from cell")
            .1
    }

    /// Time ratio lib_a / lib_b at a given message size.
    pub fn ratio_at(&self, a: Library, b: Library, msg: u64) -> f64 {
        let ta = self.points(a).iter().find(|p| p.msg_size == msg).unwrap().time;
        let tb = self.points(b).iter().find(|p| p.msg_size == msg).unwrap().time;
        ta / tb
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_respects_cap() {
        let cfg = OsuConfig::default();
        let sizes = sweep_sizes(&cfg, 8);
        assert_eq!(*sizes.first().unwrap(), 4 << 10);
        assert_eq!(*sizes.last().unwrap(), 128 << 20); // 1024/8 MB
        for w in sizes.windows(2) {
            assert_eq!(w[1], w[0] * 2);
        }
        // 16 ranks -> 64 MB max
        assert_eq!(*sweep_sizes(&cfg, 16).last().unwrap(), 64 << 20);
    }

    #[test]
    fn osu_runs_all_libraries_on_dgx1() {
        let cfg = OsuConfig::default();
        let topo = SystemKind::Dgx1.build();
        for lib in Library::all() {
            let pts = run_osu(&cfg, &topo, lib, 2);
            assert!(!pts.is_empty());
            // times monotone-ish in size: last > first
            assert!(pts.last().unwrap().time > pts.first().unwrap().time);
        }
    }

    #[test]
    fn osu_auto_never_loses_to_any_fixed_sweep() {
        let cfg = OsuConfig::default();
        let topo = SystemKind::Dgx1.build();
        let auto = run_osu_auto(&cfg, &topo, 4);
        let fixed: Vec<Vec<OsuPoint>> = Library::all()
            .into_iter()
            .map(|lib| run_osu(&cfg, &topo, lib, 4))
            .collect();
        assert_eq!(auto.len(), fixed[0].len());
        for (i, (pt, cand)) in auto.iter().enumerate() {
            for f in &fixed {
                assert!(
                    pt.time <= f[i].time,
                    "size {}: auto {} ({}) slower than fixed {}",
                    pt.msg_size, pt.time, cand.label(), f[i].time
                );
            }
        }
    }

    #[test]
    fn gpu_counts_match_paper() {
        assert_eq!(gpu_counts(SystemKind::Dgx1), vec![2, 8]);
        assert_eq!(gpu_counts(SystemKind::Cluster), vec![2, 8, 16]);
        assert_eq!(gpu_counts(SystemKind::CsStorm), vec![2, 8, 16]);
    }
}
