//! Table I: properties of the data sets — dimensions, nonzeros, and the
//! Allgatherv message statistics (avg / min / max / CV) at 2 and 8 GPUs,
//! printed next to the paper's reported values.

use crate::tensor::datasets;
use crate::tensor::messages::MsgStats;

/// Paper-reported Table I values for side-by-side comparison.
/// (name, [avg2, avg8], [min2, max2], [min8, max8], [cv2, cv8]) — MB.
pub const PAPER: &[(&str, [f64; 2], [f64; 2], [f64; 2], [f64; 2])] = &[
    ("NETFLIX", [6.4, 1.6], [0.04, 26.5], [0.01, 13.5], [1.5, 1.84]),
    ("AMAZON", [65.2, 16.3], [24.6, 89.5], [5.9, 23.7], [0.44, 0.44]),
    ("DELICIOUS", [128.9, 32.2], [0.2, 496.2], [0.006, 152.4], [1.35, 1.48]),
    ("NELL-1", [291.3, 72.8], [61.3, 729.8], [14.7, 183.5], [1.06, 1.06]),
];

/// Full Table I row: ours and the paper's.
#[derive(Clone, Debug)]
pub struct Table1Row {
    /// Data-set name.
    pub name: &'static str,
    /// Mode dimensions.
    pub dims: [u64; 3],
    /// Nonzero count.
    pub nnz: u64,
    /// Our measured statistics at 2 and 8 GPUs.
    pub ours: [MsgStats; 2],
}

/// Compute every Table I row from the calibrated profiles.
pub fn rows() -> Vec<Table1Row> {
    datasets::all()
        .into_iter()
        .map(|d| Table1Row {
            name: d.name,
            dims: d.dims(),
            nnz: d.nnz,
            ours: [MsgStats::of(&d, 2), MsgStats::of(&d, 8)],
        })
        .collect()
}

fn dims_str(d: [u64; 3]) -> String {
    fn h(x: u64) -> String {
        if x >= 1_000_000 {
            format!("{}M", (x as f64 / 1e6).round() as u64)
        } else {
            format!("{}K", x / 1000)
        }
    }
    format!("{} x {} x {}", h(d[0]), h(d[1]), h(d[2]))
}

/// Render the table (ours vs paper).
pub fn render() -> String {
    let mut out = String::new();
    out.push_str("TABLE I — PROPERTIES OF DATA SETS (ours | paper), R=16, f32\n");
    out.push_str(&format!(
        "{:<10} {:<16} {:>6}  {:>18} {:>18}  {:>26} {:>26}  {:>13} {:>13}\n",
        "Name", "Dimensions", "NNZ",
        "Avg 2GPU (MB)", "Avg 8GPU (MB)",
        "Min/Max 2GPU (MB)", "Min/Max 8GPU (MB)",
        "CV 2GPU", "CV 8GPU",
    ));
    for (row, paper) in rows().iter().zip(PAPER) {
        assert_eq!(row.name, paper.0);
        let s2 = &row.ours[0];
        let s8 = &row.ours[1];
        out.push_str(&format!(
            "{:<10} {:<16} {:>5}M  {:>8.1} | {:<7.1} {:>8.1} | {:<7.1}  {:>11} | {:<12} {:>11} | {:<12}  {:>5.2} | {:<5.2} {:>5.2} | {:<5.2}\n",
            row.name,
            dims_str(row.dims),
            row.nnz / 1_000_000,
            s2.avg_mb(), paper.1[0],
            s8.avg_mb(), paper.1[1],
            format!("{:.2}/{:.1}", s2.min_mb(), s2.max_mb()),
            format!("{:.2}/{:.1}", paper.2[0], paper.2[1]),
            format!("{:.3}/{:.1}", s8.min_mb(), s8.max_mb()),
            format!("{:.3}/{:.1}", paper.3[0], paper.3[1]),
            s2.cv(), paper.4[0],
            s8.cv(), paper.4[1],
        ));
    }
    out
}

/// CSV of ours-vs-paper.
pub fn csv() -> String {
    let mut out = String::from(
        "dataset,gpus,avg_mb,min_mb,max_mb,cv,paper_avg_mb,paper_min_mb,paper_max_mb,paper_cv\n",
    );
    for (row, paper) in rows().iter().zip(PAPER) {
        for (gi, gpus) in [2usize, 8].iter().enumerate() {
            let s = &row.ours[gi];
            let (pavg, pcv) = (paper.1[gi], paper.4[gi]);
            let (pmin, pmax) = if gi == 0 {
                (paper.2[0], paper.2[1])
            } else {
                (paper.3[0], paper.3[1])
            };
            out.push_str(&format!(
                "{},{},{:.3},{:.4},{:.2},{:.3},{},{},{},{}\n",
                row.name, gpus, s.avg_mb(), s.min_mb(), s.max_mb(), s.cv(),
                pavg, pmin, pmax, pcv,
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_datasets() {
        let t = render();
        for name in ["NETFLIX", "AMAZON", "DELICIOUS", "NELL-1"] {
            assert!(t.contains(name), "{name} missing");
        }
        assert!(t.contains("480K"));
    }

    #[test]
    fn csv_has_8_rows() {
        let c = csv();
        assert_eq!(c.trim().lines().count(), 9); // header + 4x2
    }

    #[test]
    fn paper_reference_is_table1() {
        assert_eq!(PAPER.len(), 4);
        assert_eq!(PAPER[2].0, "DELICIOUS");
        assert!((PAPER[2].2[1] - 496.2).abs() < 1e-9);
    }
}
