//! Fig. 2: OSU Allgatherv total communication time vs per-rank message
//! size, per system / library / GPU count.

use crate::comm::Library;
use crate::osu::{fig2_grid, fig2_grid_serial, Fig2Cell, OsuConfig};
use crate::util::plot::{log_log_chart, to_csv, Series};

/// Build the grid (parallel over cells, bounded worker pool).
pub fn grid() -> Vec<Fig2Cell> {
    fig2_grid(&OsuConfig::default())
}

/// Serial version used when thread spawning is undesirable (benches,
/// engine A/B runs through the thread-local reference override).
pub fn grid_serial() -> Vec<Fig2Cell> {
    fig2_grid_serial(&OsuConfig::default())
}

fn cell_series(cell: &Fig2Cell) -> Vec<Series> {
    cell.series
        .iter()
        .map(|(lib, pts)| {
            Series::new(
                lib.name(),
                pts.iter().map(|p| (p.msg_size as f64, p.time)).collect(),
            )
        })
        .collect()
}

/// ASCII rendering of the whole figure.
pub fn render(cells: &[Fig2Cell]) -> String {
    let mut out = String::from(
        "FIG. 2 — OSU Allgatherv: total communication time vs per-rank message size\n\n",
    );
    for cell in cells {
        let title = format!("{} — {} GPUs", cell.system.name(), cell.gpus);
        out.push_str(&log_log_chart(
            &title,
            "per-rank message size (bytes)",
            "total time (s)",
            &cell_series(cell),
            64,
            14,
        ));
        // numeric rows, like the benchmark's own output
        out.push_str(&format!(
            "  {:>10} {:>14} {:>14} {:>14}\n",
            "size", "MPI", "MPI-CUDA", "NCCL"
        ));
        let mpi = cell.points(Library::Mpi);
        let cuda = cell.points(Library::MpiCuda);
        let nccl = cell.points(Library::Nccl);
        for i in 0..mpi.len() {
            out.push_str(&format!(
                "  {:>10} {:>14} {:>14} {:>14}\n",
                crate::util::fmt_bytes(mpi[i].msg_size),
                crate::util::fmt_time(mpi[i].time),
                crate::util::fmt_time(cuda[i].time),
                crate::util::fmt_time(nccl[i].time),
            ));
        }
        out.push('\n');
    }
    out
}

/// CSV per cell: one file's worth of text per (system, gpus).
pub fn csv(cell: &Fig2Cell) -> String {
    to_csv(&cell_series(cell))
}

/// File name the CLI writes a cell's CSV under.
pub fn csv_name(cell: &Fig2Cell) -> String {
    format!("fig2_{}_{}gpus.csv", cell.system.name(), cell.gpus)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_has_8_cells() {
        // cluster 2/8/16, dgx1 2/8, cs-storm 2/8/16
        let g = grid();
        assert_eq!(g.len(), 8);
    }

    #[test]
    fn render_contains_all_systems() {
        let g = grid();
        let r = render(&g[..2.min(g.len())]);
        assert!(r.contains("cluster"));
        assert!(r.contains("MPI-CUDA"));
    }
}
