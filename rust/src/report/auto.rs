//! Auto-selection study: the [`crate::comm::select::AlgoSelector`]'s
//! per-mode choice next to each fixed library, across the paper's data
//! sets, the three systems, and the §VI future-work multi-DGX — the
//! "no single library wins" finding (§V-B/§V-C) answered with a
//! per-call argmin. Rendered by `agv auto`.

use crate::comm::{CommLibrary, Library, Params};
use crate::cpals::comm_model::refacto_comm_auto;
use crate::tensor::messages::mode_counts;
use crate::tensor::TensorSpec;
use crate::topology::systems::{multi_dgx, SystemKind, SystemSpec};
use crate::topology::Topology;
use crate::util::{fmt_time, stats};

/// One (data set, system, gpus) row of the comparison.
#[derive(Clone, Debug)]
pub struct AutoRow {
    /// Data-set name (Table I).
    pub dataset: &'static str,
    /// System name the row was simulated on.
    pub system: String,
    /// Simulated GPU (rank) count.
    pub gpus: usize,
    /// One-iteration communication total per fixed library.
    pub fixed: Vec<(Library, f64)>,
    /// One-iteration total of the selector's per-mode choices.
    pub auto_time: f64,
    /// The winning candidate label per mode (e.g. "MPI-CUDA/hier-ring").
    pub auto_labels: [String; 3],
    /// Whether each mode's verdict came from the decision table.
    pub cached: [bool; 3],
    /// Decision-table hits across the row's three selector calls.
    pub cache_hits: usize,
    /// Decision-table misses across the row's three selector calls.
    pub cache_misses: usize,
}

impl AutoRow {
    /// Fastest fixed-library total of the row.
    pub fn best_fixed(&self) -> f64 {
        self.fixed.iter().map(|&(_, t)| t).fold(f64::INFINITY, f64::min)
    }
}

/// The systems of the study. Default (`system = None`): the paper's
/// three (with the Fig. 2 GPU counts) plus a 2-node multi-DGX at 16
/// GPUs, where the hierarchical schedules have a non-trivial grouping
/// to exploit. With an explicit `--system` spec the study runs on that
/// one system — paper GPU counts for paper systems, a single capped
/// rank count for the parametric fabrics (a full fat-tree would put
/// thousands of ranks in one collective row).
fn systems(system: Option<SystemSpec>) -> Vec<(String, Topology, Vec<usize>)> {
    match system {
        Some(spec) => {
            let topo = spec.build();
            let counts = match spec {
                SystemSpec::Paper(k) => crate::osu::gpu_counts(k),
                _ => vec![topo.num_gpus().min(16)],
            };
            vec![(spec.name(), topo, counts)]
        }
        None => {
            let mut out: Vec<(String, Topology, Vec<usize>)> = SystemKind::all()
                .into_iter()
                .map(|k| (k.name().to_string(), k.build(), crate::osu::gpu_counts(k)))
                .collect();
            out.push(("multi-dgx-2".to_string(), multi_dgx(2), vec![16]));
            out
        }
    }
}

/// Build the comparison grid for the given data sets, optionally
/// restricted to one GPU count and/or one system. Rows fan out over
/// the bounded worker pool — each is an independent pure simulation.
pub fn grid(
    specs: &[TensorSpec],
    gpus_filter: Option<usize>,
    system: Option<SystemSpec>,
) -> Vec<AutoRow> {
    let mut jobs: Vec<Box<dyn FnOnce() -> AutoRow + Send>> = Vec::new();
    for (name, topo, gpu_counts) in systems(system) {
        for &gpus in &gpu_counts {
            if gpus_filter.is_some_and(|g| g != gpus) {
                continue;
            }
            for spec in specs {
                let (name, topo, spec) = (name.clone(), topo.clone(), spec.clone());
                jobs.push(Box::new(move || row(&name, &topo, &spec, gpus)));
            }
        }
    }
    crate::util::pool::parallel_map(jobs)
}

fn row(system: &str, topo: &Topology, spec: &TensorSpec, gpus: usize) -> AutoRow {
    let params = Params::default();
    let counts = mode_counts(spec, gpus);
    let fixed: Vec<(Library, f64)> = Library::all()
        .into_iter()
        .map(|lib| {
            let l = lib.build(params);
            let total: f64 = counts.iter().map(|c| l.allgatherv(topo, c).time).sum();
            (lib, total)
        })
        .collect();
    let auto = refacto_comm_auto(topo, params, spec, gpus, 1);
    AutoRow {
        dataset: spec.name,
        system: system.to_string(),
        gpus,
        fixed,
        auto_time: auto.total_time,
        auto_labels: auto.per_mode.map(|s| s.candidate.label()),
        cached: auto.per_mode.map(|s| s.cached),
        cache_hits: auto.cache_hits,
        cache_misses: auto.cache_misses,
    }
}

/// Render the comparison as a text table with an aggregate footer.
pub fn render(rows: &[AutoRow]) -> String {
    let mut out = String::new();
    out.push_str(
        "AUTO-SELECTION vs FIXED LIBRARIES — simulated ReFacTo communication, one CP-ALS iteration\n",
    );
    out.push_str(&format!(
        "{:<10} {:<12} {:>4} {:>12} {:>12} {:>12} {:>12} {:>8}  choices (modes 0|1|2)\n",
        "dataset", "system", "gpus", "MPI", "MPI-CUDA", "NCCL", "auto", "vs best"
    ));
    let mut speedups = Vec::new();
    let mut wins = 0usize;
    for r in rows {
        let best = r.best_fixed();
        let speedup = best / r.auto_time;
        speedups.push(speedup);
        if r.auto_time <= best {
            wins += 1;
        }
        let t = |lib: Library| {
            r.fixed
                .iter()
                .find(|&&(l, _)| l == lib)
                .map(|&(_, t)| fmt_time(t))
                .unwrap_or_else(|| "-".to_string())
        };
        let choices: Vec<String> = r
            .auto_labels
            .iter()
            .zip(r.cached)
            .map(|(l, c)| if c { format!("{l}*") } else { l.clone() })
            .collect();
        out.push_str(&format!(
            "{:<10} {:<12} {:>4} {:>12} {:>12} {:>12} {:>12} {:>7.2}x  {}\n",
            r.dataset,
            r.system,
            r.gpus,
            t(Library::Mpi),
            t(Library::MpiCuda),
            t(Library::Nccl),
            fmt_time(r.auto_time),
            speedup,
            choices.join(" | "),
        ));
    }
    if !rows.is_empty() {
        let (hits, misses) = rows
            .iter()
            .fold((0usize, 0usize), |(h, m), r| (h + r.cache_hits, m + r.cache_misses));
        out.push_str(&format!(
            "\nauto matches or beats the best fixed library on {wins}/{} rows; \
             geomean speedup vs best fixed {:.2}x\n",
            rows.len(),
            stats::geomean(&speedups),
        ));
        out.push_str(&format!(
            "decision-table cache: {hits} hits / {misses} misses over {} selector calls \
             (* = verdict served from the table, time re-simulated)\n",
            hits + misses,
        ));
    }
    out
}

/// CSV form of the grid (one row per cell).
pub fn csv(rows: &[AutoRow]) -> String {
    let mut out = String::from(
        "dataset,system,gpus,mpi_s,mpi_cuda_s,nccl_s,auto_s,choice_mode0,choice_mode1,choice_mode2\n",
    );
    for r in rows {
        let t = |lib: Library| {
            r.fixed
                .iter()
                .find(|&&(l, _)| l == lib)
                .map(|&(_, t)| format!("{t:.9}"))
                .unwrap_or_default()
        };
        out.push_str(&format!(
            "{},{},{},{},{},{},{:.9},{},{},{}\n",
            r.dataset,
            r.system,
            r.gpus,
            t(Library::Mpi),
            t(Library::MpiCuda),
            t(Library::Nccl),
            r.auto_time,
            r.auto_labels[0],
            r.auto_labels[1],
            r.auto_labels[2],
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::datasets;

    #[test]
    fn single_cell_grid_renders_and_auto_wins() {
        let rows = grid(&[datasets::netflix()], Some(2), None);
        // three paper systems at 2 GPUs (multi-dgx only runs at 16)
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert!(r.auto_time > 0.0 && r.auto_time.is_finite());
            assert!(
                r.auto_time <= r.best_fixed(),
                "{} {}: auto {} vs best fixed {}",
                r.dataset, r.system, r.auto_time, r.best_fixed()
            );
            // three selector calls per row, each a table hit or miss,
            // and the per-mode cached flags agree with the counters
            assert_eq!(r.cache_hits + r.cache_misses, 3, "{} {}", r.dataset, r.system);
            assert_eq!(
                r.cached.iter().filter(|&&c| c).count(),
                r.cache_hits,
                "{} {}: cached flags disagree with cache_stats",
                r.dataset,
                r.system
            );
        }
        let text = render(&rows);
        assert!(text.contains("AUTO-SELECTION"));
        assert!(text.contains("NETFLIX"));
        assert!(text.contains("geomean"));
        assert!(text.contains("decision-table cache:"), "{text}");
        let c = csv(&rows);
        assert_eq!(c.lines().count(), 4);
        assert!(c.starts_with("dataset,"));
    }

    #[test]
    fn multi_dgx_rows_present_at_16() {
        let rows = grid(&[datasets::amazon()], Some(16), None);
        assert!(rows.iter().any(|r| r.system == "multi-dgx-2"));
        // every 16-GPU system except the DGX-1 (max 8) shows up
        assert!(rows.iter().any(|r| r.system == "cluster"));
        assert!(rows.iter().any(|r| r.system == "cs-storm"));
        assert!(!rows.iter().any(|r| r.system == "dgx1"));
    }

    #[test]
    fn system_override_restricts_the_grid_to_a_fabric() {
        let spec = SystemSpec::MultiPlanePod { nodes: 2, gpus: 4, rails: 2 };
        let rows = grid(&[datasets::netflix()], None, Some(spec));
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].system, "pod-2x4x2");
        assert_eq!(rows[0].gpus, 8);
        assert!(rows[0].auto_time > 0.0 && rows[0].auto_time <= rows[0].best_fixed());
    }
}
