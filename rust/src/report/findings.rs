//! The paper's §VI headline findings, recomputed from our reproduction:
//!
//! 1. "as much as a 8.3x difference in Allgatherv runtime between the
//!    DGX-1 and cluster when using NCCL on the OSU benchmark; on the
//!    tensor data sets, as much as 4.7x";
//! 2. "NCCL ... 1.2x faster on average than MVAPICH-GDR on the cluster
//!    for the tensor factorization experiment";
//! 3. irregular-workload trends absent from / contradicting the
//!    benchmark (NELL-1 2-GPU flip; DELICIOUS MPI-CUDA vs MPI on the
//!    cluster; MV2_GPUDIRECT_LIMIT sensitivity).

use crate::comm::Library;
use crate::cpals::comm_model::gdr_limit_sweep;
use crate::tensor::datasets;
use crate::topology::systems::SystemKind;
use crate::util::stats::geomean;

use super::fig2::grid as fig2_grid;
use super::fig3::{default_panels, Fig3Panel};

/// The recomputed §VI headline ratios.
#[derive(Clone, Debug)]
pub struct Findings {
    /// max over message sizes of cluster/DGX-1 NCCL time ratio (OSU, 8 GPUs)
    pub osu_dgx_vs_cluster_nccl: f64,
    /// max over data sets of cluster/DGX-1 NCCL ratio (tensors, 8 GPUs)
    pub tensor_dgx_vs_cluster_nccl: f64,
    /// geomean over data sets x GPU counts of MPI-CUDA/NCCL on the cluster
    pub cluster_nccl_advantage: f64,
    /// NELL-1 2-GPU DGX-1: MPI-CUDA / NCCL (paper: > 1, contradicting OSU)
    pub nell1_2gpu_flip: f64,
    /// DELICIOUS cluster 8 GPUs: MPI-CUDA / plain-MPI (paper: 1.73x)
    pub delicious_mpicuda_vs_mpi: f64,
    /// max/min over the MV2_GPUDIRECT_LIMIT sweep (DELICIOUS, 8 GPUs)
    pub gdr_sensitivity: f64,
}

/// Recompute every §VI headline from the Fig. 2/3 grids.
pub fn compute() -> Findings {
    let fig2 = fig2_grid();
    let dgx8 = fig2
        .iter()
        .find(|c| c.system == SystemKind::Dgx1 && c.gpus == 8)
        .unwrap();
    let clu8 = fig2
        .iter()
        .find(|c| c.system == SystemKind::Cluster && c.gpus == 8)
        .unwrap();
    let osu_ratio = dgx8
        .points(Library::Nccl)
        .iter()
        .zip(clu8.points(Library::Nccl))
        .map(|(d, c)| c.time / d.time)
        .fold(0.0f64, f64::max);

    let panels = default_panels();
    let panel = |sys: SystemKind, gpus: usize| -> &Fig3Panel {
        panels
            .iter()
            .find(|p| p.system == sys && p.gpus == gpus)
            .unwrap()
    };
    let tensor_ratio = datasets::all()
        .iter()
        .map(|d| {
            panel(SystemKind::Cluster, 8).time(d.name, Library::Nccl)
                / panel(SystemKind::Dgx1, 8).time(d.name, Library::Nccl)
        })
        .fold(0.0f64, f64::max);

    let mut cluster_ratios = Vec::new();
    for d in datasets::all() {
        for gpus in [2usize, 8, 16] {
            let p = panel(SystemKind::Cluster, gpus);
            cluster_ratios.push(p.time(d.name, Library::MpiCuda) / p.time(d.name, Library::Nccl));
        }
    }

    let nell1_flip = panel(SystemKind::Dgx1, 2).time("NELL-1", Library::MpiCuda)
        / panel(SystemKind::Dgx1, 2).time("NELL-1", Library::Nccl);
    let delicious = panel(SystemKind::Cluster, 8).time("DELICIOUS", Library::MpiCuda)
        / panel(SystemKind::Cluster, 8).time("DELICIOUS", Library::Mpi);

    let topo = SystemKind::Cluster.build();
    let sweep = gdr_limit_sweep(
        &topo,
        &datasets::delicious(),
        8,
        1,
        &[16, 1 << 20, 4 << 20, 8 << 20, 64 << 20, 512 << 20],
    );
    let times: Vec<f64> = sweep.iter().map(|&(_, t)| t).collect();
    let gdr = times.iter().cloned().fold(0.0, f64::max)
        / times.iter().cloned().fold(f64::INFINITY, f64::min);

    Findings {
        osu_dgx_vs_cluster_nccl: osu_ratio,
        tensor_dgx_vs_cluster_nccl: tensor_ratio,
        cluster_nccl_advantage: geomean(&cluster_ratios),
        nell1_2gpu_flip: nell1_flip,
        delicious_mpicuda_vs_mpi: delicious,
        gdr_sensitivity: gdr,
    }
}

/// Render the findings next to the paper's reported numbers.
pub fn render(f: &Findings) -> String {
    format!(
        "HEADLINE FINDINGS (ours vs paper §VI)\n\
         1. DGX-1 vs cluster, NCCL:   OSU up to {:.1}x (paper: 8.3x); tensors up to {:.1}x (paper: 4.7x)\n\
         2. NCCL vs MVAPICH-GDR on the cluster (tensors, geomean): {:.2}x faster (paper: 1.2x)\n\
         3. Irregularity effects:\n\
            - NELL-1 @2 GPUs on DGX-1: MPI-CUDA/NCCL = {:.2}x (paper: 3.1x; OSU says NCCL slower)\n\
            - DELICIOUS @8 GPUs cluster: MPI-CUDA/MPI = {:.2}x (paper: 1.73x slower)\n\
            - MV2_GPUDIRECT_LIMIT sweep swing on DELICIOUS: {:.2}x (paper: 3.1x)\n",
        f.osu_dgx_vs_cluster_nccl,
        f.tensor_dgx_vs_cluster_nccl,
        f.cluster_nccl_advantage,
        f.nell1_2gpu_flip,
        f.delicious_mpicuda_vs_mpi,
        f.gdr_sensitivity,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn findings_reproduce_paper_directions() {
        let f = compute();
        // Direction and rough magnitude of every §VI claim:
        assert!(f.osu_dgx_vs_cluster_nccl > 2.5, "{f:?}");
        assert!(f.tensor_dgx_vs_cluster_nccl > 1.5, "{f:?}");
        assert!(f.cluster_nccl_advantage > 0.95, "{f:?}");
        assert!(f.nell1_2gpu_flip > 1.0, "{f:?}");
        assert!(f.gdr_sensitivity > 1.3, "{f:?}");
        let txt = render(&f);
        assert!(txt.contains("HEADLINE"));
    }
}
