//! Renderers regenerating every table and figure of the paper, plus the
//! §VI headline findings (see DESIGN.md §5 experiment index).
//!
//! Each function returns plain text (and the grid builders return data
//! the bench targets and CSV writers reuse). Grid evaluation fans out
//! over `std::thread` — every (system, library, GPU-count) cell is an
//! independent pure simulation.

pub mod auto;
pub mod faults;
pub mod fig2;
pub mod fig3;
pub mod findings;
pub mod serve;
pub mod table1;
pub mod workload;

use std::io::Write;
use std::path::Path;

/// Write a CSV string to `dir/name`, creating the directory if needed.
pub fn write_csv(dir: &Path, name: &str, csv: &str) -> std::io::Result<std::path::PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    let mut f = std::fs::File::create(&path)?;
    f.write_all(csv.as_bytes())?;
    Ok(path)
}

/// Run closures on worker threads and collect results in order.
///
/// Delegates to the bounded pool in [`crate::util::pool`]: at most
/// `available_parallelism` workers, regardless of grid size (the old
/// implementation spawned one OS thread per job). Kept here because
/// every grid builder in this module calls it by this path.
pub fn parallel_map<T, F>(jobs: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    crate::util::pool::parallel_map(jobs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_preserves_order() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..16usize)
            .map(|i| Box::new(move || i * i) as Box<dyn FnOnce() -> usize + Send>)
            .collect();
        let out = parallel_map(jobs);
        assert_eq!(out, (0..16usize).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn write_csv_roundtrip() {
        let dir = std::env::temp_dir().join("agv_csv_test");
        let p = write_csv(&dir, "t.csv", "a,b\n1,2\n").unwrap();
        assert_eq!(std::fs::read_to_string(p).unwrap(), "a,b\n1,2\n");
    }
}
