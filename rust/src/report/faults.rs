//! Fault & variability study: each paper system healthy vs degraded,
//! schedule fragility ranking, and robust-vs-fresh selector verdicts
//! (DESIGN.md §12); with `--outage`, the hard-fault study — outage
//! recovery strategies per system × library and the outage-aware
//! selector verdicts (DESIGN.md §14). Rendered by `agv faults`.

use crate::comm::select::{robust_argmin, Algo, AlgoSelector, RobustObjective};
use crate::comm::transport::RecoveryPolicy;
use crate::comm::{CommLibrary, Library, Params};
use crate::perturb::recovery::recovered_allgatherv;
use crate::perturb::{ensemble, perturbed_allgatherv, EnsembleCfg, Perturbation};
use crate::topology::systems::{multi_dgx, SystemKind};
use crate::topology::{LinkClass, Topology};
use crate::util::fmt_time;
use crate::util::prng::Rng;
use crate::util::prop::counts as prop_counts;

/// One (scenario, library) cell of the healthy-vs-degraded table.
#[derive(Clone, Debug)]
pub struct DegradedRow {
    /// Scenario label ("straggler gpu0 x0.50", ...).
    pub scenario: String,
    /// Library measured.
    pub lib: Library,
    /// Collective time on the pristine fabric (seconds).
    pub healthy: f64,
    /// Collective time under the scenario (seconds).
    pub degraded: f64,
}

impl DegradedRow {
    /// degraded / healthy.
    pub fn slowdown(&self) -> f64 {
        self.degraded / self.healthy
    }
}

/// One system's healthy-vs-degraded section.
#[derive(Clone, Debug)]
pub struct SystemFaults {
    /// System name.
    pub system: String,
    /// Ranks of the measured collective.
    pub gpus: usize,
    /// Scenario × library rows, scenario-major.
    pub rows: Vec<DegradedRow>,
}

/// One candidate's fragility under the inter-node degradation ensemble.
#[derive(Clone, Debug)]
pub struct FragilityRow {
    /// Candidate label ("MPI-CUDA/hier-ring", ...).
    pub label: String,
    /// Is this one of the two-level schedules?
    pub hierarchical: bool,
    /// Healthy time (seconds).
    pub healthy: f64,
    /// Mean makespan over the degradation scenarios (seconds).
    pub mean_degraded: f64,
    /// Worst-scenario makespan (seconds).
    pub worst_degraded: f64,
}

impl FragilityRow {
    /// mean degraded / healthy — the ranking key (higher = more
    /// fragile: the schedule loses more of its healthy performance).
    pub fn fragility(&self) -> f64 {
        self.mean_degraded / self.healthy
    }
}

/// Robust-vs-fresh verdict on one system.
#[derive(Clone, Debug)]
pub struct RobustRow {
    /// System name.
    pub system: String,
    /// Fresh (healthy-fabric) winner label.
    pub fresh: String,
    /// Fresh winner's healthy time (seconds).
    pub fresh_time: f64,
    /// Mean-objective robust winner label.
    pub robust_mean: String,
    /// Mean-objective winner's ensemble mean (seconds).
    pub mean: f64,
    /// P95-objective robust winner label.
    pub robust_p95: String,
    /// P95-objective winner's ensemble p95 (seconds).
    pub p95: f64,
}

/// The full study.
#[derive(Clone, Debug)]
pub struct FaultsReport {
    /// Healthy-vs-degraded sections, one per paper system.
    pub sections: Vec<SystemFaults>,
    /// Fragility ranking on the multi-node topology, most fragile
    /// first.
    pub fragility: Vec<FragilityRow>,
    /// Single-lane scenarios behind the fragility ranking.
    pub fragility_scenarios: usize,
    /// Robust-vs-fresh verdicts, one per paper system.
    pub robust: Vec<RobustRow>,
    /// Monte-Carlo scenarios behind each robust verdict.
    pub robust_scenarios: usize,
    /// Seed behind the ensembles and count vectors.
    pub seed: u64,
}

/// The canonical degradation scenarios of a system: a straggler GPU, a
/// degraded PCIe lane under GPU 0, and (where the fabric has one) an
/// InfiniBand leaf floored at 1 GB/s.
pub fn canonical_scenarios(topo: &Topology) -> Vec<(String, Vec<Perturbation>)> {
    let mut out = vec![(
        "straggler gpu0 x0.50".to_string(),
        vec![Perturbation::straggler(0, 0.5)],
    )];
    if let Some(&pcie) = topo
        .gpu_links(0)
        .iter()
        .find(|&&l| topo.links[l].class == LinkClass::PcieGen3x16)
    {
        out.push((
            format!("pcie link{pcie} x0.50"),
            vec![Perturbation::scale(pcie, 0.5)],
        ));
    }
    if let Some(ib) = (0..topo.links.len())
        .find(|&l| topo.links[l].class == LinkClass::InfinibandFdr)
    {
        out.push((
            format!("ib link{ib} floor 1GB/s"),
            vec![Perturbation::floor(ib, 1.0e9)],
        ));
    }
    out
}

fn system_section(kind: SystemKind, params: Params) -> SystemFaults {
    let topo = kind.build();
    let gpus = topo.num_gpus().min(8);
    let cv = vec![4u64 << 20; gpus];
    // one healthy baseline per library, shared across every scenario —
    // under the SAME params as the degraded runs, so the slowdown
    // column never mixes two protocol models
    let healthy: Vec<f64> = Library::all()
        .into_iter()
        .map(|lib| lib.build(params).allgatherv(&topo, &cv).time)
        .collect();
    let mut rows = Vec::new();
    for (scenario, perts) in canonical_scenarios(&topo) {
        for (li, lib) in Library::all().into_iter().enumerate() {
            let degraded = perturbed_allgatherv(&topo, lib, params, &cv, &perts).time;
            rows.push(DegradedRow {
                scenario: scenario.clone(),
                lib,
                healthy: healthy[li],
                degraded,
            });
        }
    }
    SystemFaults { system: topo.name.clone(), gpus, rows }
}

/// The fragility ensemble on the multi-node topology: every InfiniBand
/// leaf and the first four NVLinks, each scaled to 0.4 in its own
/// scenario — the single-degraded-lane regime the flat and hierarchical
/// schedules weight differently.
fn fragility_scenarios(topo: &Topology) -> Vec<Vec<Perturbation>> {
    let ib: Vec<usize> = (0..topo.links.len())
        .filter(|&l| topo.links[l].class == LinkClass::InfinibandFdr)
        .collect();
    let nv: Vec<usize> = (0..topo.links.len())
        .filter(|&l| topo.links[l].class == LinkClass::NvLink)
        .take(4)
        .collect();
    ib.into_iter()
        .chain(nv)
        .map(|l| vec![Perturbation::scale(l, 0.4)])
        .collect()
}

fn fragility_ranking(params: Params) -> Vec<FragilityRow> {
    let topo = multi_dgx(2);
    let p = 16usize;
    let cv = vec![2u64 << 20; p];
    let scenarios = fragility_scenarios(&topo);
    let sel = AlgoSelector::new(params);
    let evals = sel.evaluate_robust(&topo, &cv, &scenarios);
    // the healthy baseline comes from each eval's OWN candidate — no
    // positional pairing against a separately-enumerated list
    let mut rows: Vec<FragilityRow> = evals
        .iter()
        .map(|(cand, times)| FragilityRow {
            label: cand.label(),
            hierarchical: matches!(
                cand.algo,
                Algo::HierarchicalRing | Algo::HierarchicalBruck
            ),
            healthy: crate::comm::select::simulate(&topo, params, *cand, &cv)
                .expect("an evaluated candidate applies on its own topology")
                .time,
            mean_degraded: RobustObjective::Mean.aggregate(times),
            worst_degraded: times.iter().cloned().fold(0.0, f64::max),
        })
        .collect();
    rows.sort_by(|a, b| b.fragility().total_cmp(&a.fragility()));
    rows
}

fn robust_rows(params: Params, seed: u64) -> Vec<RobustRow> {
    let jobs: Vec<_> = SystemKind::all()
        .into_iter()
        .map(|kind| move || {
            let topo = kind.build();
            let p = topo.num_gpus().min(8);
            // a skewed irregular vector, deterministic in the seed
            let mut rng = Rng::new(seed ^ 0xFA01);
            let cv = prop_counts::skewed(&mut rng, p, 16 << 20);
            let ens = ensemble(&topo, &EnsembleCfg::quick(seed));
            let sel = AlgoSelector::new(params);
            let fresh = sel.select_fresh(&topo, &cv);
            // one candidate x scenario grid, aggregated under both
            // objectives through the selector's own argmin
            let evals = sel.evaluate_robust(&topo, &cv, &ens);
            let (mc, mean, _) = robust_argmin(&evals, RobustObjective::Mean);
            let (pc, p95, _) = robust_argmin(&evals, RobustObjective::P95);
            let (robust_mean, robust_p95) = (mc.label(), pc.label());
            RobustRow {
                system: topo.name.clone(),
                fresh: fresh.candidate.label(),
                fresh_time: fresh.time,
                robust_mean,
                mean,
                robust_p95,
                p95,
            }
        })
        .collect();
    crate::util::pool::parallel_map(jobs)
}

/// Run the full study. The per-system sections and robust verdicts fan
/// out over the bounded worker pool; results come back in
/// deterministic order (the fragility ranking is one indivisible
/// candidate-grid evaluation and runs on the caller).
pub fn study(params: Params, seed: u64) -> FaultsReport {
    let section_jobs: Vec<_> = SystemKind::all()
        .into_iter()
        .map(|kind| move || system_section(kind, params))
        .collect();
    let sections = crate::util::pool::parallel_map(section_jobs);
    let robust = robust_rows(params, seed);
    FaultsReport {
        sections,
        fragility: fragility_ranking(params),
        fragility_scenarios: fragility_scenarios(&multi_dgx(2)).len(),
        robust,
        robust_scenarios: EnsembleCfg::quick(seed).scenarios,
        seed,
    }
}

/// One (system, scenario, library) cell of the outage-recovery table.
#[derive(Clone, Debug)]
pub struct OutageRow {
    /// System name.
    pub system: String,
    /// Scenario label ("transient link3 2ms", "dead link3", "dead gpu3").
    pub scenario: String,
    /// Library measured.
    pub lib: Library,
    /// Recovery strategy that completed the op
    /// ([`crate::perturb::RecoveryStrategy::label`]; "ABORT" = it did
    /// not).
    pub strategy: String,
    /// Healthy-fabric time (seconds).
    pub healthy: f64,
    /// Completion time under the outage with recovery, if completed.
    pub time: Option<f64>,
    /// Completion minus first stall (0.0 for a clean completion).
    pub recovery_latency: f64,
    /// Ranks the completed collective served (shrink completes on
    /// fewer).
    pub survivors: usize,
}

/// The outage-aware selector's verdict on one system.
#[derive(Clone, Debug)]
pub struct OutageSelectRow {
    /// System name.
    pub system: String,
    /// Winning candidate under [`RobustObjective::Outage`].
    pub winner: String,
    /// Fraction of outage scenarios the winner completed.
    pub completion_prob: f64,
    /// The winner's effective-cost score (seconds; completion
    /// probability and recovery cost folded in).
    pub score: f64,
    /// Mean recovery latency over the winner's completed scenarios.
    pub mean_recovery: f64,
    /// The winner's healthy-fabric time.
    pub healthy: f64,
}

/// The hard-fault study behind `agv faults --outage`.
#[derive(Clone, Debug)]
pub struct OutageReport {
    /// Recovery-strategy rows, system-major then scenario-major.
    pub rows: Vec<OutageRow>,
    /// Outage-aware selection verdicts, one per paper system.
    pub select: Vec<OutageSelectRow>,
    /// Monte-Carlo outage scenarios behind each selection verdict.
    pub select_scenarios: usize,
    /// Recovery policy supervising every run.
    pub policy: RecoveryPolicy,
    /// Seed behind the selection ensembles.
    pub seed: u64,
}

/// The canonical hard-fault scenarios of a system: a transient outage
/// of a route-carrying link sized to hit mid-collective, the same link
/// dead for good, and a dead GPU. `healthy` scales the transient window
/// so it lands inside the op on any system.
pub fn outage_scenarios(topo: &Topology, healthy: f64) -> Vec<(String, Vec<Perturbation>)> {
    let link = topo
        .route_gpus(0, 1)
        .expect("paper systems route any GPU pair")
        .links[0];
    let dead_rank = topo.num_gpus().min(8) - 1;
    vec![
        (
            format!("transient link{link}"),
            vec![Perturbation::link_down(link).during(healthy * 0.25, healthy * 0.5)],
        ),
        (format!("dead link{link}"), vec![Perturbation::link_down(link)]),
        (format!("dead gpu{dead_rank}"), vec![Perturbation::gpu_down(dead_rank)]),
    ]
}

fn outage_section(kind: SystemKind, params: Params, policy: RecoveryPolicy) -> Vec<OutageRow> {
    let topo = kind.build();
    let gpus = topo.num_gpus().min(8);
    let cv = vec![4u64 << 20; gpus];
    let healthy: Vec<f64> = Library::all()
        .into_iter()
        .map(|lib| lib.build(params).allgatherv(&topo, &cv).time)
        .collect();
    let h_max = healthy.iter().cloned().fold(0.0f64, f64::max);
    let mut rows = Vec::new();
    for (scenario, perts) in outage_scenarios(&topo, h_max) {
        for (li, lib) in Library::all().into_iter().enumerate() {
            let rec = recovered_allgatherv(&topo, lib, params, &cv, &perts, &policy);
            rows.push(OutageRow {
                system: topo.name.clone(),
                scenario: scenario.clone(),
                lib,
                strategy: rec.strategy.label(),
                healthy: healthy[li],
                time: rec.time(),
                recovery_latency: rec.recovery_latency,
                survivors: rec.survivors,
            });
        }
    }
    rows
}

/// Run the hard-fault study: recovery strategies per system × scenario
/// × library under `policy`, plus the outage-aware selector verdicts
/// over seeded transient-outage ensembles. Fans out over the bounded
/// worker pool; deterministic in `seed`.
pub fn outage_study(params: Params, seed: u64) -> OutageReport {
    let policy = RecoveryPolicy::default_policy();
    let row_jobs: Vec<_> = SystemKind::all()
        .into_iter()
        .map(|kind| move || outage_section(kind, params, policy))
        .collect();
    let rows: Vec<OutageRow> =
        crate::util::pool::parallel_map(row_jobs).into_iter().flatten().collect();
    let cfg = EnsembleCfg::quick(seed).with_scenarios(4).with_outages(0.75, (0.5e-3, 2.0e-3));
    let select_scenarios = cfg.scenarios;
    let select_jobs: Vec<_> = SystemKind::all()
        .into_iter()
        .map(|kind| {
            move || {
                let topo = kind.build();
                let p = topo.num_gpus().min(8);
                let cv = vec![4u64 << 20; p];
                let ens = ensemble(&topo, &cfg);
                let sel = AlgoSelector::new(params);
                let s = sel.select_outage_robust(&topo, &cv, &ens, &policy);
                OutageSelectRow {
                    system: topo.name.clone(),
                    winner: s.candidate.label(),
                    completion_prob: s.completion_prob,
                    score: s.score,
                    mean_recovery: s.mean_recovery,
                    healthy: s.healthy,
                }
            }
        })
        .collect();
    OutageReport {
        rows,
        select: crate::util::pool::parallel_map(select_jobs),
        select_scenarios,
        policy,
        seed,
    }
}

/// Render the hard-fault study as text tables.
pub fn render_outage(r: &OutageReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "OUTAGES — hard faults, timeout-retry-reroute recovery (timeout {}, {} retries)\n\n\
         {:<12} {:<20} {:<10} {:<18} {:>12} {:>12} {:>12} {:>5}\n",
        fmt_time(r.policy.timeout),
        r.policy.max_retries,
        "system",
        "scenario",
        "lib",
        "strategy",
        "healthy",
        "recovered",
        "rec-latency",
        "p"
    ));
    for row in &r.rows {
        out.push_str(&format!(
            "{:<12} {:<20} {:<10} {:<18} {:>12} {:>12} {:>12} {:>5}\n",
            row.system,
            row.scenario,
            row.lib.name(),
            row.strategy,
            fmt_time(row.healthy),
            row.time.map(fmt_time).unwrap_or_else(|| "-".into()),
            fmt_time(row.recovery_latency),
            row.survivors,
        ));
    }
    out.push_str(&format!(
        "\n== outage-aware selection (seed {}, {} scenarios, objective `outage`) ==\n\
         {:<12} {:<22} {:>10} {:>12} {:>12} {:>12}\n",
        r.seed, r.select_scenarios, "system", "winner", "compl-prob", "score", "mean-rec", "healthy"
    ));
    for s in &r.select {
        out.push_str(&format!(
            "{:<12} {:<22} {:>9.0}% {:>12} {:>12} {:>12}\n",
            s.system,
            s.winner,
            s.completion_prob * 100.0,
            fmt_time(s.score),
            fmt_time(s.mean_recovery),
            fmt_time(s.healthy),
        ));
    }
    let aborted = r.rows.iter().filter(|row| row.time.is_none()).count();
    out.push_str(&format!(
        "\noutage verdict: {}/{} (system, scenario, library) cells complete under recovery\n",
        r.rows.len() - aborted,
        r.rows.len()
    ));
    out
}

/// CSV form of the outage-recovery table.
pub fn csv_outage(r: &OutageReport) -> String {
    let mut out = String::from(
        "system,scenario,lib,strategy,healthy_s,recovered_s,recovery_latency_s,survivors\n",
    );
    for row in &r.rows {
        out.push_str(&format!(
            "{},{},{},{},{:.9},{},{:.9},{}\n",
            row.system,
            row.scenario,
            row.lib.name(),
            row.strategy,
            row.healthy,
            row.time.map(|t| format!("{t:.9}")).unwrap_or_default(),
            row.recovery_latency,
            row.survivors,
        ));
    }
    out
}

/// Render the study as text tables.
pub fn render(r: &FaultsReport) -> String {
    let mut out = String::new();
    out.push_str(
        "FAULTS — degraded links, stragglers, and robust selection (healthy vs degraded)\n",
    );
    for s in &r.sections {
        out.push_str(&format!(
            "\n== {} @ {} GPUs, 4MB/rank ==\n{:<22} {:<10} {:>12} {:>12} {:>9}\n",
            s.system, s.gpus, "scenario", "lib", "healthy", "degraded", "slowdown"
        ));
        for row in &s.rows {
            out.push_str(&format!(
                "{:<22} {:<10} {:>12} {:>12} {:>8.2}x\n",
                row.scenario,
                row.lib.name(),
                fmt_time(row.healthy),
                fmt_time(row.degraded),
                row.slowdown(),
            ));
        }
    }
    out.push_str(&format!(
        "\n== fragility ranking — multi-dgx-2 @ 16 GPUs, 2MB/rank, {} single-lane scenarios ==\n\
         {:<24} {:>6} {:>12} {:>12} {:>12} {:>10}\n",
        r.fragility_scenarios,
        "candidate",
        "level",
        "healthy",
        "mean-deg",
        "worst-deg",
        "fragility"
    ));
    for f in &r.fragility {
        out.push_str(&format!(
            "{:<24} {:>6} {:>12} {:>12} {:>12} {:>9.2}x\n",
            f.label,
            if f.hierarchical { "hier" } else { "flat" },
            fmt_time(f.healthy),
            fmt_time(f.mean_degraded),
            fmt_time(f.worst_degraded),
            f.fragility(),
        ));
    }
    out.push_str(&format!(
        "\n== robust vs fresh selection (ensemble seed {}, {} scenarios) ==\n\
         {:<12} {:<22} {:<22} {:<22}\n",
        r.seed, r.robust_scenarios, "system", "fresh (healthy)", "robust mean", "robust p95"
    ));
    for row in &r.robust {
        out.push_str(&format!(
            "{:<12} {:<22} {:<22} {:<22}\n",
            row.system,
            format!("{} {}", row.fresh, fmt_time(row.fresh_time)),
            format!("{} {}", row.robust_mean, fmt_time(row.mean)),
            format!("{} {}", row.robust_p95, fmt_time(row.p95)),
        ));
    }
    let flips = r
        .robust
        .iter()
        .filter(|row| row.fresh != row.robust_mean || row.fresh != row.robust_p95)
        .count();
    out.push_str(&format!(
        "\nfaults verdict: robust selection flips the healthy-fabric winner on {flips}/{} systems\n",
        r.robust.len()
    ));
    out
}

/// CSV form of the healthy-vs-degraded table (one row per scenario ×
/// library × system cell).
pub fn csv(r: &FaultsReport) -> String {
    let mut out = String::from("system,gpus,scenario,lib,healthy_s,degraded_s,slowdown\n");
    for s in &r.sections {
        for row in &s.rows {
            out.push_str(&format!(
                "{},{},{},{},{:.9},{:.9},{:.6}\n",
                s.system,
                s.gpus,
                row.scenario,
                row.lib.name(),
                row.healthy,
                row.degraded,
                row.slowdown(),
            ));
        }
    }
    out
}

/// Link table of a system (`agv faults --list-links`): the id column is
/// what `--perturb link:<id>:...` and the fault timelines refer to.
pub fn links_table(topo: &Topology) -> String {
    let mut out = format!(
        "links of {} ({} links; ids are the --perturb targets)\n{:>4} {:<18} {:<18} {:<14} {:>9}\n",
        topo.name,
        topo.links.len(),
        "id",
        "a",
        "b",
        "class",
        "GB/s"
    );
    for (id, link) in topo.links.iter().enumerate() {
        out.push_str(&format!(
            "{:>4} {:<18} {:<18} {:<14} {:>9.1}\n",
            id,
            topo.devices[link.a].name,
            topo.devices[link.b].name,
            format!("{:?}", link.class),
            link.class.bandwidth() / 1e9,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn study_covers_systems_fragility_and_robust() {
        let r = study(Params::default(), 42);
        assert_eq!(r.sections.len(), 3);
        // cluster has the IB scenario, single-node systems do not
        let cluster = &r.sections[0];
        assert!(cluster.rows.iter().any(|row| row.scenario.contains("ib ")));
        assert!(r.sections[1..]
            .iter()
            .all(|s| s.rows.iter().all(|row| !row.scenario.contains("ib "))));
        for s in &r.sections {
            assert!(!s.rows.is_empty());
            for row in &s.rows {
                // link-weakening monotonicity: degradation never speeds
                // a fixed schedule up (calibrated in faults_properties)
                assert!(
                    row.slowdown() >= 1.0 - 1e-9,
                    "{}/{}/{}: slowdown {}",
                    s.system,
                    row.scenario,
                    row.lib.name(),
                    row.slowdown()
                );
            }
        }
        // the 1 GB/s IB floor throttles every library hard (Python
        // calibration: 3.5x-4.1x)
        for row in cluster.rows.iter().filter(|r| r.scenario.contains("ib ")) {
            assert!(
                row.slowdown() > 2.0,
                "{}: IB floor only {}x",
                row.lib.name(),
                row.slowdown()
            );
        }
        // fragility covers flat AND hierarchical candidates, ranked
        assert!(r.fragility.iter().any(|f| f.hierarchical));
        assert!(r.fragility.iter().any(|f| !f.hierarchical));
        for w in r.fragility.windows(2) {
            assert!(w[0].fragility() >= w[1].fragility(), "ranking not sorted");
        }
        for f in &r.fragility {
            assert!(f.worst_degraded >= f.mean_degraded - 1e-12);
            assert!(f.fragility() >= 1.0 - 1e-9, "{}: {}", f.label, f.fragility());
        }
        assert_eq!(r.robust.len(), 3);
        let text = render(&r);
        for kind in SystemKind::all() {
            assert!(text.contains(kind.name()), "{} missing:\n{text}", kind.name());
        }
        assert!(text.contains("fragility ranking"));
        assert!(text.contains("robust vs fresh"));
        let c = csv(&r);
        assert!(c.starts_with("system,"));
        assert_eq!(c.lines().count(), 1 + r.sections.iter().map(|s| s.rows.len()).sum::<usize>());
    }

    #[test]
    fn study_is_deterministic() {
        let a = study(Params::default(), 7);
        let b = study(Params::default(), 7);
        assert_eq!(render(&a), render(&b));
        assert_eq!(csv(&a), csv(&b));
    }

    #[test]
    fn links_table_lists_every_link() {
        let topo = SystemKind::Dgx1.build();
        let t = links_table(&topo);
        assert_eq!(t.lines().count(), 2 + topo.links.len());
        assert!(t.contains("NvLink"));
        assert!(t.contains("--perturb"));
    }
}
