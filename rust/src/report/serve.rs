//! Serving-capacity study: latency vs offered load per system, with
//! the p95 knee point (DESIGN.md §17). Rendered by `agv serve`.
//!
//! Each system plans its op streams once, derives a saturation rate
//! from its own isolated service time, then sweeps Poisson offered
//! load over a rho grid (fractions of saturation) re-composing the
//! serving DAG per point. The knee is the last point whose p95 stays
//! within [`crate::workload::serve::KNEE_FACTOR`] of the lowest-load
//! p95 — the fabric's practical serving capacity.

use crate::comm::Params;
use crate::topology::systems::SystemSpec;
use crate::topology::Topology;
use crate::util::fmt_time;
use crate::util::error::Result;
use crate::workload::serve::{self, knee_index, ArrivalProcess, ServeSpec, KNEE_FACTOR};
use crate::workload::engine;

/// Offered-load fractions of saturation swept by the default study.
pub const DEFAULT_RHOS: [f64; 6] = [0.2, 0.4, 0.6, 0.8, 1.0, 1.2];

/// One offered-load point of a system's capacity curve.
#[derive(Clone, Debug)]
pub struct LoadPoint {
    /// Fraction of the system's saturation rate.
    pub rho: f64,
    /// Poisson rate per tenant (jobs/second).
    pub rate: f64,
    /// Aggregate offered load (jobs/second across tenants).
    pub offered: f64,
    /// Steady-state median response latency (seconds).
    pub p50: f64,
    /// Steady-state 95th-percentile response latency.
    pub p95: f64,
    /// Steady-state 99.9th-percentile response latency.
    pub p999: f64,
    /// Completed jobs per second of makespan.
    pub throughput: f64,
    /// Jobs that completed.
    pub completed: usize,
    /// Jobs admission rejected.
    pub rejected: usize,
    /// Completed jobs dropped as warm-up transient.
    pub warmup: usize,
}

/// One system's section of the serving study.
#[derive(Clone, Debug)]
pub struct ServeSection {
    /// System name.
    pub system: String,
    /// Ranks each job spans.
    pub gpus: usize,
    /// Admission policy label.
    pub policy: String,
    /// Tenants sharing the fabric.
    pub tenants: usize,
    /// Job horizon per tenant.
    pub jobs: usize,
    /// Saturation rate per tenant, 1 / (tenants * isolated service time).
    pub saturation: f64,
    /// The sweep, ascending offered load.
    pub points: Vec<LoadPoint>,
    /// Index of the knee point in `points`.
    pub knee: usize,
}

/// Sweep one serving spec over `rhos` fractions of the system's
/// saturation rate. The base spec's arrival process is overridden per
/// point; its policy, tenants, and streams are kept.
pub fn section(
    topo: &Topology,
    base: &ServeSpec,
    rhos: &[f64],
    params: Params,
) -> Result<ServeSection> {
    base.validate(topo)?;
    // one planning pass feeds every load point — plans depend only on
    // counts and libraries, never on the arrival process
    let plans = engine::plan(topo, &base.workload, params)?;
    let s0 = serve::base_service_time(topo, params, &plans);
    let tenants = base.workload.tenants.len();
    let sat = 1.0 / (tenants as f64 * s0);
    let gpus = base.workload.tenants.iter().map(|t| t.stream.gpus()).max().unwrap_or(0);
    let mut points = Vec::with_capacity(rhos.len());
    for &rho in rhos {
        let mut spec = base.clone();
        spec.arrivals = ArrivalProcess::Poisson { rate: rho * sat };
        let r = serve::run_serve_planned(topo, &spec, params, &plans);
        points.push(LoadPoint {
            rho,
            rate: rho * sat,
            offered: r.offered_rate,
            p50: r.p50,
            p95: r.p95,
            p999: r.p999,
            throughput: r.throughput,
            completed: r.completed,
            rejected: r.rejected,
            warmup: r.warmup_jobs,
        });
    }
    let p95s: Vec<f64> = points.iter().map(|p| p.p95).collect();
    let knee = knee_index(&p95s, KNEE_FACTOR);
    Ok(ServeSection {
        system: topo.name.clone(),
        gpus,
        policy: base.policy.label(),
        tenants,
        jobs: base.workload.tenants.first().map(|t| t.ops).unwrap_or(0),
        saturation: sat,
        points,
        knee,
    })
}

/// The default study: the same serving shape on each system (sections
/// fan out over the bounded worker pool, results in system order).
/// `mk_spec` receives the system's GPU budget so specs can adapt rank
/// counts.
pub fn study(
    systems: &[SystemSpec],
    params: Params,
    rhos: &[f64],
    mk_spec: impl Fn(usize) -> ServeSpec + Sync,
) -> Result<Vec<ServeSection>> {
    let jobs: Vec<_> = systems
        .iter()
        .map(|&spec| {
            let mk = &mk_spec;
            move || {
                let topo = spec.build();
                let sspec = mk(topo.num_gpus());
                section(&topo, &sspec, rhos, params)
            }
        })
        .collect();
    crate::util::pool::parallel_map(jobs).into_iter().collect()
}

/// Render the study as text tables, one section per system.
pub fn render(sections: &[ServeSection]) -> String {
    let mut out = String::new();
    out.push_str("SERVE — open-loop serving capacity: latency vs offered load, p95 knee\n");
    for s in sections {
        out.push_str(&format!(
            "\n== {} @ {} GPUs/job — {} tenants x {} jobs, policy {}, saturation {:.1} jobs/s ==\n",
            s.system,
            s.gpus,
            s.tenants,
            s.jobs,
            s.policy,
            s.saturation * s.tenants as f64,
        ));
        out.push_str(&format!(
            "{:>5} {:>12} {:>12} {:>12} {:>12} {:>12} {:>5} {:>4} {:>5}\n",
            "rho", "offered/s", "p50", "p95", "p99.9", "thruput/s", "done", "rej", "knee"
        ));
        for (i, p) in s.points.iter().enumerate() {
            out.push_str(&format!(
                "{:>5.2} {:>12.2} {:>12} {:>12} {:>12} {:>12.2} {:>5} {:>4} {:>5}\n",
                p.rho,
                p.offered,
                fmt_time(p.p50),
                fmt_time(p.p95),
                fmt_time(p.p999),
                p.throughput,
                p.completed,
                p.rejected,
                if i == s.knee { "<==" } else { "" },
            ));
        }
    }
    if !sections.is_empty() {
        out.push_str("\ncapacity verdict:\n");
        for s in sections {
            let k = &s.points[s.knee];
            out.push_str(&format!(
                "  {:<14} knee at rho {:.2} — {:.2} jobs/s offered, p95 {}\n",
                s.system,
                k.rho,
                k.offered,
                fmt_time(k.p95),
            ));
        }
    }
    out
}

/// CSV form of the study (one row per load point).
pub fn csv(sections: &[ServeSection]) -> String {
    let mut out = String::from(
        "system,gpus,policy,tenants,jobs,rho,rate_per_tenant_hz,offered_hz,p50_s,p95_s,\
         p999_s,throughput_hz,completed,rejected,warmup_jobs,knee\n",
    );
    for s in sections {
        for (i, p) in s.points.iter().enumerate() {
            out.push_str(&format!(
                "{},{},{},{},{},{:.2},{:.6},{:.6},{:.9},{:.9},{:.9},{:.6},{},{},{},{}\n",
                s.system,
                s.gpus,
                s.policy,
                s.tenants,
                s.jobs,
                p.rho,
                p.rate,
                p.offered,
                p.p50,
                p.p95,
                p.p999,
                p.throughput,
                p.completed,
                p.rejected,
                p.warmup,
                (i == s.knee) as u8,
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Library;
    use crate::workload::serve::QueuePolicy;
    use crate::workload::TenantLib;

    fn small_spec(gpus: usize) -> ServeSpec {
        ServeSpec::synthetic(
            2,
            6,
            gpus.min(4),
            TenantLib::Fixed(Library::Nccl),
            2 << 20,
            13,
            ArrivalProcess::Poisson { rate: 1.0 },
            QueuePolicy::Fifo { depth: 4 },
        )
    }

    #[test]
    fn study_renders_all_systems_with_a_knee() {
        let rhos = [0.25, 1.0, 1.5];
        let secs =
            study(&SystemSpec::paper_all(), Params::default(), &rhos, small_spec).unwrap();
        assert_eq!(secs.len(), 3);
        let text = render(&secs);
        for k in SystemSpec::paper_all() {
            assert!(text.contains(k.name().as_str()), "{k:?} missing:\n{text}");
        }
        assert!(text.contains("SERVE"));
        assert!(text.contains("knee"));
        for s in &secs {
            assert_eq!(s.points.len(), rhos.len());
            assert!(s.saturation > 0.0);
            assert!(s.knee < s.points.len());
            for p in &s.points {
                assert!(p.p50 > 0.0 && p.p95 >= p.p50 && p.p999 >= p.p95, "{}", s.system);
                assert!(p.completed > 0);
            }
            // offered load ascends with rho
            for w in s.points.windows(2) {
                assert!(w[1].offered > w[0].offered);
            }
        }
        let c = csv(&secs);
        assert_eq!(c.lines().count(), 1 + 3 * rhos.len());
        assert!(c.starts_with("system,"));
        assert_eq!(c.matches(",1\n").count(), 3, "exactly one knee row per system");
    }

    #[test]
    fn study_runs_on_parametric_fabrics() {
        let systems = [
            SystemSpec::MultiPlanePod { nodes: 2, gpus: 4, rails: 2 },
            SystemSpec::FatTree { k: 4 },
        ];
        let secs = study(&systems, Params::default(), &[0.5, 1.0], small_spec).unwrap();
        assert_eq!(secs.len(), 2);
        assert_eq!(secs[0].system, "pod-2x4x2");
        assert_eq!(secs[1].system, "fat-tree-k4");
        for s in &secs {
            assert!(!s.system.contains(','), "{}", s.system);
            assert!(s.points.iter().all(|p| p.completed > 0), "{}: empty curve", s.system);
        }
    }

    #[test]
    fn section_is_deterministic() {
        let topo = SystemSpec::parse("dgx1").unwrap().build();
        let spec = small_spec(8);
        let a = section(&topo, &spec, &DEFAULT_RHOS, Params::default()).unwrap();
        let b = section(&topo, &spec, &DEFAULT_RHOS, Params::default()).unwrap();
        assert_eq!(render(&[a.clone()]), render(&[b.clone()]));
        assert_eq!(csv(&[a]), csv(&[b]));
    }
}
